"""Continuous-batching scheduler, runtime monitoring, and the GP serving
runtime (deadline-driven flusher + routed hot-swap)."""
import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.configs.registry import smoke_config
from repro.launch.scheduler import ContinuousBatcher, Request
from repro.models import transformer as tf
from repro.runtime.monitor import FailureDetector, TrainMonitor

KEY = jax.random.PRNGKey(0)


class TestContinuousBatcher:
    def _make(self, slots=2, max_len=48):
        cfg = smoke_config("olmo-1b")
        params = tf.init_model(KEY, cfg)
        return ContinuousBatcher(params, cfg, slots=slots, max_len=max_len), cfg

    def test_all_requests_complete(self):
        b, cfg = self._make(slots=2)
        reqs = [Request(rid=i, prompt=[1 + i, 2, 3], max_new=4)
                for i in range(5)]
        for r in reqs:
            b.submit(r)
        done = b.run()
        assert len(done) == 5
        for r in done:
            assert len(r.out) == 4
            assert all(0 <= t < cfg.vocab_padded for t in r.out)

    def test_continuous_refill_beats_sequential_capacity(self):
        """More requests than slots still complete (slots are reused)."""
        b, _ = self._make(slots=1)
        for i in range(3):
            b.submit(Request(rid=i, prompt=[5], max_new=2))
        done = b.run()
        assert sorted(r.rid for r in done) == [0, 1, 2]

    def test_matches_unbatched_greedy(self):
        """Scheduler output == plain greedy decode for the same prompt."""
        b, cfg = self._make(slots=2)
        prompt = [7, 8, 9]
        b.submit(Request(rid=0, prompt=prompt, max_new=3))
        done = b.run()
        # reference: manual greedy loop
        state = tf.init_serve(b.cfg, 1, 48)
        logits = None
        for t in prompt:
            logits, state = tf.decode_step(b.params,
                                           jnp.asarray([[t]], jnp.int32),
                                           state, b.cfg)
        ref = []
        for _ in range(3):
            nxt = int(jnp.argmax(logits[0, -1]))
            ref.append(nxt)
            logits, state = tf.decode_step(b.params,
                                           jnp.asarray([[nxt]], jnp.int32),
                                           state, b.cfg)
        assert done[0].out == ref


class TestFailureDetector:
    def test_timeout_flags_silent_machine(self):
        t = [0.0]
        det = FailureDetector(4, timeout=1.0, clock=lambda: t[0])
        t[0] = 1.0
        for m in (0, 1, 3):
            det.heartbeat(m)
        t[0] = 1.8
        newly = det.sweep()
        assert newly == [2]
        assert det.alive_mask == [True, True, False, True]

    def test_recovery_on_heartbeat(self):
        t = [0.0]
        det = FailureDetector(2, timeout=1.0, clock=lambda: t[0])
        t[0] = 2.0
        assert det.sweep() == [0, 1]
        det.heartbeat(0)
        assert det.alive_mask == [True, False]

    def test_drives_fault_recovery(self):
        """Detector events -> summary-algebra recovery (end-to-end)."""
        from repro.core import online
        from repro.parallel.runner import VmapRunner
        from repro.runtime import fault
        from helpers import make_problem
        p = make_problem()
        cl = fault.build(p["kfn"], p["params"], p["S"], p["X"], p["y"],
                         VmapRunner(M=p["M"]))
        t = [0.0]
        det = FailureDetector(p["M"], timeout=1.0, clock=lambda: t[0])
        t[0] = 2.0
        det.heartbeat(0); det.heartbeat(2); det.heartbeat(3)
        for m in det.sweep():
            cl = fault.fail(cl, m)
        mean, _ = cl.store.predict(p["U"])
        assert bool(jnp.isfinite(mean).all())


class TestTrainMonitor:
    def test_throughput_and_ema(self):
        t = [0.0]
        mon = TrainMonitor(tokens_per_step=1000, clock=lambda: t[0])
        for i in range(5):
            t[0] += 0.1
            m = mon.step(loss=2.0 - 0.1 * i)
        assert abs(m.tokens_per_s - 10000) / 10000 < 0.05
        assert m.step == 5
        assert m.loss_ema < 2.0

    def test_stall_detection(self):
        t = [0.0]
        mon = TrainMonitor(tokens_per_step=1, stall_factor=5.0,
                           clock=lambda: t[0])
        for _ in range(3):
            t[0] += 0.1
            mon.step(1.0)
        assert not mon.is_stalled()
        t[0] += 10.0
        assert mon.is_stalled()


def test_gp_experiment_grid():
    from repro.configs.gp_experiments import PAPER_GRID, scaled_grid
    g = PAPER_GRID["sarcos"]
    assert g.rank_multiplier == 2 and g.data_sizes[-1] == 32000
    s = scaled_grid("aimpeak")
    assert s.fixed_data == 4000 and s.params[0] == 32


# ---------------------------------------------------------------------------
# GP serving runtime: deadline-driven flusher + routed hot-swap
# ---------------------------------------------------------------------------

@pytest.fixture(scope="module")
def gp_prob():
    from helpers import make_problem
    return make_problem()


@pytest.fixture(scope="module")
def gp_model(gp_prob):
    from repro.core import api
    from repro.parallel.runner import VmapRunner
    p = gp_prob
    return api.fit("ppitc", p["kfn"], p["params"], p["X"], p["y"],
                   S=p["S"], runner=VmapRunner(M=p["M"]))


class TestDeadlineFlusher:
    def _server(self, model, **kw):
        from repro.launch.gp_serve import GPServer
        t = [0.0]
        srv = GPServer(model, clock=lambda: t[0], **kw)
        return srv, t

    def test_old_ticket_resolves_on_pump(self, gp_prob, gp_model):
        """A ticket past flush_deadline_ms drains on the next pump() even
        though the queue never reached max_batch."""
        srv, t = self._server(gp_model, max_batch=8, flush_deadline_ms=50)
        ticket = srv.submit(gp_prob["U"][0])
        assert srv.pending == 1
        assert srv.pump() == 0 and srv.pending == 1     # 0ms old: not due
        t[0] += 0.049
        assert srv.pump() == 0 and srv.pending == 1     # 49ms: still not due
        t[0] += 0.002
        assert srv.pump() == 1 and srv.pending == 0     # 51ms: flushed
        assert srv.done(ticket)
        assert srv.stats.n_deadline_flushes == 1
        assert srv.stats.n_size_flushes == 0
        m, v = srv.result(ticket)
        ref_m, ref_v = gp_model.predict_diag(gp_prob["U"][:1])
        np.testing.assert_allclose(m, ref_m[0], atol=1e-12)
        np.testing.assert_allclose(v, ref_v[0], atol=1e-12)

    def test_submit_observes_deadline(self, gp_prob, gp_model):
        """An overdue queue drains on the next submit too, not only pump()."""
        srv, t = self._server(gp_model, max_batch=8, flush_deadline_ms=10)
        srv.submit(gp_prob["U"][0])
        t[0] += 0.02
        srv.submit(gp_prob["U"][1])                     # observes the age
        assert srv.pending == 0
        assert srv.stats.n_deadline_flushes == 1

    def test_no_deadline_means_size_only(self, gp_prob, gp_model):
        srv, t = self._server(gp_model, max_batch=4)
        srv.submit(gp_prob["U"][0])
        t[0] += 1e6                                      # ancient ticket
        assert srv.pump() == 0 and srv.pending == 1      # no deadline set
        for i in range(1, 4):
            srv.submit(gp_prob["U"][i])
        assert srv.pending == 0
        assert srv.stats.n_size_flushes == 1
        assert srv.stats.n_deadline_flushes == 0

    def test_trigger_split_stats(self, gp_prob, gp_model):
        srv, t = self._server(gp_model, max_batch=2, flush_deadline_ms=100)
        srv.submit(gp_prob["U"][0]); srv.submit(gp_prob["U"][1])  # size
        srv.submit(gp_prob["U"][2])
        t[0] += 0.2
        srv.pump()                                                # deadline
        srv.submit(gp_prob["U"][3])
        srv.flush()                                               # manual
        s = srv.stats
        assert (s.n_size_flushes, s.n_deadline_flushes,
                s.n_manual_flushes) == (1, 1, 1)
        assert s.n_batches == 3

    def test_oldest_age_tracks_head_of_queue(self, gp_prob, gp_model):
        srv, t = self._server(gp_model, max_batch=8, flush_deadline_ms=1e9)
        assert srv.oldest_age_ms() == 0.0
        srv.submit(gp_prob["U"][0])
        t[0] += 0.25
        srv.submit(gp_prob["U"][1])
        assert abs(srv.oldest_age_ms() - 250.0) < 1e-6

    def test_bad_trigger_rejected_before_queue_is_touched(self, gp_prob,
                                                          gp_model):
        srv, t = self._server(gp_model, max_batch=8)
        ticket = srv.submit(gp_prob["U"][0])
        with pytest.raises(ValueError, match="unknown flush trigger"):
            srv.flush(trigger="timeout")
        assert srv.pending == 1          # queue intact after the bad call
        srv.flush()
        assert srv.done(ticket)

    def test_async_resolution_blocks_only_at_result(self, gp_prob, gp_model):
        """flush() leaves device values unforced; result() materializes."""
        srv, t = self._server(gp_model, max_batch=8, flush_deadline_ms=1)
        ticket = srv.submit(gp_prob["U"][0])
        t[0] += 1.0
        srv.pump()
        m, v = srv.result(ticket)
        assert np.isfinite(float(m)) and float(v) > 0


class TestRoutedServing:
    def test_routed_requires_centroid_state(self, gp_model):
        from repro.launch.gp_serve import GPServer
        with pytest.raises(ValueError, match="predict_routed_diag"):
            GPServer(gp_model, routed=True)              # ppitc: no routing

    def test_routed_swap_rejects_centroidless_state(self, gp_prob, gp_model):
        """A routed server must reject online.to_state's PITCState at swap
        time — not AttributeError mid-flush under live traffic."""
        from repro.core import api, online, ppic
        from repro.launch.gp_serve import GPServer
        from repro.parallel.runner import VmapRunner
        p = gp_prob
        runner = VmapRunner(M=p["M"])
        st = ppic.fit(p["kfn"], p["params"], p["X"], p["y"], S=p["S"],
                      runner=runner)
        srv = GPServer(api.FittedGP(api.get("ppic"), p["kfn"], p["params"],
                                    st), max_batch=8, routed=True)
        store = online.build(p["kfn"], p["params"], p["S"], p["X"], p["y"],
                             runner)
        with pytest.raises(ValueError, match="centroids"):
            srv.swap_state(online.to_state(store, p["S"]))
        # queue survives the rejected swap; serving continues on the old state
        ticket = srv.submit(p["U"][0])
        srv.flush()
        assert srv.done(ticket)

    def test_hot_swap_routed_keeps_treedef_and_shapes(self, gp_prob):
        """Refit-and-swap under routed traffic reuses the executable: the
        new PICState has the identical treedef and leaf shapes."""
        from repro.core import api, ppic
        from repro.launch.gp_serve import GPServer
        from repro.parallel.runner import VmapRunner
        p = gp_prob
        runner = VmapRunner(M=p["M"])
        st1 = ppic.fit(p["kfn"], p["params"], p["X"], p["y"], S=p["S"],
                       runner=runner)
        model = api.FittedGP(api.get("ppic"), p["kfn"], p["params"], st1)
        srv = GPServer(model, max_batch=8, flush_deadline_ms=5, routed=True)
        m1, _ = srv.predict(p["U"][:8])

        st2 = ppic.fit(p["kfn"], p["params"], p["X"], 2.0 * p["y"],
                       S=p["S"], runner=runner)
        assert jax.tree.structure(st1) == jax.tree.structure(st2)
        assert [a.shape for a in jax.tree.leaves(st1)] == \
            [a.shape for a in jax.tree.leaves(st2)]
        srv.swap_state(st2)
        m2, v2 = srv.predict(p["U"][:8])

        ref_m, ref_v = ppic.predict_routed_diag(p["kfn"], p["params"], st2,
                                                p["U"][:8])
        np.testing.assert_allclose(m2, ref_m, atol=1e-12)
        np.testing.assert_allclose(v2, ref_v, atol=1e-12)
        assert float(jnp.abs(m2 - m1).max()) > 1e-6
        assert srv.stats.n_state_swaps == 1

    def test_routed_tickets_under_mixed_triggers(self, gp_prob):
        """Deadline + size triggers interleaved on routed traffic still
        resolve every ticket to its composition-invariant posterior."""
        from repro.core import api
        from repro.launch.gp_serve import GPServer
        from repro.parallel.runner import VmapRunner
        p = gp_prob
        model = api.fit("ppic", p["kfn"], p["params"], p["X"], p["y"],
                        S=p["S"], runner=VmapRunner(M=p["M"]))
        t = [0.0]
        srv = GPServer(model, max_batch=4, flush_deadline_ms=50,
                       routed=True, clock=lambda: t[0])
        tickets = {}
        for i in range(6):                   # 4 -> size flush, 2 left over
            tickets[i] = srv.submit(p["U"][i])
            t[0] += 0.001
        assert srv.stats.n_size_flushes == 1 and srv.pending == 2
        t[0] += 0.06
        assert srv.pump() == 2               # deadline drains the remainder
        ref_m, ref_v = model.predict_routed_diag(p["U"][:6])
        for i in range(6):
            m, v = srv.result(tickets[i])
            np.testing.assert_allclose(m, ref_m[i], atol=1e-10)
            np.testing.assert_allclose(v, ref_v[i], atol=1e-10)
