"""Continuous-batching scheduler + runtime monitoring."""
import jax
import jax.numpy as jnp

from repro.configs.registry import smoke_config
from repro.launch.scheduler import ContinuousBatcher, Request
from repro.models import transformer as tf
from repro.runtime.monitor import FailureDetector, TrainMonitor

KEY = jax.random.PRNGKey(0)


class TestContinuousBatcher:
    def _make(self, slots=2, max_len=48):
        cfg = smoke_config("olmo-1b")
        params = tf.init_model(KEY, cfg)
        return ContinuousBatcher(params, cfg, slots=slots, max_len=max_len), cfg

    def test_all_requests_complete(self):
        b, cfg = self._make(slots=2)
        reqs = [Request(rid=i, prompt=[1 + i, 2, 3], max_new=4)
                for i in range(5)]
        for r in reqs:
            b.submit(r)
        done = b.run()
        assert len(done) == 5
        for r in done:
            assert len(r.out) == 4
            assert all(0 <= t < cfg.vocab_padded for t in r.out)

    def test_continuous_refill_beats_sequential_capacity(self):
        """More requests than slots still complete (slots are reused)."""
        b, _ = self._make(slots=1)
        for i in range(3):
            b.submit(Request(rid=i, prompt=[5], max_new=2))
        done = b.run()
        assert sorted(r.rid for r in done) == [0, 1, 2]

    def test_matches_unbatched_greedy(self):
        """Scheduler output == plain greedy decode for the same prompt."""
        b, cfg = self._make(slots=2)
        prompt = [7, 8, 9]
        b.submit(Request(rid=0, prompt=prompt, max_new=3))
        done = b.run()
        # reference: manual greedy loop
        state = tf.init_serve(b.cfg, 1, 48)
        logits = None
        for t in prompt:
            logits, state = tf.decode_step(b.params,
                                           jnp.asarray([[t]], jnp.int32),
                                           state, b.cfg)
        ref = []
        for _ in range(3):
            nxt = int(jnp.argmax(logits[0, -1]))
            ref.append(nxt)
            logits, state = tf.decode_step(b.params,
                                           jnp.asarray([[nxt]], jnp.int32),
                                           state, b.cfg)
        assert done[0].out == ref


class TestFailureDetector:
    def test_timeout_flags_silent_machine(self):
        t = [0.0]
        det = FailureDetector(4, timeout=1.0, clock=lambda: t[0])
        t[0] = 1.0
        for m in (0, 1, 3):
            det.heartbeat(m)
        t[0] = 1.8
        newly = det.sweep()
        assert newly == [2]
        assert det.alive_mask == [True, True, False, True]

    def test_recovery_on_heartbeat(self):
        t = [0.0]
        det = FailureDetector(2, timeout=1.0, clock=lambda: t[0])
        t[0] = 2.0
        assert det.sweep() == [0, 1]
        det.heartbeat(0)
        assert det.alive_mask == [True, False]

    def test_drives_fault_recovery(self):
        """Detector events -> summary-algebra recovery (end-to-end)."""
        from repro.core import covariance as cov, online
        from repro.parallel.runner import VmapRunner
        from repro.runtime import fault
        from helpers import make_problem
        p = make_problem()
        cl = fault.build(p["kfn"], p["params"], p["S"], p["X"], p["y"],
                         VmapRunner(M=p["M"]))
        t = [0.0]
        det = FailureDetector(p["M"], timeout=1.0, clock=lambda: t[0])
        t[0] = 2.0
        det.heartbeat(0); det.heartbeat(2); det.heartbeat(3)
        for m in det.sweep():
            cl = fault.fail(cl, m)
        mean, _ = online.predict_ppitc(cl.store, p["kfn"], p["params"],
                                       p["S"], p["U"])
        assert bool(jnp.isfinite(mean).all())


class TestTrainMonitor:
    def test_throughput_and_ema(self):
        t = [0.0]
        mon = TrainMonitor(tokens_per_step=1000, clock=lambda: t[0])
        for i in range(5):
            t[0] += 0.1
            m = mon.step(loss=2.0 - 0.1 * i)
        assert abs(m.tokens_per_s - 10000) / 10000 < 0.05
        assert m.step == 5
        assert m.loss_ema < 2.0

    def test_stall_detection(self):
        t = [0.0]
        mon = TrainMonitor(tokens_per_step=1, stall_factor=5.0,
                           clock=lambda: t[0])
        for _ in range(3):
            t[0] += 0.1
            mon.step(1.0)
        assert not mon.is_stalled()
        t[0] += 10.0
        assert mon.is_stalled()


def test_gp_experiment_grid():
    from repro.configs.gp_experiments import PAPER_GRID, scaled_grid
    g = PAPER_GRID["sarcos"]
    assert g.rank_multiplier == 2 and g.data_sizes[-1] == 32000
    s = scaled_grid("aimpeak")
    assert s.fixed_data == 4000 and s.params[0] == 32
