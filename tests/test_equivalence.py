"""Numerical verification of the paper's Theorems 1-3: the parallel methods
equal their centralized counterparts (float64, tolerances dominated by the
relative jitter in the PSD solves)."""
import jax.numpy as jnp
import numpy as np
import pytest

from repro.core import icf, picf, pitc, ppic, ppitc
from repro.parallel.runner import VmapRunner

from helpers import block_diag_err, make_problem

TOL = 5e-6


@pytest.fixture(scope="module")
def prob():
    return make_problem()


@pytest.fixture(scope="module")
def runner(prob):
    return VmapRunner(M=prob["M"])


class TestTheorem1:
    def test_ppitc_equals_pitc(self, prob, runner):
        p = pitc.pitc_predict_literal(prob["kfn"], prob["params"], prob["S"],
                                      prob["X"], prob["y"], prob["U"],
                                      prob["M"])
        q = ppitc.predict(prob["kfn"], prob["params"], prob["S"], prob["X"],
                          prob["y"], prob["U"], runner)
        np.testing.assert_allclose(q.mean, p.mean, atol=TOL)
        assert block_diag_err(p.cov, q.blocks) < TOL

    def test_blockwise_centralized_matches(self, prob):
        p = pitc.pitc_predict_literal(prob["kfn"], prob["params"], prob["S"],
                                      prob["X"], prob["y"], prob["U"],
                                      prob["M"])
        q = pitc.pitc_predict_blockwise(prob["kfn"], prob["params"],
                                        prob["S"], prob["X"], prob["y"],
                                        prob["U"], prob["M"])
        np.testing.assert_allclose(q.mean, p.mean, atol=TOL)
        np.testing.assert_allclose(q.cov, p.cov, atol=TOL)

    def test_support_equals_data_recovers_fgp(self, prob, runner):
        """PITC with S = D is exact: Gamma_DD = K_DD, Lambda = noise I."""
        from repro.core import gp
        exact = gp.predict(prob["kfn"], prob["params"], prob["X"], prob["y"],
                           prob["U"])
        q = ppitc.predict(prob["kfn"], prob["params"], prob["X"], prob["X"],
                          prob["y"], prob["U"], runner)
        np.testing.assert_allclose(q.mean, exact.mean, atol=1e-4)


class TestTheorem2:
    def test_ppic_equals_pic(self, prob, runner):
        p = pitc.pic_predict_literal(prob["kfn"], prob["params"], prob["S"],
                                     prob["X"], prob["y"], prob["U"],
                                     prob["M"])
        q = ppic.predict(prob["kfn"], prob["params"], prob["S"], prob["X"],
                         prob["y"], prob["U"], runner)
        np.testing.assert_allclose(q.mean, p.mean, atol=TOL)
        assert block_diag_err(p.cov, q.blocks) < TOL

    def test_blockwise_centralized_matches(self, prob):
        p = pitc.pic_predict_literal(prob["kfn"], prob["params"], prob["S"],
                                     prob["X"], prob["y"], prob["U"],
                                     prob["M"])
        q = pitc.pic_predict_blockwise(prob["kfn"], prob["params"], prob["S"],
                                       prob["X"], prob["y"], prob["U"],
                                       prob["M"])
        np.testing.assert_allclose(q.mean, p.mean, atol=TOL)
        # blockwise returns a dense block-diagonal cov; compare its blocks
        M, u = prob["M"], prob["U"].shape[0]
        b = u // M
        blocks = jnp.stack([q.cov[m * b:(m + 1) * b, m * b:(m + 1) * b]
                            for m in range(M)])
        assert block_diag_err(p.cov, blocks) < TOL


class TestTheorem3:
    R = 48

    def test_distributed_factor_matches_centralized(self, prob, runner):
        fc = icf.icf_factor(prob["kfn"], prob["params"], prob["X"], self.R)
        fp = picf.factor(prob["kfn"], prob["params"], prob["X"], self.R,
                         runner)
        F = jnp.concatenate(list(fp.F), axis=1)
        np.testing.assert_allclose(F, fc.F, atol=1e-9)

    def test_picf_equals_icf(self, prob, runner):
        fc = icf.icf_factor(prob["kfn"], prob["params"], prob["X"], self.R)
        p = icf.icf_predict_literal(prob["kfn"], prob["params"], prob["X"],
                                    prob["y"], prob["U"], fc.F)
        q = picf.predict(prob["kfn"], prob["params"], prob["X"], prob["y"],
                         prob["U"], self.R, runner)
        np.testing.assert_allclose(q.mean, p.mean, atol=1e-9)
        np.testing.assert_allclose(q.cov, p.cov, atol=1e-9)

    def test_picf_sharded_u_matches(self, prob, runner):
        fc = icf.icf_factor(prob["kfn"], prob["params"], prob["X"], self.R)
        p = icf.icf_predict_literal(prob["kfn"], prob["params"], prob["X"],
                                    prob["y"], prob["U"], fc.F)
        q = picf.predict(prob["kfn"], prob["params"], prob["X"], prob["y"],
                         prob["U"], self.R, runner, shard_u=True)
        np.testing.assert_allclose(q.mean, p.mean, atol=1e-9)
        assert block_diag_err(p.cov, q.blocks) < 1e-9

    def test_full_rank_icf_recovers_fgp(self, prob, runner):
        """R = |D| makes the ICF exact, so pICF == FGP."""
        from repro.core import gp
        exact = gp.predict(prob["kfn"], prob["params"], prob["X"], prob["y"],
                           prob["U"])
        q = picf.predict(prob["kfn"], prob["params"], prob["X"], prob["y"],
                         prob["U"], prob["X"].shape[0], runner)
        np.testing.assert_allclose(q.mean, exact.mean, atol=1e-5)
        np.testing.assert_allclose(q.cov, exact.cov, atol=1e-5)
