"""Test helpers: canonical small GP problem generators."""
from __future__ import annotations

import jax
import jax.numpy as jnp

from repro.core import covariance as cov


def make_problem(*, n=96, u=24, s=12, d=3, M=4, noise=0.3, lengthscale=1.5,
                 seed=0, dtype=jnp.float64):
    """Random smooth regression problem sized for M machines."""
    k0, k1, k2, k3 = jax.random.split(jax.random.PRNGKey(seed), 4)
    X = jax.random.normal(k0, (n, d), dtype)
    S = jax.random.normal(k1, (s, d), dtype)
    U = jax.random.normal(k2, (u, d), dtype)
    params = cov.init_params(d, signal=1.3, noise=noise,
                             lengthscale=lengthscale, dtype=dtype)
    f = lambda Z: jnp.sin(Z[:, 0]) * 2.0 + Z[:, 1] - 0.5 * Z[:, 2] ** 2
    y = f(X) + noise * jax.random.normal(k3, (n,), dtype)
    return dict(X=X, y=y, S=S, U=U, f=f, params=params,
                kfn=cov.make_kernel("se"), M=M)


def block_diag_err(full_cov, blocks):
    """max |diag-block difference| between a dense cov and stacked blocks."""
    M, b, _ = blocks.shape
    errs = []
    for m in range(M):
        sl = slice(m * b, (m + 1) * b)
        errs.append(jnp.abs(full_cov[sl, sl] - blocks[m]).max())
    return float(jnp.stack(errs).max())
