"""Test helpers: canonical small GP problem generators + a ``hypothesis``
fallback shim so the suite collects in offline environments."""
from __future__ import annotations

import functools
import inspect
import random
import sys
import types

import jax
import jax.numpy as jnp

from repro.core import covariance as cov


def install_hypothesis_shim() -> None:
    """Make ``from hypothesis import given, settings, strategies`` work
    without the real package (unavailable offline).

    The shim replays each property test as a small number of seeded random
    draws (deterministic across runs — ``random.Random(0)``), which keeps the
    property tests meaningful where hypothesis is missing while using the
    real engine whenever it is installed. Called from conftest.py before
    test modules import.
    """
    try:
        import hypothesis  # noqa: F401
        return
    except ImportError:
        pass

    class _Strategy:
        def __init__(self, draw):
            self.draw = draw

    st = types.ModuleType("hypothesis.strategies")
    st.integers = lambda min_value=0, max_value=2**16: _Strategy(
        lambda r: r.randint(min_value, max_value))
    st.floats = lambda min_value=0.0, max_value=1.0: _Strategy(
        lambda r: r.uniform(min_value, max_value))
    st.sampled_from = lambda seq: _Strategy(lambda r: r.choice(list(seq)))
    st.booleans = lambda: _Strategy(lambda r: bool(r.getrandbits(1)))

    def given(**strategies):
        def deco(f):
            @functools.wraps(f)
            def wrapper(*args, **kwargs):
                n = getattr(wrapper, "_shim_max_examples", 10)
                rng = random.Random(0)
                for _ in range(n):
                    drawn = {k: s.draw(rng) for k, s in strategies.items()}
                    f(*args, **drawn, **kwargs)
            # hide the drawn parameters from pytest's fixture resolution
            sig = inspect.signature(f)
            wrapper.__signature__ = sig.replace(parameters=[
                p for name, p in sig.parameters.items()
                if name not in strategies])
            return wrapper
        return deco

    def settings(**kwargs):
        def deco(f):
            f._shim_max_examples = kwargs.get("max_examples", 10)
            return f
        return deco

    mod = types.ModuleType("hypothesis")
    mod.given = given
    mod.settings = settings
    mod.strategies = st
    mod.__shim__ = True
    sys.modules["hypothesis"] = mod
    sys.modules["hypothesis.strategies"] = st


def make_problem(*, n=96, u=24, s=12, d=3, M=4, noise=0.3, lengthscale=1.5,
                 seed=0, dtype=jnp.float64):
    """Random smooth regression problem sized for M machines."""
    k0, k1, k2, k3 = jax.random.split(jax.random.PRNGKey(seed), 4)
    X = jax.random.normal(k0, (n, d), dtype)
    S = jax.random.normal(k1, (s, d), dtype)
    U = jax.random.normal(k2, (u, d), dtype)
    params = cov.init_params(d, signal=1.3, noise=noise,
                             lengthscale=lengthscale, dtype=dtype)
    f = lambda Z: jnp.sin(Z[:, 0]) * 2.0 + Z[:, 1] - 0.5 * Z[:, 2] ** 2
    y = f(X) + noise * jax.random.normal(k3, (n,), dtype)
    return dict(X=X, y=y, S=S, U=U, f=f, params=params,
                kfn=cov.make_kernel("se"), M=M)


def block_diag_err(full_cov, blocks):
    """max |diag-block difference| between a dense cov and stacked blocks."""
    M, b, _ = blocks.shape
    errs = []
    for m in range(M):
        sl = slice(m * b, (m + 1) * b)
        errs.append(jnp.abs(full_cov[sl, sl] - blocks[m]).max())
    return float(jnp.stack(errs).max())
