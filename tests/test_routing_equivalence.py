"""Property-based equivalence suite for centroid-routed pPIC serving.

The served posterior must be a pure function of (query point, fitted state):
Remark 2 says a query belongs to the block whose local data best explains
it, not to whichever block its *position in the arriving batch* happens to
map to. The properties locked down here:

* permutation invariance — bitwise: reordering a query batch permutes the
  outputs and changes nothing else (same shapes -> same executable -> same
  floating-point program per row);
* re-chunking invariance — serving the same query set in chunks of any size
  (different shapes, hence different padded executables) agrees to float64
  roundoff;
* centralized equivalence — routed pPIC from cached factors equals the
  literal centralized PIC oracle (eqs. 15-18) with eq. (18)'s i = m branch
  selected by the same nearest-centroid assignment;
* the positional path is *not* composition-invariant (the motivating bug);
* the routed GPServer resolves every ticket to the routed posterior no
  matter the arrival order.

Runs under real hypothesis when installed, else the seeded shim
(tests/helpers.py) replays each property as deterministic random draws.
"""
import jax.numpy as jnp
import numpy as np
import pytest
from hypothesis import given, settings, strategies as st

from repro.core import api, pitc, ppic
from repro.launch.gp_serve import GPServer
from repro.parallel.runner import (VmapRunner, gather_two_bucket,
                                   routed_capacity, scatter_two_bucket)

from helpers import make_problem

ORACLE_TOL = 5e-6       # matches tests/test_equivalence.py (PSD-solve jitter)
RECHUNK_TOL = 1e-10     # float64 roundoff across differently-padded shapes


@pytest.fixture(scope="module")
def prob():
    return make_problem()


@pytest.fixture(scope="module")
def state(prob):
    return ppic.fit(prob["kfn"], prob["params"], prob["X"], prob["y"],
                    S=prob["S"], runner=VmapRunner(M=prob["M"]))


@pytest.fixture(scope="module")
def base(prob, state):
    """Reference routed posterior of the full query set, served whole."""
    return ppic.predict_routed_diag(prob["kfn"], prob["params"], state,
                                    prob["U"])


class TestRoutingInvariance:
    @settings(max_examples=10)
    @given(seed=st.integers(min_value=0, max_value=2**31 - 1))
    def test_permutation_is_bitwise_invariant(self, prob, state, base, seed):
        perm = np.random.RandomState(seed).permutation(prob["U"].shape[0])
        m, v = ppic.predict_routed_diag(prob["kfn"], prob["params"], state,
                                        prob["U"][perm])
        np.testing.assert_array_equal(np.asarray(m), np.asarray(base[0])[perm])
        np.testing.assert_array_equal(np.asarray(v), np.asarray(base[1])[perm])

    @settings(max_examples=10)
    @given(seed=st.integers(min_value=0, max_value=2**31 - 1),
           chunk=st.integers(min_value=1, max_value=11))
    def test_rechunking_is_invariant(self, prob, state, base, seed, chunk):
        """Permute AND re-chunk: serving the set in arbitrary microbatches
        reproduces the whole-batch posterior (shapes differ, so only
        roundoff-level agreement is guaranteed)."""
        u = prob["U"].shape[0]
        perm = np.random.RandomState(seed).permutation(u)
        Up = prob["U"][perm]
        parts = [ppic.predict_routed_diag(prob["kfn"], prob["params"], state,
                                          Up[i:i + chunk])
                 for i in range(0, u, chunk)]
        m = jnp.concatenate([p[0] for p in parts])
        v = jnp.concatenate([p[1] for p in parts])
        np.testing.assert_allclose(m, np.asarray(base[0])[perm],
                                   atol=RECHUNK_TOL)
        np.testing.assert_allclose(v, np.asarray(base[1])[perm],
                                   atol=RECHUNK_TOL)

    def test_routing_is_pure_in_the_query(self, prob, state):
        """Assignment of a query never depends on its neighbours."""
        whole = np.asarray(ppic.route_queries(state, prob["U"]))
        for i in range(prob["U"].shape[0]):
            one = np.asarray(ppic.route_queries(state, prob["U"][i:i + 1]))
            assert one[0] == whole[i]

    def test_positional_path_is_composition_dependent(self, prob, state):
        """The motivating defect: predict_batch_diag's per-query posterior
        moves when the batch is permuted (queries land on other blocks)."""
        m, _ = ppic.predict_batch_diag(prob["kfn"], prob["params"], state,
                                       prob["U"])
        perm = np.random.RandomState(0).permutation(prob["U"].shape[0])
        mp, _ = ppic.predict_batch_diag(prob["kfn"], prob["params"], state,
                                        prob["U"][perm])
        assert float(jnp.abs(mp - jnp.asarray(np.asarray(m)[perm])).max()) \
            > 1e-6


class TestRoutedEqualsCentralizedPIC:
    def test_matches_routed_literal_oracle(self, prob, state, base):
        """Thm 2 + Remark 2: cached-factor routed pPIC == literal centralized
        PIC with the same per-query block choice in eq. (18)."""
        assign = ppic.route_queries(state, prob["U"])
        oracle = pitc.pic_predict_literal_routed(
            prob["kfn"], prob["params"], prob["S"], prob["X"], prob["y"],
            prob["U"], prob["M"], assign)
        np.testing.assert_allclose(base[0], oracle.mean, atol=ORACLE_TOL)
        np.testing.assert_allclose(base[1], jnp.diag(oracle.cov),
                                   atol=ORACLE_TOL)

    def test_full_cov_view_agrees_with_diag(self, prob, state, base):
        post = ppic.predict_routed(prob["kfn"], prob["params"], state,
                                   prob["U"])
        np.testing.assert_allclose(post.mean, base[0], atol=1e-12)
        np.testing.assert_allclose(jnp.diag(post.cov), base[1], atol=1e-10)

    def test_within_block_cov_matches_oracle(self, prob, state):
        """Same-block off-diagonal entries come from eqs. (12)-(14) too."""
        assign = np.asarray(ppic.route_queries(state, prob["U"]))
        post = ppic.predict_routed(prob["kfn"], prob["params"], state,
                                   prob["U"])
        oracle = pitc.pic_predict_literal_routed(
            prob["kfn"], prob["params"], prob["S"], prob["X"], prob["y"],
            prob["U"], prob["M"], assign)
        same = assign[:, None] == assign[None, :]
        diff = np.abs(np.asarray(post.cov) - np.asarray(oracle.cov))
        assert float(diff[same].max()) < ORACLE_TOL


class TestTwoBucketScatter:
    """The capacity-bounded routed layout (runner.scatter_two_bucket): the
    serving path computes (M + G)·cap rows instead of M·|U| but must emit
    THE SAME posterior as the capacity-|U| layout, because every predictive
    equation is row-independent and overflow groups carry their block's
    factors.

    Bitwise equality across the two layouts is asserted in float32 (the
    serving dtype). In float64 the layouts differ by LAPACK-width roundoff
    only (~1e-13): CPU trsm picks its column-panel strategy from the TOTAL
    RHS width, so a (b, cap) solve and a (b, |U|) solve give per-column
    results that agree to roundoff, not bit-for-bit. WITHIN a layout,
    permutation invariance stays bitwise in both dtypes (the core PR-2
    property, preserved by keeping every query-axis contraction row-major —
    see _block_posterior_diag)."""

    F64_LAYOUT_TOL = 1e-12

    @pytest.fixture(scope="class")
    def prob32(self):
        return make_problem(dtype=jnp.float32)

    @pytest.fixture(scope="class")
    def state32(self, prob32):
        return ppic.fit(prob32["kfn"], prob32["params"], prob32["X"],
                        prob32["y"], S=prob32["S"],
                        runner=VmapRunner(M=prob32["M"]))

    @settings(max_examples=10)
    @given(seed=st.integers(min_value=0, max_value=2**31 - 1))
    def test_two_bucket_equals_capacity_layout_bitwise_f32(self, prob32,
                                                           state32, seed):
        perm = np.random.RandomState(seed).permutation(
            prob32["U"].shape[0])
        Up = prob32["U"][perm]
        m_c, v_c = ppic.predict_routed_diag_capacity(
            prob32["kfn"], prob32["params"], state32, Up)
        m_t, v_t = ppic.predict_routed_diag(prob32["kfn"], prob32["params"],
                                            state32, Up)
        np.testing.assert_array_equal(np.asarray(m_t), np.asarray(m_c))
        np.testing.assert_array_equal(np.asarray(v_t), np.asarray(v_c))

    @settings(max_examples=10)
    @given(seed=st.integers(min_value=0, max_value=2**31 - 1))
    def test_two_bucket_equals_capacity_layout_f64(self, prob, state, seed):
        perm = np.random.RandomState(seed).permutation(prob["U"].shape[0])
        Up = prob["U"][perm]
        m_c, v_c = ppic.predict_routed_diag_capacity(
            prob["kfn"], prob["params"], state, Up)
        m_t, v_t = ppic.predict_routed_diag(prob["kfn"], prob["params"],
                                            state, Up)
        np.testing.assert_allclose(m_t, m_c, atol=self.F64_LAYOUT_TOL)
        np.testing.assert_allclose(v_t, v_c, atol=self.F64_LAYOUT_TOL)

    def test_skewed_traffic_overflows_and_still_matches(self, prob32,
                                                        state32):
        """All queries on one centroid: the main bucket overflows into the
        skew groups, which must serve the SAME block program (bitwise)."""
        c0 = np.asarray(state32.centroids)[0]
        rng = np.random.RandomState(7)
        Uskew = jnp.asarray(
            c0[None, :] + 0.01 * rng.randn(20, c0.shape[0]).astype("f4"))
        assign = np.asarray(ppic.route_queries(state32, Uskew))
        assert (assign == assign[0]).all()          # genuinely skewed
        cap, G = routed_capacity(20, prob32["M"])
        assert G > 0 and cap < 20                   # overflow exercised
        m_c, v_c = ppic.predict_routed_diag_capacity(
            prob32["kfn"], prob32["params"], state32, Uskew)
        m_t, v_t = ppic.predict_routed_diag(prob32["kfn"], prob32["params"],
                                            state32, Uskew)
        np.testing.assert_array_equal(np.asarray(m_t), np.asarray(m_c))
        np.testing.assert_array_equal(np.asarray(v_t), np.asarray(v_c))

    @settings(max_examples=10)
    @given(seed=st.integers(min_value=0, max_value=2**31 - 1),
           n=st.integers(min_value=1, max_value=40),
           m=st.integers(min_value=1, max_value=9))
    def test_scatter_gather_roundtrip(self, seed, n, m):
        """Every row lands in exactly one bucket slot and gathers back."""
        rng = np.random.RandomState(seed)
        X = jnp.asarray(rng.randn(n, 3))
        assign = jnp.asarray(rng.randint(0, m, size=n))
        lay = scatter_two_bucket(X, assign, m)
        # row identity: first coordinate survives the scatter+gather
        out = gather_two_bucket(lay.Xb[..., 0],
                                None if lay.Xo is None else lay.Xo[..., 0],
                                lay)
        np.testing.assert_array_equal(np.asarray(out), np.asarray(X[:, 0]))
        # overflow groups are single-block: each occupied slot's row was
        # assigned to the group's recorded block
        if lay.Xo is not None:
            a = np.asarray(assign)
            o_blk = np.asarray(lay.o_blk)
            order = np.asarray(lay.order)
            for j in range(n):
                if not bool(lay.in_main[j]):
                    assert a[order[j]] == o_blk[int(lay.group[j])]

    def test_padded_rows_reduction_at_m8(self):
        """ISSUE acceptance: >= 2x fewer computed rows than capacity-|U| at
        M=8 balanced traffic (alpha=2: (8+4)·cap vs 8·n)."""
        for n in (32, 64, 256):
            cap, G = routed_capacity(n, 8)
            assert 8 * n / ((8 + G) * cap) >= 2.0

    def test_tile_alignment(self):
        cap, _ = routed_capacity(50, 8, tile=16)
        assert cap % 16 == 0


class TestRegistryAndServer:
    def test_registry_exposes_routed_for_pic_family(self, prob):
        assert api.get("ppic").predict_routed_diag_fn is not None
        assert api.get("pic").predict_routed_diag_fn is not None
        assert api.get("ppitc").predict_routed_diag_fn is None

    def test_fitted_gp_routed_guard(self, prob):
        runner = VmapRunner(M=prob["M"])
        model = api.fit("ppitc", prob["kfn"], prob["params"], prob["X"],
                        prob["y"], S=prob["S"], runner=runner)
        with pytest.raises(ValueError, match="no routed prediction"):
            model.predict_routed_diag(prob["U"])

    @settings(max_examples=5)
    @given(seed=st.integers(min_value=0, max_value=2**31 - 1))
    def test_server_resolves_tickets_order_independently(self, prob, state,
                                                         seed):
        """Routed GPServer: any arrival order yields the same per-ticket
        posterior (bitwise) as the server's own compiled predict on the
        whole set. The reference goes through the SAME jitted function the
        flush dispatches — XLA's jit fuses covariance assembly differently
        from op-by-op eager execution (1-ulp differences in K_US itself),
        so eager-vs-jit bit equality was never the property; arrival-order
        independence of the compiled program is."""
        model = api.FittedGP(api.get("ppic"), prob["kfn"], prob["params"],
                             state)
        srv = GPServer(model, max_batch=8, routed=True)
        perm = np.random.RandomState(seed).permutation(8)
        tickets = {int(i): srv.submit(prob["U"][int(i)]) for i in perm}
        ref_m, ref_v = srv.plan.routed_diag(prob["U"][:8])
        for i in range(8):
            m, v = srv.result(tickets[i])
            np.testing.assert_array_equal(np.asarray(m), np.asarray(ref_m[i]))
            np.testing.assert_array_equal(np.asarray(v), np.asarray(ref_v[i]))
