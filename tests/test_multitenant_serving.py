"""Elastic multi-tenant serving runtime (repro/serving/).

Acceptance (ISSUE 8):

* a tenant served through the multiplexed runtime is BITWISE-equal (f32)
  to the same tenant served alone through its own ``GPServer`` — the
  single-tenant server IS a one-tenant client of the scheduler, and
  multiplexing other tenants in between must not perturb anyone's batches;
* plan-compatible tenants share ONE executable lineage: the trace-count
  probe shows zero recompiles across tenant interleavings at fixed shapes;
* weighted-deadline dispatch: earliest weighted due time first, no
  starvation under skewed weights, ordering invariant under submission
  permutation (hypothesis properties — the offline shim replays them as
  seeded draws);
* admission control (reject / shed_oldest) and the adaptive flusher are
  observable through per-tenant ``ServeStats`` and the fleet rollup;
* a ``save_store(..., spec=...)`` artifact re-admits the whole deployment
  (``TenantRegistry.admit_from_checkpoint``), bitwise.
"""

import jax.numpy as jnp
import numpy as np
import pytest
from hypothesis import given, settings
from hypothesis import strategies as st

from repro.core import api, serialize
from repro.core import covariance as cov
from repro.launch.gp_serve import GPServer
from repro.parallel.runner import VmapRunner
from repro.serving import (AdaptiveDeadline, AdmissionError, Ema, Reservoir,
                           ServeStats, TenantRegistry, TenantScheduler,
                           lineage_key, rollup)

from helpers import make_problem


@pytest.fixture(scope="module")
def prob():
    return make_problem(dtype=jnp.float32)


@pytest.fixture(scope="module")
def runner(prob):
    return VmapRunner(M=prob["M"])


def _fit(prob, runner, *, roll=0):
    """A ppic posterior; ``roll`` shifts y so tenants differ in VALUES
    while keeping identical tree structure (the lineage-sharing case)."""
    y = jnp.roll(prob["y"], roll)
    return api.fit("ppic", prob["kfn"], prob["params"], prob["X"], y,
                   S=prob["S"], runner=runner)


@pytest.fixture(scope="module")
def models(prob, runner):
    return [_fit(prob, runner, roll=r) for r in (0, 7, 19)]


def _sched(clock):
    return TenantScheduler(clock=clock)


# ---------------------------------------------------------------------------
# Registry: membership + lineage dedup
# ---------------------------------------------------------------------------

class TestRegistryLineage:
    def test_compatible_tenants_share_one_lineage(self, models):
        spec = api.ServeSpec(max_batch=8)
        reg = TenantRegistry()
        a = reg.admit("a", models[0], spec)
        b = reg.admit("b", models[1], spec)
        assert reg.n_lineages == 1
        assert a.plan._exec is b.plan._exec
        assert a.plan.stats is b.plan.stats
        # independent posteriors: the shared executables, not shared state
        assert a.plan.state is models[0].state
        assert b.plan.state is models[1].state
        assert lineage_key(models[0], spec) == lineage_key(models[1], spec)

    def test_incompatible_specs_fork_lineages(self, models):
        reg = TenantRegistry()
        a = reg.admit("a", models[0], api.ServeSpec(max_batch=8))
        b = reg.admit("b", models[1], api.ServeSpec(max_batch=16))
        assert reg.n_lineages == 2
        assert a.plan._exec is not b.plan._exec

    def test_zero_recompiles_across_interleavings(self, prob, models):
        """The acceptance probe: after each tenant has served one batch of
        a given shape, ANY further interleaving of tenants at fixed shapes
        adds zero traces to the shared lineage."""
        spec = api.ServeSpec(max_batch=8)
        sched = _sched(lambda: 0.0)
        for tid, m in zip("abc", models):
            sched.admit(tid, m, spec)
        U = prob["U"][:5]
        sched.predict("a", U)               # first dispatch pays the traces
        traces = sched.registry.get("a").plan.stats.n_traces
        for tid in "bacbcabccba":
            sched.predict(tid, U)
        assert sched.registry.get("a").plan.stats.n_traces == traces

    def test_evict_keeps_lineage_for_survivors(self, prob, models):
        spec = api.ServeSpec(max_batch=8)
        sched = _sched(lambda: 0.0)
        sched.admit("a", models[0], spec)
        sched.admit("b", models[1], spec)
        sched.predict("b", prob["U"][:5])
        traces = sched.registry.get("b").plan.stats.n_traces
        sched.evict("a")
        assert "a" not in sched.registry and len(sched.registry) == 1
        assert sched.registry.n_lineages == 1
        # re-admission rejoins the surviving lineage: still zero recompiles
        sched.admit("a2", models[2], spec)
        sched.predict("a2", prob["U"][:5])
        assert sched.registry.get("a2").plan.stats.n_traces == traces

    def test_evict_drains_pending_tickets(self, prob, models):
        sched = _sched(lambda: 0.0)
        sched.admit("a", models[0], api.ServeSpec(max_batch=8))
        t = sched.submit("a", prob["U"][0])
        rec = sched.evict("a")
        assert t in rec.ready          # drained, not abandoned
        with pytest.raises(KeyError, match="unknown tenant"):
            sched.submit("a", prob["U"][0])

    def test_admission_guards(self, prob, runner, models):
        reg = TenantRegistry()
        reg.admit("a", models[0], api.ServeSpec(max_batch=8))
        with pytest.raises(ValueError, match="already admitted"):
            reg.admit("a", models[1], api.ServeSpec(max_batch=8))
        with pytest.raises(ValueError, match="weight"):
            reg.admit("w", models[1], api.ServeSpec(max_batch=8), weight=0.0)
        with pytest.raises(ValueError, match="overflow"):
            reg.admit("o", models[1], api.ServeSpec(max_batch=8),
                      overflow="drop_newest")
        ppitc = api.fit("ppitc", prob["kfn"], prob["params"], prob["X"],
                        prob["y"], S=prob["S"], runner=runner)
        with pytest.raises(ValueError, match="predict_routed_diag"):
            reg.admit("r", ppitc, api.ServeSpec(max_batch=8, routed=True))

    def test_rebind_swaps_one_tenant_only(self, prob, models):
        spec = api.ServeSpec(max_batch=8)
        sched = _sched(lambda: 0.0)
        sched.admit("a", models[0], spec)
        sched.admit("b", models[1], spec)
        U = prob["U"][:5]
        mb0, vb0 = sched.predict("b", U)
        traces = sched.registry.get("a").plan.stats.n_traces
        sched.swap_state("a", models[2].state)
        ma, va = sched.predict("a", U)
        mref, vref = sched.predict("b", U)    # b untouched, bitwise
        np.testing.assert_array_equal(np.asarray(mref), np.asarray(mb0))
        np.testing.assert_array_equal(np.asarray(vref), np.asarray(vb0))
        # the swap rebound, it did not recompile
        assert sched.registry.get("a").plan.stats.n_traces == traces
        assert sched.stats("a").n_state_swaps == 1


# ---------------------------------------------------------------------------
# Bitwise multiplexed-vs-isolated equivalence (the ground truth)
# ---------------------------------------------------------------------------

def _mux_vs_isolated(prob, models, events, *, deadline_ms=50.0,
                     max_batch=4, pump_every=3):
    """Drive the same per-tenant event sequence through (1) one multiplexed
    scheduler and (2) one isolated GPServer per tenant, on the same virtual
    clock, and require bitwise-identical results per ticket."""
    tids = sorted({tid for tid, _ in events})
    clk = [0.0]
    clock = lambda: clk[0]
    sched = _sched(clock)
    for i, tid in enumerate(tids):
        sched.admit(tid, models[i], api.ServeSpec(max_batch=max_batch),
                    flush_deadline_ms=deadline_ms)
    solo = {tid: GPServer(models[i], spec=api.ServeSpec(max_batch=max_batch),
                          flush_deadline_ms=deadline_ms, clock=clock)
            for i, tid in enumerate(tids)}
    mux_tickets, solo_tickets = [], []
    for step, (tid, dt) in enumerate(events):
        clk[0] += dt
        x = prob["U"][step % prob["U"].shape[0]]
        mux_tickets.append((tid, sched.submit(tid, x)))
        solo_tickets.append((tid, solo[tid].submit(x)))
        if step % pump_every == pump_every - 1:
            sched.pump()
            for srv in solo.values():
                srv.pump()
    for (tid, tk_m), (_, tk_s) in zip(mux_tickets, solo_tickets):
        assert tk_m == tk_s            # per-tenant ticket namespaces agree
        mm, vm = sched.result(tid, tk_m)
        ms, vs = solo[tid].result(tk_s)
        np.testing.assert_array_equal(np.asarray(mm), np.asarray(ms))
        np.testing.assert_array_equal(np.asarray(vm), np.asarray(vs))


class TestBitwiseEquivalence:
    def test_multiplexed_equals_isolated_interleaved(self, prob, models):
        events = [("a", 0.001), ("b", 0.0), ("a", 0.002), ("c", 0.001),
                  ("b", 0.0), ("a", 0.0), ("c", 0.03), ("b", 0.001),
                  ("a", 0.06), ("b", 0.0), ("c", 0.0), ("a", 0.001),
                  ("b", 0.002), ("c", 0.001), ("a", 0.0), ("b", 0.03)]
        _mux_vs_isolated(prob, models, events)

    @settings(max_examples=5)
    @given(seed=st.integers(min_value=0, max_value=2**31 - 1))
    def test_multiplexed_equals_isolated_random_traffic(self, prob, models,
                                                        seed):
        r = np.random.RandomState(seed)
        tids = ["a", "b", "c"]
        events = [(tids[r.randint(3)], float(r.choice([0.0, 1e-3, 0.03])))
                  for _ in range(24)]
        _mux_vs_isolated(prob, models, events,
                         max_batch=int(r.choice([3, 4, 8])))


# ---------------------------------------------------------------------------
# Weighted-deadline scheduling properties
# ---------------------------------------------------------------------------

class TestSchedulerProperties:
    @settings(max_examples=8)
    @given(heavy=st.floats(min_value=1.0, max_value=64.0),
           light=st.floats(min_value=0.1, max_value=1.0))
    def test_no_starvation_under_skewed_weights(self, prob, models, heavy,
                                                light):
        """A due tenant is never passed over: however skewed the weights,
        every pump flushes EVERY tenant whose weighted due time passed, so
        the light tenant's staleness stays bounded by deadline/weight +
        one pump period."""
        clk = [0.0]
        period = 0.004
        sched = _sched(lambda: clk[0])
        sched.admit("heavy", models[0], api.ServeSpec(max_batch=64),
                    weight=heavy, flush_deadline_ms=10.0)
        sched.admit("light", models[1], api.ServeSpec(max_batch=64),
                    weight=light, flush_deadline_ms=10.0)
        sched.submit("light", prob["U"][0])
        due = 10e-3 / light
        i = 0
        while clk[0] <= due + period:         # heavy keeps the queue warm
            sched.submit("heavy", prob["U"][i % 8])
            clk[0] += period
            sched.pump()
            i += 1
        # light was due at 10ms/light; the first pump at/after that flushed
        assert sched.pending("light") == 0
        assert sched.stats("light").n_deadline_flushes >= 1
        assert any(e[0] == "light" for e in sched.dispatch_log)
        assert sched.stats("light").staleness.percentile(99) \
            <= (due + period) * 1e3 + 1e-6

    @settings(max_examples=8)
    @given(seed=st.integers(min_value=0, max_value=2**31 - 1))
    def test_dispatch_order_invariant_under_submission_permutation(
            self, prob, models, seed):
        """pump() drains due tenants by (weighted due time, admission seq),
        NOT by submission arrival order: permuting which tenant submitted
        first within the window leaves the dispatch order unchanged."""
        weights = {"a": 1.0, "b": 2.0, "c": 4.0}

        def run(order):
            clk = [0.0]
            sched = _sched(lambda: clk[0])
            for i, tid in enumerate(sorted(weights)):
                sched.admit(tid, models[i], api.ServeSpec(max_batch=64),
                            weight=weights[tid], flush_deadline_ms=20.0)
            for tid in order:              # same instant, permuted order
                sched.submit(tid, prob["U"][0])
            clk[0] += 1.0                  # everyone long past due
            sched.pump()
            return [tid for tid, _, _ in sched.dispatch_log]

        base = run(["a", "b", "c"])
        perm = list(np.random.RandomState(seed).permutation(["a", "b", "c"]))
        assert run(perm) == base
        # and the order is weighted-due order: heaviest weight due first
        assert base == ["c", "b", "a"]

    def test_pump_returns_total_resolved(self, prob, models):
        clk = [0.0]
        sched = _sched(lambda: clk[0])
        sched.admit("a", models[0], api.ServeSpec(max_batch=64),
                    flush_deadline_ms=5.0)
        sched.admit("b", models[1], api.ServeSpec(max_batch=64),
                    flush_deadline_ms=5.0)
        for i in range(3):
            sched.submit("a", prob["U"][i])
        sched.submit("b", prob["U"][3])
        assert sched.pump() == 0           # nothing due yet
        clk[0] += 0.01
        assert sched.pump() == 4
        assert sched.pump() == 0


# ---------------------------------------------------------------------------
# Admission control
# ---------------------------------------------------------------------------

class TestAdmissionControl:
    def test_reject_policy_raises_and_counts(self, prob, models):
        sched = _sched(lambda: 0.0)
        sched.admit("a", models[0], api.ServeSpec(max_batch=64),
                    max_pending=2, overflow="reject")
        t0 = sched.submit("a", prob["U"][0])
        sched.submit("a", prob["U"][1])
        with pytest.raises(AdmissionError, match="max_pending=2"):
            sched.submit("a", prob["U"][2])
        st_ = sched.stats("a")
        assert st_.n_rejected == 1 and st_.n_requests == 2
        assert sched.pending("a") == 2     # queue untouched by the reject
        # draining reopens admission, and ticket ids stay contiguous
        sched.flush("a")
        assert sched.submit("a", prob["U"][2]) == t0 + 2

    def test_shed_oldest_policy_drops_and_counts(self, prob, models):
        sched = _sched(lambda: 0.0)
        sched.admit("a", models[0], api.ServeSpec(max_batch=64),
                    max_pending=2, overflow="shed_oldest")
        t0 = sched.submit("a", prob["U"][0])
        t1 = sched.submit("a", prob["U"][1])
        t2 = sched.submit("a", prob["U"][2])   # sheds t0
        assert sched.stats("a").n_shed == 1
        assert sched.pending("a") == 2
        sched.flush("a")
        sched.result("a", t1)
        sched.result("a", t2)
        with pytest.raises(KeyError, match="shed"):
            sched.result("a", t0)


# ---------------------------------------------------------------------------
# Adaptive flusher
# ---------------------------------------------------------------------------

class TestAdaptiveDeadline:
    def test_policy_validation(self):
        with pytest.raises(ValueError, match="gain"):
            AdaptiveDeadline(gain=0.0)

    def test_effective_deadline_tracks_interarrival(self, prob, models):
        clk = [0.0]
        sched = _sched(lambda: clk[0])
        sched.admit("a", models[0], api.ServeSpec(max_batch=64),
                    flush_deadline_ms=100.0,
                    adaptive=AdaptiveDeadline(gain=2.0, floor_ms=0.5))
        # no interarrival data yet: the declared budget is in force
        sched.submit("a", prob["U"][0])
        assert sched.effective_deadline_ms("a") == 100.0
        # brisk traffic (1ms spacing) tightens it toward gain*EMA = ~2ms
        for i in range(8):
            clk[0] += 0.001
            sched.submit("a", prob["U"][i % 8])
        eff = sched.effective_deadline_ms("a")
        assert eff == pytest.approx(2.0, rel=0.05)
        sched.flush("a")
        # a tightened deadline actually drives earlier deadline flushes
        sched.submit("a", prob["U"][0])
        clk[0] += 0.005                     # 5ms < 100ms budget, > ~2ms eff
        assert sched.pump() == 1
        assert sched.stats("a").n_deadline_flushes >= 1

    def test_sparse_traffic_relaxes_to_declared_budget(self, prob, models):
        clk = [0.0]
        sched = _sched(lambda: clk[0])
        sched.admit("a", models[0], api.ServeSpec(max_batch=64),
                    flush_deadline_ms=10.0, adaptive=True)
        sched.submit("a", prob["U"][0])
        clk[0] += 5.0                       # huge interarrival
        sched.flush("a")
        sched.submit("a", prob["U"][1])
        # gain*EMA is seconds-scale, so the budget caps it
        assert sched.effective_deadline_ms("a") == 10.0

    def test_floor_bounds_the_tightening(self, prob, models):
        clk = [0.0]
        sched = _sched(lambda: clk[0])
        sched.admit("a", models[0], api.ServeSpec(max_batch=64),
                    flush_deadline_ms=100.0,
                    adaptive=AdaptiveDeadline(gain=4.0, floor_ms=3.0))
        for i in range(10):                 # near-zero interarrival
            clk[0] += 1e-6
            sched.submit("a", prob["U"][i % 8])
        assert sched.effective_deadline_ms("a") == 3.0


# ---------------------------------------------------------------------------
# Observability: stats primitives + fleet rollup
# ---------------------------------------------------------------------------

class TestStatsAndRollup:
    def test_ema_none_seeding(self):
        e = Ema(alpha=0.5)
        assert e.value is None and e.get(7.0) == 7.0
        assert e.update(0.0) == 0.0         # 0.0 is a legal first sample
        assert e.update(2.0) == 1.0

    def test_reservoir_bounded_and_deterministic(self):
        r1, r2 = Reservoir(cap=16, seed=3), Reservoir(cap=16, seed=3)
        for i in range(1000):
            r1.record(float(i)); r2.record(float(i))
        assert r1.n_seen == 1000 and len(r1._buf) == 16
        assert r1.snapshot() == r2.snapshot()
        assert 0.0 <= r1.percentile(50) <= 999.0

    def test_g_hist_records_routed_ladder_usage(self, prob, models):
        sched = _sched(lambda: 0.0)
        sched.admit("a", models[0],
                    api.ServeSpec(max_batch=8, routed=True))
        for i in range(8):
            sched.submit("a", prob["U"][i])  # size flush at 8
        st_ = sched.stats("a")
        assert st_.n_size_flushes == 1
        assert sum(st_.g_hist.values()) == 1
        if 0 in st_.g_hist:
            assert st_.n_g0_flushes == st_.g_hist[0]

    def test_rollup_totals_and_snapshots(self, prob, models):
        clk = [0.0]
        sched = _sched(lambda: clk[0])
        sched.admit("a", models[0], api.ServeSpec(max_batch=4))
        sched.admit("b", models[1], api.ServeSpec(max_batch=4))
        for i in range(4):
            clk[0] += 0.001
            sched.submit("a", prob["U"][i])   # size flush
        sched.submit("b", prob["U"][0])
        sched.flush("b")
        r = sched.rollup()
        assert r["n_tenants"] == 2
        assert r["totals"]["n_requests"] == 5
        assert r["totals"]["n_flushes"] == 2
        snap = r["tenants"]["a"]
        assert snap["n_size_flushes"] == 1
        assert snap["staleness_ms"]["n"] == 4
        assert snap["staleness_ms"]["p99"] >= snap["staleness_ms"]["p50"]
        assert snap["interarrival_ms"] == pytest.approx(1.0)

    def test_gpserver_stats_is_serving_stats(self, prob, models):
        """GPServer re-exports ServeStats from serving/ — one stats schema
        for single- and multi-tenant serving."""
        from repro.launch.gp_serve import ServeStats as ReExported
        assert ReExported is ServeStats
        srv = GPServer(models[0], max_batch=4)
        t = srv.submit(prob["U"][0])
        srv.flush()
        srv.result(t)
        assert isinstance(srv.stats, ServeStats)
        assert srv.stats.n_manual_flushes == 1
        assert rollup({"default": srv.stats})["totals"]["n_requests"] == 1


# ---------------------------------------------------------------------------
# Checkpoint -> re-admission (satellite: spec rides with the store)
# ---------------------------------------------------------------------------

class TestCheckpointReadmission:
    def _store_server(self, prob, runner, **srv_kw):
        p = prob
        n1 = p["X"].shape[0] // 2
        store = api.init_store("ppic", p["kfn"], p["params"], p["X"][:n1],
                               p["y"][:n1], S=p["S"], runner=runner)
        model = api.FittedGP(api.get("ppic"), p["kfn"], p["params"],
                             store.to_state())
        return GPServer(model, store=store, **srv_kw)

    def test_admit_from_checkpoint_bitwise(self, prob, runner, tmp_path):
        spec = api.ServeSpec(max_batch=8, routed=True)
        srv = self._store_server(prob, runner, spec=spec)
        path = tmp_path / "tenant.store.npz"
        srv.checkpoint_store(path)
        assert serialize.peek_store(path)["serve_spec"]["routed"] is True
        reg = TenantRegistry()
        t = reg.admit_from_checkpoint("restored", path)
        assert t.spec == spec              # policy reconstructed, not guessed
        m0, v0 = srv.predict(prob["U"][:6])
        m1, v1 = t.plan.routed_diag(prob["U"][:6])
        np.testing.assert_array_equal(np.asarray(m0), np.asarray(m1))
        np.testing.assert_array_equal(np.asarray(v0), np.asarray(v1))
        # the restored tenant resumes ASSIMILATING, not just serving
        sched = TenantScheduler(reg)
        n1 = prob["X"].shape[0] // 2
        sched.commit_store("restored",
                           t.store.assimilate(prob["X"][n1:], prob["y"][n1:]))
        assert sched.stats("restored").n_updates == 1

    def test_missing_spec_fails_loudly(self, prob, runner, tmp_path):
        srv = self._store_server(prob, runner, max_batch=8)
        path = tmp_path / "bare.store.npz"
        serialize.save_store(path, srv.store)          # no spec embedded
        reg = TenantRegistry()
        with pytest.raises(ValueError, match="no ServeSpec"):
            reg.admit_from_checkpoint("t", path)
        # explicit override still admits
        t = reg.admit_from_checkpoint("t", path,
                                      spec=api.ServeSpec(max_batch=8))
        assert t.max_batch == 8

    def test_spec_meta_roundtrip_with_kernel_spec(self, tmp_path):
        spec = api.ServeSpec(kernel=cov.KernelSpec("se", "jnp", False, 16),
                             buckets=(8, 32), routed=True, alpha=3,
                             cached_cinv=True, dtype="state")
        meta = serialize._spec_meta(spec)
        assert serialize._spec_from_meta(meta) == spec
