"""Incremental-state API (api.StateStore): rank-b Cholesky updates,
store lifecycle (assimilate / retire / revive / to_state), streamed routed
serving, versioned state checkpointing, and the GPServer streaming surface.
"""

import jax
import jax.numpy as jnp
import numpy as np
import pytest
from hypothesis import given, settings, strategies as st

from repro.core import (api, covariance as cov, gp, hyper, linalg, online,
                        picf, pitc, ppic, ppitc, serialize)
from repro.launch.gp_serve import GPServer
from repro.parallel.runner import VmapRunner

from helpers import make_problem


@pytest.fixture(scope="module")
def prob():
    return make_problem()


@pytest.fixture(scope="module")
def runner(prob):
    return VmapRunner(M=prob["M"])


def _tree_equal(a, b):
    return all(bool(jnp.array_equal(x, y)) and x.dtype == y.dtype
               for x, y in zip(jax.tree.leaves(a), jax.tree.leaves(b)))


# ---------------------------------------------------------------------------
# linalg: rank-1 / rank-b Cholesky update and downdate
# ---------------------------------------------------------------------------

class TestCholUpdate:
    def _psd(self, n, seed=0):
        A0 = np.random.RandomState(seed).randn(n, 2 * n)
        return jnp.asarray(A0 @ A0.T + n * np.eye(n))

    def test_rank1_update_matches_refactorization(self):
        A = self._psd(16)
        L = jnp.linalg.cholesky(A)
        w = jnp.asarray(np.random.RandomState(1).randn(16))
        ref = jnp.linalg.cholesky(A + jnp.outer(w, w))
        np.testing.assert_allclose(linalg.cholupdate(L, w), ref, atol=1e-12)

    def test_rank1_downdate_inverts_update(self):
        A = self._psd(16)
        L = jnp.linalg.cholesky(A)
        w = jnp.asarray(np.random.RandomState(2).randn(16))
        np.testing.assert_allclose(
            linalg.choldowndate(linalg.cholupdate(L, w), w), L, atol=1e-12)

    def test_rank_b_update_matches_refactorization(self):
        A = self._psd(20)
        L = jnp.linalg.cholesky(A)
        W = jnp.asarray(np.random.RandomState(3).randn(20, 7))
        ref = jnp.linalg.cholesky(A + W @ W.T)
        np.testing.assert_allclose(linalg.chol_update_rank(L, W), ref,
                                   atol=1e-11)
        np.testing.assert_allclose(
            linalg.chol_update_rank(ref, W, sign=-1.0), L, atol=1e-11)

    def test_zero_columns_are_inert(self):
        """Zero update vectors (the factor-padding convention) are no-ops."""
        A = self._psd(10)
        L = jnp.linalg.cholesky(A)
        W = jnp.zeros((10, 4), L.dtype)
        np.testing.assert_allclose(linalg.chol_update_rank(L, W), L, atol=0)


# ---------------------------------------------------------------------------
# Acceptance: incremental to_state (cholupdate path) vs full recomputation
# ---------------------------------------------------------------------------

class TestIncrementalToState:
    def test_assimilate_matches_full_recompute_1e5(self, prob, runner):
        """float64 gate from the issue: after streaming waves through the
        rank-b update path, (Sdd_L, alpha) match a from-scratch O(|S|^3)
        recomputation of the same summaries to 1e-5 (observed ~1e-12)."""
        p = prob
        n1 = p["X"].shape[0] // 2
        store = api.init_store("ppitc", p["kfn"], p["params"], p["X"][:n1],
                               p["y"][:n1], S=p["S"], runner=runner)
        store = store.assimilate(p["X"][n1:], p["y"][n1:])
        # full recompute of the SAME summaries (alive-mask refold)
        ref = online.with_alive(store.store, store.store.alive,
                                mode="refold")
        np.testing.assert_allclose(store.store.Sdd_L, ref.Sdd_L, atol=1e-5)
        st_inc = store.to_state()
        st_ref = online.to_state(ref, p["S"])
        np.testing.assert_allclose(st_inc.alpha, st_ref.alpha, atol=1e-5)
        # and both match a genuinely cold fit of the concatenated data
        cold = ppitc.fit(p["kfn"], p["params"], p["X"], p["y"], S=p["S"],
                         runner=VmapRunner(M=2 * p["M"]))
        np.testing.assert_allclose(st_inc.Sdd_L, cold.Sdd_L, atol=1e-5)
        np.testing.assert_allclose(st_inc.alpha, cold.alpha, atol=1e-5)

    def test_retire_downdate_matches_survivor_refold(self, prob, runner):
        p = prob
        store = api.init_store("ppitc", p["kfn"], p["params"], p["X"],
                               p["y"], S=p["S"], runner=runner).retire(1)
        ref = online.with_alive(store.store, store.store.alive,
                                mode="refold")
        np.testing.assert_allclose(store.store.Sdd_L, ref.Sdd_L, atol=1e-5)

    def test_to_state_has_no_cubic_refactorization(self, prob, runner):
        """Structural check of the O(|S|^2) claim: to_state after retire
        reuses the cached (downdated) factor — it does NOT equal a chol of
        the alive Sdd bit-for-bit, it equals the downdate of the cold
        factor (same matrix, different float path)."""
        p = prob
        store = api.init_store("ppitc", p["kfn"], p["params"], p["X"],
                               p["y"], S=p["S"], runner=runner)
        expected = linalg.chol_update_rank(store.store.Sdd_L,
                                           store.store.F[2], sign=-1.0)
        np.testing.assert_array_equal(store.retire(2).to_state().Sdd_L,
                                      expected)


# ---------------------------------------------------------------------------
# Store lifecycle (issue satellite): retire -> revive -> to_state roundtrip,
# assimilate-then-checkpoint == recompute-from-scratch
# ---------------------------------------------------------------------------

class TestWithAliveHamming:
    """``online.with_alive`` picks rank-b cholupdate/downdate vs full refold
    by the Hamming distance of the alive mask (ISSUE satellite): small
    deadline flips are O(|S|²·b) retire/revive chains, wholesale flips take
    the one-pass O(|S|³) refold. Both must produce the same matrix."""

    def _store(self, prob, runner):
        # M=12 -> b=8 < |S|: the regime where rank-b updates beat the
        # refold (the fixture's b=24 > |S|=12 would always refold — for
        # blocks wider than the support set, re-factorizing |S|³/3 is
        # genuinely cheaper than b rank-1 sweeps)
        del runner
        return api.init_store("ppitc", prob["kfn"], prob["params"],
                              prob["X"], prob["y"], S=prob["S"],
                              runner=VmapRunner(M=12))

    def test_small_flip_is_incremental(self, prob, runner):
        """A single-machine flip must follow the retire float path exactly
        (bitwise): the incremental branch IS a retire chain."""
        store = self._store(prob, runner)
        mask = np.asarray(store.alive).copy()
        mask[1] = False
        flipped = store.with_alive(jnp.asarray(mask))
        np.testing.assert_array_equal(flipped.store.Sdd_L,
                                      store.retire(1).store.Sdd_L)

    def test_incremental_matches_refold(self, prob, runner):
        store = self._store(prob, runner)
        mask = np.asarray(store.alive).copy()
        mask[0] = mask[3] = False
        inc = online.with_alive(store.store, jnp.asarray(mask),
                                mode="incremental")
        ref = online.with_alive(store.store, jnp.asarray(mask),
                                mode="refold")
        np.testing.assert_array_equal(inc.alive, ref.alive)
        np.testing.assert_allclose(inc.Sdd_L, ref.Sdd_L, atol=1e-10)
        np.testing.assert_allclose(inc.ydd, ref.ydd, atol=1e-10)

    def test_wholesale_flip_refolds(self, prob, runner):
        """Flipping every machine exceeds the h·b crossover: auto must take
        the refold float path (bitwise equal to mode='refold')."""
        store = self._store(prob, runner)
        mask = ~np.asarray(store.alive)
        mask[0] = True                      # keep one machine alive
        auto = online.with_alive(store.store, jnp.asarray(mask))
        ref = online.with_alive(store.store, jnp.asarray(mask),
                                mode="refold")
        np.testing.assert_array_equal(auto.Sdd_L, ref.Sdd_L)

    def test_noop_mask_returns_store_unchanged(self, prob, runner):
        store = self._store(prob, runner)
        same = online.with_alive(store.store, store.store.alive)
        np.testing.assert_array_equal(same.Sdd_L, store.store.Sdd_L)

    def test_bad_mode_rejected(self, prob, runner):
        store = self._store(prob, runner)
        with pytest.raises(ValueError, match="with_alive mode"):
            online.with_alive(store.store, store.store.alive, mode="nope")

    def test_traceable_under_jit(self, prob, runner):
        """A traced mask cannot drive the host-side Hamming dispatch:
        'auto' must fall back to the pure-jnp refold (and still be right);
        forcing 'incremental' under trace is an explicit error."""
        store = self._store(prob, runner)
        mask = store.store.alive.at[1].set(False)
        jit_ydd = jax.jit(
            lambda m: online.with_alive(store.store, m).ydd)(mask)
        ref = online.with_alive(store.store, mask, mode="refold")
        np.testing.assert_allclose(jit_ydd, ref.ydd, atol=1e-12)
        with pytest.raises(ValueError, match="concrete masks"):
            jax.jit(lambda m: online.with_alive(
                store.store, m, mode="incremental").ydd)(mask)


class TestStoreLifecycle:
    def test_protocol_membership(self, prob, runner):
        for name, kw in (("ppitc", dict(S=prob["S"], runner=runner)),
                         ("ppic", dict(S=prob["S"], runner=runner)),
                         ("picf", dict(rank=48, runner=runner)),
                         ("pitc", dict(S=prob["S"], M=prob["M"])),
                         ("pic", dict(S=prob["S"], M=prob["M"]))):
            store = api.init_store(name, prob["kfn"], prob["params"],
                                   prob["X"], prob["y"], **kw)
            assert isinstance(store, api.StateStore), name

    def test_fgp_has_no_store(self, prob):
        with pytest.raises(ValueError, match="no incremental StateStore"):
            api.init_store("fgp", prob["kfn"], prob["params"], prob["X"],
                           prob["y"])

    @pytest.mark.parametrize("name,kw", [
        ("ppitc", {}), ("ppic", {}), ("picf", {"rank": 48})])
    def test_retire_revive_to_state_roundtrip(self, prob, runner, name, kw):
        """retire -> revive -> to_state reproduces the original state for
        every store-backed method (downdate/update cancel)."""
        kwargs = dict(S=prob["S"], runner=runner) if "rank" not in kw \
            else dict(runner=runner, **kw)
        store = api.init_store(name, prob["kfn"], prob["params"], prob["X"],
                               prob["y"], **kwargs)
        s0 = store.to_state()
        s1 = store.retire(2).revive(2).to_state()
        for f, a, b in zip(s0._fields, s0, s1):
            np.testing.assert_allclose(a, b, atol=1e-10,
                                       err_msg=f"{name}.{f}")

    def test_retire_is_idempotent_and_revive_noop_when_alive(self, prob,
                                                             runner):
        store = api.init_store("ppitc", prob["kfn"], prob["params"],
                               prob["X"], prob["y"], S=prob["S"],
                               runner=runner)
        assert store.revive(1) is store           # already alive
        dead = store.retire(1)
        assert dead.retire(1) is dead             # already retired

    @pytest.mark.parametrize("name,kw", [
        ("ppitc", {}), ("picf", {"rank": 48})])
    def test_out_of_range_machine_rejected(self, prob, runner, name, kw):
        """jnp drops OOB scatter updates while clamping OOB gathers, so an
        unchecked bad id would corrupt the cached factor silently; the
        stores must raise instead."""
        kwargs = dict(S=prob["S"], runner=runner) if "rank" not in kw \
            else dict(runner=runner, **kw)
        store = api.init_store(name, prob["kfn"], prob["params"], prob["X"],
                               prob["y"], **kwargs)
        for machine in (prob["M"], -1, 10 ** 6):
            with pytest.raises(IndexError, match="out of range"):
                store.retire(machine)
            with pytest.raises(IndexError, match="out of range"):
                store.revive(machine)

    def test_all_alive_to_state_shares_block_buffers(self, prob, runner):
        """The streaming common case (nothing retired) must not copy the
        per-block caches — Xb in the emitted state IS the store's buffer."""
        store = api.init_store("ppic", prob["kfn"], prob["params"],
                               prob["X"], prob["y"], S=prob["S"],
                               runner=runner)
        assert store.to_state().Xb is store.blocks.Xb
        picf_store = api.init_store("picf", prob["kfn"], prob["params"],
                                    prob["X"], prob["y"], rank=48,
                                    runner=runner)
        assert picf_store.to_state().Xb is picf_store.Xb

    @pytest.mark.parametrize("name", ["ppitc", "ppic"])
    def test_assimilate_then_checkpoint_equals_recompute(self, prob, runner,
                                                         name, tmp_path):
        """Stream half the data in, checkpoint the state, reload: equals a
        cold fit of the concatenated data (and the reload is bitwise)."""
        p = prob
        n1 = p["X"].shape[0] // 2
        store = api.init_store(name, p["kfn"], p["params"], p["X"][:n1],
                               p["y"][:n1], S=p["S"], runner=runner)
        store = store.assimilate(p["X"][n1:], p["y"][n1:])
        state = store.to_state()
        path = tmp_path / f"{name}.npz"
        serialize.save_state(path, state)
        loaded = serialize.load_state(path)
        assert _tree_equal(state, loaded)
        cold = api.get(name).fit(p["kfn"], p["params"], p["X"], p["y"],
                                 S=p["S"], runner=VmapRunner(M=2 * p["M"]))
        for f, a, b in zip(state._fields, loaded, cold):
            np.testing.assert_allclose(a, b, atol=1e-9,
                                       err_msg=f"{name}.{f}")

    def test_pic_centroids_refresh_on_stream_and_retire(self, prob, runner):
        p = prob
        n1 = p["X"].shape[0] // 2
        store = api.init_store("ppic", p["kfn"], p["params"], p["X"][:n1],
                               p["y"][:n1], S=p["S"], runner=runner)
        M0 = store.to_state().centroids.shape[0]
        grown = store.assimilate(p["X"][n1:], p["y"][n1:])
        assert grown.to_state().centroids.shape[0] == 2 * M0
        shrunk = grown.retire(0).to_state()
        assert shrunk.centroids.shape[0] == 2 * M0 - 1
        np.testing.assert_allclose(shrunk.centroids,
                                   jnp.mean(shrunk.Xb, axis=1), atol=0)

    def test_pic_wave_block_size_enforced(self, prob, runner):
        p = prob
        store = api.init_store("ppic", p["kfn"], p["params"], p["X"],
                               p["y"], S=p["S"], runner=runner)
        with pytest.raises(ValueError, match="block size"):
            store.assimilate(p["X"][: p["X"].shape[0] // 2],
                             p["y"][: p["X"].shape[0] // 2])

    def test_pitc_waves_of_any_block_size(self, prob, runner):
        """pPITC summaries are block-size-agnostic: a wave with a different
        b pads the factor store and still matches the per-wave cold sum."""
        p = prob
        store = api.init_store("ppitc", p["kfn"], p["params"], p["X"],
                               p["y"], S=p["S"], runner=runner)
        X2 = jax.random.normal(jax.random.PRNGKey(5), (6, 3), jnp.float64)
        y2 = jnp.sin(X2[:, 0])
        grown = store.assimilate(X2, y2, runner=VmapRunner(M=2))   # b=3
        ref = online.with_alive(grown.store, grown.store.alive,
                                mode="refold")
        np.testing.assert_allclose(grown.store.Sdd_L, ref.Sdd_L, atol=1e-10)


# ---------------------------------------------------------------------------
# pICF row-append / retire on the distributed factor
# ---------------------------------------------------------------------------

class TestPICFStore:
    def test_append_extends_factor_in_pivot_basis(self, prob, runner):
        """Streamed factor columns are the forward solve Lp f = k(P, x) —
        and the incremental Phi_L matches a full refactorization of the
        extended factor to 1e-5 (float64 gate)."""
        p = prob
        store = api.init_store("picf", p["kfn"], p["params"], p["X"],
                               p["y"], rank=48, runner=runner)
        X2 = jax.random.normal(jax.random.PRNGKey(7),
                               p["X"].shape, jnp.float64)
        y2 = jnp.cos(X2[:, 0])
        grown = store.assimilate(X2, y2)
        # Nyström-extension identity on the appended blocks
        Xb2 = runner.shard_blocks(X2)
        F_ref = jax.vmap(lambda Xm: linalg.tri_solve(
            store.Lp, p["kfn"](p["params"], store.Xp, Xm)))(Xb2)
        np.testing.assert_array_equal(grown.F[p["M"]:], F_ref)
        # incremental Phi_L vs refactorization of I + sum F F^T / s2
        s2 = cov.noise_var(p["params"])
        R = store.Phi_L.shape[0]
        Phi = jnp.eye(R, dtype=jnp.float64) + jnp.sum(
            jnp.einsum("mrb,msb->mrs", grown.F, grown.F), 0) / s2
        np.testing.assert_allclose(grown.Phi_L, jnp.linalg.cholesky(Phi),
                                   atol=1e-5)

    def test_retire_appended_restores_original(self, prob, runner):
        p = prob
        store = api.init_store("picf", p["kfn"], p["params"], p["X"],
                               p["y"], rank=48, runner=runner)
        X2 = jax.random.normal(jax.random.PRNGKey(8),
                               p["X"].shape, jnp.float64)
        grown = store.assimilate(X2, jnp.sin(X2[:, 1]))
        for m in range(p["M"], 2 * p["M"]):
            grown = grown.retire(m)
        s0, s1 = store.to_state(), grown.to_state()
        np.testing.assert_allclose(s1.Phi_L, s0.Phi_L, atol=1e-10)
        np.testing.assert_allclose(s1.ydd, s0.ydd, atol=1e-10)
        np.testing.assert_array_equal(s1.Xb, s0.Xb)

    def test_streamed_predictions_finite_and_consistent(self, prob, runner):
        p = prob
        store = api.init_store("picf", p["kfn"], p["params"], p["X"],
                               p["y"], rank=48, runner=runner)
        half = p["X"].shape[0] // 2
        # stream a slice of the SAME data distribution back in
        grown = store.assimilate(
            p["X"] + 0.01 * jax.random.normal(jax.random.PRNGKey(9),
                                              p["X"].shape, jnp.float64),
            p["y"])
        mean, var = picf.predict_batch_diag(p["kfn"], p["params"],
                                            grown.to_state(), p["U"])
        assert bool(jnp.isfinite(mean).all()) and bool(
            jnp.isfinite(var).all())
        assert half > 0

    def test_wave_block_size_enforced(self, prob, runner):
        p = prob
        store = api.init_store("picf", p["kfn"], p["params"], p["X"],
                               p["y"], rank=48, runner=runner)
        with pytest.raises(ValueError, match="block size"):
            store.assimilate(p["X"][:12], p["y"][:12])


# ---------------------------------------------------------------------------
# Acceptance: streamed PICState through GPServer(routed=True) == cold pPIC
# fit on the concatenated data (property-tested over wave splits)
# ---------------------------------------------------------------------------

class TestStreamedRoutedServing:
    @settings(max_examples=8, deadline=None)
    @given(k=st.integers(min_value=1, max_value=3), seed=st.integers(0, 99))
    def test_streamed_equals_cold_fit_routed(self, prob, k, seed):
        """Any split of the blocks into (first wave, second wave) and any
        query batch: the streamed PICState served routed equals the cold
        pPIC fit of the concatenated data served routed."""
        p = prob
        b = p["X"].shape[0] // p["M"]          # fit-time block size
        n1 = k * b
        store = api.init_store("ppic", p["kfn"], p["params"], p["X"][:n1],
                               p["y"][:n1], S=p["S"], runner=VmapRunner(M=k))
        store = store.assimilate(p["X"][n1:], p["y"][n1:],
                                 runner=VmapRunner(M=p["M"] - k))
        streamed = api.FittedGP(api.get("ppic"), p["kfn"], p["params"],
                                store.to_state())
        cold = api.fit("ppic", p["kfn"], p["params"], p["X"], p["y"],
                       S=p["S"], runner=VmapRunner(M=p["M"]))
        perm = np.random.RandomState(seed).permutation(p["U"].shape[0])
        U = p["U"][jnp.asarray(perm)]
        srv = GPServer(streamed, max_batch=8, routed=True)
        m_s, v_s = srv.predict(U)
        m_c, v_c = cold.predict_routed_diag(U)
        np.testing.assert_allclose(m_s, m_c, atol=1e-9)
        np.testing.assert_allclose(v_s, v_c, atol=1e-9)

    def test_update_hot_swaps_routed_server(self, prob, runner):
        """GPServer.update on a routed server: streamed data changes the
        served posterior to the cold-fit-on-all-data one."""
        p = prob
        n1 = p["X"].shape[0] // 2
        store = api.init_store("ppic", p["kfn"], p["params"], p["X"][:n1],
                               p["y"][:n1], S=p["S"],
                               runner=VmapRunner(M=p["M"] // 2))
        srv = GPServer(api.FittedGP(api.get("ppic"), p["kfn"], p["params"],
                                    store.to_state()),
                       max_batch=8, routed=True, store=store)
        m_before, _ = srv.predict(p["U"][:8])
        srv.update(p["X"][n1:], p["y"][n1:])
        m_after, v_after = srv.predict(p["U"][:8])
        cold = api.fit("ppic", p["kfn"], p["params"], p["X"], p["y"],
                       S=p["S"], runner=runner)
        ref_m, ref_v = cold.predict_routed_diag(p["U"][:8])
        np.testing.assert_allclose(m_after, ref_m, atol=1e-9)
        np.testing.assert_allclose(v_after, ref_v, atol=1e-9)
        assert float(jnp.abs(m_after - m_before).max()) > 1e-6
        assert srv.stats.n_updates == 1

    def test_retire_machine_serves_survivors(self, prob, runner):
        p = prob
        store = api.init_store("ppitc", p["kfn"], p["params"], p["X"],
                               p["y"], S=p["S"], runner=runner)
        srv = GPServer(api.FittedGP(api.get("ppitc"), p["kfn"], p["params"],
                                    store.to_state()),
                       max_batch=8, store=store)
        srv.retire_machine(1)
        m, _ = srv.predict(p["U"][:8])
        b = p["X"].shape[0] // p["M"]
        keep = jnp.concatenate([jnp.arange(0, b),
                                jnp.arange(2 * b, p["X"].shape[0])])
        surv = ppitc.fit(p["kfn"], p["params"], p["X"][keep], p["y"][keep],
                         S=p["S"], runner=VmapRunner(M=p["M"] - 1))
        ref, _ = ppitc.predict_batch_diag(p["kfn"], p["params"], surv,
                                          p["U"][:8])
        np.testing.assert_allclose(m, ref, atol=1e-9)
        srv.revive_machine(1)
        assert srv.stats.n_updates == 2

    def test_update_without_store_raises(self, prob, runner):
        model = api.fit("ppitc", prob["kfn"], prob["params"], prob["X"],
                        prob["y"], S=prob["S"], runner=runner)
        srv = GPServer(model, max_batch=8)
        with pytest.raises(ValueError, match="StateStore"):
            srv.update(prob["X"], prob["y"])

    def test_rejected_update_is_atomic(self, prob, runner):
        """A routed server given a centroid-less (pPITC) store must reject
        update() WITHOUT committing the store mutation — a retry through
        the proper path must not fold the wave in twice."""
        p = prob
        pitc_store = api.init_store("ppitc", p["kfn"], p["params"], p["X"],
                                    p["y"], S=p["S"], runner=runner)
        pic_model = api.fit("ppic", p["kfn"], p["params"], p["X"], p["y"],
                            S=p["S"], runner=runner)
        srv = GPServer(pic_model, max_batch=8, routed=True, store=pitc_store)
        m0, _ = srv.predict(p["U"][:4])
        with pytest.raises(ValueError, match="centroids"):
            srv.update(p["X"], p["y"])
        assert srv.store is pitc_store            # store not committed
        assert srv.stats.n_updates == 0
        m1, _ = srv.predict(p["U"][:4])
        np.testing.assert_array_equal(m0, m1)     # posterior untouched


# ---------------------------------------------------------------------------
# Acceptance: save_state / load_state round-trips every registered state
# bitwise
# ---------------------------------------------------------------------------

class TestSerialize:
    def _states(self, p, runner):
        return {
            "FGPState": gp.fit(p["kfn"], p["params"], p["X"], p["y"]),
            "PITCState": ppitc.fit(p["kfn"], p["params"], p["X"], p["y"],
                                   S=p["S"], runner=runner),
            "PICState": ppic.fit(p["kfn"], p["params"], p["X"], p["y"],
                                 S=p["S"], runner=runner),
            "PICFState": picf.fit(p["kfn"], p["params"], p["X"], p["y"],
                                  rank=48, runner=runner),
        }

    def test_every_registered_state_roundtrips_bitwise(self, prob, runner,
                                                       tmp_path):
        states = self._states(prob, runner)
        assert set(states) == set(serialize.STATE_TYPES)
        for name, state in states.items():
            path = serialize.save_state(tmp_path / f"{name}.npz", state)
            loaded = serialize.load_state(path)
            assert type(loaded).__name__ == name
            assert _tree_equal(state, loaded), name
            meta = serialize.peek(path)
            assert meta["state"] == name
            assert meta["schema"] == serialize.SCHEMA_VERSION
            assert set(meta["fields"]) == set(state._fields)

    def test_unregistered_type_rejected(self, tmp_path):
        from repro.core.ppitc import GlobalSummary
        bogus = GlobalSummary(jnp.zeros(2), jnp.eye(2))
        with pytest.raises(ValueError, match="unregistered"):
            serialize.save_state(tmp_path / "x.npz", bogus)

    def test_not_a_checkpoint_rejected(self, tmp_path):
        path = tmp_path / "junk.npz"
        np.savez(open(path, "wb"), a=np.zeros(3))
        with pytest.raises(ValueError, match="not a repro state"):
            serialize.load_state(path)

    def test_field_drift_rejected(self, prob, runner, tmp_path):
        """A checkpoint whose fields no longer match the state class must
        fail loudly, not mis-assemble."""
        state = ppitc.fit(prob["kfn"], prob["params"], prob["X"], prob["y"],
                          S=prob["S"], runner=runner)
        path = serialize.save_state(tmp_path / "s.npz", state)
        with np.load(path) as z:
            payload = {k: z[k] for k in z.files if k != "field:alpha"}
        np.savez(open(path, "wb"), **payload)
        with pytest.raises(ValueError, match="field mismatch"):
            serialize.load_state(path)

    def test_server_checkpoint_swap(self, prob, runner, tmp_path):
        """Replica flow: server A checkpoints, server B (fitted on a
        RESCALED posterior) swaps it in and now serves A's posterior."""
        p = prob
        a = api.fit("ppitc", p["kfn"], p["params"], p["X"], p["y"],
                    S=p["S"], runner=runner)
        b = api.fit("ppitc", p["kfn"], p["params"], p["X"], 2.0 * p["y"],
                    S=p["S"], runner=runner)
        srv_a = GPServer(a, max_batch=8)
        srv_b = GPServer(b, max_batch=8)
        path = tmp_path / "replica.npz"
        srv_a.checkpoint(path)
        srv_b.swap_from_checkpoint(path)
        m_a, _ = srv_a.predict(p["U"][:8])
        m_b, _ = srv_b.predict(p["U"][:8])
        np.testing.assert_array_equal(m_a, m_b)
        assert srv_b.stats.n_state_swaps == 1

    def test_swap_from_checkpoint_detaches_stale_store(self, prob, runner,
                                                       tmp_path):
        """Restoring a checkpoint invalidates any attached store (it
        describes the pre-restore posterior); a later update() must demand
        a fresh store instead of silently reverting the restored state."""
        p = prob
        store = api.init_store("ppitc", p["kfn"], p["params"], p["X"],
                               p["y"], S=p["S"], runner=runner)
        srv = GPServer(api.FittedGP(api.get("ppitc"), p["kfn"], p["params"],
                                    store.to_state()),
                       max_batch=8, store=store)
        path = tmp_path / "restore.npz"
        serialize.save_state(path, store.retire(0).to_state())
        srv.swap_from_checkpoint(path)
        assert srv.store is None
        with pytest.raises(ValueError, match="StateStore"):
            srv.update(p["X"], p["y"])

    def test_routed_server_rejects_pitc_checkpoint(self, prob, runner,
                                                   tmp_path):
        p = prob
        pitc_state = ppitc.fit(p["kfn"], p["params"], p["X"], p["y"],
                               S=p["S"], runner=runner)
        path = serialize.save_state(tmp_path / "pitc.npz", pitc_state)
        pic_model = api.fit("ppic", p["kfn"], p["params"], p["X"], p["y"],
                            S=p["S"], runner=runner)
        srv = GPServer(pic_model, max_batch=8, routed=True)
        with pytest.raises(ValueError, match="centroids"):
            srv.swap_from_checkpoint(path)


# ---------------------------------------------------------------------------
# hyper satellite: custom objectives don't thread unused data; PITC NLML
# equals the literal centralized computation in float64
# ---------------------------------------------------------------------------

class TestHyperFix:
    def test_fit_requires_data_only_for_default_objective(self, prob):
        with pytest.raises(ValueError, match="needs \\(X, y\\)"):
            hyper.fit(prob["kfn"], prob["params"])

    def test_custom_objective_runs_without_data(self, prob):
        calls = []

        def obj(params):
            calls.append(1)
            return jnp.sum(params["log_lengthscale"] ** 2)

        params, losses = hyper.fit(prob["kfn"], prob["params"], steps=3,
                                   objective=obj)
        assert losses.shape == (3,) and calls

    def test_pitc_nlml_equals_literal_centralized_float64(self):
        """Tiny-data float64 gate: the distributable PITC likelihood equals
        -log N(y; 0, Gamma_DD + Lambda) computed literally (dense chol on
        the PITC train covariance)."""
        p = make_problem(n=24, u=4, s=6, M=3)
        r = VmapRunner(M=p["M"])
        par = hyper.pitc_nlml(p["kfn"], p["params"], p["S"], p["X"], p["y"],
                              r)
        # literal: Gamma = K_DS Kss^{-1} K_SD, Lambda = blockdiag(K - Gamma
        # + noise)
        Kss_L = linalg.chol(p["kfn"](p["params"], p["S"], p["S"]))
        Kds = p["kfn"](p["params"], p["X"], p["S"])
        Gamma = Kds @ linalg.chol_solve(Kss_L, Kds.T)
        Sig = cov.add_noise(p["kfn"](p["params"], p["X"], p["X"]),
                            p["params"]) - Gamma
        n, b = p["X"].shape[0], p["X"].shape[0] // p["M"]
        Cov = Gamma
        for m in range(p["M"]):
            sl = slice(m * b, (m + 1) * b)
            Cov = Cov.at[sl, sl].add(Sig[sl, sl])
        L = jnp.linalg.cholesky(Cov)
        quad = p["y"] @ linalg.chol_solve(L, p["y"][:, None])[:, 0]
        literal = 0.5 * (quad + linalg.logdet_from_chol(L)
                         + n * jnp.log(2 * jnp.pi))
        np.testing.assert_allclose(float(par), float(literal), rtol=1e-9)

    def test_fit_parallel_improves_without_passing_data_to_fit(self, prob):
        r = VmapRunner(M=prob["M"])
        p0 = cov.init_params(3, signal=0.5, noise=0.5, lengthscale=3.0,
                             dtype=jnp.float64)
        _, losses = hyper.fit_parallel(prob["kfn"], p0, prob["S"], prob["X"],
                                       prob["y"], r, steps=10, lr=0.08)
        assert float(losses[-1]) < float(losses[0])
