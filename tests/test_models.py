"""Per-architecture smoke tests (reduced same-family configs, CPU) plus
layer-level correctness: SSD-vs-recurrence, MoE routing invariants,
M-RoPE reduction, decode-vs-forward consistency."""
import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.configs.registry import ARCH_NAMES, smoke_config
from repro.models import moe as moe_lib, ssm as ssm_lib
from repro.models import layers, transformer as tf

KEY = jax.random.PRNGKey(0)


@pytest.mark.slow
@pytest.mark.parametrize("name", ARCH_NAMES)
class TestArchSmoke:
    def test_forward_and_grad(self, name):
        cfg = smoke_config(name)
        params = tf.init_model(KEY, cfg)
        B, T = 2, 32
        toks = jax.random.randint(KEY, (B, T), 0, cfg.vocab)
        enc_kv = None
        if cfg.enc_dec:
            frames = jax.random.normal(KEY, (B, cfg.enc_seq, cfg.d_model),
                                       jnp.float32)
            enc_kv = tf.encode(params, frames, cfg)
        logits, aux = tf.forward(params, toks, cfg, enc_kv=enc_kv,
                                 attn_impl="jnp")
        assert logits.shape == (B, T, cfg.vocab)
        assert bool(jnp.isfinite(logits).all())
        (loss, _), grads = jax.value_and_grad(tf.lm_loss, has_aux=True)(
            params, toks, toks, cfg, enc_kv=enc_kv, attn_impl="jnp")
        assert bool(jnp.isfinite(loss))
        for g in jax.tree.leaves(grads):
            assert bool(jnp.isfinite(g).all())

    def test_one_train_step_reduces_loss_direction(self, name):
        """One SGD step along the gradient must not increase loss
        (first-order sanity of the whole stack)."""
        cfg = smoke_config(name)
        params = tf.init_model(KEY, cfg)
        toks = jax.random.randint(KEY, (2, 16), 0, cfg.vocab)
        kw = {}
        if cfg.enc_dec:
            frames = jax.random.normal(KEY, (2, cfg.enc_seq, cfg.d_model),
                                       jnp.float32)
            kw["enc_kv"] = tf.encode(params, frames, cfg)
        lossf = lambda p: tf.lm_loss(p, toks, toks, cfg, attn_impl="jnp",
                                     **kw)[0]
        l0, g = jax.value_and_grad(lossf)(params)
        p1 = jax.tree.map(lambda p, gg: p - 1e-3 * gg, params, g)
        l1 = lossf(p1)
        assert float(l1) < float(l0) + 1e-4


@pytest.mark.slow
@pytest.mark.parametrize("name", ["qwen3-1.7b", "mamba2-130m",
                                  "jamba-1.5-large-398b", "gemma3-4b",
                                  "whisper-medium", "mixtral-8x22b"])
def test_decode_matches_forward(name):
    """KV-cache / SSM-state decode equals teacher-forced forward. MoE uses a
    high capacity factor (capacity dropping differs between batched-forward
    and per-token decode by construction)."""
    cfg = smoke_config(name).scaled(capacity_factor=16.0)
    if cfg.ssm_state:
        cfg = cfg.scaled(ssm_chunk=4)
    params = tf.init_model(KEY, cfg)
    B, T = 2, 16
    toks = jax.random.randint(KEY, (B, T), 0, cfg.vocab)
    enc_kv = None
    if cfg.enc_dec:
        frames = jax.random.normal(KEY, (B, cfg.enc_seq, cfg.d_model),
                                   jnp.float32)
        enc_kv = tf.encode(params, frames, cfg, compute_dtype=jnp.float32)
    full, _ = tf.forward(params, toks, cfg, enc_kv=enc_kv, attn_impl="jnp",
                         compute_dtype=jnp.float32)
    state = tf.init_serve(cfg, B, 32, enc_kv=enc_kv,
                          cache_dtype=jnp.float32)
    errs = []
    for t in range(T):
        lg, state = tf.decode_step(params, toks[:, t:t + 1], state, cfg,
                                   compute_dtype=jnp.float32)
        errs.append(float(jnp.abs(lg[:, 0] - full[:, t]).max()))
    assert max(errs) < 5e-4, errs


class TestSSD:
    def test_chunked_scan_matches_recurrence(self):
        """The SSD chunked algorithm == the naive sequential recurrence."""
        B, L, H, P, N, chunk = 2, 32, 3, 4, 8, 8
        ks = jax.random.split(KEY, 4)
        xh = jax.random.normal(ks[0], (B, L, H, P))
        dt = jax.nn.softplus(jax.random.normal(ks[1], (B, L, H)))
        A = -jnp.exp(jax.random.normal(ks[2], (H,)) * 0.3)
        Bm = jax.random.normal(ks[3], (B, L, N))
        Cm = jax.random.normal(jax.random.fold_in(KEY, 9), (B, L, N))

        Y, final = ssm_lib.ssd_scan(xh, dt, A, Bm, Cm, chunk)

        S = jnp.zeros((B, H, P, N))
        outs = []
        for t in range(L):
            dA = jnp.exp(dt[:, t] * A[None, :])                  # (B,H)
            S = (S * dA[..., None, None]
                 + jnp.einsum("bh,bhp,bn->bhpn", dt[:, t],
                              xh[:, t], Bm[:, t]))
            outs.append(jnp.einsum("bhpn,bn->bhp", S, Cm[:, t]))
        Y_ref = jnp.stack(outs, axis=1)
        np.testing.assert_allclose(Y, Y_ref, atol=2e-4)
        np.testing.assert_allclose(final, S, atol=2e-4)

    def test_chunk_size_invariance(self):
        B, L, H, P, N = 1, 24, 2, 4, 6
        ks = jax.random.split(KEY, 5)
        xh = jax.random.normal(ks[0], (B, L, H, P))
        dt = jax.nn.softplus(jax.random.normal(ks[1], (B, L, H)))
        A = -jnp.exp(jax.random.normal(ks[2], (H,)) * 0.3)
        Bm = jax.random.normal(ks[3], (B, L, N))
        Cm = jax.random.normal(ks[4], (B, L, N))
        Y1, _ = ssm_lib.ssd_scan(xh, dt, A, Bm, Cm, 4)
        Y2, _ = ssm_lib.ssd_scan(xh, dt, A, Bm, Cm, 12)
        np.testing.assert_allclose(Y1, Y2, atol=2e-4)


class TestMoE:
    def test_routing_conservation(self):
        """With generous capacity, combine weights per token sum to 1."""
        p = moe_lib.init_moe(KEY, 16, 32, 4)
        x = jax.random.normal(KEY, (2, 8, 16))
        y, aux = moe_lib.moe_ffn(p, x, top_k=2, capacity_factor=8.0)
        assert y.shape == x.shape
        assert float(aux.dropped_fraction) == 0.0

    def test_capacity_drops_reported(self):
        p = moe_lib.init_moe(KEY, 16, 32, 8)
        x = jax.random.normal(KEY, (1, 64, 16))
        _, aux = moe_lib.moe_ffn(p, x, top_k=2, capacity_factor=0.25)
        assert float(aux.dropped_fraction) > 0.0

    def test_group_invariance_with_high_capacity(self):
        """Group count must not change results when nothing is dropped."""
        p = moe_lib.init_moe(KEY, 16, 32, 4)
        x = jax.random.normal(KEY, (2, 16, 16))
        y1, _ = moe_lib.moe_ffn(p, x, top_k=2, capacity_factor=16.0,
                                n_groups=1, compute_dtype=jnp.float32)
        y2, _ = moe_lib.moe_ffn(p, x, top_k=2, capacity_factor=16.0,
                                n_groups=4, compute_dtype=jnp.float32)
        np.testing.assert_allclose(y1, y2, atol=1e-5)


class TestRoPE:
    def test_mrope_reduces_to_rope_on_text(self):
        """Equal (t,h,w) position ids == standard RoPE (Qwen2-VL property)."""
        x = jax.random.normal(KEY, (2, 4, 16, 32))
        pos = jnp.broadcast_to(jnp.arange(16)[None], (2, 16))
        pos3 = jnp.broadcast_to(pos[:, None, :], (2, 3, 16))
        a = layers.apply_rope(x, pos, 1e4)
        b = layers.apply_mrope(x, pos3, 1e4, sections=(4, 6, 6))
        np.testing.assert_allclose(a, b, atol=1e-6)

    def test_rope_preserves_norm(self):
        x = jax.random.normal(KEY, (1, 2, 8, 64))
        pos = jnp.broadcast_to(jnp.arange(8)[None], (1, 8))
        y = layers.apply_rope(x, pos, 1e4)
        np.testing.assert_allclose(jnp.linalg.norm(y, axis=-1),
                                   jnp.linalg.norm(x, axis=-1), rtol=1e-5)


def test_param_counts_match_init():
    """configs.param_counts() agrees with actual initialized trees."""
    for name in ("qwen3-1.7b", "olmo-1b"):
        cfg = smoke_config(name)
        params = tf.init_model(KEY, cfg)
        n_actual = sum(x.size for x in jax.tree.leaves(params))
        n_pred = cfg.param_counts()["total"]
        # norms/small vectors are excluded from the analytic count
        assert abs(n_actual - n_pred) / n_pred < 0.05, (name, n_actual,
                                                        n_pred)
