"""Coverage for remaining feature corners: true multimodal M-RoPE positions,
last-logits prefill, report rendering, napkin model, HLO parser units."""
import jax
import jax.numpy as jnp
import numpy as np

from repro.configs.registry import get_config, smoke_config
from repro.configs.shapes import SHAPES, applicable
from repro.models import layers, transformer as tf
from repro.roofline import analysis, hlo_parse

KEY = jax.random.PRNGKey(0)


class TestMRoPE:
    def test_distinct_spatial_positions_change_output(self):
        """Vision tokens with distinct (t,h,w) ids must differ from text
        rope (the sections actually do something)."""
        x = jax.random.normal(KEY, (1, 2, 8, 32))
        pos_t = jnp.broadcast_to(jnp.arange(8)[None], (1, 8))
        pos3_text = jnp.broadcast_to(pos_t[:, None], (1, 3, 8))
        grid = jnp.stack([jnp.zeros((1, 8)),                 # same frame
                          jnp.repeat(jnp.arange(4), 2)[None],  # row ids
                          jnp.tile(jnp.arange(2), 4)[None]],   # col ids
                         axis=1)
        a = layers.apply_mrope(x, pos3_text, 1e4, sections=(4, 6, 6))
        b = layers.apply_mrope(x, grid, 1e4, sections=(4, 6, 6))
        assert float(jnp.abs(a - b).max()) > 1e-3

    def test_vlm_forward_with_image_grid_positions(self):
        cfg = smoke_config("qwen2-vl-72b")
        params = tf.init_model(KEY, cfg)
        B, T = 1, 16
        toks = jax.random.randint(KEY, (B, T), 0, cfg.vocab)
        # first 8 tokens are a 2x4 image patch grid, rest is text
        t_id = jnp.concatenate([jnp.zeros(8), jnp.arange(1, 9)])
        h_id = jnp.concatenate([jnp.repeat(jnp.arange(2), 4),
                                jnp.arange(1, 9)])
        w_id = jnp.concatenate([jnp.tile(jnp.arange(4), 2),
                                jnp.arange(1, 9)])
        pos3 = jnp.stack([t_id, h_id, w_id])[None].astype(jnp.int32)
        logits, _ = tf.forward(params, toks, cfg, positions=pos3,
                               attn_impl="jnp")
        assert bool(jnp.isfinite(logits).all())


class TestLastLogitsPrefill:
    def test_matches_full_forward_last_position(self):
        cfg = smoke_config("qwen3-1.7b")
        params = tf.init_model(KEY, cfg)
        toks = jax.random.randint(KEY, (2, 16), 0, cfg.vocab)
        full, _ = tf.forward(params, toks, cfg, attn_impl="jnp",
                             compute_dtype=jnp.float32)
        last, _ = tf.forward(params, toks, cfg, attn_impl="jnp",
                             compute_dtype=jnp.float32,
                             logits_last_only=True)
        assert last.shape[1] == 1
        np.testing.assert_allclose(last[:, 0], full[:, -1], atol=1e-5)


class TestRooflineUnits:
    def test_shape_bytes(self):
        assert hlo_parse.shape_bytes("f32[8,128]{1,0}") == 8 * 128 * 4
        assert hlo_parse.shape_bytes("(bf16[4,4], s8[16])") == 32 + 16
        assert hlo_parse.shape_bytes("pred[]") == 1

    def test_collective_bytes_with_trip_count(self):
        hlo = """
HloModule m
%body (x: f32[4]) -> f32[4] {
  %ar = f32[4]{0} all-reduce(%x), replica_groups={}
}
%cond (x: f32[4]) -> pred[] {
  %c = s32[] constant(7)
}
ENTRY %main (p: f32[4]) -> f32[4] {
  %w = f32[4]{0} while(%p), condition=%cond, body=%body, backend_config={"known_trip_count":{"n":"7"}}
  %ag = f32[8]{0} all-gather(%w)
}
"""
        out = hlo_parse.collective_bytes(hlo)
        assert out["all-reduce"] == 7 * 16
        assert out["all-gather"] == 32
        assert out["total"] == 7 * 16 + 32

    def test_model_flops_windowed_less_than_full(self):
        cfg_g = get_config("gemma3-4b")
        shape = SHAPES["prefill_32k"]
        windowed = analysis.model_flops(cfg_g, shape)
        nowin = analysis.model_flops(
            cfg_g.scaled(layer_pattern=(
                cfg_g.layer_pattern[-1],)), shape)  # all-global variant
        assert windowed < nowin

    def test_napkin_ring_cache_reduces_decode_bytes(self):
        cfg = get_config("gemma3-4b")
        shape = SHAPES["long_500k"]
        full = analysis.napkin_bytes(cfg, shape, ring_cache=False)
        ring = analysis.napkin_bytes(cfg, shape, ring_cache=True)
        assert ring < full / 2

    def test_applicability_matrix(self):
        assert applicable("mamba2-130m", "long_500k")
        assert not applicable("qwen3-1.7b", "long_500k")
        assert applicable("qwen3-1.7b", "train_4k")


class TestReport:
    def test_table_renders(self, tmp_path):
        import json
        rec = {"status": "ok", "mesh": "single", "arch": "a", "shape": "s",
               "chips": 4, "t_compute": 0.5, "t_memory": 0.001,
               "t_collective": 2e-6, "bottleneck": "compute",
               "useful_fraction": 0.9, "roofline_fraction": 0.85}
        json.dump(rec, open(tmp_path / "a_s_single.json", "w"))
        from repro.roofline.report import table
        out = table(str(tmp_path), "single")
        assert "| a | s | 4 | 500.0ms | 1.0ms | 2us | compute | 0.90 | "
        assert "0.850" in out
