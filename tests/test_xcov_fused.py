"""Interpret-mode equivalence suite for the fused serving kernel
(kernels/rbf/xcov.py) and the KernelSpec dispatch that feeds it.

The fused ``xcov_diag`` collapses cross-covariance assembly, both cached
triangular solves, and the predictive-variance quadratic form into one
Pallas pass. Gates (ISSUE acceptance): it matches the ref.py compose path to
<= 1e-5 in float32 and <= 1e-10 in float64, across the serving bucket shape
ladder (including non-aligned |S| and query counts that exercise both the
support-column masking and the query-row padding), and the KernelSpec-routed
predict paths (ppitc/fgp) agree with their dense compose equivalents.
"""
import jax
import jax.numpy as jnp
import numpy as np
import pytest
from hypothesis import given, settings, strategies as st

from repro.core import covariance as cov, gp, ppitc
from repro.kernels.rbf import ops as rbf_ops, ref as rbf_ref
from repro.parallel.runner import VmapRunner

from helpers import make_problem

# acceptance gates: fused vs compose, interpret mode
TOL = {jnp.dtype(jnp.float32): 1e-5, jnp.dtype(jnp.float64): 1e-10}


def _factors(s, dtype, seed=0):
    ks = jax.random.split(jax.random.PRNGKey(seed), 3)
    A1 = jax.random.normal(ks[0], (s, s), dtype)
    A2 = jax.random.normal(ks[1], (s, s), dtype)
    L1 = jnp.linalg.cholesky(A1 @ A1.T + s * jnp.eye(s, dtype=dtype))
    L2 = jnp.linalg.cholesky(A2 @ A2.T + 2 * s * jnp.eye(s, dtype=dtype))
    alpha = jax.random.normal(ks[2], (s,), dtype)
    return L1, L2, alpha


class TestXcovDiagKernel:
    # serving bucket ladder (default_buckets) + unaligned stragglers
    @pytest.mark.parametrize("n", [1, 8, 16, 33, 64, 128, 200, 256])
    @pytest.mark.parametrize("s,d", [(12, 3), (128, 8), (130, 21)])
    @pytest.mark.parametrize("dtype", [jnp.float32, jnp.float64])
    def test_matches_compose_ref(self, n, s, d, dtype):
        ks = jax.random.split(jax.random.PRNGKey(n * s + d), 2)
        Xq = jax.random.normal(ks[0], (n, d), dtype)
        Xk = jax.random.normal(ks[1], (s, d), dtype)
        L1, L2, alpha = _factors(s, dtype)
        tol = TOL[jnp.dtype(dtype)]
        for L2_ in (L2, None):
            m_r, v_r = rbf_ref.xcov_diag(Xq, Xk, L1, alpha, 1.3, L2_)
            m_p, v_p = rbf_ops.xcov_diag(Xq, Xk, L1, alpha, 1.3, L2_,
                                         impl="pallas_interpret")
            assert float(jnp.abs(m_p - m_r).max()) <= tol
            assert float(jnp.abs(v_p - v_r).max()) <= tol

    def test_explicit_block_q_tiles(self):
        """A declared serving tile (bucket-aligned batches) changes the grid,
        not the numbers."""
        Xq = jax.random.normal(jax.random.PRNGKey(0), (64, 5), jnp.float32)
        Xk = jax.random.normal(jax.random.PRNGKey(1), (40, 5), jnp.float32)
        L1, L2, alpha = _factors(40, jnp.float32)
        ref = rbf_ops.xcov_diag(Xq, Xk, L1, alpha, 0.9, L2,
                                impl="pallas_interpret")
        for bq in (8, 16, 64):
            out = rbf_ops.xcov_diag(Xq, Xk, L1, alpha, 0.9, L2,
                                    impl="pallas_interpret", block_q=bq)
            np.testing.assert_allclose(out[0], ref[0], atol=1e-6)
            np.testing.assert_allclose(out[1], ref[1], atol=1e-6)

    @settings(max_examples=8, deadline=None)
    @given(n=st.integers(1, 150), s=st.integers(2, 90), d=st.integers(1, 24),
           seed=st.integers(0, 2**16))
    def test_property_random_shapes(self, n, s, d, seed):
        ks = jax.random.split(jax.random.PRNGKey(seed), 2)
        Xq = jax.random.normal(ks[0], (n, d), jnp.float32)
        Xk = jax.random.normal(ks[1], (s, d), jnp.float32)
        L1, L2, alpha = _factors(s, jnp.float32, seed=seed)
        m_r, v_r = rbf_ref.xcov_diag(Xq, Xk, L1, alpha, 1.1, L2)
        m_p, v_p = rbf_ops.xcov_diag(Xq, Xk, L1, alpha, 1.1, L2,
                                     impl="pallas_interpret")
        assert float(jnp.abs(m_p - m_r).max()) <= 1e-5
        assert float(jnp.abs(v_p - v_r).max()) <= 1e-5

    def test_resident_cap_guard(self):
        s = rbf_ops.MAX_FUSED_RESIDENT + 1
        Xq = jnp.zeros((8, 2), jnp.float32)
        Xk = jnp.zeros((s, 2), jnp.float32)
        L = jnp.eye(s, dtype=jnp.float32)
        with pytest.raises(ValueError, match="residency cap"):
            rbf_ops.xcov_diag(Xq, Xk, L, jnp.zeros((s,)), 1.0,
                              impl="pallas_interpret")


class TestKernelSpecDispatch:
    @pytest.fixture(scope="class")
    def prob(self):
        return make_problem(dtype=jnp.float64)

    def test_spec_is_callable_kernel(self, prob):
        """A spec drops in wherever a KernelFn goes; on CPU 'auto' resolves
        to the dense path bitwise."""
        spec = cov.make_spec("se")
        K0 = prob["kfn"](prob["params"], prob["X"][:7], prob["S"])
        K1 = spec(prob["params"], prob["X"][:7], prob["S"])
        np.testing.assert_array_equal(np.asarray(K0), np.asarray(K1))

    def test_spec_diag_is_signal_variance(self, prob):
        spec = cov.make_spec("se")
        d = cov.kdiag(spec, prob["params"], prob["U"])
        sig2 = float(cov.signal_var(prob["params"]))
        np.testing.assert_allclose(np.asarray(d), sig2, rtol=1e-12)

    def test_fuse_gating(self):
        assert not cov.make_spec("se", impl="jnp").fuse(64)
        assert not cov.make_spec("se", impl="pallas_interpret",
                                 fused=False).fuse(64)
        assert cov.make_spec("se", impl="pallas_interpret").fuse(64)
        assert not cov.make_spec("se", impl="pallas_interpret").fuse(
            rbf_ops.MAX_FUSED_RESIDENT + 1)
        assert not cov.make_spec("matern52", impl="pallas_interpret").fuse(64)

    def test_unknown_kernel_rejected(self):
        with pytest.raises(ValueError, match="unknown kernel"):
            cov.make_spec("nope")

    @pytest.mark.parametrize("dtype,tol", [(jnp.float32, 1e-5),
                                           (jnp.float64, 1e-10)])
    def test_ppitc_fused_equals_compose(self, dtype, tol):
        p = make_problem(dtype=dtype)
        runner = VmapRunner(M=p["M"])
        st_ = ppitc.fit(p["kfn"], p["params"], p["X"], p["y"], S=p["S"],
                        runner=runner)
        m0, v0 = ppitc.predict_batch_diag(p["kfn"], p["params"], st_, p["U"])
        spec = cov.make_spec("se", impl="pallas_interpret")
        m1, v1 = ppitc.predict_batch_diag(spec, p["params"], st_, p["U"])
        assert float(jnp.abs(m1 - m0).max()) <= 10 * tol
        assert float(jnp.abs(v1 - v0).max()) <= 10 * tol

    @pytest.mark.parametrize("dtype,tol", [(jnp.float32, 1e-5),
                                           (jnp.float64, 1e-10)])
    def test_fgp_fused_equals_compose(self, dtype, tol):
        p = make_problem(dtype=dtype)
        st_ = gp.fit(p["kfn"], p["params"], p["X"], p["y"])
        m0, v0 = gp.predict_batch_diag(p["kfn"], p["params"], st_, p["U"])
        spec = cov.make_spec("se", impl="pallas_interpret")
        m1, v1 = gp.predict_batch_diag(spec, p["params"], st_, p["U"])
        assert float(jnp.abs(m1 - m0).max()) <= 10 * tol
        assert float(jnp.abs(v1 - v0).max()) <= 10 * tol

    def test_jit_closure_hot_swap(self):
        """The serving pattern: spec closed over in a jitted predict, state
        hot-swapped without retrace (launch/gp_serve.py)."""
        p = make_problem(dtype=jnp.float32)
        runner = VmapRunner(M=p["M"])
        st_ = ppitc.fit(p["kfn"], p["params"], p["X"], p["y"], S=p["S"],
                        runner=runner)
        spec = cov.make_spec("se", impl="pallas_interpret")
        traces = []
        def f(params, state, U):
            traces.append(1)
            return ppitc.predict_batch_diag(spec, params, state, U)
        fj = jax.jit(f)
        fj(p["params"], st_, p["U"])
        st2 = jax.tree.map(lambda a: a + 0, st_)     # same shapes, new leaves
        fj(p["params"], st2, p["U"])
        assert len(traces) == 1
