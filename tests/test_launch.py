"""Launcher-level integration: sharded train/serve step builders on a real
multi-device mesh (subprocess, 8 host devices) + eager smoke on 1 device."""
import os
import subprocess
import sys
from pathlib import Path

import pytest

SCRIPT = r"""
import jax, jax.numpy as jnp
assert len(jax.devices()) == 8
from repro.configs.registry import smoke_config
from repro.data.loader import TokenLoader
from repro.launch import serve as serve_lib, train as train_lib
from repro.models import transformer as tf
from repro.optim.adam import Adam
from repro.parallel import sharding as shd

mesh = jax.make_mesh((4, 2), ("data", "model"))

# --- sharded training: mixtral-family smoke (MoE + FSDP + TP + EP path)
cfg = smoke_config("mixtral-8x22b").scaled(moe_dispatch="gather")
opt = Adam(lr=1e-3)
state = train_lib.init_state(jax.random.PRNGKey(0), cfg, opt)
step_fn, jitted = train_lib.make_train_step(cfg, mesh, opt,
                                            attn_impl="jnp", remat=True)
jstep = jitted(state)
loader = TokenLoader(cfg, mesh, batch=8, seq=32)
losses = []
for _ in range(3):
    state, m = jstep(state, next(loader))
    losses.append(float(m.loss))
assert all(jnp.isfinite(jnp.asarray(losses))), losses
assert losses[-1] < losses[0] + 0.5, losses

# --- sharded serving: decode step with KV caches on the mesh
cfg2 = smoke_config("qwen3-1.7b")
params = tf.init_model(jax.random.PRNGKey(1), cfg2)
B = 8
sstate = tf.init_serve(cfg2, B, 64)
step, jitted2 = serve_lib.make_serve_step(cfg2, mesh, batch=B)
jdecode = jitted2(params)
tok = jnp.zeros((B, 1), jnp.int32)
logits, sstate = jdecode(params, tok, sstate)
assert logits.shape == (B, 1, cfg2.vocab_padded)
logits2, sstate = jdecode(params, tok, sstate)
assert bool(jnp.isfinite(logits2).all())
print("LAUNCH_OK")
"""


@pytest.mark.slow
def test_sharded_train_and_serve_on_mesh():
    env = dict(os.environ)
    env["XLA_FLAGS"] = "--xla_force_host_platform_device_count=8"
    env["PYTHONPATH"] = str(Path(__file__).resolve().parents[1] / "src")
    r = subprocess.run([sys.executable, "-c", SCRIPT], env=env,
                       capture_output=True, text=True, timeout=900)
    assert r.returncode == 0, r.stdout[-2000:] + r.stderr[-4000:]
    assert "LAUNCH_OK" in r.stdout


@pytest.mark.slow
def test_eager_train_step_all_families():
    """One eager train step per family on one device (fast coverage of the
    builder across attention/MoE/SSM/enc-dec paths)."""
    import jax
    import jax.numpy as jnp
    from repro.configs.registry import smoke_config
    from repro.launch import train as train_lib
    from repro.optim.adam import Adam

    for name in ("olmo-1b", "qwen3-moe-30b-a3b", "mamba2-130m",
                 "whisper-medium"):
        cfg = smoke_config(name)
        opt = Adam(lr=1e-3)
        state = train_lib.init_state(jax.random.PRNGKey(0), cfg, opt)
        toks = jax.random.randint(jax.random.PRNGKey(1), (2, 16), 0,
                                  cfg.vocab)
        batch = {"tokens": toks, "labels": toks}
        if cfg.enc_dec:
            batch["frames"] = jax.random.normal(
                jax.random.PRNGKey(2), (2, cfg.enc_seq, cfg.d_model),
                jnp.bfloat16)
        step_fn, _ = train_lib.make_train_step(cfg, None, opt,
                                               attn_impl="jnp", remat=False)
        state, m = step_fn(state, batch)
        assert bool(jnp.isfinite(m.loss)), name


def test_microbatched_matches_single_batch():
    import jax
    import jax.numpy as jnp
    import numpy as np
    from repro.configs.registry import smoke_config
    from repro.launch import train as train_lib
    from repro.optim.adam import Adam

    cfg = smoke_config("olmo-1b")
    opt = Adam(lr=1e-3)
    toks = jax.random.randint(jax.random.PRNGKey(1), (4, 16), 0, cfg.vocab)
    batch = {"tokens": toks, "labels": toks}
    s1 = train_lib.init_state(jax.random.PRNGKey(0), cfg, opt)
    s2 = train_lib.init_state(jax.random.PRNGKey(0), cfg, opt)
    f1, _ = train_lib.make_train_step(cfg, None, opt, attn_impl="jnp",
                                      remat=False, microbatches=1)
    f2, _ = train_lib.make_train_step(cfg, None, opt, attn_impl="jnp",
                                      remat=False, microbatches=2)
    s1, m1 = f1(s1, batch)
    s2, m2 = f2(s2, batch)
    # same data, same update (up to accumulation-order roundoff; Adam's
    # m/sqrt(v) normalization amplifies bf16 rounding of near-zero grads to
    # +-lr on isolated elements, so compare loss tightly and params by
    # mismatch fraction)
    np.testing.assert_allclose(float(m1.loss), float(m2.loss), rtol=1e-4)
    for a, b in zip(jax.tree.leaves(s1.params), jax.tree.leaves(s2.params)):
        frac = float(jnp.mean((jnp.abs(a - b) > 2e-5).astype(jnp.float32)))
        assert frac < 0.01, frac
