"""Self-healing serving (serving/health.py + serving/chaos.py + the
degraded routing ladder in core/ppic.py, ISSUE 9).

Acceptance:

* under an injected single-block failure mid-stream the tenant answers
  EVERY routed query — degraded flag set on the stranded rows, zero
  exceptions, zero recompiles (trace probe) — auto-recovers from the last
  ``save_store`` checkpoint, and post-revive predictions are BITWISE-equal
  (f32) to a run where the failure never happened;
* retire -> routed-degraded serve -> revive round-trips bitwise under
  random routed traffic (hypothesis-seeded event sequences);
* degraded rows are served from the global S-space posterior and their
  RMSE is bounded against the ``with_alive`` refit oracle;
* ``serialize.load_store``/``load_state`` raise ``CheckpointError`` (path
  + reason) on truncated/corrupt/missing artifacts — a corrupt checkpoint
  is never loaded, revive defers, and the tenant stays degraded-but-alive;
* the fault harness is deterministic: one ``FaultPlan`` replays one
  failure schedule.
"""
import dataclasses
import os

import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.core import api, clustering, ppic, serialize
from repro.launch.gp_serve import GPServer
from repro.parallel.runner import VmapRunner
from repro.serving import (BlockDied, FaultInjector, FaultPlan, HealthPolicy,
                           HealthTracker, TenantScheduler)

from helpers import make_problem


@pytest.fixture(scope="module")
def prob():
    return make_problem(dtype=jnp.float32, n=160)


@pytest.fixture(scope="module")
def pic_store(prob):
    return api.init_store("ppic", prob["kfn"], prob["params"], prob["X"],
                          prob["y"], S=prob["S"],
                          runner=VmapRunner(M=prob["M"]))


@pytest.fixture(scope="module")
def model(pic_store):
    return api.FittedGP(api.get("ppic"), pic_store.kfn, pic_store.params,
                        pic_store.to_state())


class Clock:
    """Virtual time: the scheduler's ``clock`` and every injectable
    ``sleep`` (backoff, straggle) advance the same counter."""

    def __init__(self):
        self.t = 0.0

    def __call__(self):
        return self.t

    def sleep(self, seconds):
        self.t += seconds


def _spec(max_batch=8):
    return api.ServeSpec(max_batch=max_batch, routed=True)


def _healed_pair(model, pic_store, tmp_path, *, fault_plan, policy=None,
                 max_batch=8):
    """(scheduler-with-faults, tenant, oracle-scheduler, clock): the faulted
    tenant and a never-faulted twin driven by separate virtual clocks."""
    ckpt = os.fspath(tmp_path / "store.npz")
    serialize.save_store(ckpt, pic_store, spec=_spec(max_batch))
    clk = Clock()
    policy = policy or HealthPolicy(max_retries=2,
                                    max_consecutive_failures=1,
                                    checkpoint=ckpt, revive_after_ms=50.0)
    if policy.checkpoint is None:
        policy = dataclasses.replace(policy, checkpoint=ckpt)
    inj = FaultInjector(fault_plan, sleep=clk.sleep)
    sched = TenantScheduler(clock=clk, sleep=clk.sleep)
    t = sched.admit("t", model, _spec(max_batch), store=pic_store,
                    health=policy, chaos=inj)
    oracle = TenantScheduler(clock=Clock())
    oracle.admit("t", model, _spec(max_batch))
    return sched, t, oracle, clk


def _serve(sched, U):
    for x in U:
        sched.submit("t", x)
    sched.flush("t")


# ---------------------------------------------------------------------------
# The headline scenario: block dies mid-stream, tenant self-heals
# ---------------------------------------------------------------------------

class TestSelfHealing:
    def test_block_failure_degrade_revive_bitwise(self, model, pic_store,
                                                  tmp_path):
        """The acceptance criterion end to end: every query answered under
        an injected single-block failure (flagged, zero exceptions, zero
        recompiles), auto-revive from checkpoint, post-revive bitwise-equal
        to a never-faulted run."""
        sched, t, oracle, clk = _healed_pair(
            model, pic_store, tmp_path,
            fault_plan=FaultPlan(fail_at={1: (3, 6)}))
        t.plan.warmup(3)
        traces0 = t.plan.stats.n_traces

        rng = np.random.RandomState(7)
        U = rng.randn(40, 3).astype(np.float32)
        _serve(sched, U)
        _serve(oracle, U)
        n_degraded = 0
        for tk in range(40):
            m, v, dg = sched.collect("t", tk)
            m0, v0 = oracle.result("t", tk)
            assert np.isfinite(np.asarray(m)).all()
            assert np.isfinite(np.asarray(v)).all()
            n_degraded += dg
            if not dg:     # healthy rows are bitwise-unperturbed by the
                           # failure of an unrelated block
                np.testing.assert_array_equal(np.asarray(m), np.asarray(m0))
                np.testing.assert_array_equal(np.asarray(v), np.asarray(v0))
        assert n_degraded > 0
        assert t.health.dead_blocks() == [1]
        assert t.stats.n_auto_retired == 1
        assert t.stats.n_retries >= 1
        assert t.stats.n_degraded_rows == n_degraded

        # background revive once the timer elapses
        clk.t += 1.0
        sched.pump()
        assert t.health.dead_blocks() == []
        assert t.stats.n_revives == 1

        # post-revive: bitwise what the never-faulted twin serves
        U2 = rng.randn(8, 3).astype(np.float32)
        _serve(sched, U2)
        _serve(oracle, U2)
        for tk in range(40, 48):
            m, v, dg = sched.collect("t", tk)
            m0, v0 = oracle.result("t", tk)
            assert not dg
            np.testing.assert_array_equal(np.asarray(m), np.asarray(m0))
            np.testing.assert_array_equal(np.asarray(v), np.asarray(v0))
        assert t.plan.stats.n_traces == traces0   # zero recompiles, ever

    def test_retire_degrade_revive_random_traffic(self, model, pic_store,
                                                  tmp_path):
        """Satellite (d): the round-trip under seeded-random routed traffic
        and several fault/heal cycles — end state bitwise-equal (f32) to
        never having failed."""
        sched, t, oracle, clk = _healed_pair(
            model, pic_store, tmp_path,
            fault_plan=FaultPlan(fail_at={0: (2, 4), 2: (7, 9)},
                                 straggle_ms={3: 0.2}))
        t.plan.warmup(3)
        traces0 = t.plan.stats.n_traces
        rng = np.random.RandomState(11)
        tickets = 0
        for step in range(120):
            clk.t += float(rng.exponential(0.002))
            x = rng.randn(3).astype(np.float32)
            sched.submit("t", x)
            oracle.submit("t", x)
            tickets += 1
            if step % 17 == 16:
                clk.t += 0.2
                sched.pump()
        sched.flush("t")
        oracle.flush("t")
        for tk in range(tickets):
            m, v, dg = sched.collect("t", tk)
            assert np.isfinite(np.asarray(m)).all()
            assert np.isfinite(np.asarray(v)).all()
        assert t.stats.n_auto_retired >= 1   # the windows actually fired
        # heal everything, then the final flush must be bitwise-oracle
        clk.t += 1.0
        sched.pump()
        assert t.health.dead_blocks() == []
        U2 = rng.randn(16, 3).astype(np.float32)
        _serve(sched, U2)
        _serve(oracle, U2)
        for tk in range(tickets, tickets + 16):
            m, v, dg = sched.collect("t", tk)
            m0, v0 = oracle.result("t", tk)
            assert not dg
            np.testing.assert_array_equal(np.asarray(m), np.asarray(m0))
            np.testing.assert_array_equal(np.asarray(v), np.asarray(v0))
        assert t.plan.stats.n_traces == traces0

    def test_nan_posterior_detected_and_retired(self, model, pic_store,
                                                tmp_path):
        """Output poisoning (the organic-corruption analogue): non-finite
        healthy rows are detected, blamed on the producing block, retried,
        and the block is retired — every ticket still resolves finite."""
        sched, t, oracle, clk = _healed_pair(
            model, pic_store, tmp_path,
            fault_plan=FaultPlan(nan_at={2: (0, 4)}))
        rng = np.random.RandomState(3)
        U = rng.randn(24, 3).astype(np.float32)
        _serve(sched, U)
        for tk in range(24):
            m, v, dg = sched.collect("t", tk)
            assert np.isfinite(np.asarray(m)).all()
            assert np.isfinite(np.asarray(v)).all()
        assert 2 in t.health.dead_blocks()
        assert t.stats.n_nonfinite_flushes >= 1
        assert t.stats.n_auto_retired >= 1
        assert t.health.blocks[2].n_nonfinite >= 1

    def test_straggler_timeout_attribution(self, model, pic_store, tmp_path):
        """A straggling block trips the flush-latency budget: the timeout
        is counted, attributed via the per-block latency EMA, and repeated
        offenses retire the straggler — results are still served (a
        timeout is a latency fault on a valid posterior).

        Traffic is crafted by centroid so flushes alternate between
        straggler-free batches (fast — they pull the OTHER blocks' EMAs
        down) and batches hitting the straggler (slow): the latency
        evidence separates, and the blame lands on the right block."""
        policy = HealthPolicy(flush_timeout_ms=50.0, max_retries=1,
                              max_consecutive_failures=2,
                              revive_after_ms=1e9)
        sched, t, oracle, clk = _healed_pair(
            model, pic_store, tmp_path, policy=policy,
            fault_plan=FaultPlan(straggle_ms={1: 200.0}))
        C = np.asarray(model.state.centroids, np.float32)
        fast_rows = C[[0, 2, 3]]          # routes to blocks 0/2/3 only
        slow_rows = C[[0, 1]]             # routes through the straggler
        served = 0
        for _ in range(3):                # fast, slow, fast, slow, ...
            for x in fast_rows:
                sched.submit("t", x)
                served += 1
            sched.flush("t")
            if t.health.dead_blocks():
                break
            for x in slow_rows:
                sched.submit("t", x)
                served += 1
            sched.flush("t")
            if t.health.dead_blocks():
                break
        assert t.stats.n_timeout_flushes >= 1
        assert t.health.dead_blocks() == [1]
        assert t.health.blocks[1].latency.get() > 100.0
        for tk in range(served):
            m, v, _ = sched.collect("t", tk)
            assert np.isfinite(np.asarray(m)).all()

    def test_corrupt_checkpoint_defers_revive(self, model, pic_store,
                                              tmp_path):
        """A corrupt revive artifact is DETECTED and never loaded: the
        revive fails closed (counted, timer re-armed), the tenant keeps
        serving degraded, and a repaired checkpoint revives it."""
        sched, t, oracle, clk = _healed_pair(
            model, pic_store, tmp_path,
            fault_plan=FaultPlan(fail_at={1: (0, 2)}))
        ckpt = t.health.policy.checkpoint
        t.chaos.corrupt(ckpt)
        rng = np.random.RandomState(9)
        _serve(sched, rng.randn(16, 3).astype(np.float32))
        assert t.health.dead_blocks() == [1]
        clk.t += 1.0
        sched.pump()
        assert t.stats.n_revive_failures == 1
        assert t.stats.n_revives == 0
        assert t.health.dead_blocks() == [1]    # still degraded, still alive
        _serve(sched, rng.randn(8, 3).astype(np.float32))
        for tk in range(24):
            m, _, _ = sched.collect("t", tk)
            assert np.isfinite(np.asarray(m)).all()
        # repair the artifact -> next pump revives
        serialize.save_store(ckpt, pic_store, spec=_spec())
        clk.t += 1.0
        sched.pump()
        assert t.stats.n_revives == 1
        assert t.health.dead_blocks() == []


# ---------------------------------------------------------------------------
# Degraded routing ladder (core/ppic.py)
# ---------------------------------------------------------------------------

class TestDegradedRouting:
    def test_degraded_rows_are_global_posterior(self, prob, model):
        """Rows whose block is masked dead are answered by the global
        S-space (pPITC) posterior; alive rows are bitwise the baseline."""
        plan = model.plan(_spec(max_batch=16))
        U = np.asarray(prob["U"][:16], np.float32)
        alive = np.ones(prob["M"], bool)
        alive[1] = False
        m_base, v_base = map(np.asarray, plan.routed_diag(U))
        m_deg, v_deg = map(np.asarray, plan.routed_diag(U, block_alive=alive))
        deg = np.asarray(plan.stats.last_degraded)
        assign = clustering.nearest_center_np(
            U, np.asarray(model.state.centroids))
        np.testing.assert_array_equal(deg, assign == 1)
        assert deg.any()
        m_glob, v_glob = map(np.asarray, ppic.global_diag(
            plan.kfn, plan.params, plan.state, U))
        np.testing.assert_allclose(m_deg[deg], m_glob[deg], rtol=1e-5,
                                   atol=1e-5)
        np.testing.assert_allclose(v_deg[deg], v_glob[deg], rtol=1e-4,
                                   atol=1e-5)
        np.testing.assert_array_equal(m_deg[~deg], m_base[~deg])
        np.testing.assert_array_equal(v_deg[~deg], v_base[~deg])

    def test_degraded_rmse_bounded_by_with_alive_oracle(self, prob,
                                                        pic_store, model):
        """The bounded-degradation property: on the stranded rows, the
        degraded (global-posterior) RMSE is within a small factor of the
        with-alive refit oracle — ``PICStore.retire`` (the single-flip
        ``with_alive`` downdate) re-emitted over the surviving blocks,
        i.e. the exact posterior a full refit without the dead block
        would serve."""
        plan = model.plan(_spec(max_batch=64))
        rng = np.random.RandomState(13)
        U = rng.randn(64, 3).astype(np.float32)
        f = np.asarray(prob["f"](jnp.asarray(U)))
        assign = clustering.nearest_center_np(
            U, np.asarray(model.state.centroids))
        worst = 0.0
        for dead in range(prob["M"]):
            rows = assign == dead
            if not rows.any():
                continue
            alive = np.ones(prob["M"], bool)
            alive[dead] = False
            m_deg, _ = plan.routed_diag(U, block_alive=alive)
            m_deg = np.asarray(m_deg)
            st_alive = pic_store.retire(dead).to_state()
            m_or, _ = ppic.predict_routed_diag(
                prob["kfn"], prob["params"], st_alive, U[rows])
            rmse_deg = float(np.sqrt(np.mean((m_deg[rows] - f[rows]) ** 2)))
            rmse_or = float(np.sqrt(np.mean(
                (np.asarray(m_or) - f[rows]) ** 2)))
            rmse_prior = float(np.sqrt(np.mean(f[rows] ** 2)))
            worst = max(worst, rmse_deg / max(rmse_or, 1e-12))
            # the global posterior drops only the PIC local correction on
            # these rows: bounded loss (a small factor of the refit
            # oracle; per-block row counts are small so the ratio is a
            # noisy estimate — the 4x headroom covers that, not a real
            # 4x accuracy loss), and never catastrophe (still far better
            # than falling back to the prior mean)
            assert rmse_deg <= 4.0 * rmse_or + 1e-3, \
                (dead, rmse_deg, rmse_or)
            assert rmse_deg < rmse_prior, (dead, rmse_deg, rmse_prior)
        assert worst > 0.0     # the sweep actually exercised dead blocks

    def test_all_blocks_dead_serves_fully_degraded(self, model, prob):
        plan = model.plan(_spec(max_batch=8))
        U = np.asarray(prob["U"][:8], np.float32)
        alive = np.zeros(prob["M"], bool)
        m, v = map(np.asarray, plan.routed_diag(U, block_alive=alive))
        assert np.asarray(plan.stats.last_degraded).all()
        m_glob, v_glob = map(np.asarray, ppic.global_diag(
            plan.kfn, plan.params, plan.state, U))
        np.testing.assert_allclose(m, m_glob, rtol=1e-5, atol=1e-5)
        assert np.isfinite(m).all() and np.isfinite(v).all()

    def test_block_alive_shape_validated(self, model, prob):
        plan = model.plan(_spec(max_batch=8))
        U = np.asarray(prob["U"][:4], np.float32)
        with pytest.raises(ValueError, match="block_alive"):
            plan.routed_diag(U, block_alive=np.ones(prob["M"] + 1, bool))

    def test_generic_plan_rejects_block_alive(self, prob):
        """Only the PIC family has a degradation path; the generic routed
        plan refuses the mask instead of silently ignoring it."""
        fgp = api.fit("fgp", prob["kfn"], prob["params"], prob["X"],
                      prob["y"])
        plan = fgp.plan(api.ServeSpec(max_batch=8))
        with pytest.raises(ValueError, match="bounded-degradation"):
            plan.routed_diag(np.asarray(prob["U"][:4], np.float32),
                             block_alive=np.ones(prob["M"], bool))

    def test_warmup_covers_degraded_ladder_zero_recompiles(self, model):
        plan = model.plan(_spec(max_batch=8))
        plan.warmup(3)
        traces0 = plan.stats.n_traces
        rng = np.random.RandomState(0)
        for k in range(1, 4):       # changing failure patterns, one program
            alive = np.ones(4, bool)
            alive[rng.choice(4, size=k, replace=False)] = False
            plan.routed_diag(rng.randn(5, 3).astype(np.float32),
                             block_alive=alive)
        assert plan.stats.n_traces == traces0


# ---------------------------------------------------------------------------
# Health bookkeeping + admission validation
# ---------------------------------------------------------------------------

class TestHealthTracker:
    def test_policy_validation(self):
        with pytest.raises(ValueError):
            HealthPolicy(max_retries=-1)
        with pytest.raises(ValueError):
            HealthPolicy(max_consecutive_failures=0)
        with pytest.raises(ValueError):
            HealthPolicy(backoff_jitter=1.5)

    def test_failure_threshold_and_reset(self):
        h = HealthTracker(3, HealthPolicy(max_consecutive_failures=2))
        assert not h.record_failure(1)
        h.record_success([1])
        assert not h.record_failure(1)       # success reset the streak
        assert h.record_failure(1)           # threshold crossed
        assert h.mark_dead(1, now=10.0)
        assert h.dead_blocks() == [1]
        assert not h.mark_dead(1, now=11.0)  # idempotent
        assert h.revive_all(now=12.0) == [1]
        assert h.alive_mask().all()
        assert h.blocks[1].consecutive_failures == 0

    def test_backoff_deterministic_and_exponential(self):
        a = HealthTracker(2, HealthPolicy(seed=42))
        b = HealthTracker(2, HealthPolicy(seed=42))
        seq_a = [a.backoff_ms(i) for i in range(4)]
        seq_b = [b.backoff_ms(i) for i in range(4)]
        assert seq_a == seq_b
        no_jitter = HealthTracker(
            2, HealthPolicy(backoff_jitter=0.0, backoff_base_ms=2.0))
        assert [no_jitter.backoff_ms(i) for i in range(3)] == [2.0, 4.0, 8.0]

    def test_slowest_of_uses_latency_evidence(self):
        h = HealthTracker(3, HealthPolicy())
        h.observe_latency([0, 1], 10.0)      # seeds: 0 -> 10, 1 -> 10
        h.observe_latency([1, 2], 90.0)      # 1 blends up, 2 seeds at 90
        assert h.slowest_of([0, 1, 2]) == 2
        assert h.slowest_of([0, 1]) == 1     # mixed evidence beats fast-only
        h.mark_dead(2, now=0.0)
        assert h.slowest_of([2]) is None     # dead blocks can't be blamed

    def test_health_requires_routed(self, model):
        sched = TenantScheduler(clock=Clock())
        with pytest.raises(ValueError, match="routed"):
            sched.admit("t", model, api.ServeSpec(max_batch=8), health=True)

    def test_gpserver_surface(self, model, prob):
        srv = GPServer(model, spec=_spec(max_batch=4), health=True)
        assert srv.health is not None
        snap = srv.health_snapshot()
        assert snap["n_blocks"] == prob["M"] and snap["dead_blocks"] == []
        tk = srv.submit(np.asarray(prob["U"][0], np.float32))
        srv.flush()
        m, v, dg = srv.collect(tk)
        assert not dg and np.isfinite(np.asarray(m)).all()
        plain = GPServer(model, spec=_spec(max_batch=4))
        assert plain.health is None and plain.health_snapshot() is None


# ---------------------------------------------------------------------------
# Fault harness determinism
# ---------------------------------------------------------------------------

class TestChaosHarness:
    def test_schedule_is_deterministic(self):
        plan = FaultPlan(fail_at={1: (2, 5)}, nan_at={0: 3},
                         straggle_ms={2: 1.0}, seed=7)
        logs = []
        for _ in range(2):
            clk = Clock()
            inj = FaultInjector(plan, sleep=clk.sleep)
            log = []
            assign = np.array([0, 1, 2])
            alive = np.ones(3, bool)
            for i in range(6):
                try:
                    inj.before_dispatch(assign, alive)
                    log.append(("ok", round(clk.t, 6)))
                except BlockDied as e:
                    log.append(("died", e.block, e.flush_index))
                mean = np.zeros(3)
                m2, _ = inj.poison(assign, mean, mean.copy(), alive)
                log.append(tuple(np.isnan(m2)))
            logs.append(log)
        assert logs[0] == logs[1]

    def test_fault_windows(self):
        clk = Clock()
        inj = FaultInjector(FaultPlan(fail_at={0: (1, 3)}), sleep=clk.sleep)
        assign, alive = np.array([0]), np.ones(1, bool)
        inj.before_dispatch(assign, alive)              # idx 0: before window
        for _ in range(2):                              # idx 1, 2: active
            with pytest.raises(BlockDied):
                inj.before_dispatch(assign, alive)
        inj.before_dispatch(assign, alive)              # idx 3: healed
        assert inj.n_injected_faults == 2

    def test_dead_block_not_blamed_again(self):
        """Once routing masks a block out, its declared death no longer
        fires — the machine has stopped being asked."""
        inj = FaultInjector(FaultPlan(fail_at={1: 0}))
        assign = np.array([0, 1])
        inj.before_dispatch(assign, np.array([True, False]))  # no raise
        with pytest.raises(BlockDied):
            inj.before_dispatch(assign, np.array([True, True]))

    def test_burst_schedule(self):
        plan = FaultPlan(burst_at_steps={3: 10})
        assert plan.burst_at(3) == 10 and plan.burst_at(4) == 0

    def test_poison_state_organic_nan(self, prob, model):
        """NaN-poisoned block factors produce NaN posteriors through the
        REAL compute path for that block's rows only (the jnp.where select
        in the degraded program firewalls them once the block is masked)."""
        from repro.serving.chaos import poison_state
        bad = api.FittedGP(model.method, model.kfn, model.params,
                           poison_state(model.state, 1))
        plan = bad.plan(_spec(max_batch=16))
        U = np.asarray(prob["U"][:16], np.float32)
        assign = clustering.nearest_center_np(
            U, np.asarray(bad.state.centroids))
        m, _ = map(np.asarray, plan.routed_diag(U))
        assert np.isnan(m[assign == 1]).all()
        # mask the poisoned block out: every row finite again
        alive = np.ones(prob["M"], bool)
        alive[1] = False
        m2, v2 = map(np.asarray, plan.routed_diag(U, block_alive=alive))
        assert np.isfinite(m2).all() and np.isfinite(v2).all()


# ---------------------------------------------------------------------------
# Checkpoint integrity (core/serialize.py CheckpointError)
# ---------------------------------------------------------------------------

class TestCheckpointErrors:
    def test_missing_paths(self, tmp_path):
        missing = tmp_path / "nope.npz"
        for loader in (serialize.load_state, serialize.load_store):
            with pytest.raises(serialize.CheckpointError,
                               match="no such"):
                loader(missing)

    def test_truncated_store(self, pic_store, tmp_path):
        p = tmp_path / "store.npz"
        serialize.save_store(p, pic_store)
        raw = p.read_bytes()
        p.write_bytes(raw[:len(raw) // 2])
        with pytest.raises(serialize.CheckpointError,
                           match="truncated or corrupt"):
            serialize.load_store(p)

    def test_corrupt_store_detected(self, pic_store, tmp_path):
        p = tmp_path / "store.npz"
        serialize.save_store(p, pic_store)
        FaultInjector(FaultPlan(seed=1)).corrupt(p)
        with pytest.raises(serialize.CheckpointError) as ei:
            serialize.load_store(p)
        assert str(p) in str(ei.value)       # path + reason in the message

    def test_corrupt_state_detected(self, model, tmp_path):
        p = tmp_path / "state.npz"
        serialize.save_state(p, model.state)
        FaultInjector(FaultPlan(seed=2)).corrupt(p)
        with pytest.raises(serialize.CheckpointError):
            serialize.load_state(p)

    def test_roundtrip_still_bitwise_with_checksums(self, pic_store, model,
                                                    tmp_path):
        ps = tmp_path / "state.npz"
        serialize.save_state(ps, model.state)
        back = serialize.load_state(ps)
        for a, b in zip(jax.tree_util.tree_leaves(model.state),
                        jax.tree_util.tree_leaves(back)):
            np.testing.assert_array_equal(np.asarray(a), np.asarray(b))
        pstore = tmp_path / "store.npz"
        serialize.save_store(pstore, pic_store)
        back_store = serialize.load_store(pstore)
        for a, b in zip(jax.tree_util.tree_leaves(pic_store.to_state()),
                        jax.tree_util.tree_leaves(back_store.to_state())):
            np.testing.assert_array_equal(np.asarray(a), np.asarray(b))


# ---------------------------------------------------------------------------
# Satellite (a) regression: jitted cold-store prediction
# ---------------------------------------------------------------------------

class TestTracedStore:
    def test_jitted_cold_store_predict(self, prob):
        """The fig*/table1 bench path: ``ppic.predict`` (which builds a
        cold store and serves through ``to_state()``) must work UNDER JIT —
        the traced ``alive`` mask in ``PICStore.to_state`` used to raise
        TracerBoolConversionError and silently zero out every jitted bench
        suite."""
        p = prob
        runner = VmapRunner(M=p["M"])
        out = jax.jit(lambda: ppic.predict(p["kfn"], p["params"], p["S"],
                                           p["X"], p["y"], p["U"][:8],
                                           runner))()
        assert np.isfinite(np.asarray(out.mean)).all()
        assert np.isfinite(np.asarray(out.var)).all()
