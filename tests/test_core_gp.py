"""Exact-GP + covariance behaviour and property-based invariants."""
import jax
import jax.numpy as jnp
import numpy as np
import pytest
from hypothesis import given, settings, strategies as st

from repro.core import covariance as cov, gp, linalg

from helpers import make_problem


class TestCovariance:
    def test_symmetry_and_diag(self):
        p = make_problem()
        K = p["kfn"](p["params"], p["X"], p["X"])
        np.testing.assert_allclose(K, K.T, atol=1e-12)
        np.testing.assert_allclose(jnp.diag(K),
                                   cov.signal_var(p["params"]), atol=1e-12)

    def test_psd(self):
        p = make_problem()
        K = cov.add_noise(p["kfn"](p["params"], p["X"], p["X"]), p["params"])
        w = jnp.linalg.eigvalsh(K)
        assert float(w.min()) > 0

    @pytest.mark.parametrize("name", ["se", "matern52", "rq"])
    def test_kdiag_matches_dense(self, name):
        p = make_problem()
        kfn = cov.make_kernel(name)
        d1 = cov.kdiag(kfn, p["params"], p["X"])
        d2 = jnp.diag(kfn(p["params"], p["X"], p["X"]))
        np.testing.assert_allclose(d1, d2, atol=1e-10)

    @settings(max_examples=15, deadline=None)
    @given(seed=st.integers(0, 2**16), d=st.integers(1, 6),
           ls=st.floats(0.3, 5.0))
    def test_property_cauchy_schwarz(self, seed, d, ls):
        """|k(x,x')| <= signal_var for SE (correlation bounded by 1)."""
        key = jax.random.PRNGKey(seed)
        X = jax.random.normal(key, (20, d), jnp.float64)
        params = cov.init_params(d, signal=1.7, lengthscale=ls,
                                 dtype=jnp.float64)
        K = cov.se_ard(params, X, X)
        assert float(jnp.abs(K).max()) <= float(cov.signal_var(params)) + 1e-9


class TestFullGP:
    def test_interpolates_with_small_noise(self):
        p = make_problem(noise=1e-4)
        post = gp.predict(p["kfn"], p["params"], p["X"], p["y"], p["X"][:10])
        np.testing.assert_allclose(post.mean, p["y"][:10], atol=1e-2)

    def test_posterior_variance_below_prior(self):
        p = make_problem()
        post = gp.predict(p["kfn"], p["params"], p["X"], p["y"], p["U"])
        prior = cov.signal_var(p["params"])
        assert float(post.var.max()) <= float(prior) + 1e-9
        assert float(post.var.min()) >= 0.0

    def test_diag_only_matches_dense(self):
        p = make_problem()
        a = gp.predict(p["kfn"], p["params"], p["X"], p["y"], p["U"])
        b = gp.predict(p["kfn"], p["params"], p["X"], p["y"], p["U"],
                       diag_only=True)
        np.testing.assert_allclose(b.var, a.var, atol=1e-9)

    def test_nlml_grad_finite(self):
        p = make_problem()
        g = jax.grad(lambda th: gp.nlml(p["kfn"], th, p["X"], p["y"]))(
            p["params"])
        for leaf in jax.tree.leaves(g):
            assert bool(jnp.isfinite(leaf).all())

    @settings(max_examples=10, deadline=None)
    @given(seed=st.integers(0, 2**16))
    def test_property_more_data_lowers_variance(self, seed):
        """Conditioning on more observations cannot raise predictive var."""
        p = make_problem(seed=seed)
        v1 = gp.predict(p["kfn"], p["params"], p["X"][:32], p["y"][:32],
                        p["U"]).var
        v2 = gp.predict(p["kfn"], p["params"], p["X"], p["y"], p["U"]).var
        assert float((v2 - v1).max()) < 1e-6


class TestLinalg:
    @settings(max_examples=10, deadline=None)
    @given(seed=st.integers(0, 2**16), n=st.integers(2, 40))
    def test_property_psd_solve_roundtrip(self, seed, n):
        A = jax.random.normal(jax.random.PRNGKey(seed), (n, n), jnp.float64)
        K = A @ A.T + jnp.eye(n)
        B = jax.random.normal(jax.random.PRNGKey(seed + 1), (n, 3),
                              jnp.float64)
        X = linalg.psd_solve(K, B, jitter=0.0)
        np.testing.assert_allclose(K @ X, B, atol=1e-7)
