"""Multi-device shard_map coverage.

Runs in a subprocess with XLA_FLAGS forcing 8 host devices (the flag must not
leak into this process — smoke tests need the real single device), comparing
every parallel method's shard_map execution against the vmap simulation.
"""
import os
import subprocess
import sys
from pathlib import Path

import pytest

pytestmark = pytest.mark.slow  # subprocess spawn + 8-device XLA compile

SCRIPT = r"""
import jax, jax.numpy as jnp
jax.config.update("jax_enable_x64", True)
assert len(jax.devices()) == 8, jax.devices()
from repro.core import covariance as cov, ppitc, ppic, picf, support, hyper
from repro.parallel.runner import ShardMapRunner, VmapRunner

mesh = jax.make_mesh((8,), ("data",))
sm = ShardMapRunner(mesh=mesh, axis_name="data")
vm = VmapRunner(M=8)
key = jax.random.PRNGKey(0)
n, u, s, d = 128, 32, 12, 3
X = jax.random.normal(key, (n, d))
S = jax.random.normal(jax.random.PRNGKey(1), (s, d))
U = jax.random.normal(jax.random.PRNGKey(2), (u, d))
params = cov.init_params(d, signal=1.3, noise=0.3, lengthscale=1.5,
                         dtype=jnp.float64)
kfn = cov.make_kernel("se")
y = jnp.sin(X[:, 0]) * 2 + X[:, 1] + 0.1 * jax.random.normal(
    jax.random.PRNGKey(3), (n,))

def close(a, b, tol=1e-10):
    assert float(jnp.abs(a - b).max()) < tol, float(jnp.abs(a - b).max())

a, b = ppitc.predict(kfn, params, S, X, y, U, sm), \
    ppitc.predict(kfn, params, S, X, y, U, vm)
close(a.mean, b.mean); close(a.blocks, b.blocks)
a, b = ppic.predict(kfn, params, S, X, y, U, sm), \
    ppic.predict(kfn, params, S, X, y, U, vm)
close(a.mean, b.mean); close(a.blocks, b.blocks)
a, b = picf.predict(kfn, params, X, y, U, 48, sm), \
    picf.predict(kfn, params, X, y, U, 48, vm)
close(a.mean, b.mean); close(a.cov, b.cov)
a, b = picf.predict(kfn, params, X, y, U, 48, sm, shard_u=True), \
    picf.predict(kfn, params, X, y, U, 48, vm, shard_u=True)
close(a.mean, b.mean); close(a.blocks, b.blocks)
close(support.select_support_parallel(kfn, params, X, 8, sm),
      support.select_support_parallel(kfn, params, X, 8, vm))
close(hyper.pitc_nlml(kfn, params, S, X, y, sm),
      hyper.pitc_nlml(kfn, params, S, X, y, vm), 1e-8)

# fully-collective execution (psum inside the per-machine program)
a, b = ppitc.predict_distributed(kfn, params, S, X, y, U, sm), \
    ppitc.predict_distributed(kfn, params, S, X, y, U, vm)
close(a.mean, b.mean); close(a.blocks, b.blocks)
a, b = ppic.predict_distributed(kfn, params, S, X, y, U, sm), \
    ppic.predict_distributed(kfn, params, S, X, y, U, vm)
close(a.mean, b.mean); close(a.blocks, b.blocks)
a, b = picf.predict_distributed(kfn, params, X, y, U, 48, sm), \
    picf.predict_distributed(kfn, params, X, y, U, 48, vm)
close(a.mean, b.mean); close(a.cov, b.cov)

# PosteriorState round-trip: both runners' fit paths produce the same pytree
import jax.tree_util as jtu
def close_tree(ta, tb, tol=1e-10):
    la, lb = jax.tree.leaves(ta), jax.tree.leaves(tb)
    assert jtu.tree_structure(ta) == jtu.tree_structure(tb)
    assert len(la) == len(lb)
    for x, z in zip(la, lb):
        close(x, z, tol)
close_tree(ppitc.fit(kfn, params, X, y, S=S, runner=sm),
           ppitc.fit(kfn, params, X, y, S=S, runner=vm))
close_tree(ppic.fit(kfn, params, X, y, S=S, runner=sm),
           ppic.fit(kfn, params, X, y, S=S, runner=vm))
close_tree(picf.fit(kfn, params, X, y, rank=48, runner=sm),
           picf.fit(kfn, params, X, y, rank=48, runner=vm))

# two-axis machines: ("pod", "data") as in the production mesh
mesh2 = jax.make_mesh((2, 4), ("pod", "data"))
sm2 = ShardMapRunner(mesh=mesh2, axis_name=("pod", "data"))
a = ppic.predict(kfn, params, S, X, y, U, sm2)
close(a.mean, ppic.predict(kfn, params, S, X, y, U, vm).mean)
print("SHARD_MAP_OK")
"""


def test_shard_map_matches_vmap_on_8_devices():
    env = dict(os.environ)
    env["XLA_FLAGS"] = "--xla_force_host_platform_device_count=8"
    env["PYTHONPATH"] = str(Path(__file__).resolve().parents[1] / "src")
    r = subprocess.run([sys.executable, "-c", SCRIPT], env=env,
                       capture_output=True, text=True, timeout=600)
    assert r.returncode == 0, r.stdout + r.stderr
    assert "SHARD_MAP_OK" in r.stdout
