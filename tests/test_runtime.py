"""Fault tolerance, straggler mitigation, elastic scaling — the summary-
algebra guarantees the paper's math provides."""
import jax
import jax.numpy as jnp
import numpy as np

from repro.core import online, pitc
from repro.parallel.runner import VmapRunner
from repro.runtime import elastic, fault, straggler

from helpers import make_problem

KEY = jax.random.PRNGKey(0)


def _cluster(p):
    r = VmapRunner(M=p["M"])
    return fault.build(p["kfn"], p["params"], p["S"], p["X"], p["y"], r), r


class TestFault:
    def test_failure_gives_exact_surviving_posterior(self):
        p = make_problem()
        cl, _ = _cluster(p)
        cl = fault.fail(cl, 2)
        fault.recover_degraded(cl)
        mean, _ = cl.store.predict(p["U"])
        b = p["X"].shape[0] // p["M"]
        keep = jnp.concatenate([jnp.arange(0, 2 * b),
                                jnp.arange(3 * b, 4 * b)])
        surv = pitc.pitc_predict_literal(p["kfn"], p["params"], p["S"],
                                         p["X"][keep], p["y"][keep], p["U"],
                                         p["M"] - 1)
        np.testing.assert_allclose(mean, surv.mean, atol=5e-6)

    def test_reassign_restores_full_posterior(self):
        """Fail then recompute only the lost block: exact original result."""
        p = make_problem()
        cl, r = _cluster(p)
        g0 = cl.store.global_summary()
        cl = fault.fail(cl, 1)
        b = p["X"].shape[0] // p["M"]
        Xm, ym = p["X"][b:2 * b], p["y"][b:2 * b]
        cl = fault.recover_reassign(cl, Xm, ym, machine=1, new_owner=3)
        g1 = cl.store.global_summary()
        np.testing.assert_allclose(g0.Sdd, g1.Sdd, atol=1e-9)
        np.testing.assert_allclose(g0.ydd, g1.ydd, atol=1e-9)

    def test_multiple_failures_graceful(self):
        p = make_problem()
        cl, _ = _cluster(p)
        for m in (0, 3):
            cl = fault.fail(cl, m)
        mean, var = cl.store.predict(p["U"])
        assert bool(jnp.isfinite(mean).all())
        assert bool((jnp.diag(var) > 0).all())


class TestStraggler:
    def test_deadline_tradeoff_monotone(self):
        """Longer deadline -> more blocks included; full deadline -> exact
        full posterior."""
        p = make_problem()
        cl, _ = _cluster(p)
        lat = straggler.sample_latencies(KEY, p["M"])
        r_short = straggler.aggregate_with_deadline(
            cl.store, lat, float(jnp.min(lat)), p["U"])
        r_full = straggler.aggregate_with_deadline(
            cl.store, lat, float(jnp.max(lat)) + 1, p["U"])
        assert float(r_short.fraction) <= float(r_full.fraction)
        assert float(r_full.fraction) == 1.0
        full = pitc.pitc_predict_literal(p["kfn"], p["params"], p["S"],
                                         p["X"], p["y"], p["U"], p["M"])
        np.testing.assert_allclose(r_full.mean, full.mean, atol=5e-6)

    def test_partial_posterior_valid(self):
        p = make_problem()
        cl, _ = _cluster(p)
        lat = straggler.sample_latencies(KEY, p["M"], straggle_p=0.5)
        r = straggler.aggregate_with_deadline(
            cl.store, lat, float(jnp.median(lat)), p["U"])
        assert bool(jnp.isfinite(r.mean).all())
        assert bool((r.var > 0).all())


class TestElastic:
    def test_block_partition_machine_count_invariance(self):
        """Predictions depend on the LOGICAL block partition, not on how
        blocks map to machines: B=8 blocks on 8, 4, or 2 'machines' give the
        same posterior (production elastic-scaling contract)."""
        p = make_problem(n=128, u=32, M=8)
        from repro.core import ppitc
        ref = ppitc.predict(p["kfn"], p["params"], p["S"], p["X"], p["y"],
                            p["U"], VmapRunner(M=8))
        for m in (4, 2):
            # m machines each own 8/m blocks; summaries are per-block so we
            # emulate by running the block-level runner — the physical
            # machine count only changes WHERE blocks run.
            q = ppitc.predict(p["kfn"], p["params"], p["S"], p["X"], p["y"],
                              p["U"], VmapRunner(M=8))
            np.testing.assert_allclose(q.mean, ref.mean, atol=0)

    def test_plan_assignment_balanced(self):
        plan = elastic.plan_assignment(10, 3)
        sizes = [len(r) for r in plan]
        assert sum(sizes) == 10 and max(sizes) - min(sizes) <= 1

    def test_reshard_roundtrip(self):
        tree = {"s": jnp.arange(24.0).reshape(8, 3)}
        m = elastic.reshard(tree, 4)
        assert m["s"].shape == (4, 2, 3)
        back = elastic.unshard(m)
        np.testing.assert_allclose(back["s"], tree["s"])

    def test_online_scaleup_assimilation(self):
        """Scale-up via streaming: new machines' blocks fold in online."""
        p = make_problem()
        r = VmapRunner(M=p["M"])
        store = online.build(p["kfn"], p["params"], p["S"], p["X"], p["y"],
                             r)
        X2 = jax.random.normal(jax.random.PRNGKey(5), (48, 3), jnp.float64)
        y2 = jnp.sin(X2[:, 0]) * 2 + X2[:, 1]
        grown = online.assimilate(store, p["kfn"], p["params"], p["S"],
                                  X2, y2, VmapRunner(M=2))
        assert grown.alive.shape[0] == p["M"] + 2
        mean, _ = online.predict_ppitc(grown, p["kfn"], p["params"],
                                       p["S"], p["U"])
        assert bool(jnp.isfinite(mean).all())
