"""Two-phase serving-plan API (core/api.py ``ServeSpec``/``ServePlan``).

Acceptance (ISSUE 5):

* ``plan.diag``/``plan.routed_diag`` posteriors are BITWISE-equal (f32) to
  the existing ``predict_diag``/``predict_routed_diag`` paths across
  methods and bucket shapes — compared jitted-vs-jitted on identical padded
  batches (XLA fuses eager covariance assembly differently, so eager-vs-jit
  bit equality was never the property; see test_routing_equivalence);
* ``rebind`` after assimilate/retire reuses every executable: zero
  recompiles (trace-count probe, as in the xcov hot-swap tests) and
  bitwise-equal posteriors vs a cold plan on the same state;
* balanced routed flushes select the G=0 executable (PlanStats/ServeStats
  counters), skewed ones a g>0 program from the ladder — all bitwise-equal
  to the worst-case-G legacy program;
* the legacy ``GPMethod.predict*`` per-call shims are GONE (removed in the
  multi-tenant serving PR): ``method.plan(...)`` is the only serving entry
  point and first-party surfaces are silent under ``-W error``;
* spec-owned ladders: ``default_buckets`` edge cases (max_batch <
  min_bucket, non-tile-aligned max_batch, degenerate sizes) are pinned;
* ``ServeSpec(cached_cinv=True)`` serves the same posterior through the
  batched-matmul backend cache (allclose; the float path legitimately
  differs from trsm) and refreshes the cache on rebind;
* store checkpointing (core/serialize.save_store/load_store): bitwise
  round-trip, restart-and-keep-assimilating, opaque-member guards.
"""
import functools
import warnings

import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.core import api, gp, picf, ppic, ppitc, serialize
from repro.core import covariance as cov
from repro.launch.gp_serve import GPServer
from repro.parallel.runner import VmapRunner

from helpers import make_problem

# one jitted instance of each legacy module-level impl, shared across tests
# so plan-vs-legacy comparisons are executable-vs-executable (kfn is a
# static closure input, exactly as the plan executables close over it)
_legacy_diag = {
    "fgp": jax.jit(gp.predict_batch_diag, static_argnums=0),
    "ppitc": jax.jit(ppitc.predict_batch_diag, static_argnums=0),
    "ppic": jax.jit(ppic.predict_batch_diag, static_argnums=0),
    "picf": jax.jit(picf.predict_batch_diag, static_argnums=0),
}


def _pad(U, bucket):
    Un = np.asarray(U)
    buf = np.zeros((bucket,) + Un.shape[1:], Un.dtype)
    buf[:Un.shape[0]] = Un
    return buf


@pytest.fixture(scope="module")
def prob32():
    return make_problem(dtype=jnp.float32)


@pytest.fixture(scope="module")
def runner(prob32):
    return VmapRunner(M=prob32["M"])


@pytest.fixture(scope="module")
def models(prob32, runner):
    p = prob32
    return {
        "fgp": api.fit("fgp", p["kfn"], p["params"], p["X"], p["y"]),
        "ppitc": api.fit("ppitc", p["kfn"], p["params"], p["X"], p["y"],
                         S=p["S"], runner=runner),
        "ppic": api.fit("ppic", p["kfn"], p["params"], p["X"], p["y"],
                        S=p["S"], runner=runner),
        "picf": api.fit("picf", p["kfn"], p["params"], p["X"], p["y"],
                        rank=24, runner=runner),
    }


class TestSpecOwnedLadders:
    """Satellite: default_buckets edge cases, surfaced by spec ownership."""

    def test_max_batch_below_min_bucket_still_covers(self):
        for max_batch in (1, 3, 5, 7):
            for block_q in (1, 4, 8):
                bs = api.default_buckets(max_batch, min_bucket=8,
                                         block_q=block_q)
                assert bs[-1] >= max_batch, (max_batch, block_q, bs)
                assert len(bs) == 1            # no sub-max rungs exist

    def test_non_tile_aligned_max_batch_rounds_up_never_down(self):
        # the top bucket must COVER the queue: align up, never truncate
        for max_batch in (9, 20, 33, 100, 130):
            for block_q in (8, 16, 32):
                bs = api.default_buckets(max_batch, block_q=block_q)
                assert bs[-1] >= max_batch
                assert all(b % block_q == 0 for b in bs)
                assert list(bs) == sorted(set(bs))

    def test_degenerate_sizes_rejected(self):
        # min_bucket=0 used to hang the doubling loop; max_batch=0 emitted
        # an empty 0-bucket ladder
        for kw in (dict(max_batch=0), dict(max_batch=8, min_bucket=0),
                   dict(max_batch=8, block_q=0), dict(max_batch=-4)):
            with pytest.raises(ValueError, match="positive"):
                api.default_buckets(**{"max_batch": 64, **kw})

    def test_explicit_buckets_must_cover_max_batch(self, prob32, models):
        spec = api.ServeSpec(max_batch=64, buckets=(8, 16))
        with pytest.raises(ValueError, match="under-cover"):
            models["ppitc"].plan(spec)

    def test_identity_bucketing_by_default(self, models, prob32):
        """No declared ladder -> exact batches (padding is posterior-
        visible for positional PIC, so it must be spec-opt-in)."""
        plan = models["ppic"].plan()
        assert plan.buckets is None
        assert plan.bucket_for(13) == 13
        m, v = plan.diag(prob32["U"][:13])
        assert m.shape == (13,) and plan.stats.n_padded_rows == 0

    def test_oversized_batches_round_to_top_multiple(self, models):
        plan = models["ppitc"].plan(api.ServeSpec(max_batch=8))
        assert plan.buckets == (8,)
        assert plan.bucket_for(20) == 24

    def test_server_rejects_conflicting_legacy_kwargs(self, models):
        """spec= owns the policy: a disagreeing legacy kwarg must fail
        loudly, not silently serve the wrong path (routed=True next to a
        non-routed spec would drop composition invariance)."""
        spec = api.ServeSpec(max_batch=16)
        with pytest.raises(ValueError, match="legacy serving kwargs"):
            GPServer(models["ppic"], routed=True, spec=spec)
        with pytest.raises(ValueError, match="legacy serving kwargs"):
            GPServer(models["ppic"], block_q=16, spec=spec)
        with pytest.raises(ValueError, match="legacy serving kwargs"):
            GPServer(models["ppic"], max_batch=32, spec=spec)
        srv = GPServer(models["ppic"], spec=api.ServeSpec(max_batch=16,
                                                          routed=True))
        assert srv.routed and srv.max_batch == 16

    def test_bad_block_q_rejected(self, models):
        with pytest.raises(ValueError, match="positive tile"):
            models["ppitc"].plan(api.ServeSpec(max_batch=8, block_q=0))
        with pytest.raises(ValueError, match="positive tile"):
            cov.make_spec("se", block_q=-8)

    def test_degenerate_routed_spec_rejected(self):
        # alpha=0 used to surface as a ZeroDivisionError deep inside
        # routed_capacity at flush time; fail at construction instead
        with pytest.raises(ValueError, match="alpha"):
            api.ServeSpec(routed=True, alpha=0)
        with pytest.raises(ValueError, match="max_overflow_groups"):
            api.ServeSpec(routed=True, max_overflow_groups=-1)

    def test_default_plan_diag_stays_traceable(self, models, prob32):
        """Identity bucketing + 'preserve' dtype keeps FittedGP.predict_diag
        a pure-jax call: wrapping it in an outer jit must trace (no host
        round-trip on the unpadded hot path)."""
        p = prob32
        f = jax.jit(lambda U: models["ppitc"].predict_diag(U))
        m, v = f(p["U"][:8])
        rm, rv = models["ppitc"].predict_diag(p["U"][:8])
        # tracing is the property; the outer jit inlines and re-fuses the
        # program, so only roundoff-level agreement is guaranteed (f32)
        np.testing.assert_allclose(np.asarray(m), np.asarray(rm),
                                   rtol=1e-4, atol=1e-5)

    def test_dtype_policy(self, models, prob32):
        p = prob32
        # "state": mixed-precision callers share one executable
        plan = models["ppitc"].plan(api.ServeSpec(max_batch=8,
                                                  dtype="state"))
        m64, _ = plan.diag(np.asarray(p["U"][:4], np.float64))
        m32, _ = plan.diag(p["U"][:4])
        np.testing.assert_array_equal(np.asarray(m64), np.asarray(m32))
        with pytest.raises(ValueError, match="dtype policy"):
            models["ppitc"].plan(api.ServeSpec(max_batch=8,
                                               dtype="bf16")).diag(p["U"])


class TestPlanBitwiseEquivalence:
    """plan.diag == the jitted legacy path on the same padded batch,
    bitwise in f32, across methods and bucket shapes."""

    @pytest.mark.parametrize("name", ["fgp", "ppitc", "ppic", "picf"])
    @pytest.mark.parametrize("u", [1, 5, 8, 24])
    def test_diag_matches_legacy_bitwise(self, models, prob32, name, u):
        model = models[name]
        spec = api.ServeSpec(max_batch=16)
        plan = model.plan(spec)
        U = prob32["U"][:u]
        m, v = plan.diag(U)
        bucket = plan.bucket_for(u)
        rm, rv = _legacy_diag[name](model.kfn, model.params, model.state,
                                    jnp.asarray(_pad(U, bucket)))
        np.testing.assert_array_equal(np.asarray(m), np.asarray(rm)[:u])
        np.testing.assert_array_equal(np.asarray(v), np.asarray(rv)[:u])

    @pytest.mark.parametrize("u", [1, 5, 8, 24])
    def test_routed_matches_legacy_bitwise(self, models, prob32, u):
        model = models["ppic"]
        spec = api.ServeSpec(max_batch=16, routed=True)
        plan = model.plan(spec)
        U = prob32["U"][:u]
        m, v = plan.routed_diag(U)
        bucket = plan.bucket_for(u)
        ref = jax.jit(functools.partial(ppic.predict_routed_diag,
                                        model.kfn, tile=plan.block_q))
        rm, rv = ref(model.params, model.state, jnp.asarray(_pad(U, bucket)))
        np.testing.assert_array_equal(np.asarray(m), np.asarray(rm)[:u])
        np.testing.assert_array_equal(np.asarray(v), np.asarray(rv)[:u])

    def test_skewed_overflow_program_matches_worst_case_bitwise(self, models,
                                                                prob32):
        """A flush needing 1-2 overflow groups runs a SMALLER program than
        the worst-case G — and still produces bit-identical rows (per-row
        programs are independent of the group batch size)."""
        model = models["ppic"]
        plan = model.plan(api.ServeSpec(max_batch=32, routed=True))
        ref = jax.jit(functools.partial(ppic.predict_routed_diag,
                                        model.kfn, tile=plan.block_q))
        c = np.asarray(model.state.centroids)
        rng = np.random.RandomState(0)
        for target in range(prob32["M"]):
            # all 24 queries crowd one block's centroid -> guaranteed skew
            U = (np.tile(c[target], (24, 1))
                 + 0.01 * rng.randn(24, c.shape[1])).astype(np.float32)
            m, v = plan.routed_diag(U)
            assert plan.stats.last_g > 0
            bucket = plan.bucket_for(24)
            rm, rv = ref(model.params, model.state,
                         jnp.asarray(_pad(U, bucket)))
            np.testing.assert_array_equal(np.asarray(m), np.asarray(rm)[:24])
            np.testing.assert_array_equal(np.asarray(v), np.asarray(rv)[:24])

    def test_balanced_flush_selects_g0(self, models, prob32):
        """Balanced-by-construction traffic (bucket-exact, equal per-block
        load) runs the main-bucket-only program."""
        model = models["ppic"]
        plan = model.plan(api.ServeSpec(max_batch=32, routed=True))
        c = np.asarray(model.state.centroids)
        rng = np.random.RandomState(1)
        U = np.concatenate([np.tile(c[m], (8, 1))
                            + 0.01 * rng.randn(8, c.shape[1])
                            for m in range(c.shape[0])]).astype(np.float32)
        before = plan.stats.n_g0_batches
        m, _ = plan.routed_diag(U)           # 32 rows, 8 per block == cap
        assert plan.stats.last_g == 0
        assert plan.stats.n_g0_batches == before + 1
        # and it is the same posterior the worst-case program serves
        ref = jax.jit(functools.partial(ppic.predict_routed_diag,
                                        model.kfn, tile=plan.block_q))
        rm, _ = ref(model.params, model.state, jnp.asarray(U))
        np.testing.assert_array_equal(np.asarray(m), np.asarray(rm))

    def test_partial_flush_pads_never_inflate_overflow_demand(self, models,
                                                              prob32):
        """Regression: pad rows pack into spare main-bucket capacity, so a
        small balanced batch padded up to a large bucket — the DEADLINE-
        flush common case — still selects the G=0 program (routing pads by
        centroid would pile them onto one block and force the worst-case
        overflow program on every partial flush)."""
        model = models["ppic"]
        plan = model.plan(api.ServeSpec(max_batch=32, routed=True))
        c = np.asarray(model.state.centroids)
        rng = np.random.RandomState(3)
        for u in (1, 5, 13):
            # round-robin over the centroids: per-block REAL load stays
            # under cap, so any g > 0 could only come from pad routing
            U = np.stack([c[i % c.shape[0]] + 0.01 * rng.randn(c.shape[1])
                          for i in range(u)]).astype(np.float32)
            m, v = plan.routed_diag(U)
            assert plan.stats.last_g == 0, u
            assert m.shape == (u,) and bool(jnp.isfinite(v).all())

    def test_server_asserts_g0_on_balanced_flushes(self, prob32, runner):
        """ISSUE acceptance: the ServeStats counter shows balanced flushes
        ran the G=0 executable; a skewed flush does not."""
        p = prob32
        model = api.fit("ppic", p["kfn"], p["params"], p["X"], p["y"],
                        S=p["S"], runner=runner)
        srv = GPServer(model, max_batch=32, routed=True)
        c = np.asarray(model.state.centroids)
        rng = np.random.RandomState(2)
        for m in range(c.shape[0]):          # balanced: 8 tickets per block
            for i in range(8):
                srv.submit((c[m] + 0.01 * rng.randn(c.shape[1]))
                           .astype(np.float32))
        assert srv.stats.n_size_flushes == 1
        assert srv.stats.n_g0_flushes == 1
        for i in range(32):                  # skewed: all on one block
            srv.submit((c[0] + 0.01 * rng.randn(c.shape[1]))
                       .astype(np.float32))
        assert srv.stats.n_batches == 2
        assert srv.stats.n_g0_flushes == 1   # skew did NOT run G=0
        assert srv.plan.stats.last_g > 0

    def test_max_overflow_groups_falls_back_to_worst_case(self, models,
                                                          prob32):
        model = models["ppic"]
        plan = model.plan(api.ServeSpec(max_batch=32, routed=True,
                                        max_overflow_groups=0))
        c = np.asarray(model.state.centroids)
        U = np.tile(c[0], (24, 1)).astype(np.float32)
        m, _ = plan.routed_diag(U)
        # demand (>=1 group) exceeds the cap (0) -> the worst-case program
        from repro.parallel.runner import routed_capacity
        cap, G = routed_capacity(plan.bucket_for(24), prob32["M"],
                                 tile=plan.block_q)
        assert plan.stats.last_g == G
        assert bool(jnp.isfinite(m).all())

    def test_full_cov_through_plan_and_spec(self, prob32, runner):
        """Satellite: KernelSpec threads through plan.full — the Pallas
        covariance impl is reachable from the full-covariance path."""
        p = prob32
        model = api.fit("ppitc", p["kfn"], p["params"], p["X"], p["y"],
                        S=p["S"], runner=runner)
        dense = model.plan().full(p["U"])
        spec = api.ServeSpec(kernel=cov.make_spec("se",
                                                  impl="pallas_interpret"))
        fused = model.plan(spec).full(p["U"])
        np.testing.assert_allclose(np.asarray(fused.mean),
                                   np.asarray(dense.mean), atol=1e-5)
        np.testing.assert_allclose(np.asarray(fused.cov),
                                   np.asarray(dense.cov), atol=1e-4)


class TestPlanLifecycle:
    """Satellite: rebind after assimilate/retire — zero recompiles + bitwise
    equality with a cold plan."""

    def test_rebind_after_assimilate_zero_recompiles(self, prob32, runner):
        p = prob32
        n1 = p["X"].shape[0] // 2
        store = api.init_store("ppitc", p["kfn"], p["params"], p["X"][:n1],
                               p["y"][:n1], S=p["S"], runner=runner)
        method = api.get("ppitc")
        spec = api.ServeSpec(max_batch=16)
        plan = method.plan(p["kfn"], p["params"], store.to_state(), spec)
        plan.diag(p["U"][:8])
        plan.diag(p["U"][:16])
        traces = plan.stats.n_traces
        # pPITC assimilation keeps the S-space state shapes -> rebind must
        # reuse both bucket executables
        store2 = store.assimilate(p["X"][n1:], p["y"][n1:])
        plan2 = plan.rebind(store2.to_state())
        m8, v8 = plan2.diag(p["U"][:8])
        m16, _ = plan2.diag(p["U"][:16])
        assert plan.stats.n_traces == traces, "rebind recompiled"
        assert plan2.stats is plan.stats
        # bitwise vs a COLD plan on the same state (fresh executables)
        cold = method.plan(p["kfn"], p["params"], store2.to_state(), spec)
        cm, cv = cold.diag(p["U"][:8])
        np.testing.assert_array_equal(np.asarray(m8), np.asarray(cm))
        np.testing.assert_array_equal(np.asarray(v8), np.asarray(cv))
        # and the swap actually changed the posterior
        assert float(jnp.abs(m16[:8] - m8).max()) >= 0  # shapes consistent

    def test_rebind_after_retire_revive_zero_recompiles(self, prob32,
                                                        runner):
        p = prob32
        store = api.init_store("ppic", p["kfn"], p["params"], p["X"],
                               p["y"], S=p["S"], runner=runner)
        method = api.get("ppic")
        spec = api.ServeSpec(max_batch=16, routed=True)
        plan = method.plan(p["kfn"], p["params"], store.to_state(), spec)
        plan.routed_diag(p["U"][:8])
        plan.diag(p["U"][:8])
        traces = plan.stats.n_traces
        # retire+revive keeps every leaf shape -> zero recompiles
        store2 = store.retire(1).revive(1)
        plan2 = plan.rebind(store2.to_state())
        m, v = plan2.routed_diag(p["U"][:8])
        plan2.diag(p["U"][:8])
        assert plan.stats.n_traces == traces, "rebind recompiled"
        cold = method.plan(p["kfn"], p["params"], store2.to_state(), spec)
        cm, cv = cold.routed_diag(p["U"][:8])
        np.testing.assert_array_equal(np.asarray(m), np.asarray(cm))
        np.testing.assert_array_equal(np.asarray(v), np.asarray(cv))

    def test_fitted_gp_with_state_rebinds_plans(self, prob32, runner):
        p = prob32
        model = api.fit("ppitc", p["kfn"], p["params"], p["X"], p["y"],
                        S=p["S"], runner=runner)
        model.predict_diag(p["U"][:8])
        plan = model.plan()
        traces = plan.stats.n_traces
        st2 = jax.tree.map(lambda a: a + 0, model.state)
        model2 = model.with_state(st2)
        model2.predict_diag(p["U"][:8])
        assert model2.plan().stats is plan.stats
        assert plan.stats.n_traces == traces

    def test_server_swap_keeps_executables(self, prob32, runner):
        """The GPServer acceptance probe: hot-swap under a live server,
        zero recompiles, posteriors bitwise-equal a cold plan's."""
        p = prob32
        model = api.fit("ppic", p["kfn"], p["params"], p["X"], p["y"],
                        S=p["S"], runner=runner)
        srv = GPServer(model, max_batch=8, routed=True)
        srv.predict(p["U"][:8])
        traces = srv.plan.stats.n_traces
        st2 = ppic.fit(p["kfn"], p["params"], p["X"], 2.0 * p["y"],
                       S=p["S"], runner=runner)
        srv.swap_state(st2)
        m, v = srv.predict(p["U"][:8])
        assert srv.plan.stats.n_traces == traces
        cold = model.method.plan(p["kfn"], p["params"], st2, srv.spec)
        cm, cv = cold.routed_diag(p["U"][:8])
        np.testing.assert_array_equal(np.asarray(m), np.asarray(cm))
        np.testing.assert_array_equal(np.asarray(v), np.asarray(cv))


class TestCachedCinv:
    def test_cinv_matches_trsm_path(self, prob32, models):
        p = prob32
        model = models["ppic"]
        base = model.plan(api.ServeSpec(max_batch=16, routed=True))
        cinv = model.plan(api.ServeSpec(max_batch=16, routed=True,
                                        cached_cinv=True))
        assert cinv.caches is not None
        m0, v0 = base.routed_diag(p["U"])
        m1, v1 = cinv.routed_diag(p["U"])
        # different float path (inverse applied multiplicatively): allclose,
        # not bitwise — the f64 agreement is ~1e-12 (checked below)
        np.testing.assert_allclose(m1, m0, rtol=1e-3, atol=1e-3)
        np.testing.assert_allclose(v1, v0, rtol=1e-3, atol=1e-3)

    def test_cinv_f64_tight(self):
        p = make_problem(dtype=jnp.float64)
        runner = VmapRunner(M=p["M"])
        model = api.fit("ppic", p["kfn"], p["params"], p["X"], p["y"],
                        S=p["S"], runner=runner)
        base = model.plan(api.ServeSpec(max_batch=16, routed=True))
        cinv = model.plan(api.ServeSpec(max_batch=16, routed=True,
                                        cached_cinv=True))
        m0, v0 = base.routed_diag(p["U"])
        m1, v1 = cinv.routed_diag(p["U"])
        np.testing.assert_allclose(m1, m0, atol=1e-10)
        np.testing.assert_allclose(v1, v0, atol=1e-10)

    def test_rebind_refreshes_cache_without_recompiling(self, prob32,
                                                        runner):
        p = prob32
        model = api.fit("ppic", p["kfn"], p["params"], p["X"], p["y"],
                        S=p["S"], runner=runner)
        plan = model.plan(api.ServeSpec(max_batch=16, routed=True,
                                        cached_cinv=True))
        plan.routed_diag(p["U"][:8])
        traces = plan.stats.n_traces
        st2 = ppic.fit(p["kfn"], p["params"], p["X"], 2.0 * p["y"],
                       S=p["S"], runner=runner)
        plan2 = plan.rebind(st2)
        assert plan2.caches is not plan.caches        # refreshed
        m, _ = plan2.routed_diag(p["U"][:8])
        assert plan.stats.n_traces == traces
        cold = model.method.plan(p["kfn"], p["params"], st2,
                                 plan.spec)
        cm, _ = cold.routed_diag(p["U"][:8])
        np.testing.assert_array_equal(np.asarray(m), np.asarray(cm))

    def test_cinv_requires_backend_cache_plan(self, prob32, models):
        with pytest.raises(ValueError, match="cached_cinv"):
            api.ServeSpec(cached_cinv=True)          # needs routed=True
        with pytest.raises(ValueError, match="cached_cinv"):
            models["ppitc"].plan(api.ServeSpec(routed=True,
                                               cached_cinv=True))


class TestShimRemoval:
    """The deprecated per-call ``GPMethod.predict*`` surface was removed
    (multi-tenant serving PR satellite): ``method.plan(...)`` is the only
    serving entry point."""

    def test_per_call_shims_are_gone(self):
        meth = api.get("ppic")
        for name in ("predict", "predict_diag", "predict_routed_diag"):
            assert not hasattr(meth, name)
        assert not hasattr(api, "PlanDeprecationWarning")
        assert not hasattr(api, "_SHIM_PLANS")

    def test_plan_serves_what_the_shims_did(self, prob32, models):
        """One method.plan(...) call replaces the per-call shim — and is
        bitwise-identical to the model's memoized plan (same lineage)."""
        p = prob32
        model = models["ppic"]
        plan = model.method.plan(model.kfn, model.params, model.state,
                                 api.ServeSpec())
        pm, pv = plan.diag(p["U"][:8])
        mm, mv = model.plan().diag(p["U"][:8])
        np.testing.assert_array_equal(np.asarray(pm), np.asarray(mm))
        np.testing.assert_array_equal(np.asarray(pv), np.asarray(mv))

    def test_routedless_methods_expose_none(self):
        assert api.get("ppitc").predict_routed_diag_fn is None
        assert api.get("ppic").predict_routed_diag_fn is not None

    def test_first_party_surfaces_silent_under_w_error(self, prob32, models):
        """FittedGP and GPServer are plan clients — the serving surface
        must be silent under -W error (the CI deprecation gate)."""
        p = prob32
        with warnings.catch_warnings():
            warnings.simplefilter("error", DeprecationWarning)
            models["ppitc"].predict_diag(p["U"][:4])
            models["ppic"].predict_routed_diag(p["U"][:4])
            models["ppitc"].predict(p["U"][:4])
            srv = GPServer(models["ppic"], max_batch=8, routed=True)
            t = srv.submit(p["U"][0])
            srv.flush()
            srv.result(t)


class TestStoreCheckpointing:
    """Satellite: persist the STORES, not just their states — a restarted
    fleet keeps assimilating."""

    @pytest.mark.parametrize("name,kw", [
        ("ppitc", {}), ("ppic", {}), ("picf", {"rank": 24})])
    def test_roundtrip_bitwise_and_resume(self, prob32, runner, tmp_path,
                                          name, kw):
        p = prob32
        n1 = p["X"].shape[0] // 2
        skw = dict(S=p["S"]) if name != "picf" else {}
        store = api.init_store(name, p["kfn"], p["params"], p["X"][:n1],
                               p["y"][:n1], runner=runner, **skw, **kw)
        path = serialize.save_store(tmp_path / f"{name}.store.npz", store)
        loaded = serialize.load_store(path)
        assert type(loaded).__name__ == type(store).__name__
        # bitwise: the emitted states agree leaf-for-leaf
        for a, b in zip(jax.tree.leaves(store.to_state()),
                        jax.tree.leaves(loaded.to_state())):
            np.testing.assert_array_equal(np.asarray(a), np.asarray(b))
        # the restart keeps ASSIMILATING: resumed streaming == uninterrupted
        s_resume = loaded.assimilate(p["X"][n1:], p["y"][n1:]).to_state()
        s_orig = store.assimilate(p["X"][n1:], p["y"][n1:]).to_state()
        for a, b in zip(jax.tree.leaves(s_orig), jax.tree.leaves(s_resume)):
            np.testing.assert_array_equal(np.asarray(a), np.asarray(b))
        meta = serialize.peek_store(path)
        assert meta["store"] == type(store).__name__
        assert meta["schema"] == serialize.STORE_SCHEMA_VERSION
        assert meta["kernel"]["kind"] == "named"
        assert meta["runner"] == {"kind": "vmap", "M": p["M"],
                                  "axis_name": "machines"}

    def test_kernel_spec_roundtrips(self, prob32, runner, tmp_path):
        p = prob32
        spec = cov.make_spec("se", impl="jnp", block_q=16)
        store = api.init_store("ppitc", spec, p["params"], p["X"], p["y"],
                               S=p["S"], runner=runner)
        path = serialize.save_store(tmp_path / "spec.store.npz", store)
        loaded = serialize.load_store(path)
        assert isinstance(loaded.kfn, cov.KernelSpec)
        assert loaded.kfn == spec

    def test_opaque_kernel_requires_override(self, prob32, runner,
                                             tmp_path):
        p = prob32
        bespoke = lambda params, A, B: cov.se_ard(params, A, B)
        store = api.init_store("ppitc", bespoke, p["params"], p["X"],
                               p["y"], S=p["S"], runner=runner)
        path = serialize.save_store(tmp_path / "opaque.store.npz", store)
        with pytest.raises(ValueError, match="opaque kernel"):
            serialize.load_store(path)
        loaded = serialize.load_store(path, kfn=bespoke)
        assert loaded.kfn is bespoke

    def test_not_a_store_checkpoint_rejected(self, prob32, runner,
                                             tmp_path):
        p = prob32
        state = ppitc.fit(p["kfn"], p["params"], p["X"], p["y"], S=p["S"],
                          runner=runner)
        path = serialize.save_state(tmp_path / "state.npz", state)
        with pytest.raises(ValueError, match="not a repro store"):
            serialize.load_store(path)

    def test_server_checkpoint_store_resumes_streaming(self, prob32, runner,
                                                       tmp_path):
        """GPServer lifecycle: checkpoint the store on one server, restore
        on a fresh one, and keep assimilating through update()."""
        p = prob32
        n1 = p["X"].shape[0] // 2
        store = api.init_store("ppic", p["kfn"], p["params"], p["X"][:n1],
                               p["y"][:n1], S=p["S"], runner=runner)
        srv = GPServer(api.FittedGP(api.get("ppic"), p["kfn"], p["params"],
                                    store.to_state()),
                       max_batch=8, routed=True, store=store)
        path = tmp_path / "fleet.store.npz"
        srv.checkpoint_store(path)

        # a replica fitted on something else entirely
        other = api.fit("ppic", p["kfn"], p["params"], p["X"], 2.0 * p["y"],
                        S=p["S"], runner=runner)
        srv2 = GPServer(other, max_batch=8, routed=True)
        srv2.restore_store(path)
        srv2.update(p["X"][n1:], p["y"][n1:])       # resumes assimilating
        m2, _ = srv2.predict(p["U"][:8])
        cold = api.fit("ppic", p["kfn"], p["params"], p["X"], p["y"],
                       S=p["S"], runner=VmapRunner(M=2 * p["M"]))
        cm, _ = cold.plan(srv2.spec).routed_diag(p["U"][:8])
        # f32 streamed (rank-update) vs cold-factored path: roundoff-level
        np.testing.assert_allclose(np.asarray(m2), np.asarray(cm),
                                   atol=1e-3)

    def test_checkpoint_store_requires_store(self, prob32, models,
                                             tmp_path):
        srv = GPServer(models["ppitc"], max_batch=8)
        with pytest.raises(ValueError, match="StateStore"):
            srv.checkpoint_store(tmp_path / "x.npz")
