"""Shared fixtures. float64 is enabled for the GP equivalence tests (the
paper's LAPACK pipeline is float64); model/kernel tests pass explicit dtypes.

NOTE: XLA_FLAGS device-count forcing is deliberately NOT set here — smoke
tests must see the real single CPU device. Multi-device shard_map coverage
runs in subprocesses (tests/test_shardmap.py) with their own XLA_FLAGS.
"""
import jax
import pytest

jax.config.update("jax_enable_x64", True)

# hypothesis is unavailable offline; install the seeded fallback shim before
# any test module does `from hypothesis import ...` (tests/helpers.py).
from helpers import install_hypothesis_shim  # noqa: E402

install_hypothesis_shim()


@pytest.fixture(scope="session")
def rng():
    return jax.random.PRNGKey(0)
