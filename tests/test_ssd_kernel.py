"""SSD Pallas kernel validation: interpret-mode vs the jnp oracles, swept
over shapes and dtypes; full-scan equivalence against models/ssm.ssd_scan."""
import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.kernels.ssd import ops as ssd_ops, ref as ssd_ref
from repro.kernels.ssd.ssd import ssd_intra_chunk
from repro.models.ssm import ssd_scan as ref_scan

KEY = jax.random.PRNGKey(0)


@pytest.mark.parametrize("BC,cs,H,P,N", [(4, 16, 3, 8, 8),
                                         (2, 64, 2, 16, 16),
                                         (1, 128, 1, 64, 128),
                                         (3, 32, 4, 8, 32)])
def test_kernel_matches_oracle(BC, cs, H, P, N):
    ks = jax.random.split(KEY, 4)
    xdt = jax.random.normal(ks[0], (BC, cs, H, P))
    dA = -jnp.abs(jax.random.normal(ks[1], (BC, H, cs))) * 0.1
    Bc = jax.random.normal(ks[2], (BC, cs, N))
    Cc = jax.random.normal(ks[3], (BC, cs, N))
    Y, S, cum = ssd_intra_chunk(xdt, dA, Bc, Cc, interpret=True)
    for i in range(BC):
        for h in range(H):
            Yr, Sr, cr = ssd_ref.intra_chunk(xdt[i, :, h], dA[i, h],
                                             Bc[i], Cc[i])
            np.testing.assert_allclose(Y[i, :, h], Yr, atol=3e-4)
            np.testing.assert_allclose(S[i, h], Sr, atol=3e-4)
            np.testing.assert_allclose(cum[i, h], cr, atol=1e-5)


@pytest.mark.parametrize("chunk", [8, 16, 32])
def test_full_scan_matches_reference(chunk):
    B, L, H, P, N = 2, 64, 3, 8, 16
    ks = jax.random.split(jax.random.PRNGKey(1), 5)
    xh = jax.random.normal(ks[0], (B, L, H, P))
    dt = jax.nn.softplus(jax.random.normal(ks[1], (B, L, H)))
    A = -jnp.exp(jax.random.normal(ks[2], (H,)) * 0.3)
    Bm = jax.random.normal(ks[3], (B, L, N))
    Cm = jax.random.normal(ks[4], (B, L, N))
    Y1, f1 = ref_scan(xh, dt, A, Bm, Cm, chunk)
    Y2, f2 = ssd_ops.ssd_scan(xh, dt, A, Bm, Cm, chunk,
                              impl="pallas_interpret")
    np.testing.assert_allclose(Y1, Y2, atol=2e-4)
    np.testing.assert_allclose(f1, f2, atol=2e-4)


def test_bf16_inputs():
    BC, cs, H, P, N = 2, 32, 2, 8, 16
    ks = jax.random.split(KEY, 4)
    xdt = jax.random.normal(ks[0], (BC, cs, H, P), jnp.bfloat16)
    dA = (-jnp.abs(jax.random.normal(ks[1], (BC, H, cs))) * 0.1
          ).astype(jnp.bfloat16)
    Bc = jax.random.normal(ks[2], (BC, cs, N), jnp.bfloat16)
    Cc = jax.random.normal(ks[3], (BC, cs, N), jnp.bfloat16)
    Y, S, cum = ssd_intra_chunk(xdt, dA, Bc, Cc, interpret=True)
    Yr, Sr, _ = ssd_ref.intra_chunk(xdt[0, :, 0].astype(jnp.float32),
                                    dA[0, 0].astype(jnp.float32),
                                    Bc[0].astype(jnp.float32),
                                    Cc[0].astype(jnp.float32))
    assert float(jnp.abs(Y[0, :, 0] - Yr).max()) < 0.15  # bf16 inputs
