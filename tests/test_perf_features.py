"""Regression tests for the §Perf optimizations — each must be numerically
equivalent to its baseline (the hillclimb keeps correctness by construction)."""
import dataclasses

import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.configs.registry import smoke_config
from repro.models import moe as moe_lib, transformer as tf
from repro.parallel import sharding as shd

KEY = jax.random.PRNGKey(0)


class TestGatherDispatch:
    def test_matches_einsum_dispatch(self):
        p = moe_lib.init_moe(KEY, 16, 32, 4)
        x = jax.random.normal(jax.random.PRNGKey(1), (2, 24, 16))
        for G in (1, 4):
            y1, _ = moe_lib.moe_ffn(p, x, top_k=2, capacity_factor=16.0,
                                    n_groups=G, compute_dtype=jnp.float32)
            y2, _ = moe_lib.moe_ffn(p, x, top_k=2, capacity_factor=16.0,
                                    n_groups=G, dispatch="gather",
                                    compute_dtype=jnp.float32)
            np.testing.assert_allclose(y1, y2, atol=1e-4)

    def test_capacity_drops_counted(self):
        p = moe_lib.init_moe(KEY, 16, 32, 8)
        x = jax.random.normal(KEY, (1, 64, 16))
        y, aux = moe_lib.moe_ffn(p, x, top_k=2, capacity_factor=0.25,
                                 dispatch="gather",
                                 compute_dtype=jnp.float32)
        assert float(aux.dropped_fraction) > 0.0
        assert bool(jnp.isfinite(y).all())

    def test_grad_flows(self):
        p = moe_lib.init_moe(KEY, 8, 16, 4)
        x = jax.random.normal(KEY, (1, 16, 8))
        g = jax.grad(lambda pp: moe_lib.moe_ffn(
            pp, x, top_k=2, dispatch="gather",
            compute_dtype=jnp.float32)[0].sum())(p)
        assert all(bool(jnp.isfinite(l).all()) for l in jax.tree.leaves(g))
        assert float(jnp.abs(g["w_in"]).max()) > 0


@pytest.mark.slow
class TestRingCache:
    def test_ring_matches_full_cache(self):
        cfg = smoke_config("gemma3-4b")
        pat = tuple(dataclasses.replace(d, window=8 if d.window else None)
                    for d in cfg.layer_pattern)
        cfg = cfg.scaled(layer_pattern=pat)
        params = tf.init_model(KEY, cfg)
        B, T = 2, 24
        toks = jax.random.randint(jax.random.PRNGKey(1), (B, T), 0,
                                  cfg.vocab)
        s_full = tf.init_serve(cfg, B, 64, cache_dtype=jnp.float32)
        s_ring = tf.init_serve(cfg, B, 64, cache_dtype=jnp.float32,
                               ring_cache=True)
        # ring caches for windowed layers are window-sized
        assert s_ring.stack_caches[0].k.shape[3] == 8
        assert s_full.stack_caches[0].k.shape[3] == 64
        for t in range(T):
            lf, s_full = tf.decode_step(params, toks[:, t:t + 1], s_full,
                                        cfg, compute_dtype=jnp.float32)
            lr, s_ring = tf.decode_step(params, toks[:, t:t + 1], s_ring,
                                        cfg, compute_dtype=jnp.float32)
            assert float(jnp.abs(lf - lr).max()) < 1e-4, t


class TestCrossKVPrecompute:
    def test_matches_recompute_path(self):
        cfg = smoke_config("whisper-medium")
        params = tf.init_model(KEY, cfg)
        B, T = 2, 10
        toks = jax.random.randint(jax.random.PRNGKey(1), (B, T), 0,
                                  cfg.vocab)
        frames = jax.random.normal(jax.random.PRNGKey(2),
                                   (B, cfg.enc_seq, cfg.d_model),
                                   jnp.float32)
        enc = tf.encode(params, frames, cfg, compute_dtype=jnp.float32)
        s1 = tf.init_serve(cfg, B, 32, enc_kv=enc, cache_dtype=jnp.float32)
        ckv = tf.precompute_cross_kv(params, enc, cfg,
                                     compute_dtype=jnp.float32)
        s2 = tf.init_serve(cfg, B, 32, enc_kv=None,
                           cache_dtype=jnp.float32)._replace(cross_kv=ckv)
        for t in range(T):
            l1, s1 = tf.decode_step(params, toks[:, t:t + 1], s1, cfg,
                                    compute_dtype=jnp.float32)
            l2, s2 = tf.decode_step(params, toks[:, t:t + 1], s2, cfg,
                                    compute_dtype=jnp.float32)
            assert float(jnp.abs(l1 - l2).max()) < 1e-4


class TestVocabPadding:
    def test_padded_table_same_loss_semantics(self):
        """Padded logits columns are masked: loss over real labels matches a
        manually padded-free computation."""
        cfg = smoke_config("olmo-1b").scaled(vocab=250)   # pads to 256
        assert cfg.vocab_padded == 256
        params = tf.init_model(KEY, cfg)
        assert params["embed"]["tok"].shape[0] == 256
        toks = jax.random.randint(KEY, (2, 16), 0, 250)
        logits, _ = tf.forward(params, toks, cfg, attn_impl="jnp")
        assert logits.shape[-1] == 256
        assert float(logits[..., 250:].max()) <= -1e29
        loss, _ = tf.lm_loss(params, toks, toks, cfg, attn_impl="jnp")
        assert bool(jnp.isfinite(loss))

    def test_argmax_never_selects_padding(self):
        cfg = smoke_config("qwen3-1.7b").scaled(vocab=250)
        params = tf.init_model(KEY, cfg)
        toks = jax.random.randint(KEY, (4, 8), 0, 250)
        logits, _ = tf.forward(params, toks, cfg, attn_impl="jnp")
        assert int(jnp.argmax(logits, -1).max()) < 250


class TestShardingPolicy:
    def _mesh(self):
        return jax.make_mesh((1, 1), ("data", "model"))

    def test_small_model_replicates(self):
        cfg = smoke_config("olmo-1b")
        params = jax.eval_shape(lambda: tf.init_model(KEY, cfg))
        assert not shd.use_tp_policy(params)
        specs = shd.param_specs(params, self._mesh())
        from jax.sharding import PartitionSpec as P
        for s in jax.tree.leaves(specs, is_leaf=lambda x: isinstance(x, P)):
            assert all(a is None for a in s)

    def test_large_model_uses_tp(self):
        from repro.configs.registry import get_config
        cfg = get_config("qwen3-1.7b")          # ~2 GB params > threshold
        params = jax.eval_shape(lambda: tf.init_model(KEY, cfg))
        assert shd.use_tp_policy(params)

    def test_moe_expert_weights_fully_sharded(self):
        """The §Perf expert-sharding fix: 4-D stacked expert weights shard
        both d and ff (or E), never leaving a big dim replicated."""
        from repro.configs.registry import get_config
        cfg = get_config("mixtral-8x22b")
        mesh = self._mesh()     # (1,1): every dim divides -> full rule path
        params = jax.eval_shape(lambda: tf.init_model(KEY, cfg))
        specs = shd.param_specs(params, mesh, use_tp=True)
        s = specs["stack"][0]["moe"]["w_in"]     # (L, E, d, ff)
        # either EP (experts sharded + d on dp) or TP-in-expert (d + ff):
        # at least two of the three trailing dims must be sharded
        assert sum(x is not None for x in s[1:]) >= 2, s

    def test_batch_spec_divisibility_fallback(self):
        mesh = jax.make_mesh((1, 1), ("data", "model"))
        from jax.sharding import PartitionSpec as P
        spec = shd.batch_spec(mesh, use_tp=False, batch=3)
        # batch=3 cannot shard 2 ways -> axes dropped as needed
        assert isinstance(spec, P)
