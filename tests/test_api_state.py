"""fit -> PosteriorState -> predict_batch architecture (core/api.py):
registry, state caching vs legacy one-shot wrappers, query padding, the
microbatching server, and online state hot-swap."""
import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.core import api, gp, online, picf, pitc, ppic, ppitc
from repro.launch.gp_serve import GPServer, default_buckets
from repro.parallel.runner import VmapRunner, pad_blocks

from helpers import make_problem


@pytest.fixture(scope="module")
def prob():
    return make_problem()


@pytest.fixture(scope="module")
def runner(prob):
    return VmapRunner(M=prob["M"])


class TestRegistry:
    def test_core_methods_registered(self):
        assert {"fgp", "pitc", "pic", "ppitc", "ppic", "picf"} <= \
            set(api.names())

    def test_unknown_method_raises(self):
        with pytest.raises(ValueError, match="unknown GP method"):
            api.get("svgp")

    def test_fit_front_door(self, prob, runner):
        model = api.fit("ppitc", prob["kfn"], prob["params"], prob["X"],
                        prob["y"], S=prob["S"], runner=runner)
        assert isinstance(model.state, api.PITCState)
        mean, var = model.predict_diag(prob["U"])
        assert mean.shape == var.shape == (prob["U"].shape[0],)
        assert float(var.min()) > 0


class TestStateCaching:
    """Satellite: fit once + predict_batch twice is bit-identical to the
    legacy one-shot wrappers (which ARE fit + predict by construction)."""

    def test_fgp(self, prob):
        st = gp.fit(prob["kfn"], prob["params"], prob["X"], prob["y"])
        p1 = gp.predict_batch(prob["kfn"], prob["params"], st, prob["U"])
        p2 = gp.predict_batch(prob["kfn"], prob["params"], st, prob["U"])
        legacy = gp.predict(prob["kfn"], prob["params"], prob["X"],
                            prob["y"], prob["U"])
        np.testing.assert_array_equal(p1.mean, p2.mean)
        np.testing.assert_array_equal(p1.cov, p2.cov)
        np.testing.assert_array_equal(p1.mean, legacy.mean)
        np.testing.assert_array_equal(p1.cov, legacy.cov)

    def test_pitc(self, prob):
        st = pitc.fit(prob["kfn"], prob["params"], prob["X"], prob["y"],
                      S=prob["S"], M=prob["M"])
        p1 = ppitc.predict_batch(prob["kfn"], prob["params"], st, prob["U"])
        p2 = ppitc.predict_batch(prob["kfn"], prob["params"], st, prob["U"])
        legacy = pitc.pitc_predict_blockwise(
            prob["kfn"], prob["params"], prob["S"], prob["X"], prob["y"],
            prob["U"], prob["M"])
        np.testing.assert_array_equal(p1.mean, p2.mean)
        np.testing.assert_array_equal(p1.mean, legacy.mean)
        np.testing.assert_array_equal(p1.cov, legacy.cov)

    def test_ppitc(self, prob, runner):
        st = ppitc.fit(prob["kfn"], prob["params"], prob["X"], prob["y"],
                       S=prob["S"], runner=runner)
        p1 = ppitc.predict_blocks(prob["kfn"], prob["params"], st, prob["U"],
                                  prob["M"])
        p2 = ppitc.predict_blocks(prob["kfn"], prob["params"], st, prob["U"],
                                  prob["M"])
        legacy = ppitc.predict(prob["kfn"], prob["params"], prob["S"],
                               prob["X"], prob["y"], prob["U"], runner)
        np.testing.assert_array_equal(p1.mean, p2.mean)
        np.testing.assert_array_equal(p1.blocks, p2.blocks)
        np.testing.assert_array_equal(p1.mean, legacy.mean)
        np.testing.assert_array_equal(p1.blocks, legacy.blocks)

    def test_ppic(self, prob, runner):
        st = ppic.fit(prob["kfn"], prob["params"], prob["X"], prob["y"],
                      S=prob["S"], runner=runner)
        p1 = ppic.predict_blocks(prob["kfn"], prob["params"], st, prob["U"])
        p2 = ppic.predict_blocks(prob["kfn"], prob["params"], st, prob["U"])
        legacy = ppic.predict(prob["kfn"], prob["params"], prob["S"],
                              prob["X"], prob["y"], prob["U"], runner)
        np.testing.assert_array_equal(p1.mean, p2.mean)
        np.testing.assert_array_equal(p1.blocks, p2.blocks)
        np.testing.assert_array_equal(p1.mean, legacy.mean)
        np.testing.assert_array_equal(p1.blocks, legacy.blocks)
        # predict_batch is the type-stable dense view of the same posterior
        dense = ppic.predict_batch(prob["kfn"], prob["params"], st, prob["U"])
        np.testing.assert_array_equal(dense.mean, p1.mean)
        np.testing.assert_array_equal(dense.cov, p1.cov)

    def test_picf(self, prob, runner):
        st = picf.fit(prob["kfn"], prob["params"], prob["X"], prob["y"],
                      rank=48, runner=runner)
        p1 = picf.predict_batch(prob["kfn"], prob["params"], st, prob["U"])
        p2 = picf.predict_batch(prob["kfn"], prob["params"], st, prob["U"])
        legacy = picf.predict(prob["kfn"], prob["params"], prob["X"],
                              prob["y"], prob["U"], 48, runner)
        np.testing.assert_array_equal(p1.mean, p2.mean)
        np.testing.assert_array_equal(p1.mean, legacy.mean)
        np.testing.assert_array_equal(p1.cov, legacy.cov)

    def test_diag_matches_full(self, prob, runner):
        """predict_diag agrees with diag(predict cov) for every method."""
        cases = [
            ("fgp", {}),
            ("pitc", dict(S=prob["S"], M=prob["M"])),
            ("ppitc", dict(S=prob["S"], runner=runner)),
            ("ppic", dict(S=prob["S"], runner=runner)),
            ("picf", dict(rank=48, runner=runner)),
        ]
        for name, kw in cases:
            model = api.fit(name, prob["kfn"], prob["params"], prob["X"],
                            prob["y"], **kw)
            post = model.predict(prob["U"])
            mean, var = model.predict_diag(prob["U"])
            np.testing.assert_allclose(mean, post.mean, atol=1e-9,
                                       err_msg=name)
            np.testing.assert_allclose(var, post.var, atol=1e-8,
                                       err_msg=name)


class TestQueryPadding:
    def test_shard_blocks_raises_with_fix(self, runner):
        X = jnp.zeros((17, 3))
        with pytest.raises(ValueError, match="pad_blocks"):
            runner.shard_blocks(X)

    def test_pitc_blocks_raises_with_fix(self):
        with pytest.raises(ValueError, match="pad_blocks"):
            pitc._blocks(17, 4)

    def test_pad_blocks_roundtrip(self):
        X = jnp.arange(17 * 3, dtype=jnp.float64).reshape(17, 3)
        Xb, n = pad_blocks(X, 4)
        assert Xb.shape == (4, 5, 3) and n == 17
        np.testing.assert_array_equal(Xb.reshape(20, 3)[:17], X)
        np.testing.assert_array_equal(Xb.reshape(20, 3)[17:], 0.0)

    def test_pad_blocks_exact_division_is_noop(self):
        X = jnp.arange(16 * 3, dtype=jnp.float64).reshape(16, 3)
        Xb, n = pad_blocks(X, 4)
        assert Xb.shape == (4, 4, 3) and n == 16
        np.testing.assert_array_equal(Xb.reshape(16, 3), X)

    def test_ppitc_serves_any_batch_size(self, prob, runner):
        """PITC posteriors are query-independent: odd slices match."""
        st = ppitc.fit(prob["kfn"], prob["params"], prob["X"], prob["y"],
                       S=prob["S"], runner=runner)
        full_m, full_v = ppitc.predict_batch_diag(prob["kfn"], prob["params"],
                                                  st, prob["U"])
        for u in (1, 7, 17):
            m, v = ppitc.predict_batch_diag(prob["kfn"], prob["params"], st,
                                            prob["U"][:u])
            np.testing.assert_allclose(m, full_m[:u], atol=1e-12)
            np.testing.assert_allclose(v, full_v[:u], atol=1e-12)

    def test_ppic_serves_any_batch_size(self, prob, runner):
        """pPIC pads the query batch to the block layout and trims."""
        st = ppic.fit(prob["kfn"], prob["params"], prob["X"], prob["y"],
                      S=prob["S"], runner=runner)
        U17 = prob["U"][:17]
        m, v = ppic.predict_batch_diag(prob["kfn"], prob["params"], st, U17)
        assert m.shape == v.shape == (17,)
        assert bool(jnp.all(jnp.isfinite(m))) and float(v.min()) > 0
        # diag path agrees with the (padded, trimmed) full-cov path
        post = ppic.predict_batch(prob["kfn"], prob["params"], st, U17)
        np.testing.assert_allclose(m, post.mean, atol=1e-12)
        np.testing.assert_allclose(v, jnp.diag(post.cov), atol=1e-10)


class TestGPServer:
    def test_microbatch_matches_direct(self, prob, runner):
        model = api.fit("ppitc", prob["kfn"], prob["params"], prob["X"],
                        prob["y"], S=prob["S"], runner=runner)
        srv = GPServer(model, max_batch=16)
        tickets = [srv.submit(prob["U"][i]) for i in range(5)]
        direct_m, direct_v = model.predict_diag(prob["U"][:5])
        for i, t in enumerate(tickets):
            m, v = srv.result(t)
            np.testing.assert_allclose(m, direct_m[i], atol=1e-12)
            np.testing.assert_allclose(v, direct_v[i], atol=1e-12)

    def test_auto_flush_at_max_batch(self, prob, runner):
        model = api.fit("ppitc", prob["kfn"], prob["params"], prob["X"],
                        prob["y"], S=prob["S"], runner=runner)
        srv = GPServer(model, max_batch=8)
        for i in range(8):
            srv.submit(prob["U"][i])
        assert srv.pending == 0          # flushed on the 8th submit
        assert srv.stats.n_batches == 1

    def test_bucket_padding(self):
        assert default_buckets(64) == (8, 16, 32, 64)
        assert default_buckets(8) == (8,)

    def test_default_buckets_never_duplicate(self):
        """Regression: max_batch already a power of two >= min_bucket must
        not emit a duplicate trailing bucket, for any (max_batch, min_bucket)
        combination; ladders stay sorted and end at max_batch."""
        for min_bucket in (1, 2, 4, 8, 16):
            for max_batch in range(1, 257):
                bs = default_buckets(max_batch, min_bucket=min_bucket)
                assert len(set(bs)) == len(bs), (max_batch, min_bucket, bs)
                assert list(bs) == sorted(bs)
                assert bs[-1] == max_batch

    def test_default_buckets_align_to_block_q(self):
        """ISSUE satellite: every bucket is a multiple of the Pallas serving
        tile, so the padded microbatch IS the kernel grid (no second pad
        inside the dispatch). The historical block_q=8 ladder is unchanged."""
        for block_q in (8, 16, 32, 128):
            for max_batch in (1, 7, 8, 33, 64, 200, 256):
                bs = default_buckets(max_batch, block_q=block_q)
                assert all(b % block_q == 0 for b in bs), (block_q, bs)
                assert len(set(bs)) == len(bs)
                assert list(bs) == sorted(bs)
                assert bs[-1] >= max_batch
        assert default_buckets(64, block_q=8) == (8, 16, 32, 64)

    def test_server_buckets_follow_spec_block_q(self, prob, runner):
        """A KernelSpec's declared tile propagates into the bucket ladder."""
        from repro.core import covariance as cov
        spec = cov.make_spec("se", block_q=16)
        model = api.fit("ppitc", spec, prob["params"], prob["X"],
                        prob["y"], S=prob["S"], runner=runner)
        srv = GPServer(model, max_batch=40)
        assert srv.block_q == 16
        assert all(b % 16 == 0 for b in srv.buckets)
        m, v = srv.predict(prob["U"][:5])       # pads to a 16-aligned bucket
        ref_m, ref_v = model.predict_diag(prob["U"][:5])
        np.testing.assert_allclose(m, ref_m, atol=1e-12)
        np.testing.assert_allclose(v, ref_v, atol=1e-12)

    def test_oversized_batch(self, prob, runner):
        model = api.fit("ppitc", prob["kfn"], prob["params"], prob["X"],
                        prob["y"], S=prob["S"], runner=runner)
        srv = GPServer(model, max_batch=8)
        m, v = srv.predict(prob["U"])    # u=24 > max bucket 8 -> pads to 24
        ref_m, ref_v = model.predict_diag(prob["U"])
        np.testing.assert_allclose(m, ref_m, atol=1e-12)
        np.testing.assert_allclose(v, ref_v, atol=1e-12)

    def test_hot_swap_after_assimilate(self, prob, runner):
        """swap_state under live traffic == cold fit on all data."""
        p = prob
        n1 = p["X"].shape[0] // 2
        store = online.build(p["kfn"], p["params"], p["S"], p["X"][:n1],
                             p["y"][:n1], runner)
        model = api.get("ppitc")
        fitted = api.FittedGP(model, p["kfn"], p["params"],
                              online.to_state(store, p["S"]))
        srv = GPServer(fitted, max_batch=8)
        m_before, _ = srv.predict(p["U"][:8])

        store = online.assimilate(store, p["kfn"], p["params"], p["S"],
                                  p["X"][n1:], p["y"][n1:], runner)
        srv.swap_state(online.to_state(store, p["S"]))
        m_after, v_after = srv.predict(p["U"][:8])

        cold = ppitc.fit(p["kfn"], p["params"], p["X"], p["y"], S=p["S"],
                         runner=VmapRunner(M=2 * p["M"]))
        ref_m, ref_v = ppitc.predict_batch_diag(p["kfn"], p["params"], cold,
                                                p["U"][:8])
        np.testing.assert_allclose(m_after, ref_m, atol=1e-9)
        np.testing.assert_allclose(v_after, ref_v, atol=1e-9)
        assert float(jnp.abs(m_after - m_before).max()) > 1e-6
        assert srv.stats.n_state_swaps == 1

    def test_hot_swap_after_retire(self, prob, runner):
        p = prob
        store = online.build(p["kfn"], p["params"], p["S"], p["X"], p["y"],
                             runner)
        fitted = api.FittedGP(api.get("ppitc"), p["kfn"], p["params"],
                              online.to_state(store, p["S"]))
        srv = GPServer(fitted, max_batch=8)
        srv.swap_state(online.to_state(online.retire(store, 1), p["S"]))
        m, _ = srv.predict(p["U"][:8])
        b = p["X"].shape[0] // p["M"]
        keep = jnp.concatenate([jnp.arange(0, b),
                                jnp.arange(2 * b, p["X"].shape[0])])
        surv = ppitc.fit(p["kfn"], p["params"], p["X"][keep], p["y"][keep],
                         S=p["S"], runner=VmapRunner(M=p["M"] - 1))
        ref, _ = ppitc.predict_batch_diag(p["kfn"], p["params"], surv,
                                          p["U"][:8])
        np.testing.assert_allclose(m, ref, atol=1e-9)


class TestOnlineStateAlgebra:
    """Satellite: summary algebra in core/online.py through the state path."""

    def test_assimilate_retire_revive_roundtrip(self, prob, runner):
        p = prob
        n1 = p["X"].shape[0] // 2
        store = online.build(p["kfn"], p["params"], p["S"], p["X"][:n1],
                             p["y"][:n1], runner)
        store = online.assimilate(store, p["kfn"], p["params"], p["S"],
                                  p["X"][n1:], p["y"][n1:], runner)
        for m in range(2 * p["M"]):
            store = online.revive(online.retire(store, m), m)
        st = online.to_state(store, p["S"])
        fresh = online.build(p["kfn"], p["params"], p["S"], p["X"], p["y"],
                             VmapRunner(M=2 * p["M"]))
        st_fresh = online.to_state(fresh, p["S"])
        for a, b in zip(jax.tree.leaves(st), jax.tree.leaves(st_fresh)):
            np.testing.assert_allclose(a, b, atol=1e-10)

    def test_retired_machine_equals_ppitc_on_survivors(self, prob, runner):
        p = prob
        store = online.retire(
            online.build(p["kfn"], p["params"], p["S"], p["X"], p["y"],
                         runner), 2)
        st = online.to_state(store, p["S"])
        post = ppitc.predict_batch(p["kfn"], p["params"], st, p["U"])
        b = p["X"].shape[0] // p["M"]
        keep = jnp.concatenate([jnp.arange(0, 2 * b),
                                jnp.arange(3 * b, p["X"].shape[0])])
        surv = ppitc.fit(p["kfn"], p["params"], p["X"][keep], p["y"][keep],
                         S=p["S"], runner=VmapRunner(M=p["M"] - 1))
        ref = ppitc.predict_batch(p["kfn"], p["params"], surv, p["U"])
        np.testing.assert_allclose(post.mean, ref.mean, atol=1e-9)
        np.testing.assert_allclose(post.cov, ref.cov, atol=1e-9)
