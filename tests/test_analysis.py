"""Static analyzer + compiled-program contract auditor (repro.analysis,
ISSUE 10).

Acceptance:

* per-rule fixture snippets assert true positives, known false-positive
  guards, and suppression comments; the JIT001 rule flags a minimal
  reproduction of the PR-7 ``PICStore.to_state`` tracer bug in its
  PRE-fix form (and stays quiet on the fixed form);
* the baseline file round-trips: burned-down findings stop failing the
  CLI, editing the flagged line re-surfaces them;
* the analyzer runs clean over the repo's own ``src/`` tree;
* every tracer-safety fix the analyzer surfaced has a regression test
  (online/picf retire-revive, picf.to_state, ServePlan._padded,
  ppic.routed_diag, serialize.save_state/save_store);
* the contract auditor proves fingerprint-identical executables across
  >= 3 rebind generations and a multi-tenant interleaving, and the
  ``@no_retrace`` registry flags post-freeze signature growth.
"""
import ast
import dataclasses
import pathlib
import textwrap

import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.analysis import contracts, engine
from repro.analysis import rules as R
from repro.analysis.__main__ import main as cli_main
from repro.core import api, online, serialize
from repro.parallel.runner import VmapRunner

from helpers import make_problem

REPO_ROOT = pathlib.Path(__file__).resolve().parent.parent


def run_rule(src, rule, path="src/repro/core/fixture.py"):
    """All unsuppressed findings of one rule over a source snippet."""
    src = textwrap.dedent(src)
    mod = engine.ModuleInfo(path=path, source=src, tree=ast.parse(src))
    return [f for f in rule.check(mod)
            if not engine.is_suppressed(f, mod.lines)]


# ---------------------------------------------------------------------------
# engine: suppressions, baseline, reporters
# ---------------------------------------------------------------------------

BUGGY = """
def retire(store, machine):
    if not bool(store.alive[machine]):
        return store
"""


class TestEngine:
    def test_bare_suppression_silences_any_rule(self):
        src = BUGGY.replace("machine]):",
                            "machine]):  # analysis: ignore")
        assert run_rule(src, R.JIT001()) == []

    def test_scoped_suppression_matches_rule(self):
        src = BUGGY.replace("machine]):",
                            "machine]):  # analysis: ignore[JIT001]")
        assert run_rule(src, R.JIT001()) == []

    def test_scoped_suppression_other_rule_does_not_silence(self):
        src = BUGGY.replace("machine]):",
                            "machine]):  # analysis: ignore[DET001]")
        assert len(run_rule(src, R.JIT001())) == 1

    def test_suppression_on_line_above(self):
        src = BUGGY.replace(
            "    if not bool",
            "    # analysis: ignore[JIT001]\n    if not bool")
        assert run_rule(src, R.JIT001()) == []

    def test_baseline_round_trip(self, tmp_path):
        f = tmp_path / "mod.py"
        f.write_text(textwrap.dedent(BUGGY))
        # rule scoping is path-based; parse via run_rule for a scoped path
        findings = run_rule(BUGGY, R.JIT001())
        assert len(findings) == 1
        bl = tmp_path / "baseline.json"
        engine.write_baseline(bl, findings)
        assert engine.new_findings(findings, engine.load_baseline(bl)) == []
        # editing the flagged line invalidates its baseline entry
        edited = run_rule(BUGGY.replace("store.alive", "store2.alive"),
                          R.JIT001())
        assert len(engine.new_findings(edited,
                                       engine.load_baseline(bl))) == 1

    def test_reporters(self):
        findings = run_rule(BUGGY, R.JIT001())
        text = engine.to_text(findings)
        assert "JIT001" in text and "fixture.py:3" in text
        as_json = engine.to_json(findings)
        assert '"n_findings": 1' in as_json
        assert engine.to_text([]).startswith("analysis: clean")


# ---------------------------------------------------------------------------
# JIT001 — the PR-7 to_state bug class
# ---------------------------------------------------------------------------

PR7_PREFIX_TO_STATE = """
def to_state(store, S):
    if bool(store.alive.all()):
        return _state_all_alive(store, S)
    idx = np.flatnonzero(np.asarray(store.alive))
    return _state_compacted(store, S, idx)
"""

PR7_FIXED_TO_STATE = """
def to_state(store, S):
    if isinstance(store.alive, jax.core.Tracer):
        all_alive = True   # traced store: all-alive by construction
    else:
        all_alive = bool(np.asarray(store.alive).all())
    if all_alive:
        return _state_all_alive(store, S)
    idx = np.flatnonzero(np.asarray(store.alive))
    return _state_compacted(store, S, idx)
"""


class TestJIT001:
    def test_flags_pr7_to_state_prefix_form(self):
        """Acceptance: the exact PR-7 TracerBoolConversionError shape."""
        found = run_rule(PR7_PREFIX_TO_STATE, R.JIT001())
        assert len(found) == 1
        assert found[0].rule == "JIT001"
        assert "store.alive.all()" in found[0].snippet

    def test_fixed_to_state_form_is_clean(self):
        """The isinstance-Tracer guard IS the sanctioned host/trace
        split; the fixed function must not be re-flagged."""
        assert run_rule(PR7_FIXED_TO_STATE, R.JIT001()) == []

    def test_concrete_alive_mask_helper_exempts(self):
        src = """
        def retire(store, machine):
            alive = api.concrete_alive_mask(store.alive)
            if alive is None:
                raise TypeError("no tracing here")
            if not alive[machine]:
                return store
        """
        assert run_rule(src, R.JIT001()) == []

    def test_flags_subscripted_mask_truthiness(self):
        assert len(run_rule(BUGGY, R.JIT001())) == 1

    def test_flags_while_and_assert_and_ternary(self):
        src = """
        def f(st):
            assert st.alive.any()
            while st.mask.all():
                pass
            x = 1 if st.block_alive[0] else 2
        """
        assert len(run_rule(src, R.JIT001())) == 3

    def test_out_of_scope_path_not_flagged(self):
        assert run_rule(BUGGY, R.JIT001(),
                        path="src/repro/serving/fixture.py") == []

    def test_plain_name_subscript_not_flagged(self):
        """Host-side `mask[machine]` after a guard is the fixed idiom."""
        src = """
        def f(mask, machine):
            if not mask[machine]:
                return None
        """
        assert run_rule(src, R.JIT001()) == []


# ---------------------------------------------------------------------------
# JIT002 — host syncs inside jitted functions
# ---------------------------------------------------------------------------

class TestJIT002:
    def test_flags_item_and_asarray_in_jit_decorated(self):
        src = """
        @jax.jit
        def f(x):
            v = x.sum().item()
            a = np.asarray(x)
            return v, a
        """
        found = run_rule(src, R.JIT002())
        assert {f.message.split("(")[0].split()[0] for f in found} == \
            {".item", "np.asarray"}

    def test_flags_bool_on_traced_value(self):
        src = """
        @jax.jit
        def f(x):
            return bool(x > 0)
        """
        assert len(run_rule(src, R.JIT002())) == 1

    def test_jit_wrapped_def_is_covered(self):
        src = """
        def f(x):
            return float(x)
        g = jax.jit(f)
        """
        assert len(run_rule(src, R.JIT002())) == 1

    def test_partial_jit_decorator_is_covered(self):
        src = """
        @partial(jax.jit, static_argnames=("k",))
        def f(x, k):
            return x.tolist()
        """
        assert len(run_rule(src, R.JIT002())) == 1

    def test_plain_function_not_flagged(self):
        src = """
        def f(x):
            return np.asarray(x).item()
        """
        assert run_rule(src, R.JIT002()) == []

    def test_literal_cast_not_flagged(self):
        src = """
        @jax.jit
        def f(x):
            return x * float(2)
        """
        assert run_rule(src, R.JIT002()) == []


# ---------------------------------------------------------------------------
# JIT003 — scalar args to jitted callables
# ---------------------------------------------------------------------------

class TestJIT003:
    def test_flags_scalar_literal_arg(self):
        src = """
        f = jax.jit(g)
        y = f(x, 1.0)
        """
        assert len(run_rule(src, R.JIT003())) == 1

    def test_static_markings_exempt(self):
        src = """
        f = jax.jit(g, static_argnums=(1,))
        y = f(x, 1.0)
        """
        assert run_rule(src, R.JIT003()) == []

    def test_array_args_clean(self):
        src = """
        f = jax.jit(g)
        y = f(x, z)
        """
        assert run_rule(src, R.JIT003()) == []


# ---------------------------------------------------------------------------
# DTY001 — float64 leaks into the serving path
# ---------------------------------------------------------------------------

class TestDTY001:
    PATH = "src/repro/serving/fixture.py"

    def test_flags_astype_and_dtype_kwarg(self):
        src = """
        def stage(U):
            a = U.astype(jnp.float64)
            b = np.zeros((4,), dtype=np.float64)
            return a, b
        """
        assert len(run_rule(src, R.DTY001(), path=self.PATH)) == 2

    def test_dtype_conditional_ternary_exempt(self):
        """kernels/rbf/xcov.py mirrors the caller's dtype — policy, not
        a leak."""
        src = """
        def stage(Xq):
            acc = jnp.float64 if Xq.dtype == jnp.float64 else jnp.float32
            return Xq.astype(acc)
        """
        assert run_rule(src, R.DTY001(), path=self.PATH) == []

    def test_out_of_scope_module_clean(self):
        src = """
        def reference(U):
            return U.astype(np.float64)
        """
        assert run_rule(src, R.DTY001(),
                        path="src/repro/core/gp.py") == []


# ---------------------------------------------------------------------------
# DET001 — determinism of replay modules
# ---------------------------------------------------------------------------

class TestDET001:
    PATH = "src/repro/serving/chaos.py"

    def test_flags_wall_clock_and_unseeded_rng(self):
        src = """
        def schedule(self):
            t = time.time()
            rng = np.random.RandomState()
            r = random.random()
            return t, rng, r
        """
        assert len(run_rule(src, R.DET001(), path=self.PATH)) == 3

    def test_seeded_rng_and_injected_clock_clean(self):
        src = """
        def __init__(self, plan, sleep=time.sleep):
            self._rng = np.random.RandomState(plan.seed)
            self._sleep = sleep
        """
        assert run_rule(src, R.DET001(), path=self.PATH) == []

    def test_global_numpy_sampler_flagged(self):
        src = """
        def jitter(self):
            return np.random.uniform()
        """
        assert len(run_rule(src, R.DET001(), path=self.PATH)) == 1


# ---------------------------------------------------------------------------
# FRZ001 — frozen dataclass mutation
# ---------------------------------------------------------------------------

class TestFRZ001:
    def test_flags_self_assignment_in_frozen_class(self):
        src = """
        @dataclasses.dataclass(frozen=True)
        class Plan:
            n: int
            def bump(self):
                self.n = self.n + 1
        """
        assert len(run_rule(src, R.FRZ001())) == 1

    def test_post_init_setattr_is_the_idiom(self):
        src = """
        @dataclasses.dataclass(frozen=True)
        class Plan:
            n: int
            def __post_init__(self):
                object.__setattr__(self, "n", int(self.n))
        """
        assert run_rule(src, R.FRZ001()) == []

    def test_setattr_outside_post_init_flagged(self):
        src = """
        @dataclasses.dataclass(frozen=True)
        class Plan:
            n: int
            def bump(self):
                object.__setattr__(self, "n", self.n + 1)
        """
        assert len(run_rule(src, R.FRZ001())) == 1

    def test_known_frozen_param_mutation_flagged(self):
        src = """
        def tweak(spec: ServeSpec):
            spec.max_batch = 32
            return spec
        """
        assert len(run_rule(src, R.FRZ001())) == 1

    def test_replace_idiom_clean(self):
        src = """
        def tweak(spec: ServeSpec):
            return dataclasses.replace(spec, max_batch=32)
        """
        assert run_rule(src, R.FRZ001()) == []

    def test_unfrozen_dataclass_clean(self):
        src = """
        @dataclasses.dataclass
        class Stats:
            n: int = 0
            def bump(self):
                self.n += 1
        """
        assert run_rule(src, R.FRZ001()) == []


# ---------------------------------------------------------------------------
# CLI + the repo's own tree
# ---------------------------------------------------------------------------

class TestCLI:
    def test_repo_src_is_clean(self):
        """The shipped tree carries zero findings — the baseline is empty
        on purpose (acceptance criterion)."""
        findings = engine.run_rules([REPO_ROOT / "src"],
                                    [cls() for cls in R.ALL_RULES],
                                    root=REPO_ROOT)
        assert findings == []

    def test_exit_codes_and_baseline_flow(self, tmp_path, monkeypatch):
        bad = tmp_path / "src_repro_core_mod.py"
        # path-scope the fixture file under a core/ dir
        core = tmp_path / "src" / "repro" / "core"
        core.mkdir(parents=True)
        bad = core / "mod.py"
        bad.write_text(textwrap.dedent(BUGGY))
        monkeypatch.chdir(tmp_path)
        assert cli_main(["src"]) == 1                      # new finding
        assert cli_main(["src", "--write-baseline"]) == 0  # burn it down
        assert cli_main(["src", "--baseline"]) == 0        # now known
        bad.write_text(textwrap.dedent(BUGGY).replace("store", "st"))
        assert cli_main(["src", "--baseline"]) == 1        # edited: resurfaces
        assert cli_main(["nonexistent-dir"]) == 2


# ---------------------------------------------------------------------------
# regression tests for the fixes the analyzer surfaced
# ---------------------------------------------------------------------------

@pytest.fixture(scope="module")
def prob32():
    return make_problem(n=48, u=12, s=8, M=4, dtype=jnp.float32)


@pytest.fixture(scope="module")
def runner(prob32):
    return VmapRunner(M=prob32["M"])


@pytest.fixture(scope="module")
def ppitc_store(prob32, runner):
    p = prob32
    return api.init_store("ppitc", p["kfn"], p["params"], p["X"], p["y"],
                          S=p["S"], runner=runner)


class TestTracerSafetyFixes:
    def test_online_retire_under_jit_raises_clear_error(self, ppitc_store):
        """Pre-fix: bool(store.alive[machine]) raised a cryptic
        TracerBoolConversionError mid-trace."""
        with pytest.raises(TypeError, match="with_alive"):
            jax.jit(lambda st: online.retire(st, 0))(ppitc_store.store)

    def test_online_revive_under_jit_raises_clear_error(self, ppitc_store):
        with pytest.raises(TypeError, match="with_alive"):
            jax.jit(lambda st: online.revive(st, 0))(ppitc_store.store)

    def test_online_retire_host_path_unchanged(self, ppitc_store):
        st = online.retire(ppitc_store.store, 1)
        assert not bool(np.asarray(st.alive)[1])
        st2 = online.retire(st, 1)            # no-op branch
        assert st2 is st
        back = online.revive(st, 1)
        np.testing.assert_array_equal(np.asarray(back.alive),
                                      np.asarray(ppitc_store.store.alive))

    def test_picf_to_state_traced_alive_takes_all_alive_path(self, prob32,
                                                             runner):
        """The exact PR-7 bug shape in picf, pre-fix:
        ``if bool(self.alive.all())`` — TracerBoolConversionError when the
        alive mask is traced. Post-fix the traced store takes the
        by-reference path and matches the host result."""
        p = prob32
        store = api.init_store("picf", p["kfn"], p["params"], p["X"],
                               p["y"], rank=16, runner=runner)
        inner = store.store if hasattr(store, "store") else store
        host = inner.to_state()

        def traced_to_state(alive):
            return dataclasses.replace(inner, alive=alive).to_state()

        got = jax.jit(traced_to_state)(inner.alive)
        for a, b in zip(host, got):
            np.testing.assert_allclose(np.asarray(a), np.asarray(b),
                                       rtol=1e-6)

    def test_picf_retire_under_jit_raises_clear_error(self, prob32, runner):
        p = prob32
        store = api.init_store("picf", p["kfn"], p["params"], p["X"],
                               p["y"], rank=16, runner=runner)
        inner = store.store if hasattr(store, "store") else store
        with pytest.raises(TypeError, match="host-side"):
            jax.jit(lambda alive:
                    dataclasses.replace(inner, alive=alive).retire(0).alive
                    )(inner.alive)

    def test_padded_diag_traceable_when_pad_fires(self, prob32, runner):
        """Pre-fix: ServePlan._padded staged through np.asarray, so an
        outer jit over plan.diag exploded with TracerArrayConversionError
        whenever the batch needed bucket padding."""
        p = prob32
        model = api.fit("ppitc", p["kfn"], p["params"], p["X"], p["y"],
                        S=p["S"], runner=runner)
        plan = model.plan(api.ServeSpec(max_batch=8))
        U = p["U"][:5]                        # 5 -> bucket pad fires
        host_mean, host_var = plan.diag(np.asarray(U))
        mean, var = jax.jit(lambda u: plan.diag(u))(jnp.asarray(U))
        np.testing.assert_allclose(np.asarray(mean), np.asarray(host_mean),
                                   rtol=1e-6)
        np.testing.assert_allclose(np.asarray(var), np.asarray(host_var),
                                   rtol=1e-6)

    def test_routed_diag_under_jit_raises_clear_error(self, prob32, runner):
        """Pre-fix: a traced batch died deep inside _route with a cryptic
        TracerArrayConversionError; now rejected at entry."""
        p = prob32
        model = api.fit("ppic", p["kfn"], p["params"], p["X"], p["y"],
                        S=p["S"], runner=runner)
        plan = model.plan(api.ServeSpec(max_batch=8, routed=True))
        with pytest.raises(TypeError, match="routed_diag"):
            jax.jit(lambda u: plan.routed_diag(u))(p["U"][:5])

    def test_save_state_under_jit_raises_clear_error(self, prob32, runner,
                                                     tmp_path):
        p = prob32
        model = api.fit("ppitc", p["kfn"], p["params"], p["X"], p["y"],
                        S=p["S"], runner=runner)
        with pytest.raises(TypeError, match="save_state"):
            jax.jit(lambda st: serialize.save_state(tmp_path / "s.npz", st)
                    and st)(model.state)

    def test_save_store_traced_leaves_raise_clear_error(self, monkeypatch,
                                                        tmp_path):
        class FakeStore:
            params: dict = {}

            def __init__(self, leaf):
                self.leaf = leaf

        monkeypatch.setitem(serialize.STORE_TYPES, "FakeStore",
                            (lambda s: {"leaf": s.leaf}, None, None))
        with pytest.raises(TypeError, match="save_store"):
            jax.jit(lambda x: serialize.save_store(
                tmp_path / "st.npz", FakeStore(x)) and x)(jnp.ones(3))


# ---------------------------------------------------------------------------
# contract auditor
# ---------------------------------------------------------------------------

@pytest.fixture()
def clean_registry():
    contracts.reset_registry()
    yield
    contracts.reset_registry()


class TestContracts:
    def test_no_retrace_flags_post_freeze_signature(self, clean_registry):
        @contracts.no_retrace("test.fn")
        @jax.jit
        def fn(x):
            return x * 2

        fn(jnp.ones(3))
        fn(jnp.ones(4))
        contracts.freeze()
        fn(jnp.ones(3))                      # seen: fine
        assert contracts.violations() == {}
        fn(jnp.ones(5))                      # new signature post-freeze
        assert contracts.violations() == {"test.fn": 1}
        rep = contracts.registry_report()["test.fn"]
        assert rep["n_calls"] == 4 and rep["n_signatures"] == 3

    def test_scalar_type_change_is_a_new_signature(self, clean_registry):
        @contracts.no_retrace("test.scalar")
        def fn(x, s):
            return x

        fn(jnp.ones(3), 1)
        contracts.freeze()
        fn(jnp.ones(3), 1.0)                 # int -> float: JIT003 class
        assert "test.scalar" in contracts.violations()

    def test_rebind_generations_fingerprint_identical(self, prob32, runner):
        """Acceptance: >= 3 rebind generations, identical jaxpr
        fingerprints, zero new traces, trace counter restored."""
        p = prob32
        model = api.fit("ppitc", p["kfn"], p["params"], p["X"], p["y"],
                        S=p["S"], runner=runner)
        plan = model.plan(api.ServeSpec(max_batch=8)).warmup(
            int(p["U"].shape[1]))
        U = np.asarray(p["U"][:5])
        report = contracts.audit_rebind_generations(
            plan, lambda pl: pl.diag(U), n_generations=3)
        assert report["rebind_identical"]
        assert report["rebind_new_traces"] == 0
        assert report["n_rebind_generations"] == 3
        assert report["n_audited"] >= 1
        assert len(report["generations"]) == 3

    def test_audit_restores_trace_counter(self, prob32, runner):
        p = prob32
        model = api.fit("ppitc", p["kfn"], p["params"], p["X"], p["y"],
                        S=p["S"], runner=runner)
        plan = model.plan(api.ServeSpec(max_batch=8)).warmup(
            int(p["U"].shape[1]))
        U = np.asarray(p["U"][:5])
        plan.diag(U)
        before = plan.stats.n_traces
        contracts.audit_plan(plan, lambda pl: pl.diag(U))
        assert plan.stats.n_traces == before

    def test_tenant_interleaving_identical(self, prob32, runner):
        p = prob32
        model = api.fit("ppitc", p["kfn"], p["params"], p["X"], p["y"],
                        S=p["S"], runner=runner)
        report = contracts.audit_tenant_interleaving(
            model, api.ServeSpec(max_batch=8), np.asarray(p["U"][:6]))
        assert report["n_lineages"] == 1
        assert report["interleaving_identical"]
        assert report["interleaving_new_traces"] == 0

    @pytest.mark.slow
    def test_run_audit_end_to_end(self, tmp_path):
        """The CI artifact path: routed ppic deployment, full report."""
        report = contracts.run_audit(str(tmp_path / "audit.json"))
        assert report["ok"]
        assert report["n_rebind_generations"] >= 3
        assert (tmp_path / "audit.json").exists()
        assert report["no_retrace"]["ppic.cinv_blocks"]["n_calls"] >= 1
