"""Online/incremental learning (Sec. 5.2), support selection, clustering,
and hyperparameter MLE."""
import jax
import jax.numpy as jnp
import numpy as np

from repro.core import (clustering, covariance as cov, gp, hyper, linalg,
                        online, pitc, support)
from repro.parallel.runner import VmapRunner

from helpers import make_problem


class TestSupport:
    def test_parallel_matches_centralized(self):
        p = make_problem()
        C = jax.random.normal(jax.random.PRNGKey(9), (32, 3), jnp.float64)
        S1 = support.select_support(p["kfn"], p["params"], C, 8)
        S2 = support.select_support_parallel(p["kfn"], p["params"], C, 8,
                                             VmapRunner(M=p["M"]))
        np.testing.assert_allclose(S1, S2, atol=0)

    def test_greedy_is_max_variance(self):
        """First pick must be the argmax of prior variance; second must be
        the argmax of posterior variance given the first."""
        p = make_problem()
        C = jax.random.normal(jax.random.PRNGKey(9), (32, 3), jnp.float64)
        S = support.select_support(p["kfn"], p["params"], C, 2)
        # SE kernel: prior variance constant -> any point valid; check second
        post = gp.predict(p["kfn"],
                          {**p["params"],
                           "log_noise": jnp.asarray(-20.0, jnp.float64)},
                          S[:1], jnp.zeros(1, jnp.float64), C)
        np.testing.assert_allclose(S[1], C[jnp.argmax(post.var)], atol=0)


class TestOnline:
    def test_assimilate_equals_block_sum(self):
        p = make_problem()
        r = VmapRunner(M=p["M"])
        store = online.build(p["kfn"], p["params"], p["S"], p["X"], p["y"], r)
        X2 = jax.random.normal(jax.random.PRNGKey(11), (48, 3), jnp.float64)
        y2 = jnp.sin(X2[:, 0]) * 2 + X2[:, 1]
        s_new = online.build(p["kfn"], p["params"], p["S"], X2, y2, r)
        merged = online.assimilate(store, p["kfn"], p["params"], p["S"], X2,
                                   y2, r)
        g_m = online.global_summary(merged)
        g_a = online.global_summary(store)
        g_b = online.global_summary(s_new)
        np.testing.assert_allclose(g_m.ydd, g_a.ydd + g_b.ydd, atol=1e-9)
        np.testing.assert_allclose(g_m.Sdd, g_a.Sdd + g_b.Sdd - store.Kss,
                                   atol=1e-9)

    def test_retire_recovers_surviving_pitc(self):
        """Machine loss => posterior equals centralized PITC on survivors."""
        p = make_problem()
        r = VmapRunner(M=p["M"])
        store = online.build(p["kfn"], p["params"], p["S"], p["X"], p["y"], r)
        store = online.retire(store, 1)
        mean_r, _ = online.predict_ppitc(store, p["kfn"], p["params"],
                                         p["S"], p["U"])
        b = p["X"].shape[0] // p["M"]
        keep = jnp.concatenate([jnp.arange(0, b), jnp.arange(2 * b, 4 * b)])
        surv = pitc.pitc_predict_literal(p["kfn"], p["params"], p["S"],
                                         p["X"][keep], p["y"][keep], p["U"],
                                         p["M"] - 1)
        np.testing.assert_allclose(mean_r, surv.mean, atol=5e-6)

    def test_retire_then_revive_is_identity(self):
        p = make_problem()
        r = VmapRunner(M=p["M"])
        store = online.build(p["kfn"], p["params"], p["S"], p["X"], p["y"], r)
        g0 = online.global_summary(store)
        g1 = online.global_summary(online.revive(online.retire(store, 2), 2))
        np.testing.assert_allclose(g0.Sdd, g1.Sdd, atol=0)


class TestClustering:
    def test_capacity_respected_and_permutation_valid(self):
        p = make_problem()
        M = p["M"]
        Xc, yc, Uc, pd_, pu_ = clustering.cocluster(
            np.asarray(p["X"]), np.asarray(p["y"]), np.asarray(p["U"]), M,
            jax.random.PRNGKey(5))
        assert Xc.shape == p["X"].shape and Uc.shape == p["U"].shape
        assert (np.sort(pd_) == np.arange(p["X"].shape[0])).all()
        np.testing.assert_allclose(Xc, np.asarray(p["X"])[pd_])

    def test_uncluster_roundtrip(self):
        p = make_problem()
        _, yc, _, pd_, _ = clustering.cocluster(
            np.asarray(p["X"]), np.asarray(p["y"]), np.asarray(p["U"]),
            p["M"], jax.random.PRNGKey(5))
        np.testing.assert_allclose(clustering.uncluster(yc, pd_),
                                   np.asarray(p["y"]))

    def test_capacity_assign_single_machine(self):
        """M=1 degenerates to 'everything on machine 0'."""
        rs = np.random.RandomState(0)
        X = rs.randn(7, 3)
        assign = clustering.capacity_assign(X, X[:1], 7)
        assert (assign == 0).all()

    def test_capacity_assign_indivisible_n(self):
        """n not divisible by M: capacity = ceil(n/M) absorbs the slack
        while every block stays within capacity and every point lands."""
        rs = np.random.RandomState(1)
        n, M = 13, 4
        X = rs.randn(n, 2)
        cap = -(-n // M)
        assign = clustering.capacity_assign(X, X[:M], cap)
        assert (assign >= 0).all() and (assign < M).all()
        counts = np.bincount(assign, minlength=M)
        assert counts.sum() == n and counts.max() <= cap

    def test_capacity_assign_duplicates_spill_over(self):
        """All points identical -> all prefer one centroid; the greedy fill
        must spill to other machines instead of overfilling."""
        X = np.ones((12, 2))
        centers = np.stack([np.zeros(2), np.ones(2), 5 * np.ones(2)])
        assign = clustering.capacity_assign(X, centers, 4)
        counts = np.bincount(assign, minlength=3)
        assert counts.max() <= 4 and counts.sum() == 12
        assert (assign >= 0).all()

    def test_capacity_assign_overflow_rejected(self):
        X = np.zeros((5, 2))
        with np.testing.assert_raises(AssertionError):
            clustering.capacity_assign(X, X[:2], 2)   # 2*2 < 5

    def test_capacity_assign_permutation_roundtrips(self):
        """argsort(assign) is the block permutation; uncluster inverts it on
        per-point outputs, including when n doesn't divide M."""
        rs = np.random.RandomState(3)
        for n, M in ((12, 4), (13, 4), (7, 1), (9, 2)):
            X = rs.randn(n, 3)
            cap = -(-n // M)
            assign = clustering.capacity_assign(X, X[:M], cap)
            perm = np.argsort(assign, kind="stable")
            values = rs.randn(n)
            np.testing.assert_array_equal(
                clustering.uncluster(values[perm], perm), values)

    def test_block_centroids(self):
        Xb = jnp.asarray(np.arange(24, dtype=np.float64).reshape(2, 4, 3))
        c = clustering.block_centroids(Xb)
        np.testing.assert_allclose(c, np.asarray(Xb).mean(axis=1))

    def test_clustering_improves_ppic_over_random(self):
        """Co-clustered pPIC should not be worse than block-random pPIC on a
        spatially structured problem (Remark 2 rationale)."""
        from repro.core import ppic
        key = jax.random.PRNGKey(0)
        n, u, M = 128, 32, 4
        X = jax.random.uniform(key, (n, 2), jnp.float64) * 8
        f = lambda Z: jnp.sin(Z[:, 0]) + jnp.cos(1.3 * Z[:, 1])
        y = f(X) + 0.05 * jax.random.normal(key, (n,), jnp.float64)
        U = jax.random.uniform(jax.random.PRNGKey(1), (u, 2), jnp.float64) * 8
        params = cov.init_params(2, signal=1.0, noise=0.05, lengthscale=1.0,
                                 dtype=jnp.float64)
        kfn = cov.make_kernel("se")
        S = support.select_support(kfn, params, X, 8)
        r = VmapRunner(M=M)
        post_rand = ppic.predict(kfn, params, S, X, y, U, r)
        rmse_rand = float(jnp.sqrt(jnp.mean((post_rand.mean - f(U)) ** 2)))
        Xc, yc, Uc, _, pu_ = clustering.cocluster(
            np.asarray(X), np.asarray(y), np.asarray(U), M,
            jax.random.PRNGKey(2))
        post_c = ppic.predict(kfn, params, jnp.asarray(S), jnp.asarray(Xc),
                              jnp.asarray(yc), jnp.asarray(Uc), r)
        pred = clustering.uncluster(np.asarray(post_c.mean), pu_)
        rmse_c = float(np.sqrt(np.mean((pred - np.asarray(f(U))) ** 2)))
        assert rmse_c <= rmse_rand * 1.25  # clustered never much worse


class TestHyper:
    def test_pitc_nlml_matches_dense(self):
        p = make_problem()
        r = VmapRunner(M=p["M"])
        n = p["X"].shape[0]
        Kss_L = linalg.chol(p["kfn"](p["params"], p["S"], p["S"]))
        G = pitc._gamma(p["kfn"], p["params"], p["S"], p["X"], p["X"], Kss_L)
        Sig = cov.add_noise(p["kfn"](p["params"], p["X"], p["X"]),
                            p["params"]) - G
        Lam = jnp.zeros_like(Sig)
        b = n // p["M"]
        for m in range(p["M"]):
            sl = slice(m * b, (m + 1) * b)
            Lam = Lam.at[sl, sl].set(Sig[sl, sl])
        from jax.scipy.stats import multivariate_normal as mvn
        dense = -mvn.logpdf(p["y"], jnp.zeros(n, jnp.float64), G + Lam)
        par = hyper.pitc_nlml(p["kfn"], p["params"], p["S"], p["X"], p["y"],
                              r)
        np.testing.assert_allclose(par, dense, rtol=1e-6)

    def test_fit_improves_nlml(self):
        p = make_problem()
        p0 = cov.init_params(3, signal=0.5, noise=0.5, lengthscale=3.0,
                             dtype=jnp.float64)
        _, losses = hyper.fit(p["kfn"], p0, p["X"], p["y"], steps=40, lr=0.08)
        assert float(losses[-1]) < float(losses[0])

    def test_fit_parallel_improves_pitc_nlml(self):
        p = make_problem()
        r = VmapRunner(M=p["M"])
        p0 = cov.init_params(3, signal=0.5, noise=0.5, lengthscale=3.0,
                             dtype=jnp.float64)
        _, losses = hyper.fit_parallel(p["kfn"], p0, p["S"], p["X"], p["y"],
                                       r, steps=40, lr=0.08)
        assert float(losses[-1]) < float(losses[0])
