"""Per-kernel validation: Pallas (interpret mode — kernel body executed on
CPU) vs the pure-jnp oracles, swept over shapes and dtypes."""
import jax
import jax.numpy as jnp
import pytest
from hypothesis import given, settings, strategies as st

from repro.kernels.attention import ops as attn_ops, ref as attn_ref
from repro.kernels.rbf import ops as rbf_ops, ref as rbf_ref


class TestRBFKernel:
    @pytest.mark.parametrize("n,m,d", [(64, 96, 3), (200, 130, 21),
                                       (256, 256, 5), (33, 17, 7),
                                       (128, 128, 128), (8, 300, 1)])
    @pytest.mark.parametrize("dtype,tol", [(jnp.float32, 1e-5),
                                           (jnp.bfloat16, 3e-2)])
    def test_matches_ref(self, n, m, d, dtype, tol):
        Xq = jax.random.normal(jax.random.PRNGKey(n * m), (n, d), dtype)
        Xk = jax.random.normal(jax.random.PRNGKey(1), (m, d), dtype)
        a = rbf_ops.rbf_covariance(Xq, Xk, 1.7, impl="pallas_interpret")
        b = rbf_ref.rbf_covariance(Xq, Xk, 1.7)
        err = float(jnp.abs(a.astype(jnp.float32)
                            - b.astype(jnp.float32)).max())
        assert err < tol, err

    def test_matches_covariance_module(self):
        """se_ard (jnp) == pallas path through covariance params scaling."""
        from repro.core import covariance as cov
        X = jax.random.normal(jax.random.PRNGKey(0), (40, 5), jnp.float32)
        Z = jax.random.normal(jax.random.PRNGKey(1), (30, 5), jnp.float32)
        params = cov.init_params(5, signal=1.3, lengthscale=0.7,
                                 dtype=jnp.float32)
        a = cov.se_ard(params, X, Z)
        Xs = X / jnp.exp(params["log_lengthscale"])
        Zs = Z / jnp.exp(params["log_lengthscale"])
        b = rbf_ops.rbf_covariance(Xs, Zs, cov.signal_var(params),
                                   impl="pallas_interpret")
        assert float(jnp.abs(a - b).max()) < 1e-5

    @settings(max_examples=8, deadline=None)
    @given(n=st.integers(4, 150), m=st.integers(4, 150),
           d=st.integers(1, 40), seed=st.integers(0, 2**16))
    def test_property_random_shapes(self, n, m, d, seed):
        Xq = jax.random.normal(jax.random.PRNGKey(seed), (n, d), jnp.float32)
        Xk = jax.random.normal(jax.random.PRNGKey(seed + 1), (m, d),
                               jnp.float32)
        a = rbf_ops.rbf_covariance(Xq, Xk, 0.9, impl="pallas_interpret")
        b = rbf_ref.rbf_covariance(Xq, Xk, 0.9)
        assert float(jnp.abs(a - b).max()) < 1e-5

    def test_values_in_unit_interval(self):
        X = jax.random.normal(jax.random.PRNGKey(0), (50, 4), jnp.float32)
        K = rbf_ops.rbf_covariance(X, X, 1.0, impl="pallas_interpret")
        assert float(K.max()) <= 1.0 + 1e-6 and float(K.min()) >= 0.0


class TestFlashAttention:
    @pytest.mark.parametrize(
        "B,Hq,Hkv,Tq,Tk,D,window,off",
        [(1, 4, 4, 128, 128, 64, None, 0),
         (2, 8, 2, 128, 128, 64, None, 0),       # GQA 4:1
         (1, 4, 4, 256, 256, 32, 128, 0),        # sliding window
         (1, 2, 2, 64, 256, 64, None, 192),      # chunked prefill offset
         (1, 4, 2, 100, 200, 48, None, 100),     # ragged -> padding
         (1, 1, 1, 64, 64, 128, 32, 0)])
    def test_matches_ref_f32(self, B, Hq, Hkv, Tq, Tk, D, window, off):
        ks = jax.random.split(jax.random.PRNGKey(Tq * Tk + D), 3)
        q = jax.random.normal(ks[0], (B, Hq, Tq, D), jnp.float32)
        k = jax.random.normal(ks[1], (B, Hkv, Tk, D), jnp.float32)
        v = jax.random.normal(ks[2], (B, Hkv, Tk, D), jnp.float32)
        a = attn_ops.attention(q, k, v, window=window, q_offset=off,
                               impl="pallas_interpret", block_q=64,
                               block_k=64)
        b = attn_ref.attention(q, k, v, window=window, q_offset=off)
        assert float(jnp.abs(a - b).max()) < 2e-3

    def test_bf16(self):
        ks = jax.random.split(jax.random.PRNGKey(7), 3)
        q = jax.random.normal(ks[0], (1, 4, 128, 64), jnp.bfloat16)
        k = jax.random.normal(ks[1], (1, 4, 128, 64), jnp.bfloat16)
        v = jax.random.normal(ks[2], (1, 4, 128, 64), jnp.bfloat16)
        a = attn_ops.attention(q, k, v, impl="pallas_interpret",
                               block_q=64, block_k=64)
        b = attn_ref.attention(q, k, v)
        assert float(jnp.abs(a.astype(jnp.float32)
                             - b.astype(jnp.float32)).max()) < 3e-2

    @pytest.mark.parametrize("T,W", [(1024, 128), (2048, 256), (512, 100)])
    def test_windowed_chunked_matches_masked_full(self, T, W):
        """§Perf iteration 6: the O(T*(W+c)) chunked sliding-window path is
        exact vs the masked-full reference."""
        ks = jax.random.split(jax.random.PRNGKey(T + W), 3)
        q = jax.random.normal(ks[0], (1, 4, T, 32), jnp.float32)
        k = jax.random.normal(ks[1], (1, 2, T, 32), jnp.float32)
        v = jax.random.normal(ks[2], (1, 2, T, 32), jnp.float32)
        a = attn_ref.attention(q, k, v, causal=True, window=W)
        b = attn_ref.attention_windowed_chunked(q, k, v, window=W)
        assert float(jnp.abs(a - b).max()) < 1e-5

    def test_ops_auto_routes_windowed(self):
        """ops.attention picks the chunked path for long windowed seqs and
        stays exact."""
        ks = jax.random.split(jax.random.PRNGKey(0), 3)
        q = jax.random.normal(ks[0], (1, 2, 1024, 32), jnp.float32)
        k = jax.random.normal(ks[1], (1, 2, 1024, 32), jnp.float32)
        v = jax.random.normal(ks[2], (1, 2, 1024, 32), jnp.float32)
        a = attn_ops.attention(q, k, v, causal=True, window=128, impl="jnp")
        b = attn_ref.attention(q, k, v, causal=True, window=128)
        assert float(jnp.abs(a - b).max()) < 1e-5

    def test_rows_sum_to_one_property(self):
        """Attention output of constant-v must be constant (softmax sums 1)."""
        q = jax.random.normal(jax.random.PRNGKey(0), (1, 2, 128, 64),
                              jnp.float32)
        k = jax.random.normal(jax.random.PRNGKey(1), (1, 2, 128, 64),
                              jnp.float32)
        v = jnp.ones((1, 2, 128, 64), jnp.float32) * 3.5
        a = attn_ops.attention(q, k, v, impl="pallas_interpret",
                               block_q=64, block_k=64)
        assert float(jnp.abs(a - 3.5).max()) < 1e-4
