"""Ring all-reduce + compressed collective correctness (vmap axis)."""
import jax
import jax.numpy as jnp
import numpy as np
from hypothesis import given, settings, strategies as st

from repro.parallel.collectives import ring_all_reduce

KEY = jax.random.PRNGKey(0)


class TestRingAllReduce:
    def test_matches_psum(self):
        M = 8
        xs = jax.random.normal(KEY, (M, 37, 5))
        out = jax.vmap(
            lambda x: ring_all_reduce(x, "m", axis_size=M),
            axis_name="m")(xs)
        expected = jnp.sum(xs, axis=0)
        for m in range(M):
            np.testing.assert_allclose(out[m], expected, atol=1e-5)

    def test_compressed_close(self):
        M = 4
        xs = jax.random.normal(KEY, (M, 64)) * 0.1
        out = jax.vmap(
            lambda x: ring_all_reduce(x, "m", axis_size=M, compressed=True),
            axis_name="m")(xs)
        expected = jnp.sum(xs, axis=0)
        rel = float(jnp.abs(out[0] - expected).max()
                    / (jnp.abs(expected).max() + 1e-9))
        assert rel < 0.1

    @settings(max_examples=6, deadline=None)
    @given(m=st.sampled_from([2, 3, 4, 8]), n=st.integers(2, 50),
           seed=st.integers(0, 2**16))
    def test_property_any_shape(self, m, n, seed):
        xs = jax.random.normal(jax.random.PRNGKey(seed), (m, n))
        out = jax.vmap(
            lambda x: ring_all_reduce(x, "mm", axis_size=m),
            axis_name="mm")(xs)
        np.testing.assert_allclose(out[0], jnp.sum(xs, 0), atol=1e-4)
