"""Optimizer, checkpointing, data pipeline, gradient compression."""
import jax
import jax.numpy as jnp
import numpy as np

from repro.checkpoint.manager import CheckpointManager
from repro.configs.registry import smoke_config
from repro.data import loader, synthetic
from repro.optim import compression
from repro.optim.adam import Adam, cosine_schedule

KEY = jax.random.PRNGKey(0)


class TestAdam:
    def test_converges_on_quadratic(self):
        opt = Adam(lr=0.1)
        params = {"w": jnp.ones((8,)) * 5.0}
        state = opt.init(params)
        for _ in range(200):
            g = jax.grad(lambda p: jnp.sum(p["w"] ** 2))(params)
            params, state = opt.update(g, state, params)
        assert float(jnp.abs(params["w"]).max()) < 1e-2

    def test_clipping(self):
        opt = Adam(lr=0.1, clip_norm=1.0)
        params = {"w": jnp.zeros((4,))}
        state = opt.init(params)
        g = {"w": jnp.ones((4,)) * 1e6}
        p2, _ = opt.update(g, state, params)
        assert bool(jnp.isfinite(p2["w"]).all())

    def test_cosine_schedule(self):
        lr = cosine_schedule(1.0, warmup=10, total=100, floor=0.1)
        assert float(lr(jnp.asarray(0))) == 0.0
        assert abs(float(lr(jnp.asarray(10))) - 1.0) < 1e-6
        assert abs(float(lr(jnp.asarray(100))) - 0.1) < 1e-6


class TestCheckpoint:
    def test_roundtrip(self, tmp_path):
        mgr = CheckpointManager(tmp_path, keep=2)
        tree = {"a": jnp.arange(10.0), "b": ({"c": jnp.ones((3, 4))},
                                             jnp.asarray(3))}
        mgr.save(1, tree)
        like = jax.tree.map(lambda a: jnp.zeros_like(a), tree)
        step, restored = mgr.restore_latest(like)
        assert step == 1
        for a, b in zip(jax.tree.leaves(tree), jax.tree.leaves(restored)):
            np.testing.assert_allclose(a, b)

    def test_rotation(self, tmp_path):
        mgr = CheckpointManager(tmp_path, keep=2)
        tree = {"a": jnp.zeros(3)}
        for s in (1, 2, 3, 4):
            mgr.save(s, tree)
        assert mgr.steps() == [3, 4]

    def test_async_save(self, tmp_path):
        mgr = CheckpointManager(tmp_path)
        tree = {"a": jnp.arange(1000.0)}
        mgr.save(7, tree, sync=False)
        mgr.wait()
        _, restored = mgr.restore_latest(jax.tree.map(jnp.zeros_like, tree))
        np.testing.assert_allclose(restored["a"], tree["a"])

    def test_train_state_resume_exact(self, tmp_path):
        """Full train loop resume: save at step k, restart, identical
        params at step k+n (fault-tolerance contract)."""
        from repro.launch import train as train_lib
        cfg = smoke_config("olmo-1b")
        opt = Adam(lr=1e-3)
        state = train_lib.init_state(KEY, cfg, opt)
        toks = jax.random.randint(KEY, (2, 16), 0, cfg.vocab)
        batch = {"tokens": toks, "labels": toks}
        step_fn, _ = train_lib.make_train_step(
            cfg, None, opt, attn_impl="jnp", remat=False)

        for _ in range(2):
            state, _ = step_fn(state, batch)
        mgr = CheckpointManager(tmp_path)
        mgr.save(2, state)

        # branch A: continue
        cont = state
        for _ in range(2):
            cont, _ = step_fn(cont, batch)
        # branch B: restore + continue
        _, rest = mgr.restore_latest(jax.tree.map(
            lambda a: jnp.zeros_like(a), state))
        for _ in range(2):
            rest, _ = step_fn(rest, batch)
        for a, b in zip(jax.tree.leaves(cont.params),
                        jax.tree.leaves(rest.params)):
            np.testing.assert_allclose(a, b, atol=1e-7)


class TestCompression:
    def test_error_feedback_unbiased_over_time(self):
        """Error feedback: sum of compressed grads ~= sum of true grads."""
        g = jax.random.normal(KEY, (1000,)) * 0.01
        ef = compression.init_ef({"g": g})
        tot_true = jnp.zeros_like(g)
        tot_comp = jnp.zeros_like(g)
        for i in range(50):
            gi = {"g": g * (1 + 0.1 * i)}
            ci, ef = compression.compress_grads(gi, ef)
            tot_true += gi["g"]
            tot_comp += ci["g"]
        # telescoping: |sum difference| bounded by one quantization step
        err = float(jnp.abs(tot_true - tot_comp).max())
        step = float(jnp.abs(tot_true).max()) / 127.0
        assert err < 4 * step, (err, step)

    def test_compressed_psum_close_to_exact(self):
        f = lambda x: compression.compressed_psum(x, "m")
        xs = jax.random.normal(KEY, (8, 256))
        approx = jax.vmap(f, axis_name="m")(xs)
        exact = jnp.sum(xs, axis=0)
        rel = float(jnp.abs(approx[0] - exact).max()
                    / (jnp.abs(exact).max() + 1e-9))
        assert rel < 0.05


class TestData:
    def test_gp_datasets_shapes_and_stats(self):
        for gen, d in ((synthetic.aimpeak_like, 5),
                       (synthetic.sarcos_like, 21)):
            ds = gen(KEY, n=512, n_test=64)
            assert ds.X.shape == (512, d)
            std = synthetic.standardize(ds)
            assert abs(float(std.y.mean())) < 0.3

    def test_token_loader_deterministic_resume(self):
        cfg = smoke_config("olmo-1b")
        mesh = jax.make_mesh((1,), ("data",))
        l1 = loader.TokenLoader(cfg, mesh, batch=4, seq=16, seed=3)
        b1 = next(l1)
        b2 = next(l1)
        l2 = loader.TokenLoader(cfg, mesh, batch=4, seq=16, seed=3)
        l2.restore_state({"step": 1, "seed": 3})
        b2r = next(l2)
        np.testing.assert_array_equal(b2["tokens"], b2r["tokens"])
        assert not bool(jnp.all(b1["tokens"] == b2["tokens"]))

    def test_zipf_tokens_skewed(self):
        toks = synthetic.lm_tokens(KEY, batch=8, seq=512, vocab=1000)
        frac_low = float(jnp.mean(toks < 10))
        assert frac_low > 0.2  # head-heavy (uniform would give 0.01)
