"""repro — parallel GP regression with low-rank covariance approximations
(pPITC / pPIC / pICF) as a production JAX framework, plus the assigned
LM architecture zoo, multi-pod launcher, and roofline tooling."""
__version__ = "1.0.0"
