"""Weighted-deadline dispatch over per-tenant microbatch queues.

One process, many tenants, one dispatch loop. Each tenant keeps its own
queue, tickets, and flush policy (its ``GPServer`` semantics, unchanged);
what centralizes is WHEN queues drain: ``pump()`` replaces per-server
polling with earliest-weighted-deadline-first over every admitted tenant.

A tenant's oldest ticket is DUE at

    due = t_submit(oldest) + effective_deadline_ms / 1e3 / weight

so ``weight`` scales urgency (a weight-2 tenant's staleness budget is
effectively halved) without touching the declared budget, and ``weight=1``
with a fixed deadline reproduces ``GPServer`` exactly — the bitwise
single-tenant-equivalence ground truth (tests/test_multitenant_serving.py)
rests on that identity. ``pump()`` flushes EVERY due tenant, ordered by
(due, admission seq): a due tenant is never passed over for a
heavier-weighted one, which is the no-starvation property — skewed weights
reorder service, they cannot deny it.

The other two policies hang off the same loop:

* admission control — ``max_pending`` caps a tenant's queue depth at
  submit time; ``overflow="reject"`` raises ``AdmissionError`` (the caller
  holds no ticket), ``overflow="shed_oldest"`` drops the oldest queued
  ticket to admit the newest (the shed ticket will never resolve). Both
  are counted (``n_rejected``/``n_shed``) — load shedding that doesn't
  show up in stats is an outage that doesn't show up in monitoring.
* adaptive flusher — with an ``AdaptiveDeadline`` policy the effective
  deadline tracks ``gain x EMA(interarrival)`` clipped to
  [floor_ms, declared budget]: brisk tenants flush at the cadence their
  own traffic sets (low staleness), sparse tenants wait out the full
  budget (maximum batching). See ``registry.AdaptiveDeadline``.
* self-healing dispatch — tenants admitted with ``health=`` run every
  flush through ``_dispatch``'s policy ladder (``serving/health.py``):
  latency and output-finiteness evidence is attributed per block, failed
  flushes retry with exponential backoff (re-routing around blocks retired
  in between), a block crossing the failure threshold is auto-retired from
  ROUTING ONLY (its stranded queries served degraded from the global
  posterior — zero recompiles, every ticket still answered), and ``pump``
  background-revives retired blocks from the last good ``save_store``
  checkpoint. ``chaos=`` attaches deterministic fault injection
  (``serving/chaos.py``) for exercising all of the above.

Everything is driven by one injectable ``clock`` (seconds, monotonic) and
one injectable ``sleep`` (retry backoff) so scheduling and chaos tests run
on virtual time.
"""
from __future__ import annotations

import time
from collections import deque
from typing import Any, Callable, Optional

import jax
import numpy as np

from repro.core import clustering
from repro.serving.registry import Tenant, TenantRegistry
from repro.serving.stats import rollup


class _FlushFault(Exception):
    """Internal: a health-dispatch attempt produced evidence bad enough to
    retry (non-finite healthy rows). Never escapes ``_dispatch``."""


class AdmissionError(RuntimeError):
    """Submit refused: the tenant's queue is at ``max_pending`` under the
    ``reject`` overflow policy. The request holds NO ticket."""


class TenantScheduler:
    """Central dispatch loop over a ``TenantRegistry``'s tenant queues.

    The request path mirrors ``GPServer`` per tenant — ``submit`` returns a
    ticket (per-tenant namespace, starting at 0), size/deadline/manual
    triggers drain the queue through one padded plan dispatch, ``result``
    blocks on exactly one ticket — plus the cross-tenant policies described
    in the module docstring. ``GPServer`` itself is a one-tenant client of
    this class.
    """

    def __init__(self, registry: TenantRegistry | None = None, *,
                 clock: Callable[[], float] = time.monotonic,
                 sleep: Callable[[float], None] = time.sleep,
                 log_len: int = 512):
        self.registry = registry if registry is not None else TenantRegistry()
        self._clock = clock
        self._sleep = sleep
        # (tenant_id, trigger, n_tickets) per flush, newest last — the
        # ordering the property tests (and a human debugging priority
        # inversions) inspect
        self.dispatch_log: deque = deque(maxlen=log_len)

    # -- membership (registry passthrough + drain semantics) ----------------

    def admit(self, tenant_id: str, model, spec=None, **kw) -> Tenant:
        """``TenantRegistry.admit`` — see there for the knobs."""
        return self.registry.admit(tenant_id, model, spec, **kw)

    def admit_from_checkpoint(self, tenant_id: str, path, **kw) -> Tenant:
        return self.registry.admit_from_checkpoint(tenant_id, path, **kw)

    def evict(self, tenant_id: str, *, drain: bool = True) -> Tenant:
        """Remove a tenant. ``drain=True`` (default) flushes its pending
        tickets first so already-promised work resolves into the returned
        record's ``ready`` map; ``drain=False`` abandons them."""
        if drain:
            self.flush(tenant_id)
        return self.registry.evict(tenant_id)

    # -- request path --------------------------------------------------------

    def submit(self, tenant_id: str, x) -> int:
        """Enqueue one query point (d,) for a tenant; returns its ticket.

        Points are staged host-side (NumPy): microbatch assembly must not
        touch XLA, otherwise every distinct queue length eagerly compiles
        a fresh stack/pad kernel (serving tail latency). Admission control
        runs BEFORE enqueue; size/deadline triggers after, exactly as in
        ``GPServer.submit``."""
        t = self.registry.get(tenant_id)
        now = self._clock()
        if t.max_pending is not None and len(t.queue) >= t.max_pending:
            if t.overflow == "reject":
                t.stats.n_rejected += 1
                raise AdmissionError(
                    f"tenant {tenant_id!r}: queue depth {len(t.queue)} at "
                    f"max_pending={t.max_pending} (reject policy); pump or "
                    f"flush before resubmitting")
            t.queue.pop(0)
            t.stats.n_shed += 1
        t.stats.observe_arrival(now, t.last_arrival)
        t.last_arrival = now
        ticket = t.next_ticket
        t.next_ticket += 1
        t.queue.append((ticket, np.asarray(x), now))
        if len(t.queue) >= t.max_batch:
            self._flush(t, "size")
        elif self._past_deadline(t, now):
            self._flush(t, "deadline")
        return ticket

    def pending(self, tenant_id: str) -> int:
        return self.registry.get(tenant_id).pending

    def oldest_age_ms(self, tenant_id: str) -> float:
        """Age of a tenant's oldest pending ticket (0.0 when empty)."""
        t = self.registry.get(tenant_id)
        if not t.queue:
            return 0.0
        return (self._clock() - t.queue[0][2]) * 1e3

    # -- deadline machinery --------------------------------------------------

    def effective_deadline_ms(self, tenant_id: str) -> Optional[float]:
        """The deadline actually in force for a tenant right now: the
        declared ``flush_deadline_ms``, tightened by the adaptive policy
        when one is set and interarrival data exists."""
        return self._eff_ms(self.registry.get(tenant_id))

    def _eff_ms(self, t: Tenant) -> Optional[float]:
        base = t.flush_deadline_ms
        if base is None or t.adaptive is None:
            return base
        ia = t.stats.interarrival.value
        if ia is None:
            return base
        return min(base, max(t.adaptive.floor_ms, t.adaptive.gain * ia * 1e3))

    def _due_at(self, t: Tenant) -> Optional[float]:
        """Absolute weighted due time of a tenant's oldest ticket (None
        when it has no deadline or an empty queue)."""
        eff = self._eff_ms(t)
        if eff is None or not t.queue:
            return None
        return t.queue[0][2] + eff * 1e-3 / t.weight

    def _past_deadline(self, t: Tenant, now: float) -> bool:
        due = self._due_at(t)
        return due is not None and now >= due

    def pump(self) -> int:
        """Deadline driver: flush every tenant whose weighted due time has
        passed, earliest-weighted-deadline first (admission order breaks
        ties deterministically). Call from the serving loop whenever idle.
        Returns total tickets resolved (0 if nothing was due)."""
        now = self._clock()
        due = []
        for t in self.registry.tenants():
            if (t.health is not None and t.health.dead_blocks()
                    and t.health.policy.checkpoint is not None
                    and now >= t.health.revive_due):
                self._try_revive(t, now)
            d = self._due_at(t)
            if d is not None and now >= d:
                due.append((d, t.seq, t))
        due.sort(key=lambda e: (e[0], e[1]))
        return sum(self._flush(t, "deadline") for _, _, t in due)

    def _try_revive(self, t: Tenant, now: float) -> bool:
        """Background revive: reload the tenant's last known-good
        ``save_store`` checkpoint and swap it in via ``commit_store`` —
        pending tickets flush (degraded) against the old posterior FIRST,
        then the restored store's state rebinds with zero recompiles and
        the dead blocks return to routing. A corrupt/truncated artifact is
        detected (``serialize.CheckpointError``) and NEVER loaded: the
        tenant stays degraded-but-correct and the revive timer re-arms."""
        from repro.core import serialize
        try:
            store = serialize.load_store(
                t.health.policy.checkpoint,
                kfn=t.store.kfn if t.store is not None else t.model.kfn,
                runner=t.store.runner if t.store is not None else None)
        except serialize.CheckpointError:
            t.stats.n_revive_failures += 1
            t.health.defer_revive(self._clock())
            return False
        self.commit_store(t.tenant_id, store)
        revived = t.health.revive_all(self._clock())
        t.stats.n_revives += 1
        self.dispatch_log.append((t.tenant_id, "revive", len(revived)))
        return True

    def flush(self, tenant_id: str | None = None, *,
              trigger: str = "manual") -> int:
        """Drain one tenant's queue (or every tenant's, ``tenant_id=None``)
        with one padded, jitted plan dispatch each. Returns tickets
        resolved. Dispatch is asynchronous — nothing blocks until
        ``result``/``sync``."""
        if tenant_id is None:
            return sum(self._flush(t, trigger)
                       for t in self.registry.tenants())
        return self._flush(self.registry.get(tenant_id), trigger)

    def _flush(self, t: Tenant, trigger: str) -> int:
        if trigger not in ("size", "deadline", "manual"):
            # validate before touching the queue: a bad trigger must not
            # destroy pending tickets after predict but before resolution
            raise ValueError(f"unknown flush trigger {trigger!r}; "
                             f"expected 'size', 'deadline', or 'manual'")
        if not t.queue:
            return 0
        queue = t.queue
        U = np.stack([x for _, x, _ in queue])
        tickets = [tk for tk, _, _ in queue]
        # predict before clearing: a failing batch (e.g. one malformed
        # point) must not destroy the other pending tickets
        mean, var, deg = self._dispatch(t, U)
        now = self._clock()
        for _, _, t_sub in queue:
            t.stats.staleness.record((now - t_sub) * 1e3)
        t.stats.observe_flush(
            trigger, t.plan.stats.last_g if t.spec.routed else None)
        if deg is not None and deg.any():
            t.stats.n_degraded_flushes += 1
            t.stats.n_degraded_rows += int(deg.sum())
        t.queue.clear()
        self.dispatch_log.append((t.tenant_id, trigger, len(tickets)))
        for i, tk in enumerate(tickets):
            t.ready[tk] = (mean[i], var[i])
            t.ready_degraded[tk] = bool(deg[i]) if deg is not None else False
        # bound memory against abandoned tickets: evict oldest results
        # (dicts preserve insertion order) beyond max_ready
        while len(t.ready) > t.max_ready:
            dropped = next(iter(t.ready))
            del t.ready[dropped]
            t.ready_degraded.pop(dropped, None)
            t.stats.n_evicted += 1
        return len(tickets)

    def done(self, tenant_id: str, ticket: int) -> bool:
        """True when a ticket's flush was dispatched (device values may
        still be in flight; ``result``/``sync`` do the blocking)."""
        return ticket in self.registry.get(tenant_id).ready

    def sync(self, tenant_id: str | None = None) -> None:
        """Block until every already-flushed result (of one tenant, or of
        all) has materialized — a measurement/shutdown barrier."""
        tenants = (self.registry.tenants() if tenant_id is None
                   else [self.registry.get(tenant_id)])
        jax.block_until_ready([list(t.ready.values()) for t in tenants])

    def result(self, tenant_id: str, ticket: int):
        """(mean, var) for a tenant's ticket; flushes its queue if the
        ticket is still pending. The only point this layer blocks on the
        device."""
        t = self.registry.get(tenant_id)
        if ticket not in t.ready:
            self._flush(t, "manual")
        try:
            out = t.ready.pop(ticket)
        except KeyError:
            raise KeyError(
                f"ticket {ticket}: unknown, already collected, shed, or "
                f"evicted (max_ready={t.max_ready})") from None
        t.ready_degraded.pop(ticket, None)
        return jax.block_until_ready(out)

    def collect(self, tenant_id: str, ticket: int):
        """(mean, var, degraded) for a tenant's ticket — ``result`` plus
        the per-query degradation flag: True when the row's routed block
        was health-retired and the answer came from the global S-space
        posterior (bounded accuracy loss, see serving/health.py). Callers
        that ignore the flag can keep using ``result``."""
        t = self.registry.get(tenant_id)
        if ticket not in t.ready:
            self._flush(t, "manual")
        degraded = t.ready_degraded.get(ticket, False)
        mean, var = self.result(tenant_id, ticket)
        return mean, var, degraded

    # -- batch path ----------------------------------------------------------

    def predict(self, tenant_id: str, U):
        """Synchronous bucket-padded (mean, var) over a caller-held (u, d)
        batch for one tenant — one plan dispatch, no queue involved."""
        return self._predict(self.registry.get(tenant_id), U)

    def _predict(self, t: Tenant, U, block_alive=None):
        before = t.plan.stats.n_padded_rows
        if t.spec.routed:
            mean, var = t.plan.routed_diag(U, block_alive=block_alive)
        elif block_alive is not None:
            raise ValueError(f"tenant {t.tenant_id!r}: block_alive routing "
                             f"masks apply to routed tenants only")
        else:
            mean, var = t.plan.diag(U)
        t.stats.n_batches += 1
        t.stats.n_padded_rows += t.plan.stats.n_padded_rows - before
        return mean, var

    def _dispatch(self, t: Tenant, U):
        """One flush's (mean, var, degraded) through the self-healing policy
        ladder. Without ``health``/``chaos`` this IS ``_predict`` — the
        zero-overhead fast path every pre-existing tenant takes.

        With health, the loop walks the ladder per attempt: route host-side
        (same nearest-centroid float path as the plan — blame attribution
        must agree with the device scatter), dispatch with the current
        routing mask, MATERIALIZE the outputs (health is a blocking
        observer: finiteness cannot be judged on an in-flight device
        value), attribute evidence, and either accept or retry after a
        seeded backoff. Every retry past the policy budget force-retires
        the blocks it blamed, so each extra attempt strictly shrinks the
        set of blocks that can fail — the loop provably terminates with
        every ticket answered (worst case: all blocks retired, the whole
        flush served degraded from the global posterior). Exceptions never
        escape a health-managed dispatch."""
        h, c = t.health, t.chaos
        if h is None and c is None:
            mean, var = self._predict(t, U)
            return mean, var, None
        from repro.serving.chaos import BlockDied
        max_retries = h.policy.max_retries if h is not None else 0
        attempt = 0
        while True:
            alive = h.alive_mask() if h is not None else None
            assign = None
            if t.spec.routed:
                assign = clustering.nearest_center_np(
                    np.asarray(U), np.asarray(t.model.state.centroids))
            participating = ([] if assign is None else
                             sorted({int(m) for m in assign
                                     if alive is None or alive[m]}))
            t0 = self._clock()
            try:
                if c is not None:
                    c.before_dispatch(assign, alive)
                mean, var = self._predict(t, U, block_alive=alive)
                # materialize: the latency sample must cover device compute,
                # and finiteness is only observable on host values
                mean = np.asarray(jax.block_until_ready(mean))
                var = np.asarray(jax.block_until_ready(var))
                if c is not None:
                    mean, var = c.poison(assign, mean, var, alive)
                latency_ms = (self._clock() - t0) * 1e3
                deg = (np.asarray(t.plan.stats.last_degraded)
                       if t.spec.routed and t.plan.stats.last_degraded
                       is not None else None)
                if h is None:
                    return mean, var, deg
                h.observe_latency(participating, latency_ms)
                bad = ~(np.isfinite(mean) & np.isfinite(var))
                if deg is not None:
                    bad &= ~deg       # degraded rows came from the global
                                      # posterior, not a routed block
                if bad.any():
                    blamed = (participating if assign is None else
                              sorted({int(m) for m in assign[bad]
                                      if alive is None or alive[m]}))
                    if blamed:
                        t.stats.n_nonfinite_flushes += 1
                        raise _FlushFault(blamed)
                    # non-finite with nothing left to blame (the global
                    # posterior itself is bad): retrying cannot help —
                    # return what we have rather than loop or raise
                    t.stats.n_nonfinite_flushes += 1
                    return mean, var, deg
                p = h.policy
                if (p.flush_timeout_ms is not None
                        and latency_ms > p.flush_timeout_ms):
                    # a timeout is a LATENCY fault on a valid posterior:
                    # accept the result, count the evidence against the
                    # participating block the latency EMAs most implicate
                    t.stats.n_timeout_flushes += 1
                    culprit = h.slowest_of(participating)
                    if culprit is not None and h.record_failure(culprit):
                        if h.mark_dead(culprit, self._clock()):
                            t.stats.n_auto_retired += 1
                else:
                    h.record_success(participating)
                return mean, var, deg
            except (BlockDied, _FlushFault) as e:
                blamed = ([e.block] if isinstance(e, BlockDied)
                          else list(e.args[0]))
                if h is None:
                    raise    # chaos without health: faults hit the caller
                             # raw (the un-healed control experiment)
                now = self._clock()
                for m in blamed:
                    threshold = h.record_failure(
                        m, nonfinite=isinstance(e, _FlushFault))
                    if (threshold or attempt >= max_retries) \
                            and h.mark_dead(m, now):
                        t.stats.n_auto_retired += 1
                if attempt < max_retries:
                    self._sleep(h.backoff_ms(attempt) * 1e-3)
                t.stats.n_retries += 1
                attempt += 1

    # -- state lifecycle -----------------------------------------------------

    def swap_state(self, tenant_id: str, state: Any) -> None:
        """Hot-swap one tenant's posterior (``TenantRegistry.rebind``):
        executables are reused at unchanged shapes, other tenants are
        untouched. Does NOT flush — tickets already queued resolve against
        the new state; use ``commit_store`` for flush-then-swap."""
        self.registry.rebind(tenant_id, state)

    def commit_store(self, tenant_id: str, store) -> None:
        """Swap in a mutated store: pending tickets flush FIRST so every
        ticket resolves against the posterior it was submitted under.
        Atomic: rebind (and its routed-centroid validation) runs before the
        store is reassigned, so a rejected state leaves the tenant on the
        old store AND the old posterior."""
        t = self.registry.get(tenant_id)
        self._flush(t, "manual")
        self.registry.rebind(tenant_id, store.to_state())
        t.store = store
        t.stats.n_updates += 1

    # -- observability -------------------------------------------------------

    def stats(self, tenant_id: str):
        return self.registry.stats(tenant_id)

    def rollup(self) -> dict:
        """Fleet view: per-tenant snapshots + aggregate totals
        (``serving.stats.rollup`` over the registry)."""
        return rollup(self.registry.stats_by_tenant())
