"""Weighted-deadline dispatch over per-tenant microbatch queues.

One process, many tenants, one dispatch loop. Each tenant keeps its own
queue, tickets, and flush policy (its ``GPServer`` semantics, unchanged);
what centralizes is WHEN queues drain: ``pump()`` replaces per-server
polling with earliest-weighted-deadline-first over every admitted tenant.

A tenant's oldest ticket is DUE at

    due = t_submit(oldest) + effective_deadline_ms / 1e3 / weight

so ``weight`` scales urgency (a weight-2 tenant's staleness budget is
effectively halved) without touching the declared budget, and ``weight=1``
with a fixed deadline reproduces ``GPServer`` exactly — the bitwise
single-tenant-equivalence ground truth (tests/test_multitenant_serving.py)
rests on that identity. ``pump()`` flushes EVERY due tenant, ordered by
(due, admission seq): a due tenant is never passed over for a
heavier-weighted one, which is the no-starvation property — skewed weights
reorder service, they cannot deny it.

The other two policies hang off the same loop:

* admission control — ``max_pending`` caps a tenant's queue depth at
  submit time; ``overflow="reject"`` raises ``AdmissionError`` (the caller
  holds no ticket), ``overflow="shed_oldest"`` drops the oldest queued
  ticket to admit the newest (the shed ticket will never resolve). Both
  are counted (``n_rejected``/``n_shed``) — load shedding that doesn't
  show up in stats is an outage that doesn't show up in monitoring.
* adaptive flusher — with an ``AdaptiveDeadline`` policy the effective
  deadline tracks ``gain x EMA(interarrival)`` clipped to
  [floor_ms, declared budget]: brisk tenants flush at the cadence their
  own traffic sets (low staleness), sparse tenants wait out the full
  budget (maximum batching). See ``registry.AdaptiveDeadline``.

Everything is driven by one injectable ``clock`` (seconds, monotonic) so
scheduling tests and the latency bench run on virtual time.
"""
from __future__ import annotations

import time
from collections import deque
from typing import Any, Callable, Optional

import jax
import numpy as np

from repro.serving.registry import Tenant, TenantRegistry
from repro.serving.stats import rollup


class AdmissionError(RuntimeError):
    """Submit refused: the tenant's queue is at ``max_pending`` under the
    ``reject`` overflow policy. The request holds NO ticket."""


class TenantScheduler:
    """Central dispatch loop over a ``TenantRegistry``'s tenant queues.

    The request path mirrors ``GPServer`` per tenant — ``submit`` returns a
    ticket (per-tenant namespace, starting at 0), size/deadline/manual
    triggers drain the queue through one padded plan dispatch, ``result``
    blocks on exactly one ticket — plus the cross-tenant policies described
    in the module docstring. ``GPServer`` itself is a one-tenant client of
    this class.
    """

    def __init__(self, registry: TenantRegistry | None = None, *,
                 clock: Callable[[], float] = time.monotonic,
                 log_len: int = 512):
        self.registry = registry if registry is not None else TenantRegistry()
        self._clock = clock
        # (tenant_id, trigger, n_tickets) per flush, newest last — the
        # ordering the property tests (and a human debugging priority
        # inversions) inspect
        self.dispatch_log: deque = deque(maxlen=log_len)

    # -- membership (registry passthrough + drain semantics) ----------------

    def admit(self, tenant_id: str, model, spec=None, **kw) -> Tenant:
        """``TenantRegistry.admit`` — see there for the knobs."""
        return self.registry.admit(tenant_id, model, spec, **kw)

    def admit_from_checkpoint(self, tenant_id: str, path, **kw) -> Tenant:
        return self.registry.admit_from_checkpoint(tenant_id, path, **kw)

    def evict(self, tenant_id: str, *, drain: bool = True) -> Tenant:
        """Remove a tenant. ``drain=True`` (default) flushes its pending
        tickets first so already-promised work resolves into the returned
        record's ``ready`` map; ``drain=False`` abandons them."""
        if drain:
            self.flush(tenant_id)
        return self.registry.evict(tenant_id)

    # -- request path --------------------------------------------------------

    def submit(self, tenant_id: str, x) -> int:
        """Enqueue one query point (d,) for a tenant; returns its ticket.

        Points are staged host-side (NumPy): microbatch assembly must not
        touch XLA, otherwise every distinct queue length eagerly compiles
        a fresh stack/pad kernel (serving tail latency). Admission control
        runs BEFORE enqueue; size/deadline triggers after, exactly as in
        ``GPServer.submit``."""
        t = self.registry.get(tenant_id)
        now = self._clock()
        if t.max_pending is not None and len(t.queue) >= t.max_pending:
            if t.overflow == "reject":
                t.stats.n_rejected += 1
                raise AdmissionError(
                    f"tenant {tenant_id!r}: queue depth {len(t.queue)} at "
                    f"max_pending={t.max_pending} (reject policy); pump or "
                    f"flush before resubmitting")
            t.queue.pop(0)
            t.stats.n_shed += 1
        t.stats.observe_arrival(now, t.last_arrival)
        t.last_arrival = now
        ticket = t.next_ticket
        t.next_ticket += 1
        t.queue.append((ticket, np.asarray(x), now))
        if len(t.queue) >= t.max_batch:
            self._flush(t, "size")
        elif self._past_deadline(t, now):
            self._flush(t, "deadline")
        return ticket

    def pending(self, tenant_id: str) -> int:
        return self.registry.get(tenant_id).pending

    def oldest_age_ms(self, tenant_id: str) -> float:
        """Age of a tenant's oldest pending ticket (0.0 when empty)."""
        t = self.registry.get(tenant_id)
        if not t.queue:
            return 0.0
        return (self._clock() - t.queue[0][2]) * 1e3

    # -- deadline machinery --------------------------------------------------

    def effective_deadline_ms(self, tenant_id: str) -> Optional[float]:
        """The deadline actually in force for a tenant right now: the
        declared ``flush_deadline_ms``, tightened by the adaptive policy
        when one is set and interarrival data exists."""
        return self._eff_ms(self.registry.get(tenant_id))

    def _eff_ms(self, t: Tenant) -> Optional[float]:
        base = t.flush_deadline_ms
        if base is None or t.adaptive is None:
            return base
        ia = t.stats.interarrival.value
        if ia is None:
            return base
        return min(base, max(t.adaptive.floor_ms, t.adaptive.gain * ia * 1e3))

    def _due_at(self, t: Tenant) -> Optional[float]:
        """Absolute weighted due time of a tenant's oldest ticket (None
        when it has no deadline or an empty queue)."""
        eff = self._eff_ms(t)
        if eff is None or not t.queue:
            return None
        return t.queue[0][2] + eff * 1e-3 / t.weight

    def _past_deadline(self, t: Tenant, now: float) -> bool:
        due = self._due_at(t)
        return due is not None and now >= due

    def pump(self) -> int:
        """Deadline driver: flush every tenant whose weighted due time has
        passed, earliest-weighted-deadline first (admission order breaks
        ties deterministically). Call from the serving loop whenever idle.
        Returns total tickets resolved (0 if nothing was due)."""
        now = self._clock()
        due = []
        for t in self.registry.tenants():
            d = self._due_at(t)
            if d is not None and now >= d:
                due.append((d, t.seq, t))
        due.sort(key=lambda e: (e[0], e[1]))
        return sum(self._flush(t, "deadline") for _, _, t in due)

    def flush(self, tenant_id: str | None = None, *,
              trigger: str = "manual") -> int:
        """Drain one tenant's queue (or every tenant's, ``tenant_id=None``)
        with one padded, jitted plan dispatch each. Returns tickets
        resolved. Dispatch is asynchronous — nothing blocks until
        ``result``/``sync``."""
        if tenant_id is None:
            return sum(self._flush(t, trigger)
                       for t in self.registry.tenants())
        return self._flush(self.registry.get(tenant_id), trigger)

    def _flush(self, t: Tenant, trigger: str) -> int:
        if trigger not in ("size", "deadline", "manual"):
            # validate before touching the queue: a bad trigger must not
            # destroy pending tickets after predict but before resolution
            raise ValueError(f"unknown flush trigger {trigger!r}; "
                             f"expected 'size', 'deadline', or 'manual'")
        if not t.queue:
            return 0
        queue = t.queue
        U = np.stack([x for _, x, _ in queue])
        tickets = [tk for tk, _, _ in queue]
        # predict before clearing: a failing batch (e.g. one malformed
        # point) must not destroy the other pending tickets
        mean, var = self._predict(t, U)
        now = self._clock()
        for _, _, t_sub in queue:
            t.stats.staleness.record((now - t_sub) * 1e3)
        t.stats.observe_flush(
            trigger, t.plan.stats.last_g if t.spec.routed else None)
        t.queue.clear()
        self.dispatch_log.append((t.tenant_id, trigger, len(tickets)))
        for i, tk in enumerate(tickets):
            t.ready[tk] = (mean[i], var[i])
        # bound memory against abandoned tickets: evict oldest results
        # (dicts preserve insertion order) beyond max_ready
        while len(t.ready) > t.max_ready:
            dropped = next(iter(t.ready))
            del t.ready[dropped]
            t.stats.n_evicted += 1
        return len(tickets)

    def done(self, tenant_id: str, ticket: int) -> bool:
        """True when a ticket's flush was dispatched (device values may
        still be in flight; ``result``/``sync`` do the blocking)."""
        return ticket in self.registry.get(tenant_id).ready

    def sync(self, tenant_id: str | None = None) -> None:
        """Block until every already-flushed result (of one tenant, or of
        all) has materialized — a measurement/shutdown barrier."""
        tenants = (self.registry.tenants() if tenant_id is None
                   else [self.registry.get(tenant_id)])
        jax.block_until_ready([list(t.ready.values()) for t in tenants])

    def result(self, tenant_id: str, ticket: int):
        """(mean, var) for a tenant's ticket; flushes its queue if the
        ticket is still pending. The only point this layer blocks on the
        device."""
        t = self.registry.get(tenant_id)
        if ticket not in t.ready:
            self._flush(t, "manual")
        try:
            out = t.ready.pop(ticket)
        except KeyError:
            raise KeyError(
                f"ticket {ticket}: unknown, already collected, shed, or "
                f"evicted (max_ready={t.max_ready})") from None
        return jax.block_until_ready(out)

    # -- batch path ----------------------------------------------------------

    def predict(self, tenant_id: str, U):
        """Synchronous bucket-padded (mean, var) over a caller-held (u, d)
        batch for one tenant — one plan dispatch, no queue involved."""
        return self._predict(self.registry.get(tenant_id), U)

    def _predict(self, t: Tenant, U):
        before = t.plan.stats.n_padded_rows
        if t.spec.routed:
            mean, var = t.plan.routed_diag(U)
        else:
            mean, var = t.plan.diag(U)
        t.stats.n_batches += 1
        t.stats.n_padded_rows += t.plan.stats.n_padded_rows - before
        return mean, var

    # -- state lifecycle -----------------------------------------------------

    def swap_state(self, tenant_id: str, state: Any) -> None:
        """Hot-swap one tenant's posterior (``TenantRegistry.rebind``):
        executables are reused at unchanged shapes, other tenants are
        untouched. Does NOT flush — tickets already queued resolve against
        the new state; use ``commit_store`` for flush-then-swap."""
        self.registry.rebind(tenant_id, state)

    def commit_store(self, tenant_id: str, store) -> None:
        """Swap in a mutated store: pending tickets flush FIRST so every
        ticket resolves against the posterior it was submitted under.
        Atomic: rebind (and its routed-centroid validation) runs before the
        store is reassigned, so a rejected state leaves the tenant on the
        old store AND the old posterior."""
        t = self.registry.get(tenant_id)
        self._flush(t, "manual")
        self.registry.rebind(tenant_id, store.to_state())
        t.store = store
        t.stats.n_updates += 1

    # -- observability -------------------------------------------------------

    def stats(self, tenant_id: str):
        return self.registry.stats(tenant_id)

    def rollup(self) -> dict:
        """Fleet view: per-tenant snapshots + aggregate totals
        (``serving.stats.rollup`` over the registry)."""
        return rollup(self.registry.stats_by_tenant())
