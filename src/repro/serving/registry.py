"""TenantRegistry: many posteriors, shared hardware, deduplicated compiles.

The paper parallelizes ONE posterior across machines; production inverts
it — many independent posteriors (per-tenant hyperparameters, regions,
sensor networks) multiplexed onto one process. The expensive resource to
share is not the state (a few small factors per tenant) but the COMPILED
serving programs: every (bucket, overflow-group) executable costs an XLA
compile, and per-tenant plans would each pay the whole ladder.

The registry closes that gap with the lineage map: a tenant is admitted as
a (tenant_id, FittedGP, ServeSpec[, StateStore]) tuple; its lineage key is

    (method name, ServeSpec.compat_key(kfn), state tree structure,
     params tree structure)

— exactly the things the compiled executables depend on. Params, state,
and backend caches are TRACED arguments of every plan executable, so
tenants with equal keys run byte-identical programs on different posterior
values: the first admit builds the plan, every later admit REBINDS the
anchored lineage (``dataclasses.replace`` keeps the executable dict and
trace-counting ``PlanStats`` shared by reference), and the trace-count
probe shows zero recompiles across tenant interleavings at fixed shapes
(tests/test_multitenant_serving.py). The anchor itself is stripped of
params/state/caches so a lineage never pins an evicted tenant's posterior.

Queue mechanics (weighted deadlines, admission control, flushing) live in
``serving/scheduler.py``; the registry owns membership, lineage dedup, and
the state/store lifecycle (``rebind`` = hot-swap with routed-state
validation, ``admit_from_checkpoint`` = fleet re-admission from one
``serialize.save_store(..., spec=...)`` artifact).
"""
from __future__ import annotations

import dataclasses
from typing import Any, Optional

import jax
import numpy as np

from repro.core import api
from repro.serving.stats import ServeStats


@dataclasses.dataclass(frozen=True)
class AdaptiveDeadline:
    """Adaptive-flusher policy: a tenant's EFFECTIVE deadline is

        clip(gain * EMA(interarrival), floor_ms, flush_deadline_ms)

    The declared ``flush_deadline_ms`` is a staleness BUDGET — the worst
    queue time a ticket may ever see. When traffic is brisk but below the
    size-trigger rate, holding a ticket for the whole budget buys little
    extra batching: ~``gain`` more arrivals is all a flush can gain, and
    those arrive within ``gain`` interarrival times. So the effective
    deadline tracks the observed rate (low staleness under load) and
    relaxes toward the declared budget as traffic thins (maximum batching
    when batches are hard to fill). Never exceeds the declared budget.
    """
    gain: float = 4.0
    floor_ms: float = 0.5

    def __post_init__(self):
        if self.gain <= 0 or self.floor_ms < 0:
            raise ValueError(f"AdaptiveDeadline needs gain > 0 and "
                             f"floor_ms >= 0; got {self}")


@dataclasses.dataclass
class Tenant:
    """One admitted tenant: its model/plan/store plus the scheduler-owned
    queue state. Mutable by design — the scheduler and registry are the
    only writers; everything observable rides in ``stats``."""
    tenant_id: str
    model: api.FittedGP
    spec: api.ServeSpec
    plan: api.ServePlan
    store: Optional[api.StateStore]
    weight: float
    flush_deadline_ms: Optional[float]
    adaptive: Optional[AdaptiveDeadline]
    max_pending: Optional[int]
    overflow: str
    max_ready: int
    max_batch: int
    seq: int                       # admission order: deterministic tie-break
    stats: ServeStats = dataclasses.field(default_factory=ServeStats)
    queue: list = dataclasses.field(default_factory=list)
    ready: dict = dataclasses.field(default_factory=dict)
    next_ticket: int = 0
    last_arrival: Optional[float] = None
    # self-healing (serving/health.py) + fault injection (serving/chaos.py);
    # None = the zero-overhead fast path in the scheduler's dispatch
    health: Optional[Any] = None        # HealthTracker
    chaos: Optional[Any] = None         # FaultInjector
    # ticket -> bool, maintained in lockstep with ``ready``: True when the
    # ticket's row was answered from the global posterior (its routed block
    # was health-retired). Collected via TenantScheduler.collect().
    ready_degraded: dict = dataclasses.field(default_factory=dict)

    @property
    def pending(self) -> int:
        return len(self.queue)


def _tree_struct(tree) -> tuple:
    leaves, treedef = jax.tree.flatten(tree)
    shapes = tuple((tuple(np.shape(leaf)), str(np.asarray(leaf).dtype))
                   for leaf in leaves)
    return (treedef, shapes)


def lineage_key(model: api.FittedGP, spec: api.ServeSpec) -> tuple:
    """What compiled-program sharing legitimately depends on — and nothing
    else. Posterior VALUES are absent on purpose: they are traced
    arguments, so equal-key tenants reuse one executable cache."""
    return (model.method.name, spec.compat_key(model.kfn),
            _tree_struct(model.state), _tree_struct(model.params))


# store type -> the registry method whose plan serves it (fleet re-admission
# from a store checkpoint has no FittedGP to name the method)
_METHOD_FOR_STORE = {"PITCStore": "ppitc", "PICStore": "ppic",
                     "PICFStore": "picf"}


class TenantRegistry:
    """Membership + compiled-lineage dedup for a multi-tenant serving
    process. See the module docstring for the sharing contract."""

    def __init__(self):
        self._tenants: dict[str, Tenant] = {}
        self._lineages: dict[tuple, api.ServePlan] = {}
        self._seq = 0

    # -- membership ---------------------------------------------------------

    def admit(self, tenant_id: str, model: api.FittedGP,
              spec: api.ServeSpec | None = None, *,
              store: api.StateStore | None = None,
              weight: float = 1.0,
              flush_deadline_ms: float | None = None,
              adaptive: AdaptiveDeadline | bool | None = None,
              max_pending: int | None = None,
              overflow: str = "reject",
              max_ready: int = 65536,
              max_batch: int = 64,
              health: Any = None,
              chaos: Any = None) -> Tenant:
        """Admit a tenant; returns its live ``Tenant`` record.

        ``weight`` scales deadline urgency (a weight-2 tenant's tickets
        are due in half the time); ``max_pending``/``overflow`` are the
        admission-control knobs (``"reject"`` raises at submit,
        ``"shed_oldest"`` drops the oldest queued ticket — both counted);
        ``adaptive=True`` opts into the default ``AdaptiveDeadline``.

        ``health`` opts into self-healing dispatch (``serving/health.py``):
        ``True`` for the default ``HealthPolicy``, or a ``HealthPolicy``
        instance. Requires a routed spec — degraded serving re-routes a
        retired block's queries to the global posterior, which only exists
        for routed states. ``chaos`` attaches deterministic fault injection
        (a ``chaos.FaultPlan`` or prebuilt ``chaos.FaultInjector``) for
        tests/benches; production tenants leave it None.
        """
        if tenant_id in self._tenants:
            raise ValueError(f"tenant {tenant_id!r} already admitted; "
                             f"evict it first to re-admit")
        if weight <= 0:
            raise ValueError(f"tenant weight must be > 0; got {weight} "
                             f"(zero/negative weight would starve the "
                             f"tenant forever)")
        if overflow not in ("reject", "shed_oldest"):
            raise ValueError(f"unknown overflow policy {overflow!r}; "
                             f"expected 'reject' or 'shed_oldest'")
        if spec is None:
            spec = api.ServeSpec(max_batch=max_batch)
        elif spec.max_batch is None and spec.buckets is None:
            # a multiplexed tenant NEEDS a finite ladder (identity
            # bucketing compiles per distinct queue length — the serving
            # tail-latency failure mode; same contract as GPServer)
            spec = dataclasses.replace(spec, max_batch=max_batch)
        if spec.routed and model.method.predict_routed_diag_fn is None:
            raise ValueError(
                f"routed=True but method {model.method.name!r} has no "
                f"predict_routed_diag (needs a state with block centroids, "
                f"e.g. ppic/pic)")
        if adaptive is True:
            adaptive = AdaptiveDeadline()
        elif adaptive is False:
            adaptive = None
        if health is not None and health is not False:
            from repro.serving.health import HealthPolicy, HealthTracker
            if not spec.routed:
                raise ValueError(
                    f"tenant {tenant_id!r}: health tracking requires "
                    f"routed=True — degraded serving answers a retired "
                    f"block's queries from the global posterior, which "
                    f"needs per-query block routing")
            policy = HealthPolicy() if health is True else health
            health = HealthTracker(
                int(np.shape(model.state.centroids)[0]), policy)
        else:
            health = None
        if chaos is not None:
            from repro.serving.chaos import FaultInjector, FaultPlan
            if isinstance(chaos, FaultPlan):
                chaos = FaultInjector(chaos)
        plan = self._plan_for(model, spec)
        t = Tenant(tenant_id=tenant_id, model=model, spec=spec, plan=plan,
                   store=store, weight=weight,
                   flush_deadline_ms=flush_deadline_ms, adaptive=adaptive,
                   max_pending=max_pending, overflow=overflow,
                   max_ready=max_ready,
                   max_batch=(spec.max_batch if spec.max_batch is not None
                              else max(spec.buckets)),
                   seq=self._seq, health=health, chaos=chaos)
        self._seq += 1
        self._tenants[tenant_id] = t
        return t

    def _plan_for(self, model: api.FittedGP,
                  spec: api.ServeSpec) -> api.ServePlan:
        key = lineage_key(model, spec)
        anchor = self._lineages.get(key)
        if anchor is None:
            # through the model's per-spec memo, so a plan the caller
            # already built (or builds later via model.predict*) IS the
            # lineage. The anchor is stripped of the admitting tenant's
            # arrays: a lineage owns executables, never a posterior.
            plan = model.plan(spec)
            self._lineages[key] = dataclasses.replace(
                plan, params=None, state=None, caches=None)
            return plan
        plan = dataclasses.replace(
            anchor, params=model.params, state=model.state,
            caches=anchor._rebuild_caches(model.state))
        # install into the model's memo so direct model.predict* calls on
        # the same spec share the lineage too (instead of recompiling)
        model.__dict__.setdefault("_plans", {})[spec] = plan
        return plan

    def admit_from_checkpoint(self, tenant_id: str, path, *, kfn=None,
                              runner=None, spec: api.ServeSpec | None = None,
                              method: str | None = None,
                              **tenant_kw) -> Tenant:
        """Re-admit a tenant from one ``serialize.save_store(..., spec=...)``
        artifact: the store resumes ASSIMILATING and the embedded ServeSpec
        reconstructs the serving policy — a restarted fleet member needs
        nothing else. ``spec=`` overrides the embedded spec (required when
        the checkpoint predates spec embedding); ``kfn``/``runner`` as in
        ``serialize.load_store``."""
        from repro.core import serialize
        store, saved = serialize.load_store(path, kfn=kfn, runner=runner,
                                            with_spec=True)
        if spec is None:
            spec = saved
        if spec is None:
            raise ValueError(
                f"{path}: store checkpoint carries no ServeSpec (saved "
                f"before spec embedding, or via save_store without spec=); "
                f"pass admit_from_checkpoint(..., spec=...)")
        name = method or _METHOD_FOR_STORE.get(type(store).__name__)
        if name is None:
            raise ValueError(f"no registry method known for store type "
                             f"{type(store).__name__!r}; pass method=")
        m = api.get(name)
        model = api.FittedGP(m, store.kfn, store.params, store.to_state())
        return self.admit(tenant_id, model, spec, store=store, **tenant_kw)

    def evict(self, tenant_id: str) -> Tenant:
        """Remove a tenant (its record is returned — pending queue/ready
        state included, so the caller can drain or account for it). The
        lineage anchor stays: executables are the expensive shared asset
        and other tenants may reference them."""
        return self._tenants.pop(self._require(tenant_id).tenant_id)

    def get(self, tenant_id: str) -> Tenant:
        return self._require(tenant_id)

    def _require(self, tenant_id: str) -> Tenant:
        try:
            return self._tenants[tenant_id]
        except KeyError:
            raise KeyError(f"unknown tenant {tenant_id!r}; admitted: "
                           f"{sorted(self._tenants)}") from None

    def __contains__(self, tenant_id: str) -> bool:
        return tenant_id in self._tenants

    def __len__(self) -> int:
        return len(self._tenants)

    def ids(self) -> list[str]:
        return list(self._tenants)

    def tenants(self) -> list[Tenant]:
        return list(self._tenants.values())

    @property
    def n_lineages(self) -> int:
        return len(self._lineages)

    # -- state lifecycle ----------------------------------------------------

    def rebind(self, tenant_id: str, state: Any) -> Tenant:
        """Hot-swap one tenant's posterior: the plan is REBOUND (executables
        reused — zero recompilation at unchanged shapes), every other
        tenant is untouched. Validates routed-state compatibility BEFORE
        mutating, so a rejected swap leaves the tenant serving its old
        posterior."""
        t = self._require(tenant_id)
        if t.spec.routed and not hasattr(state, "centroids"):
            raise ValueError(
                f"routed tenant {tenant_id!r} requires a state with block "
                f"centroids; got {type(state).__name__} (a pPITC store "
                f"emits PITCState — stream through a PIC-family store, or "
                f"serve unrouted)")
        t.model = t.model.with_state(state)
        t.plan = t.model.plan(t.spec)
        t.stats.n_state_swaps += 1
        return t

    # -- observability ------------------------------------------------------

    def stats(self, tenant_id: str) -> ServeStats:
        return self._require(tenant_id).stats

    def stats_by_tenant(self) -> dict[str, ServeStats]:
        return {tid: t.stats for tid, t in self._tenants.items()}
