"""Per-tenant serving observability: counters, staleness percentiles,
G-ladder usage, and fleet rollups.

This is the serving-side generalization of ``runtime/monitor.py``'s
TrainMonitor/FailureDetector pattern: the monitor tracks ONE training
loop's EMAs and stalls; a serving fleet multiplexes many tenants, each
with its own traffic shape, so the stats object is per-tenant and the
rollup aggregates across the registry the way a fleet controller's
per-worker stats rollup does.

* ``ServeStats``   — one tenant's (or one ``GPServer``'s) counters. The
  flush-trigger split (size/deadline/manual) says WHAT drained the queue;
  ``n_shed``/``n_rejected`` account for admission control; ``g_hist``
  records which routed overflow programs actually ran (the compiled-ladder
  usage the plan's lazy-overflow design is about); ``staleness`` holds
  queue-time samples (submit -> flush dispatch, ms) for p50/p99 export.
* ``Reservoir``    — bounded percentile tracker (seeded-deterministic
  replacement above capacity, so long-running tenants keep a stable-memory
  latency profile instead of an unbounded sample list).
* ``interarrival`` — ``runtime.monitor.Ema`` over observed per-tenant
  interarrival times; the scheduler's adaptive flusher reads it to tune
  each tenant's effective deadline.
* ``rollup``       — fleet view: per-tenant snapshots + aggregate totals,
  what an exporter would scrape.
"""
from __future__ import annotations

import dataclasses
from typing import Optional

import numpy as np

from repro.runtime.monitor import Ema


class Reservoir:
    """Bounded sample store with deterministic reservoir replacement.

    Percentiles over ALL seen samples would need unbounded memory; a
    serving tenant lives for days. Classic reservoir sampling keeps a
    uniform sample of the stream in O(cap) memory; the RNG is seeded so
    two runs of the same traffic report identical percentiles (the bench
    gates assert on these numbers)."""

    def __init__(self, cap: int = 4096, seed: int = 0):
        if cap < 1:
            raise ValueError(f"Reservoir cap must be >= 1; got {cap}")
        self.cap = cap
        self._rng = np.random.RandomState(seed)
        self._buf: list[float] = []
        self.n_seen = 0

    def record(self, value: float) -> None:
        self.n_seen += 1
        if len(self._buf) < self.cap:
            self._buf.append(float(value))
        else:
            j = self._rng.randint(self.n_seen)
            if j < self.cap:
                self._buf[j] = float(value)

    def percentile(self, q: float) -> Optional[float]:
        if not self._buf:
            return None
        return float(np.percentile(self._buf, q))

    def snapshot(self) -> dict:
        return {"n": self.n_seen,
                "p50": self.percentile(50.0),
                "p99": self.percentile(99.0)}


@dataclasses.dataclass
class ServeStats:
    """Counters for one serving tenant (also ``GPServer.stats`` — the
    single-tenant server is a one-tenant client of the same runtime)."""
    n_requests: int = 0
    n_batches: int = 0
    n_padded_rows: int = 0
    n_state_swaps: int = 0
    n_updates: int = 0        # store-backed assimilate/retire/revive swaps
    n_evicted: int = 0
    # flush-trigger split: what actually drained the queue
    n_size_flushes: int = 0
    n_deadline_flushes: int = 0
    n_manual_flushes: int = 0
    # routed flushes served by the G=0 executable (no overflow dispatch)
    n_g0_flushes: int = 0
    # admission control: requests turned away (reject policy) / oldest
    # queued tickets dropped to admit newer ones (shed_oldest policy)
    n_rejected: int = 0
    n_shed: int = 0
    # self-healing ladder (serving/health.py): degraded serving, retries,
    # auto-retires, and checkpoint revives. All ints, so they flow into
    # snapshot() and the fleet rollup automatically.
    n_degraded_rows: int = 0      # rows answered from the global posterior
    n_degraded_flushes: int = 0   # flushes with >= 1 degraded row
    n_retries: int = 0            # dispatch attempts retried (backoff slept)
    n_auto_retired: int = 0       # blocks health-retired from routing
    n_revives: int = 0            # successful checkpoint revives
    n_revive_failures: int = 0    # revive attempts refused (bad checkpoint)
    n_nonfinite_flushes: int = 0  # flushes with non-finite healthy rows
    n_timeout_flushes: int = 0    # flushes over the latency budget
    # routed overflow-ladder usage: group count g -> flushes served by the
    # g-group executable (which compiled programs traffic actually exercises)
    g_hist: dict = dataclasses.field(default_factory=dict)
    # queue time submit -> flush dispatch (ms); p50/p99 via snapshot()
    staleness: Reservoir = dataclasses.field(default_factory=Reservoir)
    # EMA of per-tenant interarrival seconds (adaptive flusher's input)
    interarrival: Ema = dataclasses.field(
        default_factory=lambda: Ema(alpha=0.8))

    def observe_arrival(self, now: float, last_arrival: Optional[float]
                        ) -> None:
        self.n_requests += 1
        if last_arrival is not None:
            self.interarrival.update(max(now - last_arrival, 0.0))

    def observe_flush(self, trigger: str, last_g: Optional[int]) -> None:
        field = {"size": "n_size_flushes", "deadline": "n_deadline_flushes",
                 "manual": "n_manual_flushes"}[trigger]
        setattr(self, field, getattr(self, field) + 1)
        if last_g is not None:
            self.g_hist[last_g] = self.g_hist.get(last_g, 0) + 1
            if last_g == 0:
                self.n_g0_flushes += 1

    @property
    def n_flushes(self) -> int:
        return (self.n_size_flushes + self.n_deadline_flushes
                + self.n_manual_flushes)

    def snapshot(self) -> dict:
        """Export view: plain scalars + staleness percentiles, the shape an
        exporter/bench scrapes (no live objects leak out)."""
        out = {f.name: getattr(self, f.name) for f in dataclasses.fields(self)
               if f.name not in ("g_hist", "staleness", "interarrival")}
        out["n_flushes"] = self.n_flushes
        out["g_hist"] = dict(sorted(self.g_hist.items()))
        out["staleness_ms"] = self.staleness.snapshot()
        ia = self.interarrival.value
        out["interarrival_ms"] = None if ia is None else ia * 1e3
        return out


def rollup(stats_by_tenant: dict) -> dict:
    """Fleet view over ``{tenant_id: ServeStats}``: per-tenant snapshots
    plus aggregate counter totals (the controller/per-worker stats-rollup
    shape). Percentiles are per-tenant only — pooling latency samples
    across tenants with different traffic would manufacture a meaningless
    fleet p99."""
    tenants = {tid: st.snapshot() for tid, st in stats_by_tenant.items()}
    totals: dict = {}
    for snap in tenants.values():
        for k, v in snap.items():
            if isinstance(v, int):
                totals[k] = totals.get(k, 0) + v
    return {"tenants": tenants, "totals": totals,
            "n_tenants": len(tenants)}
