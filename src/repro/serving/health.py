"""Per-block health tracking + the self-healing policy ladder.

The paper's cluster-of-20 premise means every serving posterior is a SUM of
per-machine contributions — and a serving runtime that assumes all M blocks
are forever healthy turns one straggling or NaN-producing block into a
tenant-wide outage. This module is the scheduler's health brain: it watches
every flush (latency, output finiteness, dispatch failures), attributes
trouble to blocks, and walks the policy ladder

    flush timeout ──► retry with exponential backoff + jitter
                 ──► auto-retire the offending block (ROUTING-MASK only:
                     the store is untouched and the state keeps its block
                     axis, so the degraded executables — dead-row mask as a
                     traced value — serve stranded queries from the global
                     S-space posterior with ZERO recompiles)
                 ──► background revive from the last ``save_store``
                     checkpoint (``TenantScheduler.pump``), restoring the
                     block bitwise.

Retirement here is deliberately NOT ``StateStore.retire``: the store-level
retire gathers alive blocks and SHRINKS the state's block axis — exact
posterior, but one serving recompile and a changed routing space. The
health layer instead keeps the fitted state intact and masks the block out
of routing (``PICServePlan.routed_diag(block_alive=...)``), trading a
bounded accuracy loss on the stranded queries (pPITC-level, property-tested
against the ``with_alive`` oracle) for uninterrupted zero-recompile
serving. Store-level retire remains the right tool for PERMANENT
decommission, where a recompile is acceptable.

All counters surface through ``ServeStats`` (``n_retries``,
``n_auto_retired``, ``n_revives``, ...); per-block detail through
``HealthTracker.snapshot()``.
"""
from __future__ import annotations

import dataclasses
from typing import Optional

import numpy as np

from repro.runtime.monitor import Ema


@dataclasses.dataclass(frozen=True)
class HealthPolicy:
    """The self-healing knobs, declared once per tenant (frozen).

    * ``flush_timeout_ms`` — per-flush latency budget. A flush exceeding it
      counts a timeout failure against the participating block with the
      WORST latency EMA (a single fused dispatch has one aggregate latency;
      the per-block EMA is what localizes the straggler over repeated
      flushes). ``None`` disables timeout tracking.
    * ``max_retries`` — failed/NaN flushes are retried this many times
      before the dispatch loop escalates; each retry re-routes around any
      block retired in between, so a retry after an auto-retire serves the
      stranded rows degraded instead of failing again.
    * ``backoff_base_ms`` / ``backoff_jitter`` — retry n sleeps
      ``backoff_base_ms * 2^n``, jittered by ``±backoff_jitter`` fraction
      (seeded: chaos runs are reproducible). The scheduler's injectable
      ``sleep`` makes this virtual-time-testable.
    * ``max_consecutive_failures`` — consecutive failures attributed to one
      block before it is auto-retired (routing mask, see module docstring).
      A successful flush the block participates in resets its counter.
    * ``checkpoint`` — path of the last known-good ``save_store`` artifact;
      enables background revive. A corrupt/truncated artifact is DETECTED
      (``serialize.CheckpointError``, counted in ``n_revive_failures``) and
      never loaded.
    * ``revive_after_ms`` — how long a block stays retired before the
      scheduler's ``pump`` attempts a checkpoint revive (also the re-arm
      delay after a failed revive attempt).
    * ``seed`` — jitter RNG seed.
    """
    flush_timeout_ms: Optional[float] = None
    max_retries: int = 2
    backoff_base_ms: float = 1.0
    backoff_jitter: float = 0.5
    max_consecutive_failures: int = 2
    checkpoint: Optional[object] = None
    revive_after_ms: float = 0.0
    seed: int = 0

    def __post_init__(self):
        if self.max_retries < 0 or self.max_consecutive_failures < 1:
            raise ValueError(
                f"HealthPolicy needs max_retries >= 0 and "
                f"max_consecutive_failures >= 1; got {self}")
        if self.backoff_base_ms < 0 or not 0 <= self.backoff_jitter <= 1:
            raise ValueError(
                f"HealthPolicy needs backoff_base_ms >= 0 and jitter in "
                f"[0, 1]; got {self}")


@dataclasses.dataclass
class BlockHealth:
    """One block's health ledger."""
    latency: Ema = dataclasses.field(
        default_factory=lambda: Ema(alpha=0.7))
    consecutive_failures: int = 0
    n_failures: int = 0
    n_nonfinite: int = 0
    alive: bool = True
    retired_at: Optional[float] = None

    def snapshot(self) -> dict:
        return {"alive": self.alive,
                "latency_ms": self.latency.value,
                "consecutive_failures": self.consecutive_failures,
                "n_failures": self.n_failures,
                "n_nonfinite": self.n_nonfinite}


class HealthTracker:
    """Per-block health state for one tenant's M serving blocks.

    Pure bookkeeping — the POLICY decisions (when to retry, retire, revive)
    live in ``TenantScheduler``'s dispatch loop; this object answers "what
    does the evidence say about block m" and owns the routing mask.
    """

    def __init__(self, n_blocks: int, policy: HealthPolicy):
        if n_blocks < 1:
            raise ValueError(f"HealthTracker needs >= 1 block; got "
                             f"{n_blocks}")
        self.policy = policy
        self.blocks = [BlockHealth() for _ in range(n_blocks)]
        self._rng = np.random.RandomState(policy.seed)
        self.revive_due: float = -np.inf

    @property
    def n_blocks(self) -> int:
        return len(self.blocks)

    # -- routing mask --------------------------------------------------------

    def alive_mask(self) -> np.ndarray:
        return np.array([b.alive for b in self.blocks], bool)

    def dead_blocks(self) -> list[int]:
        return [m for m, b in enumerate(self.blocks) if not b.alive]

    def mark_dead(self, m: int, now: float) -> bool:
        """Retire block ``m`` from routing. Returns True if it was alive."""
        b = self.blocks[m]
        if not b.alive:
            return False
        b.alive = False
        b.retired_at = now
        self.revive_due = max(self.revive_due,
                              now + self.policy.revive_after_ms * 1e-3)
        return True

    def revive_all(self, now: float) -> list[int]:
        """Mark every dead block routable again (post checkpoint-restore);
        failure ledgers reset — the restored factors are known-good."""
        revived = self.dead_blocks()
        for m in revived:
            b = self.blocks[m]
            b.alive = True
            b.retired_at = None
            b.consecutive_failures = 0
        self.revive_due = -np.inf
        return revived

    def defer_revive(self, now: float) -> None:
        """Re-arm the revive timer after a failed attempt (e.g. a corrupt
        checkpoint) so pump doesn't hot-loop on a bad artifact."""
        self.revive_due = now + self.policy.revive_after_ms * 1e-3

    # -- evidence ------------------------------------------------------------

    def observe_latency(self, blocks, latency_ms: float) -> None:
        """Fold one flush's aggregate latency into every participating
        block's EMA. A persistent straggler participates only in slow
        flushes, so its EMA separates upward from blocks that also see
        fast, straggler-free flushes — which is what ``slowest_of`` keys
        timeout attribution on."""
        for m in blocks:
            self.blocks[int(m)].latency.update(latency_ms)

    def slowest_of(self, blocks) -> Optional[int]:
        """The participating block most implicated by latency evidence."""
        blocks = [int(m) for m in blocks if self.blocks[int(m)].alive]
        if not blocks:
            return None
        return max(blocks,
                   key=lambda m: self.blocks[m].latency.get(default=0.0))

    def record_failure(self, m: int, *, nonfinite: bool = False) -> bool:
        """Count one failure against block ``m``; True when its consecutive
        count crosses the retire threshold (the CALLER retires — policy
        actions stay in the scheduler)."""
        b = self.blocks[int(m)]
        b.n_failures += 1
        b.consecutive_failures += 1
        if nonfinite:
            b.n_nonfinite += 1
        return (b.alive and b.consecutive_failures
                >= self.policy.max_consecutive_failures)

    def record_success(self, blocks) -> None:
        for m in blocks:
            self.blocks[int(m)].consecutive_failures = 0

    # -- backoff -------------------------------------------------------------

    def backoff_ms(self, attempt: int) -> float:
        """Exponential backoff with seeded jitter for retry ``attempt``
        (0-based): ``base * 2^attempt * (1 ± jitter)``."""
        p = self.policy
        base = p.backoff_base_ms * (2.0 ** attempt)
        if p.backoff_jitter:
            base *= 1.0 + p.backoff_jitter * self._rng.uniform(-1.0, 1.0)
        return base

    # -- observability -------------------------------------------------------

    def snapshot(self) -> dict:
        return {"n_blocks": self.n_blocks,
                "dead_blocks": self.dead_blocks(),
                "blocks": [b.snapshot() for b in self.blocks]}
