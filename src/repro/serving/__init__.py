"""Elastic multi-tenant serving runtime.

Many independent posteriors multiplexed onto one process: a
``TenantRegistry`` deduplicates compiled lineages across plan-compatible
tenants, a ``TenantScheduler`` drains per-tenant microbatch queues
earliest-weighted-deadline-first with admission control, an adaptive
flusher, and self-healing dispatch (``serving.health``: per-block health
tracking, retry/retire/revive, bounded-degradation routed serving;
``serving.chaos``: the deterministic fault injection that exercises it),
and ``serving.stats`` exports per-tenant/fleet observability.
``launch.gp_serve.GPServer`` is the one-tenant client of this package.
"""
from repro.serving.chaos import BlockDied, FaultInjector, FaultPlan
from repro.serving.health import BlockHealth, HealthPolicy, HealthTracker
from repro.serving.registry import (AdaptiveDeadline, Tenant, TenantRegistry,
                                    lineage_key)
from repro.serving.scheduler import AdmissionError, TenantScheduler
from repro.serving.stats import Ema, Reservoir, ServeStats, rollup

__all__ = [
    "AdaptiveDeadline",
    "AdmissionError",
    "BlockDied",
    "BlockHealth",
    "Ema",
    "FaultInjector",
    "FaultPlan",
    "HealthPolicy",
    "HealthTracker",
    "Reservoir",
    "ServeStats",
    "Tenant",
    "TenantRegistry",
    "TenantScheduler",
    "lineage_key",
    "rollup",
]
