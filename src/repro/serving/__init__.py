"""Elastic multi-tenant serving runtime.

Many independent posteriors multiplexed onto one process: a
``TenantRegistry`` deduplicates compiled lineages across plan-compatible
tenants, a ``TenantScheduler`` drains per-tenant microbatch queues
earliest-weighted-deadline-first with admission control and an adaptive
flusher, and ``serving.stats`` exports per-tenant/fleet observability.
``launch.gp_serve.GPServer`` is the one-tenant client of this package.
"""
from repro.serving.registry import (AdaptiveDeadline, Tenant, TenantRegistry,
                                    lineage_key)
from repro.serving.scheduler import AdmissionError, TenantScheduler
from repro.serving.stats import Ema, Reservoir, ServeStats, rollup

__all__ = [
    "AdaptiveDeadline",
    "AdmissionError",
    "Ema",
    "Reservoir",
    "ServeStats",
    "Tenant",
    "TenantRegistry",
    "TenantScheduler",
    "lineage_key",
    "rollup",
]
