"""Deterministic fault injection for the serving runtime.

Resilience claims that are only exercised by real failures are untestable
claims. This module makes every failure mode the health layer handles
REPRODUCIBLE: a frozen, seeded ``FaultPlan`` declares what goes wrong and
when (per-block straggle latency, block death, NaN posteriors, checkpoint
corruption, query bursts), and a ``FaultInjector`` instantiated from it is
attached to a tenant (``TenantScheduler.admit(..., chaos=...)``) where it
wraps scheduler dispatch:

* ``before_dispatch`` runs at the top of every flush attempt — it sleeps
  the declared straggle (through an injectable ``sleep``, so virtual-time
  tests advance a fake clock instead of wall time) and raises ``BlockDied``
  when a flush routes a real row at a block declared dead;
* ``poison`` runs on the flush outputs — it overwrites the rows routed at
  NaN-declared blocks with NaN, which is what the health layer's
  non-finite detection must catch;
* ``corrupt`` deterministically flips bytes in a checkpoint artifact so
  the revive path's corruption handling (``serialize.CheckpointError``,
  never load) is testable;
* ``burst_at`` tells a traffic driver how many extra queries to slam in at
  a given step (admission-control pressure).

Everything is a pure function of (plan, flush index, seed): the same
FaultPlan replays the same failure schedule in tests and benches, which is
what lets the acceptance suite assert exact recovery behavior
(tests/test_resilience.py, benchmarks/bench_fault.py).
"""
from __future__ import annotations

import dataclasses
import time
from typing import Callable, Mapping, Optional

import numpy as np


class BlockDied(RuntimeError):
    """Injected hard failure: the flush routed a query at a block whose
    FaultPlan declares it dead. Carries the block id so the health layer
    can attribute the failure exactly."""

    def __init__(self, block: int, flush_index: int):
        self.block = int(block)
        self.flush_index = int(flush_index)
        super().__init__(f"injected failure: block {block} died "
                         f"(flush {flush_index})")


def _as_int_map(m: Mapping[int, float] | None) -> dict:
    return {} if m is None else {int(k): v for k, v in dict(m).items()}


def _active(sched, idx: int) -> bool:
    """True when a fail_at/nan_at schedule entry is active at flush ``idx``:
    a bare start index (permanent) or a half-open (start, stop) window."""
    if isinstance(sched, tuple):
        start, stop = sched
        return int(start) <= idx < int(stop)
    return idx >= int(sched)


@dataclasses.dataclass(frozen=True)
class FaultPlan:
    """One deterministic failure schedule (frozen + seeded).

    * ``straggle_ms`` — ``{block: added latency}``: every flush attempt in
      which the block participates (routes >= 1 real row) sleeps the
      declared extra milliseconds first — the paper's Sec. 6 straggler,
      serving-side. Multiple participating stragglers sleep the MAX (they
      straggle in parallel, the flush waits for the slowest).
    * ``fail_at`` — ``{block: flush index}`` or ``{block: (start, stop)}``:
      while active, any attempt routing a real row at the block raises
      ``BlockDied`` UNLESS the routing mask already excludes it — exactly a
      machine that stops answering until the health layer stops asking. A
      bare index is a permanent failure (active from there on); a
      half-open ``(start, stop)`` window is a transient one — the machine
      would answer again after ``stop``, which is what the
      revive-to-bitwise-recovery tests need.
    * ``nan_at`` — same scheduling forms: while active, rows routed at the
      block come back NaN (applied to the flush OUTPUT — the posterior the
      block "computed" is garbage, the program ran fine).
    * ``burst_at_steps`` — ``{step: n extra queries}`` for traffic drivers.
    * ``seed`` — RNG stream for corruption byte picks.

    The flush index is the tenant's attempt counter maintained by the
    injector (every dispatch attempt increments it, retries included), so
    a schedule expressed in flush indices is reproducible run-to-run.
    """
    straggle_ms: Mapping[int, float] | None = None
    fail_at: Mapping[int, int] | None = None
    nan_at: Mapping[int, int] | None = None
    burst_at_steps: Mapping[int, int] | None = None
    seed: int = 0

    def burst_at(self, step: int) -> int:
        """Extra queries a traffic driver should inject at ``step``."""
        return _as_int_map(self.burst_at_steps).get(int(step), 0)


class FaultInjector:
    """Live injection state for one tenant: the FaultPlan plus the flush
    counter that advances its schedule. ``sleep`` is injectable so
    virtual-time tests advance a fake clock instead of wall time."""

    def __init__(self, plan: FaultPlan, *,
                 sleep: Callable[[float], None] = time.sleep):
        self.plan = plan
        self._sleep = sleep
        self._rng = np.random.RandomState(plan.seed)
        self.n_dispatches = 0
        self.n_injected_faults = 0

    # -- scheduler hooks -----------------------------------------------------

    def before_dispatch(self, assign: Optional[np.ndarray],
                        alive: Optional[np.ndarray]) -> None:
        """Run the pre-dispatch faults for one flush attempt. ``assign`` is
        the host-side routed block per real row (None for unrouted
        tenants: straggle applies to every block, death/NaN need routing);
        ``alive`` is the health layer's routing mask (None = all alive).
        Raises ``BlockDied`` only for a block the mask still routes to —
        once health has retired it, the tenant has stopped asking the dead
        machine and the fault no longer fires."""
        idx = self.n_dispatches
        self.n_dispatches += 1
        routed = (lambda m: True) if assign is None else \
            (lambda m: bool(np.any(assign == m)))
        routable = (lambda m: True) if alive is None else \
            (lambda m: bool(alive[m]))
        delay = 0.0
        for m, ms in _as_int_map(self.plan.straggle_ms).items():
            if routed(m) and routable(m):
                delay = max(delay, float(ms))
        if delay > 0:
            self._sleep(delay * 1e-3)
        for m, at in sorted(_as_int_map(self.plan.fail_at).items()):
            if _active(at, idx) and routed(m) and routable(m):
                self.n_injected_faults += 1
                raise BlockDied(m, idx)

    def poison(self, assign: Optional[np.ndarray], mean: np.ndarray,
               var: np.ndarray, alive: Optional[np.ndarray] = None
               ) -> tuple[np.ndarray, np.ndarray]:
        """Overwrite the rows routed at NaN-scheduled blocks with NaN —
        the non-finite posterior the health layer must detect. Operates on
        the (already materialized) flush outputs; the index that gates the
        schedule is the attempt counter ``before_dispatch`` advanced. Rows
        whose block ``alive`` already marks dead are spared: those rows were
        answered by the global posterior, not the faulty machine."""
        sched = _as_int_map(self.plan.nan_at)
        if not sched or assign is None:
            return mean, var
        idx = self.n_dispatches - 1     # the attempt just dispatched
        rows = np.zeros(len(assign), bool)
        for m, at in sched.items():
            if _active(at, idx) and (alive is None or bool(alive[m])):
                rows |= np.asarray(assign) == m
        if rows.any():
            mean = np.array(mean, copy=True)
            var = np.array(var, copy=True)
            mean[rows[:len(mean)]] = np.nan
            var[rows[:len(var)]] = np.nan
            self.n_injected_faults += 1
        return mean, var

    # -- artifact faults -----------------------------------------------------

    def corrupt(self, path, n_bytes: int = 8) -> None:
        """Deterministically flip ``n_bytes`` bytes spread through the file
        at ``path`` — a torn write / bit-rot checkpoint. The revive path
        must DETECT this (``serialize.CheckpointError``) and refuse to
        load; seeded byte picks make the corruption reproducible."""
        with open(path, "r+b") as fh:
            fh.seek(0, 2)
            size = fh.tell()
            if size == 0:
                return
            # skip the first 256 bytes: corrupting the zip local header of
            # the first entry is trivially detected; mid-payload flips are
            # the interesting (checksum-caught) case
            lo = min(256, size // 4)
            for off in sorted(self._rng.randint(lo, size, size=n_bytes)):
                fh.seek(int(off))
                b = fh.read(1)
                fh.seek(int(off))
                fh.write(bytes([b[0] ^ 0xFF]))
        self.n_injected_faults += 1

    # -- observability -------------------------------------------------------

    def snapshot(self) -> dict:
        return {"n_dispatches": self.n_dispatches,
                "n_injected_faults": self.n_injected_faults}


def poison_state(state, block: int, fields: tuple[str, ...] = ("C_L", "Wy")):
    """A NaN-poisoned copy of a PIC state: block ``block``'s cached factors
    are overwritten with NaN — the in-memory analogue of a machine whose
    local factors went bad (bit flips, a partial in-place update). Swapping
    this into a tenant makes every query routed at the block produce NaN
    posteriors ORGANICALLY (through the real compute path, not the output
    poisoner), which the health ladder must then detect, retire, and
    recover from via checkpoint."""
    repl = {}
    for f in fields:
        a = np.array(getattr(state, f), copy=True)
        a[int(block)] = np.nan
        repl[f] = a
    return state._replace(**repl)
