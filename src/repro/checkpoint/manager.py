"""Checkpoint manager: rotation, async save, elastic restore.

Async saves copy the (host-side) snapshot on the caller thread — cheap
relative to serialization — then write on a background thread so the training
loop isn't blocked (the paper-scale analogue: summary/optimizer state must
persist without stalling the all-reduce pipeline). Restore reshard onto any
mesh (see checkpoint/io.py).
"""
from __future__ import annotations

import pathlib
import re
import threading

import jax
import numpy as np

from repro.checkpoint import io


class CheckpointManager:
    def __init__(self, directory: str | pathlib.Path, *, keep: int = 3):
        self.dir = pathlib.Path(directory)
        self.dir.mkdir(parents=True, exist_ok=True)
        self.keep = keep
        self._thread: threading.Thread | None = None

    def _path(self, step: int) -> pathlib.Path:
        return self.dir / f"ckpt_{step:010d}.msgpack"

    def steps(self) -> list[int]:
        out = []
        for p in self.dir.glob("ckpt_*.msgpack"):
            m = re.match(r"ckpt_(\d+)\.msgpack", p.name)
            if m:
                out.append(int(m.group(1)))
        return sorted(out)

    def latest_step(self) -> int | None:
        s = self.steps()
        return s[-1] if s else None

    def save(self, step: int, tree, *, sync: bool = True) -> None:
        if sync:
            io.save(self._path(step), tree)
            self._rotate()
            return
        self.wait()
        snapshot = jax.tree.map(lambda a: np.asarray(a), tree)

        def work():
            io.save(self._path(step), snapshot)
            self._rotate()

        self._thread = threading.Thread(target=work, daemon=True)
        self._thread.start()

    def wait(self) -> None:
        if self._thread is not None:
            self._thread.join()
            self._thread = None

    def restore(self, step: int, tree_like, *, shardings=None):
        return io.load(self._path(step), tree_like, shardings=shardings)

    def restore_latest(self, tree_like, *, shardings=None):
        step = self.latest_step()
        if step is None:
            return None, None
        return step, self.restore(step, tree_like, shardings=shardings)

    def _rotate(self) -> None:
        steps = self.steps()
        for s in steps[:-self.keep]:
            self._path(s).unlink(missing_ok=True)
