"""Checkpoint serialization: msgpack manifest + raw little-endian buffers.

No orbax in this environment — this is a small, real implementation with the
properties the runtime needs: pytree-faithful (dicts/tuples/NamedTuples via
jax's flatten-with-path), atomic (write to tmp, rename), and reshardable on
restore (leaves are saved unsharded; restore device_puts against the target
mesh's NamedShardings, so checkpoints survive mesh-shape changes — the
elastic-scaling path).
"""
from __future__ import annotations

import os
import pathlib

import jax
import jax.numpy as jnp
import msgpack
import numpy as np


def _path_key(path) -> str:
    parts = []
    for k in path:
        if hasattr(k, "key"):
            parts.append(f"k:{k.key}")
        elif hasattr(k, "idx"):
            parts.append(f"i:{k.idx}")
        elif hasattr(k, "name"):
            parts.append(f"a:{k.name}")
        else:
            parts.append(f"?:{k}")
    return "/".join(parts)


def save(path: str | pathlib.Path, tree) -> None:
    path = pathlib.Path(path)
    tmp = path.with_suffix(".tmp")
    leaves = jax.tree_util.tree_flatten_with_path(tree)[0]
    with open(tmp, "wb") as f:
        header_entries = []
        blobs = []
        for p, leaf in leaves:
            arr = np.asarray(leaf)
            blobs.append(arr.tobytes())
            header_entries.append({
                "key": _path_key(p),
                "dtype": arr.dtype.str,
                "shape": list(arr.shape),
                "nbytes": len(blobs[-1]),
            })
        header = msgpack.packb(header_entries)
        f.write(len(header).to_bytes(8, "little"))
        f.write(header)
        for b in blobs:
            f.write(b)
    os.replace(tmp, path)


def load(path: str | pathlib.Path, tree_like, *, shardings=None):
    """Restore into the structure of ``tree_like``; optional pytree of
    NamedShardings reshard leaves onto the target mesh."""
    path = pathlib.Path(path)
    with open(path, "rb") as f:
        hlen = int.from_bytes(f.read(8), "little")
        header = msgpack.unpackb(f.read(hlen))
        by_key = {}
        for ent in header:
            buf = f.read(ent["nbytes"])
            by_key[ent["key"]] = np.frombuffer(
                buf, dtype=np.dtype(ent["dtype"])).reshape(ent["shape"])

    leaves_like = jax.tree_util.tree_flatten_with_path(tree_like)
    paths = [(_path_key(p), leaf) for p, leaf in leaves_like[0]]
    shard_leaves = (jax.tree.leaves(
        shardings, is_leaf=lambda x: hasattr(x, "spec"))
        if shardings is not None else [None] * len(paths))

    new_leaves = []
    for (key, like), shard in zip(paths, shard_leaves):
        if key not in by_key:
            raise KeyError(f"checkpoint missing leaf {key!r}")
        arr = by_key[key]
        if tuple(arr.shape) != tuple(like.shape):
            raise ValueError(f"{key}: shape {arr.shape} != {like.shape}")
        val = jnp.asarray(arr, dtype=like.dtype)
        if shard is not None:
            val = jax.device_put(val, shard)
        new_leaves.append(val)
    return jax.tree_util.tree_unflatten(
        jax.tree_util.tree_structure(tree_like), new_leaves)
