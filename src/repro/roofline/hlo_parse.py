"""HLO-text analysis: collective-operand bytes (cost_analysis does not report
them) and while-loop trip counts (XLA's cost analysis visits a while body
ONCE — verified empirically on this jax build — so loop-carried work must be
rescaled).

Trip counts come from the ``backend_config={"known_trip_count":{"n":...}}``
annotation XLA attaches to counted loops (condition-constant heuristic as
fallback).
"""
from __future__ import annotations

import re
from collections import defaultdict

_DTYPE_BYTES = {
    "pred": 1, "s8": 1, "u8": 1, "s16": 2, "u16": 2, "f16": 2, "bf16": 2,
    "s32": 4, "u32": 4, "f32": 4, "s64": 8, "u64": 8, "f64": 8,
    "c64": 8, "c128": 16, "s4": 1, "u4": 1, "f8e4m3fn": 1, "f8e5m2": 1,
}

COLLECTIVES = ("all-reduce", "all-gather", "reduce-scatter", "all-to-all",
               "collective-permute")

_SHAPE_RE = re.compile(r"\b([a-z][a-z0-9]*)\[([0-9,]*)\]")
_OP_RE = re.compile(
    r"=\s*((?:\([^=]*?\))|(?:[a-z][a-z0-9]*\[[^\]]*\](?:\{[^}]*\})?))\s+"
    r"(" + "|".join(COLLECTIVES) + r")(-start|-done)?\(")
_HDR_RE = re.compile(r"^(?:ENTRY\s+)?(%?[\w.\-]+)\s*\(")
_WHILE_RE = re.compile(
    r"while\(.*?condition=(%?[\w.\-]+),\s*body=(%?[\w.\-]+)")
_TRIP_RE = re.compile(r'"known_trip_count":\{"n":"(\d+)"\}')


def shape_bytes(type_str: str) -> int:
    total = 0
    for dt, dims in _SHAPE_RE.findall(type_str):
        if dt not in _DTYPE_BYTES:
            continue
        n = 1
        for d in dims.split(","):
            if d.strip():
                n *= int(d)
        total += n * _DTYPE_BYTES[dt]
    return total


def split_computations(hlo: str) -> dict[str, str]:
    """computation name -> body text (brace-depth scanner over lines)."""
    comps: dict[str, str] = {}
    cur_name, cur_lines, depth = None, [], 0
    for line in hlo.splitlines():
        stripped = line.strip()
        if cur_name is None:
            if stripped.endswith("{") and "->" in stripped:
                m = _HDR_RE.match(stripped)
                if m:
                    cur_name = m.group(1).lstrip("%")
                    cur_lines = []
                    depth = 1
            continue
        depth += stripped.count("{") - stripped.count("}")
        cur_lines.append(line)
        if depth <= 0:
            comps[cur_name] = "\n".join(cur_lines)
            cur_name = None
    return comps


def collective_ops_in(text: str):
    """Yield (op, bytes) per collective instruction (async pairs counted
    once, at the -start)."""
    for m in _OP_RE.finditer(text):
        type_str, op, async_suffix = m.group(1), m.group(2), m.group(3)
        if async_suffix == "-done":
            continue
        yield op, shape_bytes(type_str)


def _trip_counts(hlo: str) -> dict[str, int]:
    """while body computation name -> known trip count."""
    out: dict[str, int] = {}
    for line in hlo.splitlines():
        if "while(" not in line:
            continue
        m = _WHILE_RE.search(line)
        if not m:
            continue
        body = m.group(2).lstrip("%")
        t = _TRIP_RE.search(line)
        tc = int(t.group(1)) if t else 1
        out[body] = max(out.get(body, 1), tc)
    return out


def collective_bytes(hlo: str) -> dict[str, float]:
    """Total collective-operand bytes by op kind (+"total"), while-body ops
    scaled by their loop trip count."""
    comps = split_computations(hlo)
    trips = _trip_counts(hlo)
    totals: dict[str, float] = defaultdict(float)
    for name, body in comps.items():
        scale = trips.get(name, 1)
        for op, b in collective_ops_in(body):
            totals[op] += b * scale
    totals["total"] = sum(v for k, v in totals.items() if k != "total")
    return dict(totals)


def loop_flops_correction(hlo: str, comp_flops_fn=None) -> float:
    """Multiplier correcting cost_analysis FLOPs for the dominant counted
    loop. For our stacks the layer scan holds ~all FLOPs, so scaling total
    FLOPs by the scan trip count is accurate to the (tiny) non-loop part.
    Returns max trip count (1 if no loops)."""
    trips = _trip_counts(hlo)
    return float(max(trips.values())) if trips else 1.0


def trip_counts(hlo: str) -> dict[str, int]:
    return _trip_counts(hlo)
