import os
os.environ["XLA_FLAGS"] = (os.environ.get("REPRO_XLA_EXTRA", "") +
                           " --xla_force_host_platform_device_count="
                           + os.environ.get("REPRO_DEVICES", "8"))
"""Rescore saved dry-run records: fresh FLOP probes (fixing the moe_groups
probe bug without recompiling the 512-way cells) + napkin memory terms.

    PYTHONPATH=src python -m repro.roofline.rescore experiments/dryrun
"""
import json
import pathlib
import sys

from repro.configs.registry import get_config
from repro.configs.shapes import SHAPES
from repro.roofline import analysis


def main(dirpath: str, reprobe_all: bool = False):
    from repro.launch.dryrun import probe_flops
    d = pathlib.Path(dirpath)
    probe_cache: dict[tuple, float] = {}
    for p in sorted(d.glob("*.json")):
        if p.stem.startswith("gp_"):
            continue
        rec = json.load(open(p))
        if rec.get("status") != "ok" or "arch" not in rec:
            continue
        cfg = get_config(rec["arch"])
        shape = SHAPES[rec["shape"]]
        # production DP product: single 16 (of 256=16x16), multi 32 (2x16x16)
        mg = 32 if rec["mesh"] == "multi" else 16
        needs_probe = reprobe_all or bool(cfg.moe_experts)
        pf = None
        if needs_probe:
            key = (rec["arch"], rec["shape"], mg)
            if key not in probe_cache:
                probe_cache[key] = probe_flops(cfg, shape, shape.kind,
                                               moe_groups=mg)
            pf = probe_cache[key]
        new = analysis.rescore(rec, probe_flops_new=pf)
        json.dump(new, open(p, "w"), indent=1)
        print(p.stem, f"useful={new['useful_fraction']:.2f}",
              f"bottleneck={new['bottleneck']}",
              f"roofline={new['roofline_fraction']:.3f}", flush=True)


if __name__ == "__main__":
    main(sys.argv[1] if len(sys.argv) > 1 else "experiments/dryrun",
         reprobe_all="--reprobe-all" in sys.argv)
