"""Render the dry-run JSONs into the EXPERIMENTS.md roofline table."""
from __future__ import annotations

import json
import pathlib
import sys


def fmt_s(x):
    if x is None:
        return "-"
    if x >= 1:
        return f"{x:.2f}s"
    if x >= 1e-3:
        return f"{x * 1e3:.1f}ms"
    return f"{x * 1e6:.0f}us"


def load(dirpath):
    recs = []
    for p in sorted(pathlib.Path(dirpath).glob("*.json")):
        r = json.load(open(p))
        r["_name"] = p.stem
        recs.append(r)
    return recs


VARIANT_SUFFIXES = ("_gather", "_ring", "_vpad", "_puredp", "_ckv", "_bf16")


def table(dirpath, mesh="single", variants=False):
    rows = []
    hdr = ("| arch | shape | chips | t_compute | t_memory | t_coll | "
           "bottleneck | useful | roofline |")
    sep = "|" + "---|" * 9
    rows.append(hdr)
    rows.append(sep)
    for r in load(dirpath):
        is_variant = any(r["_name"].endswith(v) for v in VARIANT_SUFFIXES)
        if is_variant != variants:
            continue
        if r.get("status") == "skip":
            if r["_name"].endswith(f"_{mesh}"):
                arch, shape = r["_name"].rsplit(f"_{mesh}", 1)[0].rsplit(
                    "_", 1)
                rows.append(f"| {arch} | {shape} | - | - | - | - | SKIP "
                            f"(sub-quadratic rule) | - | - |")
            continue
        if r.get("status") != "ok" or r.get("mesh") != mesh \
                or "t_compute" not in r:
            continue
        tag = ""
        for v in VARIANT_SUFFIXES:
            if r["_name"].endswith(v):
                tag = " " + v
        rows.append(
            f"| {r['arch']}{tag} | {r['shape']} | {r['chips']} | "
            f"{fmt_s(r['t_compute'])} | {fmt_s(r['t_memory'])} | "
            f"{fmt_s(r['t_collective'])} | {r['bottleneck']} | "
            f"{r['useful_fraction']:.2f} | {r['roofline_fraction']:.3f} |")
    return "\n".join(rows)


if __name__ == "__main__":
    d = sys.argv[1] if len(sys.argv) > 1 else "experiments/dryrun"
    mesh = sys.argv[2] if len(sys.argv) > 2 else "single"
    print(table(d, mesh))
