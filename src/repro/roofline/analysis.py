"""Three-term roofline from the compiled dry-run artifact.

  compute    = HLO_FLOPs        / (chips * PEAK_FLOPS_BF16)
  memory     = HLO_bytes        / (chips * HBM_BW)
  collective = collective_bytes / (chips * ICI_BW_PER_LINK * ICI_LINKS)

Sources & caveats (CPU container, TPU target — no wall clocks):
* ``compiled.cost_analysis()`` counts a while body ONCE (verified on this
  build). FLOPs are therefore taken from an *unrolled probe* —
  ``lowered.cost_analysis()`` of the same step with the layer scan unrolled
  (no loop, no XLA compile needed; matmul FLOPs are optimization-invariant).
* HBM bytes: compiled "bytes accessed" rescaled by the probe/raw FLOP ratio
  to spread the loop body over its trip count (documented estimate — fusion
  means unoptimized byte counts would be useless).
* collective bytes: parsed from compiled HLO per computation, while bodies
  scaled by their ``known_trip_count`` (roofline/hlo_parse.py).
* MODEL_FLOPS = 6·N(active)·D for train, 2·N(active)·D per generated token
  for decode — the "useful work" yardstick; ratio to HLO FLOPs exposes
  remat/dispatch waste.
"""
from __future__ import annotations

import dataclasses
import json
from repro.roofline import hlo_parse, hw


@dataclasses.dataclass
class Roofline:
    arch: str
    shape: str
    mesh: str
    chips: int
    # raw measurements
    hlo_flops: float              # probe (exact, unrolled)
    hlo_flops_raw: float          # compiled cost_analysis (loop body x1)
    hlo_bytes: float              # rescaled estimate (see module docstring)
    collective: dict
    model_flops: float
    bytes_per_device: float       # peak HBM from memory_analysis
    napkin_bytes_est: float = 0.0  # fusion-aware analytic HBM traffic
    # derived terms (seconds)
    t_compute: float = 0.0
    t_memory: float = 0.0         # napkin (headline; see docstring)
    t_memory_hlo_upper: float = 0.0  # CPU-HLO derived upper bound
    t_collective: float = 0.0
    bottleneck: str = ""
    useful_fraction: float = 0.0  # MODEL_FLOPS / HLO_FLOPs
    roofline_fraction: float = 0.0  # MODEL_FLOPS-time / dominant-term time

    def finalize(self) -> "Roofline":
        chips = self.chips
        self.t_compute = self.hlo_flops / (chips * hw.PEAK_FLOPS_BF16)
        self.t_memory = self.napkin_bytes_est / (chips * hw.HBM_BW)
        self.t_memory_hlo_upper = self.hlo_bytes / (chips * hw.HBM_BW)
        self.t_collective = self.collective.get("total", 0.0) / (
            chips * hw.ICI_BW_PER_LINK * hw.ICI_LINKS)
        terms = {"compute": self.t_compute, "memory": self.t_memory,
                 "collective": self.t_collective}
        self.bottleneck = max(terms, key=terms.get)
        self.useful_fraction = (self.model_flops / self.hlo_flops
                                if self.hlo_flops else 0.0)
        t_ideal = self.model_flops / (chips * hw.PEAK_FLOPS_BF16)
        t_dom = max(terms.values())
        self.roofline_fraction = t_ideal / t_dom if t_dom else 0.0
        return self

    def to_json(self) -> dict:
        return dataclasses.asdict(self)


def napkin_bytes(cfg, shape, *, ring_cache: bool = False,
                 param_bytes_each: float = 4.0) -> float:
    """Fusion-aware analytic HBM traffic per step (global bytes).

    The CPU-compiled "bytes accessed" is not representative of TPU traffic
    (no bf16 fusion, remat recompute double-counted, loop rescale smears
    non-loop bytes), so the headline memory term uses this napkin model and
    the HLO figure is kept as an upper bound. Coefficients:

    train:   params * 32 B  (f32 read fwd + read bwd + Adam p/m/v r+w)
             + tokens*d*L*60 B  (bf16 activations fwd+bwd incl. remat ~1.5x)
             + tokens*V*8 B     (f32 logits write + bwd read)
    prefill: params * param_bytes_each + tokens*d*L*20 B + tokens*V*4 B
    decode:  params * param_bytes_each + KV/SSM state traffic + logits.
             ``ring_cache=True`` models the ring-buffer windowed cache
             (reads min(T, window) instead of T for windowed layers).
    """
    counts = cfg.param_counts()
    P_tot = counts["total"]
    B, T = shape.global_batch, shape.seq_len
    tokens = B * T
    d, L, V = cfg.d_model, cfg.n_layers, cfg.vocab

    if shape.kind == "train":
        return P_tot * 32.0 + tokens * d * L * 60.0 + tokens * V * 8.0
    if shape.kind == "prefill":
        return (P_tot * param_bytes_each + tokens * d * L * 20.0
                + tokens * V * 4.0)
    # decode: one token per sequence
    cache = 0.0
    for desc in cfg.plan():
        if desc.kind == "attn":
            eff = min(T, desc.window) if (desc.window and ring_cache) else T
            cache += B * cfg.n_kv_heads * eff * cfg.head_dim * 2 * 2.0
        elif cfg.ssm_state:
            d_inner = cfg.ssm_expand * d
            H = d_inner // cfg.ssm_headdim
            cache += 2.0 * B * H * cfg.ssm_headdim * cfg.ssm_state * 4.0
    if cfg.enc_dec:
        cache += B * cfg.enc_seq * d * 2.0 * cfg.n_layers  # cross-attn reads
    return P_tot * param_bytes_each + cache + B * V * 4.0


def model_flops(cfg, shape, last_logits: bool = False) -> float:
    """Analytic useful FLOPs for the cell (per step).

    train: 6 * N_active * tokens  (fwd 2x + bwd 4x)
    prefill: 2 * N_active * tokens (+ attention quadratic term)
    decode: 2 * N_active * batch   (one token per sequence; attention term
            counts the KV-cache dot products)
    """
    counts = cfg.param_counts()
    n_act = counts["active"] - counts.get("encoder", 0)
    n_enc = counts.get("encoder", 0)
    B, T = shape.global_batch, shape.seq_len

    # attention FLOPs (QK^T + PV): 4 * tokens * ctx * d_head * heads,
    # windowed layers use min(ctx, window)
    def attn_flops(tokens_per_seq, ctx_len):
        total = 0.0
        for desc in cfg.plan():
            if desc.kind != "attn":
                continue
            eff = min(ctx_len, desc.window) if desc.window else ctx_len
            total += 4.0 * tokens_per_seq * eff * cfg.head_dim * cfg.n_heads
        return total * B

    if shape.kind == "train":
        return (6.0 * n_act * B * T + 3.0 * attn_flops(T, T / 2)
                + 6.0 * n_enc * B * cfg.enc_seq)
    if shape.kind == "prefill":
        emb = counts.get("embedding", 0)
        if last_logits:   # unembed runs for one position per sequence
            return (2.0 * (n_act - emb / 2) * B * T + attn_flops(T, T / 2)
                    + 2.0 * n_enc * B * cfg.enc_seq + emb * B)
        return (2.0 * n_act * B * T + attn_flops(T, T / 2)
                + 2.0 * n_enc * B * cfg.enc_seq)
    # decode: one new token against a T-long cache (encoder already ran)
    return 2.0 * n_act * B * 1 + attn_flops(1, T)


def analyze(arch, shape_name, mesh_name, *, chips, compiled, probe_lowered,
            cfg, shape, bytes_per_device, ring_cache=False,
            param_bytes_each=4.0, last_logits=False) -> Roofline:
    ca = compiled.cost_analysis() or {}
    raw_flops = float(ca.get("flops", 0.0))
    raw_bytes = float(ca.get("bytes accessed", 0.0))
    probe_ca = probe_lowered.cost_analysis() or {}
    probe_flops = float(probe_ca.get("flops", raw_flops))
    # spread loop-once bytes over trips proportionally to the flops ratio
    ratio = probe_flops / raw_flops if raw_flops else 1.0
    est_bytes = raw_bytes * max(ratio, 1.0)
    coll = hlo_parse.collective_bytes(compiled.as_text())
    return Roofline(
        arch=arch, shape=shape_name, mesh=mesh_name, chips=chips,
        hlo_flops=probe_flops, hlo_flops_raw=raw_flops, hlo_bytes=est_bytes,
        collective=coll,
        model_flops=model_flops(cfg, shape, last_logits=last_logits),
        bytes_per_device=bytes_per_device,
        napkin_bytes_est=napkin_bytes(
            cfg, shape, ring_cache=ring_cache,
            param_bytes_each=param_bytes_each)).finalize()


def rescore(rec: dict, *, probe_flops_new: float | None = None,
            ring_cache: bool = False) -> dict:
    """Recompute derived terms of a saved dry-run record (no recompile):
    fresh probe FLOPs (if given) + napkin memory terms."""
    from repro.configs.registry import get_config
    from repro.configs.shapes import SHAPES
    cfg = get_config(rec["arch"])
    shape = SHAPES[rec["shape"]]
    raw_flops = rec["hlo_flops_raw"]
    pf = probe_flops_new if probe_flops_new is not None else rec["hlo_flops"]
    ratio = pf / raw_flops if raw_flops else 1.0
    raw_bytes = rec.get("cost_analysis", {}).get("bytes accessed",
                                                 rec["hlo_bytes"])
    roof = Roofline(
        arch=rec["arch"], shape=rec["shape"], mesh=rec["mesh"],
        chips=rec["chips"], hlo_flops=pf, hlo_flops_raw=raw_flops,
        hlo_bytes=raw_bytes * max(ratio, 1.0), collective=rec["collective"],
        model_flops=model_flops(cfg, shape),
        bytes_per_device=rec["bytes_per_device"],
        napkin_bytes_est=napkin_bytes(cfg, shape,
                                      ring_cache=ring_cache)).finalize()
    out = dict(rec)
    out.update(roof.to_json())
    return out


def save(results: list[Roofline], path: str):
    with open(path, "w") as f:
        json.dump([r.to_json() for r in results], f, indent=1)
