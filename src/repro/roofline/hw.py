"""TPU v5e hardware constants (the TARGET platform of this framework;
the container executes on CPU, so these feed the analytical roofline)."""

PEAK_FLOPS_BF16 = 197e12      # per chip, bf16
HBM_BW = 819e9                # bytes/s per chip
ICI_BW_PER_LINK = 50e9        # bytes/s per link (~400 Gbps x dirs)
ICI_LINKS = 4                 # torus links usable per chip (2D torus: 4)
VMEM_BYTES = 128 * 1024**2    # ~128 MiB vector memory
HBM_BYTES = 16 * 1024**3      # 16 GiB per chip
MXU_DIM = 128                 # systolic array tile edge
