"""Shared transformer building blocks (pure functions + explicit params).

Param trees use descriptive leaf names; parallel/sharding.py assigns
PartitionSpecs by name convention (e.g. "*/w_in" -> shard d_ff on "model").
All matmuls cast to the compute dtype (bf16 on TPU) with f32 params.
"""
from __future__ import annotations

import jax
import jax.numpy as jnp


def rms_norm(x, weight, eps: float = 1e-6):
    x32 = x.astype(jnp.float32)
    inv = jax.lax.rsqrt(jnp.mean(x32 * x32, axis=-1, keepdims=True) + eps)
    out = x32 * inv
    if weight is not None:
        out = out * (1.0 + weight.astype(jnp.float32))
    return out.astype(x.dtype)


def layer_norm(x, weight, bias, eps: float = 1e-5):
    """Non-parametric when weight/bias are None (OLMo)."""
    x32 = x.astype(jnp.float32)
    mu = jnp.mean(x32, axis=-1, keepdims=True)
    var = jnp.var(x32, axis=-1, keepdims=True)
    out = (x32 - mu) * jax.lax.rsqrt(var + eps)
    if weight is not None:
        out = out * weight.astype(jnp.float32)
    if bias is not None:
        out = out + bias.astype(jnp.float32)
    return out.astype(x.dtype)


def make_norm(cfg):
    """Returns (init_fn(key) -> params|None, apply_fn(x, params))."""
    if cfg.nonparametric_ln:
        return (lambda key: None,
                lambda x, p: layer_norm(x, None, None, cfg.norm_eps))
    return (lambda key: jnp.zeros((cfg.d_model,), jnp.float32),
            lambda x, p: rms_norm(x, p, cfg.norm_eps))


# ---------------------------------------------------------------------------
# Rotary embeddings (standard + M-RoPE)
# ---------------------------------------------------------------------------

def rope_freqs(head_dim: int, theta: float) -> jax.Array:
    return 1.0 / (theta ** (jnp.arange(0, head_dim, 2, dtype=jnp.float32)
                            / head_dim))


def apply_rope(x, positions, theta: float = 1e4):
    """x: (B, H, T, D); positions: (B, T) int."""
    D = x.shape[-1]
    freqs = rope_freqs(D, theta)                          # (D/2,)
    angles = positions[:, None, :, None].astype(jnp.float32) * freqs
    cos, sin = jnp.cos(angles), jnp.sin(angles)           # (B, 1, T, D/2)
    x1, x2 = jnp.split(x.astype(jnp.float32), 2, axis=-1)
    out = jnp.concatenate([x1 * cos - x2 * sin, x2 * cos + x1 * sin], -1)
    return out.astype(x.dtype)


def apply_mrope(x, positions3, theta: float, sections=(16, 24, 24)):
    """Multimodal RoPE (Qwen2-VL): the D/2 frequency slots are split into
    (temporal, height, width) sections, each rotated by its own position id.
    positions3: (B, 3, T). For pure text all three ids coincide, which makes
    M-RoPE reduce to standard RoPE (verified in tests)."""
    D = x.shape[-1]
    freqs = rope_freqs(D, theta)                          # (D/2,)
    sec = jnp.cumsum(jnp.asarray((0,) + tuple(sections)))
    slot = jnp.arange(D // 2)
    which = jnp.clip(jnp.searchsorted(sec, slot, side="right") - 1, 0, 2)
    pos = positions3.astype(jnp.float32)[:, which, :]    # (B, D/2, T)
    angles = jnp.swapaxes(pos, 1, 2)[:, None, :, :] * freqs[None, None, None]
    cos, sin = jnp.cos(angles), jnp.sin(angles)           # (B, 1, T, D/2)
    x1, x2 = jnp.split(x.astype(jnp.float32), 2, axis=-1)
    out = jnp.concatenate([x1 * cos - x2 * sin, x2 * cos + x1 * sin], -1)
    return out.astype(x.dtype)


# ---------------------------------------------------------------------------
# MLP (SwiGLU)
# ---------------------------------------------------------------------------

def init_mlp(key, d_model: int, d_ff: int, dtype=jnp.float32):
    k1, k2, k3 = jax.random.split(key, 3)
    s_in = d_model ** -0.5
    s_out = d_ff ** -0.5
    return {
        "w_gate": jax.random.normal(k1, (d_model, d_ff), dtype) * s_in,
        "w_in": jax.random.normal(k2, (d_model, d_ff), dtype) * s_in,
        "w_out": jax.random.normal(k3, (d_ff, d_model), dtype) * s_out,
    }


def mlp(params, x, compute_dtype=jnp.bfloat16):
    xc = x.astype(compute_dtype)
    g = xc @ params["w_gate"].astype(compute_dtype)
    h = xc @ params["w_in"].astype(compute_dtype)
    y = (jax.nn.silu(g.astype(jnp.float32)).astype(compute_dtype) * h)
    return (y @ params["w_out"].astype(compute_dtype)).astype(x.dtype)


# ---------------------------------------------------------------------------
# Embedding / unembedding
# ---------------------------------------------------------------------------

def init_embed(key, vocab: int, d_model: int, tie: bool,
               dtype=jnp.float32):
    k1, k2 = jax.random.split(key)
    p = {"tok": jax.random.normal(k1, (vocab, d_model), dtype) * 0.02}
    if not tie:
        p["unembed"] = jax.random.normal(k2, (d_model, vocab),
                                         dtype) * d_model ** -0.5
    return p


def embed(params, tokens):
    return jnp.take(params["tok"], tokens, axis=0)


def unembed(params, x, compute_dtype=jnp.bfloat16, n_valid: int | None = None):
    w = params.get("unembed")
    if w is None:
        w = params["tok"].T
    logits = (x.astype(compute_dtype)
              @ w.astype(compute_dtype)).astype(jnp.float32)
    if n_valid is not None and n_valid < logits.shape[-1]:
        # vocab rows beyond n_valid are table padding (see configs.base)
        col = jnp.arange(logits.shape[-1])
        logits = jnp.where(col >= n_valid, -1e30, logits)
    return logits
