"""Generic LM stack: decoder-only, hybrid SSM/attention, MoE interleaves,
and encoder-decoder — driven entirely by ModelConfig.layer_pattern.

Layer parameters are stacked per pattern-position and scanned over periods
(jax.lax.scan) so the lowered HLO contains each distinct layer body once —
this keeps 80-layer dry-run compiles fast and is remat-friendly. The
remainder (n_layers % period) is unrolled.
"""
from __future__ import annotations

from typing import Any, NamedTuple

import jax
import jax.numpy as jnp

from repro.models import attention as attn
from repro.models import layers, moe, ssm
from repro.configs.base import LayerDesc, ModelConfig


class Aux(NamedTuple):
    moe_loss: jax.Array
    dropped: jax.Array


# ---------------------------------------------------------------------------
# init
# ---------------------------------------------------------------------------

def init_layer(key, cfg: ModelConfig, desc: LayerDesc, *,
               cross: bool = False, dtype=jnp.float32):
    norm_init, _ = layers.make_norm(cfg)
    ks = jax.random.split(key, 6)
    p: dict[str, Any] = {"ln1": norm_init(ks[0]), "ln2": norm_init(ks[1])}
    if desc.kind == "attn":
        p["attn"] = attn.init_attn(ks[2], cfg, dtype=dtype)
    else:
        p["ssm"] = ssm.init_ssm(ks[2], cfg, dtype=dtype)
    if cross:
        p["ln_x"] = norm_init(ks[3])
        p["cross"] = attn.init_attn(ks[4], cfg, cross=True, dtype=dtype)
    if desc.moe:
        p["moe"] = moe.init_moe(ks[5], cfg.d_model, cfg.moe_d_ff,
                                cfg.moe_experts, dtype=dtype)
    elif cfg.d_ff > 0:
        p["mlp"] = layers.init_mlp(ks[5], cfg.d_model, cfg.d_ff, dtype=dtype)
    else:
        del p["ln2"]   # pure-mixer block (Mamba-2): no FFN sub-block
    return p


def _split_plan(cfg: ModelConfig):
    plan = cfg.plan()
    period = cfg.period
    n_full = len(plan) // period
    rest = plan[n_full * period:]
    return plan, period, n_full, rest


def init_model(key, cfg: ModelConfig, dtype=jnp.float32):
    plan, period, n_full, rest = _split_plan(cfg)
    k_emb, k_stack, k_rest, k_fin, k_enc = jax.random.split(key, 5)
    params: dict[str, Any] = {
        "embed": layers.init_embed(k_emb, cfg.vocab_padded, cfg.d_model,
                                   cfg.tie_embeddings, dtype=dtype),
    }
    cross = cfg.enc_dec
    if n_full:
        stacked = []
        for pos in range(period):
            keys = jax.random.split(jax.random.fold_in(k_stack, pos), n_full)
            stacked.append(jax.vmap(
                lambda k: init_layer(k, cfg, cfg.layer_pattern[pos],
                                     cross=cross, dtype=dtype))(keys))
        params["stack"] = tuple(stacked)
    params["rest"] = tuple(
        init_layer(jax.random.fold_in(k_rest, i), cfg, desc, cross=cross,
                   dtype=dtype)
        for i, desc in enumerate(rest))
    norm_init, _ = layers.make_norm(cfg)
    params["final_norm"] = norm_init(k_fin)
    if cfg.enc_dec:
        enc_desc = LayerDesc(kind="attn", window=None, moe=False)
        keys = jax.random.split(k_enc, cfg.enc_layers)
        params["encoder"] = jax.vmap(
            lambda k: init_layer(k, cfg, enc_desc, dtype=dtype))(keys)
        params["enc_norm"] = norm_init(jax.random.fold_in(k_enc, 1))
    return params


# ---------------------------------------------------------------------------
# layer application (shared by train/prefill and decode)
# ---------------------------------------------------------------------------

def apply_layer(p, x, cfg: ModelConfig, desc: LayerDesc, *, positions=None,
                enc_kv=None, causal=True, attn_impl="auto",
                moe_groups: int = 1, compute_dtype=jnp.bfloat16):
    _, norm = layers.make_norm(cfg)
    h = norm(x, p["ln1"])
    if desc.kind == "attn":
        h = attn.attend(p["attn"], h, cfg, window=desc.window,
                        positions=positions, causal=causal,
                        compute_dtype=compute_dtype, attn_impl=attn_impl)
    else:
        h = ssm.ssm_mixer(p["ssm"], h, cfg, compute_dtype=compute_dtype)
    x = x + h
    if enc_kv is not None and "cross" in p:
        x = x + attn.attend_cross(p["cross"], norm(x, p["ln_x"]), enc_kv,
                                  cfg, compute_dtype=compute_dtype)
    zero_aux = Aux(jnp.zeros((), jnp.float32), jnp.zeros((), jnp.float32))
    if "ln2" not in p:                       # pure-mixer block (no FFN)
        return x, zero_aux
    h2 = norm(x, p["ln2"])
    if desc.moe:
        y, aux = moe.moe_ffn(p["moe"], h2, top_k=cfg.moe_top_k,
                             capacity_factor=cfg.capacity_factor,
                             n_groups=moe_groups, dispatch=cfg.moe_dispatch,
                             compute_dtype=compute_dtype)
        aux = Aux(aux.load_balance_loss, aux.dropped_fraction)
    else:
        y = layers.mlp(p["mlp"], h2, compute_dtype=compute_dtype)
        aux = zero_aux
    return x + y, aux


def apply_layer_decode(p, x, cache, cfg: ModelConfig, desc: LayerDesc, *,
                       enc_kv=None, cross_kv=None, moe_groups: int = 1,
                       compute_dtype=jnp.bfloat16):
    _, norm = layers.make_norm(cfg)
    h = norm(x, p["ln1"])
    if desc.kind == "attn":
        h, cache = attn.attend_decode(p["attn"], h, cfg, cache,
                                      window=desc.window,
                                      compute_dtype=compute_dtype)
    else:
        h, cache = ssm.ssm_decode(p["ssm"], h, cfg, cache,
                                  compute_dtype=compute_dtype)
    x = x + h
    if (enc_kv is not None or cross_kv is not None) and "cross" in p:
        x = x + attn.attend_cross(p["cross"], norm(x, p["ln_x"]), enc_kv,
                                  cfg, compute_dtype=compute_dtype,
                                  kv=cross_kv)
    if "ln2" not in p:                       # pure-mixer block (no FFN)
        return x, cache
    h2 = norm(x, p["ln2"])
    if desc.moe:
        y, _ = moe.moe_ffn(p["moe"], h2, top_k=cfg.moe_top_k,
                           capacity_factor=cfg.capacity_factor,
                           n_groups=moe_groups, dispatch=cfg.moe_dispatch,
                           compute_dtype=compute_dtype)
    else:
        y = layers.mlp(p["mlp"], h2, compute_dtype=compute_dtype)
    return x + y, cache


# ---------------------------------------------------------------------------
# forward (train / prefill)
# ---------------------------------------------------------------------------

def encode(params, frames, cfg: ModelConfig, *, attn_impl="auto",
           compute_dtype=jnp.bfloat16):
    """Encoder for enc-dec models. ``frames``: precomputed frontend
    embeddings (B, Te, d) — the conv frontend is a stub per the brief."""
    _, norm = layers.make_norm(cfg)
    x = frames

    def body(x, p):
        h = norm(x, p["ln1"])
        h = attn.attend(p["attn"], h, cfg, causal=False, use_rope=True,
                        compute_dtype=compute_dtype, attn_impl="jnp")
        x = x + h
        y = layers.mlp(p["mlp"], norm(x, p["ln2"]),
                       compute_dtype=compute_dtype)
        return x + y, None

    x, _ = jax.lax.scan(body, x, params["encoder"])
    return norm(x, params["enc_norm"])


def forward(params, tokens, cfg: ModelConfig, *, positions=None,
            enc_kv=None, inputs_embeds=None, attn_impl="auto",
            compute_dtype=jnp.bfloat16, remat: bool = False,
            remat_policy=None, moe_groups: int = 1,
            unroll_scan: bool = False, logits_last_only: bool = False):
    """Returns (logits (B,T,V) f32, Aux). ``logits_last_only`` computes the
    unembed for the final position only (serving prefill — §Perf: the
    full-sequence unembed dominates prefill FLOPs for large vocabularies).

    ``unroll_scan=True`` replaces the period scan with a Python loop — used
    by the roofline probe (exact FLOP counting on unoptimized HLO; XLA's
    cost analysis visits a while body once, an unrolled module has no loop).

    ``remat=True`` rematerializes each scanned period (activation
    checkpointing): memory per layer-period drops to the carried residual
    stream; ``remat_policy`` (e.g. jax.checkpoint_policies
    .dots_with_no_batch_dims_saveable) trades recompute for saved matmuls.
    """
    plan, period, n_full, rest = _split_plan(cfg)
    x = (inputs_embeds if inputs_embeds is not None
         else layers.embed(params["embed"], tokens)).astype(compute_dtype)
    B, T, _ = x.shape
    if positions is None:
        positions = jnp.broadcast_to(jnp.arange(T)[None], (B, T))

    aux0 = Aux(jnp.zeros((), jnp.float32), jnp.zeros((), jnp.float32))

    def period_body(carry, per_period):
        x, aux = carry
        for pos in range(period):
            p = jax.tree.map(lambda a: a, per_period[pos])
            x, a = apply_layer(p, x, cfg, cfg.layer_pattern[pos],
                               positions=positions, enc_kv=enc_kv,
                               attn_impl=attn_impl, moe_groups=moe_groups,
                               compute_dtype=compute_dtype)
            aux = Aux(aux.moe_loss + a.moe_loss, aux.dropped + a.dropped)
        return (x, aux), None

    if n_full and unroll_scan:
        carry = (x, aux0)
        for i in range(n_full):
            sl = jax.tree.map(lambda a: a[i], params["stack"])
            carry, _ = period_body(carry, sl)
        x, aux = carry
    elif n_full:
        body = period_body
        if remat:
            body = jax.checkpoint(period_body, policy=remat_policy,
                                  prevent_cse=False)
        (x, aux), _ = jax.lax.scan(body, (x, aux0), params["stack"])
    else:
        aux = aux0
    for i, desc in enumerate(rest):
        x, a = apply_layer(params["rest"][i], x, cfg, desc,
                           positions=positions, enc_kv=enc_kv,
                           attn_impl=attn_impl, moe_groups=moe_groups,
                           compute_dtype=compute_dtype)
        aux = Aux(aux.moe_loss + a.moe_loss, aux.dropped + a.dropped)

    _, norm = layers.make_norm(cfg)
    x = norm(x, params["final_norm"])
    if logits_last_only:
        x = x[:, -1:, :]
    logits = layers.unembed(params["embed"], x, compute_dtype=compute_dtype,
                            n_valid=cfg.vocab)
    n_moe = max(sum(d.moe for d in plan), 1)
    return logits, Aux(aux.moe_loss / n_moe, aux.dropped / n_moe)


# ---------------------------------------------------------------------------
# serving (single-token decode with caches)
# ---------------------------------------------------------------------------

class ServeState(NamedTuple):
    stack_caches: Any     # tuple per pattern-position of stacked caches
    rest_caches: Any      # tuple per remainder layer
    enc_kv: Any           # encoder output (enc-dec) or None
    cross_kv: Any = None  # precomputed per-layer cross K/V (§Perf) or None


def _init_cache_for(cfg, desc: LayerDesc, batch: int, max_len: int,
                    dtype=jnp.bfloat16, ring_cache: bool = False):
    if desc.kind == "attn":
        if ring_cache and desc.window is not None:
            max_len = min(max_len, desc.window)   # ring buffer (§Perf)
        return attn.init_cache(cfg, batch, max_len, dtype=dtype)
    return ssm.init_state(cfg, batch, conv_dtype=dtype)


def init_serve(cfg: ModelConfig, batch: int, max_len: int,
               enc_kv=None, cache_dtype=jnp.bfloat16,
               ring_cache: bool = False) -> ServeState:
    plan, period, n_full, rest = _split_plan(cfg)
    stack_caches = tuple(
        jax.tree.map(
            lambda a: jnp.broadcast_to(a[None], (n_full,) + a.shape).copy(),
            _init_cache_for(cfg, cfg.layer_pattern[pos], batch, max_len,
                            cache_dtype, ring_cache))
        for pos in range(period)) if n_full else ()
    rest_caches = tuple(_init_cache_for(cfg, d, batch, max_len, cache_dtype,
                                        ring_cache)
                        for d in rest)
    return ServeState(stack_caches, rest_caches, enc_kv)


def precompute_cross_kv(params, enc_kv, cfg: ModelConfig,
                        compute_dtype=jnp.bfloat16):
    """Per-layer encoder K/V for an enc-dec serve session (§Perf): call
    once after ``encode`` and attach via ``state._replace(cross_kv=...,
    enc_kv=None)`` — decode then never re-projects the encoder states."""
    plan, period, n_full, rest = _split_plan(cfg)
    stack = tuple(
        jax.vmap(lambda p: attn.project_cross_kv(p["cross"], enc_kv, cfg,
                                                 compute_dtype))(
            params["stack"][pos])
        for pos in range(period)) if n_full else ()
    rest_kv = tuple(
        attn.project_cross_kv(params["rest"][i]["cross"], enc_kv, cfg,
                              compute_dtype)
        for i in range(len(rest)))
    return stack, rest_kv


def decode_step(params, token, state: ServeState, cfg: ModelConfig, *,
                moe_groups: int = 1, compute_dtype=jnp.bfloat16):
    """token: (B, 1) int32 -> (logits (B,1,V), new state)."""
    plan, period, n_full, rest = _split_plan(cfg)
    x = layers.embed(params["embed"], token).astype(compute_dtype)

    has_ckv = state.cross_kv is not None

    def period_body(x, xs):
        if has_ckv:
            per_params, per_caches, per_ckv = xs
        else:
            per_params, per_caches = xs
            per_ckv = None
        new_caches = []
        for pos in range(period):
            ckv = per_ckv[pos] if has_ckv else None
            x, c = apply_layer_decode(per_params[pos], x, per_caches[pos],
                                      cfg, cfg.layer_pattern[pos],
                                      enc_kv=state.enc_kv, cross_kv=ckv,
                                      moe_groups=moe_groups,
                                      compute_dtype=compute_dtype)
            new_caches.append(c)
        return x, tuple(new_caches)

    if n_full:
        xs = ((params["stack"], state.stack_caches, state.cross_kv[0])
              if has_ckv else (params["stack"], state.stack_caches))
        x, new_stack = jax.lax.scan(period_body, x, xs)
    else:
        new_stack = ()
    new_rest = []
    for i, desc in enumerate(rest):
        ckv = state.cross_kv[1][i] if has_ckv else None
        x, c = apply_layer_decode(params["rest"][i], x,
                                  state.rest_caches[i], cfg, desc,
                                  enc_kv=state.enc_kv, cross_kv=ckv,
                                  moe_groups=moe_groups,
                                  compute_dtype=compute_dtype)
        new_rest.append(c)

    _, norm = layers.make_norm(cfg)
    x = norm(x, params["final_norm"])
    logits = layers.unembed(params["embed"], x, compute_dtype=compute_dtype,
                            n_valid=cfg.vocab)
    return logits, ServeState(new_stack, tuple(new_rest), state.enc_kv,
                              state.cross_kv)


# ---------------------------------------------------------------------------
# loss
# ---------------------------------------------------------------------------

def lm_loss(params, tokens, labels, cfg: ModelConfig, *, enc_kv=None,
            inputs_embeds=None, attn_impl="auto", moe_loss_weight=0.01,
            compute_dtype=jnp.bfloat16, remat: bool = False,
            remat_policy=None, moe_groups: int = 1):
    logits, aux = forward(params, tokens, cfg, enc_kv=enc_kv,
                          inputs_embeds=inputs_embeds, attn_impl=attn_impl,
                          compute_dtype=compute_dtype, remat=remat,
                          remat_policy=remat_policy, moe_groups=moe_groups)
    logp = jax.nn.log_softmax(logits.astype(jnp.float32), axis=-1)
    nll = -jnp.take_along_axis(logp, labels[..., None], axis=-1)[..., 0]
    loss = jnp.mean(nll) + moe_loss_weight * aux.moe_loss
    return loss, aux
