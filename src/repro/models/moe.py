"""Mixture-of-Experts FFN with top-k routing (Mixtral / Qwen3-MoE / Jamba).

Dispatch uses the GShard/Mesh-TF einsum formulation: a (tokens, E, C)
dispatch tensor turns routing into dot-products, which GSPMD shards cleanly —
tokens on ("pod","data"), experts on "model" — lowering to the expected
all-to-all pair on the mesh. Capacity drops overflow tokens (counted in the
aux outputs); the load-balancing auxiliary loss follows Shazeer et al.
"""
from __future__ import annotations

from typing import NamedTuple

import jax
import jax.numpy as jnp


class MoEAux(NamedTuple):
    load_balance_loss: jax.Array
    dropped_fraction: jax.Array


def init_moe(key, d_model: int, d_ff: int, n_experts: int,
             dtype=jnp.float32):
    ks = jax.random.split(key, 4)
    s_in, s_out = d_model ** -0.5, d_ff ** -0.5
    return {
        "router": jax.random.normal(ks[0], (d_model, n_experts), dtype) * s_in,
        "w_gate": jax.random.normal(ks[1], (n_experts, d_model, d_ff),
                                    dtype) * s_in,
        "w_in": jax.random.normal(ks[2], (n_experts, d_model, d_ff),
                                  dtype) * s_in,
        "w_out": jax.random.normal(ks[3], (n_experts, d_ff, d_model),
                                   dtype) * s_out,
    }


def moe_ffn(params, x, *, top_k: int, capacity_factor: float = 1.25,
            n_groups: int = 1, dispatch: str = "einsum",
            compute_dtype=jnp.bfloat16):
    """x: (B, T, d) -> (y, MoEAux).

    ``n_groups`` is the GShard routing-group count — set to the number of
    data shards so capacity/dispatch are per-group: the dispatch tensor is
    (G, n, E, c) with n = tokens per group, which shards as (1, n, E, c) per
    device instead of a global (N, E, C) monster. The group dim carries the
    all-to-all to expert-sharded weights.

    ``dispatch``:
      * "einsum" — GShard one-hot dispatch/combine einsums. Robustly
        shardable, but burns 2*G*n*E*C*d MAC-FLOPs per layer on one-hot
        matmuls (the §Perf baseline showed this dominating MoE compute:
        useful fraction 0.04 for qwen3-moe).
      * "gather" — sort-based dispatch: argsort by expert, scatter-add into
        (E*C, d) buffers, gather back. Zero matmul FLOPs for routing; the
        data movement is O(n*k*d) memory traffic instead.
    """
    B, T, d = x.shape
    E = params["router"].shape[1]
    N = B * T
    G = n_groups if N % n_groups == 0 else 1
    n = N // G
    tokens = x.reshape(G, n, d)
    C = max(int(n * top_k / E * capacity_factor), top_k)

    logits = (tokens.astype(jnp.float32)
              @ params["router"].astype(jnp.float32))        # (G, n, E)
    probs = jax.nn.softmax(logits, axis=-1)
    gate_vals, gate_idx = jax.lax.top_k(probs, top_k)        # (G, n, k)
    gate_vals = gate_vals / jnp.sum(gate_vals, -1, keepdims=True)

    if dispatch == "gather":
        return _moe_gather(params, tokens, probs, gate_vals, gate_idx,
                           B=B, T=T, d=d, E=E, C=C, top_k=top_k,
                           compute_dtype=compute_dtype)

    # GShard position assignment within each group, k-major priority
    eh = jax.nn.one_hot(gate_idx, E, dtype=jnp.int32)        # (G, n, k, E)
    ehf = eh.transpose(0, 2, 1, 3).reshape(G, top_k * n, E)  # k-major
    pos = jnp.cumsum(ehf, axis=1) - 1                        # (G, kn, E)
    pos = (pos * ehf).sum(-1).reshape(G, top_k, n).transpose(0, 2, 1)
    in_cap = (pos < C) & (gate_vals > 0)                     # (G, n, k)

    # dispatch/combine tensors (G, n, E, C)
    disp = (jax.nn.one_hot(gate_idx, E, dtype=compute_dtype)[..., None]
            * jax.nn.one_hot(pos, C, dtype=compute_dtype)[..., None, :]
            * in_cap[..., None, None].astype(compute_dtype))  # (G,n,k,E,C)
    combine = (disp * gate_vals[..., None, None].astype(compute_dtype)
               ).sum(2)                                       # (G, n, E, C)
    disp = disp.sum(2)                                        # (G, n, E, C)

    # dispatch: (G,n,E,C)x(G,n,d) -> (E,G,C,d); contracting with E-sharded
    # expert weights makes GSPMD emit the canonical all-to-all pair
    xe = jnp.einsum("gnec,gnd->egcd", disp,
                    tokens.astype(compute_dtype))             # (E, G, C, d)
    g = jnp.einsum("egcd,edf->egcf", xe,
                   params["w_gate"].astype(compute_dtype))
    h = jnp.einsum("egcd,edf->egcf", xe,
                   params["w_in"].astype(compute_dtype))
    act = jax.nn.silu(g.astype(jnp.float32)).astype(compute_dtype) * h
    ye = jnp.einsum("egcf,efd->egcd", act,
                    params["w_out"].astype(compute_dtype))    # (E, G, C, d)
    y = jnp.einsum("gnec,egcd->gnd", combine, ye)

    # aux: load-balance loss + drop rate (global means)
    me = jnp.mean(probs, axis=(0, 1))                         # (E,)
    ce = jnp.mean(eh[:, :, 0].astype(jnp.float32), axis=(0, 1))
    lb = E * jnp.sum(me * ce)
    dropped = 1.0 - jnp.mean(in_cap.astype(jnp.float32))
    return y.reshape(B, T, d).astype(x.dtype), MoEAux(lb, dropped)


def _expert_ffn(params, xe, compute_dtype):
    """xe: (E, ..., d) -> (E, ..., d) via stacked expert SwiGLU."""
    g = jnp.einsum("e...d,edf->e...f", xe,
                   params["w_gate"].astype(compute_dtype))
    h = jnp.einsum("e...d,edf->e...f", xe,
                   params["w_in"].astype(compute_dtype))
    act = jax.nn.silu(g.astype(jnp.float32)).astype(compute_dtype) * h
    return jnp.einsum("e...f,efd->e...d", act,
                      params["w_out"].astype(compute_dtype))


def _moe_gather(params, tokens, probs, gate_vals, gate_idx, *, B, T, d, E,
                C, top_k, compute_dtype):
    """Sort-based dispatch (§Perf optimization; see moe_ffn docstring)."""
    G, n, _ = tokens.shape
    k = top_k
    flat_e = gate_idx.reshape(G, n * k)                       # (G, nk)
    order = jnp.argsort(flat_e, axis=1, stable=True)          # (G, nk)
    sorted_e = jnp.take_along_axis(flat_e, order, axis=1)
    tok_of = order // k                                       # source token
    # position within expert: running index minus expert start offset
    counts = jax.vmap(lambda e: jnp.bincount(e, length=E))(flat_e)
    starts = jnp.cumsum(counts, axis=1) - counts              # (G, E)
    pos = (jnp.arange(n * k)[None, :]
           - jnp.take_along_axis(starts, sorted_e, axis=1))   # (G, nk)
    keep = pos < C
    slot = jnp.where(keep, sorted_e * C + pos, E * C)         # E*C = dropped

    toks_sorted = jnp.take_along_axis(
        tokens.astype(compute_dtype), tok_of[..., None], axis=1)

    def scatter_one(tk, sl):
        buf = jnp.zeros((E * C + 1, d), compute_dtype)
        return buf.at[sl].add(tk, mode="drop")[:E * C]

    xe = jax.vmap(scatter_one)(toks_sorted, slot)             # (G, E*C, d)
    xe = xe.reshape(G, E, C, d).transpose(1, 0, 2, 3)         # (E, G, C, d)
    ye = _expert_ffn(params, xe, compute_dtype)               # (E, G, C, d)
    ye = ye.transpose(1, 0, 2, 3).reshape(G, E * C, d)

    def gather_one(buf, sl):
        padded = jnp.concatenate([buf, jnp.zeros((1, d), compute_dtype)])
        return padded[jnp.minimum(sl, E * C)]

    out_sorted = jax.vmap(gather_one)(ye, slot)               # (G, nk, d)
    gates_sorted = jnp.take_along_axis(
        gate_vals.reshape(G, n * k), order, axis=1)
    contrib = out_sorted * (gates_sorted
                            * keep.astype(jnp.float32))[..., None].astype(
        compute_dtype)
    # scatter-add back to token order, summing the k expert contributions
    def unsort_one(c, t):
        return jnp.zeros((n, d), compute_dtype).at[t].add(c)

    y = jax.vmap(unsort_one)(contrib, tok_of)                 # (G, n, d)

    me = jnp.mean(probs, axis=(0, 1))
    ce = jnp.mean(jax.nn.one_hot(gate_idx[..., 0], E), axis=(0, 1))
    lb = E * jnp.sum(me * ce)
    dropped = 1.0 - jnp.mean(keep.astype(jnp.float32))
    return (y.reshape(B, T, d).astype(tokens.dtype),
            MoEAux(lb, dropped))
