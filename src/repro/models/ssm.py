"""Mamba-2 (SSD — state space duality) mixer layer, chunked scan + decode.

Implements the SSD algorithm (Dao & Gu 2024): the sequence is split into
chunks; intra-chunk terms are computed as (masked, decay-weighted) attention-
like matmuls — MXU-friendly — while inter-chunk terms flow through a small
sequential scan over per-chunk states (h, p, n). This is the TPU-native
adaptation: the CUDA implementation leans on warp-level scans; here the
state recurrence is a lax.scan over (seq/chunk) steps with all heavy lifting
in einsums.

Single group (g=1) B/C projections; per-head scalar decay A (SSD).
"""
from __future__ import annotations

from typing import NamedTuple

import jax
import jax.numpy as jnp


class SSMState(NamedTuple):
    ssm: jax.Array    # (B, H, P, N) running state
    conv: jax.Array   # (B, K-1, conv_dim) last inputs for the causal conv


def dims(cfg):
    d_inner = cfg.ssm_expand * cfg.d_model
    H = d_inner // cfg.ssm_headdim
    return d_inner, H, cfg.ssm_headdim, cfg.ssm_state


def init_ssm(key, cfg, dtype=jnp.float32):
    d = cfg.d_model
    d_inner, H, P, N = dims(cfg)
    conv_dim = d_inner + 2 * N                       # x, B, C go through conv
    ks = jax.random.split(key, 5)
    s = d ** -0.5
    return {
        # order: [z (d_inner), x (d_inner), B (N), C (N), dt (H)]
        "in_proj": jax.random.normal(
            ks[0], (d, 2 * d_inner + 2 * N + H), dtype) * s,
        "conv_w": jax.random.normal(ks[1], (cfg.ssm_conv, conv_dim),
                                    dtype) * 0.2,
        "A_log": jnp.log(jnp.linspace(1.0, 16.0, H).astype(dtype)),
        "D": jnp.ones((H,), dtype),
        "dt_bias": jnp.zeros((H,), dtype),
        "norm": jnp.zeros((d_inner,), jnp.float32),
        "out_proj": jax.random.normal(ks[2], (d_inner, d),
                                      dtype) * d_inner ** -0.5,
    }


def _split(cfg, zxbcdt):
    d_inner, H, P, N = dims(cfg)
    z = zxbcdt[..., :d_inner]
    x = zxbcdt[..., d_inner:2 * d_inner]
    Bm = zxbcdt[..., 2 * d_inner:2 * d_inner + N]
    Cm = zxbcdt[..., 2 * d_inner + N:2 * d_inner + 2 * N]
    dt = zxbcdt[..., 2 * d_inner + 2 * N:]
    return z, x, Bm, Cm, dt


def _causal_conv(u, w):
    """Depthwise causal conv. u: (B, T, D), w: (K, D)."""
    K = w.shape[0]
    upad = jnp.pad(u, ((0, 0), (K - 1, 0), (0, 0)))
    out = sum(upad[:, i:i + u.shape[1], :] * w[i][None, None]
              for i in range(K))
    return jax.nn.silu(out.astype(jnp.float32)).astype(u.dtype)


def _segsum(a):
    """exp-able segment sums: L[i, j] = sum_{j < k <= i} a_k (lower-tri)."""
    T = a.shape[-1]
    cum = jnp.cumsum(a, axis=-1)
    L = cum[..., :, None] - cum[..., None, :]
    mask = jnp.tril(jnp.ones((T, T), bool), k=0)
    return jnp.where(mask, L, -jnp.inf)


def ssd_scan(xh, dt, A, Bm, Cm, chunk: int):
    """Chunked SSD. xh: (B,L,H,P), dt: (B,L,H) (post-softplus),
    A: (H,) negative decay rates, Bm/Cm: (B,L,N). Returns (B,L,H,P) and the
    final state (B,H,P,N)."""
    Bsz, L, H, P = xh.shape
    nc = L // chunk
    c = lambda t: t.reshape((Bsz, nc, chunk) + t.shape[2:])
    xc, dtc, Bc, Cc = c(xh), c(dt), c(Bm), c(Cm)

    dA = dtc * A[None, None, None, :]                # (B,nc,cs,H) log-decays
    dA = jnp.moveaxis(dA, -1, 2)                     # (B,nc,H,cs)
    cum = jnp.cumsum(dA, axis=-1)                    # (B,nc,H,cs)

    # 1) intra-chunk (diagonal blocks): decay-masked attention on the MXU
    Lmat = jnp.exp(_segsum(dA))                      # (B,nc,H,cs,cs)
    G = jnp.einsum("bcin,bcjn->bcij", Cc, Bc)        # (B,nc,cs,cs)
    M = G[:, :, None] * Lmat                         # (B,nc,H,cs,cs)
    xdt = xc * jnp.moveaxis(dtc, -1, -1)[..., None]  # (B,nc,cs,H,P) * dt
    xdt = xc * dtc[..., None]
    Y_diag = jnp.einsum("bchij,bcjhp->bcihp", M, xdt)

    # 2) chunk states: decay-to-end weighted outer products
    decay_end = jnp.exp(cum[..., -1:] - cum)         # (B,nc,H,cs)
    S = jnp.einsum("bcjn,bchj,bcjhp->bchpn", Bc,
                   decay_end * jnp.moveaxis(dtc, 2, 3)
                   if False else decay_end * jnp.moveaxis(dtc, -1, 2), xc)

    # 3) inter-chunk recurrence (sequential over nc chunks)
    chunk_decay = jnp.exp(cum[..., -1])              # (B,nc,H)

    def step(carry, inp):
        S_c, g_c = inp                               # (B,H,P,N), (B,H)
        prev = carry
        new = prev * g_c[..., None, None] + S_c
        return new, prev

    S_seq = jnp.moveaxis(S, 1, 0)                    # (nc,B,H,P,N)
    g_seq = jnp.moveaxis(chunk_decay, 1, 0)          # (nc,B,H)
    init = jnp.zeros_like(S_seq[0])
    final, prev_states = jax.lax.scan(step, init, (S_seq, g_seq))
    prev_states = jnp.moveaxis(prev_states, 0, 1)    # (B,nc,H,P,N)

    # 4) off-diagonal contribution: state entering the chunk, decayed to i
    in_decay = jnp.exp(cum)                          # (B,nc,H,cs)
    Y_off = jnp.einsum("bcin,bchpn,bchi->bcihp", Cc, prev_states, in_decay)

    Y = (Y_diag + Y_off).reshape(Bsz, L, H, P)
    return Y, final


def ssm_mixer(params, x, cfg, compute_dtype=jnp.bfloat16):
    """Full Mamba-2 block (training / prefill). x: (B, T, d)."""
    from repro.models.layers import rms_norm
    B, T, d = x.shape
    d_inner, H, P, N = dims(cfg)
    zxbcdt = (x.astype(compute_dtype)
              @ params["in_proj"].astype(compute_dtype))
    z, xu, Bm, Cm, dt = _split(cfg, zxbcdt)
    conv_in = jnp.concatenate([xu, Bm, Cm], axis=-1)
    conv_out = _causal_conv(conv_in, params["conv_w"].astype(compute_dtype))
    xu, Bm, Cm = (conv_out[..., :d_inner], conv_out[..., d_inner:d_inner + N],
                  conv_out[..., d_inner + N:])
    dt = jax.nn.softplus(dt.astype(jnp.float32)
                         + params["dt_bias"][None, None])     # (B,T,H)
    A = -jnp.exp(params["A_log"].astype(jnp.float32))          # (H,)
    xh = xu.reshape(B, T, H, P).astype(jnp.float32)
    # Pallas intra-chunk kernel on TPU; this pure-jnp scan elsewhere
    from repro.kernels.ssd import ops as ssd_ops
    Y, _ = ssd_ops.ssd_scan(xh, dt, A, Bm.astype(jnp.float32),
                            Cm.astype(jnp.float32), cfg.ssm_chunk)
    Y = Y + params["D"][None, None, :, None] * xh
    y = Y.reshape(B, T, d_inner).astype(compute_dtype)
    y = rms_norm(y * jax.nn.silu(z.astype(jnp.float32)).astype(compute_dtype),
                 params["norm"], cfg.norm_eps)
    return (y @ params["out_proj"].astype(compute_dtype)).astype(x.dtype)


def ssm_decode(params, x, cfg, state: SSMState,
               compute_dtype=jnp.bfloat16):
    """Single-token decode. x: (B, 1, d). O(1) state update — the reason
    long_500k is cheap for SSM archs."""
    from repro.models.layers import rms_norm
    B, _, d = x.shape
    d_inner, H, P, N = dims(cfg)
    zxbcdt = (x.astype(compute_dtype)
              @ params["in_proj"].astype(compute_dtype))
    z, xu, Bm, Cm, dt = _split(cfg, zxbcdt)
    conv_in = jnp.concatenate([xu, Bm, Cm], axis=-1)          # (B,1,conv_dim)
    hist = jnp.concatenate([state.conv, conv_in], axis=1)     # (B,K,conv)
    w = params["conv_w"].astype(compute_dtype)
    conv_out = jax.nn.silu(
        jnp.einsum("bkc,kc->bc", hist.astype(jnp.float32),
                   w.astype(jnp.float32)))[:, None].astype(compute_dtype)
    new_conv = hist[:, 1:]
    xu, Bm, Cm = (conv_out[..., :d_inner], conv_out[..., d_inner:d_inner + N],
                  conv_out[..., d_inner + N:])
    dt = jax.nn.softplus(dt.astype(jnp.float32)
                         + params["dt_bias"][None, None])[:, 0]  # (B,H)
    A = -jnp.exp(params["A_log"].astype(jnp.float32))
    dA = jnp.exp(dt * A[None])                                 # (B,H)
    xh = xu.reshape(B, H, P).astype(jnp.float32)
    Bv = Bm[:, 0].astype(jnp.float32)                          # (B,N)
    Cv = Cm[:, 0].astype(jnp.float32)
    new_ssm = (state.ssm * dA[..., None, None]
               + jnp.einsum("bh,bhp,bn->bhpn", dt, xh, Bv))
    y = jnp.einsum("bhpn,bn->bhp", new_ssm, Cv) + params["D"][None, :, None] * xh
    y = y.reshape(B, 1, d_inner).astype(compute_dtype)
    y = rms_norm(y * jax.nn.silu(z.astype(jnp.float32)).astype(compute_dtype),
                 params["norm"], cfg.norm_eps)
    out = (y @ params["out_proj"].astype(compute_dtype)).astype(x.dtype)
    return out, SSMState(new_ssm, new_conv)


def init_state(cfg, batch: int, dtype=jnp.float32,
               conv_dtype=jnp.bfloat16) -> SSMState:
    d_inner, H, P, N = dims(cfg)
    conv_dim = d_inner + 2 * N
    return SSMState(jnp.zeros((batch, H, P, N), dtype),
                    jnp.zeros((batch, cfg.ssm_conv - 1, conv_dim),
                              conv_dtype))
