"""Attention layer: GQA projections, RoPE/M-RoPE, qk-norm, sliding window,
KV cache for decode, optional cross-attention (enc-dec).

The score computation routes through kernels/attention (Pallas flash on TPU,
jnp reference elsewhere/decode).
"""
from __future__ import annotations

from typing import NamedTuple

import jax
import jax.numpy as jnp

from repro.kernels.attention import ops as attn_ops
from repro.models import layers


class KVCache(NamedTuple):
    k: jax.Array       # (B, Hkv, T_max, Dh)
    v: jax.Array       # (B, Hkv, T_max, Dh)
    length: jax.Array  # () int32 — filled prefix


def init_attn(key, cfg, *, cross: bool = False, dtype=jnp.float32):
    d, hd = cfg.d_model, cfg.head_dim
    Hq, Hkv = cfg.n_heads, cfg.n_kv_heads
    ks = jax.random.split(key, 5)
    s = d ** -0.5
    p = {
        "wq": jax.random.normal(ks[0], (d, Hq * hd), dtype) * s,
        "wk": jax.random.normal(ks[1], (d, Hkv * hd), dtype) * s,
        "wv": jax.random.normal(ks[2], (d, Hkv * hd), dtype) * s,
        "wo": jax.random.normal(ks[3], (Hq * hd, d), dtype) * (Hq * hd) ** -0.5,
    }
    if cfg.qk_norm:
        p["q_norm"] = jnp.zeros((hd,), jnp.float32)
        p["k_norm"] = jnp.zeros((hd,), jnp.float32)
    return p


def _project(params, x, cfg, compute_dtype):
    B, T, _ = x.shape
    hd = cfg.head_dim
    xc = x.astype(compute_dtype)
    q = (xc @ params["wq"].astype(compute_dtype)).reshape(
        B, T, cfg.n_heads, hd).transpose(0, 2, 1, 3)
    k = (xc @ params["wk"].astype(compute_dtype)).reshape(
        B, T, cfg.n_kv_heads, hd).transpose(0, 2, 1, 3)
    v = (xc @ params["wv"].astype(compute_dtype)).reshape(
        B, T, cfg.n_kv_heads, hd).transpose(0, 2, 1, 3)
    if cfg.qk_norm:
        q = layers.rms_norm(q, params["q_norm"], cfg.norm_eps)
        k = layers.rms_norm(k, params["k_norm"], cfg.norm_eps)
    return q, k, v


def _rope(q, k, positions, cfg):
    if cfg.mrope:
        pos3 = positions if positions.ndim == 3 else \
            jnp.broadcast_to(positions[:, None, :],
                             (positions.shape[0], 3, positions.shape[1]))
        q = layers.apply_mrope(q, pos3, cfg.rope_theta, cfg.mrope_sections)
        k = layers.apply_mrope(k, pos3, cfg.rope_theta, cfg.mrope_sections)
    else:
        q = layers.apply_rope(q, positions, cfg.rope_theta)
        k = layers.apply_rope(k, positions, cfg.rope_theta)
    return q, k


def attend(params, x, cfg, *, window=None, positions=None, causal=True,
           use_rope=True, compute_dtype=jnp.bfloat16, attn_impl="auto"):
    """Full-sequence attention (training / prefill without cache)."""
    B, T, _ = x.shape
    if positions is None:
        positions = jnp.broadcast_to(jnp.arange(T)[None], (B, T))
    q, k, v = _project(params, x, cfg, compute_dtype)
    if use_rope:
        q, k = _rope(q, k, positions, cfg)
    o = attn_ops.attention(q, k, v, causal=causal, window=window,
                           impl=attn_impl)
    o = o.transpose(0, 2, 1, 3).reshape(B, T, -1)
    return (o @ params["wo"].astype(compute_dtype)).astype(x.dtype)


def attend_decode(params, x, cfg, cache: KVCache, *, window=None,
                  compute_dtype=jnp.bfloat16):
    """Single-token decode against a KV cache. x: (B, 1, d).

    Ring-buffer mode (§Perf): when the cache was allocated with exactly
    ``window`` slots (init_serve(ring_cache=True)), writes wrap modulo the
    window and scoring uses the ring's logical positions — HBM per windowed
    layer drops from O(T) to O(window) and so does per-token read traffic.
    Detected structurally: cache length-dim == window < needed context.
    """
    B = x.shape[0]
    pos = jnp.broadcast_to(cache.length[None, None], (B, 1))
    q, k_new, v_new = _project(params, x, cfg, compute_dtype)
    q, k_new = _rope(q, k_new, pos, cfg)

    W = cache.k.shape[2]
    ring = window is not None and W == window
    slot = (cache.length % W) if ring else cache.length
    k = jax.lax.dynamic_update_slice_in_dim(
        cache.k, k_new.astype(cache.k.dtype), slot, axis=2)
    v = jax.lax.dynamic_update_slice_in_dim(
        cache.v, v_new.astype(cache.v.dtype), slot, axis=2)

    if ring:
        # logical position held by ring slot s: length - ((slot - s) mod W)
        s = jnp.arange(W)
        logical = cache.length - jnp.mod(slot - s, W)
        valid = logical >= 0                       # window bound is implicit
        qf = q.astype(jnp.float32)
        kf = k.astype(jnp.float32)
        vf = v.astype(jnp.float32)
        G = cfg.n_heads // cfg.n_kv_heads
        qf = qf.reshape(B, cfg.n_kv_heads, G, 1, -1)
        scores = jnp.einsum("bhgqd,bhkd->bhgqk", qf, kf) * (
            cfg.head_dim ** -0.5)
        scores = jnp.where(valid[None, None, None, None, :], scores, -1e30)
        probs = jax.nn.softmax(scores, axis=-1)
        o = jnp.einsum("bhgqk,bhkd->bhgqd", probs, vf)
        o = o.reshape(B, cfg.n_heads, 1, -1).astype(compute_dtype)
    else:
        # full cache: causal mask with q_offset handles prefix validity
        o = attn_ops.attention(q, k, v, causal=True, window=window,
                               q_offset=cache.length, impl="jnp")
    o = o.transpose(0, 2, 1, 3).reshape(B, 1, -1)
    y = (o @ params["wo"].astype(compute_dtype)).astype(x.dtype)
    return y, KVCache(k, v, cache.length + 1)


def project_cross_kv(params, enc_kv, cfg, compute_dtype=jnp.bfloat16):
    """Encoder-side K/V projections for one cross-attn layer — computed ONCE
    per request at serve init instead of per decode step (§Perf: the baseline
    recomputed these every token, useful fraction 0.03 for whisper decode)."""
    B, Te, _ = enc_kv.shape
    hd = cfg.head_dim
    kc = (enc_kv.astype(compute_dtype)
          @ params["wk"].astype(compute_dtype)).reshape(
        B, Te, cfg.n_kv_heads, hd).transpose(0, 2, 1, 3)
    vc = (enc_kv.astype(compute_dtype)
          @ params["wv"].astype(compute_dtype)).reshape(
        B, Te, cfg.n_kv_heads, hd).transpose(0, 2, 1, 3)
    return kc, vc


def attend_cross(params, x, enc_kv, cfg, compute_dtype=jnp.bfloat16,
                 kv=None):
    """Cross-attention for enc-dec (whisper): kv from encoder output, or
    precomputed (kc, vc) via ``kv`` (decode fast path)."""
    B, T, _ = x.shape
    q, _, _ = _project(params, x, cfg, compute_dtype)
    kc, vc = kv if kv is not None else project_cross_kv(
        params, enc_kv, cfg, compute_dtype)
    o = attn_ops.attention(q, kc, vc, causal=False, impl="jnp")
    o = o.transpose(0, 2, 1, 3).reshape(B, T, -1)
    return (o @ params["wo"].astype(compute_dtype)).astype(x.dtype)


def init_cache(cfg, batch: int, max_len: int,
               dtype=jnp.bfloat16) -> KVCache:
    shape = (batch, cfg.n_kv_heads, max_len, cfg.head_dim)
    return KVCache(jnp.zeros(shape, dtype), jnp.zeros(shape, dtype),
                   jnp.zeros((), jnp.int32))
