"""Sharding rules: DP/FSDP over ("pod","data"), TP/EP over "model", SP for
long-context KV caches.

Rules are name-convention based over the param tree and *size-aware*: an axis
is only sharded if its size divides the mesh axis product (so the same rules
serve the 512-chip production mesh and tiny smoke meshes). Priority when a
dim can't shard: drop to None (replicate) — correctness first, the roofline
pass tells us what it cost.
"""
from __future__ import annotations

from typing import Any

import jax
from jax.sharding import Mesh, NamedSharding, PartitionSpec as P

DP = ("pod", "data")     # data/FSDP axes (pod may be absent on 1-pod meshes)
TP = "model"


def dp_axes(mesh: Mesh) -> tuple[str, ...]:
    return tuple(a for a in DP if a in mesh.shape)


def _size(mesh: Mesh, axes) -> int:
    if axes is None:
        return 1
    if isinstance(axes, str):
        return mesh.shape[axes]
    out = 1
    for a in axes:
        out *= mesh.shape[a]
    return out


def _fit(dim: int, mesh: Mesh, axes):
    """axes if dim divides their product else None."""
    return axes if (axes and dim % _size(mesh, axes) == 0) else None


def spec_for(path: str, shape: tuple[int, ...], mesh: Mesh) -> P:
    """PartitionSpec for one parameter, by name convention."""
    dp = dp_axes(mesh)
    dp = dp if len(dp) > 1 else (dp[0] if dp else None)

    def fit(i, axes):
        return _fit(shape[i], mesh, axes)

    last = path.rsplit("/", 1)[-1]

    # MoE expert weights (E, d, ff)/(E, ff, d) — checked BEFORE the 2-D name
    # rules (same leaf names) so the expert dim is handled explicitly
    if len(shape) == 3 and last in ("w_gate", "w_in", "w_out"):
        if shape[0] % _size(mesh, TP) == 0:
            return P(TP, fit(1, dp), None)     # EP: experts on model
        return P(None, fit(1, dp), fit(2, TP))  # TP fallback inside experts

    if last in ("tok",):                       # (V, d) embed
        # small tables: replicate d — avoids a partial-sum all-reduce of
        # full logits over DP from the d-contraction (§Perf iteration 3)
        small = shape[0] * shape[1] * 4 <= 2 ** 31
        return P(fit(0, TP), None if small else fit(1, dp))
    if last in ("unembed",):                   # (d, V)
        small = shape[0] * shape[1] * 4 <= 2 ** 31
        return P(None if small else fit(0, dp), fit(1, TP))
    if last in ("wq", "wk", "wv", "w_gate", "w_in", "in_proj"):
        return P(fit(0, dp), fit(1, TP))       # (d, out): TP on out
    if last in ("wo", "w_out", "out_proj"):
        return P(fit(0, TP), fit(1, dp))       # (in, d): TP on in
    if last == "router":                       # (d, E) — small, replicate
        return P(None, None)
    if last == "conv_w":                       # (K, conv_dim)
        return P(None, fit(1, TP))
    if len(shape) == 3:                        # other stacked 3-D weights
        return P(None, fit(1, dp), fit(2, TP))
    if len(shape) == 1:
        return P(fit(0, TP))                   # per-channel vectors
    if len(shape) == 2:
        return P(fit(0, dp), fit(1, TP))
    return P()


def _path_str(path) -> str:
    parts = []
    for k in path:
        if hasattr(k, "key"):
            parts.append(str(k.key))
        elif hasattr(k, "idx"):
            parts.append(str(k.idx))
        else:
            parts.append(str(k))
    return "/".join(parts)


PURE_DP_THRESHOLD_BYTES = 4e9   # below this, replicate params: no TP/FSDP


def use_tp_policy(params) -> bool:
    """Size-aware parallelism policy: tiny models (e.g. mamba2-130m) pay
    more in per-layer TP all-reduces than they save — replicate them and
    spend every mesh axis on data parallelism (§Perf iteration 3b)."""
    total = sum(l.size * l.dtype.itemsize for l in jax.tree.leaves(params))
    return total > PURE_DP_THRESHOLD_BYTES


def param_specs(params: Any, mesh: Mesh, use_tp: bool | None = None):
    """Pytree of PartitionSpecs.

    Stacked-scan params carry a leading (n_full) layer axis — detected by the
    'stack'/'encoder' path component — which is never sharded (it is the scan
    dimension); rules apply to the trailing dims.

    ``use_tp=False`` (auto for small models) replicates every parameter —
    pure data parallelism over all mesh axes.
    """
    if use_tp is None:
        use_tp = use_tp_policy(params)

    def one(path, leaf):
        if not use_tp:
            return P(*(None,) * leaf.ndim)
        p = _path_str(path)
        shape = leaf.shape
        if ("stack/" in p or p.startswith("stack") or "encoder" in p) \
                and leaf.ndim >= 1:
            inner = spec_for(p, shape[1:], mesh)
            return P(None, *inner)
        return spec_for(p, shape, mesh)

    return jax.tree_util.tree_map_with_path(one, params)


def shardings(tree_specs, mesh: Mesh):
    return jax.tree.map(lambda s: NamedSharding(mesh, s), tree_specs,
                        is_leaf=lambda x: isinstance(x, P))


def batch_spec(mesh: Mesh, use_tp: bool = True,
               batch: int | None = None) -> P:
    dp = dp_axes(mesh)
    if not use_tp and TP in mesh.shape:
        dp = dp + (TP,)          # pure DP: batch over every axis
    if batch is not None:        # drop axes until the batch divides
        while dp and batch % _size(mesh, dp):
            dp = dp[:-1]
    return P(dp if len(dp) > 1 else (dp[0] if dp else None))


def logits_spec(mesh: Mesh, *, batch: int | None = None,
                vocab: int | None = None) -> P:
    dp = dp_axes(mesh)
    dpx = dp if len(dp) > 1 else (dp[0] if dp else None)
    if batch is not None and (batch % max(_size(mesh, dpx), 1) or batch == 1):
        dpx = None
    tp = TP
    if vocab is not None and vocab % _size(mesh, TP):
        tp = None
    return P(dpx, None, tp)


def cache_spec(mesh: Mesh, *, batch: int, n_kv: int, seq: int,
               stacked: bool) -> P:
    """KV cache (B, Hkv, T, hd) sharding.

    decode_32k-style (large batch): batch on DP, heads on TP if divisible.
    long_500k-style (batch 1): sequence-parallel — T on DP (flash-decode
    layout; GSPMD turns the softmax/PV contractions into all-reduces).
    """
    dp = dp_axes(mesh)
    dpx = dp if len(dp) > 1 else (dp[0] if dp else None)
    tp_heads = TP if (n_kv % _size(mesh, TP) == 0) else None
    if batch % max(_size(mesh, dpx), 1) == 0 and batch > 1:
        spec = P(dpx, tp_heads, None, None)
    else:
        spec = P(None, tp_heads, dpx, None)
    if stacked:
        return P(None, *spec)
    return spec


def ssm_state_spec(mesh: Mesh, *, batch: int, n_heads: int,
                   stacked: bool) -> P:
    dp = dp_axes(mesh)
    dpx = dp if len(dp) > 1 else (dp[0] if dp else None)
    tp_heads = TP if (n_heads % _size(mesh, TP) == 0) else None
    if batch % max(_size(mesh, dpx), 1) == 0 and batch > 1:
        spec = P(dpx, tp_heads, None, None)
    else:
        spec = P(None, tp_heads, None, None)
    if stacked:
        return P(None, *spec)
    return spec
