"""Execution backends for per-machine GP programs.

The paper's algorithms are written ONCE as per-machine functions that use
``jax.lax`` collectives over ``axis_name`` (psum / all_gather / psum_scatter /
all_to_all — the TPU realization of the paper's MPI broadcast/reduce). A
Runner decides how the machine axis is realized:

* ``VmapRunner``    — `jax.vmap(axis_name=...)`: single-device simulation of M
  machines. Used by tests and CPU examples; bit-identical math.
* ``ShardMapRunner`` — `jax.shard_map` over one or more mesh axes: the real
  multi-device execution (multi-pod dry-run uses ("pod", "data")).

Both consume *stacked* inputs with a leading machine axis (M, ...) and return
stacked outputs (M, ...), so callers are backend-agnostic.
"""
from __future__ import annotations

import dataclasses
from typing import Any, Callable, NamedTuple, Sequence

import jax
import jax.numpy as jnp
from jax.sharding import Mesh, PartitionSpec as P

# jax.shard_map graduated from jax.experimental in 0.4.x; support both
_shard_map = getattr(jax, "shard_map", None)
if _shard_map is None:
    from jax.experimental.shard_map import shard_map as _shard_map


@dataclasses.dataclass(frozen=True)
class Runner:
    """Abstract machine-axis executor."""
    axis_name: Any = "machines"

    @property
    def num_machines(self) -> int:
        raise NotImplementedError

    def map(self, fn: Callable, sharded: Sequence, replicated: Sequence = ()):
        """Run per-machine ``fn(*block_args, *replicated_args)``.

        ``sharded`` entries are pytrees whose leaves carry a leading (M, ...)
        machine axis; ``fn`` sees them without it. Returns stacked outputs.
        """
        raise NotImplementedError

    def shard_blocks(self, X: jax.Array) -> jax.Array:
        """(n, ...) -> (M, n/M, ...) block layout (paper Def. 1).

        Training data must divide exactly — zero-padding data rows would
        corrupt the local summaries (a padded row adds a spurious noise-only
        observation to Sigma_{DmDm|S}). Query batches are row-independent and
        go through ``pad_blocks`` instead (the serving path).
        """
        M = self.num_machines
        n = X.shape[0]
        if n % M != 0:
            raise ValueError(
                f"n={n} does not divide among M={M} machines (Def. 1). "
                f"Either trim/re-block the data so M | n, or — for query "
                f"batches — use parallel.runner.pad_blocks(X, M), which "
                f"zero-pads and returns the valid count for trimming.")
        return X.reshape((M, n // M) + X.shape[1:])

    def pad_blocks(self, X: jax.Array) -> tuple[jax.Array, int]:
        """Zero-padded (M, ceil(n/M), ...) block layout; see ``pad_blocks``."""
        return pad_blocks(X, self.num_machines)

    def unshard(self, Xb: jax.Array) -> jax.Array:
        return Xb.reshape((-1,) + Xb.shape[2:])


@dataclasses.dataclass(frozen=True)
class VmapRunner(Runner):
    """Single-device simulation of M machines via vmap collectives."""
    M: int = 4

    @property
    def num_machines(self) -> int:
        return self.M

    def map(self, fn, sharded, replicated=()):
        g = lambda *blocks: fn(*blocks, *replicated)
        return jax.vmap(g, axis_name=self.axis_name)(*sharded)


@dataclasses.dataclass(frozen=True)
class ShardMapRunner(Runner):
    """Real distribution over mesh axes.

    ``axis_name`` may be a single mesh axis ("data") or a tuple
    (("pod", "data")) — collectives inside per-machine code reduce over all of
    them; the number of machines is the product of the axis sizes.
    """
    mesh: Mesh | None = None

    def __post_init__(self):
        assert self.mesh is not None

    @property
    def axes(self) -> tuple[str, ...]:
        a = self.axis_name
        return (a,) if isinstance(a, str) else tuple(a)

    @property
    def num_machines(self) -> int:
        out = 1
        for a in self.axes:
            out *= self.mesh.shape[a]
        return out

    def map(self, fn, sharded, replicated=()):
        n_shard = len(sharded)
        spec = P(self.axes if len(self.axes) > 1 else self.axes[0])

        def inner(*args):
            blocks = tuple(jax.tree.map(lambda a: a[0], x)
                           for x in args[:n_shard])
            out = fn(*blocks, *args[n_shard:])
            return jax.tree.map(lambda a: a[None], out)

        in_specs = tuple(spec for _ in sharded) + tuple(P() for _ in replicated)
        return _shard_map(inner, mesh=self.mesh, in_specs=in_specs,
                          out_specs=spec)(*sharded, *replicated)


def pad_blocks(X: jax.Array, M: int) -> tuple[jax.Array, int]:
    """(n, ...) -> ((M, ceil(n/M), ...), n): zero-pad to the block layout.

    For *query* batches only: query rows are independent in every predictive
    equation, so padded rows produce garbage predictions for themselves and
    affect nothing else — callers slice outputs back to the returned valid
    count ``n``. (Training data must not be padded; see Runner.shard_blocks.)
    """
    n = X.shape[0]
    b = -(-n // M)                    # ceil(n / M)
    pad = M * b - n
    if pad:
        widths = [(0, pad)] + [(0, 0)] * (X.ndim - 1)
        X = jnp.pad(X, widths)
    return X.reshape((M, b) + X.shape[1:]), n


def scatter_by_block(X: jax.Array, assign: jax.Array, M: int):
    """Scatter (n, ...) rows into an (M, n, ...) block layout by assignment.

    The routed-serving counterpart of ``pad_blocks``: instead of slicing the
    batch positionally, row i lands in block ``assign[i]`` at the next free
    slot (original order preserved within a block — stable sort). Capacity is
    ``n`` per block, so the output shape depends only on (n, M): any
    composition of the same-sized batch compiles to the same executable, and
    a fully-skewed batch (all rows on one block) still fits. Unoccupied slots
    stay zero; per-row independence of the predictive equations makes them
    inert (see ``pad_blocks``).

    Returns ``(Xb, order, block_of, slot)`` where ``Xb[block_of[j], slot[j]]
    == X[order[j]]``; pass the triple to ``gather_by_block`` to restore
    caller order.
    """
    n = X.shape[0]
    order = jnp.argsort(assign, stable=True)               # group by block
    block_of = assign[order]                               # (n,) sorted ids
    starts = jnp.searchsorted(block_of, jnp.arange(M))     # first row of m
    slot = jnp.arange(n) - starts[block_of]                # intra-block slot
    Xb = jnp.zeros((M, n) + X.shape[1:], X.dtype)
    Xb = Xb.at[block_of, slot].set(X[order])
    return Xb, order, block_of, slot


def gather_by_block(vals: jax.Array, order: jax.Array, block_of: jax.Array,
                    slot: jax.Array) -> jax.Array:
    """Invert ``scatter_by_block`` on per-row outputs: (M, n, ...) -> (n, ...)
    in the original caller order."""
    picked = vals[block_of, slot]                          # sorted order
    out = jnp.zeros_like(picked)
    return out.at[order].set(picked)


# ---------------------------------------------------------------------------
# Two-bucket routed scatter: capacity-bounded main bucket + skew overflow.
#
# ``scatter_by_block``'s capacity-n layout is shape-stable and skew-proof but
# computes M*n rows to serve n queries — an M x compute overhead for balanced
# traffic. The two-bucket scheme keeps both properties at ~(1 + 1/alpha) x:
#
#   * main bucket    — (M, cap) per-block layout with cap = alpha*ceil(n/M):
#     each block keeps its first cap routed rows (stable order);
#   * overflow bucket — (G, cap) groups for the rows a skewed batch pushes
#     past a block's capacity. Rows are packed positionally into groups, one
#     BLOCK per group (block m's overflow fills ceil/cap groups exclusively),
#     and each group records the block id whose cached factors serve it —
#     the caller gathers that block's state fields per group, so an overflow
#     row computes the SAME per-row program as the capacity-n layout
#     (bitwise: every predictive equation is row-independent).
#
# G is static: blocks that overflow hold > cap >= alpha*n/M rows, so at most
# n/cap <= M/alpha blocks overflow, and sum_m ceil(o_m/cap) <= n/cap, giving
# G = ceil(M/alpha). Total padded rows: (M + G)*cap ~ (alpha + 1)*n versus
# M*n — at M=8, alpha=2 that is 3n vs 8n (the >= 2x reduction gate in
# benchmarks/bench_serve_latency.py). When cap >= n no row can overflow and
# the overflow bucket is dropped entirely (G = 0).
# ---------------------------------------------------------------------------

ROUTED_ALPHA = 2   # main-bucket capacity multiplier alpha (headroom vs skew)


class RoutedLayout(NamedTuple):
    """Two-bucket scatter result + the bookkeeping to invert it.

    ``Xb[block_of[j], rank[j]] == X[order[j]]`` for main rows
    (``in_main[j]``); overflow row j sits at ``Xo[group[j], slot_o[j]]`` and
    must be served with block ``block_of[j]``'s factors (= ``o_blk`` of its
    group). Pass per-row outputs to ``gather_two_bucket``.
    """
    Xb: jax.Array              # (M, cap, ...) main routed bucket
    Xo: jax.Array | None       # (G, cap, ...) overflow groups (None: G == 0)
    o_blk: jax.Array | None    # (G,) block id served by each overflow group
    order: jax.Array           # (n,) argsort(assign), stable
    block_of: jax.Array        # (n,) assignment in sorted order
    rank: jax.Array            # (n,) intra-block arrival rank
    group: jax.Array           # (n,) overflow group per row (junk if in_main)
    slot_o: jax.Array          # (n,) slot within the overflow group
    in_main: jax.Array         # (n,) bool: row landed in the main bucket

    @property
    def padded_rows(self) -> int:
        """Total computed rows (both buckets) — the compute the layout pays."""
        go = 0 if self.Xo is None else self.Xo.shape[0]
        return (self.Xb.shape[0] + go) * self.Xb.shape[1]


def routed_capacity(n: int, M: int, *, alpha: int = ROUTED_ALPHA,
                    tile: int = 1,
                    max_groups: int | None = None) -> tuple[int, int]:
    """(cap, G) of the two-bucket layout — static given (n, M, alpha).

    ``tile`` rounds cap up to a hardware tile multiple (the Pallas serving
    kernel's block_q), so the per-group query buffers need no second pad
    inside the kernel dispatch.

    ``max_groups`` overrides the worst-case overflow-group count with a
    SMALLER program (lazy overflow dispatch): a caller that knows the
    actual per-block occupancy — the routed ServePlan computes it host-side
    per flush — can run the G=0 program on balanced traffic, or a 1-2 group
    program on mild skew, instead of always paying for ceil(M/alpha)
    groups. The caller owns the sufficiency contract: rows past the
    declared groups' capacity are silently dropped by the scatter (jit-safe
    OOB-drop semantics), so the count AND the assignment driving the
    scatter must come from one float path (ppic.PICServePlan passes its
    host assignment into the program for exactly this reason). Values above
    the worst case are clamped (extra groups could never be occupied)."""
    cap = min(alpha * (-(-n // M)), n)
    cap = -(-cap // tile) * tile
    G = 0 if cap >= n else -(-M // alpha)
    if max_groups is not None:
        G = min(G, max_groups)
    return cap, G


def scatter_two_bucket(X: jax.Array, assign: jax.Array, M: int, *,
                       alpha: int = ROUTED_ALPHA, tile: int = 1,
                       max_groups: int | None = None) -> RoutedLayout:
    """Scatter (n, ...) rows into the two-bucket routed layout by assignment.

    Shape-stable: every array depends only on (n, M, alpha, tile,
    max_groups), so any composition of a same-sized batch reuses the
    compiled executable — the property that makes routed serving
    jit-friendly (see scatter_by_block). Unoccupied slots stay zero;
    per-row independence of the predictive equations makes them inert (see
    ``pad_blocks``). ``max_groups`` selects a smaller overflow program (see
    ``routed_capacity``); the caller guarantees it covers the actual
    overflow, otherwise rows are dropped.
    """
    n = X.shape[0]
    cap, G = routed_capacity(n, M, alpha=alpha, tile=tile,
                             max_groups=max_groups)
    order = jnp.argsort(assign, stable=True)               # group by block
    block_of = assign[order]                               # (n,) sorted ids
    starts = jnp.searchsorted(block_of, jnp.arange(M + 1))
    counts = jnp.diff(starts)                              # (M,) block loads
    rank = jnp.arange(n) - starts[block_of]                # intra-block rank
    in_main = rank < cap

    Xb = jnp.zeros((M, cap) + X.shape[1:], X.dtype)
    Xb = Xb.at[jnp.where(in_main, block_of, M),
               jnp.where(in_main, rank, 0)].set(X[order], mode="drop")

    if G == 0:
        zero = jnp.zeros((n,), jnp.int32)
        return RoutedLayout(Xb, None, None, order, block_of, rank,
                            zero, zero, in_main)

    # overflow: block m's surplus o_m fills ceil(o_m/cap) exclusive groups
    om = jnp.maximum(counts - cap, 0)
    gm = -(-om // cap)                                     # groups per block
    gstart = jnp.cumsum(gm) - gm                           # exclusive prefix
    orank = rank - cap                                     # >= 0 iff overflow
    group = gstart[block_of] + jnp.maximum(orank, 0) // cap
    slot_o = jnp.maximum(orank, 0) % cap
    gi = jnp.where(in_main, G, group)                      # OOB drop for main
    Xo = jnp.zeros((G, cap) + X.shape[1:], X.dtype)
    Xo = Xo.at[gi, jnp.where(in_main, 0, slot_o)].set(X[order], mode="drop")
    o_blk = jnp.zeros((G,), block_of.dtype).at[gi].set(block_of, mode="drop")
    return RoutedLayout(Xb, Xo, o_blk, order, block_of, rank,
                        group, slot_o, in_main)


def gather_two_bucket(vals_main: jax.Array, vals_over: jax.Array | None,
                      lay: RoutedLayout) -> jax.Array:
    """Invert ``scatter_two_bucket`` on per-row outputs: (M, cap, ...) +
    (G, cap, ...) -> (n, ...) in the original caller order."""
    picked = vals_main[lay.block_of, jnp.minimum(lay.rank,
                                                 vals_main.shape[1] - 1)]
    if vals_over is not None:
        over = vals_over[jnp.minimum(lay.group, vals_over.shape[0] - 1),
                         lay.slot_o]
        cond = lay.in_main.reshape((-1,) + (1,) * (picked.ndim - 1))
        picked = jnp.where(cond, picked, over)
    out = jnp.zeros_like(picked)
    return out.at[lay.order].set(picked)


def make_runner(mode: str, *, M: int | None = None, mesh: Mesh | None = None,
                axis_name="machines") -> Runner:
    if mode == "vmap":
        return VmapRunner(M=M, axis_name=axis_name)
    if mode == "shard_map":
        return ShardMapRunner(mesh=mesh, axis_name=axis_name)
    raise ValueError(f"unknown runner mode {mode!r}")
