"""Execution backends for per-machine GP programs.

The paper's algorithms are written ONCE as per-machine functions that use
``jax.lax`` collectives over ``axis_name`` (psum / all_gather / psum_scatter /
all_to_all — the TPU realization of the paper's MPI broadcast/reduce). A
Runner decides how the machine axis is realized:

* ``VmapRunner``    — `jax.vmap(axis_name=...)`: single-device simulation of M
  machines. Used by tests and CPU examples; bit-identical math.
* ``ShardMapRunner`` — `jax.shard_map` over one or more mesh axes: the real
  multi-device execution (multi-pod dry-run uses ("pod", "data")).

Both consume *stacked* inputs with a leading machine axis (M, ...) and return
stacked outputs (M, ...), so callers are backend-agnostic.
"""
from __future__ import annotations

import dataclasses
from typing import Any, Callable, Sequence

import jax
import jax.numpy as jnp
from jax.sharding import Mesh, PartitionSpec as P

# jax.shard_map graduated from jax.experimental in 0.4.x; support both
_shard_map = getattr(jax, "shard_map", None)
if _shard_map is None:
    from jax.experimental.shard_map import shard_map as _shard_map


@dataclasses.dataclass(frozen=True)
class Runner:
    """Abstract machine-axis executor."""
    axis_name: Any = "machines"

    @property
    def num_machines(self) -> int:
        raise NotImplementedError

    def map(self, fn: Callable, sharded: Sequence, replicated: Sequence = ()):
        """Run per-machine ``fn(*block_args, *replicated_args)``.

        ``sharded`` entries are pytrees whose leaves carry a leading (M, ...)
        machine axis; ``fn`` sees them without it. Returns stacked outputs.
        """
        raise NotImplementedError

    def shard_blocks(self, X: jax.Array) -> jax.Array:
        """(n, ...) -> (M, n/M, ...) block layout (paper Def. 1).

        Training data must divide exactly — zero-padding data rows would
        corrupt the local summaries (a padded row adds a spurious noise-only
        observation to Sigma_{DmDm|S}). Query batches are row-independent and
        go through ``pad_blocks`` instead (the serving path).
        """
        M = self.num_machines
        n = X.shape[0]
        if n % M != 0:
            raise ValueError(
                f"n={n} does not divide among M={M} machines (Def. 1). "
                f"Either trim/re-block the data so M | n, or — for query "
                f"batches — use parallel.runner.pad_blocks(X, M), which "
                f"zero-pads and returns the valid count for trimming.")
        return X.reshape((M, n // M) + X.shape[1:])

    def pad_blocks(self, X: jax.Array) -> tuple[jax.Array, int]:
        """Zero-padded (M, ceil(n/M), ...) block layout; see ``pad_blocks``."""
        return pad_blocks(X, self.num_machines)

    def unshard(self, Xb: jax.Array) -> jax.Array:
        return Xb.reshape((-1,) + Xb.shape[2:])


@dataclasses.dataclass(frozen=True)
class VmapRunner(Runner):
    """Single-device simulation of M machines via vmap collectives."""
    M: int = 4

    @property
    def num_machines(self) -> int:
        return self.M

    def map(self, fn, sharded, replicated=()):
        g = lambda *blocks: fn(*blocks, *replicated)
        return jax.vmap(g, axis_name=self.axis_name)(*sharded)


@dataclasses.dataclass(frozen=True)
class ShardMapRunner(Runner):
    """Real distribution over mesh axes.

    ``axis_name`` may be a single mesh axis ("data") or a tuple
    (("pod", "data")) — collectives inside per-machine code reduce over all of
    them; the number of machines is the product of the axis sizes.
    """
    mesh: Mesh | None = None

    def __post_init__(self):
        assert self.mesh is not None

    @property
    def axes(self) -> tuple[str, ...]:
        a = self.axis_name
        return (a,) if isinstance(a, str) else tuple(a)

    @property
    def num_machines(self) -> int:
        out = 1
        for a in self.axes:
            out *= self.mesh.shape[a]
        return out

    def map(self, fn, sharded, replicated=()):
        n_shard = len(sharded)
        spec = P(self.axes if len(self.axes) > 1 else self.axes[0])

        def inner(*args):
            blocks = tuple(jax.tree.map(lambda a: a[0], x)
                           for x in args[:n_shard])
            out = fn(*blocks, *args[n_shard:])
            return jax.tree.map(lambda a: a[None], out)

        in_specs = tuple(spec for _ in sharded) + tuple(P() for _ in replicated)
        return _shard_map(inner, mesh=self.mesh, in_specs=in_specs,
                          out_specs=spec)(*sharded, *replicated)


def pad_blocks(X: jax.Array, M: int) -> tuple[jax.Array, int]:
    """(n, ...) -> ((M, ceil(n/M), ...), n): zero-pad to the block layout.

    For *query* batches only: query rows are independent in every predictive
    equation, so padded rows produce garbage predictions for themselves and
    affect nothing else — callers slice outputs back to the returned valid
    count ``n``. (Training data must not be padded; see Runner.shard_blocks.)
    """
    n = X.shape[0]
    b = -(-n // M)                    # ceil(n / M)
    pad = M * b - n
    if pad:
        widths = [(0, pad)] + [(0, 0)] * (X.ndim - 1)
        X = jnp.pad(X, widths)
    return X.reshape((M, b) + X.shape[1:]), n


def scatter_by_block(X: jax.Array, assign: jax.Array, M: int):
    """Scatter (n, ...) rows into an (M, n, ...) block layout by assignment.

    The routed-serving counterpart of ``pad_blocks``: instead of slicing the
    batch positionally, row i lands in block ``assign[i]`` at the next free
    slot (original order preserved within a block — stable sort). Capacity is
    ``n`` per block, so the output shape depends only on (n, M): any
    composition of the same-sized batch compiles to the same executable, and
    a fully-skewed batch (all rows on one block) still fits. Unoccupied slots
    stay zero; per-row independence of the predictive equations makes them
    inert (see ``pad_blocks``).

    Returns ``(Xb, order, block_of, slot)`` where ``Xb[block_of[j], slot[j]]
    == X[order[j]]``; pass the triple to ``gather_by_block`` to restore
    caller order.
    """
    n = X.shape[0]
    order = jnp.argsort(assign, stable=True)               # group by block
    block_of = assign[order]                               # (n,) sorted ids
    starts = jnp.searchsorted(block_of, jnp.arange(M))     # first row of m
    slot = jnp.arange(n) - starts[block_of]                # intra-block slot
    Xb = jnp.zeros((M, n) + X.shape[1:], X.dtype)
    Xb = Xb.at[block_of, slot].set(X[order])
    return Xb, order, block_of, slot


def gather_by_block(vals: jax.Array, order: jax.Array, block_of: jax.Array,
                    slot: jax.Array) -> jax.Array:
    """Invert ``scatter_by_block`` on per-row outputs: (M, n, ...) -> (n, ...)
    in the original caller order."""
    picked = vals[block_of, slot]                          # sorted order
    out = jnp.zeros_like(picked)
    return out.at[order].set(picked)


def make_runner(mode: str, *, M: int | None = None, mesh: Mesh | None = None,
                axis_name="machines") -> Runner:
    if mode == "vmap":
        return VmapRunner(M=M, axis_name=axis_name)
    if mode == "shard_map":
        return ShardMapRunner(mesh=mesh, axis_name=axis_name)
    raise ValueError(f"unknown runner mode {mode!r}")
