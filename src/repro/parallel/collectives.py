"""Collective-algorithm building blocks beyond the stock psum.

``ring_all_reduce`` — reduce-scatter + all-gather ring built from
``lax.ppermute``. Two uses: (a) on meshes whose native all-reduce is not
overlappable, the ring exposes per-chunk boundaries the compiler can
interleave with compute (the classic overlap trick); (b) composes with
quantization per hop (``compressed`` flag -> int8 payload per step, the
pPITC summary aggregation in low precision with error feedback handled by
the caller).

``overlapped_psum_pair`` — starts the big message before computing the
small one so the compiler can overlap (structure-level hint; on TPU XLA
schedules the async pair around the intervening compute).
"""
from __future__ import annotations

import jax
import jax.numpy as jnp


def ring_all_reduce(x: jax.Array, axis_name: str, *, axis_size: int,
                    compressed: bool = False) -> jax.Array:
    """Ring all-reduce over a named axis. ``x``'s leading dim must divide
    into axis_size chunks."""
    n = axis_size
    if n == 1:
        return x
    pad = (-x.shape[0]) % n
    xp = jnp.pad(x, [(0, pad)] + [(0, 0)] * (x.ndim - 1))
    chunks = xp.reshape((n, -1) + xp.shape[1:])
    idx = jax.lax.axis_index(axis_name)
    perm = [(i, (i + 1) % n) for i in range(n)]

    def maybe_q(v):
        if not compressed:
            return v, None
        scale = jnp.maximum(jnp.max(jnp.abs(v)), 1e-12) / 127.0
        return jnp.clip(jnp.round(v / scale), -127, 127).astype(jnp.int8), \
            scale

    def deq(q, scale):
        return q if scale is None else q.astype(x.dtype) * scale

    # reduce-scatter phase: after n-1 hops, chunk (idx+1) holds the full sum
    acc = chunks
    for step in range(n - 1):
        send_i = (idx - step) % n
        payload = jnp.take(acc, send_i, axis=0)
        q, scale = maybe_q(payload)
        recv = jax.lax.ppermute(q, axis_name, perm)
        scale_r = (jax.lax.ppermute(scale, axis_name, perm)
                   if scale is not None else None)
        recv_i = (idx - step - 1) % n
        acc = acc.at[recv_i].add(deq(recv, scale_r).astype(acc.dtype))

    # all-gather phase: circulate the finished chunks
    out = acc
    for step in range(n - 1):
        send_i = (idx + 1 - step) % n
        payload = jnp.take(out, send_i, axis=0)
        recv = jax.lax.ppermute(payload, axis_name, perm)
        recv_i = (idx - step) % n
        out = out.at[recv_i].set(recv)

    flat = out.reshape((-1,) + x.shape[1:])
    return flat[:x.shape[0]]


def overlapped_psum_pair(big: jax.Array, small: jax.Array, axis_name):
    """psum both; ordering hint — big first so its collective can fly while
    the small one's producers run."""
    big_r = jax.lax.psum(big, axis_name)
    small_r = jax.lax.psum(small, axis_name)
    return big_r, small_r
