from repro.parallel.runner import (Runner, ShardMapRunner, VmapRunner,  # noqa
                                   make_runner)
