"""gemma3-4b [dense] — 5:1 local:global attention (window 1024), 128k ctx
[hf:google/gemma-3-1b-pt scaled; unverified]."""
from repro.configs.base import LayerDesc, ModelConfig

_LOCAL = LayerDesc(kind="attn", window=1024)
_GLOBAL = LayerDesc(kind="attn", window=None)

CONFIG = ModelConfig(
    name="gemma3-4b", family="dense",
    n_layers=34, d_model=2560, n_heads=8, n_kv_heads=4,
    d_ff=10240, vocab=262144, head_dim=256,
    layer_pattern=(_LOCAL, _LOCAL, _LOCAL, _LOCAL, _LOCAL, _GLOBAL),
    rope_theta=1e6, tie_embeddings=True, max_seq=131072,
)
