"""Architecture registry: --arch <id> -> ModelConfig, plus reduced smoke
configs for CPU tests."""
from __future__ import annotations

import dataclasses

from repro.configs import (deepseek_coder_33b, gemma3_4b, jamba_1p5_large,
                           mamba2_130m, mixtral_8x22b, olmo_1b, qwen2_vl_72b,
                           qwen3_1p7b, qwen3_moe_30b_a3b, whisper_medium)
from repro.configs.base import ModelConfig

_MODULES = (mixtral_8x22b, qwen3_moe_30b_a3b, qwen2_vl_72b, mamba2_130m,
            gemma3_4b, qwen3_1p7b, deepseek_coder_33b, olmo_1b,
            whisper_medium, jamba_1p5_large)

REGISTRY: dict[str, ModelConfig] = {m.CONFIG.name: m.CONFIG
                                    for m in _MODULES}
ARCH_NAMES = tuple(REGISTRY)


def get_config(name: str) -> ModelConfig:
    try:
        return REGISTRY[name]
    except KeyError:
        raise ValueError(f"unknown arch {name!r}; have {sorted(REGISTRY)}")


def smoke_config(name: str) -> ModelConfig:
    """Reduced same-family config: small widths, few experts, tiny vocab —
    one full pattern period (+1 remainder layer when the full model has one)
    so heterogeneous stacks exercise both the scan and the remainder path."""
    cfg = get_config(name)
    period = cfg.period
    n_layers = period + (1 if cfg.n_layers % period else 0)
    n_layers = max(n_layers, 2)
    heads = 4 if cfg.n_heads else 0
    kv = min(max(cfg.n_kv_heads and 2, 0), heads) if cfg.n_kv_heads else 0
    return dataclasses.replace(
        cfg,
        name=cfg.name + "-smoke",
        n_layers=n_layers,
        d_model=64,
        n_heads=heads,
        n_kv_heads=kv,
        head_dim=16 if heads else 1,
        d_ff=0 if cfg.d_ff == 0 else 128,
        moe_d_ff=128 if cfg.moe_experts else 0,
        vocab=256,
        moe_experts=4 if cfg.moe_experts else 0,
        moe_top_k=2 if cfg.moe_experts else 0,
        ssm_state=16 if cfg.ssm_state else 0,
        ssm_headdim=16 if cfg.ssm_state else 64,
        ssm_chunk=8,
        enc_layers=2 if cfg.enc_dec else 0,
        enc_seq=24 if cfg.enc_dec else cfg.enc_seq,
        mrope_sections=(4, 2, 2) if cfg.mrope else cfg.mrope_sections,
        max_seq=128,
    )
