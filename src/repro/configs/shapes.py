"""Assigned input shapes (one set, shared by all 10 LM archs) and the
per-arch applicability rules for the 40-cell dry-run matrix."""
from __future__ import annotations

import dataclasses


@dataclasses.dataclass(frozen=True)
class ShapeSpec:
    name: str
    seq_len: int
    global_batch: int
    kind: str            # "train" | "prefill" | "decode"


SHAPES = {
    "train_4k": ShapeSpec("train_4k", 4096, 256, "train"),
    "prefill_32k": ShapeSpec("prefill_32k", 32768, 32, "prefill"),
    "decode_32k": ShapeSpec("decode_32k", 32768, 128, "decode"),
    "long_500k": ShapeSpec("long_500k", 524288, 1, "decode"),
}

# long_500k needs sub-quadratic / bounded attention state. Allowed:
#   SSM (mamba2), hybrid (jamba), SWA-dominant (gemma3 5:1 local, mixtral SWA).
# Pure full-attention archs + enc-dec whisper skip it (DESIGN.md §skips).
LONG_OK = {"mamba2-130m", "jamba-1.5-large-398b", "gemma3-4b",
           "mixtral-8x22b"}


def applicable(arch_name: str, shape: str) -> bool:
    if shape == "long_500k":
        return arch_name in LONG_OK
    return True


def cells(arch_names):
    """All (arch, shape) dry-run cells incl. skip markers."""
    out = []
    for a in arch_names:
        for s in SHAPES:
            out.append((a, s, applicable(a, s)))
    return out
