"""qwen2-vl-72b [vlm] — M-RoPE, dynamic-resolution vision frontend (STUB:
input_specs provides precomputed patch embeddings) [arXiv:2409.12191; hf]."""
from repro.configs.base import LayerDesc, ModelConfig

CONFIG = ModelConfig(
    name="qwen2-vl-72b", family="vlm",
    n_layers=80, d_model=8192, n_heads=64, n_kv_heads=8,
    d_ff=29568, vocab=152064,
    layer_pattern=(LayerDesc(kind="attn"),),
    mrope=True, mrope_sections=(16, 24, 24),
    rope_theta=1e6, max_seq=32768, frontend="vision",
)
