"""jamba-1.5-large-398b [hybrid] — Mamba+attention 1:7 interleave
(attention at offset 4, period 8), MoE 16e top-2 every other layer
[arXiv:2403.19887; hf]."""
from repro.configs.base import LayerDesc, ModelConfig

def _desc(i: int) -> LayerDesc:
    kind = "attn" if i % 8 == 4 else "ssm"
    return LayerDesc(kind=kind, moe=(i % 2 == 1))

CONFIG = ModelConfig(
    name="jamba-1.5-large-398b", family="hybrid",
    n_layers=72, d_model=8192, n_heads=64, n_kv_heads=8,
    d_ff=24576, vocab=65536,
    layer_pattern=tuple(_desc(i) for i in range(8)),
    moe_experts=16, moe_top_k=2,
    ssm_state=128, ssm_expand=2, ssm_headdim=64,
    max_seq=262144,
)
