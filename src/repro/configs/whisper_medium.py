"""whisper-medium [audio] — enc-dec; conv frontend is a STUB (input_specs
provides precomputed frame embeddings) [arXiv:2212.04356; unverified]."""
from repro.configs.base import LayerDesc, ModelConfig

CONFIG = ModelConfig(
    name="whisper-medium", family="audio",
    n_layers=24, d_model=1024, n_heads=16, n_kv_heads=16,
    d_ff=4096, vocab=51865,
    layer_pattern=(LayerDesc(kind="attn"),),
    enc_dec=True, enc_layers=24, enc_seq=1500,
    frontend="audio", max_seq=448,
)
