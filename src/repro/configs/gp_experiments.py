"""The paper's experiment grid (Sec. 6), as config objects.

Values from the text: |D| in {8000, 16000, 24000, 32000}; M in
{4, 8, 12, 16, 20}; P = |S| = R in {256, 512, 1024, 2048} (R doubled for
SARCOS); test fraction 10%; hyperparameters by MLE on a 10000 subset.
``scaled_grid`` shrinks everything by a factor for CPU-container benches
while preserving the ratios the figures sweep.
"""
from __future__ import annotations

import dataclasses


@dataclasses.dataclass(frozen=True)
class GPExperiment:
    domain: str                  # "aimpeak" | "sarcos"
    data_sizes: tuple            # |D| sweep (Fig 1)
    machines: tuple              # M sweep (Fig 2)
    params: tuple                # P = |S| sweep (Fig 3)
    rank_multiplier: int         # R = mult * |S| (SARCOS uses 2, Sec. 6)
    fixed_data: int              # |D| for Figs 2-3
    fixed_machines: int          # M for Figs 1,3
    fixed_param: int             # |S| for Figs 1-2
    input_dim: int
    mle_subset: int = 10000


PAPER_GRID = {
    "aimpeak": GPExperiment(
        domain="aimpeak",
        data_sizes=(8000, 16000, 24000, 32000),
        machines=(4, 8, 12, 16, 20),
        params=(256, 512, 1024, 2048),
        rank_multiplier=1,
        fixed_data=32000, fixed_machines=20, fixed_param=2048,
        input_dim=5),
    "sarcos": GPExperiment(
        domain="sarcos",
        data_sizes=(8000, 16000, 24000, 32000),
        machines=(4, 8, 12, 16, 20),
        params=(256, 512, 1024, 2048),
        rank_multiplier=2,
        fixed_data=32000, fixed_machines=20, fixed_param=2048,
        input_dim=21),
}


def scaled_grid(domain: str, factor: int = 8) -> GPExperiment:
    """CPU-container scale-down preserving sweep ratios (factor 8:
    |D| 1000-4000, P 32-256, M 4-16)."""
    g = PAPER_GRID[domain]
    return dataclasses.replace(
        g,
        data_sizes=tuple(max(n // factor, 512) for n in g.data_sizes),
        machines=tuple(m for m in g.machines if m <= 16),
        params=tuple(max(p // factor, 32) for p in g.params),
        fixed_data=max(g.fixed_data // factor, 2048),
        fixed_machines=8,
        fixed_param=max(g.fixed_param // factor, 128),
        mle_subset=max(g.mle_subset // factor, 512))
