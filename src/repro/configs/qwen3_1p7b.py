"""qwen3-1.7b [dense] — qk-norm, GQA [hf:Qwen/Qwen3-1.7B; hf]."""
from repro.configs.base import LayerDesc, ModelConfig

CONFIG = ModelConfig(
    name="qwen3-1.7b", family="dense",
    n_layers=28, d_model=2048, n_heads=16, n_kv_heads=8,
    d_ff=6144, vocab=151936, head_dim=128,
    layer_pattern=(LayerDesc(kind="attn"),),
    qk_norm=True, rope_theta=1e6, tie_embeddings=True, max_seq=32768,
)
