"""qwen3-moe-30b-a3b [moe] — 128 experts top-8, qk-norm
[hf:Qwen/Qwen3-30B-A3B; hf]."""
from repro.configs.base import LayerDesc, ModelConfig

CONFIG = ModelConfig(
    name="qwen3-moe-30b-a3b", family="moe",
    n_layers=48, d_model=2048, n_heads=32, n_kv_heads=4,
    d_ff=768, vocab=151936, head_dim=128,
    layer_pattern=(LayerDesc(kind="attn", moe=True),),
    moe_experts=128, moe_top_k=8, moe_d_ff=768,
    qk_norm=True, rope_theta=1e6, max_seq=32768,
)
