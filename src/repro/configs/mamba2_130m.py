"""mamba2-130m [ssm] — SSD (state-space duality), attention-free
[arXiv:2405.21060; unverified]."""
from repro.configs.base import LayerDesc, ModelConfig

CONFIG = ModelConfig(
    name="mamba2-130m", family="ssm",
    n_layers=24, d_model=768, n_heads=0, n_kv_heads=0, head_dim=1,
    d_ff=0, vocab=50280,
    layer_pattern=(LayerDesc(kind="ssm"),),
    ssm_state=128, ssm_expand=2, ssm_headdim=64,
    tie_embeddings=True, max_seq=1048576,
)
