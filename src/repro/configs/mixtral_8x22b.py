"""mixtral-8x22b [moe] — 8 experts top-2, SWA [arXiv:2401.04088; hf]."""
from repro.configs.base import LayerDesc, ModelConfig

CONFIG = ModelConfig(
    name="mixtral-8x22b", family="moe",
    n_layers=56, d_model=6144, n_heads=48, n_kv_heads=8,
    d_ff=16384, vocab=32768,
    layer_pattern=(LayerDesc(kind="attn", window=4096, moe=True),),
    moe_experts=8, moe_top_k=2,
    rope_theta=1e6, max_seq=65536,
)
