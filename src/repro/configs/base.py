"""Model configuration schema.

A ModelConfig fully determines the architecture. Heterogeneous stacks
(hybrid SSM/attention, local:global window ratios, MoE interleaves) are
expressed via ``layer_pattern`` — a repeating period of LayerDesc entries;
models/transformer.py scans over full periods (compile-time-compact HLO)
and unrolls the remainder (n_layers % len(pattern)).
"""
from __future__ import annotations

import dataclasses
from typing import Optional


@dataclasses.dataclass(frozen=True)
class LayerDesc:
    kind: str = "attn"               # "attn" | "ssm"
    window: Optional[int] = None     # sliding-window size (None = global)
    moe: bool = False                # MoE FFN instead of dense MLP


@dataclasses.dataclass(frozen=True)
class ModelConfig:
    name: str
    family: str                      # dense | moe | ssm | hybrid | vlm | audio
    n_layers: int
    d_model: int
    n_heads: int
    n_kv_heads: int
    d_ff: int
    vocab: int
    head_dim: int = 0                # 0 -> d_model // n_heads

    # layer pattern (repeated); default: homogeneous global attention
    layer_pattern: tuple = (LayerDesc(),)

    # MoE
    moe_experts: int = 0
    moe_top_k: int = 0
    moe_d_ff: int = 0                # 0 -> d_ff
    capacity_factor: float = 1.25
    moe_dispatch: str = "einsum"     # "einsum" | "gather" (see models/moe.py)

    # SSM (Mamba-2 SSD)
    ssm_state: int = 0
    ssm_expand: int = 2
    ssm_headdim: int = 64
    ssm_conv: int = 4
    ssm_chunk: int = 256

    # attention details
    qk_norm: bool = False
    nonparametric_ln: bool = False   # OLMo-style LN without params
    mrope: bool = False              # Qwen2-VL multimodal RoPE
    mrope_sections: tuple = (16, 24, 24)
    rope_theta: float = 1e4
    norm_eps: float = 1e-6
    tie_embeddings: bool = False

    # encoder-decoder (whisper)
    enc_dec: bool = False
    enc_layers: int = 0
    enc_seq: int = 1500              # encoder frames (audio stub length)

    # modality frontend stub: None | "audio" | "vision"
    frontend: Optional[str] = None

    # training defaults
    max_seq: int = 8192

    def __post_init__(self):
        if self.head_dim == 0:
            object.__setattr__(self, "head_dim",
                               self.d_model // self.n_heads)
        if self.moe_experts and self.moe_d_ff == 0:
            object.__setattr__(self, "moe_d_ff", self.d_ff)

    @property
    def vocab_padded(self) -> int:
        """Embedding-table rows padded to a 256 multiple: unpadded vocabs
        (e.g. mamba2's 50280) cannot vocab-shard on a 16-way TP axis, which
        forces a full-logits all-reduce over DP (observed 211 GB/step in the
        baseline dry-run — §Perf iteration 3)."""
        return -(-self.vocab // 256) * 256

    @property
    def period(self) -> int:
        return len(self.layer_pattern)

    def plan(self) -> list[LayerDesc]:
        """Per-layer descriptors for the full stack."""
        reps = -(-self.n_layers // self.period)
        return (list(self.layer_pattern) * reps)[:self.n_layers]

    def scaled(self, **overrides) -> "ModelConfig":
        """Reduced config of the same family (smoke tests)."""
        return dataclasses.replace(self, **overrides)

    # ---- parameter counting (roofline MODEL_FLOPS = 6*N*D) ----
    def param_counts(self) -> dict:
        d, hd = self.d_model, self.head_dim
        attn = d * hd * (self.n_heads * 2 + self.n_kv_heads * 2)
        mlp_dense = 3 * d * self.d_ff
        moe_total = self.moe_experts * 3 * d * self.moe_d_ff + d * self.moe_experts
        moe_active = self.moe_top_k * 3 * d * self.moe_d_ff + d * self.moe_experts
        d_inner = self.ssm_expand * d
        H = d_inner // self.ssm_headdim if self.ssm_state else 0
        ssm = (d * (2 * d_inner + 2 * self.ssm_state + H)
               + self.ssm_conv * (d_inner + 2 * self.ssm_state)
               + 3 * H + d_inner + d_inner * d) if self.ssm_state else 0
        total = active = 0
        for desc in self.plan():
            blk = attn if desc.kind == "attn" else ssm
            ffn_t = moe_total if desc.moe else mlp_dense
            ffn_a = moe_active if desc.moe else mlp_dense
            total += blk + ffn_t
            active += blk + ffn_a
        emb = self.vocab_padded * d * (1 if self.tie_embeddings else 2)
        enc = cross = 0
        if self.enc_dec:
            # encoder layers (attn + dense mlp) + cross-attn in decoder;
            # kept separate: encoder params see enc_seq tokens, not T
            enc = self.enc_layers * (attn + mlp_dense)
            cross = self.n_layers * attn
            total += enc + cross
            active += enc + cross
        return {"total": total + emb, "active": active + emb,
                "embedding": emb, "encoder": enc, "cross": cross}
