"""Dependency-free AST lint engine.

The engine is deliberately boring: it parses each file once, hands the
module to every rule, and collects :class:`Finding` objects.  All the
repo-specific intelligence lives in :mod:`repro.analysis.rules`.  What the
engine owns is the workflow plumbing:

* **suppressions** — a ``# analysis: ignore`` comment (optionally scoped,
  ``# analysis: ignore[JIT001,DTY001]``) on the flagged line or the line
  directly above silences the finding.  Scoped suppressions are preferred;
  a bare ``ignore`` silences every rule on that line.
* **baseline** — pre-existing findings can be checked into a JSON baseline
  so the CLI only fails on NEW findings; fingerprints are
  ``(path, rule, stripped source line)`` so ordinary line drift does not
  invalidate the baseline, while editing the flagged code does.
* **reporters** — ``to_text`` for humans, ``to_json`` for CI artifacts.

Stdlib-only by design: the CI lint job runs this without jax installed.
"""
from __future__ import annotations

import ast
import dataclasses
import json
import re
from pathlib import Path
from typing import Callable, Iterable, Sequence

__all__ = [
    "Finding", "ModuleInfo", "Rule", "parse_module", "run_rules",
    "load_baseline", "write_baseline", "new_findings", "to_text", "to_json",
]

_SUPPRESS_RE = re.compile(
    r"#\s*analysis:\s*ignore(?:\[(?P<rules>[A-Z0-9,\s]+)\])?")


@dataclasses.dataclass(frozen=True)
class Finding:
    """One rule violation at one source location."""
    rule: str
    path: str
    line: int        # 1-based
    col: int         # 0-based
    message: str
    snippet: str = ""

    @property
    def fingerprint(self) -> str:
        """Baseline identity: stable under line drift, invalidated when
        the flagged source line itself changes."""
        return f"{self.path}::{self.rule}::{self.snippet.strip()}"

    def format(self) -> str:
        return (f"{self.path}:{self.line}:{self.col + 1}: "
                f"{self.rule} {self.message}")


@dataclasses.dataclass
class ModuleInfo:
    """A parsed source file as rules see it."""
    path: str          # repo-relative posix path, e.g. src/repro/core/api.py
    source: str
    tree: ast.Module
    lines: list[str] = dataclasses.field(default_factory=list)

    def __post_init__(self):
        if not self.lines:
            self.lines = self.source.splitlines()

    def snippet(self, node: ast.AST) -> str:
        ln = getattr(node, "lineno", 0)
        if 1 <= ln <= len(self.lines):
            return self.lines[ln - 1]
        return ""

    def finding(self, rule: str, node: ast.AST, message: str) -> Finding:
        return Finding(rule=rule, path=self.path,
                       line=getattr(node, "lineno", 0),
                       col=getattr(node, "col_offset", 0),
                       message=message, snippet=self.snippet(node))


# A rule is anything with .name and .check(module) -> iterable of findings.
class Rule:
    name = "RULE000"

    def check(self, module: ModuleInfo) -> Iterable[Finding]:  # pragma: no cover
        raise NotImplementedError


# -- suppression ------------------------------------------------------------

def _suppressed_rules(line: str) -> set[str] | None:
    """None = no suppression on this line; empty set = bare ``ignore``
    (suppresses everything); otherwise the named rules."""
    m = _SUPPRESS_RE.search(line)
    if m is None:
        return None
    rules = m.group("rules")
    if rules is None:
        return set()
    return {r.strip() for r in rules.split(",") if r.strip()}

def is_suppressed(finding: Finding, lines: Sequence[str]) -> bool:
    """A finding is suppressed by a marker on its own line or the line
    directly above (for when the flagged line has no room)."""
    for ln in (finding.line, finding.line - 1):
        if 1 <= ln <= len(lines):
            rules = _suppressed_rules(lines[ln - 1])
            if rules is not None and (not rules or finding.rule in rules):
                return True
    return False


# -- driver -----------------------------------------------------------------

def parse_module(path: Path, root: Path | None = None) -> ModuleInfo | None:
    """Parse one file; None when it is not valid Python (reported by the
    caller as a hard error, not a finding)."""
    source = path.read_text()
    rel = path.as_posix()
    if root is not None:
        try:
            rel = path.resolve().relative_to(root.resolve()).as_posix()
        except ValueError:
            pass

    try:
        tree = ast.parse(source, filename=rel)
    except SyntaxError:
        return None
    return ModuleInfo(path=rel, source=source, tree=tree)


def iter_py_files(paths: Sequence[Path]) -> Iterable[Path]:
    for p in paths:
        if p.is_dir():
            yield from sorted(p.rglob("*.py"))
        elif p.suffix == ".py":
            yield p


def run_rules(paths: Sequence[Path], rules: Sequence[Rule], *,
              root: Path | None = None,
              on_error: Callable[[Path], None] | None = None
              ) -> list[Finding]:
    """Run every rule over every ``*.py`` under ``paths``; suppressed
    findings are dropped here so callers only ever see actionable ones."""
    findings: list[Finding] = []
    for path in iter_py_files(paths):
        mod = parse_module(path, root)
        if mod is None:
            if on_error is not None:
                on_error(path)
            continue
        for rule in rules:
            for f in rule.check(mod):
                if not is_suppressed(f, mod.lines):
                    findings.append(f)
    findings.sort(key=lambda f: (f.path, f.line, f.col, f.rule))
    return findings


# -- baseline ---------------------------------------------------------------

def load_baseline(path: Path) -> set[str]:
    if not path.exists():
        return set()
    data = json.loads(path.read_text())
    return set(data.get("findings", []))


def write_baseline(path: Path, findings: Sequence[Finding]) -> None:
    data = {
        "comment": "Known findings burned down deliberately; regenerate "
                   "with `python -m repro.analysis --write-baseline`.",
        "findings": sorted({f.fingerprint for f in findings}),
    }
    path.write_text(json.dumps(data, indent=2) + "\n")


def new_findings(findings: Sequence[Finding],
                 baseline: set[str]) -> list[Finding]:
    return [f for f in findings if f.fingerprint not in baseline]


# -- reporters --------------------------------------------------------------

def to_text(findings: Sequence[Finding]) -> str:
    if not findings:
        return "analysis: clean (0 findings)"
    out = [f.format() + "\n    " + f.snippet.strip() for f in findings]
    out.append(f"analysis: {len(findings)} finding(s)")
    return "\n".join(out)


def to_json(findings: Sequence[Finding]) -> str:
    return json.dumps(
        {"n_findings": len(findings),
         "findings": [dataclasses.asdict(f) for f in findings]},
        indent=2)
