"""Static analysis for the serving stack's compiled-program invariants.

The runtime guarantees this repo leans on — zero recompiles across
rebinds/tenants, tracer-safe state transitions, a single serving dtype,
seeded determinism — are enforced dynamically by the test suites, but a
tracer-safety bug (``TracerBoolConversionError`` in ``PICStore.to_state``)
still reached main before PR 7 hot-fixed it.  This package is the static
half of the enforcement:

* :mod:`repro.analysis.engine` — a dependency-free AST lint engine
  (per-rule visitors, ``# analysis: ignore[RULE]`` suppressions,
  text/JSON reporters, a checked-in baseline file);
* :mod:`repro.analysis.rules` — the repo-specific rules (JIT001..JIT003,
  DTY001, DET001, FRZ001);
* :mod:`repro.analysis.contracts` — the compiled-program contract
  auditor: jaxpr fingerprints for every ServePlan executable, a
  ``@no_retrace`` registry, and rebind/tenant interleaving audits that
  prove the zero-recompile claim structurally;
* ``python -m repro.analysis`` — the CLI that runs the lint pass (and,
  with ``--contracts``, the auditor) over ``src/`` and exits nonzero on
  new findings.

``engine`` and ``rules`` are stdlib-only on purpose: the CI lint job can
run them without installing jax.  ``contracts`` imports jax lazily.
"""
from repro.analysis.engine import (  # noqa: F401
    Finding,
    ModuleInfo,
    load_baseline,
    run_rules,
    to_json,
    to_text,
    write_baseline,
)
