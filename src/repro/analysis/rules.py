"""Repo-specific lint rules over the jit-safety / determinism invariants.

Every rule here encodes a bug class this repo has actually hit or is one
refactor away from hitting (see docs/invariants.md):

* **JIT001** — Python truthiness on likely-traced values (the PR-7
  ``PICStore.to_state`` ``TracerBoolConversionError`` class).  A function
  that explicitly branches on ``isinstance(x, jax.core.Tracer)`` has
  already confronted the tracer case and is exempt — that is exactly the
  shape of the PR-7 fix.
* **JIT002** — host-sync calls (``.item()``, ``bool()``, ``np.asarray``)
  inside functions that are jitted in this module.
* **JIT003** — Python scalar literals passed to a jitted callable with no
  static markings: each distinct Python type re-specializes the
  executable, which silently violates the zero-recompile budget.
* **DTY001** — float64 ``astype``/``dtype=`` leaking into the f32 serving
  path against the ``ServeSpec`` dtype policy.
* **DET001** — unseeded RNG / wall-clock calls in modules that promise
  deterministic replay (chaos, health, stats, scheduler).
* **FRZ001** — mutation of frozen plan/spec dataclasses (use
  ``dataclasses.replace`` instead).

Rules are path-scoped with substring prefixes so test fixtures can opt in
by using a matching fake path.
"""
from __future__ import annotations

import ast
from typing import Iterable, Iterator

from repro.analysis.engine import Finding, ModuleInfo, Rule

__all__ = ["ALL_RULES", "default_rules", "JIT001", "JIT002", "JIT003",
           "DTY001", "DET001", "FRZ001"]


# -- shared AST helpers -----------------------------------------------------

def _dotted(node: ast.AST) -> str:
    """``a.b.c`` for Attribute/Name chains, '' for anything else."""
    parts = []
    while isinstance(node, ast.Attribute):
        parts.append(node.attr)
        node = node.value
    if isinstance(node, ast.Name):
        parts.append(node.id)
        return ".".join(reversed(parts))
    return ""


def _functions(tree: ast.Module) -> Iterator[ast.FunctionDef]:
    for node in ast.walk(tree):
        if isinstance(node, (ast.FunctionDef, ast.AsyncFunctionDef)):
            yield node


def _handles_tracers(fn: ast.AST) -> bool:
    """True when the function already branches on the tracer-ness of a
    value — `isinstance(x, jax.core.Tracer)` (possibly inside a type
    tuple), or a call to the sanctioned `api.concrete_alive_mask` guard.
    That is the shape of every deliberate host/trace split in this repo,
    so the whole function is exempt from JIT001."""
    for node in ast.walk(fn):
        if not isinstance(node, ast.Call):
            continue
        if _dotted(node.func).endswith("concrete_alive_mask"):
            return True
        if (isinstance(node.func, ast.Name)
                and node.func.id == "isinstance" and len(node.args) == 2):
            types = node.args[1]
            cands = types.elts if isinstance(types, ast.Tuple) else [types]
            for c in cands:
                if _dotted(c).endswith("Tracer"):
                    return True
    return False


def _in_scope(path: str, prefixes: tuple[str, ...]) -> bool:
    return any(p in path for p in prefixes)


# -- JIT001 -----------------------------------------------------------------

class JIT001(Rule):
    """Python truthiness/branching on likely-traced mask values.

    Flags ``if``/``while``/``assert``/``bool()``/``not``/ternary tests
    whose expression touches a store mask (``.alive`` / ``.block_alive`` /
    ``.mask``) or reduces one with ``.all()``/``.any()`` — evaluating such
    a test under ``jax.jit`` raises ``TracerBoolConversionError`` at the
    first traced call (the PR-7 ``PICStore.to_state`` bug).  Functions
    that already split on ``isinstance(..., Tracer)`` are exempt.
    """
    name = "JIT001"
    SCOPE = ("repro/core/", "repro/kernels/", "repro/parallel/")
    MASK_ATTRS = frozenset({"alive", "block_alive", "mask", "dead"})
    MASK_NAMES = frozenset({"alive", "dead", "mask"})

    def _suspicious(self, expr: ast.AST) -> bool:
        for node in ast.walk(expr):
            # store.alive, self.alive, st.block_alive — attribute access on
            # anything; plain Name masks are deliberately not matched so
            # host-side `mask[machine]` after a tracer guard stays clean.
            if isinstance(node, ast.Attribute) and node.attr in self.MASK_ATTRS:
                return True
            if isinstance(node, ast.Call):
                f = node.func
                # x.all() / x.any() where x is (or contains) a mask
                if isinstance(f, ast.Attribute) and f.attr in ("all", "any"):
                    for sub in ast.walk(f.value):
                        if (isinstance(sub, ast.Attribute)
                                and sub.attr in self.MASK_ATTRS):
                            return True
                        if (isinstance(sub, ast.Name)
                                and sub.id in self.MASK_NAMES):
                            return True
                # np.all(mask) / jnp.any(store.alive)
                if _dotted(f) in ("np.all", "np.any", "jnp.all", "jnp.any",
                                  "numpy.all", "numpy.any",
                                  "jax.numpy.all", "jax.numpy.any"):
                    for arg in node.args:
                        for sub in ast.walk(arg):
                            if ((isinstance(sub, ast.Attribute)
                                 and sub.attr in self.MASK_ATTRS)
                                    or (isinstance(sub, ast.Name)
                                        and sub.id in self.MASK_NAMES)):
                                return True
        return False

    def check(self, module: ModuleInfo) -> Iterable[Finding]:
        if not _in_scope(module.path, self.SCOPE):
            return
        flagged: set[int] = set()   # one finding per source line
        for fn in _functions(module.tree):
            if _handles_tracers(fn):
                continue
            for node in ast.walk(fn):
                tests: list[ast.AST] = []
                if isinstance(node, (ast.If, ast.While, ast.IfExp)):
                    tests.append(node.test)
                elif isinstance(node, ast.Assert):
                    tests.append(node.test)
                elif isinstance(node, ast.UnaryOp) and isinstance(node.op, ast.Not):
                    tests.append(node.operand)
                elif (isinstance(node, ast.Call)
                      and isinstance(node.func, ast.Name)
                      and node.func.id == "bool" and node.args):
                    tests.append(node.args[0])
                for t in tests:
                    ln = getattr(node, "lineno", 0)
                    if ln not in flagged and self._suspicious(t):
                        flagged.add(ln)
                        yield module.finding(
                            self.name, node,
                            "Python truthiness on a possibly-traced mask "
                            "(TracerBoolConversionError under jit — the "
                            "PR-7 to_state bug class); guard with "
                            "isinstance(x, jax.core.Tracer) or stay in "
                            "jnp.where")
                        break   # one finding per statement


# -- JIT002 -----------------------------------------------------------------

def _jitted_functions(tree: ast.Module) -> Iterator[ast.AST]:
    """Function bodies that execute under jit in this module: defs with a
    jit decorator, defs later wrapped as ``g = jax.jit(f)``, and lambdas
    passed to ``jax.jit`` inline."""
    defs = {}
    for node in ast.walk(tree):
        if isinstance(node, (ast.FunctionDef, ast.AsyncFunctionDef)):
            defs[node.name] = node
            for dec in node.decorator_list:
                d = dec.func if isinstance(dec, ast.Call) else dec
                name = _dotted(d)
                if name in ("jit", "jax.jit"):
                    yield node
                elif name in ("partial", "functools.partial") and \
                        isinstance(dec, ast.Call) and dec.args and \
                        _dotted(dec.args[0]) in ("jit", "jax.jit"):
                    yield node
    for node in ast.walk(tree):
        if isinstance(node, ast.Call) and _dotted(node.func) in ("jit", "jax.jit"):
            for arg in node.args[:1]:
                if isinstance(arg, ast.Lambda):
                    yield arg
                elif isinstance(arg, ast.Name) and arg.id in defs:
                    yield defs[arg.id]


class JIT002(Rule):
    """Host-synchronizing calls inside a function jitted in this module:
    ``.item()``/``.tolist()``, ``bool()/int()/float()`` on non-literals,
    and ``np.asarray``/``np.array`` staging (TracerArrayConversionError
    or a silent trace-time constant)."""
    name = "JIT002"
    NP_STAGING = frozenset({"np.asarray", "np.array", "numpy.asarray",
                            "numpy.array", "onp.asarray", "onp.array"})

    def check(self, module: ModuleInfo) -> Iterable[Finding]:
        seen: set[int] = set()
        for fn in _jitted_functions(module.tree):
            if id(fn) in seen:
                continue
            seen.add(id(fn))
            for node in ast.walk(fn):
                if not isinstance(node, ast.Call):
                    continue
                f = node.func
                if isinstance(f, ast.Attribute) and f.attr in ("item", "tolist"):
                    yield module.finding(
                        self.name, node,
                        f".{f.attr}() forces a host sync inside a jitted "
                        "function")
                elif (isinstance(f, ast.Name) and f.id in ("bool", "int", "float")
                      and node.args
                      and not isinstance(node.args[0], ast.Constant)):
                    yield module.finding(
                        self.name, node,
                        f"{f.id}() on a traced value forces a host sync "
                        "inside a jitted function")
                elif _dotted(f) in self.NP_STAGING:
                    yield module.finding(
                        self.name, node,
                        f"{_dotted(f)}() stages through host numpy inside "
                        "a jitted function (TracerArrayConversionError or "
                        "a baked-in constant)")


# -- JIT003 -----------------------------------------------------------------

class JIT003(Rule):
    """Python scalar literals passed to a jitted callable that has no
    static_argnums/static_argnames: each distinct Python type (int vs
    float vs bool) re-specializes the compiled program — a silent
    recompile.  Pass a jnp array or mark the argument static."""
    name = "JIT003"

    def check(self, module: ModuleInfo) -> Iterable[Finding]:
        jitted: set[str] = set()
        for node in ast.walk(module.tree):
            if not isinstance(node, ast.Assign):
                continue
            call = node.value
            if (isinstance(call, ast.Call)
                    and _dotted(call.func) in ("jit", "jax.jit")
                    and not any(kw.arg in ("static_argnums", "static_argnames")
                                for kw in call.keywords)):
                for tgt in node.targets:
                    if isinstance(tgt, ast.Name):
                        jitted.add(tgt.id)
        if not jitted:
            return
        for node in ast.walk(module.tree):
            if (isinstance(node, ast.Call) and isinstance(node.func, ast.Name)
                    and node.func.id in jitted):
                for arg in node.args:
                    v = arg.operand if (isinstance(arg, ast.UnaryOp)
                                        and isinstance(arg.op, ast.USub)) else arg
                    if isinstance(v, ast.Constant) and \
                            isinstance(v.value, (bool, int, float)):
                        yield module.finding(
                            self.name, node,
                            f"Python scalar literal passed to jitted "
                            f"'{node.func.id}' (no static markings): type "
                            "changes silently retrigger compilation")
                        break


# -- DTY001 -----------------------------------------------------------------

class DTY001(Rule):
    """float64 ``astype``/``dtype=`` in a serving-path module, against the
    ServeSpec dtype policy (serving is f32 end-to-end; f64 is the offline
    reference dtype).  Dtype-conditional ternaries that inspect an input's
    ``.dtype`` are exempt — mirroring the caller's dtype is the policy."""
    name = "DTY001"
    SCOPE = ("repro/serving/", "repro/launch/", "repro/kernels/",
             "repro/core/api.py", "repro/core/ppic.py")

    @staticmethod
    def _is_f64(node: ast.AST) -> bool:
        for sub in ast.walk(node):
            if isinstance(sub, ast.Constant) and sub.value == "float64":
                return True
            if isinstance(sub, (ast.Attribute, ast.Name)) and \
                    _dotted(sub).split(".")[-1] == "float64":
                return True
        return False

    def check(self, module: ModuleInfo) -> Iterable[Finding]:
        if not _in_scope(module.path, self.SCOPE):
            return
        # anything under a dtype-conditional ternary is policy-compliant
        exempt: set[int] = set()
        for node in ast.walk(module.tree):
            if isinstance(node, ast.IfExp) and any(
                    isinstance(s, ast.Attribute) and s.attr == "dtype"
                    for s in ast.walk(node.test)):
                for sub in ast.walk(node):
                    exempt.add(id(sub))
        for node in ast.walk(module.tree):
            if id(node) in exempt or not isinstance(node, ast.Call):
                continue
            if (isinstance(node.func, ast.Attribute)
                    and node.func.attr == "astype" and node.args
                    and self._is_f64(node.args[0])):
                yield module.finding(
                    self.name, node,
                    "astype(float64) in a serving-path module violates the "
                    "ServeSpec f32 dtype policy")
            for kw in node.keywords:
                if kw.arg == "dtype" and id(kw.value) not in exempt and \
                        self._is_f64(kw.value):
                    yield module.finding(
                        self.name, node,
                        "dtype=float64 in a serving-path module violates "
                        "the ServeSpec f32 dtype policy")


# -- DET001 -----------------------------------------------------------------

class DET001(Rule):
    """Unseeded RNG or wall-clock *calls* in deterministic-replay modules.
    References (e.g. ``sleep=time.sleep`` as an injectable default) are
    fine; calling the global clock or an unseeded sampler inside replay
    logic is not."""
    name = "DET001"
    SCOPE = ("repro/serving/chaos.py", "repro/serving/health.py",
             "repro/serving/stats.py", "repro/serving/scheduler.py")
    GLOBAL_SAMPLERS = frozenset({
        "rand", "randn", "randint", "random", "random_sample", "choice",
        "shuffle", "permutation", "normal", "uniform", "standard_normal"})
    CLOCKS = frozenset({"time.time", "time.monotonic", "time.perf_counter",
                        "time.time_ns", "time.monotonic_ns"})

    def check(self, module: ModuleInfo) -> Iterable[Finding]:
        if not _in_scope(module.path, self.SCOPE):
            return
        for node in ast.walk(module.tree):
            if not isinstance(node, ast.Call):
                continue
            name = _dotted(node.func)
            if name in self.CLOCKS:
                yield module.finding(
                    self.name, node,
                    f"{name}() call in a deterministic-replay module; "
                    "thread an injectable clock instead")
            elif name.startswith("random."):
                yield module.finding(
                    self.name, node,
                    f"stdlib global-RNG call {name}() breaks seeded "
                    "replay; use np.random.RandomState(seed)")
            elif name in ("np.random.RandomState", "numpy.random.RandomState",
                          "np.random.default_rng", "numpy.random.default_rng"):
                if not node.args and not node.keywords:
                    yield module.finding(
                        self.name, node,
                        f"{name}() without a seed breaks deterministic "
                        "replay")
            elif (name.startswith(("np.random.", "numpy.random."))
                  and name.split(".")[-1] in self.GLOBAL_SAMPLERS):
                yield module.finding(
                    self.name, node,
                    f"{name}() samples numpy's process-global RNG; use a "
                    "seeded RandomState")


# -- FRZ001 -----------------------------------------------------------------

class FRZ001(Rule):
    """Attribute assignment on a frozen plan/spec dataclass.  Frozen
    classes are collected from the module itself plus the repo's known
    frozen API types, so cross-module mutation of a ``spec``/``plan``
    parameter is caught too.  ``object.__setattr__`` is only legitimate
    inside ``__post_init__``."""
    name = "FRZ001"
    KNOWN_FROZEN = frozenset({
        "ServeSpec", "ServePlan", "PICServePlan", "GPMethod", "FittedGP",
        "HealthPolicy", "FaultPlan", "KernelSpec"})

    @staticmethod
    def _frozen_classes(tree: ast.Module) -> set[str]:
        out = set()
        for node in ast.walk(tree):
            if not isinstance(node, ast.ClassDef):
                continue
            for dec in node.decorator_list:
                if isinstance(dec, ast.Call) and \
                        _dotted(dec.func) in ("dataclass", "dataclasses.dataclass"):
                    for kw in dec.keywords:
                        if kw.arg == "frozen" and \
                                isinstance(kw.value, ast.Constant) and \
                                kw.value.value is True:
                            out.add(node.name)
        return out

    def check(self, module: ModuleInfo) -> Iterable[Finding]:
        local_frozen = self._frozen_classes(module.tree)
        frozen = local_frozen | self.KNOWN_FROZEN

        # 1. methods of locally-defined frozen classes
        for node in ast.walk(module.tree):
            if not (isinstance(node, ast.ClassDef) and node.name in local_frozen):
                continue
            for meth in node.body:
                if not isinstance(meth, (ast.FunctionDef, ast.AsyncFunctionDef)):
                    continue
                in_post_init = meth.name == "__post_init__"
                for sub in ast.walk(meth):
                    if isinstance(sub, (ast.Assign, ast.AugAssign)):
                        tgts = sub.targets if isinstance(sub, ast.Assign) \
                            else [sub.target]
                        for t in tgts:
                            if (isinstance(t, ast.Attribute)
                                    and isinstance(t.value, ast.Name)
                                    and t.value.id == "self"):
                                yield module.finding(
                                    self.name, sub,
                                    f"direct field assignment in frozen "
                                    f"dataclass {node.name} raises "
                                    "FrozenInstanceError; use "
                                    "dataclasses.replace")
                    if (not in_post_init and isinstance(sub, ast.Call)
                            and _dotted(sub.func) == "object.__setattr__"):
                        yield module.finding(
                            self.name, sub,
                            f"object.__setattr__ outside __post_init__ "
                            f"mutates frozen dataclass {node.name}")

        # 2. mutation through a variable known to hold a frozen instance
        for fn in _functions(module.tree):
            frozen_vars: set[str] = set()
            args = fn.args
            for a in (args.posonlyargs + args.args + args.kwonlyargs):
                if a.annotation is not None and \
                        _dotted(a.annotation).split(".")[-1] in frozen:
                    frozen_vars.add(a.arg)
            for sub in ast.walk(fn):
                if isinstance(sub, ast.Assign) and \
                        isinstance(sub.value, ast.Call) and \
                        _dotted(sub.value.func).split(".")[-1] in frozen:
                    for t in sub.targets:
                        if isinstance(t, ast.Name):
                            frozen_vars.add(t.id)
            if not frozen_vars:
                continue
            for sub in ast.walk(fn):
                if isinstance(sub, (ast.Assign, ast.AugAssign)):
                    tgts = sub.targets if isinstance(sub, ast.Assign) \
                        else [sub.target]
                    for t in tgts:
                        if (isinstance(t, ast.Attribute)
                                and isinstance(t.value, ast.Name)
                                and t.value.id in frozen_vars):
                            yield module.finding(
                                self.name, sub,
                                f"assignment to field of frozen instance "
                                f"'{t.value.id}' raises "
                                "FrozenInstanceError; use "
                                "dataclasses.replace")


ALL_RULES = (JIT001, JIT002, JIT003, DTY001, DET001, FRZ001)


def default_rules() -> list[Rule]:
    return [cls() for cls in ALL_RULES]
