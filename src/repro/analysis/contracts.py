"""Compiled-program contract auditor.

The zero-recompile claim — rebinding a posterior or interleaving tenants
never builds a new XLA program — is enforced dynamically by the
``PlanStats.n_traces`` counter.  A counter can only say *how many* traces
happened; it cannot say the programs are the *same program*.  This module
proves the claim structurally:

* :func:`fingerprint` — sha256 of the jaxpr a plan executable traces to
  for a given call signature (``jax.make_jaxpr`` re-traces the Python
  callable, so audits snapshot/restore the trace counter around
  themselves);
* :func:`audit_plan` — capture the live call arguments of every cached
  ``ServePlan`` executable by wrapping ``plan._exec`` during a traffic
  drive, then fingerprint each one;
* :func:`audit_rebind_generations` — serve, rebind onto value-perturbed
  same-shape states N times, and require the executable set, the trace
  counter, and every fingerprint to be identical across generations;
* :func:`audit_tenant_interleaving` — admit two tenants of one compiled
  lineage, interleave their traffic, and require no growth of the shared
  executable cache and fingerprint-identical programs;
* :func:`no_retrace` — a decorator registry for module-level jitted
  functions: after :func:`freeze`, a call with a never-seen abstract
  signature is a contract violation (it implies a silent recompile);
* :func:`run_audit` — the CLI/CI entry: builds a small synthetic routed
  ppic deployment, runs every audit, optionally writes a JSON report.

jax is imported lazily so ``repro.analysis``'s lint half stays importable
without it.
"""
from __future__ import annotations

import dataclasses
import functools
import hashlib
import json
import pathlib
from typing import Any, Callable, Mapping

__all__ = [
    "fingerprint", "audit_plan", "audit_rebind_generations",
    "audit_tenant_interleaving", "no_retrace", "freeze", "violations",
    "registry_report", "reset_registry", "run_audit",
]


# ---------------------------------------------------------------------------
# jaxpr fingerprints
# ---------------------------------------------------------------------------

def fingerprint(fn: Callable, args: tuple) -> str:
    """sha256 of the jaxpr ``fn`` traces to for ``args``.  Two calls that
    fingerprint equal are the same compiled program for XLA's purposes
    (same primitives, shapes, dtypes); posterior VALUES ride in as traced
    arguments and cannot influence the hash."""
    import jax
    jaxpr = jax.make_jaxpr(fn)(*args)
    return hashlib.sha256(str(jaxpr).encode()).hexdigest()


def _capture_args(plan, drive: Callable[[Any], None]) -> dict:
    """Run ``drive(plan)`` with every cached executable wrapped by an
    argument recorder; returns ``{exec_key: first call args}``.  The wrap
    is reverted before returning even if the drive raises."""
    originals = dict(plan._exec)
    captured: dict = {}

    def wrap(key, fn):
        def spy(*args):
            captured.setdefault(key, args)
            return fn(*args)
        return spy

    plan._exec.update({k: wrap(k, f) for k, f in originals.items()})
    try:
        drive(plan)
    finally:
        # unwrap the spies but keep executables the drive created lazily —
        # deleting them would force a recompile on the next drive and
        # corrupt the very trace counter the audit protects
        created = {k: f for k, f in plan._exec.items() if k not in originals}
        plan._exec.clear()
        plan._exec.update(originals)
        plan._exec.update(created)
    return captured


def audit_plan(plan, drive: Callable[[Any], None]) -> dict:
    """Fingerprint every executable ``drive`` exercises on ``plan``.

    Returns ``{"fingerprints": {key: sha256}, "n_executables": int}``.
    ``make_jaxpr`` re-traces through the plan's counted wrappers, so the
    plan's trace counter is snapshotted and restored — an audit must not
    perturb the very counter the runtime tests assert on."""
    drive(plan)   # materialize lazily-selected executables before spying
    captured = _capture_args(plan, drive)
    before = plan.stats.n_traces
    try:
        fps = {str(k): fingerprint(plan._exec[k], args)
               for k, args in sorted(captured.items(), key=lambda kv: str(kv[0]))}
    finally:
        plan.stats.n_traces = before
    return {"fingerprints": fps, "n_executables": len(plan._exec)}


# ---------------------------------------------------------------------------
# rebind-generation audit
# ---------------------------------------------------------------------------

def _perturbed(state, rel: float):
    """Same-shape, same-dtype state with every float leaf nudged — a
    stand-in for an assimilate-free online refresh (assimilation grows
    the support set and legitimately re-specializes)."""
    import jax
    import jax.numpy as jnp

    def nudge(a):
        a = jnp.asarray(a)
        if jnp.issubdtype(a.dtype, jnp.floating):
            return a * (1 + jnp.asarray(rel, a.dtype))
        return a
    return jax.tree.map(nudge, state)


def audit_rebind_generations(plan, drive: Callable[[Any], None], *,
                             n_generations: int = 3) -> dict:
    """Serve, then rebind onto ``n_generations`` value-perturbed states and
    re-audit: the executable set, the trace counter, and every jaxpr
    fingerprint must be identical across generations — the structural form
    of the zero-recompile-on-rebind claim."""
    base = audit_plan(plan, drive)
    keys0 = set(map(str, plan._exec))
    generations = [base["fingerprints"]]
    traces0 = plan.stats.n_traces
    identical = True
    for i in range(1, n_generations):
        gen_plan = plan.rebind(_perturbed(plan.state, 1e-6 * i))
        audit = audit_plan(gen_plan, drive)
        generations.append(audit["fingerprints"])
        if audit["fingerprints"] != base["fingerprints"]:
            identical = False
        if set(map(str, gen_plan._exec)) != keys0:
            identical = False
    new_traces = plan.stats.n_traces - traces0
    return {
        "n_executables": base["n_executables"],
        "n_audited": len(base["fingerprints"]),
        "n_rebind_generations": n_generations,
        "rebind_identical": identical,
        "rebind_new_traces": int(new_traces),
        "fingerprints": base["fingerprints"],
        "generations": generations,
    }


# ---------------------------------------------------------------------------
# tenant-interleaving audit
# ---------------------------------------------------------------------------

def audit_tenant_interleaving(model, spec, queries, *,
                              n_rounds: int = 3) -> dict:
    """Admit two tenants of one compiled lineage (same method/spec/state
    shapes, independent posterior values), interleave their traffic, and
    require: one lineage, no executable-cache growth, no new traces, and
    fingerprint-identical programs before vs after the interleaving."""
    import numpy as np
    from repro.serving.registry import TenantRegistry
    from repro.serving.scheduler import TenantScheduler

    reg = TenantRegistry()
    sched = TenantScheduler(reg)
    ta = sched.admit("tenant-a", model, spec)
    sched.admit("tenant-b", model.with_state(_perturbed(model.state, 1e-5)),
                spec)
    n_lineages = reg.n_lineages
    # the lineage anchor is state-stripped (it owns executables, never a
    # posterior); audit through tenant A's plan, which shares the anchor's
    # executable cache with tenant B's
    shared_plan = ta.plan
    d = int(np.shape(queries)[-1])
    shared_plan.warmup(d)

    def drive(plan):
        plan.diag(np.asarray(queries))
        if plan.spec.routed:
            plan.routed_diag(np.asarray(queries))

    before = audit_plan(shared_plan, drive)
    keys0 = set(map(str, shared_plan._exec))
    traces0 = shared_plan.stats.n_traces
    for r in range(n_rounds):
        for i, row in enumerate(np.asarray(queries)):
            sched.submit("tenant-a" if (i + r) % 2 == 0 else "tenant-b", row)
        sched.flush()
    after = audit_plan(shared_plan, drive)
    return {
        "n_lineages": int(n_lineages),
        "n_tenant_interleavings": n_rounds,
        "interleaving_identical": (
            n_lineages == 1
            and before["fingerprints"] == after["fingerprints"]
            and set(map(str, shared_plan._exec)) == keys0
            and shared_plan.stats.n_traces == traces0),
        "interleaving_new_traces": int(shared_plan.stats.n_traces - traces0),
    }


# ---------------------------------------------------------------------------
# @no_retrace registry
# ---------------------------------------------------------------------------

@dataclasses.dataclass
class _NoRetraceRecord:
    name: str
    signatures: set = dataclasses.field(default_factory=set)
    frozen: set | None = None
    n_calls: int = 0


_REGISTRY: dict[str, _NoRetraceRecord] = {}


def _abstract_signature(args: tuple, kwargs: Mapping) -> tuple:
    """The jit cache key as far as shapes/dtypes are concerned: array
    leaves contribute (shape, dtype), everything else its repr (a Python
    scalar's repr changing per call is exactly the JIT003 retrace bug)."""
    import jax
    import numpy as np
    leaves, treedef = jax.tree.flatten((args, dict(kwargs)))
    sig = []
    for leaf in leaves:
        if hasattr(leaf, "shape") and hasattr(leaf, "dtype"):
            sig.append((tuple(leaf.shape), str(leaf.dtype)))
        elif isinstance(leaf, (bool, int, float, complex)):
            sig.append((type(leaf).__name__, repr(leaf)))
        else:
            sig.append(repr(np.asarray(leaf).dtype) if hasattr(leaf, "__len__")
                       else repr(leaf))
    return (str(treedef), tuple(sig))


def no_retrace(name: str) -> Callable:
    """Register a jitted callable under the no-retrace contract: after
    :func:`freeze`, any call with a never-seen abstract signature is a
    violation (a distinct signature means jax compiled a new program).
    Purely observational — calls are never blocked."""
    def deco(fn: Callable) -> Callable:
        rec = _REGISTRY.setdefault(name, _NoRetraceRecord(name))

        @functools.wraps(fn)
        def wrapper(*args, **kwargs):
            rec.signatures.add(_abstract_signature(args, kwargs))
            rec.n_calls += 1
            return fn(*args, **kwargs)

        wrapper.__wrapped__ = fn
        return wrapper
    return deco


def freeze() -> None:
    """Snapshot every registered function's signature set — the post-warmup
    declaration that all compiles have happened."""
    for rec in _REGISTRY.values():
        rec.frozen = set(rec.signatures)


def violations() -> dict[str, int]:
    """``{name: n new signatures since freeze}`` for every frozen record
    that saw a never-before-seen signature — i.e. a silent recompile."""
    return {rec.name: len(rec.signatures - rec.frozen)
            for rec in _REGISTRY.values()
            if rec.frozen is not None and rec.signatures - rec.frozen}


def registry_report() -> dict[str, dict]:
    return {rec.name: {"n_calls": rec.n_calls,
                       "n_signatures": len(rec.signatures),
                       "frozen": rec.frozen is not None}
            for rec in _REGISTRY.values()}


def reset_registry() -> None:
    """Test hook: drop all recorded signatures and freeze points (the
    decorated functions stay registered)."""
    for rec in _REGISTRY.values():
        rec.signatures.clear()
        rec.frozen = None
        rec.n_calls = 0


# ---------------------------------------------------------------------------
# CLI/CI entry
# ---------------------------------------------------------------------------

def run_audit(report_path: str | None = None, *, n_rebinds: int = 3,
              seed: int = 0) -> dict:
    """Build a small synthetic routed ppic deployment and run the full
    audit: rebind generations, tenant interleaving, no_retrace registry.
    Returns the report dict (and writes it as JSON to ``report_path``)."""
    import jax
    import jax.numpy as jnp
    import numpy as np
    from repro.core import api
    from repro.core import covariance as cov
    from repro.parallel.runner import VmapRunner

    n, s, d, M, u = 64, 12, 3, 4, 10
    k0, k1, k2, k3 = jax.random.split(jax.random.PRNGKey(seed), 4)
    X = jax.random.normal(k0, (n, d), jnp.float32)
    S = jax.random.normal(k1, (s, d), jnp.float32)
    U = np.asarray(jax.random.normal(k2, (u, d), jnp.float32))
    params = cov.init_params(d, signal=1.3, noise=0.3, lengthscale=1.5,
                             dtype=jnp.float32)
    y = jnp.sin(X[:, 0]) + 0.3 * jax.random.normal(k3, (n,), jnp.float32)
    kfn = cov.make_kernel("se")

    model = api.fit("ppic", kfn, params, X, y, S=S,
                    runner=VmapRunner(M=M))
    # cached_cinv exercises the @no_retrace contract on ppic.cinv_blocks:
    # plan build and every rebind recompute the block-inverse cache, which
    # must reuse one compiled signature
    spec = api.ServeSpec(max_batch=16, routed=True, cached_cinv=True)
    plan = model.plan(spec)
    plan.warmup(d)
    freeze()

    def drive(p):
        p.diag(U)          # padded unrouted path
        p.routed_diag(U)   # padded routed path

    report: dict = {"seed": seed}
    report.update(audit_rebind_generations(plan, drive,
                                           n_generations=n_rebinds))
    report.update(audit_tenant_interleaving(model, spec, U))
    report["no_retrace"] = registry_report()
    report["no_retrace_violations"] = violations()
    report["ok"] = bool(
        report["rebind_identical"]
        and report["rebind_new_traces"] == 0
        and report["interleaving_identical"]
        and not report["no_retrace_violations"])
    if report_path is not None:
        pathlib.Path(report_path).write_text(json.dumps(report, indent=2)
                                             + "\n")
    return report
