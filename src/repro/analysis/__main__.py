"""CLI: ``python -m repro.analysis [paths...]``.

Runs the lint rules over ``src/`` (or the given paths) and exits nonzero
on findings not in the checked-in baseline.  With ``--contracts`` it also
runs the compiled-program contract auditor (requires jax) and folds its
verdict into the exit code.

    python -m repro.analysis                      # lint src/, text report
    python -m repro.analysis --json               # machine-readable
    python -m repro.analysis --baseline           # diff vs analysis_baseline.json
    python -m repro.analysis --write-baseline     # burn current findings in
    python -m repro.analysis --contracts --report AUDIT_contracts.json
"""
from __future__ import annotations

import argparse
import sys
from pathlib import Path

from repro.analysis import engine
from repro.analysis.rules import default_rules

DEFAULT_BASELINE = "analysis_baseline.json"


def main(argv=None) -> int:
    ap = argparse.ArgumentParser(prog="python -m repro.analysis")
    ap.add_argument("paths", nargs="*", default=None,
                    help="files/dirs to lint (default: src/)")
    ap.add_argument("--json", action="store_true", dest="as_json",
                    help="emit findings as JSON")
    ap.add_argument("--baseline", nargs="?", const=DEFAULT_BASELINE,
                    default=None, metavar="FILE",
                    help=f"only fail on findings absent from FILE "
                         f"(default {DEFAULT_BASELINE})")
    ap.add_argument("--write-baseline", nargs="?", const=DEFAULT_BASELINE,
                    default=None, metavar="FILE",
                    help="write current findings as the new baseline and exit 0")
    ap.add_argument("--contracts", action="store_true",
                    help="also run the compiled-program contract audit "
                         "(imports jax)")
    ap.add_argument("--report", default=None, metavar="FILE",
                    help="write the contract-audit JSON report to FILE")
    args = ap.parse_args(argv)

    root = Path.cwd()
    paths = [Path(p) for p in (args.paths or ["src"])]
    missing = [p for p in paths if not p.exists()]
    if missing:
        print(f"analysis: no such path: {', '.join(map(str, missing))}",
              file=sys.stderr)
        return 2

    bad_files: list[Path] = []
    findings = engine.run_rules(paths, default_rules(), root=root,
                                on_error=bad_files.append)
    for p in bad_files:
        print(f"analysis: syntax error, skipped: {p}", file=sys.stderr)

    if args.write_baseline is not None:
        engine.write_baseline(Path(args.write_baseline), findings)
        print(f"analysis: wrote {len(findings)} finding(s) to "
              f"{args.write_baseline}")
        return 0

    if args.baseline is not None:
        baseline = engine.load_baseline(Path(args.baseline))
        findings = engine.new_findings(findings, baseline)

    print(engine.to_json(findings) if args.as_json
          else engine.to_text(findings))
    rc = 1 if findings else 0

    if args.contracts:
        from repro.analysis import contracts
        report = contracts.run_audit(report_path=args.report)
        ok = report.get("ok", False)
        print(f"contracts: {'OK' if ok else 'VIOLATION'} — "
              f"{report.get('n_executables', 0)} executables, "
              f"{report.get('n_rebind_generations', 0)} rebind generations, "
              f"{report.get('n_tenant_interleavings', 0)} tenant "
              f"interleavings audited")
        if not ok:
            rc = 1
    return rc


if __name__ == "__main__":
    sys.exit(main())
