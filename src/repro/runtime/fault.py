"""Fault tolerance built on the paper's summary algebra.

The global summary (eqs. 5-6 / 22-23) is a SUM of per-machine terms, which
gives this framework a fault model most training stacks lack: when machine m
dies, the posterior over the SURVIVING data is recovered by re-aggregating
cached local summaries — zero recomputation of the survivors' O((|D|/M)^3)
work, and the result is *exactly* the PITC/PIC posterior of the surviving
blocks (verified in tests/test_runtime.py).

Recovery ladder implemented here:
  1. degrade     — drop the lost block (alive-mask re-aggregation);
  2. reassign    — a standby/surviving machine recomputes ONLY the lost
                   block's summary from the (replicated or re-readable) data
                   shard and folds it back in;
  3. checkpoint  — summaries are tiny (M x (|S| + |S|^2)) and checkpointed
                   every aggregation round, so a master loss replays the sum.

The same logic covers elastic scale-down (retire = planned failure) and
scale-up (assimilate new blocks online — Sec. 5.2).
"""
from __future__ import annotations

from typing import NamedTuple

import jax
import jax.numpy as jnp

from repro.core import linalg, online
from repro.core.ppitc import LocalSummary
from repro.parallel.runner import Runner


class ClusterState(NamedTuple):
    store: online.SummaryStore
    # block -> machine assignment (simulation bookkeeping)
    owner: jax.Array          # (n_blocks,) int32


def build(kfn, params, S, X, y, runner: Runner) -> ClusterState:
    store = online.build(kfn, params, S, X, y, runner)
    M = store.alive.shape[0]
    return ClusterState(store, jnp.arange(M, dtype=jnp.int32))


def fail(state: ClusterState, machine: int) -> ClusterState:
    """Machine loss: mask its contribution. O(1), no recompute."""
    return state._replace(store=online.retire(state.store, machine))


def recover_degraded(state: ClusterState):
    """Posterior ingredients over surviving blocks only."""
    return online.global_summary(state.store)


def recover_reassign(state: ClusterState, kfn, params, S, Xm, ym,
                     machine: int, new_owner: int) -> ClusterState:
    """Standby machine recomputes ONLY the lost block's summary (the paper's
    Step 2 for one block) and folds it back in."""
    Kss_L = linalg.chol(kfn(params, S, S))
    from repro.core.ppitc import local_summary
    loc, _ = local_summary(kfn, params, S, Kss_L, Xm, ym)
    locs = state.store.locals_
    locs = LocalSummary(locs.ydot.at[machine].set(loc.ydot),
                        locs.Sdot.at[machine].set(loc.Sdot))
    store = online.SummaryStore(locs,
                                state.store.alive.at[machine].set(True),
                                state.store.Kss)
    owner = state.owner.at[machine].set(new_owner)
    return ClusterState(store, owner)
