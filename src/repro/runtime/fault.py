"""Fault tolerance built on the paper's summary algebra.

The global summary (eqs. 5-6 / 22-23) is a SUM of per-machine terms, which
gives this framework a fault model most training stacks lack: when machine m
dies, the posterior over the SURVIVING data is recovered by re-aggregating
cached local summaries — zero recomputation of the survivors' O((|D|/M)^3)
work, and the result is *exactly* the PITC/PIC posterior of the surviving
blocks (verified in tests/test_runtime.py).

Recovery ladder implemented here:
  1. degrade     — drop the lost block (rank-b downdate of the cached
                   global factor via ``StateStore.retire``);
  2. reassign    — a standby/surviving machine recomputes ONLY the lost
                   block's summary from the (replicated or re-readable) data
                   shard and folds it back in;
  3. checkpoint  — summaries are tiny (M x (|S| + |S|^2)) and checkpointed
                   every aggregation round, so a master loss replays the sum.

The same logic covers elastic scale-down (retire = planned failure) and
scale-up (assimilate new blocks online — Sec. 5.2). Built on the
``api.StateStore`` protocol (``online.PITCStore``); the cluster only adds
the block→machine assignment bookkeeping a scheduler needs.
"""
from __future__ import annotations

from typing import NamedTuple

import jax
import jax.numpy as jnp

from repro.core import online
from repro.core.ppitc import GlobalSummary
from repro.parallel.runner import Runner


class ClusterState(NamedTuple):
    store: online.PITCStore
    # block -> machine assignment (simulation bookkeeping)
    owner: jax.Array          # (n_blocks,) int32


def build(kfn, params, S, X, y, runner: Runner) -> ClusterState:
    store = online.init_pitc_store(kfn, params, X, y, S=S, runner=runner)
    return ClusterState(store, jnp.arange(store.num_machines,
                                          dtype=jnp.int32))


def fail(state: ClusterState, machine: int) -> ClusterState:
    """Machine loss: fold its contribution out — one O(|S|² b) downdate of
    the cached global factor, no recompute of survivors."""
    return state._replace(store=state.store.retire(machine))


def recover_degraded(state: ClusterState) -> GlobalSummary:
    """Posterior ingredients over surviving blocks only."""
    return state.store.global_summary()


def recover_reassign(state: ClusterState, Xm, ym, *, machine: int,
                     new_owner: int) -> ClusterState:
    """Standby machine recomputes ONLY the lost block's summary (the paper's
    Step 2 for one block) and folds it back in. The store owns the fit
    context (kernel/params/S), so recovery needs just the re-read shard."""
    store = state.store.reassign(machine, Xm, ym)
    owner = state.owner.at[machine].set(new_owner)
    return ClusterState(store, owner)
