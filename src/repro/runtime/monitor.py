"""Runtime monitoring: heartbeats, failure detection, throughput metrics.

The fault-tolerance math (runtime/fault.py) needs a DETECTOR to drive it.
This module provides the control-plane piece: machines report heartbeats
(in simulation, a latency/crash model generates them); the detector flags
machines whose heartbeat age exceeds the timeout and emits fail/recover
events that the caller applies to the ClusterState (fault.fail /
fault.recover_reassign). Also tracks step timing and EMA throughput the way
a training-loop babysitter would.
"""
from __future__ import annotations

import dataclasses
import time
from typing import Callable, Optional


@dataclasses.dataclass
class MachineStatus:
    last_heartbeat: float
    alive: bool = True
    failures: int = 0


class FailureDetector:
    """Heartbeat-timeout failure detector (phi-accrual simplified)."""

    def __init__(self, n_machines: int, *, timeout: float = 5.0,
                 clock: Callable[[], float] = time.monotonic):
        self.timeout = timeout
        self.clock = clock
        now = clock()
        self.machines = {m: MachineStatus(now) for m in range(n_machines)}

    def heartbeat(self, machine: int) -> None:
        st = self.machines[machine]
        st.last_heartbeat = self.clock()
        if not st.alive:
            st.alive = True          # recovered

    def sweep(self) -> list[int]:
        """Returns machines newly declared failed."""
        now = self.clock()
        newly = []
        for m, st in self.machines.items():
            if st.alive and now - st.last_heartbeat > self.timeout:
                st.alive = False
                st.failures += 1
                newly.append(m)
        return newly

    @property
    def alive_mask(self) -> list[bool]:
        return [self.machines[m].alive for m in sorted(self.machines)]


@dataclasses.dataclass
class StepMetrics:
    step: int = 0
    tokens_per_s: float = 0.0
    step_time_ema: float = 0.0
    loss_ema: float = 0.0


class TrainMonitor:
    """EMA step timing / throughput / loss tracking + stall detection."""

    def __init__(self, *, tokens_per_step: int, ema: float = 0.9,
                 stall_factor: float = 10.0,
                 clock: Callable[[], float] = time.monotonic):
        self.tokens = tokens_per_step
        self.ema = ema
        self.stall_factor = stall_factor
        self.clock = clock
        self._last: Optional[float] = None
        self.metrics = StepMetrics()

    def step(self, loss: float) -> StepMetrics:
        now = self.clock()
        m = self.metrics
        if self._last is not None:
            dt = now - self._last
            m.step_time_ema = (self.ema * m.step_time_ema
                               + (1 - self.ema) * dt
                               if m.step_time_ema else dt)
            m.tokens_per_s = self.tokens / max(m.step_time_ema, 1e-9)
        self._last = now
        m.loss_ema = (self.ema * m.loss_ema + (1 - self.ema) * loss
                      if m.step != 0 else loss)
        m.step = m.step + 1
        return m

    def is_stalled(self) -> bool:
        """True when no step completed within stall_factor x EMA time."""
        if self._last is None or not self.metrics.step_time_ema:
            return False
        return (self.clock() - self._last
                > self.stall_factor * self.metrics.step_time_ema)
