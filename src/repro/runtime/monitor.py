"""Runtime monitoring: heartbeats, failure detection, throughput metrics.

The fault-tolerance math (runtime/fault.py) needs a DETECTOR to drive it.
This module provides the control-plane piece: machines report heartbeats
(in simulation, a latency/crash model generates them); the detector flags
machines whose heartbeat age exceeds the timeout and emits fail/recover
events that the caller applies to the ClusterState (fault.fail /
fault.recover_reassign). Also tracks step timing and EMA throughput the way
a training-loop babysitter would.

Every component takes an injectable ``clock`` (seconds, monotonic) — the
same pattern as ``launch.gp_serve.GPServer`` — so heartbeat/sweep/stall
tests drive a virtual clock instead of sleeping. ``Ema`` is the shared
exponential-moving-average primitive: ``TrainMonitor`` uses it for step
time and loss, and the serving observability layer (``serving/stats.py``)
reuses it for per-tenant interarrival tracking (the adaptive flusher's
input).
"""
from __future__ import annotations

import dataclasses
import time
from typing import Callable, Optional


@dataclasses.dataclass
class Ema:
    """Exponential moving average with explicit first-sample seeding.

    ``update(x)`` seeds the average with the first observation (no
    zero-bias warmup) and blends thereafter; ``value`` is ``None`` until a
    sample arrives, so consumers can distinguish "no data yet" from a
    genuinely small average (0.0 is a legal observation — truthiness tests
    on the value would misclassify it)."""
    alpha: float = 0.9
    value: Optional[float] = None

    def update(self, x: float) -> float:
        self.value = (x if self.value is None
                      else self.alpha * self.value + (1 - self.alpha) * x)
        return self.value

    def get(self, default: float = 0.0) -> float:
        return default if self.value is None else self.value


@dataclasses.dataclass
class MachineStatus:
    last_heartbeat: float
    alive: bool = True
    failures: int = 0


class FailureDetector:
    """Heartbeat-timeout failure detector (phi-accrual simplified)."""

    def __init__(self, n_machines: int, *, timeout: float = 5.0,
                 clock: Callable[[], float] = time.monotonic):
        self.timeout = timeout
        self.clock = clock
        now = clock()
        self.machines = {m: MachineStatus(now) for m in range(n_machines)}

    def heartbeat(self, machine: int) -> None:
        st = self.machines[machine]
        st.last_heartbeat = self.clock()
        if not st.alive:
            st.alive = True          # recovered

    def sweep(self) -> list[int]:
        """Returns machines newly declared failed."""
        now = self.clock()
        newly = []
        for m, st in self.machines.items():
            if st.alive and now - st.last_heartbeat > self.timeout:
                st.alive = False
                st.failures += 1
                newly.append(m)
        return newly

    @property
    def alive_mask(self) -> list[bool]:
        return [self.machines[m].alive for m in sorted(self.machines)]


@dataclasses.dataclass
class StepMetrics:
    step: int = 0
    tokens_per_s: float = 0.0
    step_time_ema: float = 0.0
    loss_ema: float = 0.0


class TrainMonitor:
    """EMA step timing / throughput / loss tracking + stall detection."""

    def __init__(self, *, tokens_per_step: int, ema: float = 0.9,
                 stall_factor: float = 10.0,
                 clock: Callable[[], float] = time.monotonic):
        self.tokens = tokens_per_step
        self.ema = ema
        self.stall_factor = stall_factor
        self.clock = clock
        self._last: Optional[float] = None
        self.metrics = StepMetrics()

        self._step_ema = Ema(alpha=ema)
        self._loss_ema = Ema(alpha=ema)

    def step(self, loss: float) -> StepMetrics:
        now = self.clock()
        m = self.metrics
        if self._last is not None:
            m.step_time_ema = self._step_ema.update(now - self._last)
            m.tokens_per_s = self.tokens / max(m.step_time_ema, 1e-9)
        self._last = now
        m.loss_ema = self._loss_ema.update(loss)
        m.step = m.step + 1
        return m

    def is_stalled(self) -> bool:
        """True when no step completed within stall_factor x EMA time."""
        if self._last is None or not self.metrics.step_time_ema:
            return False
        return (self.clock() - self._last
                > self.stall_factor * self.metrics.step_time_ema)
