"""Elastic scaling: decouple LOGICAL blocks from PHYSICAL machines.

The production design for 1000+ nodes: the data is partitioned into a fixed
number of logical blocks B >> M (the paper's Def. 1 applied at block
granularity). Machines own contiguous runs of blocks; the PITC/PIC posterior
is a function of the BLOCK partition only, so changing M:

  * never changes predictions (verified in tests/test_runtime.py),
  * needs no summary recomputation — blocks move, their cached summaries
    move with them (a pytree gather),
  * keeps the all-reduce payload constant (|S|^2, independent of B and M).

``plan_assignment`` balances blocks over machines; ``reshard`` reshapes the
stacked block tensors for a new machine count.
"""
from __future__ import annotations

import jax


def plan_assignment(n_blocks: int, n_machines: int) -> list[range]:
    """Contiguous balanced assignment; machine i owns blocks plan[i]."""
    base, extra = divmod(n_blocks, n_machines)
    out, start = [], 0
    for i in range(n_machines):
        size = base + (1 if i < extra else 0)
        out.append(range(start, start + size))
        start += size
    return out


def blocks_per_machine(n_blocks: int, n_machines: int) -> int:
    assert n_blocks % n_machines == 0, \
        "logical block count must be divisible for the stacked layout"
    return n_blocks // n_machines


def reshard(block_tree, n_machines_new: int):
    """(B, ...) stacked per-block arrays -> (M', B/M', ...) machine-major.

    Machines process their owned blocks with an inner vmap/loop; the
    collective code is unchanged because summaries stay per-block.
    """
    def one(a):
        B = a.shape[0]
        k = blocks_per_machine(B, n_machines_new)
        return a.reshape((n_machines_new, k) + a.shape[1:])

    return jax.tree.map(one, block_tree)


def machine_view(block_tree, n_machines: int):
    """Convenience: reshard + flatten back check."""
    return reshard(block_tree, n_machines)


def unshard(machine_tree):
    return jax.tree.map(
        lambda a: a.reshape((-1,) + a.shape[2:]), machine_tree)
