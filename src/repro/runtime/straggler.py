"""Straggler mitigation via deadline-based partial aggregation.

Unique property of this paper's math: the global summary is a sum whose
partial sums are themselves VALID posteriors (over the blocks that arrived).
So instead of backup workers or re-execution, the aggregation simply stops
waiting at the deadline: predictions proceed with the K<=M summaries present
and the stragglers fold in later as an online update (Sec. 5.2 algebra).

``simulate`` quantifies the accuracy/latency trade-off: per-machine latency
draws -> deadline sweep -> (fraction of blocks included, posterior RMSE).
Operates on the ``api.StateStore`` protocol (``online.PITCStore``): a
deadline view is ``store.with_alive(arrived_mask)`` — many machines flip at
once, so the store re-derives its cached factor in one pass instead of a
chain of rank updates.
"""
from __future__ import annotations

from typing import NamedTuple

import jax
import jax.numpy as jnp

from repro.core import online


class DeadlineResult(NamedTuple):
    deadline: float
    included: jax.Array       # (M,) bool
    fraction: jax.Array
    mean: jax.Array           # posterior mean over U
    var: jax.Array


def sample_latencies(key, M: int, *, base: float = 1.0,
                     straggle_p: float = 0.1,
                     straggle_factor: float = 10.0) -> jax.Array:
    """Bimodal latency model: exp(1) body + a straggler tail."""
    k1, k2, k3 = jax.random.split(key, 3)
    lat = base * (1.0 + jax.random.exponential(k1, (M,)) * 0.2)
    slow = jax.random.bernoulli(k2, straggle_p, (M,))
    return jnp.where(slow, lat * straggle_factor *
                     (1 + jax.random.uniform(k3, (M,))), lat)


def aggregate_with_deadline(store: online.PITCStore, latencies,
                            deadline: float, U) -> DeadlineResult:
    included = (latencies <= deadline) & store.alive
    mean, cov = store.with_alive(included).predict(U)
    return DeadlineResult(deadline, included,
                          jnp.mean(included.astype(jnp.float32)), mean,
                          jnp.diag(cov))


def simulate(key, store: online.PITCStore, U, y_true, deadlines):
    """RMSE + inclusion fraction per deadline (benchmarks/bench_fault.py)."""
    lat = sample_latencies(key, store.num_machines)
    rows = []
    for d in deadlines:
        r = aggregate_with_deadline(store, lat, d, U)
        rmse = jnp.sqrt(jnp.mean((r.mean - y_true) ** 2))
        rows.append({"deadline": float(d), "fraction": float(r.fraction),
                     "rmse": float(rmse)})
    return rows
