"""Centralized PITC and PIC approximations of FGP.

These are the *centralized counterparts* that Theorems 1 and 2 prove our
parallel methods equal:

  PITC — eqs. (9)-(11)  (Quinonero-Candela & Rasmussen 2005)
  PIC  — eqs. (15)-(18) (Snelson 2007)

Two implementations each:
  * ``*_literal``  — builds Gamma_DD + Lambda as a dense |D|x|D| matrix exactly
    as written in the theorem statements. O(|D|^2) memory; this is the oracle
    the equivalence tests compare the parallel methods against.
  * ``*_blockwise`` — the efficient centralized algorithm (block loop on one
    machine, Table 1 complexity row "PITC"/"PIC") used by the benchmark
    harness for the speedup curves.
"""
from __future__ import annotations

import jax
import jax.numpy as jnp

from repro.core import covariance as cov
from repro.core import linalg
from repro.core.gp import GPPosterior


# ---------------------------------------------------------------------------
# shared pieces
# ---------------------------------------------------------------------------

def _gamma(kfn, params, S, A, B, Kss_L):
    """Gamma_AB = K_AS K_SS^{-1} K_SB   (eq. 11), via cholesky of K_SS."""
    Vas = linalg.tri_solve(Kss_L, kfn(params, S, A)).T   # K_AS Kss^{-1/2}
    Vbs = linalg.tri_solve(Kss_L, kfn(params, S, B))     # Kss^{-1/2} K_SB
    return Vas @ Vbs


def _blocks(n: int, M: int) -> list[slice]:
    assert n % M == 0, f"|D|={n} must divide among M={M} machines (Def. 1)"
    b = n // M
    return [slice(m * b, (m + 1) * b) for m in range(M)]


# ---------------------------------------------------------------------------
# PITC — literal (theorem oracle)
# ---------------------------------------------------------------------------

def pitc_predict_literal(kfn, params, S, X_train, y_train, X_test,
                         M: int) -> GPPosterior:
    """Eqs. (9)-(10) built dense, Lambda from the M diagonal blocks of
    Sigma_DD|S (noise included, as Sigma_xx' carries the delta term)."""
    Kss_L = linalg.chol(kfn(params, S, S))
    G_dd = _gamma(kfn, params, S, X_train, X_train, Kss_L)
    G_ud = _gamma(kfn, params, S, X_test, X_train, Kss_L)

    K_dd = cov.add_noise(kfn(params, X_train, X_train), params)
    Sig_dd_s = K_dd - G_dd                     # Sigma_DD|S  (with noise)
    Lam = jnp.zeros_like(Sig_dd_s)
    for blk in _blocks(X_train.shape[0], M):
        Lam = Lam.at[blk, blk].set(Sig_dd_s[blk, blk])

    A = G_dd + Lam                             # Gamma_DD + Lambda
    A_L = linalg.chol(A)
    r = y_train[:, None]
    mean = (G_ud @ linalg.chol_solve(A_L, r))[:, 0]
    K_uu = kfn(params, X_test, X_test)
    covm = K_uu - G_ud @ linalg.chol_solve(A_L, G_ud.T)
    return GPPosterior(mean, covm)


# ---------------------------------------------------------------------------
# PIC — literal (theorem oracle)
# ---------------------------------------------------------------------------

def pic_predict_literal(kfn, params, S, X_train, y_train, X_test,
                        M: int) -> GPPosterior:
    """Eqs. (15)-(18): Gamma~ replaces the (U_i, D_i) blocks of Gamma_UD with
    the exact cross-covariance Sigma_{U_i D_i}."""
    n, u = X_train.shape[0], X_test.shape[0]
    Kss_L = linalg.chol(kfn(params, S, S))
    G_dd = _gamma(kfn, params, S, X_train, X_train, Kss_L)
    G_ud = _gamma(kfn, params, S, X_test, X_train, Kss_L)
    K_ud = kfn(params, X_test, X_train)

    K_dd = cov.add_noise(kfn(params, X_train, X_train), params)
    Sig_dd_s = K_dd - G_dd
    Lam = jnp.zeros_like(Sig_dd_s)
    d_blocks = _blocks(n, M)
    u_blocks = _blocks(u, M)
    Gt_ud = G_ud
    for db, ub in zip(d_blocks, u_blocks):
        Lam = Lam.at[db, db].set(Sig_dd_s[db, db])
        Gt_ud = Gt_ud.at[ub, db].set(K_ud[ub, db])   # eq. (18), i = m branch

    A_L = linalg.chol(G_dd + Lam)
    mean = (Gt_ud @ linalg.chol_solve(A_L, y_train[:, None]))[:, 0]
    K_uu = kfn(params, X_test, X_test)
    covm = K_uu - Gt_ud @ linalg.chol_solve(A_L, Gt_ud.T)
    return GPPosterior(mean, covm)


# ---------------------------------------------------------------------------
# Efficient centralized PITC/PIC — block loop on one machine.
# Same math as the parallel methods but sequential: this is what the paper
# times as "PITC"/"PIC" when reporting speedups of pPITC/pPIC.
# ---------------------------------------------------------------------------

def _local_summaries(kfn, params, S, Xb, yb):
    """Per-block (3)-(4) restricted to B=B'=S, plus pieces reused by PIC.

    Xb: (M, b, d) stacked blocks; returns stacked summaries.
    """
    Kss = kfn(params, S, S)
    Kss_L = linalg.chol(Kss)

    def one(Xm, ym):
        Ksd = kfn(params, S, Xm)                       # (s, b)
        V = linalg.tri_solve(Kss_L, Ksd)               # Kss^{-1/2} K_SD_m
        Kdd = cov.add_noise(kfn(params, Xm, Xm), params)
        C = Kdd - V.T @ V                              # Sigma_DmDm|S
        C_L = linalg.chol(C)
        W = linalg.chol_solve(C_L, Ksd.T)              # C^{-1} K_DmS  (b, s)
        ydot = Ksd @ linalg.chol_solve(C_L, ym[:, None])[:, 0]   # (s,)
        Sdot = Ksd @ W                                 # (s, s)
        return ydot, Sdot

    return Kss, Kss_L, jax.vmap(one)(Xb, yb)


def _stack_blocks(X, y, M):
    n, d = X.shape
    b = n // M
    return X.reshape(M, b, d), y.reshape(M, b)


def pitc_predict_blockwise(kfn, params, S, X_train, y_train, X_test,
                           M: int) -> GPPosterior:
    Xb, yb = _stack_blocks(X_train, y_train, M)
    Kss, Kss_L, (ydots, Sdots) = _local_summaries(kfn, params, S, Xb, yb)
    ydd = jnp.sum(ydots, axis=0)                       # eq. (5)
    Sdd = Kss + jnp.sum(Sdots, axis=0)                 # eq. (6)
    Sdd_L = linalg.chol(Sdd)

    Kus = kfn(params, X_test, S)
    mean = Kus @ linalg.chol_solve(Sdd_L, ydd[:, None])[:, 0]      # eq. (7)
    K_uu = kfn(params, X_test, X_test)
    covm = K_uu - Kus @ (linalg.chol_solve(Kss_L, Kus.T)
                         - linalg.chol_solve(Sdd_L, Kus.T))        # eq. (8)
    return GPPosterior(mean, covm)


def pic_predict_blockwise(kfn, params, S, X_train, y_train, X_test,
                          M: int) -> GPPosterior:
    """Efficient centralized PIC: summary term + per-block local correction.

    Matches eqs. (12)-(14) computed sequentially over blocks; the equivalence
    test checks it against pic_predict_literal.
    """
    n, u = X_train.shape[0], X_test.shape[0]
    Xb, yb = _stack_blocks(X_train, y_train, M)
    Ub = X_test.reshape(M, u // M, -1)
    Kss, Kss_L, (ydots, Sdots) = _local_summaries(kfn, params, S, Xb, yb)
    ydd = jnp.sum(ydots, axis=0)
    Sdd = Kss + jnp.sum(Sdots, axis=0)
    Sdd_L = linalg.chol(Sdd)

    def one(Xm, ym, Um, ydot_m):
        Ksd = kfn(params, S, Xm)
        V = linalg.tri_solve(Kss_L, Ksd)
        Kdd = cov.add_noise(kfn(params, Xm, Xm), params)
        C_L = linalg.chol(Kdd - V.T @ V)               # Sigma_DmDm|S
        Kud = kfn(params, Um, Xm)                      # Sigma_UmDm
        Kus = kfn(params, Um, S)
        W = linalg.chol_solve(C_L, Kud.T)              # C^{-1} K_DmUm
        ydot_u = Kud @ linalg.chol_solve(C_L, ym[:, None])[:, 0]   # ydot_{U_m}
        Sdot_su = Ksd @ W                              # Sigma-dot_{S U_m}
        Sdot_uu = Kud @ W                              # Sigma-dot_{U_m U_m}
        # eq. (14): Phi_{U_m S}
        Sdot_ss = Ksd @ linalg.chol_solve(C_L, Ksd.T)
        Phi = Kus + Kus @ linalg.chol_solve(Kss_L, Sdot_ss) - Sdot_su.T
        # eq. (12)
        mean = (Phi @ linalg.chol_solve(Sdd_L, ydd[:, None])[:, 0]
                - Kus @ linalg.chol_solve(Kss_L, ydot_m[:, None])[:, 0]
                + ydot_u)
        # eq. (13). NB the published rendering drops the Phi Sdd^{-1} Phi^T
        # term; re-derived from Thm 2 (Woodbury on Gamma_DD + Lambda):
        #   Sigma+_mm = K_uu - Phi Kss^{-1} K_su + Phi Sdd^{-1} Phi^T
        #               + K_us Kss^{-1} Sdot_su - Sdot_uu
        Kuu = kfn(params, Um, Um)
        covm = Kuu - (Phi @ linalg.chol_solve(Kss_L, Kus.T)
                      - Phi @ linalg.chol_solve(Sdd_L, Phi.T)
                      - Kus @ linalg.chol_solve(Kss_L, Sdot_su)) - Sdot_uu
        return mean, covm

    means, covs = jax.vmap(one)(Xb, yb, Ub, ydots)
    mean = means.reshape(u)
    covm = jax.scipy.linalg.block_diag(*[covs[m] for m in range(M)])
    return GPPosterior(mean, covm)
