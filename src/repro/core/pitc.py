"""Centralized PITC and PIC approximations of FGP.

These are the *centralized counterparts* that Theorems 1 and 2 prove our
parallel methods equal:

  PITC — eqs. (9)-(11)  (Quinonero-Candela & Rasmussen 2005)
  PIC  — eqs. (15)-(18) (Snelson 2007)

Two implementations each:
  * ``*_literal``  — builds Gamma_DD + Lambda as a dense |D|x|D| matrix exactly
    as written in the theorem statements. O(|D|^2) memory; this is the oracle
    the equivalence tests compare the parallel methods against.
  * ``*_blockwise`` — the efficient centralized algorithm (block loop on one
    machine, Table 1 complexity row "PITC"/"PIC"): since the math is identical
    to the parallel methods', these are thin wrappers over the shared
    ``fit -> PosteriorState -> predict_batch`` path (core/api.py) with a
    single-process VmapRunner standing in for the M machines.
"""
from __future__ import annotations

import jax.numpy as jnp

from repro.core import api
from repro.core import covariance as cov
from repro.core import linalg
from repro.core.gp import GPPosterior
from repro.parallel.runner import VmapRunner


# ---------------------------------------------------------------------------
# shared pieces
# ---------------------------------------------------------------------------

def _gamma(kfn, params, S, A, B, Kss_L):
    """Gamma_AB = K_AS K_SS^{-1} K_SB   (eq. 11), via cholesky of K_SS."""
    Vas = linalg.tri_solve(Kss_L, kfn(params, S, A)).T   # K_AS Kss^{-1/2}
    Vbs = linalg.tri_solve(Kss_L, kfn(params, S, B))     # Kss^{-1/2} K_SB
    return Vas @ Vbs


def _blocks(n: int, M: int) -> list[slice]:
    if n % M != 0:
        raise ValueError(
            f"|D|={n} must divide among M={M} machines (Def. 1); pad the "
            f"data or pick M dividing n — query batches go through "
            f"parallel.runner.pad_blocks instead")
    b = n // M
    return [slice(m * b, (m + 1) * b) for m in range(M)]


# ---------------------------------------------------------------------------
# PITC — literal (theorem oracle)
# ---------------------------------------------------------------------------

def pitc_predict_literal(kfn, params, S, X_train, y_train, X_test,
                         M: int) -> GPPosterior:
    """Eqs. (9)-(10) built dense, Lambda from the M diagonal blocks of
    Sigma_DD|S (noise included, as Sigma_xx' carries the delta term)."""
    Kss_L = linalg.chol(kfn(params, S, S))
    G_dd = _gamma(kfn, params, S, X_train, X_train, Kss_L)
    G_ud = _gamma(kfn, params, S, X_test, X_train, Kss_L)

    K_dd = cov.add_noise(kfn(params, X_train, X_train), params)
    Sig_dd_s = K_dd - G_dd                     # Sigma_DD|S  (with noise)
    Lam = jnp.zeros_like(Sig_dd_s)
    for blk in _blocks(X_train.shape[0], M):
        Lam = Lam.at[blk, blk].set(Sig_dd_s[blk, blk])

    A = G_dd + Lam                             # Gamma_DD + Lambda
    A_L = linalg.chol(A)
    r = y_train[:, None]
    mean = (G_ud @ linalg.chol_solve(A_L, r))[:, 0]
    K_uu = kfn(params, X_test, X_test)
    covm = K_uu - G_ud @ linalg.chol_solve(A_L, G_ud.T)
    return GPPosterior(mean, covm)


# ---------------------------------------------------------------------------
# PIC — literal (theorem oracle)
# ---------------------------------------------------------------------------

def pic_predict_literal(kfn, params, S, X_train, y_train, X_test,
                        M: int) -> GPPosterior:
    """Eqs. (15)-(18): Gamma~ replaces the (U_i, D_i) blocks of Gamma_UD with
    the exact cross-covariance Sigma_{U_i D_i}."""
    n, u = X_train.shape[0], X_test.shape[0]
    Kss_L = linalg.chol(kfn(params, S, S))
    G_dd = _gamma(kfn, params, S, X_train, X_train, Kss_L)
    G_ud = _gamma(kfn, params, S, X_test, X_train, Kss_L)
    K_ud = kfn(params, X_test, X_train)

    K_dd = cov.add_noise(kfn(params, X_train, X_train), params)
    Sig_dd_s = K_dd - G_dd
    Lam = jnp.zeros_like(Sig_dd_s)
    d_blocks = _blocks(n, M)
    u_blocks = _blocks(u, M)
    Gt_ud = G_ud
    for db, ub in zip(d_blocks, u_blocks):
        Lam = Lam.at[db, db].set(Sig_dd_s[db, db])
        Gt_ud = Gt_ud.at[ub, db].set(K_ud[ub, db])   # eq. (18), i = m branch

    A_L = linalg.chol(G_dd + Lam)
    mean = (Gt_ud @ linalg.chol_solve(A_L, y_train[:, None]))[:, 0]
    K_uu = kfn(params, X_test, X_test)
    covm = K_uu - Gt_ud @ linalg.chol_solve(A_L, Gt_ud.T)
    return GPPosterior(mean, covm)


def pic_predict_literal_routed(kfn, params, S, X_train, y_train, X_test,
                               M: int, assign) -> GPPosterior:
    """Eqs. (15)-(18) with the i = m branch of eq. (18) chosen per query by
    ``assign`` (u,) — the centralized oracle for centroid-routed pPIC.

    ``pic_predict_literal`` hardcodes positional query blocks; here query i
    takes the exact cross-covariance against training block ``assign[i]``
    and the low-rank Gamma against every other block, which is exactly what
    ``ppic.predict_routed`` computes from cached factors
    (tests/test_routing_equivalence.py).
    """
    n = X_train.shape[0]
    assign = jnp.asarray(assign)
    Kss_L = linalg.chol(kfn(params, S, S))
    G_dd = _gamma(kfn, params, S, X_train, X_train, Kss_L)
    G_ud = _gamma(kfn, params, S, X_test, X_train, Kss_L)
    K_ud = kfn(params, X_test, X_train)

    K_dd = cov.add_noise(kfn(params, X_train, X_train), params)
    Sig_dd_s = K_dd - G_dd
    Lam = jnp.zeros_like(Sig_dd_s)
    for db in _blocks(n, M):
        Lam = Lam.at[db, db].set(Sig_dd_s[db, db])

    # eq. (18): routed i = m branch — data column j belongs to block j // b
    b = n // M
    routed = assign[:, None] == (jnp.arange(n)[None, :] // b)
    Gt_ud = jnp.where(routed, K_ud, G_ud)

    A_L = linalg.chol(G_dd + Lam)
    mean = (Gt_ud @ linalg.chol_solve(A_L, y_train[:, None]))[:, 0]
    K_uu = kfn(params, X_test, X_test)
    covm = K_uu - Gt_ud @ linalg.chol_solve(A_L, Gt_ud.T)
    return GPPosterior(mean, covm)


# ---------------------------------------------------------------------------
# Efficient centralized PITC/PIC — thin wrappers over the shared state path.
# Same math as the parallel methods but on one process: this is what the
# paper times as "PITC"/"PIC" when reporting speedups of pPITC/pPIC.
# ---------------------------------------------------------------------------

def fit(kfn, params, X, y, *, S, M: int) -> api.PITCState:
    """Centralized PITC fit: identical state to ``ppitc.fit`` by
    construction (the block loop is the vmap simulation of M machines)."""
    from repro.core import ppitc
    return ppitc.fit(kfn, params, X, y, S=S, runner=VmapRunner(M=M))


def fit_pic(kfn, params, X, y, *, S, M: int) -> api.PICState:
    """Centralized PIC fit over the shared pPIC state path."""
    from repro.core import ppic
    return ppic.fit(kfn, params, X, y, S=S, runner=VmapRunner(M=M))


def pitc_predict_blockwise(kfn, params, S, X_train, y_train, X_test,
                           M: int) -> GPPosterior:
    from repro.core import ppitc
    state = fit(kfn, params, X_train, y_train, S=S, M=M)
    return ppitc.predict_batch(kfn, params, state, X_test)


def pic_predict_blockwise(kfn, params, S, X_train, y_train, X_test,
                          M: int) -> GPPosterior:
    """Efficient centralized PIC: summary term + per-block local correction.

    Matches eqs. (12)-(14) computed blockwise; the equivalence test checks it
    against pic_predict_literal. Returns the dense block-diagonal cov view.
    """
    from repro.core import ppic
    state = fit_pic(kfn, params, X_train, y_train, S=S, M=M)
    return ppic.predict_batch(kfn, params, state, X_test)


def _pitc_predict(kfn, params, state, U):
    from repro.core import ppitc
    return ppitc.predict_batch(kfn, params, state, U)


def _pitc_predict_diag(kfn, params, state, U):
    from repro.core import ppitc
    return ppitc.predict_batch_diag(kfn, params, state, U)


def _pic_predict(kfn, params, state, U):
    from repro.core import ppic
    return ppic.predict_batch(kfn, params, state, U)


def _pic_predict_diag(kfn, params, state, U):
    from repro.core import ppic
    return ppic.predict_batch_diag(kfn, params, state, U)


def _pic_predict_routed_diag(kfn, params, state, U, *, tile=None):
    from repro.core import ppic
    return ppic.predict_routed_diag(kfn, params, state, U, tile=tile)


def _pitc_init_store(kfn, params, X, y, *, S, M: int):
    """Centralized PITC shares pPITC's StateStore (vmap-simulated blocks)."""
    from repro.core import online
    return online.init_pitc_store(kfn, params, X, y, S=S,
                                  runner=VmapRunner(M=M))


def _pic_init_store(kfn, params, X, y, *, S, M: int):
    from repro.core import online
    return online.init_pic_store(kfn, params, X, y, S=S,
                                 runner=VmapRunner(M=M))


def _pic_plan(method, kfn, params, state, spec):
    """Centralized PIC serves through pPIC's plan (same PICState, same
    backend caches and overflow-executable ladder)."""
    from repro.core import ppic
    return ppic.make_plan(method, kfn, params, state, spec)


api.register(api.GPMethod("pitc", fit, predict_fn=_pitc_predict,
                          predict_diag_fn=_pitc_predict_diag,
                          init_store=_pitc_init_store))
api.register(api.GPMethod("pic", fit_pic, predict_fn=_pic_predict,
                          predict_diag_fn=_pic_predict_diag,
                          predict_routed_diag_fn=_pic_predict_routed_diag,
                          init_store=_pic_init_store, plan_fn=_pic_plan))
