"""Parallelized co-clustering of (D_m, U_m) — Remark 2 after Def. 5.

pPIC's local correction helps only if y_{D_m} and Y_{U_m} are correlated, so
training and test inputs must be co-located per machine. The paper's scheme:
each machine proposes one random center from its block, centers are shared
(all-gather), every point goes to its nearest center subject to the capacity
constraint |D_i| <= |D|/M, |U_i| <= |U|/M.

This is a *data-pipeline* step (host-side, pre-sharding), so it is implemented
in NumPy: capacity-constrained nearest-center assignment is a greedy fill in
best-distance order — O(n log n), deterministic given the key.
"""
from __future__ import annotations

import jax
import jax.numpy as jnp
import numpy as np


def propose_centers(X: np.ndarray, M: int, key) -> np.ndarray:
    """Each machine m picks one random center from its block (Def. 1 layout)."""
    n = X.shape[0]
    b = n // M
    offs = jax.random.randint(key, (M,), 0, b)
    idx = np.asarray(offs) + np.arange(M) * b
    return X[idx]


def capacity_assign(X: np.ndarray, centers: np.ndarray,
                    capacity: int) -> np.ndarray:
    """Greedy capacity-constrained nearest-center assignment.

    Points are processed in order of their best-center distance (closest
    first); a full machine falls through to the next-nearest center.
    Returns machine id per point; no machine exceeds ``capacity``, and when
    ``n == M * capacity`` every machine is filled exactly. ``n`` need not
    divide ``M`` — pass ``capacity = ceil(n / M)`` and the trailing slack is
    absorbed by whichever machines the greedy fill leaves short.
    """
    n, M = X.shape[0], centers.shape[0]
    assert n <= M * capacity, \
        f"M * capacity = {M * capacity} cannot hold n = {n} points"
    d2 = ((X[:, None, :] - centers[None, :, :]) ** 2).sum(-1)   # (n, M)
    pref = np.argsort(d2, axis=1)                               # (n, M)
    order = np.argsort(d2.min(axis=1))
    assign = np.full(n, -1, np.int64)
    load = np.zeros(M, np.int64)
    for p in order:
        for c in pref[p]:
            if load[c] < capacity:
                assign[p] = c
                load[c] += 1
                break
    return assign


def cocluster(X: np.ndarray, y: np.ndarray, U: np.ndarray, M: int, key):
    """Full Remark-2 scheme. Returns permuted (X, y, U) in block layout plus
    the permutations (so predictions can be un-permuted)."""
    X, y, U = np.asarray(X), np.asarray(y), np.asarray(U)
    centers = propose_centers(X, M, key)
    a_d = capacity_assign(X, centers, X.shape[0] // M)
    a_u = capacity_assign(U, centers, U.shape[0] // M)
    perm_d = np.argsort(a_d, kind="stable")
    perm_u = np.argsort(a_u, kind="stable")
    return X[perm_d], y[perm_d], U[perm_u], perm_d, perm_u


def uncluster(values: np.ndarray, perm: np.ndarray) -> np.ndarray:
    """Invert a cocluster permutation on per-point outputs."""
    out = np.empty_like(values)
    out[perm] = values
    return out


def nearest_center_np(X: np.ndarray, centers: np.ndarray) -> np.ndarray:
    """(n,) index of each row's nearest center — host-side NumPy.

    The serving queue groups tickets by target block BEFORE any device work
    (launch/gp_serve.py), so this must not touch XLA; it is the host mirror
    of ``ppic.route_queries`` (same centers, same squared-distance argmin),
    kept here so fit-time assignment and serve-time grouping share one
    definition.
    """
    X, centers = np.asarray(X), np.asarray(centers)
    d2 = ((X[:, None, :] - centers[None, :, :]) ** 2).sum(-1)
    return d2.argmin(axis=1)


def block_centroids(Xb) -> jax.Array:
    """(M, b, d) block layout -> (M, d) per-block data centroids.

    Unlike the rest of this module (host-side pipeline steps), this runs in
    jnp: the result is a ``PICState`` pytree leaf, built at fit time from
    device-resident blocks.

    These are the serving-side routing targets cached in ``api.PICState``:
    at predict time a query goes to the block whose centroid it is nearest
    (Remark 2 applied to queries that arrive after fit). The mean is the
    natural summary of "whose local data best explains this query" for
    stationary kernels — nearest centroid maximizes the expected local
    cross-covariance against the block.
    """
    return jnp.mean(Xb, axis=1)
