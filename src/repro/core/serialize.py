"""Versioned posterior-state AND incremental-store persistence (npz).

Serving fleets replicate by shipping ``PosteriorState`` pytrees, not data:
a state is a few small dense factors (|S|-space for the summary methods,
R-space for pICF), so a fitted/streamed posterior can be checkpointed on one
process and restored bit-for-bit on another (``GPServer.swap_from_checkpoint``
hot-swaps it under live traffic with zero recompilation when shapes match).

Format: one ``.npz`` per state. ``__schema__`` guards the container layout,
``__state__`` names the registered NamedTuple type, and every field is
stored as its own array under ``field:<name>`` — NumPy round-trips array
bits exactly, so ``load_state(save_state(p, s)) == s`` bitwise, dtypes
included (float64 fields need x64 enabled on load, as everywhere else).

The registry is keyed by type NAME, so any module can add its own state via
``register_state`` and the loader stays closed over registered types —
unknown or field-mismatched files fail loudly instead of mis-assembling.

``save_store``/``load_store`` persist the incremental STORES themselves
(``online.PITCStore``/``online.PICStore``/``picf.PICFStore``): the
per-machine summary factors, pPIC block caches, and the pICF pivot basis —
everything the Sec. 5.2 update algebra is closed over. A state checkpoint
lets a restarted process SERVE; a store checkpoint lets it keep
ASSIMILATING. Arrays round-trip bitwise under their own schema tag
(``__store_schema__``); the two non-array store members are encoded as
metadata — the kernel by registry name / ``KernelSpec`` fields, the runner
by mode + machine count — and anything unencodable (a bespoke kernel
closure, a ``ShardMapRunner`` whose mesh is process-local) must be
re-supplied via the ``kfn=``/``runner=`` overrides at load time, failing
loudly otherwise.
"""
from __future__ import annotations

import contextlib
import json
import pathlib
import zipfile
import zlib

import jax
import jax.numpy as jnp
import numpy as np

from repro.core import api
from repro.core import covariance as cov

SCHEMA_VERSION = 1
STORE_SCHEMA_VERSION = 1

_FIELD = "field:"


class CheckpointError(ValueError):
    """A checkpoint file cannot be trusted: missing, truncated, corrupt, or
    failing its embedded per-field checksums. Carries the offending ``path``
    and a human ``reason`` — the serving runtime's revive path keys on this
    (a corrupt artifact must be DETECTED, never loaded into a tenant)."""

    def __init__(self, path, reason: str):
        self.path = str(path)
        self.reason = reason
        super().__init__(f"{self.path}: {reason}")


@contextlib.contextmanager
def _checkpoint_io(path, kind: str):
    """Translate the raw failure modes of reading an npz — zipfile CRC/central-
    directory errors on truncated or bit-flipped files, ``KeyError`` on
    missing entries, NumPy header ``ValueError``s — into one CheckpointError
    with the path attached. Our own CheckpointErrors pass through."""
    try:
        yield
    except CheckpointError:
        raise
    except FileNotFoundError as e:
        raise CheckpointError(path, f"no such {kind}") from e
    except (zipfile.BadZipFile, EOFError, KeyError, OSError, ValueError) as e:
        raise CheckpointError(
            path, f"truncated or corrupt {kind} "
                  f"({type(e).__name__}: {e})") from e


def _crc(a: np.ndarray) -> int:
    return zlib.crc32(np.ascontiguousarray(a).tobytes())


def _checksum_meta(payload: dict) -> np.str_:
    return np.str_(json.dumps({k: _crc(v) for k, v in payload.items()}))


def _verify_checksums(path, z, arrays: dict) -> None:
    """Check materialized arrays against the embedded ``__checksums__`` map
    (absent on pre-checksum checkpoints: nothing to verify). The zip layer
    already CRCs each entry's bytes; this additionally pins the DECODED
    array content, so a checkpoint that unzips cleanly but decodes to the
    wrong bits (header tampering, partial rewrite) still fails loudly."""
    if "__checksums__" not in z.files:
        return
    want = json.loads(str(z["__checksums__"]))
    for k, a in arrays.items():
        if k in want and _crc(a) != want[k]:
            raise CheckpointError(
                path, f"checksum mismatch for {k!r} (file is corrupt — "
                      f"expected crc {want[k]}, got {_crc(a)})")

STATE_TYPES: dict[str, type] = {}


def register_state(cls: type) -> type:
    """Register a NamedTuple state type for save/load by name."""
    if not hasattr(cls, "_fields"):
        raise TypeError(f"{cls!r} is not a NamedTuple state type")
    STATE_TYPES[cls.__name__] = cls
    return cls


for _cls in (api.FGPState, api.PITCState, api.PICState, api.PICFState):
    register_state(_cls)


def save_state(path, state) -> pathlib.Path:
    """Write a registered PosteriorState to ``path`` (npz). Returns the
    path actually written (always exactly ``path`` — no implicit .npz
    suffix surprises)."""
    name = type(state).__name__
    if name not in STATE_TYPES:
        raise ValueError(
            f"cannot serialize unregistered state type {name!r}; "
            f"registered: {sorted(STATE_TYPES)} (register_state to extend)")
    path = pathlib.Path(path)
    traced = [f for f, v in zip(state._fields, state)
              if isinstance(v, jax.core.Tracer)]
    if traced:
        raise TypeError(
            f"save_state({name}) materializes every field on the host and "
            f"cannot run under jit/vmap (traced fields: {traced}); "
            "checkpoint from the serving loop, not inside a traced "
            "function")
    payload = {_FIELD + f: np.asarray(v) for f, v in
               zip(state._fields, state)}
    with open(path, "wb") as fh:
        np.savez(fh, __schema__=np.int64(SCHEMA_VERSION),
                 __state__=np.str_(name),
                 __checksums__=_checksum_meta(payload), **payload)
    return path


def load_state(path):
    """Reconstruct the state saved at ``path``; bitwise-identical leaves.
    Truncated/corrupt files (and checksum failures) raise
    ``CheckpointError`` instead of leaking raw zipfile/KeyError tracebacks."""
    with _checkpoint_io(path, "state checkpoint"), \
            np.load(pathlib.Path(path), allow_pickle=False) as z:
        if "__schema__" not in z or "__state__" not in z:
            raise CheckpointError(path, "not a repro state checkpoint")
        schema = int(z["__schema__"])
        if schema != SCHEMA_VERSION:
            raise CheckpointError(
                path, f"schema v{schema} != supported v{SCHEMA_VERSION}")
        name = str(z["__state__"])
        if name not in STATE_TYPES:
            raise CheckpointError(
                path, f"unknown state type {name!r}; registered: "
                      f"{sorted(STATE_TYPES)}")
        cls = STATE_TYPES[name]
        saved = {k[len(_FIELD):] for k in z.files if k.startswith(_FIELD)}
        if saved != set(cls._fields):
            raise CheckpointError(
                path, f"field mismatch for {name}: file has "
                      f"{sorted(saved)}, {name} expects "
                      f"{sorted(cls._fields)} (state schema drifted — "
                      f"migrate the checkpoint)")
        arrays = {_FIELD + f: z[_FIELD + f] for f in cls._fields}
        _verify_checksums(path, z, arrays)
        return cls(*(jnp.asarray(arrays[_FIELD + f]) for f in cls._fields))


def peek(path) -> dict:
    """Cheap metadata read: {'state': type name, 'schema': int, 'fields':
    {name: (shape, dtype)}} without materializing device arrays."""
    with _checkpoint_io(path, "state checkpoint"), \
            np.load(pathlib.Path(path), allow_pickle=False) as z:
        return {
            "state": str(z["__state__"]),
            "schema": int(z["__schema__"]),
            "fields": {k[len(_FIELD):]: (z[k].shape, str(z[k].dtype))
                       for k in z.files if k.startswith(_FIELD)},
        }


# ---------------------------------------------------------------------------
# Store checkpointing: persist the Sec. 5.2 algebra, not just its output.
# ---------------------------------------------------------------------------

def _kernel_meta(kfn) -> dict:
    """Encode a kernel by value where possible: a ``KernelSpec`` by its
    (frozen, declarative) fields, a registry kernel by name. Anything else
    is opaque — recorded for the error message, re-supplied at load."""
    if isinstance(kfn, cov.KernelSpec):
        return {"kind": "spec", "name": kfn.name, "impl": kfn.impl,
                "fused": kfn.fused, "block_q": kfn.block_q}
    for name, fn in cov.KERNELS.items():
        if fn is kfn:
            return {"kind": "named", "name": name}
    return {"kind": "opaque", "repr": repr(kfn)}


def _kernel_from_meta(meta: dict, override):
    if override is not None:
        return override
    if meta["kind"] == "named":
        return cov.make_kernel(meta["name"])
    if meta["kind"] == "spec":
        return cov.KernelSpec(meta["name"], meta["impl"], meta["fused"],
                              meta["block_q"])
    raise ValueError(
        f"store checkpoint carries an opaque kernel ({meta.get('repr')}); "
        f"pass load_store(..., kfn=<the fit-time kernel>) to restore")


def _spec_meta(spec: api.ServeSpec) -> dict:
    """Encode a ``ServeSpec`` as JSON metadata. Every field but the kernel
    is a plain scalar/tuple; the kernel reuses the kernel encoding above
    (an opaque kernel is recorded and fails loudly at DECODE time, so a
    checkpoint is always writable and re-admission with an explicit
    ``spec=`` override still works)."""
    return {
        "kernel": None if spec.kernel is None else _kernel_meta(spec.kernel),
        "block_q": spec.block_q, "max_batch": spec.max_batch,
        "buckets": None if spec.buckets is None else list(spec.buckets),
        "min_bucket": spec.min_bucket, "routed": spec.routed,
        "alpha": spec.alpha, "max_overflow_groups": spec.max_overflow_groups,
        "cached_cinv": spec.cached_cinv, "dtype": spec.dtype,
    }


def _spec_from_meta(meta: dict) -> api.ServeSpec:
    kernel = meta["kernel"]
    if kernel is not None and kernel["kind"] == "opaque":
        raise ValueError(
            f"store checkpoint's ServeSpec carries an opaque kernel "
            f"({kernel.get('repr')}); the serving policy cannot be "
            f"reconstructed from the artifact alone — pass an explicit "
            f"spec (e.g. TenantRegistry.admit_from_checkpoint(..., "
            f"spec=...))")
    kw = dict(meta, kernel=(None if kernel is None
                            else _kernel_from_meta(kernel, None)))
    buckets = kw["buckets"]
    kw["buckets"] = None if buckets is None else tuple(buckets)
    return api.ServeSpec(**kw)


def _runner_meta(runner) -> dict:
    from repro.parallel.runner import VmapRunner
    if isinstance(runner, VmapRunner):
        a = runner.axis_name
        return {"kind": "vmap", "M": int(runner.M),
                "axis_name": a if isinstance(a, str) else list(a)}
    return {"kind": "opaque", "repr": repr(runner)}


def _runner_from_meta(meta: dict, override):
    from repro.parallel.runner import VmapRunner
    if override is not None:
        return override
    if meta["kind"] == "vmap":
        a = meta["axis_name"]
        return VmapRunner(M=meta["M"],
                          axis_name=a if isinstance(a, str) else tuple(a))
    raise ValueError(
        f"store checkpoint carries an opaque runner ({meta.get('repr')} — "
        f"e.g. a ShardMapRunner, whose mesh is process-local); pass "
        f"load_store(..., runner=<a runner for this process>) to restore")


def _summary_arrays(s) -> dict:
    return {"sum:ydot": s.locals_.ydot, "sum:Sdot": s.locals_.Sdot,
            "sum:F": s.F, "sum:alive": s.alive, "sum:Kss": s.Kss,
            "sum:Kss_L": s.Kss_L, "sum:Sdd_L": s.Sdd_L, "sum:ydd": s.ydd}


def _summary_from(arr) -> "object":
    from repro.core.online import SummaryStore
    from repro.core.ppitc import LocalSummary
    return SummaryStore(LocalSummary(arr["sum:ydot"], arr["sum:Sdot"]),
                        arr["sum:F"], arr["sum:alive"], arr["sum:Kss"],
                        arr["sum:Kss_L"], arr["sum:Sdd_L"], arr["sum:ydd"])


def _pitc_store_arrays(store) -> dict:
    return {"arr:S": store.S, **_summary_arrays(store.store)}


def _pitc_store_from(kfn, params, runner, arr):
    from repro.core.online import PITCStore
    return PITCStore(kfn, params, arr["arr:S"], runner, _summary_from(arr))


_PIC_BLOCK_FIELDS = ("Xb", "yb", "Ksd", "C_L", "Wy", "beta", "B")


def _pic_store_arrays(store) -> dict:
    out = {"arr:S": store.S, **_summary_arrays(store.store)}
    out.update({f"blk:{f}": getattr(store.blocks, f)
                for f in _PIC_BLOCK_FIELDS})
    return out


def _pic_store_from(kfn, params, runner, arr):
    from repro.core.online import PICBlocks, PICStore
    blocks = PICBlocks(*(arr[f"blk:{f}"] for f in _PIC_BLOCK_FIELDS))
    return PICStore(kfn, params, arr["arr:S"], runner, _summary_from(arr),
                    blocks)


_PICF_FIELDS = ("Xb", "yb", "F", "Xp", "Lp", "alive", "Phi_L", "yF")


def _picf_store_arrays(store) -> dict:
    return {f"arr:{f}": getattr(store, f) for f in _PICF_FIELDS}


def _picf_store_from(kfn, params, runner, arr):
    from repro.core.picf import PICFStore
    return PICFStore(kfn, params, runner,
                     *(arr[f"arr:{f}"] for f in _PICF_FIELDS))


_SUM_KEYS = ("sum:ydot", "sum:Sdot", "sum:F", "sum:alive", "sum:Kss",
             "sum:Kss_L", "sum:Sdd_L", "sum:ydd")

# name -> (flatten, rebuild(kfn, params, runner, arrays), expected keys)
STORE_TYPES: dict[str, tuple] = {
    "PITCStore": (_pitc_store_arrays, _pitc_store_from,
                  frozenset(("arr:S",) + _SUM_KEYS)),
    "PICStore": (_pic_store_arrays, _pic_store_from,
                 frozenset(("arr:S",) + _SUM_KEYS
                           + tuple(f"blk:{f}" for f in _PIC_BLOCK_FIELDS))),
    "PICFStore": (_picf_store_arrays, _picf_store_from,
                  frozenset(f"arr:{f}" for f in _PICF_FIELDS)),
}

_PARAM = "param:"


def save_store(path, store, *, spec: api.ServeSpec | None = None
               ) -> pathlib.Path:
    """Write an incremental ``StateStore`` to ``path`` (npz). Arrays —
    summaries, factors, block caches, pivot basis, hyperparameters —
    round-trip bitwise; the kernel and runner are encoded as metadata (see
    module docstring). ``spec=`` additionally embeds the deployment's
    ``ServeSpec`` next to the store, making the checkpoint a COMPLETE
    serving artifact: a restarted fleet member re-admits the tenant —
    posterior, streaming algebra, and serving policy — from this one file
    (``serving.TenantRegistry.admit_from_checkpoint``). Returns the path
    written."""
    name = type(store).__name__
    if name not in STORE_TYPES:
        raise ValueError(
            f"cannot serialize store type {name!r}; "
            f"supported: {sorted(STORE_TYPES)}")
    flatten, _, _ = STORE_TYPES[name]
    leaves = flatten(store)
    traced = [k for k, v in leaves.items()
              if isinstance(v, jax.core.Tracer)]
    if traced:
        raise TypeError(
            f"save_store({name}) materializes every array on the host and "
            f"cannot run under jit/vmap (traced leaves: {traced}); "
            "checkpoint from the serving loop, not inside a traced "
            "function")
    payload = {k: np.asarray(v) for k, v in leaves.items()}
    payload.update({_PARAM + k: np.asarray(v)
                    for k, v in store.params.items()})
    payload["__checksums__"] = _checksum_meta(
        {k: v for k, v in payload.items() if not k.startswith("__")})
    if spec is not None:
        payload["__serve_spec__"] = np.str_(json.dumps(_spec_meta(spec)))
    path = pathlib.Path(path)
    with open(path, "wb") as fh:
        np.savez(fh, __store_schema__=np.int64(STORE_SCHEMA_VERSION),
                 __store__=np.str_(name),
                 __kernel__=np.str_(json.dumps(_kernel_meta(store.kfn))),
                 __runner__=np.str_(json.dumps(_runner_meta(store.runner))),
                 **payload)
    return path


def load_store(path, *, kfn=None, runner=None, with_spec: bool = False):
    """Reconstruct the store saved at ``path``; array members bitwise-
    identical, so a restarted fleet resumes assimilating exactly where the
    checkpoint left off. ``kfn``/``runner`` override the encoded members
    (REQUIRED when the checkpoint recorded them as opaque).

    ``with_spec=True`` returns ``(store, spec)`` where ``spec`` is the
    embedded ``ServeSpec`` (``None`` when the checkpoint predates spec
    embedding or was saved without ``spec=``).

    Truncated/corrupt files — and files whose arrays fail the embedded
    ``__checksums__`` — raise ``CheckpointError`` (path + reason), never a
    raw ``zipfile``/``KeyError`` traceback: the serving revive path must be
    able to tell 'artifact is bad' from 'loader is broken'."""
    with _checkpoint_io(path, "store checkpoint"), \
            np.load(pathlib.Path(path), allow_pickle=False) as z:
        if "__store_schema__" not in z or "__store__" not in z:
            raise CheckpointError(
                path, "not a repro store checkpoint (state checkpoints "
                      "load via load_state)")
        schema = int(z["__store_schema__"])
        if schema != STORE_SCHEMA_VERSION:
            raise CheckpointError(
                path, f"store schema v{schema} != supported "
                      f"v{STORE_SCHEMA_VERSION}")
        name = str(z["__store__"])
        if name not in STORE_TYPES:
            raise CheckpointError(
                path, f"unknown store type {name!r}; "
                      f"supported: {sorted(STORE_TYPES)}")
        _, rebuild, expect = STORE_TYPES[name]
        raw = {k: z[k] for k in z.files
               if k.startswith(("arr:", "sum:", "blk:", _PARAM))}
        _verify_checksums(path, z, raw)
        arr = {k: jnp.asarray(v) for k, v in raw.items()
               if not k.startswith(_PARAM)}
        if set(arr) != set(expect):
            raise CheckpointError(
                path, f"field mismatch for {name}: file has "
                      f"{sorted(arr)}, expected {sorted(expect)} "
                      f"(store schema drifted — migrate the checkpoint)")
        params = {k[len(_PARAM):]: jnp.asarray(v) for k, v in raw.items()
                  if k.startswith(_PARAM)}
        kfn = _kernel_from_meta(json.loads(str(z["__kernel__"])), kfn)
        runner = _runner_from_meta(json.loads(str(z["__runner__"])), runner)
        store = rebuild(kfn, params, runner, arr)
        if not with_spec:
            return store
        spec = (None if "__serve_spec__" not in z.files else
                _spec_from_meta(json.loads(str(z["__serve_spec__"]))))
        return store, spec


def peek_store(path) -> dict:
    """Cheap metadata read for a store checkpoint: type, schema, kernel and
    runner encodings, and array shapes/dtypes."""
    with _checkpoint_io(path, "store checkpoint"), \
            np.load(pathlib.Path(path), allow_pickle=False) as z:
        return {
            "store": str(z["__store__"]),
            "schema": int(z["__store_schema__"]),
            "kernel": json.loads(str(z["__kernel__"])),
            "runner": json.loads(str(z["__runner__"])),
            "serve_spec": (json.loads(str(z["__serve_spec__"]))
                           if "__serve_spec__" in z.files else None),
            "fields": {k: (z[k].shape, str(z[k].dtype)) for k in z.files
                       if k.startswith(("arr:", "sum:", "blk:", _PARAM))},
        }
