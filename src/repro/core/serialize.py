"""Versioned posterior-state persistence (npz + schema tag).

Serving fleets replicate by shipping ``PosteriorState`` pytrees, not data:
a state is a few small dense factors (|S|-space for the summary methods,
R-space for pICF), so a fitted/streamed posterior can be checkpointed on one
process and restored bit-for-bit on another (``GPServer.swap_from_checkpoint``
hot-swaps it under live traffic with zero recompilation when shapes match).

Format: one ``.npz`` per state. ``__schema__`` guards the container layout,
``__state__`` names the registered NamedTuple type, and every field is
stored as its own array under ``field:<name>`` — NumPy round-trips array
bits exactly, so ``load_state(save_state(p, s)) == s`` bitwise, dtypes
included (float64 fields need x64 enabled on load, as everywhere else).

The registry is keyed by type NAME, so any module can add its own state via
``register_state`` and the loader stays closed over registered types —
unknown or field-mismatched files fail loudly instead of mis-assembling.
"""
from __future__ import annotations

import pathlib

import jax.numpy as jnp
import numpy as np

from repro.core import api

SCHEMA_VERSION = 1

_FIELD = "field:"

STATE_TYPES: dict[str, type] = {}


def register_state(cls: type) -> type:
    """Register a NamedTuple state type for save/load by name."""
    if not hasattr(cls, "_fields"):
        raise TypeError(f"{cls!r} is not a NamedTuple state type")
    STATE_TYPES[cls.__name__] = cls
    return cls


for _cls in (api.FGPState, api.PITCState, api.PICState, api.PICFState):
    register_state(_cls)


def save_state(path, state) -> pathlib.Path:
    """Write a registered PosteriorState to ``path`` (npz). Returns the
    path actually written (always exactly ``path`` — no implicit .npz
    suffix surprises)."""
    name = type(state).__name__
    if name not in STATE_TYPES:
        raise ValueError(
            f"cannot serialize unregistered state type {name!r}; "
            f"registered: {sorted(STATE_TYPES)} (register_state to extend)")
    path = pathlib.Path(path)
    payload = {_FIELD + f: np.asarray(v) for f, v in
               zip(state._fields, state)}
    with open(path, "wb") as fh:
        np.savez(fh, __schema__=np.int64(SCHEMA_VERSION),
                 __state__=np.str_(name), **payload)
    return path


def load_state(path):
    """Reconstruct the state saved at ``path``; bitwise-identical leaves."""
    with np.load(pathlib.Path(path), allow_pickle=False) as z:
        if "__schema__" not in z or "__state__" not in z:
            raise ValueError(f"{path}: not a repro state checkpoint")
        schema = int(z["__schema__"])
        if schema != SCHEMA_VERSION:
            raise ValueError(
                f"{path}: schema v{schema} != supported v{SCHEMA_VERSION}")
        name = str(z["__state__"])
        if name not in STATE_TYPES:
            raise ValueError(
                f"{path}: unknown state type {name!r}; registered: "
                f"{sorted(STATE_TYPES)}")
        cls = STATE_TYPES[name]
        saved = {k[len(_FIELD):] for k in z.files if k.startswith(_FIELD)}
        if saved != set(cls._fields):
            raise ValueError(
                f"{path}: field mismatch for {name}: file has "
                f"{sorted(saved)}, {name} expects {sorted(cls._fields)} "
                f"(state schema drifted — migrate the checkpoint)")
        return cls(*(jnp.asarray(z[_FIELD + f]) for f in cls._fields))


def peek(path) -> dict:
    """Cheap metadata read: {'state': type name, 'schema': int, 'fields':
    {name: (shape, dtype)}} without materializing device arrays."""
    with np.load(pathlib.Path(path), allow_pickle=False) as z:
        return {
            "state": str(z["__state__"]),
            "schema": int(z["__schema__"]),
            "fields": {k[len(_FIELD):]: (z[k].shape, str(z[k].dtype))
                       for k in z.files if k.startswith(_FIELD)},
        }
