"""Centralized ICF-approximated GP regression — paper Sec. 4 baseline.

* ``icf_factor`` — pivoted incomplete Cholesky factorization of the *signal*
  kernel matrix K_DD (noise-free): returns upper-triangular-in-pivot-order
  F (R x |D|) with K_DD ~= F^T F. Never forms K_DD: only diag(K) and one
  kernel column per pivot step (O(R |D|) kernel evaluations, O(R^2 |D|) flops).
* ``icf_predict_literal`` — eqs. (28)-(29) with a dense |D|x|D| solve; the
  oracle for the Theorem 3 equivalence test.
* ``icf_predict`` — efficient centralized version via the Woodbury identity
    (F^T F + s^2 I)^{-1} = s^{-2} I - s^{-4} F^T Phi^{-1} F,
    Phi = I + s^{-2} F F^T                       (R x R),
  which is exactly what the distributed steps 3-6 compute; Table 1 row
  "ICF-based".

Zero prior mean is assumed (data pipeline centers y).
"""
from __future__ import annotations

from typing import NamedTuple

import jax
import jax.numpy as jnp

from repro.core import covariance as cov
from repro.core import linalg
from repro.core.gp import GPPosterior


class ICFFactor(NamedTuple):
    F: jax.Array        # (R, n) incomplete Cholesky factor, K ~= F^T F
    pivots: jax.Array   # (R,) pivot indices in selection order
    residual: jax.Array  # (n,) remaining diagonal residual (trace error)


def icf_factor(kfn, params, X: jax.Array, R: int) -> ICFFactor:
    """Pivoted incomplete Cholesky of the signal kernel matrix."""
    n = X.shape[0]
    d0 = cov.kdiag(kfn, params, X)                    # diag of K (signal)
    F0 = jnp.zeros((R, n), d0.dtype)
    piv0 = jnp.zeros((R,), jnp.int32)

    def step(i, carry):
        F, d, piv = carry
        p = jnp.argmax(d)
        xp = jax.lax.dynamic_slice_in_dim(X, p, 1, axis=0)       # (1, dim)
        col = kfn(params, xp, X)[0]                              # K[p, :]
        fp = F[:, p]                                             # F[:i, p] (rest 0)
        f = (col - F.T @ fp) / jnp.sqrt(jnp.maximum(d[p], 1e-30))
        F = jax.lax.dynamic_update_slice_in_dim(F, f[None], i, axis=0)
        d = jnp.maximum(d - f * f, 0.0)
        d = d.at[p].set(0.0)
        piv = piv.at[i].set(p.astype(jnp.int32))
        return F, d, piv

    F, d, piv = jax.lax.fori_loop(0, R, step, (F0, d0, piv0))
    return ICFFactor(F, piv, d)


def icf_predict_literal(kfn, params, X_train, y_train, X_test,
                        F: jax.Array) -> GPPosterior:
    """Eqs. (28)-(29) with the dense (F^T F + s^2 I) solve. Test oracle."""
    s2 = cov.noise_var(params)
    n = X_train.shape[0]
    A = F.T @ F + s2 * jnp.eye(n, dtype=F.dtype)
    A_L = linalg.chol(A, jitter=0.0)
    K_ud = kfn(params, X_test, X_train)
    mean = (K_ud @ linalg.chol_solve(A_L, y_train[:, None]))[:, 0]
    K_uu = kfn(params, X_test, X_test)
    covm = K_uu - K_ud @ linalg.chol_solve(A_L, K_ud.T)
    return GPPosterior(mean, covm)


def icf_predict(kfn, params, X_train, y_train, X_test,
                F: jax.Array) -> GPPosterior:
    """Woodbury form — O(R^2 |D| + R |U| |D|), Table 1 row "ICF-based"."""
    s2 = cov.noise_var(params)
    R = F.shape[0]
    Phi = jnp.eye(R, dtype=F.dtype) + F @ F.T / s2            # (R, R)
    Phi_L = linalg.chol(Phi, jitter=0.0)

    K_ud = kfn(params, X_test, X_train)                       # (u, n)
    ydot = F @ y_train                                        # (R,)
    Sdot = F @ K_ud.T                                         # (R, u)
    ydd = linalg.chol_solve(Phi_L, ydot[:, None])[:, 0]       # eq. (22)
    Sdd = linalg.chol_solve(Phi_L, Sdot)                      # eq. (23)

    mean = (K_ud @ y_train) / s2 - (Sdot.T @ ydd) / s2**2     # eqs. (24),(26)
    K_uu = kfn(params, X_test, X_test)
    covm = K_uu - (K_ud @ K_ud.T) / s2 + (Sdot.T @ Sdd) / s2**2   # (25),(27)
    return GPPosterior(mean, covm)
