"""Covariance (kernel) functions for GP regression.

A kernel is a pure function ``k(params, X1, X2) -> (n1, n2)`` over the *signal*
part only; observation noise sigma_n^2 * I is added explicitly where the paper's
equations call for it (the paper's sigma_xx' includes a Kronecker-delta noise
term — we keep it separate so that cross-covariances K_SD, K_UD never
accidentally carry noise).

Params are stored in log-space for unconstrained MLE (core/hyper.py):
  {"log_signal": (), "log_noise": (), "log_lengthscale": (d,)}

The squared-exponential path can route through the Pallas TPU kernel
(kernels/rbf) when ``impl="pallas"`` — the fused pairwise-distance+exp tiling is
the dominant FLOP producer of local-summary construction.
"""
from __future__ import annotations

import math
from functools import partial
from typing import Callable

import jax
import jax.numpy as jnp

KernelFn = Callable[[dict, jax.Array, jax.Array], jax.Array]


def init_params(d: int, *, signal: float = 1.0, noise: float = 0.1,
                lengthscale: float | jax.Array = 1.0,
                dtype=jnp.float32) -> dict:
    ls = jnp.broadcast_to(jnp.asarray(lengthscale, dtype), (d,))
    return {
        "log_signal": jnp.asarray(math.log(signal), dtype),
        "log_noise": jnp.asarray(math.log(noise), dtype),
        "log_lengthscale": jnp.log(ls),
    }


def signal_var(params: dict) -> jax.Array:
    return jnp.exp(2.0 * params["log_signal"])


def noise_var(params: dict) -> jax.Array:
    return jnp.exp(2.0 * params["log_noise"])


def _scale(params: dict, X: jax.Array) -> jax.Array:
    return X / jnp.exp(params["log_lengthscale"])


def _sqdist(A: jax.Array, B: jax.Array) -> jax.Array:
    """Pairwise squared distances, clamped at 0 against roundoff."""
    a2 = jnp.sum(A * A, axis=-1)[:, None]
    b2 = jnp.sum(B * B, axis=-1)[None, :]
    d2 = a2 + b2 - 2.0 * (A @ B.T)
    return jnp.maximum(d2, 0.0)


def se_ard(params: dict, X1: jax.Array, X2: jax.Array) -> jax.Array:
    """Squared-exponential ARD kernel (paper Sec. 6, signal part)."""
    d2 = _sqdist(_scale(params, X1), _scale(params, X2))
    return signal_var(params) * jnp.exp(-0.5 * d2)


def se_ard_pallas(params: dict, X1: jax.Array, X2: jax.Array) -> jax.Array:
    """SE-ARD routed through the Pallas fused kernel (TPU hot path)."""
    from repro.kernels.rbf import ops as rbf_ops
    return rbf_ops.rbf_covariance(
        _scale(params, X1), _scale(params, X2), signal_var(params))


def matern52(params: dict, X1: jax.Array, X2: jax.Array) -> jax.Array:
    d2 = _sqdist(_scale(params, X1), _scale(params, X2))
    r = jnp.sqrt(d2 + 1e-12) * math.sqrt(5.0)
    return signal_var(params) * (1.0 + r + r * r / 3.0) * jnp.exp(-r)


def rational_quadratic(params: dict, X1: jax.Array, X2: jax.Array,
                       alpha: float = 1.0) -> jax.Array:
    d2 = _sqdist(_scale(params, X1), _scale(params, X2))
    return signal_var(params) * (1.0 + d2 / (2.0 * alpha)) ** (-alpha)


KERNELS: dict[str, KernelFn] = {
    "se": se_ard,
    "se_pallas": se_ard_pallas,
    "matern52": matern52,
    "rq": partial(rational_quadratic, alpha=1.0),
}


def make_kernel(name: str) -> KernelFn:
    try:
        return KERNELS[name]
    except KeyError:
        raise ValueError(f"unknown kernel {name!r}; have {sorted(KERNELS)}")


def kdiag(kfn: KernelFn, params: dict, X: jax.Array) -> jax.Array:
    """diag k(X, X) without forming the matrix (O(n·d))."""
    return jax.vmap(lambda x: kfn(params, x[None], x[None])[0, 0])(X)


def add_noise(K: jax.Array, params: dict) -> jax.Array:
    """K + sigma_n^2 I — the paper's delta_xx' noise term (square K only)."""
    return K + noise_var(params) * jnp.eye(K.shape[-1], dtype=K.dtype)
