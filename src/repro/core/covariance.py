"""Covariance (kernel) functions for GP regression.

A kernel is a pure function ``k(params, X1, X2) -> (n1, n2)`` over the *signal*
part only; observation noise sigma_n^2 * I is added explicitly where the paper's
equations call for it (the paper's sigma_xx' includes a Kronecker-delta noise
term — we keep it separate so that cross-covariances K_SD, K_UD never
accidentally carry noise).

Params are stored in log-space for unconstrained MLE (core/hyper.py):
  {"log_signal": (), "log_noise": (), "log_lengthscale": (d,)}

The squared-exponential path can route through the Pallas TPU kernel
(kernels/rbf) when ``impl="pallas"`` — the fused pairwise-distance+exp tiling is
the dominant FLOP producer of local-summary construction.

``KernelSpec`` is the serving-side kernel abstraction: a callable drop-in for
any bare ``KernelFn`` that additionally DECLARES how cross-covariances should
be built (dense jnp vs the fused Pallas tiling) and whether the predict paths
may collapse covariance + cached solves + variance reduction into the fused
``xcov_diag`` serving kernel (kernels/rbf/xcov.py). Every registered predict
path accepts a spec wherever it accepts a kernel function — the spec routes
``k(params, X1, X2)`` through its declared implementation transparently, so
``ppic``/``picf``/``fgp`` cross-covariance assembly moves onto the Pallas hot
path without touching their math.
"""
from __future__ import annotations

import dataclasses
import math
from functools import partial
from typing import Callable

import jax
import jax.numpy as jnp

KernelFn = Callable[[dict, jax.Array, jax.Array], jax.Array]


def init_params(d: int, *, signal: float = 1.0, noise: float = 0.1,
                lengthscale: float | jax.Array = 1.0,
                dtype=jnp.float32) -> dict:
    ls = jnp.broadcast_to(jnp.asarray(lengthscale, dtype), (d,))
    return {
        "log_signal": jnp.asarray(math.log(signal), dtype),
        "log_noise": jnp.asarray(math.log(noise), dtype),
        "log_lengthscale": jnp.log(ls),
    }


def signal_var(params: dict) -> jax.Array:
    return jnp.exp(2.0 * params["log_signal"])


def noise_var(params: dict) -> jax.Array:
    return jnp.exp(2.0 * params["log_noise"])


def _scale(params: dict, X: jax.Array) -> jax.Array:
    return X / jnp.exp(params["log_lengthscale"])


def _sqdist(A: jax.Array, B: jax.Array) -> jax.Array:
    """Pairwise squared distances, clamped at 0 against roundoff."""
    a2 = jnp.sum(A * A, axis=-1)[:, None]
    b2 = jnp.sum(B * B, axis=-1)[None, :]
    d2 = a2 + b2 - 2.0 * (A @ B.T)
    return jnp.maximum(d2, 0.0)


def se_ard(params: dict, X1: jax.Array, X2: jax.Array) -> jax.Array:
    """Squared-exponential ARD kernel (paper Sec. 6, signal part)."""
    d2 = _sqdist(_scale(params, X1), _scale(params, X2))
    return signal_var(params) * jnp.exp(-0.5 * d2)


def se_ard_pallas(params: dict, X1: jax.Array, X2: jax.Array) -> jax.Array:
    """SE-ARD routed through the Pallas fused kernel (TPU hot path)."""
    from repro.kernels.rbf import ops as rbf_ops
    return rbf_ops.rbf_covariance(
        _scale(params, X1), _scale(params, X2), signal_var(params))


def matern52(params: dict, X1: jax.Array, X2: jax.Array) -> jax.Array:
    d2 = _sqdist(_scale(params, X1), _scale(params, X2))
    r = jnp.sqrt(d2 + 1e-12) * math.sqrt(5.0)
    return signal_var(params) * (1.0 + r + r * r / 3.0) * jnp.exp(-r)


def rational_quadratic(params: dict, X1: jax.Array, X2: jax.Array,
                       alpha: float = 1.0) -> jax.Array:
    d2 = _sqdist(_scale(params, X1), _scale(params, X2))
    return signal_var(params) * (1.0 + d2 / (2.0 * alpha)) ** (-alpha)


KERNELS: dict[str, KernelFn] = {
    "se": se_ard,
    "se_pallas": se_ard_pallas,
    "matern52": matern52,
    "rq": partial(rational_quadratic, alpha=1.0),
}


def make_kernel(name: str) -> KernelFn:
    try:
        return KERNELS[name]
    except KeyError:
        raise ValueError(f"unknown kernel {name!r}; have {sorted(KERNELS)}")


# ---------------------------------------------------------------------------
# KernelSpec — the serving-side kernel abstraction (hot-path declaration).
# ---------------------------------------------------------------------------

_SE_FAMILY = ("se", "se_pallas")


@dataclasses.dataclass(frozen=True)
class KernelSpec:
    """A kernel plus its declared cross-covariance/serving implementation.

    Callable with the ``KernelFn`` signature, so it drops into every fit and
    predict path unchanged. What it adds over a bare function:

    * ``impl`` — how cross-covariances are assembled: ``"auto"`` (Pallas on
      TPU, dense jnp elsewhere), ``"pallas"`` (compiled kernel),
      ``"pallas_interpret"`` (Python-executed kernel body, for validation on
      CPU), ``"jnp"`` (always dense). Only the SE family has a Pallas
      realization; other kernels fall through to their dense fn.
    * ``fused`` — allow predict paths with S-space cached factors (ppitc /
      pitc eqs. 7-8, fgp eqs. 1-2) to dispatch the fused ``xcov_diag``
      serving kernel: covariance tile + cached triangular solve + variance
      quadratic form in one VMEM-resident pass (kernels/rbf/xcov.py).
      Honoured only when ``impl`` resolves to a Pallas mode and the cached
      factor fits the kernel's VMEM residency cap.
    * ``block_q`` — serving tile override; also consumed by the two-bucket
      routed scatter (ppic.predict_routed_diag) and ``default_buckets`` so
      microbatch padding lands on kernel tile boundaries.

    Frozen/hashable: safe to close over in jitted serving functions.
    """
    name: str = "se"
    impl: str = "auto"
    fused: bool = True
    block_q: int | None = None

    @property
    def kfn(self) -> KernelFn:
        return make_kernel(self.name)

    def resolved_impl(self) -> str:
        if self.impl == "auto":
            return "pallas" if jax.default_backend() == "tpu" else "jnp"
        return self.impl

    def __call__(self, params: dict, X1: jax.Array, X2: jax.Array):
        impl = self.resolved_impl()
        if self.name not in _SE_FAMILY or impl == "jnp":
            # dense path in the native dtype (float64 equivalence tests)
            return (se_ard if self.name in _SE_FAMILY else self.kfn)(
                params, X1, X2)
        from repro.kernels.rbf import ops as rbf_ops
        return rbf_ops.rbf_covariance(
            _scale(params, X1), _scale(params, X2), signal_var(params),
            impl=impl)

    def diag(self, params: dict, X: jax.Array) -> jax.Array:
        """diag k(X, X) — constant sig2 for the stationary kernels this
        registry carries (no per-row kernel dispatch)."""
        return jnp.full((X.shape[0],), signal_var(params), X.dtype)

    def fuse(self, k: int) -> bool:
        """May the S-space diag predict collapse into ``xcov_diag`` for a
        cached factor of size k? (Pallas impl + VMEM-resident factor.)"""
        from repro.kernels.rbf import ops as rbf_ops
        return (self.fused and self.name in _SE_FAMILY
                and self.resolved_impl() in ("pallas", "pallas_interpret")
                and -(-k // 128) * 128 <= rbf_ops.MAX_FUSED_RESIDENT)

    def fused_diag(self, params: dict, U: jax.Array, Xk: jax.Array,
                   L1: jax.Array, alpha: jax.Array,
                   L2: jax.Array | None = None):
        """Dispatch the fused serving kernel: (mean, var) with
        var = sig2 - q(L1) [+ q(L2)] over lengthscale-scaled inputs."""
        from repro.kernels.rbf import ops as rbf_ops
        return rbf_ops.xcov_diag(
            _scale(params, U), _scale(params, Xk), L1, alpha,
            signal_var(params), L2, impl=self.resolved_impl(),
            block_q=self.block_q)


_IMPLS = ("auto", "pallas", "pallas_interpret", "jnp")


def make_spec(name: str = "se", *, impl: str = "auto", fused: bool = True,
              block_q: int | None = None) -> KernelSpec:
    """Front door for the serving kernel-spec knob (README "Performance").

    Validates eagerly: the spec's declared ``block_q`` becomes the serving
    tile that bucket ladders and the routed scatter align to
    (``api.ServeSpec.resolve_block_q``), so a non-positive tile must fail
    here, not as a silent mis-aligned ladder at plan-build time."""
    make_kernel(name)            # validate eagerly
    if impl not in _IMPLS:
        raise ValueError(f"unknown kernel impl {impl!r}; have {_IMPLS}")
    if block_q is not None and block_q < 1:
        raise ValueError(f"block_q must be a positive tile size; got "
                         f"{block_q}")
    return KernelSpec(name, impl, fused, block_q)


def kdiag(kfn: KernelFn, params: dict, X: jax.Array) -> jax.Array:
    """diag k(X, X) without forming the matrix (O(n·d))."""
    if isinstance(kfn, KernelSpec):
        return kfn.diag(params, X)
    return jax.vmap(lambda x: kfn(params, x[None], x[None])[0, 0])(X)


def add_noise(K: jax.Array, params: dict) -> jax.Array:
    """K + sigma_n^2 I — the paper's delta_xx' noise term (square K only)."""
    return K + noise_var(params) * jnp.eye(K.shape[-1], dtype=K.dtype)
