"""Unified GP method API: ``fit -> PosteriorState -> plan -> serve``.

The paper's real-time claim rests on amortization: everything that is
O((|D|/M)^3) or O(|S|^3) happens ONCE at fit time and is cached in a
per-method ``PosteriorState`` (a pure-array NamedTuple, hence a pytree that
jits, shards, checkpoints, and hot-swaps); a repeated query then costs only
the cross-covariances against the cached factors — O(|U||S| + |S|^2) for the
summary methods instead of re-running the local Cholesky pipeline.

Serving is TWO-phase (the plan/execute split):

* phase 1 — ``GPMethod.plan(kfn, params, state, spec) -> ServePlan``: a
  ``ServeSpec`` declares every per-deployment serving decision ONCE (kernel
  spec, query tile, bucket ladder, routed dispatch, overflow-executable
  ladder, backend caches, dtype policy), and the plan owns what was
  precompiled for that state: jitted executables per bucket (and, for
  routed pPIC, per overflow-group count) plus backend caches such as the
  per-block ``C⁻¹`` that turns the per-flush batched triangular solve into
  a batched matmul. ``plan.rebind(state)`` hot-swaps the posterior while
  REUSING every executable (zero recompilation when the state keeps its
  treedef/shapes) — the serving fleet's assimilate/retire path.
* phase 2 — ``plan.diag(U)`` / ``plan.routed_diag(U)`` / ``plan.full(U)``:
  the only predict entry points serving uses. ``FittedGP.predict*`` and
  ``launch.gp_serve.GPServer`` are thin clients of a plan (the legacy
  per-call ``GPMethod.predict*`` shim surface is gone — one deprecation
  cycle, as promised). ``ServeSpec.compat_key`` names the resolved policy
  so the multi-tenant registry (``serving/``) can share one executable
  lineage across plan-compatible deployments.

Three structural layers below the plans:

* per-method states   — ``FGPState`` / ``PITCState`` / ``PICState`` /
  ``PICFState``, defined here so core modules, runners, serving, and
  checkpointing all agree on the cached representation;
* ``GPMethod``        — (name, fit, predict impls, plan builder) registered
  by each core module at import; ``get``/``names`` look methods up by
  string, which is what examples/benchmarks/serving use;
* ``FittedGP``        — convenience pairing of (method, kfn, params, state)
  with plan-backed ``predict``/``predict_diag`` and ``with_state`` (which
  rebinds any already-built plans).

Fit is runner-agnostic: the summary/factor construction goes through
``parallel.runner.Runner.map``, so ``VmapRunner`` and ``ShardMapRunner``
produce the same state pytree (tested in tests/test_shardmap.py).

On top of the cached states sits the incremental-state layer (Sec. 5.2):
``StateStore`` is the method-owned protocol that unifies cold fits,
streaming assimilation, machine retirement, and checkpointing — a cold fit
is just ``init_store(...).to_state()``, and every later mutation reuses the
already-paid O(b³)/O(|S|³) work (``core/online.py`` for pPITC/pPIC,
``core/picf.py`` for the ICF factor). ``core/serialize.py`` persists every
registered state — and the stores themselves — with versioned schemas so
serving fleets can checkpoint, restore, replicate, and keep assimilating.
"""
from __future__ import annotations

import dataclasses
from typing import Any, Callable, NamedTuple, Protocol, runtime_checkable

import jax
import numpy as np

from repro.parallel.runner import ROUTED_ALPHA


# ---------------------------------------------------------------------------
# Per-method posterior states (pure-array pytrees).
# ---------------------------------------------------------------------------

class FGPState(NamedTuple):
    """Exact GP: cached |D|x|D| Cholesky + weights (eqs. 1-2)."""
    X: jax.Array        # (n, d) training inputs
    L: jax.Array        # (n, n) chol(K_DD + noise)
    alpha: jax.Array    # (n,)   (K_DD + noise)^{-1} y


class PITCState(NamedTuple):
    """PITC/pPITC: everything global lives in S-space (eqs. 5-8)."""
    S: jax.Array        # (s, d) support set
    Kss_L: jax.Array    # (s, s) chol K_SS
    Sdd_L: jax.Array    # (s, s) chol Sigma-dot_DD  (eq. 6)
    alpha: jax.Array    # (s,)   Sdd^{-1} ydd       (eq. 7 weights)


class PICState(NamedTuple):
    """PIC/pPIC: PITC globals + per-block caches for the local correction
    (eqs. 12-14). Leading axis of the block fields is the machine axis M.

    ``centroids`` realizes Remark 2 on the serving side: the per-block data
    centroids fixed at fit time let ``ppic.predict_routed`` assign each query
    to the block whose local data best explains it, independent of how the
    query batch happens to be composed."""
    S: jax.Array        # (s, d)
    Kss_L: jax.Array    # (s, s)
    Sdd_L: jax.Array    # (s, s)
    alpha: jax.Array    # (s,)    Sdd^{-1} ydd
    Xb: jax.Array       # (M, b, d) data blocks
    yb: jax.Array       # (M, b)
    Ksd: jax.Array      # (M, s, b) cached K_S,Dm
    C_L: jax.Array      # (M, b, b) chol Sigma_{DmDm|S}
    Wy: jax.Array       # (M, b)    C^{-1} y_m
    ydot: jax.Array     # (M, s)    local summaries (eq. 3)
    beta: jax.Array     # (M, s)    Kss^{-1} ydot_m
    B: jax.Array        # (M, s, s) Kss^{-1} Sdot_m
    Sdot: jax.Array     # (M, s, s) local summaries (eq. 4)
    centroids: jax.Array  # (M, d)  block centroids (query routing targets)


class PICFState(NamedTuple):
    """pICF-based GP: distributed ICF factor + cached R-space solves
    (eqs. 19-23)."""
    Xb: jax.Array       # (M, b, d)
    yb: jax.Array       # (M, b)
    F: jax.Array        # (M, R, b) per-machine factor columns
    Phi_L: jax.Array    # (R, R)   chol(I + sum_m F_m F_m^T / s2)
    ydd: jax.Array      # (R,)     Phi^{-1} sum_m F_m y_m  (eq. 22)


# ---------------------------------------------------------------------------
# Incremental-state protocol (Sec. 5.2 summary algebra, method-owned).
# ---------------------------------------------------------------------------

@runtime_checkable
class StateStore(Protocol):
    """What a method's incremental state container must support.

    A store owns everything ``fit`` needed (kernel, hyperparameters, support
    set / rank, runner) plus the cached per-machine contributions, so the
    update algebra is closed over it:

    * ``assimilate(X_new, y_new)`` — fold a new data stream in as fresh
      machine blocks, reusing every already-paid local factorization (the
      paper's streaming add);
    * ``retire(machine)`` / ``revive(machine)`` — subtract / re-add one
      machine's contribution (failure, decommission, straggler deadline);
    * ``to_state()`` — assemble the method's cached ``PosteriorState`` from
      whatever machines are alive. Incremental by contract: implementations
      keep the expensive global factor maintained via rank-b Cholesky
      updates (``linalg.chol_update_rank``), so this is O(|S|²) per call,
      not O(|S|³).

    Stores are immutable: every mutation returns a new store, so serving can
    hold the old one until the hot-swap commits. All methods are host-side
    (they orchestrate jitted device work but are not themselves jitted).
    """

    def assimilate(self, X_new, y_new) -> "StateStore": ...

    def retire(self, machine: int) -> "StateStore": ...

    def revive(self, machine: int) -> "StateStore": ...

    def to_state(self) -> Any: ...


def check_machine_index(n_machines: int, machine: int) -> None:
    """Shared retire/revive guard: reject out-of-range machine ids up
    front. jnp clamps OOB gathers but silently DROPS OOB scatter updates,
    so an unchecked bad index would downdate a clamped machine's cached
    factor while leaving the alive mask untouched — silent store corruption
    instead of an error."""
    if not 0 <= machine < n_machines:
        raise IndexError(
            f"machine {machine} out of range for {n_machines} machines")


def concrete_alive_mask(alive) -> np.ndarray | None:
    """Host view of a store's alive mask, or ``None`` while tracing.

    Host-side maintenance ops (retire/revive no-op checks, ``to_state``
    compaction) need Python truthiness on the mask — which is exactly the
    ``TracerBoolConversionError`` bug class that hit ``PICStore.to_state``
    (lint rule JIT001). Every such branch goes through this guard and
    handles the ``None`` case explicitly: either a clear TypeError
    (data-dependent host work, impossible under trace) or the all-alive
    fast path (a traced store is all-alive by construction, because the
    single-machine mutators reject traced masks)."""
    if isinstance(alive, jax.core.Tracer):
        return None
    return np.asarray(alive)


# ---------------------------------------------------------------------------
# ServeSpec — phase 1's input: every per-deployment serving decision, once.
# ---------------------------------------------------------------------------

def default_buckets(max_batch: int, *, min_bucket: int = 8,
                    block_q: int = 1) -> tuple[int, ...]:
    """Powers of two from min_bucket up, capped by max_batch (inclusive),
    each rounded up to a multiple of ``block_q``.

    ``block_q`` is the Pallas serving kernel's query-tile size: emitting
    bucket sizes on tile boundaries means the jitted predict's padded batch
    IS the kernel grid — no second pad inside the kernel dispatch (the
    fused ``xcov_diag`` and the two-bucket routed scatter both consume the
    same alignment). The bare default 1 keeps direct calls' ladders ending
    exactly at max_batch; powers of two >= 8 are already 8-aligned, so the
    historical ladder is unchanged under the server default block_q=8.

    Ladder invariants (regression-tested exhaustively in
    tests/test_api_state.py and tests/test_plan.py):

    * covering — the top bucket is >= max_batch even when ``max_batch <
      min_bucket`` or ``max_batch`` is not tile-aligned (the top entry is
      ``max_batch`` rounded UP to the tile, never truncated down);
    * sorted and duplicate-free — a duplicate bucket would compile the same
      executable twice and skew padding stats, so the ladder is squeezed
      through ``dict.fromkeys`` regardless of how the loop, the rounding,
      and the trailing ``max_batch`` append interact;
    * validated — non-positive ``max_batch``/``min_bucket``/``block_q``
      raise instead of emitting a 0-bucket or looping forever
      (``min_bucket=0`` used to hang the doubling loop).
    """
    if max_batch < 1 or min_bucket < 1 or block_q < 1:
        raise ValueError(
            f"default_buckets needs positive sizes; got max_batch="
            f"{max_batch}, min_bucket={min_bucket}, block_q={block_q}")
    align = lambda v: -(-v // block_q) * block_q
    sizes = []
    b = min_bucket
    while b < max_batch:
        sizes.append(align(b))
        b *= 2
    sizes.append(align(max_batch))
    return tuple(dict.fromkeys(sizes))


@dataclasses.dataclass(frozen=True)
class ServeSpec:
    """Frozen per-deployment serving policy — phase 1's single input.

    Everything ``predict_diag``/``predict_routed_diag`` used to re-decide
    per call (ad-hoc ``tile=`` kwargs, ``KernelSpec`` threading, server-side
    bucket ladders) is declared here once; ``GPMethod.plan`` turns it into a
    ``ServePlan`` whose executables and caches realize the policy.

    * ``kernel``   — a ``cov.KernelSpec`` overriding the fit-time kernel
      callable (how cross-covariances are built: dense jnp vs Pallas vs the
      fused ``xcov_diag``); ``None`` serves with the kernel the plan was
      built with.
    * ``block_q``  — serving query-tile size. Resolution order: this field,
      then the kernel's declared ``block_q``, then the f32 sublane (8).
      Bucket ladders AND the routed scatter's capacity both land on this
      boundary.
    * ``max_batch`` / ``buckets`` / ``min_bucket`` — the bucket ladder.
      Explicit ``buckets`` win; otherwise ``default_buckets(max_batch,
      min_bucket, block_q)``; with NEITHER declared the plan serves every
      batch at its exact size (identity bucketing — the legacy direct-call
      behavior, bitwise; the PIC family's positional path assigns queries
      to blocks by batch position, so padding is a posterior-visible
      decision the spec must own, not a silent default). Oversized batches
      round up to a multiple of the top bucket (never under-covered).
    * ``routed``   — serve through the batch-composition-invariant
      centroid-routed path (PIC family only); ``GPServer`` consumes this.
    * ``alpha``    — routed main-bucket capacity multiplier (headroom vs
      skew, see ``runner.scatter_two_bucket``).
    * ``max_overflow_groups`` — bounds the routed overflow-executable
      ladder: flush-time group counts snap up within {0, 1, 2, 4, ...};
      a demand above this cap runs the full worst-case-G program instead of
      compiling a dedicated one. ``None`` = the full power-of-two ladder.
    * ``cached_cinv`` — precompute per-block ``C⁻¹ = (C_L C_Lᵀ)⁻¹`` at plan
      build so the per-flush batched triangular solve becomes ONE batched
      matmul (pays where batched trsm bills per program — XLA-CPU, small-RHS
      TPU). Off by default: the matmul takes a different float path, and the
      default plan is bitwise-faithful to the legacy trsm serving path.
    * ``dtype``    — query dtype policy: ``"preserve"`` (serve in whatever
      dtype queries arrive, the legacy behavior), ``"state"`` (cast queries
      to the state's dtype so one executable serves mixed-precision
      callers), ``"float32"``.

    Frozen/hashable: a spec is a cache key (``FittedGP`` memoizes one plan
    per spec) and safe to close over in jitted code.
    """
    kernel: Any = None
    block_q: int | None = None
    max_batch: int | None = None
    buckets: tuple[int, ...] | None = None
    min_bucket: int = 8
    routed: bool = False
    alpha: int = ROUTED_ALPHA
    max_overflow_groups: int | None = None
    cached_cinv: bool = False
    dtype: str = "preserve"

    def __post_init__(self):
        # fail at construction, not deep inside routed_capacity at flush
        # time (alpha=0 would divide by zero there; alpha<0 a garbage
        # layout; the pad-packing invariant M*cap >= bucket needs alpha>=1)
        if self.alpha < 1:
            raise ValueError(f"ServeSpec.alpha must be >= 1; got "
                             f"{self.alpha}")
        if self.max_overflow_groups is not None \
                and self.max_overflow_groups < 0:
            raise ValueError(f"ServeSpec.max_overflow_groups must be >= 0; "
                             f"got {self.max_overflow_groups}")
        if self.cached_cinv and not self.routed:
            # the C^-1 cache is consumed by the routed flush executables
            # only; building it for a diag-only plan would pay O(M b^3)
            # per rebind for zero effect
            raise ValueError(
                "ServeSpec(cached_cinv=True) serves the routed flush path; "
                "set routed=True as well")

    def resolve_kfn(self, kfn: Callable) -> Callable:
        served = self.kernel if self.kernel is not None else kfn
        if self.block_q is not None:
            from repro.core import covariance as cov
            if isinstance(served, cov.KernelSpec) and \
                    served.block_q != self.block_q:
                # the spec's tile overrides the kernel's: the fused
                # xcov_diag dispatch reads the KernelSpec's block_q, and a
                # mismatch would re-pick a tile and pad the bucket AGAIN
                # inside the dispatch — the second pad the bucket-ladder
                # alignment exists to avoid
                served = dataclasses.replace(served, block_q=self.block_q)
        return served

    def resolve_block_q(self, kfn: Callable) -> int:
        if self.block_q is not None and self.block_q < 1:
            raise ValueError(f"ServeSpec.block_q must be a positive tile "
                             f"size; got {self.block_q}")
        kfn = self.resolve_kfn(kfn)
        return self.block_q or getattr(kfn, "block_q", None) or 8

    def resolve_buckets(self, kfn: Callable) -> tuple[int, ...] | None:
        """The ladder, or ``None`` for identity bucketing (no padding)."""
        if self.buckets is not None:
            buckets = tuple(sorted(dict.fromkeys(self.buckets)))
            if not buckets or buckets[0] < 1:
                raise ValueError(f"ServeSpec.buckets must be positive; got "
                                 f"{self.buckets}")
            if self.max_batch is not None and buckets[-1] < self.max_batch:
                raise ValueError(
                    f"largest bucket {buckets[-1]} < max_batch "
                    f"{self.max_batch}: the ladder would under-cover the "
                    f"serving queue")
            return buckets
        if self.max_batch is None:
            return None
        return default_buckets(self.max_batch, min_bucket=self.min_bucket,
                               block_q=self.resolve_block_q(kfn))

    def compat_key(self, kfn: Callable) -> tuple:
        """Hashable identity of the COMPILED serving policy this spec
        resolves to over fit-time kernel ``kfn``.

        Two deployments whose compat keys match run byte-identical serving
        programs: same resolved kernel callable, tile, bucket ladder, routed
        dispatch, overflow ladder bound, backend caches, and dtype policy.
        Everything that is a TRACED argument of the executables — params,
        state, caches — is deliberately absent: executables are compiled
        per argument SHAPE, so deployments differing only in posterior
        values can share one executable lineage (the multi-tenant registry
        combines this key with the method name and the state/params tree
        structure to decide lineage sharing; ``serving/registry.py``).

        Distinct specs can map to one key (e.g. ``block_q=None`` vs an
        explicit ``block_q`` equal to the kernel's declared tile): the key
        captures the RESOLVED policy, which is what the compiled programs
        depend on.
        """
        served = self.resolve_kfn(kfn)
        try:
            hash(served)
        except TypeError:       # bespoke closure: identity is the best key
            served = id(served)
        return (served, self.resolve_block_q(kfn), self.resolve_buckets(kfn),
                self.routed, self.alpha, self.max_overflow_groups,
                self.cached_cinv, self.dtype)


# ---------------------------------------------------------------------------
# ServePlan — phase 1's output: executables + caches, owned per state.
# ---------------------------------------------------------------------------

@dataclasses.dataclass
class PlanStats:
    """Shared across ``rebind`` generations — the executable cache and its
    counters describe the plan LINEAGE, which is what the zero-recompile
    guarantee is about (tests probe ``n_traces`` across hot-swaps)."""
    n_traces: int = 0          # jit traces across all executables
    n_diag_batches: int = 0
    n_routed_batches: int = 0
    n_full_batches: int = 0
    n_padded_rows: int = 0
    n_g0_batches: int = 0      # routed flushes served by the G=0 program
    last_g: int | None = None  # overflow-group count of the last routed call
    # bounded degradation (PIC family): rows answered from the global
    # S-space posterior because their routed block was marked dead
    n_degraded_rows: int = 0
    last_degraded: Any = None  # (u,) bool of the last routed call, or None


@dataclasses.dataclass(frozen=True)
class ServePlan:
    """Executable serving program for ONE (method, kernel, spec, state).

    Owns (a) the resolved serving policy (kernel callable, tile, bucket
    ladder), (b) jitted executables, created once per entry point and
    reused across every ``rebind`` (the executable cache dict is shared by
    reference), and (c) ``caches`` — method-specific precomputed backend
    state (``None`` here; pPIC's plan carries per-block ``C⁻¹``) that is
    passed to executables as a TRACED argument, so refreshing it on rebind
    never recompiles.

    Entry points (phase 2):

    * ``diag(U)``        — (mean, var) for any |U|; host-side pad to the
      bucket ladder, one jitted dispatch, trim;
    * ``routed_diag(U)`` — the batch-composition-invariant path (PIC
      family; raises here);
    * ``full(U)``        — the method's native posterior (dense/block
      covariance view), un-padded (the covariance shape is the point);
    * ``rebind(state)``  — same plan, new posterior: every executable is
      reused, so a same-shape hot-swap costs zero recompilation and a
      grown block axis costs exactly one re-trace per entry point.

    Padding/staging is host-side NumPy throughout (device-stage a
    microbatch and every distinct queue length eagerly compiles a fresh
    stack/pad kernel — the tail-latency lesson baked into GPServer).
    """
    method: "GPMethod"
    kfn: Callable
    params: dict
    state: Any
    spec: ServeSpec
    block_q: int
    buckets: tuple[int, ...]
    caches: Any = None
    stats: PlanStats = dataclasses.field(default_factory=PlanStats)
    _exec: dict = dataclasses.field(default_factory=dict)

    # -- ladder -------------------------------------------------------------

    def bucket_for(self, u: int) -> int:
        if self.buckets is None:        # identity bucketing: exact batches
            return u
        for b in self.buckets:
            if b >= u:
                return b
        big = self.buckets[-1]          # oversized: multiple of the top
        return -(-u // big) * big

    def _staged(self, U):
        """Apply the spec's dtype policy. Zero-copy under ``"preserve"``
        (device arrays stay on device; ``plan.diag``/``plan.full`` remain
        jax-traceable when no bucket padding fires), device-/trace-side
        cast for jax values otherwise."""
        if self.spec.dtype == "preserve":
            return U
        if self.spec.dtype == "state":
            target = jax.tree.leaves(self.state)[0].dtype
        elif self.spec.dtype == "float32":
            target = np.float32
        else:
            raise ValueError(
                f"unknown ServeSpec.dtype policy {self.spec.dtype!r}; "
                f"expected 'preserve', 'state', or 'float32'")
        if isinstance(U, (np.ndarray, list, tuple)):
            return np.asarray(U, dtype=target)
        return U.astype(target)          # jax array / tracer: no host trip

    def _padded(self, U) -> tuple[Any, int]:
        U = self._staged(U)
        u = U.shape[0]
        bucket = self.bucket_for(u)
        if bucket == u:
            return U, u
        if isinstance(U, jax.core.Tracer):
            # inside an outer jit the pad must stay on device; u and the
            # bucket are static under trace, and compile-per-batch-length
            # is the OUTER program's choice (host serving traffic never
            # takes this branch)
            pad = jax.numpy.zeros((bucket - u,) + tuple(U.shape[1:]),
                                  U.dtype)
            self.stats.n_padded_rows += bucket - u   # counted per trace
            return jax.numpy.concatenate([U, pad]), u
        # padding is host-side serving staging by design (an eager device
        # pad would compile once per distinct batch length — the serving
        # tail-latency failure mode); bucket ladders are a serving policy
        Un = np.asarray(U)
        buf = np.zeros((bucket,) + Un.shape[1:], Un.dtype)
        buf[:u] = Un
        self.stats.n_padded_rows += bucket - u
        return buf, u

    # -- executables ----------------------------------------------------------

    def _jitted(self, key: str, build: Callable[[], Callable]) -> Callable:
        """One jitted executable per key, created lazily, shared across
        rebinds. ``build`` returns the python callable to jit; a trace
        counter rides inside it so the lifecycle tests can assert the
        zero-recompile hot-swap contract."""
        fn = self._exec.get(key)
        if fn is None:
            inner = build()
            stats = self.stats

            def counted(*args):
                stats.n_traces += 1
                return inner(*args)

            fn = self._exec[key] = jax.jit(counted)
        return fn

    def _diag_exec(self) -> Callable:
        impl, kfn = self.method.predict_diag_fn, self.kfn
        return self._jitted(
            "diag", lambda: lambda params, state, caches, U:
                impl(kfn, params, state, U))

    def _full_exec(self) -> Callable:
        impl, kfn = self.method.predict_fn, self.kfn
        return self._jitted(
            "full", lambda: lambda params, state, caches, U:
                impl(kfn, params, state, U))

    # -- phase 2 entry points -------------------------------------------------

    def diag(self, U) -> tuple[jax.Array, jax.Array]:
        """(mean, var) over a (u, d) batch — THE serving hot path."""
        Up, u = self._padded(U)
        mean, var = self._diag_exec()(self.params, self.state, self.caches,
                                      Up)
        self.stats.n_diag_batches += 1
        return mean[:u], var[:u]

    def routed_diag(self, U, block_alive=None):
        """Generic routed path: the method's raw routed impl, jitted with
        the spec's tile. Methods with a specialized plan (pPIC/PIC's
        ``PICServePlan``) override this with backend caches, the
        overflow-executable ladder, and bounded degradation
        (``block_alive``); methods with no routed impl raise —
        their posterior is composition-invariant already, use ``diag``."""
        impl, kfn, tile = (self.method.predict_routed_diag_fn, self.kfn,
                           self.block_q)
        if block_alive is not None:
            raise ValueError(
                f"method {self.method.name!r}'s generic routed plan has no "
                f"bounded-degradation path (block_alive); only the PIC "
                f"family's PICServePlan serves dead-block traffic from the "
                f"global posterior")
        self.stats.last_degraded = None
        if impl is None:
            raise ValueError(
                f"method {self.method.name!r} has no routed serving "
                f"program; its posterior does not depend on query-block "
                f"assignment — use plan.diag")
        Up, u = self._padded(U)
        fn = self._jitted(
            "routed", lambda: lambda params, state, caches, U:
                impl(kfn, params, state, U, tile=tile))
        mean, var = fn(self.params, self.state, self.caches, Up)
        self.stats.n_routed_batches += 1
        self.stats.last_g = None
        return mean[:u], var[:u]

    def full(self, U):
        """The method's native posterior (mean + covariance view). Queries
        are NOT bucket-padded — the covariance block shape is the output."""
        post = self._full_exec()(self.params, self.state, self.caches,
                                 self._staged(U))
        self.stats.n_full_batches += 1
        return post

    # -- lifecycle ------------------------------------------------------------

    def rebind(self, state) -> "ServePlan":
        """Hot-swap the posterior: a new plan over ``state`` sharing this
        plan's executables and stats. Same treedef + leaf shapes -> every
        compiled program is reused (zero recompilation, probe-tested);
        changed shapes cost one re-trace per entry point on next use."""
        return dataclasses.replace(self, state=state,
                                   caches=self._rebuild_caches(state))

    def _rebuild_caches(self, state):
        """Recompute backend caches for a new state (no-op here)."""
        return None

    def warmup(self, d: int, *, dtype=np.float32) -> "ServePlan":
        """Compile every executable the serving loop can hit, up front
        (steady-state serving: one-time XLA compiles must not masquerade as
        tail latency): the diag program per bucket — or, for a routed spec,
        the routed program per bucket (specialized plans extend this to
        their whole overflow-executable ladder). ``d`` is the query feature
        dimension; a no-op under identity bucketing (no finite ladder)."""
        routed = (self.spec.routed
                  and self.method.predict_routed_diag_fn is not None)
        for b in self.buckets or ():
            U0 = np.zeros((b, d), dtype)
            jax.block_until_ready(
                (self.routed_diag(U0) if routed else self.diag(U0))[0])
        return self


# ---------------------------------------------------------------------------
# Method registry.
# ---------------------------------------------------------------------------

_DEFAULT_SPEC = ServeSpec()


@dataclasses.dataclass(frozen=True)
class GPMethod:
    """One GP regression method behind the uniform state API.

    ``fit(kfn, params, X, y, **kw) -> state`` where ``kw`` is the subset of
    (S=, M=, rank=, runner=) the method needs. The ``*_fn`` fields are the
    RAW prediction implementations (what plans jit):

    * ``predict_fn(kfn, params, state, U)``      -> native posterior;
    * ``predict_diag_fn(kfn, params, state, U)`` -> (mean, var) vectors;
    * ``predict_routed_diag_fn(..., tile=)``     -> the batch-composition-
      invariant path (PIC family; ``None`` for methods whose posterior is
      already independent of query-block assignment — fgp/pitc/ppitc/picf
      get the invariance for free and ``GPServer(routed=True)`` rejects
      them at construction);
    * ``plan_fn(method, kfn, params, state, spec)`` — method-owned
      ``ServePlan`` factory (``None`` -> the generic plan). pPIC/PIC
      install a plan carrying per-block ``C⁻¹`` caches and the
      per-overflow-group-count executable ladder.
    * ``init_store`` (optional) — the incremental-state entry point:
      ``init_store(kfn, params, X, y, **kw) -> StateStore`` with the same
      keyword subset as ``fit``. Methods without an incremental algebra
      (``fgp``) leave it ``None``; for the summary/factor methods ``fit``
      IS ``init_store(...).to_state()``.

    The legacy per-call ``method.predict*(kfn, params, state, U, **kw)``
    shim surface is GONE (it lived one deprecation cycle behind
    ``PlanDeprecationWarning``): every prediction goes through
    ``method.plan(...)`` / ``FittedGP`` / a serving runtime.
    """
    name: str
    fit: Callable[..., Any]
    predict_fn: Callable[..., Any]
    predict_diag_fn: Callable[..., Any]
    predict_routed_diag_fn: Callable[..., Any] | None = None
    init_store: Callable[..., "StateStore"] | None = None
    plan_fn: Callable[..., ServePlan] | None = None

    # -- phase 1 --------------------------------------------------------------

    def plan(self, kfn, params, state, spec: ServeSpec | None = None
             ) -> ServePlan:
        """Build the serving program for ``state`` under ``spec``."""
        spec = spec if spec is not None else _DEFAULT_SPEC
        if spec.cached_cinv and self.plan_fn is None:
            raise ValueError(
                f"ServeSpec(cached_cinv=True) but method {self.name!r} has "
                f"no backend-cache plan (only the PIC family serves from "
                f"per-block C factors)")
        if self.plan_fn is not None:
            return self.plan_fn(self, kfn, params, state, spec)
        served = spec.resolve_kfn(kfn)
        return ServePlan(self, served, params, state, spec,
                         spec.resolve_block_q(kfn),
                         spec.resolve_buckets(kfn))


REGISTRY: dict[str, GPMethod] = {}


def register(method: GPMethod) -> GPMethod:
    REGISTRY[method.name] = method
    return method


def get(name: str) -> GPMethod:
    if name not in REGISTRY:
        # methods self-register at module import; pull the core modules in
        from repro.core import gp, picf, pitc, ppic, ppitc  # noqa: F401
    try:
        return REGISTRY[name]
    except KeyError:
        raise ValueError(f"unknown GP method {name!r}; have {names()}")


def names() -> list[str]:
    return sorted(REGISTRY)


# ---------------------------------------------------------------------------
# FittedGP — what serving / examples hold on to.
# ---------------------------------------------------------------------------

@dataclasses.dataclass(frozen=True)
class FittedGP:
    """A fitted model: method + kernel + hyperparameters + cached state.

    A thin client of the two-phase API: every predict goes through a
    memoized ``ServePlan`` (one per ``ServeSpec``), so repeated calls reuse
    jitted executables and ``with_state`` (hot-swap after a ``StateStore``
    assimilate/retire) REBINDS the existing plans instead of rebuilding —
    zero recompilation when the state keeps its shapes.
    """
    method: GPMethod
    kfn: Callable
    params: dict
    state: Any

    def plan(self, spec: ServeSpec | None = None) -> ServePlan:
        """The serving program for this model under ``spec`` (memoized)."""
        spec = spec if spec is not None else _DEFAULT_SPEC
        plans = self.__dict__.setdefault("_plans", {})
        if spec not in plans:
            plans[spec] = self.method.plan(self.kfn, self.params, self.state,
                                           spec)
        return plans[spec]

    def predict(self, U: jax.Array):
        return self.plan().full(U)

    def predict_diag(self, U: jax.Array):
        return self.plan().diag(U)

    def predict_routed_diag(self, U: jax.Array):
        """Centroid-routed (mean, var) — batch-composition-invariant."""
        if self.method.predict_routed_diag_fn is None:
            raise ValueError(
                f"method {self.method.name!r} has no routed prediction path; "
                f"its posterior does not depend on query-block assignment — "
                f"use predict_diag")
        return self.plan().routed_diag(U)

    def with_state(self, state) -> "FittedGP":
        """Hot-swap the cached posterior (online assimilate/retire); any
        already-built plans are rebound, keeping their executables."""
        new = dataclasses.replace(self, state=state)
        plans = self.__dict__.get("_plans")
        if plans:
            new.__dict__["_plans"] = {sp: pl.rebind(state)
                                      for sp, pl in plans.items()}
        return new


def _method_kwargs(S=None, M=None, rank=None, runner=None) -> dict:
    kw = {}
    if S is not None:
        kw["S"] = S
    if M is not None:
        kw["M"] = M
    if rank is not None:
        kw["rank"] = rank
    if runner is not None:
        kw["runner"] = runner
    return kw


def fit(name: str, kfn, params, X, y, *, S=None, M=None, rank=None,
        runner=None) -> FittedGP:
    """Registry front door: fit method ``name`` and return a FittedGP."""
    method = get(name)
    state = method.fit(kfn, params, X, y,
                       **_method_kwargs(S, M, rank, runner))
    return FittedGP(method, kfn, params, state)


def init_store(name: str, kfn, params, X, y, *, S=None, M=None, rank=None,
               runner=None) -> StateStore:
    """Registry front door for the incremental-state protocol: build method
    ``name``'s ``StateStore`` from an initial data batch. The cold-fit state
    is ``store.to_state()``; later ``assimilate``/``retire`` calls mutate
    incrementally (see ``launch.gp_serve.GPServer.update``)."""
    method = get(name)
    if method.init_store is None:
        raise ValueError(
            f"method {name!r} has no incremental StateStore (its cached "
            f"state has no cheap update algebra); have "
            f"{[m for m in names() if REGISTRY[m].init_store is not None]}")
    return method.init_store(kfn, params, X, y,
                             **_method_kwargs(S, M, rank, runner))
