"""Unified GP method API: ``fit -> PosteriorState -> predict_batch``.

The paper's real-time claim rests on amortization: everything that is
O((|D|/M)^3) or O(|S|^3) happens ONCE at fit time and is cached in a
per-method ``PosteriorState`` (a pure-array NamedTuple, hence a pytree that
jits, shards, checkpoints, and hot-swaps); a repeated query then costs only
the cross-covariances against the cached factors — O(|U||S| + |S|^2) for the
summary methods instead of re-running the local Cholesky pipeline.

Three layers:

* per-method states   — ``FGPState`` / ``PITCState`` / ``PICState`` /
  ``PICFState``, defined here so core modules, runners, serving, and
  checkpointing all agree on the cached representation;
* ``GPMethod``        — (name, fit, predict, predict_diag) registered by each
  core module at import; ``get``/``names`` look methods up by string, which
  is what examples/benchmarks/serving use instead of hand-wired plumbing;
* ``FittedGP``        — convenience pairing of (method, kfn, params, state)
  with ``predict``/``predict_diag``/``with_state`` (hot-swap after a
  ``StateStore`` assimilate/retire).

Fit is runner-agnostic: the summary/factor construction goes through
``parallel.runner.Runner.map``, so ``VmapRunner`` and ``ShardMapRunner``
produce the same state pytree (tested in tests/test_shardmap.py).

On top of the cached states sits the incremental-state layer (Sec. 5.2):
``StateStore`` is the method-owned protocol that unifies cold fits,
streaming assimilation, machine retirement, and checkpointing — a cold fit
is just ``init_store(...).to_state()``, and every later mutation reuses the
already-paid O(b³)/O(|S|³) work (``core/online.py`` for pPITC/pPIC,
``core/picf.py`` for the ICF factor). ``core/serialize.py`` persists every
registered state with a versioned schema so serving fleets can checkpoint,
restore, and replicate posteriors.
"""
from __future__ import annotations

import dataclasses
from typing import Any, Callable, NamedTuple, Protocol, runtime_checkable

import jax


# ---------------------------------------------------------------------------
# Per-method posterior states (pure-array pytrees).
# ---------------------------------------------------------------------------

class FGPState(NamedTuple):
    """Exact GP: cached |D|x|D| Cholesky + weights (eqs. 1-2)."""
    X: jax.Array        # (n, d) training inputs
    L: jax.Array        # (n, n) chol(K_DD + noise)
    alpha: jax.Array    # (n,)   (K_DD + noise)^{-1} y


class PITCState(NamedTuple):
    """PITC/pPITC: everything global lives in S-space (eqs. 5-8)."""
    S: jax.Array        # (s, d) support set
    Kss_L: jax.Array    # (s, s) chol K_SS
    Sdd_L: jax.Array    # (s, s) chol Sigma-dot_DD  (eq. 6)
    alpha: jax.Array    # (s,)   Sdd^{-1} ydd       (eq. 7 weights)


class PICState(NamedTuple):
    """PIC/pPIC: PITC globals + per-block caches for the local correction
    (eqs. 12-14). Leading axis of the block fields is the machine axis M.

    ``centroids`` realizes Remark 2 on the serving side: the per-block data
    centroids fixed at fit time let ``ppic.predict_routed`` assign each query
    to the block whose local data best explains it, independent of how the
    query batch happens to be composed."""
    S: jax.Array        # (s, d)
    Kss_L: jax.Array    # (s, s)
    Sdd_L: jax.Array    # (s, s)
    alpha: jax.Array    # (s,)    Sdd^{-1} ydd
    Xb: jax.Array       # (M, b, d) data blocks
    yb: jax.Array       # (M, b)
    Ksd: jax.Array      # (M, s, b) cached K_S,Dm
    C_L: jax.Array      # (M, b, b) chol Sigma_{DmDm|S}
    Wy: jax.Array       # (M, b)    C^{-1} y_m
    ydot: jax.Array     # (M, s)    local summaries (eq. 3)
    beta: jax.Array     # (M, s)    Kss^{-1} ydot_m
    B: jax.Array        # (M, s, s) Kss^{-1} Sdot_m
    Sdot: jax.Array     # (M, s, s) local summaries (eq. 4)
    centroids: jax.Array  # (M, d)  block centroids (query routing targets)


class PICFState(NamedTuple):
    """pICF-based GP: distributed ICF factor + cached R-space solves
    (eqs. 19-23)."""
    Xb: jax.Array       # (M, b, d)
    yb: jax.Array       # (M, b)
    F: jax.Array        # (M, R, b) per-machine factor columns
    Phi_L: jax.Array    # (R, R)   chol(I + sum_m F_m F_m^T / s2)
    ydd: jax.Array      # (R,)     Phi^{-1} sum_m F_m y_m  (eq. 22)


# ---------------------------------------------------------------------------
# Incremental-state protocol (Sec. 5.2 summary algebra, method-owned).
# ---------------------------------------------------------------------------

@runtime_checkable
class StateStore(Protocol):
    """What a method's incremental state container must support.

    A store owns everything ``fit`` needed (kernel, hyperparameters, support
    set / rank, runner) plus the cached per-machine contributions, so the
    update algebra is closed over it:

    * ``assimilate(X_new, y_new)`` — fold a new data stream in as fresh
      machine blocks, reusing every already-paid local factorization (the
      paper's streaming add);
    * ``retire(machine)`` / ``revive(machine)`` — subtract / re-add one
      machine's contribution (failure, decommission, straggler deadline);
    * ``to_state()`` — assemble the method's cached ``PosteriorState`` from
      whatever machines are alive. Incremental by contract: implementations
      keep the expensive global factor maintained via rank-b Cholesky
      updates (``linalg.chol_update_rank``), so this is O(|S|²) per call,
      not O(|S|³).

    Stores are immutable: every mutation returns a new store, so serving can
    hold the old one until the hot-swap commits. All methods are host-side
    (they orchestrate jitted device work but are not themselves jitted).
    """

    def assimilate(self, X_new, y_new) -> "StateStore": ...

    def retire(self, machine: int) -> "StateStore": ...

    def revive(self, machine: int) -> "StateStore": ...

    def to_state(self) -> Any: ...


def check_machine_index(n_machines: int, machine: int) -> None:
    """Shared retire/revive guard: reject out-of-range machine ids up
    front. jnp clamps OOB gathers but silently DROPS OOB scatter updates,
    so an unchecked bad index would downdate a clamped machine's cached
    factor while leaving the alive mask untouched — silent store corruption
    instead of an error."""
    if not 0 <= machine < n_machines:
        raise IndexError(
            f"machine {machine} out of range for {n_machines} machines")


# ---------------------------------------------------------------------------
# Method registry.
# ---------------------------------------------------------------------------

@dataclasses.dataclass(frozen=True)
class GPMethod:
    """One GP regression method behind the uniform state API.

    ``fit(kfn, params, X, y, **kw) -> state`` where ``kw`` is the subset of
    (S=, M=, rank=, runner=) the method needs; ``predict`` returns the
    method's native posterior (GPPosterior or ParallelPosterior);
    ``predict_diag`` always returns a (mean, var) pair of (u,) arrays and
    accepts query batches of any size (block methods pad internally).

    ``predict_routed_diag`` (optional) is the batch-composition-invariant
    serving path: each query is assigned to its nearest-centroid block
    (Remark 2) instead of positionally, so a query's (mean, var) depends only
    on the query point and the fitted state — never on what else happened to
    arrive in the same microbatch. Implementations accept an optional
    ``tile=`` keyword (serving-kernel query-tile size) that the routed
    scatter aligns its bucket widths to; ``GPServer(routed=True)`` threads
    its ``block_q`` through it. Methods whose posterior is already
    query-independent of the block layout (fgp/pitc/ppitc/picf) leave it
    ``None``: ``FittedGP.predict_routed_diag`` raises for them and
    ``GPServer(routed=True)`` rejects them at construction — their
    ``predict_diag`` already has the invariance routing buys.

    ``init_store`` (optional) is the incremental-state entry point:
    ``init_store(kfn, params, X, y, **kw) -> StateStore`` with the same
    keyword subset as ``fit``. Methods without an incremental algebra
    (``fgp`` — the exact Cholesky has no cheap update) leave it ``None``;
    for the summary/factor methods ``fit`` IS ``init_store(...).to_state()``
    so cold fits and streamed states share one code path.
    """
    name: str
    fit: Callable[..., Any]
    predict: Callable[..., Any]        # (kfn, params, state, U) -> posterior
    predict_diag: Callable[..., Any]   # (kfn, params, state, U) -> (mean, var)
    predict_routed_diag: Callable[..., Any] | None = None
    init_store: Callable[..., "StateStore"] | None = None


REGISTRY: dict[str, GPMethod] = {}


def register(method: GPMethod) -> GPMethod:
    REGISTRY[method.name] = method
    return method


def get(name: str) -> GPMethod:
    if name not in REGISTRY:
        # methods self-register at module import; pull the core modules in
        from repro.core import gp, picf, pitc, ppic, ppitc  # noqa: F401
    try:
        return REGISTRY[name]
    except KeyError:
        raise ValueError(f"unknown GP method {name!r}; have {names()}")


def names() -> list[str]:
    return sorted(REGISTRY)


# ---------------------------------------------------------------------------
# FittedGP — what serving / examples hold on to.
# ---------------------------------------------------------------------------

@dataclasses.dataclass(frozen=True)
class FittedGP:
    """A fitted model: method + kernel + hyperparameters + cached state.

    ``state`` is the only field that changes across online updates, so
    serving jits ``predict_diag(params, state, U)`` once and hot-swaps the
    state pytree without recompiling (launch/gp_serve.py).
    """
    method: GPMethod
    kfn: Callable
    params: dict
    state: Any

    def predict(self, U: jax.Array):
        return self.method.predict(self.kfn, self.params, self.state, U)

    def predict_diag(self, U: jax.Array):
        return self.method.predict_diag(self.kfn, self.params, self.state, U)

    def predict_routed_diag(self, U: jax.Array):
        """Centroid-routed (mean, var) — batch-composition-invariant."""
        if self.method.predict_routed_diag is None:
            raise ValueError(
                f"method {self.method.name!r} has no routed prediction path; "
                f"its posterior does not depend on query-block assignment — "
                f"use predict_diag")
        return self.method.predict_routed_diag(self.kfn, self.params,
                                               self.state, U)

    def with_state(self, state) -> "FittedGP":
        """Hot-swap the cached posterior (online assimilate/retire)."""
        return dataclasses.replace(self, state=state)


def _method_kwargs(S=None, M=None, rank=None, runner=None) -> dict:
    kw = {}
    if S is not None:
        kw["S"] = S
    if M is not None:
        kw["M"] = M
    if rank is not None:
        kw["rank"] = rank
    if runner is not None:
        kw["runner"] = runner
    return kw


def fit(name: str, kfn, params, X, y, *, S=None, M=None, rank=None,
        runner=None) -> FittedGP:
    """Registry front door: fit method ``name`` and return a FittedGP."""
    method = get(name)
    state = method.fit(kfn, params, X, y,
                       **_method_kwargs(S, M, rank, runner))
    return FittedGP(method, kfn, params, state)


def init_store(name: str, kfn, params, X, y, *, S=None, M=None, rank=None,
               runner=None) -> StateStore:
    """Registry front door for the incremental-state protocol: build method
    ``name``'s ``StateStore`` from an initial data batch. The cold-fit state
    is ``store.to_state()``; later ``assimilate``/``retire`` calls mutate
    incrementally (see ``launch.gp_serve.GPServer.update``)."""
    method = get(name)
    if method.init_store is None:
        raise ValueError(
            f"method {name!r} has no incremental StateStore (its cached "
            f"state has no cheap update algebra); have "
            f"{[m for m in names() if REGISTRY[m].init_store is not None]}")
    return method.init_store(kfn, params, X, y,
                             **_method_kwargs(S, M, rank, runner))
