"""pPIC — parallel PIC approximation of FGP (paper Sec. 3, Def. 5, Thm. 2).

Extends pPITC with the worker-local correction: machine m blends the global
summary with exact covariance against its own block (eqs. 12-14), recovering
centralized PIC (Snelson 2007) exactly.

NB eq. (13) as printed drops a `Phi Sdd^{-1} Phi^T` term; the form implemented
here is re-derived from Theorem 2 (see core/pitc.py) and verified against the
literal PIC oracle in tests/test_equivalence.py.
"""
from __future__ import annotations

import jax
import jax.numpy as jnp

from repro.core import covariance as cov
from repro.core import linalg
from repro.core.ppitc import (GlobalSummary, LocalSummary, ParallelPosterior,
                              global_summary, local_summary)
from repro.parallel.runner import Runner


def machine_step(kfn, params, S, Xm, ym, Um, *, axis_name):
    """Full pPIC per-machine program: steps 2-4 with local correction."""
    Kss_L = linalg.chol(kfn(params, S, S))
    local, (Ksd, C_L) = local_summary(kfn, params, S, Kss_L, Xm, ym)
    glob = global_summary(kfn, params, S, local, axis_name)
    return predict_from_summary(kfn, params, S, Kss_L, local, glob,
                                Xm, ym, Um, Ksd=Ksd, C_L=C_L)


def predict_from_summary(kfn, params, S, Kss_L, local: LocalSummary,
                         glob: GlobalSummary, Xm, ym, Um, *, Ksd=None,
                         C_L=None):
    """Eqs. (12)-(14). ``Ksd``/``C_L`` are reusable from local_summary."""
    if Ksd is None:
        Ksd = kfn(params, S, Xm)
        V = linalg.tri_solve(Kss_L, Ksd)
        Kdd = cov.add_noise(kfn(params, Xm, Xm), params)
        C_L = linalg.chol(Kdd - V.T @ V)

    Sdd_L = linalg.chol(glob.Sdd)
    Kus = kfn(params, Um, S)
    Kud = kfn(params, Um, Xm)                          # Sigma_{U_m D_m}

    Wy = linalg.chol_solve(C_L, ym[:, None])[:, 0]     # C^{-1} y_m
    ydot_u = Kud @ Wy                                  # y-dot_{U_m}^m
    Wd = linalg.chol_solve(C_L, Kud.T)                 # C^{-1} K_{D_m U_m}
    Sdot_su = Ksd @ Wd                                 # Sigma-dot_{S U_m}^m
    Sdot_uu = Kud @ Wd                                 # Sigma-dot_{U_m U_m}^m

    # eq. (14): Phi_{U_m S} = K_US + K_US Kss^{-1} Sdot_SS - Sdot_US
    Phi = Kus + Kus @ linalg.chol_solve(Kss_L, local.Sdot) - Sdot_su.T

    # eq. (12)
    mean = (Phi @ linalg.chol_solve(Sdd_L, glob.ydd[:, None])[:, 0]
            - Kus @ linalg.chol_solve(Kss_L, local.ydot[:, None])[:, 0]
            + ydot_u)

    # eq. (13), re-derived (Thm 2):
    Kuu = kfn(params, Um, Um)
    covm = Kuu - (Phi @ linalg.chol_solve(Kss_L, Kus.T)
                  - Phi @ linalg.chol_solve(Sdd_L, Phi.T)
                  - Kus @ linalg.chol_solve(Kss_L, Sdot_su)) - Sdot_uu
    return mean, covm


def predict(kfn, params, S, X, y, U, runner: Runner) -> ParallelPosterior:
    """End-to-end pPIC over a Runner.

    For best accuracy X/U should be co-clustered first
    (core/clustering.py — Remark 2 after Def. 5).
    """
    Xb, yb, Ub = (runner.shard_blocks(a) for a in (X, y, U))
    fn = lambda Xm, ym, Um, params, S: machine_step(
        kfn, params, S, Xm, ym, Um, axis_name=runner.axis_name)
    means, covs = runner.map(fn, (Xb, yb, Ub), (params, S))
    return ParallelPosterior(runner.unshard(means), covs)
