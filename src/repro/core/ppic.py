"""pPIC — parallel PIC approximation of FGP (paper Sec. 3, Def. 5, Thm. 2).

Extends pPITC with the worker-local correction: machine m blends the global
summary with exact covariance against its own block (eqs. 12-14), recovering
centralized PIC (Snelson 2007) exactly.

Fit/predict split (core/api.py): ``fit`` caches, per block, the factors the
local correction needs (Ksd, chol Sigma_{DmDm|S}, C^{-1}y, Kss^{-1}-projected
summaries) plus the global S-space factors, in an ``api.PICState``. A
repeated query batch then skips every O(b^3) local Cholesky — only
cross-covariances and cached triangular solves remain. Two query-to-block
assignment policies:

* positional (``predict_batch``/``predict_batch_diag``) — query blocks are
  slices of the batch in arrival order, zero-padded when |U| doesn't divide
  M. Fast, but the posterior of a query depends on where in the batch it sat;
  co-cluster queries first (core/clustering.py, Remark 2) when accuracy
  matters.
* routed (``predict_routed``/``predict_routed_diag``) — each query goes to
  the block whose fit-time centroid it is nearest (Remark 2 realized at
  serving time; centroids are cached in the state). A query's posterior then
  depends only on the query point and the fitted state — invariant to batch
  order and composition (tests/test_routing_equivalence.py) — which is what
  arbitrary-traffic serving needs (launch/gp_serve.py). The diag variant
  serves through the two-bucket capacity layout
  (``runner.scatter_two_bucket``): ~(alpha+1)·|U| computed rows instead of
  the skew-proof-but-padded M·|U| of ``scatter_by_block``, same posteriors.

NB eq. (13) as printed drops a `Phi Sdd^{-1} Phi^T` term; the form implemented
here is re-derived from Theorem 2 (see core/pitc.py) and verified against the
literal PIC oracle in tests/test_equivalence.py.
"""
from __future__ import annotations

import dataclasses

import jax
import jax.numpy as jnp
import numpy as np

from repro.analysis.contracts import no_retrace
from repro.core import api, clustering
from repro.core import covariance as cov
from repro.core import linalg
from repro.core.gp import GPPosterior
from repro.core.ppitc import (GlobalSummary, LocalSummary, ParallelPosterior,
                              global_summary, local_summary)
from repro.parallel.runner import (ROUTED_ALPHA, Runner, gather_by_block,
                                   gather_two_bucket, pad_blocks,
                                   routed_capacity, scatter_by_block,
                                   scatter_two_bucket)


def machine_step(kfn, params, S, Xm, ym, Um, *, axis_name):
    """Full pPIC per-machine program: steps 2-4 with local correction."""
    Kss_L = linalg.chol(kfn(params, S, S))
    local, (Ksd, C_L, _) = local_summary(kfn, params, S, Kss_L, Xm, ym)
    glob = global_summary(kfn, params, S, local, axis_name)
    return predict_from_summary(kfn, params, S, Kss_L, local, glob,
                                Xm, ym, Um, Ksd=Ksd, C_L=C_L)


def predict_from_summary(kfn, params, S, Kss_L, local: LocalSummary,
                         glob: GlobalSummary, Xm, ym, Um, *, Ksd=None,
                         C_L=None):
    """Eqs. (12)-(14). ``Ksd``/``C_L`` are reusable from local_summary."""
    if Ksd is None:
        Ksd = kfn(params, S, Xm)
        V = linalg.tri_solve(Kss_L, Ksd)
        Kdd = cov.add_noise(kfn(params, Xm, Xm), params)
        C_L = linalg.chol(Kdd - V.T @ V)

    Sdd_L = linalg.chol(glob.Sdd)
    Kus = kfn(params, Um, S)
    Kud = kfn(params, Um, Xm)                          # Sigma_{U_m D_m}

    Wy = linalg.chol_solve(C_L, ym[:, None])[:, 0]     # C^{-1} y_m
    ydot_u = Kud @ Wy                                  # y-dot_{U_m}^m
    Wd = linalg.chol_solve(C_L, Kud.T)                 # C^{-1} K_{D_m U_m}
    Sdot_su = Ksd @ Wd                                 # Sigma-dot_{S U_m}^m
    Sdot_uu = Kud @ Wd                                 # Sigma-dot_{U_m U_m}^m

    # eq. (14): Phi_{U_m S} = K_US + K_US Kss^{-1} Sdot_SS - Sdot_US
    Phi = Kus + Kus @ linalg.chol_solve(Kss_L, local.Sdot) - Sdot_su.T

    # eq. (12)
    mean = (Phi @ linalg.chol_solve(Sdd_L, glob.ydd[:, None])[:, 0]
            - Kus @ linalg.chol_solve(Kss_L, local.ydot[:, None])[:, 0]
            + ydot_u)

    # eq. (13), re-derived (Thm 2):
    Kuu = kfn(params, Um, Um)
    covm = Kuu - (Phi @ linalg.chol_solve(Kss_L, Kus.T)
                  - Phi @ linalg.chol_solve(Sdd_L, Phi.T)
                  - Kus @ linalg.chol_solve(Kss_L, Sdot_su)) - Sdot_uu
    return mean, covm


# ---------------------------------------------------------------------------
# fit -> PosteriorState -> predict_batch (core/api.py architecture)
# ---------------------------------------------------------------------------

def fit(kfn, params, X, y, *, S, runner: Runner) -> api.PICState:
    """Steps 1-3 over a Runner + per-block caches for eqs. (12)-(14).

    ``online.PICStore`` is the fit-side producer (one code path for cold
    fits and streamed states, mirroring ppitc.fit): a cold fit is just the
    store's initial ``to_state``.
    """
    from repro.core import online
    return online.init_pic_store(kfn, params, X, y, S=S,
                                 runner=runner).to_state()


def init_store(kfn, params, X, y, *, S, runner: Runner):
    """``api.StateStore`` entry point (online.PICStore): streamed/retired
    blocks keep emitting routed-servable PICStates with fresh centroids."""
    from repro.core import online
    return online.init_pic_store(kfn, params, X, y, S=S, runner=runner)


def _block_posterior(kfn, params, state: api.PICState, Um, m_fields):
    """Eqs. (12)-(14) for one query block from cached factors."""
    Xm, ym, Ksd, C_L, Wy, ydot, beta, B = m_fields
    Kus = kfn(params, Um, state.S)
    Kud = kfn(params, Um, Xm)
    rowdot = lambda A, v: jnp.sum(A * v[None, :], axis=1)
    ydot_u = rowdot(Kud, Wy)
    WdT = linalg.chol_solve_right(C_L, Kud)            # K_{U_m D_m} C^{-1}
    Sdot_us = WdT @ Ksd.T                              # see the diag variant
    Sdot_uu = WdT @ Kud.T
    Phi = Kus + Kus @ B - Sdot_us                      # eq. (14)
    mean = rowdot(Phi, state.alpha) - rowdot(Kus, beta) + ydot_u  # eq. (12)
    Kuu = kfn(params, Um, Um)
    covm = Kuu - (Phi @ linalg.chol_solve(state.Kss_L, Kus.T)
                  - Phi @ linalg.chol_solve(state.Sdd_L, Phi.T)
                  - Kus @ linalg.chol_solve(state.Kss_L, Sdot_us.T)) - Sdot_uu
    return mean, covm


def _block_posterior_diag(kfn, params, state: api.PICState, Um, m_fields):
    """Diagonal of eqs. (12)-(13) for one query block, no |U_m|^2 buffers.

    Every contraction keeps the query axis on matrix ROWS (row-wise
    multiply-reduce instead of gemv, ``chol_solve_right`` instead of a
    left-sided solve on Kᵀ, row-major gemms): XLA picks gemv/trsm/gemm
    panel strategies from the row count and total width, so a query-COLUMN
    formulation is not bitwise stable across slot positions or buffer
    widths — which would break both the routed permutation-invariance
    property and the two-bucket layout's equivalence to the capacity-|U|
    layout (tests/test_routing_equivalence.py). Row-major forms are stable.
    """
    Xm, ym, Ksd, C_L, Wy, ydot, beta, B = m_fields
    Kus = kfn(params, Um, state.S)
    Kud = kfn(params, Um, Xm)
    rowdot = lambda A, v: jnp.sum(A * v[None, :], axis=1)
    ydot_u = rowdot(Kud, Wy)
    WdT = linalg.chol_solve_right(C_L, Kud)            # K_{U_m D_m} C^{-1}
    Sdot_us = WdT @ Ksd.T                              # (u, s)
    Phi = Kus + Kus @ B - Sdot_us
    mean = rowdot(Phi, state.alpha) - rowdot(Kus, beta) + ydot_u
    var = (cov.kdiag(kfn, params, Um)
           - jnp.sum(Phi * linalg.chol_solve_right(state.Kss_L, Kus), 1)
           + jnp.sum(Phi * linalg.chol_solve_right(state.Sdd_L, Phi), 1)
           + jnp.sum(Kus * linalg.chol_solve_right(state.Kss_L, Sdot_us), 1)
           - jnp.sum(Kud * WdT, 1))
    return mean, var


def _block_fields(state: api.PICState):
    return (state.Xb, state.yb, state.Ksd, state.C_L, state.Wy, state.ydot,
            state.beta, state.B)


def predict_blocks(kfn, params, state: api.PICState,
                   U) -> ParallelPosterior:
    """Block-layout posterior from cached state (|U| must divide M;
    queries are assigned to blocks in order)."""
    M = state.Xb.shape[0]
    u = U.shape[0]
    if u % M != 0:
        raise ValueError(
            f"|U|={u} must divide M={M} for the block layout; use "
            f"predict_batch/predict_batch_diag for arbitrary batch sizes")
    one = lambda Um, *mf: _block_posterior(kfn, params, state, Um, mf)
    means, covs = jax.vmap(one)(U.reshape((M, u // M) + U.shape[1:]),
                                *_block_fields(state))
    return ParallelPosterior(means.reshape(-1), covs)


def predict_batch(kfn, params, state: api.PICState, U) -> GPPosterior:
    """Blockwise posterior from cached state for any |U|: pads the query
    batch to the block layout, assembles the dense block-diagonal
    covariance, and trims. (Type-stable; use ``predict_blocks`` when the
    per-machine block layout itself is wanted.)"""
    M = state.Xb.shape[0]
    u = U.shape[0]
    Ub, _ = pad_blocks(U, M)
    one = lambda Um, *mf: _block_posterior(kfn, params, state, Um, mf)
    means, covs = jax.vmap(one)(Ub, *_block_fields(state))
    post = ParallelPosterior(means.reshape(-1), covs)
    return GPPosterior(post.mean[:u], post.cov[:u, :u])


def predict_batch_diag(kfn, params, state: api.PICState, U):
    """(mean, var) for any |U|: pads to the block layout, trims after."""
    M = state.Xb.shape[0]
    u = U.shape[0]
    Ub, _ = pad_blocks(U, M)
    one = lambda Um, *mf: _block_posterior_diag(kfn, params, state, Um, mf)
    means, vars_ = jax.vmap(one)(Ub, *_block_fields(state))
    return means.reshape(-1)[:u], vars_.reshape(-1)[:u]


# ---------------------------------------------------------------------------
# Routed prediction (Remark 2 at serving time): nearest-centroid assignment.
# ---------------------------------------------------------------------------

def route_queries(state: api.PICState, U) -> jax.Array:
    """(u,) block id per query: nearest fit-time block centroid.

    A pure function of (query point, state), so the induced posterior cannot
    depend on batch order or composition — the serving-side equivalence the
    positional path lacks.
    """
    d2 = jnp.sum((U[:, None, :] - state.centroids[None, :, :]) ** 2, axis=-1)
    return jnp.argmin(d2, axis=1)


def _block_posterior_diag_cinv(kfn, params, state: api.PICState, Um,
                               m_fields, Cinv_m):
    """``_block_posterior_diag`` with the per-block solve served from a
    PRECOMPUTED dense inverse: ``K_{U_m D_m} C⁻¹`` is one row-major gemm
    instead of the two-sided batched triangular solve.

    This is the plan-owned backend cache (``ServeSpec(cached_cinv=True)``):
    XLA-CPU (and small-RHS TPU) batched trsm bills per PROGRAM almost
    independently of the RHS width, so the routed layout's M+G solve
    programs cost more than their row saving — a batched matmul scales with
    the RHS width on every backend. Different float path than the trsm
    (same math, inverse applied multiplicatively), hence opt-in: the
    default serving plan stays bitwise-faithful to the legacy path.
    Row-major throughout for the same composition-invariance reasons as
    ``_block_posterior_diag``.
    """
    Xm, ym, Ksd, C_L, Wy, ydot, beta, B = m_fields
    Kus = kfn(params, Um, state.S)
    Kud = kfn(params, Um, Xm)
    rowdot = lambda A, v: jnp.sum(A * v[None, :], axis=1)
    ydot_u = rowdot(Kud, Wy)
    WdT = Kud @ Cinv_m                                 # K_{U_m D_m} C^{-1}
    Sdot_us = WdT @ Ksd.T                              # (u, s)
    Phi = Kus + Kus @ B - Sdot_us
    mean = rowdot(Phi, state.alpha) - rowdot(Kus, beta) + ydot_u
    var = (cov.kdiag(kfn, params, Um)
           - jnp.sum(Phi * linalg.chol_solve_right(state.Kss_L, Kus), 1)
           + jnp.sum(Phi * linalg.chol_solve_right(state.Sdd_L, Phi), 1)
           + jnp.sum(Kus * linalg.chol_solve_right(state.Kss_L, Sdot_us), 1)
           - jnp.sum(Kud * WdT, 1))
    return mean, var


@no_retrace("ppic.cinv_blocks")
@jax.jit
def cinv_blocks(C_L: jax.Array) -> jax.Array:
    """(M, b, b) dense symmetric inverses ``(C_L C_Lᵀ)⁻¹`` per block — the
    one-time plan-build cost behind ``ServeSpec(cached_cinv=True)``; every
    routed flush thereafter multiplies instead of solving.

    Under the ``no_retrace`` contract: after a deployment's warmup
    ``contracts.freeze()``, a rebind/refresh must only ever call this with
    already-seen (M, b, b) signatures — a new signature mid-serving is a
    silent recompile the audit flags."""
    eye = jnp.eye(C_L.shape[-1], dtype=C_L.dtype)
    return jax.vmap(lambda L: linalg.chol_solve(L, eye))(C_L)


def _routed_diag_program(kfn, params, state: api.PICState, Cinv, U,
                         assign=None, *, alpha: int, tile: int,
                         n_groups: int | None):
    """The routed serving program body: two-bucket scatter -> per-block
    posterior -> gather, parameterized by the overflow-group count and the
    optional C⁻¹ backend cache. ``predict_routed_diag`` is this program at
    its worst-case defaults (assignment derived on device); ``PICServePlan``
    jits one instance per selected group count (lazy overflow dispatch) and
    passes its host-computed ``assign`` in as a traced argument — the SAME
    assignment that sized the group count, so the scatter can never see a
    row the selection did not provision for (a device-side re-derivation
    could flip a near-boundary argmin across float paths and silently drop
    the flipped row past the chosen capacity)."""
    M = state.Xb.shape[0]
    if assign is None:
        assign = route_queries(state, U)
    lay = scatter_two_bucket(U, assign, M, alpha=alpha, tile=tile,
                             max_groups=n_groups)
    if Cinv is None:
        one = lambda Um, *mf: _block_posterior_diag(kfn, params, state,
                                                    Um, mf)
        means, vars_ = jax.vmap(one)(lay.Xb, *_block_fields(state))
    else:
        one = lambda Um, Ci, *mf: _block_posterior_diag_cinv(
            kfn, params, state, Um, mf, Ci)
        means, vars_ = jax.vmap(one)(lay.Xb, Cinv, *_block_fields(state))
    means_o = vars_o = None
    if lay.Xo is not None:
        # overflow groups: gather the owning block's cached factors per
        # group (dynamic indices, static shapes — jit-safe)
        mf_o = tuple(a[lay.o_blk] for a in _block_fields(state))
        if Cinv is None:
            means_o, vars_o = jax.vmap(one)(lay.Xo, *mf_o)
        else:
            means_o, vars_o = jax.vmap(one)(lay.Xo, Cinv[lay.o_blk], *mf_o)
    return (gather_two_bucket(means, means_o, lay),
            gather_two_bucket(vars_, vars_o, lay))


def global_diag(kfn, params, state: api.PICState, U):
    """The pPITC (eqs. 7-8) diag posterior from a PIC state's GLOBAL
    factors only — no per-block cache touched.

    ``PICState``'s first four fields ARE a ``PITCState`` (the S-space
    summary the local corrections refine), so a query whose nearest block
    is unavailable can still be answered from the global posterior: a
    strictly coarser approximation (PIC minus its local correction), never
    an error and never a NaN from the dead block's factors. This is the
    bounded-degradation serving path — accuracy degrades to pPITC, bounded
    by the ``with_alive`` refit oracle (tests/test_resilience.py)."""
    from repro.core import ppitc
    gstate = api.PITCState(state.S, state.Kss_L, state.Sdd_L, state.alpha)
    return ppitc.predict_batch_diag(kfn, params, gstate, U)


def _routed_deg_program(kfn, params, state: api.PICState, Cinv, U, assign,
                        dead_row, *, alpha: int, tile: int,
                        n_groups: int | None):
    """``_routed_diag_program`` with per-row bounded degradation.

    ``dead_row`` is a (|U|,) bool TRACED value (not a shape), so one
    compiled program serves every failure pattern — which block died, and
    how many rows it strands, never triggers a recompile (the acceptance
    criterion the health layer's auto-retire leans on). Rows whose target
    block is dead are answered from the global S-space posterior via a
    per-row select; the select also firewalls NaN/Inf a poisoned block's
    factors may have produced, since ``jnp.where`` never propagates the
    unselected branch's values."""
    mean_r, var_r = _routed_diag_program(kfn, params, state, Cinv, U, assign,
                                         alpha=alpha, tile=tile,
                                         n_groups=n_groups)
    mean_g, var_g = global_diag(kfn, params, state, U)
    return (jnp.where(dead_row, mean_g, mean_r),
            jnp.where(dead_row, var_g, var_r))


def predict_routed_diag(kfn, params, state: api.PICState, U, *,
                        alpha: int = ROUTED_ALPHA, tile: int | None = None):
    """Batch-composition-invariant (mean, var) for any |U|.

    Scatters the batch to nearest-centroid blocks through the two-bucket
    capacity scheme (``runner.scatter_two_bucket``): a (M, alpha*ceil(|U|/M))
    main bucket plus a static set of skew-overflow groups, each served with
    its recorded block's cached factors. Shapes — and the compiled
    executable — still depend only on (|U|, M), but balanced traffic pays
    ~(alpha+1)*|U| computed rows instead of the capacity-|U| layout's M*|U|.
    Per-row posteriors are bitwise identical to that layout (every
    predictive equation is row-independent; tests/test_routing_equivalence).

    ``tile`` aligns the bucket width to the serving kernel's block_q so the
    Pallas dispatch needs no second pad. This is the worst-case-G, no-cache
    instance of the serving program; a ``PICServePlan`` additionally selects
    smaller overflow programs from the flush occupancy and can serve the
    per-block solve from cached C⁻¹ (``GPMethod.plan``).
    """
    if tile is None:   # a KernelSpec declares its serving tile; bare kfns: 1
        tile = getattr(kfn, "block_q", None) or 1
    return _routed_diag_program(kfn, params, state, None, U, None,
                                alpha=alpha, tile=tile, n_groups=None)


def predict_routed_diag_capacity(kfn, params, state: api.PICState, U):
    """Capacity-|U| routed reference (the pre-two-bucket layout): every block
    gets a (|U|,)-slot buffer via ``scatter_by_block``. Kept as the oracle
    the two-bucket path is property-tested against (bitwise) and for the
    bench's padded-rows comparison; ``predict_routed`` still uses this
    layout for its dense within-block covariance view."""
    M = state.Xb.shape[0]
    assign = route_queries(state, U)
    Ub, order, block_of, slot = scatter_by_block(U, assign, M)
    one = lambda Um, *mf: _block_posterior_diag(kfn, params, state, Um, mf)
    means, vars_ = jax.vmap(one)(Ub, *_block_fields(state))
    return (gather_by_block(means, order, block_of, slot),
            gather_by_block(vars_, order, block_of, slot))


def predict_routed(kfn, params, state: api.PICState, U) -> GPPosterior:
    """Routed posterior with the dense within-block covariance view.

    Mean/variance are the routed per-query values; covariance entries are
    filled for query pairs routed to the same block (eqs. 12-14) and zero
    across blocks — the routed analogue of ``predict_batch``'s
    block-diagonal dense view.
    """
    M = state.Xb.shape[0]
    assign = route_queries(state, U)
    Ub, order, block_of, slot = scatter_by_block(U, assign, M)
    one = lambda Um, *mf: _block_posterior(kfn, params, state, Um, mf)
    means, covs = jax.vmap(one)(Ub, *_block_fields(state))
    mean = gather_by_block(means, order, block_of, slot)
    slot_q = jnp.zeros_like(slot).at[order].set(slot)   # slot in caller order
    same = assign[:, None] == assign[None, :]
    covm = jnp.where(same,
                     covs[assign[:, None], slot_q[:, None], slot_q[None, :]],
                     jnp.zeros((), covs.dtype))
    return GPPosterior(mean, covm)


def predict(kfn, params, S, X, y, U, runner: Runner) -> ParallelPosterior:
    """End-to-end pPIC: thin wrapper over fit + predict_blocks.

    For best accuracy X/U should be co-clustered first
    (core/clustering.py — Remark 2 after Def. 5).
    """
    state = fit(kfn, params, X, y, S=S, runner=runner)
    return predict_blocks(kfn, params, state, U)


# ---------------------------------------------------------------------------
# PICServePlan — the PIC family's phase-1 serving program (api.GPMethod.plan).
# ---------------------------------------------------------------------------

@dataclasses.dataclass(frozen=True)
class PICServePlan(api.ServePlan):
    """``api.ServePlan`` with the two PIC-specific assets the plan/execute
    split exists for:

    * backend caches — ``caches`` holds the per-block dense ``C⁻¹`` when
      the spec asks for it (``cached_cinv=True``), passed to executables as
      a traced argument and recomputed on ``rebind`` — so hot-swapping a
      streamed state refreshes the cache with zero recompilation;
    * a routed executable LADDER — one jitted program per overflow-group
      count g ∈ {0, 1, 2, 4, ..., G_worst}, selected per flush from the
      host-side occupancy: balanced traffic runs the G=0 program (main
      bucket only — no overflow compute dispatched at all), mild skew runs
      a 1-2 group program, and only adversarial skew pays the worst case.
      The selection is EXACT (counts, not a guess): a row past the chosen
      program's capacity would be silently dropped by the scatter, so the
      plan never under-provisions.

    Per-row posteriors are bitwise-identical across the ladder: group k's
    rows run the same row-independent per-block program wherever the batch
    composition lands them (property-tested in tests/test_plan.py).
    """

    def _rebuild_caches(self, state):
        return cinv_blocks(state.C_L) if self.spec.cached_cinv else None

    def _routed_exec(self, g: int):
        kfn, alpha, tile = self.kfn, self.spec.alpha, self.block_q
        return self._jitted(
            ("routed", g), lambda: lambda params, state, caches, U, assign:
                _routed_diag_program(kfn, params, state, caches, U, assign,
                                     alpha=alpha, tile=tile, n_groups=g))

    def _routed_deg_exec(self, g: int):
        """The degraded-dispatch sibling of ``_routed_exec``: same program
        plus a per-row global-posterior select keyed on a traced dead-row
        mask (``_routed_deg_program``). A separate ladder key so healthy
        flushes keep running the bitwise-unchanged baseline program."""
        kfn, alpha, tile = self.kfn, self.spec.alpha, self.block_q
        return self._jitted(
            ("routed_deg", g),
            lambda: lambda params, state, caches, U, assign, dead:
                _routed_deg_program(kfn, params, state, caches, U, assign,
                                    dead, alpha=alpha, tile=tile,
                                    n_groups=g))

    def routed_diag(self, U, block_alive=None):
        """Batch-composition-invariant (mean, var): pad to the bucket
        ladder, route host-side, pick the overflow program from the
        occupancy, dispatch.

        The host-side nearest-centroid assignment of the STAGED padded
        batch is authoritative for BOTH the group-count selection and the
        device scatter (it is passed into the executable as a traced
        argument): one float path, so the program the occupancy sized is
        by construction sufficient for the rows the scatter places.

        Pad rows are NOT routed by centroid — they are packed into blocks
        with spare main-bucket capacity. Every row must land somewhere
        (the scatter's drop semantics would otherwise demand provisioning
        for them), but letting zeros route naturally would pile them onto
        one block and drag partially-filled flushes — the deadline-trigger
        common case — onto the worst-case overflow program. Spare capacity
        always covers them (M·cap >= alpha·ceil(bucket/M)·M >= bucket for
        alpha >= 1), pads sit positionally AFTER the real rows so they can
        never displace a real row's (block, slot) placement, and their
        outputs are trimmed — so overflow demand is the REAL rows' demand,
        and balanced traffic runs G=0 regardless of padding.

        ``block_alive`` (optional (M,) bool) is the health layer's routing
        mask: rows whose nearest-centroid block is marked dead are answered
        from the global S-space posterior instead (``global_diag``) through
        the degraded executable ladder — same shapes, mask passed as a
        traced value, zero recompiles once warmed. Which rows degraded is
        surfaced via ``stats.last_degraded`` (None on fully-healthy
        flushes, where the bitwise-unchanged baseline program runs)."""
        if isinstance(U, jax.core.Tracer):
            raise TypeError(
                "routed_diag stages on the host (nearest-centroid routing "
                "and pad-packing pick data-dependent programs) and cannot "
                "run under jit/vmap; call it with concrete batches, or "
                "use plan.diag for the traceable unrouted path")
        Up, u = self._padded(U)
        assign, g = self._route(np.asarray(Up), u)
        self.stats.last_degraded = None
        dead = None
        if block_alive is not None:
            alive = np.asarray(block_alive, bool)
            M = int(self.state.Xb.shape[0])
            if alive.shape != (M,):
                raise ValueError(
                    f"block_alive must be an ({M},) bool mask over the "
                    f"state's blocks; got shape {alive.shape}")
            dead = ~alive[assign]
        if dead is not None and dead.any():
            mean, var = self._routed_deg_exec(g)(self.params, self.state,
                                                 self.caches, Up, assign,
                                                 dead)
            self.stats.last_degraded = dead[:u].copy()
            self.stats.n_degraded_rows += int(dead[:u].sum())
        else:
            mean, var = self._routed_exec(g)(self.params, self.state,
                                             self.caches, Up, assign)
        self.stats.n_routed_batches += 1
        self.stats.last_g = g
        if g == 0:
            self.stats.n_g0_batches += 1
        return mean[:u], var[:u]

    def _route(self, Up: np.ndarray, u: int) -> tuple[np.ndarray, int]:
        """(assign, g) for a staged padded batch whose first ``u`` rows are
        real — the ONE host-side routing decision behind ``routed_diag``
        (and the bench's executable-level timings, which must provision
        exactly what a real flush would)."""
        M = int(self.state.Xb.shape[0])
        assign = clustering.nearest_center_np(
            Up[:u], np.asarray(self.state.centroids)).astype(np.int32)
        counts = np.bincount(assign, minlength=M)
        cap, G_full = routed_capacity(Up.shape[0], M, alpha=self.spec.alpha,
                                      tile=self.block_q)
        pad = Up.shape[0] - u
        if pad:
            spare = (cap - np.minimum(counts, cap)).astype(np.int64)
            pad_assign = np.repeat(np.arange(M, dtype=np.int32),
                                   spare)[:pad]
            assert pad_assign.shape[0] == pad   # M*cap >= bucket invariant
            assign = np.concatenate([assign, pad_assign])
        g = 0
        if G_full:
            over = np.maximum(counts - cap, 0)
            g = _snap_groups(int(np.sum(-(-over // cap))), G_full,
                             self.spec.max_overflow_groups)
        return assign, g

    def warmup(self, d: int, *, dtype=np.float32,
               degraded: bool = True) -> "PICServePlan":
        """Pre-compile the FULL routed executable ladder per bucket — every
        (bucket, g) program a flush can select — so g-selection never pays
        a mid-serving compile (the p99 simulation in bench_serve_latency
        charges real flush time to tickets and would see it).

        ``degraded=True`` (default) additionally compiles the degraded
        sibling of every (bucket, g) program: a block failing MID-STREAM
        must not cost a compile on the first stranded flush (the dead-row
        mask is a traced value, so one degraded program per (bucket, g)
        covers every failure pattern). Pass ``degraded=False`` to halve
        warmup time on deployments that run without the health layer."""
        if not self.spec.routed:
            return super().warmup(d, dtype=dtype)
        M = int(self.state.Xb.shape[0])
        for b in self.buckets or ():
            U0 = np.zeros((b, d), dtype)
            _, G = routed_capacity(b, M, alpha=self.spec.alpha,
                                   tile=self.block_q)
            gs, g = {0, G}, 1
            while g < G:                      # the _snap_groups ladder
                gs.add(g)
                g *= 2
            if self.spec.max_overflow_groups is not None:
                gs = {g for g in gs
                      if g <= self.spec.max_overflow_groups} | {G}
            a0 = np.zeros((b,), np.int32)
            d0 = np.zeros((b,), bool)
            for g in sorted(gs):
                jax.block_until_ready(self._routed_exec(g)(
                    self.params, self.state, self.caches, U0, a0)[0])
                if degraded:
                    jax.block_until_ready(self._routed_deg_exec(g)(
                        self.params, self.state, self.caches, U0, a0,
                        d0)[0])
        return self


def _snap_groups(needed: int, G_full: int, max_groups: int | None) -> int:
    """Snap an exact group demand onto the executable ladder {0, 1, 2, 4,
    ...}: bounded compile count (log G programs) without ever serving a
    program too small for the flush. Demands above ``max_groups`` fall back
    to the always-sufficient worst-case program."""
    if needed <= 0:
        return 0
    g = 1
    while g < needed:
        g *= 2
    if max_groups is not None and g > max_groups:
        return G_full
    return min(g, G_full)


def make_plan(method: api.GPMethod, kfn, params, state: api.PICState,
              spec: api.ServeSpec) -> PICServePlan:
    """``GPMethod.plan_fn`` for ppic/pic."""
    plan = PICServePlan(method, spec.resolve_kfn(kfn), params, state, spec,
                        spec.resolve_block_q(kfn), spec.resolve_buckets(kfn))
    if spec.cached_cinv:
        plan = dataclasses.replace(plan,
                                   caches=plan._rebuild_caches(state))
    return plan


def predict_distributed(kfn, params, S, X, y, U,
                        runner: Runner) -> ParallelPosterior:
    """Fully-collective pPIC (psum inside the per-machine program)."""
    Xb, yb, Ub = (runner.shard_blocks(a) for a in (X, y, U))
    fn = lambda Xm, ym, Um, params, S: machine_step(
        kfn, params, S, Xm, ym, Um, axis_name=runner.axis_name)
    means, covs = runner.map(fn, (Xb, yb, Ub), (params, S))
    return ParallelPosterior(runner.unshard(means), covs)


api.register(api.GPMethod("ppic", fit, predict_fn=predict_batch,
                          predict_diag_fn=predict_batch_diag,
                          predict_routed_diag_fn=predict_routed_diag,
                          init_store=init_store, plan_fn=make_plan))
