"""Online/incremental learning (Sec. 5.2) + summary-algebra fault tolerance.

The pPITC/pPIC global summary (eqs. 5-6) is an algebraic SUM of per-machine
local summaries, so:

* new data blocks fold in with an add (no recompute of old blocks' O(b^3)
  inverses) — the paper's streaming argument;
* a failed machine folds OUT with a subtract — survivors' work is preserved
  and the posterior remains a *valid* PITC/PIC posterior over the surviving
  data (runtime/fault.py builds on this);
* elastic scale-up/down is re-blocking + re-summing cached summaries.

Two layers here:

* ``SummaryStore`` — the pure-array pytree of stacked per-machine summaries
  (cheap: M x (|S| + |S|² + |S|·b)) PLUS the incrementally-maintained global
  factors. Every local summary Σ-dot_SS^m is PSD with the explicit low-rank
  factor F_m = K_SDm chol(Σ_{DmDm|S})^{-T} (Σ-dot^m = F_m F_mᵀ), so folding a
  machine in/out is a rank-b Cholesky update/downdate of ``Sdd_L``
  (``linalg.chol_update_rank``) — O(|S|²·b) instead of the O(|S|³)
  re-factorization, which makes ``to_state`` an O(|S|²) solve.
* ``PITCStore`` / ``PICStore`` — the method-owned ``api.StateStore``
  implementations (registered via ``GPMethod.init_store`` by core/ppitc.py
  and core/ppic.py). ``PITCStore`` emits ``api.PITCState``; ``PICStore``
  additionally carries the per-block caches of eqs. (12)-(14) and emits
  ``api.PICState`` with alive-block selection and centroid refresh, so
  ``GPServer(routed=True)`` hot-swaps streamed data too.

The module-level free functions (``build``/``assimilate``/``retire``/
``revive``/``to_state``/``predict_ppitc``) are the underlying SummaryStore
algebra; prefer the ``api.StateStore`` protocol (``api.init_store``) in new
code — the free functions survive as the implementation + back-compat
surface for callers that hold a bare ``SummaryStore``.
"""
from __future__ import annotations

import dataclasses
from typing import NamedTuple

import jax
import jax.numpy as jnp
import numpy as np

from repro.core import api, clustering, linalg
from repro.core.ppitc import (GlobalSummary, LocalSummary, local_summary,
                              predict_batch)
from repro.parallel.runner import Runner


class SummaryStore(NamedTuple):
    locals_: LocalSummary     # stacked (M, ...) per-machine summaries
    F: jax.Array              # (M, s, b) low-rank factors: Sdot_m = F_m F_mᵀ
    alive: jax.Array          # (M,) bool — machine participation mask
    Kss: jax.Array            # (s, s) prior support covariance
    Kss_L: jax.Array          # (s, s) chol K_SS (static across mutations)
    Sdd_L: jax.Array          # (s, s) chol of the ALIVE Σ-dot-dot (cached,
    #                           maintained by rank-b updates — never refolded)
    ydd: jax.Array            # (s,)   alive Σ_m y-dot^m (cached)


def _sdd_chol(Kss: jax.Array, Sdd: jax.Array) -> jax.Array:
    """chol(Sdd + jitter·I) with the jitter anchored to K_SS.

    Anchoring to the (mutation-invariant) prior scale instead of mean
    diag(Sdd) makes the cold factorization and the incrementally-updated one
    factor THE SAME matrix: assimilate/retire then differ from a full
    recompute only by rank-update roundoff (~1e-13 in float64), not by a
    data-dependent jitter drift.
    """
    scale = linalg.default_jitter(Sdd.dtype) * jnp.mean(jnp.diag(Kss))
    return jnp.linalg.cholesky(
        Sdd + scale * jnp.eye(Sdd.shape[-1], dtype=Sdd.dtype))


def _summarize(kfn, params, S, X, y, runner: Runner):
    """Per-machine local summaries + low-rank factors (paper Steps 1-2)."""
    Xb, yb = runner.shard_blocks(X), runner.shard_blocks(y)

    def fn(Xm, ym, params, S):
        Kss_L = linalg.chol(kfn(params, S, S))
        loc, (Ksd, C_L, _) = local_summary(kfn, params, S, Kss_L, Xm, ym)
        F = linalg.tri_solve(C_L, Ksd.T).T        # (s, b): Sdot = F Fᵀ
        return loc, F

    return runner.map(fn, (Xb, yb), (params, S))


def _pad_factor(F: jax.Array, b: int) -> jax.Array:
    """Zero-pad the block axis of an (M, s, b') factor to width b. Padded
    columns contribute 0·0ᵀ to F Fᵀ, so the algebra is unchanged — this is
    what lets waves of different block sizes share one stacked store."""
    if F.shape[-1] >= b:
        return F
    return jnp.pad(F, [(0, 0), (0, 0), (0, b - F.shape[-1])])


def _cold_store(kfn, params, S, locals_: LocalSummary,
                F: jax.Array) -> SummaryStore:
    """Assemble a SummaryStore from freshly-summarized blocks: the ONE
    place the global factor is Cholesky'd from scratch (cold O(|S|³), paid
    once per store lifetime) — shared by the PITC and PIC builders so both
    anchor the same jitter to the same matrix."""
    alive = jnp.ones((locals_.ydot.shape[0],), bool)
    Kss = kfn(params, S, S)
    ydd = jnp.sum(locals_.ydot, axis=0)
    Sdd_L = _sdd_chol(Kss, Kss + jnp.sum(locals_.Sdot, axis=0))
    return SummaryStore(locals_, F, alive, Kss, linalg.chol(Kss), Sdd_L, ydd)


def build(kfn, params, S, X, y, runner: Runner) -> SummaryStore:
    """Initial store from blocked data (paper Steps 1-3)."""
    locals_, F = _summarize(kfn, params, S, X, y, runner)
    return _cold_store(kfn, params, S, locals_, F)


def global_summary(store: SummaryStore) -> GlobalSummary:
    """Assemble eqs. (5)-(6) from whatever machines are alive — the full
    (non-incremental) reference the cached ``Sdd_L``/``ydd`` are tested
    against; use it for arbitrary alive-mask views (``with_alive``)."""
    w = store.alive.astype(store.locals_.ydot.dtype)
    ydd = jnp.einsum("m,ms->s", w, store.locals_.ydot)
    Sdd = store.Kss + jnp.einsum("m,mst->st", w, store.locals_.Sdot)
    return GlobalSummary(ydd, Sdd)


def to_state(store: SummaryStore, S: jax.Array) -> api.PITCState:
    """Assemble the cached prediction factors (eqs. 7-8 precomputation).

    O(|S|²): ``Sdd_L`` is maintained incrementally by assimilate/retire, so
    only the weight solve remains here — the |S|³ factorization happens once
    at ``build`` and never again across the store's lifetime."""
    alpha = linalg.chol_solve(store.Sdd_L, store.ydd[:, None])[:, 0]
    return api.PITCState(S, store.Kss_L, store.Sdd_L, alpha)


def _fold_in(store: SummaryStore, locals_new: LocalSummary,
             F_new: jax.Array) -> SummaryStore:
    """Append new machine blocks and rank-update the cached global factors."""
    b = max(store.F.shape[-1], F_new.shape[-1])
    merged = LocalSummary(
        jnp.concatenate([store.locals_.ydot, locals_new.ydot]),
        jnp.concatenate([store.locals_.Sdot, locals_new.Sdot]))
    F = jnp.concatenate([_pad_factor(store.F, b), _pad_factor(F_new, b)])
    alive = jnp.concatenate(
        [store.alive, jnp.ones((F_new.shape[0],), bool)])
    # one rank-(M'·b) update: stack the new machines' factor columns
    W = jnp.concatenate([f for f in F_new], axis=1)        # (s, M'·b)
    Sdd_L = linalg.chol_update_rank(store.Sdd_L, W)
    ydd = store.ydd + jnp.sum(locals_new.ydot, axis=0)
    return SummaryStore(merged, F, alive, store.Kss, store.Kss_L, Sdd_L, ydd)


def assimilate(store: SummaryStore, kfn, params, S, X_new, y_new,
               runner: Runner) -> SummaryStore:
    """Fold a new data stream (D', y_D') in — Sec. 5.2.

    The new blocks are summarized in parallel and appended; old summaries
    are reused untouched, and the global factor is advanced by a rank-b
    Cholesky update per new block — O(|S|²·b) each, no |S|³ anywhere."""
    locals_new, F_new = _summarize(kfn, params, S, X_new, y_new, runner)
    return _fold_in(store, locals_new, F_new)


def retire(store: SummaryStore, machine: int) -> SummaryStore:
    """Drop a machine's contribution (failure or decommission): rank-b
    DOWNdate of the cached factor. No-op if already retired."""
    api.check_machine_index(store.alive.shape[0], machine)
    alive = api.concrete_alive_mask(store.alive)
    if alive is None:
        raise TypeError(
            "retire() branches on the alive mask host-side (the "
            "already-retired no-op check) and cannot run under jit/vmap; "
            "flip machines wholesale with with_alive(store, mask), whose "
            "refold path traces")
    if not alive[machine]:
        return store
    Sdd_L = linalg.chol_update_rank(store.Sdd_L, store.F[machine], sign=-1.0)
    return store._replace(alive=store.alive.at[machine].set(False),
                          Sdd_L=Sdd_L,
                          ydd=store.ydd - store.locals_.ydot[machine])


def revive(store: SummaryStore, machine: int) -> SummaryStore:
    """Fold a previously-retired machine back in (rank-b update)."""
    api.check_machine_index(store.alive.shape[0], machine)
    alive = api.concrete_alive_mask(store.alive)
    if alive is None:
        raise TypeError(
            "revive() branches on the alive mask host-side (the "
            "already-alive no-op check) and cannot run under jit/vmap; "
            "flip machines wholesale with with_alive(store, mask), whose "
            "refold path traces")
    if alive[machine]:
        return store
    Sdd_L = linalg.chol_update_rank(store.Sdd_L, store.F[machine])
    return store._replace(alive=store.alive.at[machine].set(True),
                          Sdd_L=Sdd_L,
                          ydd=store.ydd + store.locals_.ydot[machine])


def with_alive(store: SummaryStore, alive: jax.Array, *,
               mode: str = "auto") -> SummaryStore:
    """Arbitrary alive-mask view (straggler deadlines flip many machines at
    once) — the one sanctioned way to set ``alive`` wholesale (a raw
    ``_replace`` would desynchronize the cache). Two realizations:

    * ``incremental`` — one rank-b cholupdate/downdate per FLIPPED machine
      (retire/revive chain): O(|S|²·b·h) for Hamming distance h, so a small
      deadline flip costs O(|S|²·b) — no |S|³ anywhere;
    * ``refold``      — re-derive the factors from the masked summary sum in
      one O(|S|³) pass (the cold-factorization float path).

    ``mode="auto"`` picks by the Hamming distance of the mask against the
    cost crossover: h·b rank-1 sweeps at O(|S|²) each versus the refold's
    O(|S|³)/3 factorization plus the O(M·|S|²) masked re-sum — incremental
    wins while h·b <= |S|/3 + M. Both paths produce the same matrix; they
    differ only in float path (rank-update roundoff ~1e-13 in float64,
    tests/test_state_store.py).
    """
    alive = jnp.asarray(alive, bool)
    if mode not in ("auto", "incremental", "refold"):
        raise ValueError(f"unknown with_alive mode {mode!r}")
    if isinstance(alive, jax.core.Tracer) or \
            isinstance(store.alive, jax.core.Tracer):
        # under jit/vmap the Hamming distance is data we cannot branch on
        # host-side; the refold is the pure-jnp realization and traces fine
        if mode == "incremental":
            raise ValueError(
                "with_alive(mode='incremental') needs concrete masks (it "
                "dispatches a host-side retire/revive chain); under "
                "jit/vmap use mode='auto'/'refold'")
        mode = "refold"
    if mode != "refold":
        flips = np.flatnonzero(np.asarray(store.alive) != np.asarray(alive))
        if mode == "auto":
            s = store.Sdd_L.shape[0]
            b = store.F.shape[-1]
            M = store.alive.shape[0]
            mode = ("incremental" if len(flips) * b <= s // 3 + M
                    else "refold")
    if mode == "incremental":
        for m in flips:
            m = int(m)
            store = revive(store, m) if bool(alive[m]) else retire(store, m)
        return store
    store = store._replace(alive=alive)
    glob = global_summary(store)
    return store._replace(Sdd_L=_sdd_chol(store.Kss, glob.Sdd),
                          ydd=glob.ydd)


def replace_block(store: SummaryStore, kfn, params, S, machine: int,
                  Xm, ym) -> SummaryStore:
    """Recompute ONE machine's summary from its (re-read) data shard and
    fold it in alive — the fault-recovery reassign path. Incremental: at
    most one downdate (if the stale summary was still folded in) plus one
    update."""
    api.check_machine_index(store.alive.shape[0], machine)
    store = retire(store, machine)
    loc, (Ksd, C_L, _) = local_summary(kfn, params, S, store.Kss_L, Xm, ym)
    F_m = linalg.tri_solve(C_L, Ksd.T).T
    b = max(store.F.shape[-1], F_m.shape[-1])
    F_m = _pad_factor(F_m[None], b)[0]
    locs = LocalSummary(store.locals_.ydot.at[machine].set(loc.ydot),
                        store.locals_.Sdot.at[machine].set(loc.Sdot))
    store = store._replace(locals_=locs, F=_pad_factor(store.F, b)
                           .at[machine].set(F_m))
    return revive(store, machine)


def predict_ppitc(store: SummaryStore, kfn, params, S, U) -> tuple:
    """pPITC prediction (eqs. 7-8) straight from the store: thin wrapper
    over ``to_state`` + ``ppitc.predict_batch``."""
    post = predict_batch(kfn, params, to_state(store, S), U)
    return post.mean, post.cov


# ---------------------------------------------------------------------------
# Method-owned StateStore implementations (api.StateStore protocol).
# ---------------------------------------------------------------------------

@dataclasses.dataclass(frozen=True)
class PITCStore:
    """pPITC's ``api.StateStore``: owns the fit context, emits PITCState.

    Immutable — every mutation returns a new store sharing the untouched
    leaves, so serving can keep the previous store alive until a hot-swap
    commits (launch/gp_serve.py).
    """
    kfn: object
    params: dict
    S: jax.Array
    runner: Runner
    store: SummaryStore

    # -- protocol -----------------------------------------------------------

    def assimilate(self, X_new, y_new, runner: Runner | None = None
                   ) -> "PITCStore":
        """Fold a new stream in. ``runner`` overrides how the WAVE is
        blocked (elastic scale-up arrives on however many machines it
        arrives on); defaults to the fit-time runner."""
        return dataclasses.replace(self, store=assimilate(
            self.store, self.kfn, self.params, self.S, X_new, y_new,
            runner or self.runner))

    def retire(self, machine: int) -> "PITCStore":
        new = retire(self.store, machine)
        return self if new is self.store else \
            dataclasses.replace(self, store=new)

    def revive(self, machine: int) -> "PITCStore":
        new = revive(self.store, machine)
        return self if new is self.store else \
            dataclasses.replace(self, store=new)

    def to_state(self) -> api.PITCState:
        return to_state(self.store, self.S)

    # -- beyond-protocol surface (fault/straggler runtimes) -----------------

    @property
    def alive(self) -> jax.Array:
        return self.store.alive

    @property
    def num_machines(self) -> int:
        return int(self.store.alive.shape[0])

    def with_alive(self, alive, *, mode: str = "auto") -> "PITCStore":
        return dataclasses.replace(self, store=with_alive(self.store, alive,
                                                          mode=mode))

    def reassign(self, machine: int, Xm, ym) -> "PITCStore":
        return dataclasses.replace(self, store=replace_block(
            self.store, self.kfn, self.params, self.S, machine, Xm, ym))

    def global_summary(self) -> GlobalSummary:
        return global_summary(self.store)

    def predict(self, U) -> tuple:
        """(mean, cov) over U from the current alive set."""
        return predict_ppitc(self.store, self.kfn, self.params, self.S, U)


def init_pitc_store(kfn, params, X, y, *, S, runner: Runner) -> PITCStore:
    """``GPMethod.init_store`` for ppitc/pitc (registered in core/ppitc.py)."""
    return PITCStore(kfn, params, S, runner,
                     build(kfn, params, S, X, y, runner))


class PICBlocks(NamedTuple):
    """Per-block caches for the pPIC local correction (eqs. 12-14); the
    global algebra lives in the shared SummaryStore. Leading axis M."""
    Xb: jax.Array      # (M, b, d)
    yb: jax.Array      # (M, b)
    Ksd: jax.Array     # (M, s, b)
    C_L: jax.Array     # (M, b, b)
    Wy: jax.Array      # (M, b)
    beta: jax.Array    # (M, s)
    B: jax.Array       # (M, s, s)


def _summarize_pic(kfn, params, S, X, y, runner: Runner):
    """Per-machine summaries + the eqs. (12)-(14) caches, one map."""
    Xb, yb = runner.shard_blocks(X), runner.shard_blocks(y)

    def fn(Xm, ym, params, S):
        Kss_L = linalg.chol(kfn(params, S, S))
        loc, (Ksd, C_L, Wy) = local_summary(kfn, params, S, Kss_L, Xm, ym)
        F = linalg.tri_solve(C_L, Ksd.T).T
        beta = linalg.chol_solve(Kss_L, loc.ydot[:, None])[:, 0]
        B = linalg.chol_solve(Kss_L, loc.Sdot)
        return loc, F, Ksd, C_L, Wy, beta, B

    loc, F, Ksd, C_L, Wy, beta, B = runner.map(fn, (Xb, yb), (params, S))
    return loc, F, PICBlocks(Xb, yb, Ksd, C_L, Wy, beta, B)


@dataclasses.dataclass(frozen=True)
class PICStore:
    """pPIC's ``api.StateStore``: the PITC global algebra + per-block local
    caches; ``to_state`` emits an ``api.PICState`` over the ALIVE blocks
    with refreshed centroids, so ``GPServer(routed=True)`` hot-swaps
    streamed data (Remark 2 keeps holding: routing targets are exactly the
    blocks that can serve a local correction).

    Streamed waves must keep the fit-time block size (|D'|/M' == b): the
    block caches are stacked arrays, and zero-padding *data* rows would
    inject spurious noise-only observations into Σ_{DmDm|S} (see
    Runner.shard_blocks). Retiring a machine shrinks the state's block axis
    at the next ``to_state`` — one serving recompile, flagged by gp_serve.
    """
    kfn: object
    params: dict
    S: jax.Array
    runner: Runner
    store: SummaryStore
    blocks: PICBlocks

    @property
    def block_size(self) -> int:
        return int(self.blocks.Xb.shape[1])

    def assimilate(self, X_new, y_new, runner: Runner | None = None
                   ) -> "PICStore":
        runner = runner or self.runner
        M_new = runner.num_machines
        b_new = X_new.shape[0] // M_new
        if X_new.shape[0] % M_new or b_new != self.block_size:
            raise ValueError(
                f"pPIC streaming keeps the fit-time block size: got "
                f"|D'|={X_new.shape[0]} over M={M_new} machines "
                f"(b={X_new.shape[0] / M_new:g}) but the store's blocks are "
                f"b={self.block_size}. Re-chunk the wave (or use the pPITC "
                f"store, which accepts any block size).")
        loc, F, blocks_new = _summarize_pic(self.kfn, self.params, self.S,
                                            X_new, y_new, runner)
        merged = PICBlocks(*(jnp.concatenate([a, b]) for a, b in
                             zip(self.blocks, blocks_new)))
        return dataclasses.replace(
            self, store=_fold_in(self.store, loc, F), blocks=merged)

    def retire(self, machine: int) -> "PICStore":
        new = retire(self.store, machine)
        return self if new is self.store else \
            dataclasses.replace(self, store=new)

    def revive(self, machine: int) -> "PICStore":
        new = revive(self.store, machine)
        return self if new is self.store else \
            dataclasses.replace(self, store=new)

    def to_state(self) -> api.PICState:
        st = self.store
        glob = to_state(st, self.S)      # shared O(|S|²) global-factor path
        if isinstance(st.alive, jax.core.Tracer):
            # under jit/vmap the mask is data we cannot branch on, and the
            # dead-block gather below is a data-dependent shape anyway. A
            # traced store can only have been built inside the trace
            # (retire/revive/with_alive-incremental are host-side), so all
            # blocks are alive by construction — take the no-gather path.
            all_alive = True
        else:
            all_alive = bool(np.asarray(st.alive).all())
        if all_alive:
            # streaming common case: no gather — every block cache (incl.
            # the full Xb dataset) is passed through by reference, keeping
            # update() at the advertised O(|S|² b)
            blk, loc = self.blocks, st.locals_
        else:
            idx = jnp.asarray(np.flatnonzero(np.asarray(st.alive)))
            blk = PICBlocks(*(a[idx] for a in self.blocks))
            loc = LocalSummary(st.locals_.ydot[idx], st.locals_.Sdot[idx])
        return api.PICState(
            self.S, glob.Kss_L, glob.Sdd_L, glob.alpha, blk.Xb, blk.yb,
            blk.Ksd, blk.C_L, blk.Wy, loc.ydot, blk.beta, blk.B, loc.Sdot,
            clustering.block_centroids(blk.Xb))


def init_pic_store(kfn, params, X, y, *, S, runner: Runner) -> PICStore:
    """``GPMethod.init_store`` for ppic/pic (registered in core/ppic.py)."""
    loc, F, blocks = _summarize_pic(kfn, params, S, X, y, runner)
    return PICStore(kfn, params, S, runner,
                    _cold_store(kfn, params, S, loc, F), blocks)
