"""Online/incremental learning (Sec. 5.2) + summary-algebra fault tolerance.

The pPITC/pPIC global summary (eqs. 5-6) is an algebraic SUM of per-machine
local summaries, so:

* new data blocks fold in with an add (no recompute of old blocks' O(b^3)
  inverses) — the paper's streaming argument;
* a failed machine folds OUT with a subtract — survivors' work is preserved
  and the posterior remains a *valid* PITC/PIC posterior over the surviving
  data (runtime/fault.py builds on this);
* elastic scale-up/down is re-blocking + re-summing cached summaries.

The store keeps the stacked per-machine summaries (cheap: M x (|S| + |S|^2))
and the running global summary. It is the fit-side *producer* of the cached
``api.PITCState``: ``to_state`` assembles the S-space factors
(Kss_L, Sdd_L, alpha) from whatever machines are alive, which is what
``ppitc.fit`` calls for a cold fit and what serving hot-swaps after
``assimilate``/``retire`` (launch/gp_serve.py).
"""
from __future__ import annotations

from typing import NamedTuple

import jax
import jax.numpy as jnp

from repro.core import api, linalg
from repro.core.ppitc import (GlobalSummary, LocalSummary, local_summary,
                              predict_batch)
from repro.parallel.runner import Runner


class SummaryStore(NamedTuple):
    locals_: LocalSummary     # stacked (M, ...) per-machine summaries
    alive: jax.Array          # (M,) bool — machine participation mask
    Kss: jax.Array            # (s, s) prior support covariance


def build(kfn, params, S, X, y, runner: Runner) -> SummaryStore:
    """Initial store from blocked data (paper Steps 1-3)."""
    Xb, yb = runner.shard_blocks(X), runner.shard_blocks(y)

    def fn(Xm, ym, params, S):
        Kss_L = linalg.chol(kfn(params, S, S))
        loc, _ = local_summary(kfn, params, S, Kss_L, Xm, ym)
        return loc

    locals_ = runner.map(fn, (Xb, yb), (params, S))
    alive = jnp.ones((runner.num_machines,), bool)
    return SummaryStore(locals_, alive, kfn(params, S, S))


def global_summary(store: SummaryStore) -> GlobalSummary:
    """Assemble eqs. (5)-(6) from whatever machines are alive."""
    w = store.alive.astype(store.locals_.ydot.dtype)
    ydd = jnp.einsum("m,ms->s", w, store.locals_.ydot)
    Sdd = store.Kss + jnp.einsum("m,mst->st", w, store.locals_.Sdot)
    return GlobalSummary(ydd, Sdd)


def to_state(store: SummaryStore, S: jax.Array) -> api.PITCState:
    """Assemble the cached prediction factors (eqs. 7-8 precomputation).

    This is the O(|S|^3) step — done once per store mutation, after which
    every ``ppitc.predict_batch`` call is O(|U||S| + |S|^2)."""
    glob = global_summary(store)
    Kss_L = linalg.chol(store.Kss)
    Sdd_L = linalg.chol(glob.Sdd)
    alpha = linalg.chol_solve(Sdd_L, glob.ydd[:, None])[:, 0]
    return api.PITCState(S, Kss_L, Sdd_L, alpha)


def assimilate(store: SummaryStore, kfn, params, S, X_new, y_new,
               runner: Runner) -> SummaryStore:
    """Fold a new data stream (D', y_D') in — Sec. 5.2.

    The new blocks are summarized in parallel and appended; old summaries are
    reused untouched (this is the saving over recomputing eqs. 3-4 for D)."""
    new = build(kfn, params, S, X_new, y_new, runner)
    merged = LocalSummary(
        jnp.concatenate([store.locals_.ydot, new.locals_.ydot]),
        jnp.concatenate([store.locals_.Sdot, new.locals_.Sdot]))
    alive = jnp.concatenate([store.alive, new.alive])
    return SummaryStore(merged, alive, store.Kss)


def retire(store: SummaryStore, machine: int) -> SummaryStore:
    """Drop a machine's contribution (failure or decommission)."""
    return store._replace(alive=store.alive.at[machine].set(False))


def revive(store: SummaryStore, machine: int) -> SummaryStore:
    return store._replace(alive=store.alive.at[machine].set(True))


def predict_ppitc(store: SummaryStore, kfn, params, S, U) -> tuple:
    """pPITC prediction (eqs. 7-8) straight from the store: thin wrapper
    over ``to_state`` + ``ppitc.predict_batch``."""
    post = predict_batch(kfn, params, to_state(store, S), U)
    return post.mean, post.cov
