"""pICF-based GP — parallel incomplete Cholesky factorization GP (Sec. 4).

Step 2's row-based parallel ICF (Chang et al. 2007) is adapted to the TPU
mesh: the rank loop is a ``lax.fori_loop``; per iteration the global pivot is
an all-reduce argmax and the pivot's feature vector / factor column are
broadcast as masked psums (owner contributes, others contribute zeros) — the
collective realization of the MPI pivot broadcast. Communication per step is
O(d + R); O(R(d+R)) total, matching Table 1's O(R^2 log M) summary term.

Steps 3-6 (eqs. 19-27) then need one psum of (R, R+1+u') quantities and an
R x R solve. The fit/predict split (core/api.py) caches the expensive parts —
the rank-R factor F and the R-space solves Phi_L / ydd (eqs. 21-22) — in an
``api.PICFState``; ``predict_batch`` only recomputes the query-dependent
Sigma-dot (eq. 20) and predictive combine (eqs. 24-27). Prediction layouts:

* ``predict_batch``           — centralized combine from the cached state
  (U replicated; what ``predict`` and the serving path use);
* ``machine_step``            — fully-collective, U replicated (Defs. 8-9);
* ``machine_step_sharded_u``  — U sharded over machines (the Remark after
  Def. 7): Sigma-dot chunks are exchanged with ``lax.all_to_all`` and the
  predictive components combined with ``lax.psum_scatter``, cutting the
  per-machine collective payload from O(R|U|) to O(R|U|/M).

Zero prior mean assumed.
"""
from __future__ import annotations

import dataclasses
from typing import NamedTuple

import jax
import jax.numpy as jnp
import numpy as np

from repro.core import api
from repro.core import covariance as cov
from repro.core import linalg
from repro.core.gp import GPPosterior
from repro.core.ppitc import ParallelPosterior
from repro.parallel.runner import Runner


class ICFLocal(NamedTuple):
    F: jax.Array         # (R, b) this machine's factor columns
    residual: jax.Array  # (b,)   local diagonal residual
    pivots: jax.Array    # (R, d) pivot INPUTS in selection order (replicated)
    Lp: jax.Array        # (R, R) lower factor at the pivots: chol K_PP
    #                      (replicated) — row i is pivot i's factor column,
    #                      which is what extends the factor to unseen rows


def icf_factor_local(kfn, params, Xm, R: int, *, axis_name) -> ICFLocal:
    """Distributed pivoted incomplete Cholesky of the signal kernel.

    Concatenating the returned F over machines (in machine order) equals the
    centralized ``core.icf.icf_factor`` on concatenated data, pivot-for-pivot
    (Theorem-3 equivalence test).

    The pivot sequence (inputs + triangular factor at the pivots) is
    recorded on the side: for any unseen point x the consistent factor
    column is the forward solve ``Lp f = k(P, x)`` — the streaming
    row-append path of ``PICFStore`` (no rank loop per new block).
    """
    b = Xm.shape[0]
    m_idx = jax.lax.axis_index(axis_name)
    d0 = cov.kdiag(kfn, params, Xm)
    # zeros + 0*d0 marks the carries as device-varying so the shard_map scan
    # carry type-checks (VMA inference); a no-op after fusion.
    vary = 0.0 * d0[0]
    F0 = jnp.zeros((R, b), d0.dtype) + 0.0 * d0[None, :]
    Xp0 = jnp.zeros((R, Xm.shape[1]), d0.dtype) + vary
    Lp0 = jnp.zeros((R, R), d0.dtype) + vary

    def step(i, carry):
        F, d, Xp, Lp = carry
        # --- global pivot selection: argmax over machines of local maxima
        local_max = jnp.max(d)
        local_arg = jnp.argmax(d)
        gmax = jax.lax.all_gather(local_max, axis_name)       # (M,)
        owner = jnp.argmax(gmax)
        dp = jnp.max(gmax)
        is_owner = (owner == m_idx)
        # --- owner broadcasts pivot input x_p and partial column F[:, p]
        xp = jax.lax.psum(jnp.where(is_owner, Xm[local_arg], 0.0), axis_name)
        fp = jax.lax.psum(jnp.where(is_owner, F[:, local_arg], 0.0), axis_name)
        rp = jnp.sqrt(jnp.maximum(dp, 1e-30))
        # pivot i's factor column after this step is (fp, rp, 0...): record
        # it as row i of the pivot-triangle (fp rows >= i are still zero)
        Xp = Xp.at[i].set(xp)
        Lp = Lp.at[i].set(fp.at[i].set(rp))
        # --- local rank-1 update (each machine only touches its columns)
        col = kfn(params, xp[None], Xm)[0]                    # K[p, D_m]
        f = (col - F.T @ fp) / rp
        F = jax.lax.dynamic_update_slice_in_dim(F, f[None], i, axis=0)
        d = jnp.maximum(d - f * f, 0.0)
        d = jnp.where(is_owner, d.at[local_arg].set(0.0), d)
        return F, d, Xp, Lp

    F, d, Xp, Lp = jax.lax.fori_loop(0, R, step, (F0, d0, Xp0, Lp0))
    return ICFLocal(F, d, Xp, Lp)


def _global_pieces(params, Fm, ym, Sdot_m, *, axis_name):
    """Steps 3-4 (eqs. 19-23): fused psum of [Phi_m | ydot_m | Sdot_m]."""
    s2 = cov.noise_var(params)
    R = Fm.shape[0]
    ydot = Fm @ ym                                          # (R,)   eq. 19
    Phi_m = Fm @ Fm.T                                       # (R, R) eq. 21
    # fuse the three all-reduces into one message (overlap-friendly)
    packed = jnp.concatenate(
        [Phi_m, ydot[:, None], Sdot_m], axis=1)             # (R, R+1+u)
    packed = jax.lax.psum(packed, axis_name)
    Phi = jnp.eye(R, dtype=Fm.dtype) + packed[:, :R] / s2
    Phi_L = linalg.chol(Phi, jitter=0.0)
    ydd = linalg.chol_solve(Phi_L, packed[:, R:R + 1])[:, 0]        # eq. 22
    Sdd = linalg.chol_solve(Phi_L, packed[:, R + 1:])               # eq. 23
    return ydd, Sdd


def machine_step(kfn, params, Xm, ym, U, Fm, *, axis_name):
    """Steps 3-6 with replicated U. Returns replicated (mean_U, cov_UU)."""
    s2 = cov.noise_var(params)
    Kud = kfn(params, U, Xm)                                # (u, b)
    Sdot_m = Fm @ Kud.T                                     # (R, u) eq. 20
    ydd, Sdd = _global_pieces(params, Fm, ym, Sdot_m, axis_name=axis_name)
    # eqs. (24)-(25): predictive components; (26)-(27): psum-combine
    mu_m = Kud @ ym / s2 - Sdot_m.T @ ydd / s2**2
    Sig_m = Kud @ Kud.T / s2 - Sdot_m.T @ Sdd / s2**2
    mean = jax.lax.psum(mu_m, axis_name)
    Kuu = kfn(params, U, U)
    covm = Kuu - jax.lax.psum(Sig_m, axis_name)
    return mean, covm


def machine_step_sharded_u(kfn, params, Xm, ym, Ub_all, Fm, *, axis_name):
    """Steps 3-6 with U sharded (Remark after Def. 7), reduce-scatter form.

    ``Ub_all``: (M, u/M, d) — every machine sees the chunk layout of U (cheap:
    inputs only). Machine m computes Sigma-dot against all of U but only
    chunk-sized pieces cross the network:

      * Phi, ydot  — one (R, R+1) all-reduce (the paper's O(R^2 log M));
      * Sdot       — ``psum_scatter``: machine i receives S_i = sum_m
        Sdot_m^{(i)} — exactly the paper's "each machine m sends Sdot_m^i to
        machine i";
      * the cross terms fold algebraically:
            sum_m (Sdot_m^i)^T ydd   = S_i^T ydd
            sum_m (Sdot_m^i)^T Sdd^i = S_i^T Phi^{-1} S_i
        so no machine ever needs the full (R, |U|) global Sigma-dot.

    §Perf (GP cells): this cut pICF collective bytes 302MB -> ~20MB at
    |U| = 32768, R = 2048, M = 256.
    """
    s2 = cov.noise_var(params)
    M, bu, _ = Ub_all.shape
    U = Ub_all.reshape(M * bu, -1)
    m_idx = jax.lax.axis_index(axis_name)
    R = Fm.shape[0]

    Kud = kfn(params, U, Xm)                                # (u, b)
    Sdot_m = Fm @ Kud.T                                     # (R, u)
    ydot_m = Fm @ ym                                        # (R,)
    Phi_m = Fm @ Fm.T                                       # (R, R)

    packed = jax.lax.psum(
        jnp.concatenate([Phi_m, ydot_m[:, None]], axis=1), axis_name)
    Phi_L = linalg.chol(jnp.eye(R, dtype=Fm.dtype) + packed[:, :R] / s2,
                        jitter=0.0)
    ydd = linalg.chol_solve(Phi_L, packed[:, R:])[:, 0]     # eq. 22

    # reduce-scatter the Sdot chunks: machine i gets S_i = sum_m Sdot_m^i
    S_i = jax.lax.psum_scatter(
        Sdot_m.reshape(R, M, bu).transpose(1, 0, 2), axis_name,
        scatter_dimension=0, tiled=False)                   # (R, bu)
    Sdd_i = linalg.chol_solve(Phi_L, S_i)                   # eq. 23, chunk i

    mean_chunk = (jax.lax.psum_scatter(
        (Kud @ ym / s2).reshape(M, bu), axis_name,
        scatter_dimension=0, tiled=False)
        - S_i.T @ ydd / s2**2)                              # eqs. 24/26

    Kud_c = Kud.reshape(M, bu, -1)                          # (M, bu, b)
    blocks = jnp.einsum("mib,mjb->mij", Kud_c, Kud_c) / s2
    Sig_chunk = (jax.lax.psum_scatter(
        blocks, axis_name, scatter_dimension=0, tiled=False)
        - S_i.T @ Sdd_i / s2**2)                            # eqs. 25/27

    Um = Ub_all[m_idx]
    return mean_chunk, kfn(params, Um, Um) - Sig_chunk


def factor(kfn, params, X, R: int, runner: Runner) -> ICFLocal:
    """Distributed ICF over a Runner; returns stacked (M, R, b) factors."""
    Xb = runner.shard_blocks(X)
    fn = lambda Xm, params: icf_factor_local(kfn, params, Xm, R,
                                             axis_name=runner.axis_name)
    return runner.map(fn, (Xb,), (params,))


# ---------------------------------------------------------------------------
# fit -> PosteriorState -> predict_batch (core/api.py architecture)
# ---------------------------------------------------------------------------

def fit(kfn, params, X, y, *, rank: int, runner: Runner) -> api.PICFState:
    """Distributed ICF (the O(R^2 |D|/M) part) + cached R-space solves.

    ``PICFStore`` (below) is the fit-side producer, so cold fits and the
    streaming row-append/retire path share one code path."""
    return init_picf_store(kfn, params, X, y, rank=rank,
                           runner=runner).to_state()


def predict_batch(kfn, params, state: api.PICFState, U, *,
                  diag_only: bool = False) -> GPPosterior:
    """Eqs. (20), (23)-(27) from the cached factor — no rank loop per query."""
    s2 = cov.noise_var(params)

    def per_m(Xm, ym, Fm):
        Kud = kfn(params, U, Xm)                            # (u, b)
        return Kud @ ym, Fm @ Kud.T, Kud

    Ky, Sdot_m, Kud_m = jax.vmap(per_m)(state.Xb, state.yb, state.F)
    Sdot = jnp.sum(Sdot_m, 0)                               # (R, u) eq. 20
    mean = jnp.sum(Ky, 0) / s2 - Sdot.T @ state.ydd / s2**2  # eqs. 24/26
    Sdd = linalg.chol_solve(state.Phi_L, Sdot)              # eq. 23
    if diag_only:
        var = (cov.kdiag(kfn, params, U)
               - jnp.sum(jnp.einsum("mub,mub->mu", Kud_m, Kud_m), 0) / s2
               + jnp.sum(Sdot * Sdd, 0) / s2**2)
        return GPPosterior(mean, jnp.diag(var))
    Kuu = kfn(params, U, U)
    Sig = jnp.sum(jnp.einsum("mub,mvb->muv", Kud_m, Kud_m), 0) / s2 \
        - Sdot.T @ Sdd / s2**2                              # eqs. 25/27
    return GPPosterior(mean, Kuu - Sig)


def predict_batch_diag(kfn, params, state: api.PICFState, U):
    """(mean, var) vectors — no |U|x|U| intermediates (serving hot path)."""
    s2 = cov.noise_var(params)

    def per_m(Xm, ym, Fm):
        Kud = kfn(params, U, Xm)                            # (u, b)
        return Kud @ ym, Fm @ Kud.T, jnp.sum(Kud * Kud, axis=1)

    Ky, Sdot_m, K2 = jax.vmap(per_m)(state.Xb, state.yb, state.F)
    Sdot = jnp.sum(Sdot_m, 0)                               # (R, u) eq. 20
    mean = jnp.sum(Ky, 0) / s2 - Sdot.T @ state.ydd / s2**2
    Sdd = linalg.chol_solve(state.Phi_L, Sdot)              # eq. 23
    var = (cov.kdiag(kfn, params, U) - jnp.sum(K2, 0) / s2
           + jnp.sum(Sdot * Sdd, 0) / s2**2)
    return mean, var


def predict(kfn, params, X, y, U, R: int, runner: Runner, *,
            shard_u: bool = False):
    """End-to-end pICF-based GP regression over a Runner.

    The replicated-U layout is a thin wrapper over fit + predict_batch; the
    sharded-U layout stays fully collective (its point is the comm pattern).
    """
    if shard_u:
        Xb, yb = runner.shard_blocks(X), runner.shard_blocks(y)
        local = factor(kfn, params, X, R, runner)
        Ub = runner.shard_blocks(U)
        fn = lambda Xm, ym, Fm, params, Ub_all: machine_step_sharded_u(
            kfn, params, Xm, ym, Ub_all, Fm, axis_name=runner.axis_name)
        means, covs = runner.map(fn, (Xb, yb, local.F), (params, Ub))
        return ParallelPosterior(runner.unshard(means), covs)

    state = fit(kfn, params, X, y, rank=R, runner=runner)
    return predict_batch(kfn, params, state, U)


def predict_distributed(kfn, params, X, y, U, R: int, runner: Runner):
    """Fully-collective replicated-U pICF (Defs. 8-9 as written)."""
    Xb, yb = runner.shard_blocks(X), runner.shard_blocks(y)
    local = factor(kfn, params, X, R, runner)
    fn = lambda Xm, ym, Fm, params, U: machine_step(
        kfn, params, Xm, ym, U, Fm, axis_name=runner.axis_name)
    means, covs = runner.map(fn, (Xb, yb, local.F), (params, U))
    # replicated outputs: every machine holds the same full posterior
    return GPPosterior(means[0], covs[0])


# ---------------------------------------------------------------------------
# Incremental state (api.StateStore): row-append / retire on the ICF factor.
# ---------------------------------------------------------------------------

@dataclasses.dataclass(frozen=True)
class PICFStore:
    """pICF's ``api.StateStore`` over the distributed rank-R factor.

    The fit-time pivot basis is FROZEN: a streamed block's factor columns
    are the Nyström-consistent extension ``F_new = Lp^{-1} K_{P,D'}``
    (standard streaming ICF — the same forward solve the rank loop performs
    per pivot, batched over the new rows), so appending b rows costs
    O(R²·b) and the global R-space factor advances by a rank-b Cholesky
    update of ``Phi_L`` (eq. 21) instead of an O(R³) refactorization.
    Retiring a machine downdates by its factor columns — the summary
    algebra of eqs. (19)/(21) is a sum over machines, same as pPITC's.

    Note the retired/streamed posterior lives in the ORIGINAL pivot basis;
    a from-scratch refit would re-pivot greedily. That is the standard
    streaming trade: the basis stays optimal for the fit-time data and
    Nyström-extends to new rows.
    """
    kfn: object
    params: dict
    runner: Runner
    Xb: jax.Array      # (M, b, d)
    yb: jax.Array      # (M, b)
    F: jax.Array       # (M, R, b)
    Xp: jax.Array      # (R, d) pivot inputs
    Lp: jax.Array      # (R, R) pivot triangle (chol K_PP)
    alive: jax.Array   # (M,) bool
    Phi_L: jax.Array   # (R, R) cached chol(I + Σ_alive F_m F_mᵀ / s2)
    yF: jax.Array      # (R,)   cached Σ_alive F_m y_m

    @property
    def block_size(self) -> int:
        return int(self.Xb.shape[1])

    def _scaled(self, Fm: jax.Array) -> jax.Array:
        """Factor columns as Phi update vectors: Phi += (F/σ)(F/σ)ᵀ."""
        return Fm / jnp.sqrt(cov.noise_var(self.params))

    def assimilate(self, X_new, y_new,
                   runner: Runner | None = None) -> "PICFStore":
        runner = runner or self.runner
        M_new = runner.num_machines
        b = X_new.shape[0] // M_new
        if X_new.shape[0] % M_new or b != self.block_size:
            raise ValueError(
                f"pICF streaming keeps the fit-time block size: got "
                f"|D'|={X_new.shape[0]} over M={M_new} machines but the "
                f"store's blocks are b={self.block_size}; re-chunk the wave.")
        Xb_new = runner.shard_blocks(X_new)
        yb_new = runner.shard_blocks(y_new)
        # Nyström extension in the frozen pivot basis, one forward solve
        F_new = jax.vmap(lambda Xm: linalg.tri_solve(
            self.Lp, self.kfn(self.params, self.Xp, Xm)))(Xb_new)
        W = jnp.concatenate([self._scaled(f) for f in F_new], axis=1)
        return dataclasses.replace(
            self,
            Xb=jnp.concatenate([self.Xb, Xb_new]),
            yb=jnp.concatenate([self.yb, yb_new]),
            F=jnp.concatenate([self.F, F_new]),
            alive=jnp.concatenate(
                [self.alive, jnp.ones((M_new,), bool)]),
            Phi_L=linalg.chol_update_rank(self.Phi_L, W),
            yF=self.yF + jnp.sum(jnp.einsum("mrb,mb->mr", F_new, yb_new), 0))

    def retire(self, machine: int) -> "PICFStore":
        api.check_machine_index(self.alive.shape[0], machine)
        alive = api.concrete_alive_mask(self.alive)
        if alive is None:
            raise TypeError(
                "PICFStore.retire() branches on the alive mask host-side "
                "(the already-retired no-op check) and cannot run under "
                "jit/vmap; retire machines before entering the traced "
                "region")
        if not alive[machine]:
            return self
        return dataclasses.replace(
            self,
            alive=self.alive.at[machine].set(False),
            Phi_L=linalg.chol_update_rank(
                self.Phi_L, self._scaled(self.F[machine]), sign=-1.0),
            yF=self.yF - self.F[machine] @ self.yb[machine])

    def revive(self, machine: int) -> "PICFStore":
        api.check_machine_index(self.alive.shape[0], machine)
        alive = api.concrete_alive_mask(self.alive)
        if alive is None:
            raise TypeError(
                "PICFStore.revive() branches on the alive mask host-side "
                "(the already-alive no-op check) and cannot run under "
                "jit/vmap; revive machines before entering the traced "
                "region")
        if alive[machine]:
            return self
        return dataclasses.replace(
            self,
            alive=self.alive.at[machine].set(True),
            Phi_L=linalg.chol_update_rank(
                self.Phi_L, self._scaled(self.F[machine])),
            yF=self.yF + self.F[machine] @ self.yb[machine])

    def to_state(self) -> api.PICFState:
        ydd = linalg.chol_solve(self.Phi_L, self.yF[:, None])[:, 0]  # eq. 22
        alive = api.concrete_alive_mask(self.alive)
        if alive is None or alive.all():
            # streaming common case: pass the block arrays by reference.
            # A TRACED store is all-alive by construction (retire/revive
            # reject traced masks), so this branch is also the only
            # realizable one under jit — the PR-7 to_state bug class,
            # fixed the same way as PICStore.to_state
            return api.PICFState(self.Xb, self.yb, self.F, self.Phi_L, ydd)
        idx = jnp.asarray(np.flatnonzero(alive))
        return api.PICFState(self.Xb[idx], self.yb[idx], self.F[idx],
                             self.Phi_L, ydd)


def init_picf_store(kfn, params, X, y, *, rank: int,
                    runner: Runner) -> PICFStore:
    """``GPMethod.init_store`` for picf: distributed ICF + cached R-space
    factors, cold-factorized once."""
    Xb, yb = runner.shard_blocks(X), runner.shard_blocks(y)
    local = factor(kfn, params, X, rank, runner)            # (M, R, b)
    s2 = cov.noise_var(params)
    R = local.F.shape[1]
    Phi = jnp.eye(R, dtype=local.F.dtype) \
        + jnp.sum(jnp.einsum("mrb,msb->mrs", local.F, local.F), 0) / s2
    Phi_L = linalg.chol(Phi, jitter=0.0)                    # eq. 21
    yF = jnp.sum(jnp.einsum("mrb,mb->mr", local.F, yb), 0)  # eq. 19
    alive = jnp.ones((runner.num_machines,), bool)
    # pivots/Lp are replicated across machines: take machine 0's copy
    return PICFStore(kfn, params, runner, Xb, yb, local.F,
                     local.pivots[0], local.Lp[0], alive, Phi_L, yF)


api.register(api.GPMethod("picf", fit, predict_fn=predict_batch,
                          predict_diag_fn=predict_batch_diag,
                          init_store=init_picf_store))
