"""Hyperparameter learning via maximum likelihood (paper Sec. 6: MLE on a
random 10k subset; Rasmussen & Williams 2006 ch. 5).

Two objectives:
* ``gp.nlml``      — exact marginal likelihood (what the paper uses, on a
  subset small enough for O(n^3));
* ``pitc_nlml``    — the PITC approximate marginal likelihood, which is
  *distributable with the same summary trick* as prediction: per-block terms
  + one |S|x|S| all-reduce. Lets hyperparameters be fit on all data in
  parallel (beyond-paper but paper-consistent: same structural assumption).
"""
from __future__ import annotations

import jax
import jax.numpy as jnp

from repro.core import gp, linalg
from repro.core.ppitc import local_summary
from repro.optim.adam import Adam
from repro.parallel.runner import Runner


def pitc_nlml_machine(kfn, params, S, Xm, ym, *, axis_name) -> jax.Array:
    """-log p(y|theta) under the PITC model  N(0, Gamma_DD + Lambda).

    Uses the matrix-determinant/inversion lemmas so everything global lives in
    S-space: one psum of [quad-vector | S x S matrix | scalars]. Every machine
    returns the same (replicated) scalar. The per-block pieces are the same
    local summaries prediction caches (ppitc.local_summary) — fit and
    prediction share one summary producer.
    """
    n_m = Xm.shape[0]
    Kss = kfn(params, S, S)
    Kss_L = linalg.chol(Kss)
    local, (Ksd, C_L, Wy) = local_summary(kfn, params, S, Kss_L, Xm, ym)
    quad_m = ym @ Wy                                      # y C^{-1} y
    ydot_m, Sdot_m = local.ydot, local.Sdot
    logdet_m = linalg.logdet_from_chol(C_L)
    # one fused all-reduce
    s = S.shape[0]
    packed = jnp.concatenate([
        Sdot_m, ydot_m[:, None],
        jnp.zeros((s, 1), Sdot_m.dtype).at[0, 0].set(quad_m)
            .at[1, 0].set(logdet_m)
            .at[2, 0].set(jnp.asarray(n_m, Sdot_m.dtype))], axis=1)
    packed = jax.lax.psum(packed, axis_name)
    Sdot, ydd = packed[:, :s], packed[:, s]
    quad, logdet_blocks, n = packed[0, s + 1], packed[1, s + 1], \
        packed[2, s + 1]
    # det lemma: log|Gamma+Lambda| = log|Sdd| - log|Kss| + sum_m log|C_m|
    Sdd_L = linalg.chol(Kss + Sdot)
    logdet = (linalg.logdet_from_chol(Sdd_L)
              - linalg.logdet_from_chol(Kss_L) + logdet_blocks)
    # inv lemma: y(G+L)^{-1}y = y L^{-1} y - ydd^T Sdd^{-1} ydd
    w = linalg.chol_solve(Sdd_L, ydd[:, None])[:, 0]
    quad_total = quad - ydd @ w
    return 0.5 * (quad_total + logdet + n * jnp.log(2 * jnp.pi))


def pitc_nlml(kfn, params, S, X, y, runner: Runner) -> jax.Array:
    Xb, yb = runner.shard_blocks(X), runner.shard_blocks(y)
    fn = lambda Xm, ym, params, S: pitc_nlml_machine(
        kfn, params, S, Xm, ym, axis_name=runner.axis_name)
    vals = runner.map(fn, (Xb, yb), (params, S))
    return vals[0]


def fit(kfn, params, X=None, y=None, *, steps: int = 200, lr: float = 0.05,
        objective=None) -> tuple[dict, jax.Array]:
    """Adam on the (exact, by default) negative log marginal likelihood.

    ``objective`` overrides the data-bound default entirely; (X, y) are
    only consulted — and only then required — when no objective is given,
    so custom-objective callers (fit_parallel) don't thread unused data
    through."""
    if objective is None:
        if X is None or y is None:
            raise ValueError(
                "hyper.fit needs (X, y) for the default exact-NLML "
                "objective; pass data or a custom objective")
        objective = lambda p: gp.nlml(kfn, p, X, y)
    opt = Adam(lr=lr)
    state = opt.init(params)

    @jax.jit
    def step(params, state):
        loss, grads = jax.value_and_grad(objective)(params)
        params, state = opt.update(grads, state, params)
        return params, state, loss

    losses = []
    for _ in range(steps):
        params, state, loss = step(params, state)
        losses.append(loss)
    return params, jnp.stack(losses)


def fit_parallel(kfn, params, S, X, y, runner: Runner, *, steps: int = 200,
                 lr: float = 0.05) -> tuple[dict, jax.Array]:
    """MLE on ALL data via the distributable PITC likelihood. The data is
    bound inside the objective; ``fit`` never sees it (it would only be
    captured by the unused exact-NLML default)."""
    obj = lambda p: pitc_nlml(kfn, p, S, X, y, runner)
    return fit(kfn, params, steps=steps, lr=lr, objective=obj)
