"""pPITC — parallel PITC approximation of FGP (paper Sec. 3, Defs. 1-4).

Per-machine program (runs under VmapRunner or ShardMapRunner):

  Step 1  data arrives block-sharded: machine m holds (D_m, y_{D_m});
  Step 2  local summary  (eqs. 3-4)  — O((|D|/M)^3) local cholesky;
  Step 3  global summary (eqs. 5-6)  — ONE all-reduce of an |S|-vector and an
          |S|x|S| matrix (lax.psum == the master-free assimilation; comm
          O(|S|^2 log M) as in Table 1);
  Step 4  each machine predicts its U_m slice (eqs. 7-8) locally.

Fit/predict split (core/api.py): ``fit`` runs steps 1-3 through a Runner and
caches the S-space factors in an ``api.PITCState`` (Kss_L, Sdd_L,
alpha = Sdd^{-1} ydd); ``predict_batch`` is then O(|U||S| + |S|^2) per query
batch — the real-time path. ``predict`` (legacy one-shot) is a thin wrapper
over the two; ``predict_distributed`` keeps the fully-collective execution
where prediction itself must stay on-device.

Unlike pPIC, pPITC needs no routed serving variant: eqs. (7)-(8) touch only
the global S-space factors, so a query's posterior is already independent of
which machine evaluates it — ``predict_blocks`` is pure layout. The
``GPMethod`` therefore registers with ``predict_routed_diag_fn=None``; a
``GPServer(routed=True)`` rejects it at construction, ``ServePlan.
routed_diag`` raises, and the plain diag path already carries the
invariance routing buys (see ppic.predict_routed for the block-sensitive
case).

Zero prior mean assumed (data pipeline centers y).
"""
from __future__ import annotations

from typing import NamedTuple

import jax
import jax.numpy as jnp

from repro.core import api
from repro.core import covariance as cov
from repro.core import linalg
from repro.core.gp import GPPosterior
from repro.parallel.runner import Runner


class LocalSummary(NamedTuple):
    """(eqs. 3-4) restricted to B = B' = S — what crosses the network."""
    ydot: jax.Array   # (s,)    y-dot_S^m
    Sdot: jax.Array   # (s, s)  Sigma-dot_SS^m


class GlobalSummary(NamedTuple):
    """(eqs. 5-6)."""
    ydd: jax.Array    # (s,)
    Sdd: jax.Array    # (s, s)  ( = K_SS + sum_m Sdot^m )


class ParallelPosterior(NamedTuple):
    """Block posterior: machine m owns mean/cov of its U_m slice."""
    mean: jax.Array      # (u,)
    blocks: jax.Array    # (M, u/M, u/M) diagonal covariance blocks

    @property
    def var(self) -> jax.Array:
        M, b, _ = self.blocks.shape
        return jax.vmap(jnp.diag)(self.blocks).reshape(M * b)

    @property
    def cov(self) -> jax.Array:   # dense block-diagonal view (small U only)
        return jax.scipy.linalg.block_diag(
            *[self.blocks[m] for m in range(self.blocks.shape[0])])


def local_summary(kfn, params, S, Kss_L, Xm, ym):
    """Eqs. (3)-(4) with B=B'=S. Also returns the pieces pPIC/hyper reuse:
    (Ksd, C_L = chol Sigma_{DmDm|S}, Wy = C^{-1} y_m)."""
    Ksd = kfn(params, S, Xm)                          # (s, b)
    V = linalg.tri_solve(Kss_L, Ksd)                  # Kss^{-1/2} K_SD_m
    Kdd = cov.add_noise(kfn(params, Xm, Xm), params)
    C_L = linalg.chol(Kdd - V.T @ V)                  # chol Sigma_{DmDm|S}
    Wy = linalg.chol_solve(C_L, ym[:, None])[:, 0]    # C^{-1}(y - mu)
    ydot = Ksd @ Wy
    Sdot = Ksd @ linalg.chol_solve(C_L, Ksd.T)
    return LocalSummary(ydot, Sdot), (Ksd, C_L, Wy)


def global_summary(kfn, params, S, local: LocalSummary,
                   axis_name) -> GlobalSummary:
    """Eqs. (5)-(6): the single all-reduce of the algorithm."""
    Kss = kfn(params, S, S)
    ydd = jax.lax.psum(local.ydot, axis_name)
    Sdd = Kss + jax.lax.psum(local.Sdot, axis_name)
    return GlobalSummary(ydd, Sdd)


def machine_step(kfn, params, S, Xm, ym, Um, *, axis_name):
    """Full pPITC per-machine program: steps 2-4. Returns (mean_m, cov_m)."""
    Kss_L = linalg.chol(kfn(params, S, S))
    local, _ = local_summary(kfn, params, S, Kss_L, Xm, ym)
    glob = global_summary(kfn, params, S, local, axis_name)
    return predict_from_summary(kfn, params, S, Kss_L, glob, Um)


def predict_from_summary(kfn, params, S, Kss_L, glob: GlobalSummary, Um):
    """Eqs. (7)-(8) — purely local given the global summary."""
    Sdd_L = linalg.chol(glob.Sdd)
    Kus = kfn(params, Um, S)
    mean = Kus @ linalg.chol_solve(Sdd_L, glob.ydd[:, None])[:, 0]
    Kuu = kfn(params, Um, Um)
    covm = Kuu - Kus @ (linalg.chol_solve(Kss_L, Kus.T)
                        - linalg.chol_solve(Sdd_L, Kus.T))
    return mean, covm


# ---------------------------------------------------------------------------
# fit -> PosteriorState -> predict_batch (core/api.py architecture)
# ---------------------------------------------------------------------------

def fit(kfn, params, X, y, *, S, runner: Runner) -> api.PITCState:
    """Steps 1-3 over a Runner, cached as an ``api.PITCState``.

    ``online.SummaryStore`` is the fit-side producer: the same per-machine
    summaries that support streaming assimilation (Sec. 5.2) are assembled
    into the cached S-space factors here, so online updates and cold fits
    share one code path.
    """
    from repro.core import online
    return online.to_state(online.build(kfn, params, S, X, y, runner), S)


def predict_batch(kfn, params, state: api.PITCState, U) -> GPPosterior:
    """Eqs. (7)-(8) from cached factors: O(|U||S| + |S|^2) per call."""
    Kus = kfn(params, U, state.S)
    mean = Kus @ state.alpha
    Kuu = kfn(params, U, U)
    covm = Kuu - Kus @ (linalg.chol_solve(state.Kss_L, Kus.T)
                        - linalg.chol_solve(state.Sdd_L, Kus.T))
    return GPPosterior(mean, covm)


def predict_batch_diag(kfn, params, state: api.PITCState, U):
    """(mean, var) without forming the |U|x|U| posterior covariance.

    The serving hot path: with a ``cov.KernelSpec`` declaring a Pallas
    implementation, the whole computation — K_US tile, both cached
    triangular solves, and the variance quadratic form — collapses into the
    fused ``xcov_diag`` kernel (kernels/rbf/xcov.py) and the (|U|, |S|)
    cross-covariance never round-trips to HBM. The compose path below is
    the math it is validated against (tests/test_xcov_fused.py).
    """
    if isinstance(kfn, cov.KernelSpec) and kfn.fuse(state.S.shape[0]):
        return kfn.fused_diag(params, U, state.S, state.Kss_L, state.alpha,
                              L2=state.Sdd_L)
    Kus = kfn(params, U, state.S)
    mean = Kus @ state.alpha
    A = linalg.chol_solve(state.Kss_L, Kus.T)         # Kss^{-1} K_SU
    B = linalg.chol_solve(state.Sdd_L, Kus.T)         # Sdd^{-1} K_SU
    var = (cov.kdiag(kfn, params, U)
           - jnp.sum(Kus.T * A, axis=0) + jnp.sum(Kus.T * B, axis=0))
    return mean, var


def predict_blocks(kfn, params, state: api.PITCState, U,
                   M: int) -> ParallelPosterior:
    """Per-machine prediction layout (step 4) from the cached state."""
    u = U.shape[0]
    Ub = U.reshape(M, u // M, -1)

    def one(Um):
        Kus = kfn(params, Um, state.S)
        mean = Kus @ state.alpha
        Kuu = kfn(params, Um, Um)
        covm = Kuu - Kus @ (linalg.chol_solve(state.Kss_L, Kus.T)
                            - linalg.chol_solve(state.Sdd_L, Kus.T))
        return mean, covm

    means, covs = jax.vmap(one)(Ub)
    return ParallelPosterior(means.reshape(u), covs)


def predict(kfn, params, S, X, y, U, runner: Runner) -> ParallelPosterior:
    """End-to-end pPITC: thin wrapper over fit + predict_blocks."""
    state = fit(kfn, params, X, y, S=S, runner=runner)
    return predict_blocks(kfn, params, state, U, runner.num_machines)


def predict_distributed(kfn, params, S, X, y, U,
                        runner: Runner) -> ParallelPosterior:
    """Fully-collective pPITC (psum inside the per-machine program) — the
    execution the paper describes; kept for on-device end-to-end runs."""
    Xb, yb, Ub = runner.shard_blocks(X), runner.shard_blocks(y), \
        runner.shard_blocks(U)
    fn = lambda Xm, ym, Um, params, S: machine_step(
        kfn, params, S, Xm, ym, Um, axis_name=runner.axis_name)
    means, covs = runner.map(fn, (Xb, yb, Ub), (params, S))
    return ParallelPosterior(runner.unshard(means), covs)


def summaries(kfn, params, S, X, y, runner: Runner):
    """Stacked per-machine local summaries + the global summary.

    Exposed for online/incremental learning (Sec. 5.2) and fault tolerance:
    the global summary is an algebraic sum, so machine loss/addition is a
    subtraction/addition of cached LocalSummary terms (runtime/fault.py).
    """
    Xb, yb = runner.shard_blocks(X), runner.shard_blocks(y)

    def fn(Xm, ym, params, S):
        Kss_L = linalg.chol(kfn(params, S, S))
        local, _ = local_summary(kfn, params, S, Kss_L, Xm, ym)
        return local

    locals_ = runner.map(fn, (Xb, yb), (params, S))
    Kss = kfn(params, S, S)
    glob = GlobalSummary(jnp.sum(locals_.ydot, 0),
                         Kss + jnp.sum(locals_.Sdot, 0))
    return locals_, glob


def init_store(kfn, params, X, y, *, S, runner: Runner):
    """``api.StateStore`` entry point: the same summaries ``fit`` builds,
    kept mutable via the Sec. 5.2 algebra (online.PITCStore)."""
    from repro.core import online
    return online.init_pitc_store(kfn, params, X, y, S=S, runner=runner)


api.register(api.GPMethod("ppitc", fit, predict_fn=predict_batch,
                          predict_diag_fn=predict_batch_diag,
                          init_store=init_store))
