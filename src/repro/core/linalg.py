"""Shared PSD linear-algebra helpers used across the GP stack.

All solves in this package funnel through these helpers so that jitter policy
and dtype behaviour are uniform (the paper's MPI/LAPACK float64 pipeline maps
onto jax.scipy cholesky solves; equivalence tests run in float64, performance
paths in float32).
"""
from __future__ import annotations

import jax
import jax.numpy as jnp

# Jitter scaled to dtype: float64 paths need far less regularisation.
_JITTER = {jnp.float64.dtype: 1e-10, jnp.float32.dtype: 1e-6}


def default_jitter(dtype) -> float:
    return _JITTER.get(jnp.dtype(dtype), 1e-6)


def add_jitter(K: jax.Array, jitter: float | None = None) -> jax.Array:
    """K + jitter * mean(diag(K)) * I — relative jitter keeps scale-invariance."""
    if jitter is None:
        jitter = default_jitter(K.dtype)
    scale = jnp.mean(jnp.diag(K))
    return K + (jitter * scale) * jnp.eye(K.shape[-1], dtype=K.dtype)


def chol(K: jax.Array, jitter: float | None = None) -> jax.Array:
    """Lower Cholesky factor of a PSD matrix with relative jitter."""
    return jnp.linalg.cholesky(add_jitter(K, jitter))


def chol_solve(L: jax.Array, B: jax.Array) -> jax.Array:
    """Solve (L Lᵀ) X = B given lower Cholesky L."""
    return jax.scipy.linalg.cho_solve((L, True), B)


def psd_solve(K: jax.Array, B: jax.Array, jitter: float | None = None) -> jax.Array:
    """Solve K X = B for PSD K via jittered Cholesky."""
    return chol_solve(chol(K, jitter), B)


def psd_inv(K: jax.Array, jitter: float | None = None) -> jax.Array:
    return psd_solve(K, jnp.eye(K.shape[-1], dtype=K.dtype), jitter)


def tri_solve(L: jax.Array, B: jax.Array, *, lower: bool = True,
              trans: bool = False) -> jax.Array:
    return jax.scipy.linalg.solve_triangular(L, B, lower=lower,
                                             trans=1 if trans else 0)


def logdet_from_chol(L: jax.Array) -> jax.Array:
    return 2.0 * jnp.sum(jnp.log(jnp.diag(L)))
