"""Shared PSD linear-algebra helpers used across the GP stack.

All solves in this package funnel through these helpers so that jitter policy
and dtype behaviour are uniform (the paper's MPI/LAPACK float64 pipeline maps
onto jax.scipy cholesky solves; equivalence tests run in float64, performance
paths in float32).
"""
from __future__ import annotations

import functools

import jax
import jax.numpy as jnp

# Jitter scaled to dtype: float64 paths need far less regularisation.
_JITTER = {jnp.float64.dtype: 1e-10, jnp.float32.dtype: 1e-6}


def default_jitter(dtype) -> float:
    return _JITTER.get(jnp.dtype(dtype), 1e-6)


def add_jitter(K: jax.Array, jitter: float | None = None) -> jax.Array:
    """K + jitter * mean(diag(K)) * I — relative jitter keeps scale-invariance."""
    if jitter is None:
        jitter = default_jitter(K.dtype)
    scale = jnp.mean(jnp.diag(K))
    return K + (jitter * scale) * jnp.eye(K.shape[-1], dtype=K.dtype)


def chol(K: jax.Array, jitter: float | None = None) -> jax.Array:
    """Lower Cholesky factor of a PSD matrix with relative jitter."""
    return jnp.linalg.cholesky(add_jitter(K, jitter))


def chol_solve(L: jax.Array, B: jax.Array) -> jax.Array:
    """Solve (L Lᵀ) X = B given lower Cholesky L."""
    return jax.scipy.linalg.cho_solve((L, True), B)


def chol_solve_right(L: jax.Array, A: jax.Array) -> jax.Array:
    """Solve X (L Lᵀ) = A given lower Cholesky L — i.e. A (L Lᵀ)⁻¹ with A's
    ROWS as the batch axis (= ``chol_solve(L, A.T).T`` mathematically).

    Exists for bitwise row-stability, not speed: serving paths that must be
    batch-composition-invariant bit-for-bit (ppic routed prediction) keep
    the query axis on matrix rows everywhere, because XLA's *batched*
    left-sided triangular solve (and gemms with queries on the column axis)
    pick panel strategies that make a column's float path depend on its
    position and on the total width — row-sided solves and row-major gemms
    do not (tests/test_routing_equivalence.py)."""
    t = jax.lax.linalg.triangular_solve(L, A, left_side=False, lower=True,
                                        transpose_a=True)    # X Lᵀ = A
    return jax.lax.linalg.triangular_solve(L, t, left_side=False, lower=True,
                                           transpose_a=False)  # X L = t


def psd_solve(K: jax.Array, B: jax.Array, jitter: float | None = None) -> jax.Array:
    """Solve K X = B for PSD K via jittered Cholesky."""
    return chol_solve(chol(K, jitter), B)


def psd_inv(K: jax.Array, jitter: float | None = None) -> jax.Array:
    return psd_solve(K, jnp.eye(K.shape[-1], dtype=K.dtype), jitter)


def tri_solve(L: jax.Array, B: jax.Array, *, lower: bool = True,
              trans: bool = False) -> jax.Array:
    return jax.scipy.linalg.solve_triangular(L, B, lower=lower,
                                             trans=1 if trans else 0)


def logdet_from_chol(L: jax.Array) -> jax.Array:
    return 2.0 * jnp.sum(jnp.log(jnp.diag(L)))


# ---------------------------------------------------------------------------
# Rank-1 / rank-b Cholesky updates (paper Sec. 5.2 incremental summaries).
#
# The streaming argument needs chol(A + W Wᵀ) from chol(A) without the O(n³)
# refactorization: one LINPACK-style rotation sweep per update vector is
# O(n²), so folding a b-column factor costs O(n² b). ``sign=-1`` is the
# downdate (machine retirement / summary subtraction); it is well-defined
# only while A - W Wᵀ stays positive definite — exactly the summary-algebra
# guarantee (removing a block's PSD contribution from Sdd never crosses
# K_SS), so no rank-revealing fallback is needed here.
# ---------------------------------------------------------------------------

def _chol_rank1(L: jax.Array, w: jax.Array, sign: float) -> jax.Array:
    """chol(L Lᵀ + sign·w wᵀ) via one sweep of (hyperbolic) rotations."""
    n = L.shape[0]
    idx = jnp.arange(n)

    def body(k, carry):
        L, w = carry
        Lkk, wk = L[k, k], w[k]
        r = jnp.sqrt(jnp.maximum(Lkk * Lkk + sign * wk * wk,
                                 jnp.finfo(L.dtype).tiny))
        c, s = r / Lkk, wk / Lkk
        below = idx > k
        col = jnp.where(below, (L[:, k] + sign * s * w) / c, L[:, k])
        col = col.at[k].set(r)
        w = jnp.where(below, c * w - s * col, w)
        return L.at[:, k].set(col), w

    L, _ = jax.lax.fori_loop(0, n, body, (L, w))
    return L


@jax.jit
def cholupdate(L: jax.Array, w: jax.Array) -> jax.Array:
    """Lower Cholesky of (L Lᵀ + w wᵀ) in O(n²)."""
    return _chol_rank1(L, w, 1.0)


@jax.jit
def choldowndate(L: jax.Array, w: jax.Array) -> jax.Array:
    """Lower Cholesky of (L Lᵀ - w wᵀ) in O(n²); requires the difference to
    remain positive definite (guaranteed when removing a PSD contribution
    that was previously folded in)."""
    return _chol_rank1(L, w, -1.0)


@functools.partial(jax.jit, static_argnames=("sign",))
def chol_update_rank(L: jax.Array, W: jax.Array, *,
                     sign: float = 1.0) -> jax.Array:
    """Lower Cholesky of (L Lᵀ + sign·W Wᵀ) for an (n, b) factor W: b
    sequential rank-1 sweeps, O(n² b) total — the incremental ``to_state``
    path (vs O(n³) refactorization). Jitted (one executable per (n, b)
    shape): the sweeps are sequential scalar-ish work that would otherwise
    pay per-op dispatch on the streaming hot path."""
    def step(L, w):
        return _chol_rank1(L, w, sign), None

    L, _ = jax.lax.scan(step, L, W.T)
    return L
