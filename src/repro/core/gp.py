"""Full (exact) Gaussian process regression — paper Sec. 2, eqs. (1)-(2).

This is FGP: the O(|D|^3) centralized baseline every approximation is measured
against (paper Figs. 1-3).
"""
from __future__ import annotations

from typing import NamedTuple

import jax
import jax.numpy as jnp

from repro.core import covariance as cov
from repro.core import linalg


class GPPosterior(NamedTuple):
    """Predictive Gaussian N(mean, cov); ``var`` is diag(cov)."""
    mean: jax.Array
    cov: jax.Array

    @property
    def var(self) -> jax.Array:
        return jnp.diag(self.cov)


def predict(kfn: cov.KernelFn, params: dict,
            X_train: jax.Array, y_train: jax.Array, X_test: jax.Array,
            mean_fn=None, *, diag_only: bool = False) -> GPPosterior:
    """Eqs. (1)-(2): mu_{U|D}, Sigma_{UU|D} with Sigma_DD including noise."""
    mu_d = _mean(mean_fn, X_train, y_train.dtype)
    mu_u = _mean(mean_fn, X_test, y_train.dtype)

    K_dd = cov.add_noise(kfn(params, X_train, X_train), params)
    K_ud = kfn(params, X_test, X_train)
    L = linalg.chol(K_dd)

    alpha = linalg.chol_solve(L, (y_train - mu_d)[:, None])[:, 0]
    mean = mu_u + K_ud @ alpha

    V = linalg.tri_solve(L, K_ud.T)           # L^{-1} K_du
    if diag_only:
        var = cov.kdiag(kfn, params, X_test) - jnp.sum(V * V, axis=0)
        return GPPosterior(mean, jnp.diag(var))
    K_uu = kfn(params, X_test, X_test)
    return GPPosterior(mean, K_uu - V.T @ V)


def nlml(kfn: cov.KernelFn, params: dict,
         X_train: jax.Array, y_train: jax.Array, mean_fn=None) -> jax.Array:
    """Negative log marginal likelihood -log p(y_D | theta) for MLE."""
    n = X_train.shape[0]
    mu_d = _mean(mean_fn, X_train, y_train.dtype)
    K = cov.add_noise(kfn(params, X_train, X_train), params)
    L = linalg.chol(K)
    r = (y_train - mu_d)[:, None]
    alpha = linalg.chol_solve(L, r)
    return 0.5 * (r.T @ alpha)[0, 0] + 0.5 * linalg.logdet_from_chol(L) \
        + 0.5 * n * jnp.log(2.0 * jnp.pi)


def _mean(mean_fn, X: jax.Array, dtype) -> jax.Array:
    if mean_fn is None:
        return jnp.zeros((X.shape[0],), dtype)
    return mean_fn(X)
