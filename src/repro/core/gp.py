"""Full (exact) Gaussian process regression — paper Sec. 2, eqs. (1)-(2).

This is FGP: the O(|D|^3) centralized baseline every approximation is measured
against (paper Figs. 1-3). Split into ``fit`` (the O(|D|^3) Cholesky, cached
in an ``api.FGPState``) and ``predict_batch`` (O(|U||D|) per query batch);
``predict`` remains as the one-shot wrapper over the two.
"""
from __future__ import annotations

from typing import NamedTuple

import jax
import jax.numpy as jnp

from repro.core import api
from repro.core import covariance as cov
from repro.core import linalg


class GPPosterior(NamedTuple):
    """Predictive Gaussian N(mean, cov); ``var`` is diag(cov)."""
    mean: jax.Array
    cov: jax.Array

    @property
    def var(self) -> jax.Array:
        return jnp.diag(self.cov)


def fit(kfn: cov.KernelFn, params: dict, X_train: jax.Array,
        y_train: jax.Array, **_) -> api.FGPState:
    """Cache chol(K_DD + noise) and its solve against y (zero prior mean)."""
    K_dd = cov.add_noise(kfn(params, X_train, X_train), params)
    L = linalg.chol(K_dd)
    alpha = linalg.chol_solve(L, y_train[:, None])[:, 0]
    return api.FGPState(X_train, L, alpha)


def predict_batch(kfn: cov.KernelFn, params: dict, state: api.FGPState,
                  X_test: jax.Array, *, diag_only: bool = False) -> GPPosterior:
    """Eqs. (1)-(2) from the cached factors: no |D|^3 work per query."""
    K_ud = kfn(params, X_test, state.X)
    mean = K_ud @ state.alpha
    V = linalg.tri_solve(state.L, K_ud.T)     # L^{-1} K_du
    if diag_only:
        var = cov.kdiag(kfn, params, X_test) - jnp.sum(V * V, axis=0)
        return GPPosterior(mean, jnp.diag(var))
    K_uu = kfn(params, X_test, X_test)
    return GPPosterior(mean, K_uu - V.T @ V)


def predict_batch_diag(kfn, params, state: api.FGPState, X_test):
    """(mean, var) vectors — no |U|x|U| intermediates (serving hot path).

    With a Pallas ``cov.KernelSpec`` and a VMEM-resident training factor
    (|D| within the fused residency cap) this is one ``xcov_diag`` dispatch:
    FGP is the L2-less case of the fused serving kernel (var = sig2 - q(L))."""
    if isinstance(kfn, cov.KernelSpec) and kfn.fuse(state.X.shape[0]):
        return kfn.fused_diag(params, X_test, state.X, state.L, state.alpha)
    K_ud = kfn(params, X_test, state.X)
    mean = K_ud @ state.alpha
    V = linalg.tri_solve(state.L, K_ud.T)
    var = cov.kdiag(kfn, params, X_test) - jnp.sum(V * V, axis=0)
    return mean, var


def predict(kfn: cov.KernelFn, params: dict,
            X_train: jax.Array, y_train: jax.Array, X_test: jax.Array,
            mean_fn=None, *, diag_only: bool = False) -> GPPosterior:
    """One-shot eqs. (1)-(2): thin wrapper over fit + predict_batch."""
    if mean_fn is None:
        state = fit(kfn, params, X_train, y_train)
        return predict_batch(kfn, params, state, X_test, diag_only=diag_only)

    # non-zero prior mean: legacy inline path (mean_fn is not state-cacheable)
    mu_d = _mean(mean_fn, X_train, y_train.dtype)
    mu_u = _mean(mean_fn, X_test, y_train.dtype)
    K_dd = cov.add_noise(kfn(params, X_train, X_train), params)
    K_ud = kfn(params, X_test, X_train)
    L = linalg.chol(K_dd)
    alpha = linalg.chol_solve(L, (y_train - mu_d)[:, None])[:, 0]
    mean = mu_u + K_ud @ alpha
    V = linalg.tri_solve(L, K_ud.T)           # L^{-1} K_du
    if diag_only:
        var = cov.kdiag(kfn, params, X_test) - jnp.sum(V * V, axis=0)
        return GPPosterior(mean, jnp.diag(var))
    K_uu = kfn(params, X_test, X_test)
    return GPPosterior(mean, K_uu - V.T @ V)


def nlml(kfn: cov.KernelFn, params: dict,
         X_train: jax.Array, y_train: jax.Array, mean_fn=None) -> jax.Array:
    """Negative log marginal likelihood -log p(y_D | theta) for MLE."""
    n = X_train.shape[0]
    mu_d = _mean(mean_fn, X_train, y_train.dtype)
    K = cov.add_noise(kfn(params, X_train, X_train), params)
    L = linalg.chol(K)
    r = (y_train - mu_d)[:, None]
    alpha = linalg.chol_solve(L, r)
    return 0.5 * (r.T @ alpha)[0, 0] + 0.5 * linalg.logdet_from_chol(L) \
        + 0.5 * n * jnp.log(2.0 * jnp.pi)


def _mean(mean_fn, X: jax.Array, dtype) -> jax.Array:
    if mean_fn is None:
        return jnp.zeros((X.shape[0],), dtype)
    return mean_fn(X)


api.register(api.GPMethod("fgp", fit, predict_fn=predict_batch,
                          predict_diag_fn=predict_batch_diag))
