"""Core library: the paper's contribution (parallel GP regression with
low-rank covariance approximations) as composable JAX modules.

Layout:
  covariance / linalg        kernel functions + PSD solve helpers
  gp                         exact FGP (eqs. 1-2)
  pitc / icf                 centralized counterparts (Thm oracles + Table 1 rows)
  ppitc / ppic / picf        the paper's parallel methods (Secs. 3-4)
  support / clustering       support-set selection + (D_m, U_m) co-clustering
  online                     incremental summary assimilation (Sec. 5.2)
  hyper                      marginal-likelihood hyperparameter MLE
"""
from repro.core import (covariance, gp, icf, linalg, picf, pitc, ppic,  # noqa
                        ppitc)
from repro.core.covariance import init_params, make_kernel  # noqa
from repro.core.gp import GPPosterior  # noqa
from repro.core.ppitc import ParallelPosterior  # noqa
