"""Core library: the paper's contribution (parallel GP regression with
low-rank covariance approximations) as composable JAX modules.

Layout:
  covariance / linalg        kernel functions + PSD solve helpers
  api                        fit -> PosteriorState -> predict_batch registry
  gp                         exact FGP (eqs. 1-2)
  pitc / icf                 centralized counterparts (Thm oracles + Table 1 rows)
  ppitc / ppic / picf        the paper's parallel methods (Secs. 3-4)
  support / clustering       support-set selection + (D_m, U_m) co-clustering
  online                     incremental summary assimilation (Sec. 5.2)
  hyper                      marginal-likelihood hyperparameter MLE

Importing this package populates the method registry (``api.REGISTRY``):
fgp, pitc, pic, ppitc, ppic, picf.
"""
from repro.core import (api, covariance, gp, icf, linalg, picf, pitc,  # noqa
                        ppic, ppitc)
from repro.core.api import FittedGP, fit, get, names  # noqa
from repro.core.covariance import init_params, make_kernel  # noqa
from repro.core.gp import GPPosterior  # noqa
from repro.core.ppitc import ParallelPosterior  # noqa
