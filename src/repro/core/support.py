"""Support-set selection (remark after Def. 2).

Greedy differential-entropy-score selection (Lawrence et al. 2003): repeatedly
add the candidate with the largest posterior variance Sigma_{xx|S}. For a
deterministic kernel this greedy order is *exactly* the pivot order of pivoted
incomplete Cholesky on the candidate kernel matrix (the residual diagonal d
maintained by ICF *is* Sigma_{xx|S}) — so selection costs O(|S|^2 |C|), never
forms K_CC, and the distributed variant reuses the pICF pivot loop.
"""
from __future__ import annotations

import jax
import jax.numpy as jnp

from repro.core import covariance as cov
from repro.core.icf import icf_factor
from repro.parallel.runner import Runner


def select_support(kfn, params, candidates: jax.Array, size: int) -> jax.Array:
    """Centralized greedy selection; returns (size, d) support inputs."""
    fac = icf_factor(kfn, params, candidates, size)
    return candidates[fac.pivots]


def select_support_parallel(kfn, params, candidates: jax.Array, size: int,
                            runner: Runner) -> jax.Array:
    """Distributed greedy selection over machine-sharded candidates.

    Per step: all-reduce argmax of the residual variance, owner broadcasts the
    chosen input (masked psum), everyone rank-1-updates its residual shard.
    Returns the selected inputs (size, d), replicated.
    """
    Cb = runner.shard_blocks(candidates)

    def machine(Cm, params):
        b, dim = Cm.shape
        axis = runner.axis_name
        m_idx = jax.lax.axis_index(axis)
        d0 = cov.kdiag(kfn, params, Cm)
        F0 = jnp.zeros((size, b), d0.dtype) + 0.0 * d0[None, :]
        S0 = jnp.zeros((size, dim), Cm.dtype) + 0.0 * Cm[:1] * 0.0

        def step(i, carry):
            F, d, Ssel = carry
            gmax = jax.lax.all_gather(jnp.max(d), axis)
            owner = jnp.argmax(gmax)
            dp = jnp.max(gmax)
            is_owner = owner == m_idx
            la = jnp.argmax(d)
            xp = jax.lax.psum(jnp.where(is_owner, Cm[la], 0.0), axis)
            fp = jax.lax.psum(jnp.where(is_owner, F[:, la], 0.0), axis)
            col = kfn(params, xp[None], Cm)[0]
            f = (col - F.T @ fp) / jnp.sqrt(jnp.maximum(dp, 1e-30))
            F = jax.lax.dynamic_update_slice_in_dim(F, f[None], i, axis=0)
            d = jnp.maximum(d - f * f, 0.0)
            d = jnp.where(is_owner, d.at[la].set(0.0), d)
            Ssel = jax.lax.dynamic_update_slice_in_dim(Ssel, xp[None], i,
                                                       axis=0)
            return F, d, Ssel

        _, _, Ssel = jax.lax.fori_loop(0, size, step, (F0, d0, S0))
        return Ssel

    stacked = runner.map(machine, (Cb,), (params,))
    return stacked[0]
