"""Gradient compression with error feedback (1-bit-Adam-style int8 variant).

The paper's core systems insight — cross-worker traffic should live in a
compressed/low-rank space (|S|^2 summaries instead of |D|^2 blocks) — applied
to LM data-parallel training: gradients are quantized to int8 (per-tensor
scale) before the data-parallel all-reduce, with the quantization error fed
back into the next step so the bias telescopes away.

Two entry points:
* ``compress_grads``     — numerics simulation under pjit (the implicit
  all-reduce still moves f32; used to validate convergence impact cheaply);
* ``compressed_psum``    — the real thing for the manual-DP (shard_map) path:
  int8 payload over the wire, 4x collective-byte reduction (shows up in the
  dry-run HLO as s8 all-reduces; see EXPERIMENTS.md §Perf).
"""
from __future__ import annotations

from typing import NamedTuple

import jax
import jax.numpy as jnp


class EFState(NamedTuple):
    error: dict            # pytree like grads


def init_ef(params) -> EFState:
    return EFState(jax.tree.map(jnp.zeros_like, params))


def _quantize(x):
    scale = jnp.maximum(jnp.max(jnp.abs(x)), 1e-12) / 127.0
    q = jnp.clip(jnp.round(x / scale), -127, 127).astype(jnp.int8)
    return q, scale


def _dequantize(q, scale):
    return q.astype(jnp.float32) * scale


def compress_grads(grads, ef: EFState):
    """Quantize(+error feedback) each gradient leaf; returns (grads', ef')."""
    def deq_of(g, e):
        corrected = g.astype(jnp.float32) + e
        q, scale = _quantize(corrected)
        return _dequantize(q, scale).astype(g.dtype)

    new_grads = jax.tree.map(deq_of, grads, ef.error)
    new_err = jax.tree.map(
        lambda g, e, d: (g.astype(jnp.float32) + e
                         - d.astype(jnp.float32)).astype(e.dtype),
        grads, ef.error, new_grads)
    return new_grads, EFState(new_err)


def compressed_psum(x, axis_name):
    """int8-payload all-reduce inside shard_map: agree on a shared scale
    (one scalar pmax), quantize, psum(int32), dequantize. Wire bytes:
    1 per element + one scalar — 4x less than f32."""
    absmax = jax.lax.pmax(jnp.max(jnp.abs(x)), axis_name)
    scale = jnp.maximum(absmax, 1e-12) / 127.0
    q = jnp.clip(jnp.round(x / scale), -127, 127).astype(jnp.int8)
    total = jax.lax.psum(q.astype(jnp.int32), axis_name)
    return total.astype(jnp.float32) * scale
