"""Minimal-but-real Adam/AdamW implementation (optax is not vendored here).

Used by (a) GP hyperparameter MLE (core/hyper.py) and (b) the LM training
loop (launch/train.py). Pytree-native, jit/shard_map friendly, supports
global-norm clipping, decoupled weight decay, and schedule callables.
"""
from __future__ import annotations

from typing import Callable, NamedTuple

import jax
import jax.numpy as jnp


class AdamState(NamedTuple):
    step: jax.Array
    mu: dict
    nu: dict


class Adam(NamedTuple):
    lr: float | Callable = 1e-3
    b1: float = 0.9
    b2: float = 0.999
    eps: float = 1e-8
    weight_decay: float = 0.0
    clip_norm: float | None = None

    def init(self, params) -> AdamState:
        z = jax.tree.map(jnp.zeros_like, params)
        return AdamState(jnp.zeros((), jnp.int32), z,
                         jax.tree.map(jnp.zeros_like, params))

    def update(self, grads, state: AdamState, params):
        step = state.step + 1
        if self.clip_norm is not None:
            gnorm = global_norm(grads)
            scale = jnp.minimum(1.0, self.clip_norm / (gnorm + 1e-9))
            grads = jax.tree.map(lambda g: g * scale, grads)
        mu = jax.tree.map(lambda m, g: self.b1 * m + (1 - self.b1) * g,
                          state.mu, grads)
        nu = jax.tree.map(lambda v, g: self.b2 * v + (1 - self.b2) * g * g,
                          state.nu, grads)
        bc1 = 1 - self.b1 ** step.astype(jnp.float32)
        bc2 = 1 - self.b2 ** step.astype(jnp.float32)
        lr = self.lr(step) if callable(self.lr) else self.lr

        def upd(p, m, v):
            u = (m / bc1) / (jnp.sqrt(v / bc2) + self.eps)
            if self.weight_decay:
                u = u + self.weight_decay * p
            return p - lr * u

        new_params = jax.tree.map(upd, params, mu, nu)
        return new_params, AdamState(step, mu, nu)


def global_norm(tree) -> jax.Array:
    leaves = jax.tree.leaves(tree)
    return jnp.sqrt(sum(jnp.sum(jnp.square(l.astype(jnp.float32)))
                        for l in leaves))


def cosine_schedule(peak: float, warmup: int, total: int,
                    floor: float = 0.0) -> Callable:
    def lr(step):
        step = step.astype(jnp.float32)
        warm = peak * step / max(warmup, 1)
        t = jnp.clip((step - warmup) / max(total - warmup, 1), 0.0, 1.0)
        cos = floor + 0.5 * (peak - floor) * (1 + jnp.cos(jnp.pi * t))
        return jnp.where(step < warmup, warm, cos)
    return lr
