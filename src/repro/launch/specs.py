"""ShapeDtypeStruct input stand-ins for every (arch x shape) dry-run cell —
weak-type-correct, shardable, zero device allocation."""
from __future__ import annotations

import jax
import jax.numpy as jnp

from repro.configs.base import ModelConfig
from repro.configs.shapes import SHAPES, ShapeSpec


def train_input_specs(cfg: ModelConfig, shape: ShapeSpec) -> dict:
    B, T = shape.global_batch, shape.seq_len
    specs = {
        "tokens": jax.ShapeDtypeStruct((B, T), jnp.int32),
        "labels": jax.ShapeDtypeStruct((B, T), jnp.int32),
    }
    if cfg.family == "vlm":
        # frontend STUB: precomputed patch embeddings replace token embeds
        specs["inputs_embeds"] = jax.ShapeDtypeStruct((B, T, cfg.d_model),
                                                      jnp.bfloat16)
    if cfg.enc_dec:
        # frontend STUB: precomputed audio frame embeddings
        specs["frames"] = jax.ShapeDtypeStruct((B, cfg.enc_seq, cfg.d_model),
                                               jnp.bfloat16)
    return specs


def decode_input_specs(cfg: ModelConfig, shape: ShapeSpec) -> dict:
    B = shape.global_batch
    return {"token": jax.ShapeDtypeStruct((B, 1), jnp.int32)}


def input_specs(cfg: ModelConfig, shape_name: str) -> dict:
    shape = SHAPES[shape_name]
    if shape.kind == "train" or shape.kind == "prefill":
        return train_input_specs(cfg, shape)
    return decode_input_specs(cfg, shape)
