"""Production mesh construction.

``make_production_mesh`` is a FUNCTION (not a module-level constant) so that
importing this module never touches jax device state — the dry-run sets
XLA_FLAGS before any jax initialization.
"""
from __future__ import annotations

import jax


def make_production_mesh(*, multi_pod: bool = False):
    """16x16 single-pod (256 chips) or 2x16x16 two-pod (512 chips) mesh."""
    shape = (2, 16, 16) if multi_pod else (16, 16)
    axes = ("pod", "data", "model") if multi_pod else ("data", "model")
    return jax.make_mesh(shape, axes)


def make_mesh(shape, axes):
    """Arbitrary mesh (tests / examples)."""
    return jax.make_mesh(tuple(shape), tuple(axes))


def gp_machine_axes(mesh) -> tuple[str, ...]:
    """The paper's M machines = all DP axes of the mesh."""
    return tuple(a for a in ("pod", "data") if a in mesh.shape)
