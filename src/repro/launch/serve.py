"""Sharded serving: batched single-token decode against KV/SSM caches.

decode_32k: batch sharded over DP, KV heads over TP.
long_500k:  batch=1 — the KV cache is sequence-sharded over DP (flash-decode
layout); GSPMD lowers the softmax/PV contractions to all-reduces over the
sequence shards.
"""
from __future__ import annotations

import jax
import jax.numpy as jnp
from jax.sharding import NamedSharding, PartitionSpec as P

from repro.configs.base import ModelConfig
from repro.models import transformer as tf
from repro.models.attention import KVCache
from repro.models.ssm import SSMState
from repro.parallel import sharding as shd


def serve_state_specs(cfg: ModelConfig, mesh, *, batch: int):
    """Mirror pytree of PartitionSpecs for a ServeState."""
    plan, period, n_full, rest = tf._split_plan(cfg)
    d_inner = cfg.ssm_expand * cfg.d_model
    H_ssm = d_inner // cfg.ssm_headdim if cfg.ssm_state else 1

    def cache_specs(desc, stacked):
        if desc.kind == "attn":
            kv = shd.cache_spec(mesh, batch=batch, n_kv=cfg.n_kv_heads,
                                seq=cfg.max_seq, stacked=stacked)
            length = P(None) if stacked else P()
            return KVCache(kv, kv, length)
        s = shd.ssm_state_spec(mesh, batch=batch, n_heads=H_ssm,
                               stacked=stacked)
        dp = shd.dp_axes(mesh)
        dpx = dp if len(dp) > 1 else (dp[0] if dp else None)
        bshard = dpx if (batch > 1 and batch % max(
            1, shd._size(mesh, dpx)) == 0) else None
        conv = (P(None, bshard, None, None) if stacked
                else P(bshard, None, None))
        return SSMState(s, conv)

    stack = tuple(cache_specs(cfg.layer_pattern[pos], True)
                  for pos in range(period)) if n_full else ()
    rest_s = tuple(cache_specs(d, False) for d in rest)
    if not cfg.enc_dec:
        return tf.ServeState(stack, rest_s, None, None)
    # precomputed cross K/V (§Perf): (n_full, B, Hkv, Te, hd) per position
    kvspec = shd.cache_spec(mesh, batch=batch, n_kv=cfg.n_kv_heads,
                            seq=cfg.enc_seq, stacked=True)
    kvspec_r = shd.cache_spec(mesh, batch=batch, n_kv=cfg.n_kv_heads,
                              seq=cfg.enc_seq, stacked=False)
    ckv = (tuple((kvspec, kvspec) for _ in range(period)) if n_full else (),
           tuple((kvspec_r, kvspec_r) for _ in rest))
    return tf.ServeState(stack, rest_s, None, ckv)


def make_serve_step(cfg: ModelConfig, mesh, *, batch: int,
                    attn_impl: str = "jnp", donate: bool = True):
    """Returns jitted decode step: (params, token, state) -> (logits, state)."""
    def step(params, token, state):
        return tf.decode_step(params, token, state, cfg)

    def jitted(params_like):
        pspec = shd.param_specs(params_like, mesh)
        sspec = serve_state_specs(cfg, mesh, batch=batch)
        bspec = shd.batch_spec(mesh)
        return jax.jit(
            step,
            in_shardings=(shd.shardings(pspec, mesh),
                          NamedSharding(mesh, bspec),
                          shd.shardings(sspec, mesh)),
            out_shardings=(NamedSharding(
                mesh, shd.logits_spec(mesh, batch=batch, vocab=cfg.vocab_padded)),
                           shd.shardings(sspec, mesh)),
            donate_argnums=(2,) if donate else ())

    return step, jitted


def prefill_then_decode(params, tokens, cfg: ModelConfig, *, max_len: int,
                        n_decode: int, attn_impl: str = "jnp",
                        temperature: float = 0.0, key=None):
    """Reference generation loop (examples/serving): sequential prefill via
    decode steps (simple, exact), then greedy/temperature sampling."""
    B, T = tokens.shape
    state = tf.init_serve(cfg, B, max_len)
    logits = None
    for t in range(T):
        logits, state = tf.decode_step(params, tokens[:, t:t + 1], state, cfg)
    out = [tokens]
    cur = None
    for i in range(n_decode):
        if temperature > 0.0 and key is not None:
            key, sub = jax.random.split(key)
            cur = jax.random.categorical(
                sub, logits[:, -1] / temperature)[:, None]
        else:
            cur = jnp.argmax(logits[:, -1], axis=-1)[:, None]
        out.append(cur)
        logits, state = tf.decode_step(params, cur, state, cfg)
    return jnp.concatenate(out, axis=1)
