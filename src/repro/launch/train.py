"""Sharded training step builder (pjit/GSPMD path).

Features: FSDP+TP param sharding (parallel/sharding.py), remat over the
layer-period scan, microbatch gradient accumulation, optional int8+error-
feedback gradient compression (numerics-sim under pjit), Adam(W) update,
aux-loss logging. The returned step is jitted with explicit in/out shardings
and donates the state.
"""
from __future__ import annotations

from typing import Any, NamedTuple

import jax
import jax.numpy as jnp
from jax.sharding import NamedSharding, PartitionSpec as P

from repro.configs.base import ModelConfig
from repro.models import transformer as tf
from repro.optim.adam import Adam, AdamState
from repro.optim import compression
from repro.parallel import sharding as shd


class TrainState(NamedTuple):
    params: Any
    opt: AdamState
    ef: Any                # compression.EFState | None
    step: jax.Array


class Metrics(NamedTuple):
    loss: jax.Array
    moe_loss: jax.Array
    dropped: jax.Array
    grad_norm: jax.Array


def init_state(key, cfg: ModelConfig, opt: Adam, *,
               compress: bool = False) -> TrainState:
    params = tf.init_model(key, cfg)
    ef = compression.init_ef(params) if compress else None
    return TrainState(params, opt.init(params), ef,
                      jnp.zeros((), jnp.int32))


def state_specs(state: TrainState, mesh):
    pspec = shd.param_specs(state.params, mesh)
    ef = (compression.EFState(pspec) if state.ef is not None else None)
    return TrainState(pspec, AdamState(P(), pspec, pspec), ef, P())


def _loss(params, batch, cfg: ModelConfig, *, remat, remat_policy,
          attn_impl):
    return tf.lm_loss(
        params, batch.get("tokens"), batch["labels"], cfg,
        enc_kv=batch.get("enc_kv"),
        inputs_embeds=batch.get("inputs_embeds"),
        attn_impl=attn_impl, remat=remat, remat_policy=remat_policy)


def make_train_step(cfg: ModelConfig, mesh, opt: Adam, *,
                    microbatches: int = 1, remat: bool = True,
                    remat_policy=None, compress: bool = False,
                    attn_impl: str = "auto", donate: bool = True):
    """Returns (train_step, jitted_builder). train_step(state, batch) runs
    eagerly (CPU tests, mesh=None); jitted_builder(state) returns the
    sharded/jitted version for the mesh."""

    def loss_fn(params, batch):
        if cfg.enc_dec and "frames" in batch:
            enc_kv = tf.encode(params, batch["frames"], cfg,
                               attn_impl=attn_impl)
            batch = {**batch, "enc_kv": enc_kv}
        return _loss(params, batch, cfg, remat=remat,
                     remat_policy=remat_policy, attn_impl=attn_impl)

    grad_fn = jax.value_and_grad(loss_fn, has_aux=True)

    def train_step(state: TrainState, batch: dict):
        if microbatches == 1:
            (loss, aux), grads = grad_fn(state.params, batch)
        else:
            def mb_slice(b, i):
                return jax.tree.map(
                    lambda x: x.reshape((microbatches,
                                         x.shape[0] // microbatches)
                                        + x.shape[1:])[i], b)

            def acc(carry, i):
                g_acc, l_acc, m_acc, d_acc = carry
                (l, a), g = grad_fn(state.params, mb_slice(batch, i))
                g_acc = jax.tree.map(jnp.add, g_acc, g)
                return (g_acc, l_acc + l, m_acc + a.moe_loss,
                        d_acc + a.dropped), None

            zeros = jax.tree.map(jnp.zeros_like, state.params)
            (grads, loss, moe_l, drop), _ = jax.lax.scan(
                acc, (zeros, 0.0, 0.0, 0.0), jnp.arange(microbatches))
            inv = 1.0 / microbatches
            grads = jax.tree.map(lambda g: g * inv, grads)
            loss, aux = loss * inv, tf.Aux(moe_l * inv, drop * inv)

        ef = state.ef
        if compress and ef is not None:
            grads, ef = compression.compress_grads(grads, ef)

        from repro.optim.adam import global_norm
        gnorm = global_norm(grads)
        params, opt_state = opt.update(grads, state.opt, state.params)
        new_state = TrainState(params, opt_state, ef, state.step + 1)
        return new_state, Metrics(loss, aux.moe_loss, aux.dropped, gnorm)

    def jitted(state: TrainState):
        bspec = shd.batch_spec(mesh)
        sspec = state_specs(state, mesh)
        bshape_spec = {k: bspec for k in _batch_keys(cfg)}
        return jax.jit(
            train_step,
            in_shardings=(shd.shardings(sspec, mesh),
                          shd.shardings(bshape_spec, mesh)),
            out_shardings=(shd.shardings(sspec, mesh),
                           NamedSharding(mesh, P())),
            donate_argnums=(0,) if donate else ())

    return train_step, jitted


def _batch_keys(cfg: ModelConfig):
    keys = ["tokens", "labels"]
    if cfg.family == "vlm":
        keys.append("inputs_embeds")
    if cfg.enc_dec:
        keys.append("frames")
    return keys
