"""Continuous-batching serving scheduler.

Production serving needs more than a decode step: requests arrive at
arbitrary times with different prompt/output lengths, and the batch must be
re-filled as sequences finish (otherwise throughput collapses to the
longest request). This scheduler implements slot-based continuous batching
over the framework's decode_step:

  * a fixed pool of B slots, each holding one in-flight sequence;
  * per-slot KV caches are written at per-slot lengths (the batched cache
    carries a length PER SLOT, not a global scalar);
  * finished slots (EOS or max-tokens) are released and refilled from the
    queue on the next tick — prefill of the new prompt runs via decode
    steps on its slot only (token-level scheduling a la Orca);
  * the whole tick is one jitted call — no host round-trip per token.

This file is host-side orchestration; the device-side per-slot cache
mechanics live in models/attention.py (attend_decode already masks by
per-row position when lengths differ — we exploit q_offset per slot).
"""
from __future__ import annotations

import dataclasses
from collections import deque
from typing import Optional

import jax
import jax.numpy as jnp

from repro.configs.base import ModelConfig
from repro.models import transformer as tf


@dataclasses.dataclass
class Request:
    rid: int
    prompt: list[int]
    max_new: int = 32
    out: list[int] = dataclasses.field(default_factory=list)
    done: bool = False


@dataclasses.dataclass
class _Slot:
    req: Optional[Request] = None
    fed: int = 0                 # prompt tokens already fed

    @property
    def free(self) -> bool:
        return self.req is None


class ContinuousBatcher:
    """Slot-based continuous batching over per-slot decode.

    Uses a per-slot serve state: each slot has its own ServeState of
    batch 1 (stacked host-side); a tick feeds one token per active slot.
    CPU-simple and exactly correct; the TPU-scale variant fuses slots into
    one batched state with per-slot lengths (see DESIGN.md §5/PP note).
    """

    def __init__(self, params, cfg: ModelConfig, *, slots: int = 4,
                 max_len: int = 256, eos_id: int | None = None,
                 greedy: bool = True, seed: int = 0):
        self.params, self.cfg = params, cfg
        self.n_slots, self.max_len = slots, max_len
        self.eos_id, self.greedy = eos_id, greedy
        self.key = jax.random.PRNGKey(seed)
        self.queue: deque[Request] = deque()
        self.slots = [_Slot() for _ in range(slots)]
        self.states = [tf.init_serve(cfg, 1, max_len) for _ in range(slots)]
        self.finished: list[Request] = []
        self._step = jax.jit(
            lambda p, t, s: tf.decode_step(p, t, s, cfg))

    # ------------------------------------------------------------------
    def submit(self, req: Request) -> None:
        self.queue.append(req)

    def _refill(self) -> None:
        for i, slot in enumerate(self.slots):
            if slot.free and self.queue:
                slot.req = self.queue.popleft()
                slot.fed = 0
                self.states[i] = tf.init_serve(self.cfg, 1, self.max_len)

    def _release(self, i: int) -> None:
        self.slots[i].req.done = True
        self.finished.append(self.slots[i].req)
        self.slots[i] = _Slot()

    # ------------------------------------------------------------------
    def tick(self) -> int:
        """One scheduling step: each active slot consumes one token
        (prompt feed or generation). Returns number of active slots."""
        self._refill()
        active = 0
        for i, slot in enumerate(self.slots):
            if slot.free:
                continue
            active += 1
            req = slot.req
            if slot.fed < len(req.prompt):                  # prefill phase
                tok = req.prompt[slot.fed]
                slot.fed += 1
                logits, self.states[i] = self._step(
                    self.params, jnp.asarray([[tok]], jnp.int32),
                    self.states[i])
                if slot.fed == len(req.prompt):
                    self._emit(i, logits)
            else:                                           # decode phase
                tok = req.out[-1]
                logits, self.states[i] = self._step(
                    self.params, jnp.asarray([[tok]], jnp.int32),
                    self.states[i])
                self._emit(i, logits)
            req = self.slots[i].req
            if req is not None and (
                    len(req.out) >= req.max_new
                    or (self.eos_id is not None and req.out
                        and req.out[-1] == self.eos_id)
                    or slot.fed + len(req.out) >= self.max_len - 1):
                self._release(i)
        return active

    def _emit(self, i: int, logits) -> None:
        if self.greedy:
            tok = int(jnp.argmax(logits[0, -1]))
        else:
            self.key, sub = jax.random.split(self.key)
            tok = int(jax.random.categorical(sub, logits[0, -1]))
        self.slots[i].req.out.append(tok)

    # ------------------------------------------------------------------
    def run(self, max_ticks: int = 10_000) -> list[Request]:
        ticks = 0
        while (self.queue or any(not s.free for s in self.slots)) \
                and ticks < max_ticks:
            self.tick()
            ticks += 1
        return self.finished
