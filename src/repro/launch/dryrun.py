import os
os.environ["XLA_FLAGS"] = (os.environ.get("REPRO_XLA_EXTRA", "") +
                           " --xla_force_host_platform_device_count="
                           + os.environ.get("REPRO_DEVICES", "512"))
"""Multi-pod dry-run: lower + compile every (architecture x input-shape x
mesh) cell on placeholder devices, then derive the roofline terms.

MUST be run as its own process (the device-count flag above is set before
any jax import, and only here — tests/benches see the real single device):

    PYTHONPATH=src python -m repro.launch.dryrun --all
    PYTHONPATH=src python -m repro.launch.dryrun --arch qwen3-1.7b \
        --shape train_4k --mesh multi
    PYTHONPATH=src python -m repro.launch.dryrun --gp   # paper-technique cells

Outputs one JSON per cell under experiments/dryrun/ (memory analysis, cost
analysis, collective bytes, roofline terms).
"""
import argparse
import json
import pathlib
import time
import traceback

import jax
import jax.numpy as jnp
from jax.sharding import NamedSharding, PartitionSpec as P

from repro.configs.base import ModelConfig
from repro.configs.registry import ARCH_NAMES, get_config
from repro.configs.shapes import SHAPES, applicable
from repro.launch.mesh import make_production_mesh
from repro.launch import serve as serve_lib
from repro.launch import train as train_lib
from repro.models import transformer as tf
from repro.optim.adam import Adam
from repro.parallel import sharding as shd
from repro.roofline import analysis, hlo_parse

OUT_DIR = pathlib.Path(__file__).resolve().parents[3] / "experiments" / "dryrun"


# ---------------------------------------------------------------------------
# abstract inputs / states
# ---------------------------------------------------------------------------

def batch_sds(cfg: ModelConfig, shape):
    B, T = shape.global_batch, shape.seq_len
    b = {"tokens": jax.ShapeDtypeStruct((B, T), jnp.int32),
         "labels": jax.ShapeDtypeStruct((B, T), jnp.int32)}
    if cfg.family == "vlm":
        b["inputs_embeds"] = jax.ShapeDtypeStruct((B, T, cfg.d_model),
                                                  jnp.bfloat16)
    if cfg.enc_dec:
        b["frames"] = jax.ShapeDtypeStruct((B, cfg.enc_seq, cfg.d_model),
                                           jnp.bfloat16)
    return b


def sharded_param_bytes(tree_sds, specs, mesh) -> float:
    """Per-device bytes of a sharded pytree (analytic)."""
    total = 0.0
    for sds, spec in zip(jax.tree.leaves(tree_sds),
                         jax.tree.leaves(
                             specs, is_leaf=lambda x: isinstance(x, P))):
        n = 1
        for axes in spec:
            if axes is None:
                continue
            for a in (axes,) if isinstance(axes, str) else axes:
                n *= mesh.shape[a]
        total += sds.size * sds.dtype.itemsize / n
    return total


# ---------------------------------------------------------------------------
# FLOP probe: three-point layer solve on unoptimized HLO (scan trip 1 is
# counted exactly; see roofline/analysis.py docstring)
# ---------------------------------------------------------------------------

def _probe_lower(cfg, shape, kind, moe_groups=1, ring_cache=False,
                 last_logits=False):
    B, T = shape.global_batch, shape.seq_len

    if kind == "decode":
        def step(params, token, state):
            logits, st = tf.decode_step(params, token, state, cfg,
                                        moe_groups=min(moe_groups, B) or 1)
            return logits

        def mk():
            p = tf.init_model(jax.random.PRNGKey(0), cfg)
            st = tf.init_serve(cfg, B, T + 8, enc_kv=None,
                               ring_cache=ring_cache)
            if cfg.enc_dec:
                enc_arr = jnp.zeros((B, cfg.enc_seq, cfg.d_model),
                                    jnp.bfloat16)
                st = st._replace(cross_kv=tf.precompute_cross_kv(
                    p, enc_arr, cfg))
            return p, st

        params, state = jax.eval_shape(mk)
        tok = jax.ShapeDtypeStruct((B, 1), jnp.int32)
        return jax.jit(step).lower(params, tok, state)

    batch = batch_sds(cfg, shape)

    def loss_fn(params, batch):
        enc_kv = None
        if cfg.enc_dec:
            enc_kv = tf.encode(params, batch["frames"], cfg, attn_impl="jnp")
        return tf.lm_loss(params, batch.get("tokens"), batch["labels"], cfg,
                          enc_kv=enc_kv,
                          inputs_embeds=batch.get("inputs_embeds"),
                          attn_impl="jnp", moe_groups=moe_groups)[0]

    if kind == "train":
        fn = lambda p, b: jax.grad(loss_fn)(p, b)
    elif last_logits:  # serving prefill: last-position logits only
        def fn(p, b):
            enc_kv = None
            if cfg.enc_dec:
                enc_kv = tf.encode(p, b["frames"], cfg, attn_impl="jnp")
            return tf.forward(p, b.get("tokens"), cfg, enc_kv=enc_kv,
                              inputs_embeds=b.get("inputs_embeds"),
                              attn_impl="jnp", moe_groups=moe_groups,
                              logits_last_only=True)[0]
    else:  # prefill as loss-forward
        fn = lambda p, b: loss_fn(p, b)
    params = jax.eval_shape(lambda: tf.init_model(jax.random.PRNGKey(0), cfg))
    return jax.jit(fn).lower(params, batch)


def probe_flops(cfg: ModelConfig, shape, kind, moe_groups=1,
                ring_cache=False, last_logits=False) -> float:
    period = cfg.period
    plan = cfg.plan()
    n_full = len(plan) // period
    n_rest = len(plan) % period

    def flops_of(n_layers, enc_layers):
        c = cfg.scaled(n_layers=n_layers,
                       enc_layers=enc_layers if cfg.enc_dec else 0)
        lw = _probe_lower(c, shape, kind, moe_groups, ring_cache,
                          last_logits)
        return float((lw.cost_analysis() or {}).get("flops", 0.0))

    e1 = 1 if cfg.enc_dec else 0
    f0 = flops_of(0, e1)
    f1 = flops_of(period, e1)
    total = f0 + n_full * (f1 - f0)
    if n_rest:
        f2 = flops_of(period + n_rest, e1)
        total += f2 - f1
    if cfg.enc_dec and kind != "decode":
        f0e2 = flops_of(0, 2)
        total += (cfg.enc_layers - 1) * (f0e2 - f0)
    return total


# ---------------------------------------------------------------------------
# cell lowering on the production mesh
# ---------------------------------------------------------------------------

def lower_cell(cfg: ModelConfig, shape, mesh, ring_cache: bool = False,
               serve_bf16: bool = False, last_logits: bool = False):
    dp = shd.dp_axes(mesh)
    moe_groups = 1
    for a in dp:
        moe_groups *= mesh.shape[a]
    kind = shape.kind

    if kind == "decode":
        B, T = shape.global_batch, shape.seq_len

        def step(params, token, state):
            return tf.decode_step(params, token, state, cfg,
                                  moe_groups=min(moe_groups, B) or 1)

        # +512 headroom keeps max_len divisible by every DP factor so the
        # sequence-sharded (batch=1) cache layout is valid
        def mk():
            dt = jnp.bfloat16 if serve_bf16 else jnp.float32
            p = tf.init_model(jax.random.PRNGKey(0), cfg, dtype=dt)
            st = tf.init_serve(cfg, B, T + 512, enc_kv=None,
                               ring_cache=ring_cache)
            if cfg.enc_dec:
                enc_arr = jnp.zeros((B, cfg.enc_seq, cfg.d_model),
                                    jnp.bfloat16)
                st = st._replace(cross_kv=tf.precompute_cross_kv(
                    p, enc_arr, cfg))
            return p, st

        params, state = jax.eval_shape(mk)
        tok = jax.ShapeDtypeStruct((B, 1), jnp.int32)
        pspec = shd.param_specs(params, mesh)
        sspec = serve_lib.serve_state_specs(cfg, mesh, batch=B)
        lowered = jax.jit(
            step,
            in_shardings=(shd.shardings(pspec, mesh),
                          NamedSharding(mesh, shd.batch_spec(mesh)
                                        if B > 1 else P()),
                          shd.shardings(sspec, mesh)),
            out_shardings=(NamedSharding(
                mesh, shd.logits_spec(mesh, batch=B, vocab=cfg.vocab_padded)),
                           shd.shardings(sspec, mesh)),
        ).lower(params, tok, state)
        state_bytes = sharded_param_bytes(state, sspec, mesh)
        param_bytes = sharded_param_bytes(params, pspec, mesh)
        return lowered, param_bytes + state_bytes

    batch = batch_sds(cfg, shape)
    params = jax.eval_shape(lambda: tf.init_model(jax.random.PRNGKey(0), cfg))
    use_tp = shd.use_tp_policy(params)
    B = shape.global_batch
    if not use_tp and B % (moe_groups * mesh.shape.get("model", 1)) == 0:
        moe_groups = moe_groups * mesh.shape.get("model", 1)
    pspec = shd.param_specs(params, mesh, use_tp=use_tp)
    bspec = {k: shd.batch_spec(mesh, use_tp=use_tp, batch=B)
             for k in batch}

    def loss_fn(params, batch):
        enc_kv = None
        if cfg.enc_dec:
            enc_kv = tf.encode(params, batch["frames"], cfg, attn_impl="jnp")
        return tf.lm_loss(params, batch.get("tokens"), batch["labels"], cfg,
                          enc_kv=enc_kv,
                          inputs_embeds=batch.get("inputs_embeds"),
                          attn_impl="jnp", remat=True,
                          moe_groups=moe_groups)[0]

    if kind == "train":
        opt = Adam(lr=1e-4)

        def step(params, opt_state, batch):
            loss, grads = jax.value_and_grad(loss_fn)(params, batch)
            new_params, new_opt = opt.update(grads, opt_state, params)
            return loss, new_params, new_opt

        opt_state = jax.eval_shape(lambda: opt.init(params))
        ospec = train_lib.AdamState(P(), pspec, pspec)
        lowered = jax.jit(
            step,
            in_shardings=(shd.shardings(pspec, mesh),
                          shd.shardings(ospec, mesh),
                          shd.shardings(bspec, mesh)),
            out_shardings=(NamedSharding(mesh, P()),
                           shd.shardings(pspec, mesh),
                           shd.shardings(ospec, mesh)),
        ).lower(params, opt_state, batch)
        mem = (sharded_param_bytes(params, pspec, mesh) * 3)  # p + m + v
    else:  # prefill
        if last_logits:
            def prefill_fn(params, batch):
                enc_kv = None
                if cfg.enc_dec:
                    enc_kv = tf.encode(params, batch["frames"], cfg,
                                       attn_impl="jnp")
                return tf.forward(params, batch.get("tokens"), cfg,
                                  enc_kv=enc_kv,
                                  inputs_embeds=batch.get("inputs_embeds"),
                                  attn_impl="jnp", moe_groups=moe_groups,
                                  logits_last_only=True)[0]
            out_sh = NamedSharding(mesh, shd.logits_spec(
                mesh, batch=shape.global_batch, vocab=cfg.vocab_padded))
            lowered = jax.jit(
                prefill_fn,
                in_shardings=(shd.shardings(pspec, mesh),
                              shd.shardings(bspec, mesh)),
                out_shardings=out_sh,
            ).lower(params, batch)
        else:
            lowered = jax.jit(
                loss_fn,
                in_shardings=(shd.shardings(pspec, mesh),
                              shd.shardings(bspec, mesh)),
                out_shardings=NamedSharding(mesh, P()),
            ).lower(params, batch)
        mem = sharded_param_bytes(params, pspec, mesh)
    return lowered, mem


def _make_mesh(mesh_name: str):
    """Production meshes, or tiny test meshes when REPRO_DEVICES is small
    (debugging the cell plumbing without the 512-device compile cost)."""
    n_dev = len(jax.devices())
    multi = mesh_name == "multi"
    if n_dev >= 512:
        return make_production_mesh(multi_pod=multi), (512 if multi else 256)
    if multi:
        shape = (2, 2, n_dev // 4)
        return jax.make_mesh(shape, ("pod", "data", "model")), n_dev
    return jax.make_mesh((2, n_dev // 4), ("data", "model")), n_dev // 2


def run_cell(arch: str, shape_name: str, mesh_name: str, *,
             skip_probe=False, overrides=None,
             ring_cache: bool = False, serve_bf16: bool = False,
             last_logits: bool = False) -> dict:
    cfg = get_config(arch)
    if overrides:
        cfg = cfg.scaled(**overrides)
    shape = SHAPES[shape_name]
    mesh, chips = _make_mesh(mesh_name)
    t0 = time.time()
    lowered, static_bytes = lower_cell(cfg, shape, mesh,
                                       ring_cache=ring_cache,
                                       serve_bf16=serve_bf16,
                                       last_logits=last_logits)
    if serve_bf16:
        static_bytes = static_bytes  # cache dtypes already bf16; params halve

    t_lower = time.time() - t0
    t0 = time.time()
    compiled = lowered.compile()
    t_compile = time.time() - t0
    mem = compiled.memory_analysis()
    mem_info = {}
    for attr in ("argument_size_in_bytes", "output_size_in_bytes",
                 "temp_size_in_bytes", "generated_code_size_in_bytes"):
        mem_info[attr] = getattr(mem, attr, None)
    print(compiled.memory_analysis())
    print({k: v for k, v in (compiled.cost_analysis() or {}).items()
           if k in ("flops", "bytes accessed")})

    dp_prod = 1
    for a in shd.dp_axes(mesh):
        dp_prod *= mesh.shape[a]
    pf = (probe_flops(cfg, shape, shape.kind, moe_groups=dp_prod,
                      ring_cache=ring_cache, last_logits=last_logits)
          if not skip_probe else 0.0)

    class _Probe:  # adapter for analysis.analyze
        def cost_analysis(self):
            return {"flops": pf}

    roof = analysis.analyze(
        arch, shape_name, mesh_name, chips=chips, compiled=compiled,
        probe_lowered=_Probe(), cfg=cfg, shape=shape,
        bytes_per_device=static_bytes, ring_cache=ring_cache,
        param_bytes_each=2.0 if serve_bf16 else 4.0,
        last_logits=last_logits)
    rec = roof.to_json()
    rec.update({"memory_analysis": mem_info, "lower_s": t_lower,
                "compile_s": t_compile,
                "cost_analysis": {k: v for k, v in
                                  (compiled.cost_analysis() or {}).items()
                                  if k in ("flops", "bytes accessed",
                                           "transcendentals")}})
    return rec


# ---------------------------------------------------------------------------
# GP (paper technique) dry-run cells
# ---------------------------------------------------------------------------

def run_gp_cell(method: str, mesh_name: str, *, n=1 << 20, s=2048, u=1 << 15,
                r=2048, d=8) -> dict:
    from repro.core import covariance as cov, ppic, ppitc, picf
    from repro.parallel.runner import ShardMapRunner

    mesh, chips = _make_mesh(mesh_name)
    axes = tuple(mesh.axis_names)
    runner = ShardMapRunner(mesh=mesh, axis_name=axes)
    M = runner.num_machines
    kfn = cov.make_kernel("se")
    params = jax.eval_shape(lambda: cov.init_params(d))
    X = jax.ShapeDtypeStruct((n, d), jnp.float32)
    y = jax.ShapeDtypeStruct((n,), jnp.float32)
    S = jax.ShapeDtypeStruct((s, d), jnp.float32)
    U = jax.ShapeDtypeStruct((u, d), jnp.float32)

    if method == "ppitc":
        fn = lambda p, S, X, y, U: ppitc.predict(kfn, p, S, X, y, U, runner)
        args = (params, S, X, y, U)
    elif method == "ppic":
        fn = lambda p, S, X, y, U: ppic.predict(kfn, p, S, X, y, U, runner)
        args = (params, S, X, y, U)
    else:
        fn = lambda p, X, y, U: picf.predict(kfn, p, X, y, U, r, runner,
                                             shard_u=True)
        args = (params, X, y, U)

    t0 = time.time()
    lowered = jax.jit(fn).lower(*args)
    compiled = lowered.compile()
    t_compile = time.time() - t0
    print(compiled.memory_analysis())
    coll = hlo_parse.collective_bytes(compiled.as_text())
    ca = compiled.cost_analysis() or {}
    return {"method": method, "mesh": mesh_name, "chips": chips, "M": M,
            "n": n, "s": s, "u": u, "r": r,
            "flops": ca.get("flops"), "bytes": ca.get("bytes accessed"),
            "collective": coll, "compile_s": t_compile}


# ---------------------------------------------------------------------------

def main():
    ap = argparse.ArgumentParser()
    ap.add_argument("--arch", default=None)
    ap.add_argument("--shape", default=None)
    ap.add_argument("--mesh", default="single", choices=["single", "multi"])
    ap.add_argument("--all", action="store_true")
    ap.add_argument("--gp", action="store_true")
    ap.add_argument("--skip-probe", action="store_true")
    ap.add_argument("--moe-dispatch", default=None,
                    help="override cfg.moe_dispatch (perf variants)")
    ap.add_argument("--ring-cache", action="store_true",
                    help="ring-buffer windowed KV caches (perf variant)")
    ap.add_argument("--serve-bf16", action="store_true",
                    help="bf16 weights for decode cells (perf variant)")
    ap.add_argument("--prefill-last", action="store_true",
                    help="last-position-only prefill logits (perf variant)")
    ap.add_argument("--suffix", default="",
                    help="output-name suffix for variant cells")
    ap.add_argument("--out", default=str(OUT_DIR))
    args = ap.parse_args()
    overrides = ({"moe_dispatch": args.moe_dispatch}
                 if args.moe_dispatch else None)
    out = pathlib.Path(args.out)
    out.mkdir(parents=True, exist_ok=True)

    def write(name, rec):
        with open(out / f"{name}.json", "w") as f:
            json.dump(rec, f, indent=1)

    if args.gp:
        for method in ("ppitc", "ppic", "picf"):
            for mesh_name in ("single", "multi"):
                name = f"gp_{method}_{mesh_name}"
                try:
                    rec = run_gp_cell(method, mesh_name)
                    rec["status"] = "ok"
                except Exception as e:
                    rec = {"status": "fail", "error": str(e),
                           "trace": traceback.format_exc()}
                print(name, rec.get("status"), flush=True)
                write(name, rec)
        return

    cells = []
    if args.all:
        for a in ARCH_NAMES:
            for sname in SHAPES:
                for mesh_name in ("single", "multi"):
                    cells.append((a, sname, mesh_name))
    else:
        cells.append((args.arch, args.shape, args.mesh))

    for arch, sname, mesh_name in cells:
        name = f"{arch}_{sname}_{mesh_name}{args.suffix}"
        if not applicable(arch, sname):
            write(name, {"status": "skip",
                         "reason": "long_500k needs sub-quadratic attention "
                                   "(DESIGN.md §shape-cell skips)"})
            print(name, "SKIP", flush=True)
            continue
        if (out / f"{name}.json").exists():
            rec = json.load(open(out / f"{name}.json"))
            if rec.get("status") == "ok":
                print(name, "CACHED", flush=True)
                continue
        t0 = time.time()
        try:
            rec = run_cell(arch, sname, mesh_name,
                           skip_probe=args.skip_probe, overrides=overrides,
                           ring_cache=args.ring_cache,
                           serve_bf16=args.serve_bf16,
                           last_logits=args.prefill_last)
            rec["status"] = "ok"
            print(f"{name} OK compile={rec['compile_s']:.1f}s "
                  f"bottleneck={rec['bottleneck']} "
                  f"roofline={rec['roofline_fraction']:.3f}", flush=True)
        except Exception as e:
            rec = {"status": "fail", "error": str(e)[-4000:],
                   "trace": traceback.format_exc()[-8000:]}
            print(name, "FAIL", str(e)[:300], flush=True)
        rec["wall_s"] = time.time() - t0
        write(name, rec)


if __name__ == "__main__":
    main()
