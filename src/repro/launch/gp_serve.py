"""Real-time microbatched GP prediction serving.

The paper's headline claim is that low-rank parallel GPs make *real-time*
prediction possible. The serving-side realization (core/api.py architecture):

* the expensive factors live in a cached ``PosteriorState`` (fit once, or
  streamed via ``online.assimilate``);
* incoming query points are queued and padded to a small set of bucket
  sizes, so ONE jitted ``predict_diag(params, state, U)`` call serves the
  whole microbatch with at most ``len(buckets)`` compilations ever;
* the state is hot-swappable: after ``online.assimilate``/``retire`` the
  new state pytree has the same treedef/shapes (pPITC: |S|-space only), so
  ``swap_state`` changes the posterior under live traffic with zero
  recompilation.

Single-process and synchronous by design — the concurrency story is the
mesh underneath (ShardMapRunner fit) plus XLA async dispatch; what this
layer owns is amortization (never redo O(b^3) work per query) and batching
(never launch per-point kernels). benchmarks/bench_serve_latency.py
quantifies both.
"""
from __future__ import annotations

import dataclasses
from typing import Any, Callable

import jax
import jax.numpy as jnp

from repro.core import api


def default_buckets(max_batch: int, *, min_bucket: int = 8) -> tuple[int, ...]:
    """Powers of two from min_bucket to max_batch (inclusive)."""
    sizes = []
    b = min_bucket
    while b < max_batch:
        sizes.append(b)
        b *= 2
    sizes.append(max_batch)
    return tuple(sizes)


@dataclasses.dataclass
class ServeStats:
    n_requests: int = 0
    n_batches: int = 0
    n_padded_rows: int = 0
    n_state_swaps: int = 0
    n_evicted: int = 0


class GPServer:
    """Microbatching front-end over a ``FittedGP``.

    ``submit`` enqueues query points and returns a ticket; ``flush`` runs one
    jitted predict over the padded queue and resolves every ticket to a
    (mean, var) pair. ``submit`` auto-flushes when the queue reaches
    ``max_batch``. ``predict`` is the synchronous path for a caller-held
    batch (still bucket-padded, still amortized).
    """

    def __init__(self, model: api.FittedGP, *, max_batch: int = 64,
                 buckets: tuple[int, ...] | None = None,
                 max_ready: int = 65536):
        self.model = model
        self.max_batch = max_batch
        self.buckets = tuple(sorted(buckets or default_buckets(max_batch)))
        if self.buckets[-1] < max_batch:
            raise ValueError(f"largest bucket {self.buckets[-1]} < "
                             f"max_batch {max_batch}")
        self.max_ready = max_ready
        self.stats = ServeStats()
        self._queue: list[tuple[int, jax.Array]] = []
        self._ready: dict[int, tuple[jax.Array, jax.Array]] = {}
        self._next_ticket = 0
        method, kfn = model.method, model.kfn
        # params/state are traced arguments: hot-swapping either re-runs the
        # same compiled executable as long as shapes/dtypes are unchanged.
        self._predict_fn: Callable = jax.jit(
            lambda params, state, U: method.predict_diag(kfn, params,
                                                         state, U))

    # -- request path -------------------------------------------------------

    def submit(self, x: jax.Array) -> int:
        """Enqueue one query point (d,); returns a ticket for ``result``."""
        ticket = self._next_ticket
        self._next_ticket += 1
        self._queue.append((ticket, jnp.asarray(x)))
        self.stats.n_requests += 1
        if len(self._queue) >= self.max_batch:
            self.flush()
        return ticket

    @property
    def pending(self) -> int:
        return len(self._queue)

    def flush(self) -> None:
        """Serve the queue with one padded, jitted predict call."""
        if not self._queue:
            return
        tickets = [t for t, _ in self._queue]
        U = jnp.stack([x for _, x in self._queue])
        # predict before clearing: a failing batch (e.g. one malformed
        # point) must not destroy the other pending tickets
        mean, var = self.predict(U)
        self._queue.clear()
        for i, t in enumerate(tickets):
            self._ready[t] = (mean[i], var[i])
        # bound memory against abandoned tickets: evict oldest results
        # (dicts preserve insertion order) beyond max_ready
        while len(self._ready) > self.max_ready:
            dropped = next(iter(self._ready))
            del self._ready[dropped]
            self.stats.n_evicted += 1

    def result(self, ticket: int) -> tuple[jax.Array, jax.Array]:
        """(mean, var) for a ticket; flushes if it is still queued."""
        if ticket not in self._ready:
            self.flush()
        try:
            return self._ready.pop(ticket)
        except KeyError:
            raise KeyError(f"ticket {ticket}: unknown, already collected, "
                           f"or evicted (max_ready={self.max_ready})") \
                from None

    # -- batch path ---------------------------------------------------------

    def predict(self, U: jax.Array) -> tuple[jax.Array, jax.Array]:
        """Bucket-padded (mean, var) over a (u, d) batch of queries."""
        u = U.shape[0]
        bucket = self._bucket_for(u)
        if bucket > u:
            U = jnp.pad(U, [(0, bucket - u)] + [(0, 0)] * (U.ndim - 1))
            self.stats.n_padded_rows += bucket - u
        mean, var = self._predict_fn(self.model.params, self.model.state, U)
        self.stats.n_batches += 1
        return mean[:u], var[:u]

    def _bucket_for(self, u: int) -> int:
        for b in self.buckets:
            if b >= u:
                return b
        # oversized batches round up to a multiple of the largest bucket
        big = self.buckets[-1]
        return -(-u // big) * big

    # -- state hot-swap -----------------------------------------------------

    def swap_state(self, state: Any) -> None:
        """Install a new PosteriorState (after online assimilate/retire).

        Same treedef + leaf shapes -> the jitted executable is reused; a
        changed structure (e.g. pPIC after assimilate grew the block axis)
        triggers exactly one recompile on the next call.
        """
        self.model = self.model.with_state(state)
        self.stats.n_state_swaps += 1
