"""Real-time microbatched GP prediction serving.

The paper's headline claim is that low-rank parallel GPs make *real-time*
prediction possible. The serving-side realization (core/api.py architecture):

* the expensive factors live in a cached ``PosteriorState`` (fit once, or
  streamed through an attached ``api.StateStore``);
* incoming query points are queued and padded to a small set of bucket
  sizes, so ONE jitted ``predict_diag(params, state, U)`` call serves the
  whole microbatch with at most ``len(buckets)`` compilations ever;
* flushes trigger on **size** (queue reaches ``max_batch``) or on **age**
  (oldest pending ticket exceeds ``flush_deadline_ms`` at the next
  ``pump()``), so p99 latency at low arrival rates is bounded by the
  deadline instead of by how long the queue takes to fill;
* flushes dispatch asynchronously: the jitted predict and the per-ticket
  slices are enqueued on the XLA stream and nothing blocks until a ticket
  is actually resolved (``result`` calls ``block_until_ready``), so compute
  overlaps with further submits;
* with ``routed=True`` (pPIC/PIC states carrying block centroids) the flush
  groups queue entries by their nearest-centroid target block before
  padding and serves them through the method's ``predict_routed_diag`` —
  each ticket's posterior is then invariant to what else arrived in the
  same microbatch (Remark 2; tests/test_routing_equivalence.py);
* the state is hot-swappable: after an incremental-store update (or a
  refit) the new state pytree usually has the same treedef/shapes, so
  ``swap_state`` changes the posterior under live traffic with zero
  recompilation (a grown block axis costs exactly one recompile);
* with an attached ``api.StateStore`` the server owns the full streaming
  lifecycle: ``update(X_new, y_new)`` assimilates + hot-swaps,
  ``retire_machine``/``revive_machine`` fold machines out/in, and
  ``checkpoint``/``swap_from_checkpoint`` persist/restore the posterior
  through ``core.serialize`` (versioned npz) — how a serving fleet
  replicates state without re-reading data.

Single-process by design — the concurrency story is the mesh underneath
(ShardMapRunner fit) plus XLA async dispatch; what this layer owns is
amortization (never redo O(b^3) work per query), batching (never launch
per-point kernels), and latency bounding (never hold a ticket past its
deadline). benchmarks/bench_serve_latency.py quantifies all three.
"""
from __future__ import annotations

import dataclasses
import time
from typing import Any, Callable

import jax
import jax.numpy as jnp
import numpy as np

from repro.core import api, clustering, serialize


def default_buckets(max_batch: int, *, min_bucket: int = 8,
                    block_q: int = 1) -> tuple[int, ...]:
    """Powers of two from min_bucket up, capped by max_batch (inclusive),
    each rounded up to a multiple of ``block_q``.

    ``block_q`` is the Pallas serving kernel's query-tile size: emitting
    bucket sizes on tile boundaries means the jitted predict's padded batch
    IS the kernel grid — no second pad inside the kernel dispatch (the
    fused ``xcov_diag`` and the two-bucket routed scatter both consume the
    same alignment). ``GPServer`` passes its tile (f32 sublane 8 by
    default, or the KernelSpec's declared ``block_q``); the bare default 1
    keeps direct calls' ladders ending exactly at max_batch. Powers of two
    >= 8 are already 8-aligned, so the historical ladder is unchanged.

    Deduplicated by construction: a duplicate bucket would compile the same
    executable twice and skew padding stats, so the ladder is squeezed
    through ``dict.fromkeys`` regardless of how the loop, the rounding, and
    the trailing ``max_batch`` append interact (regression-tested
    exhaustively in tests/test_api_state.py)."""
    align = lambda v: -(-v // block_q) * block_q
    sizes = []
    b = min_bucket
    while b < max_batch:
        sizes.append(align(b))
        b *= 2
    sizes.append(align(max_batch))
    return tuple(dict.fromkeys(sizes))


@dataclasses.dataclass
class ServeStats:
    n_requests: int = 0
    n_batches: int = 0
    n_padded_rows: int = 0
    n_state_swaps: int = 0
    n_updates: int = 0        # store-backed assimilate/retire/revive swaps
    n_evicted: int = 0
    # flush-trigger split: what actually drained the queue
    n_size_flushes: int = 0
    n_deadline_flushes: int = 0
    n_manual_flushes: int = 0


class GPServer:
    """Microbatching front-end over a ``FittedGP``.

    ``submit`` enqueues query points and returns a ticket; ``flush`` runs one
    jitted predict over the padded queue and resolves every ticket to a
    (mean, var) pair. The queue drains on three triggers:

    * size     — ``submit`` auto-flushes when the queue reaches ``max_batch``;
    * deadline — when ``flush_deadline_ms`` is set, any ``submit``/``pump``
      that observes the oldest pending ticket older than the deadline flushes
      immediately (call ``pump()`` from the serving loop's idle path);
    * manual   — ``flush()``/``result()`` on a still-queued ticket.

    ``predict`` is the synchronous path for a caller-held batch (still
    bucket-padded, still amortized). ``clock`` is injectable for tests and
    simulation (seconds, monotonic).
    """

    def __init__(self, model: api.FittedGP, *, max_batch: int = 64,
                 buckets: tuple[int, ...] | None = None,
                 max_ready: int = 65536,
                 flush_deadline_ms: float | None = None,
                 routed: bool = False,
                 store: api.StateStore | None = None,
                 block_q: int | None = None,
                 clock: Callable[[], float] = time.monotonic):
        self.model = model
        self.store = store
        self.max_batch = max_batch
        # bucket padding lands on the serving kernel's query-tile boundary:
        # explicit arg > the KernelSpec's declared tile > f32 sublane (8)
        self.block_q = (block_q or getattr(model.kfn, "block_q", None) or 8)
        self.buckets = tuple(sorted(set(
            buckets or default_buckets(max_batch, block_q=self.block_q))))
        if self.buckets[-1] < max_batch:
            raise ValueError(f"largest bucket {self.buckets[-1]} < "
                             f"max_batch {max_batch}")
        self.max_ready = max_ready
        self.flush_deadline_ms = flush_deadline_ms
        self.routed = routed
        self._clock = clock
        self.stats = ServeStats()
        self._queue: list[tuple[int, jax.Array, float]] = []
        self._ready: dict[int, tuple[jax.Array, jax.Array]] = {}
        self._next_ticket = 0
        method, kfn = model.method, model.kfn
        if routed and method.predict_routed_diag is None:
            raise ValueError(
                f"routed=True but method {method.name!r} has no "
                f"predict_routed_diag (needs a state with block centroids, "
                f"e.g. ppic/pic)")
        # params/state are traced arguments: hot-swapping either re-runs the
        # same compiled executable as long as shapes/dtypes are unchanged.
        if routed:
            # thread the serving tile into the routed scatter so its bucket
            # widths land on the same boundary as the bucket ladder (the
            # registry contract: predict_routed_diag accepts tile=)
            diag = method.predict_routed_diag
            tile = self.block_q
            self._predict_fn: Callable = jax.jit(
                lambda params, state, U: diag(kfn, params, state, U,
                                              tile=tile))
        else:
            diag = method.predict_diag
            self._predict_fn = jax.jit(
                lambda params, state, U: diag(kfn, params, state, U))

    # -- request path -------------------------------------------------------

    def submit(self, x: jax.Array) -> int:
        """Enqueue one query point (d,); returns a ticket for ``result``.

        Points are staged host-side (NumPy): microbatch assembly must not
        touch XLA, otherwise every distinct queue length eagerly compiles a
        fresh stack/pad kernel and the one-time compiles show up as serving
        tail latency."""
        ticket = self._next_ticket
        self._next_ticket += 1
        self._queue.append((ticket, np.asarray(x), self._clock()))
        self.stats.n_requests += 1
        if len(self._queue) >= self.max_batch:
            self.flush(trigger="size")
        elif self._deadline_exceeded():
            self.flush(trigger="deadline")
        return ticket

    @property
    def pending(self) -> int:
        return len(self._queue)

    def oldest_age_ms(self) -> float:
        """Age of the oldest pending ticket (0.0 when the queue is empty)."""
        if not self._queue:
            return 0.0
        return (self._clock() - self._queue[0][2]) * 1e3

    def _deadline_exceeded(self) -> bool:
        return (self.flush_deadline_ms is not None and bool(self._queue)
                and self.oldest_age_ms() >= self.flush_deadline_ms)

    def pump(self) -> int:
        """Deadline driver: flush if the oldest pending ticket is past
        ``flush_deadline_ms``. Call from the serving loop whenever idle.
        Returns the number of tickets resolved (0 if nothing was due)."""
        if self._deadline_exceeded():
            return self.flush(trigger="deadline")
        return 0

    def flush(self, *, trigger: str = "manual") -> int:
        """Serve the queue with one padded, jitted predict call.

        Dispatch is asynchronous: the predict call and the per-ticket result
        slices go onto the XLA stream without blocking; the host returns to
        accepting submits immediately and each ticket materializes at
        ``result`` time. Returns the number of tickets resolved.
        """
        if trigger not in ("size", "deadline", "manual"):
            # validate before touching the queue: a bad trigger must not
            # destroy pending tickets after predict but before resolution
            raise ValueError(f"unknown flush trigger {trigger!r}; "
                             f"expected 'size', 'deadline', or 'manual'")
        if not self._queue:
            return 0
        queue = self._queue
        U = np.stack([x for _, x, _ in queue])
        if self.routed:
            # group queue entries by their target block before padding so
            # the device-side scatter sees contiguous per-block runs.
            # Host-side mirror of ppic.route_queries (same centroids, same
            # squared-distance argmin); the routed predict re-derives the
            # assignment on device, so this ordering affects locality only —
            # per-ticket posteriors are identical either way
            # (tests/test_routing_equivalence.py, bitwise).
            a = clustering.nearest_center_np(
                U, np.asarray(self.model.state.centroids))
            order = np.argsort(a, kind="stable")
            queue = [queue[i] for i in order]
            U = U[order]
        tickets = [t for t, _, _ in queue]
        # predict before clearing: a failing batch (e.g. one malformed
        # point) must not destroy the other pending tickets
        mean, var = self.predict(U)
        self._queue.clear()
        field = {"size": "n_size_flushes", "deadline": "n_deadline_flushes",
                 "manual": "n_manual_flushes"}[trigger]
        setattr(self.stats, field, getattr(self.stats, field) + 1)
        for i, t in enumerate(tickets):
            self._ready[t] = (mean[i], var[i])
        # bound memory against abandoned tickets: evict oldest results
        # (dicts preserve insertion order) beyond max_ready
        while len(self._ready) > self.max_ready:
            dropped = next(iter(self._ready))
            del self._ready[dropped]
            self.stats.n_evicted += 1
        return len(tickets)

    def done(self, ticket: int) -> bool:
        """True when a ticket's result is ready to collect without flushing.

        'Ready' means the flush was dispatched — the device values may still
        be in flight; ``result``/``sync`` do the blocking."""
        return ticket in self._ready

    def sync(self) -> None:
        """Block until every already-flushed result has materialized.

        A measurement/shutdown barrier (benchmarks use it to charge real
        flush compute to the clock); normal serving lets ``result`` block
        per ticket instead."""
        jax.block_until_ready(list(self._ready.values()))

    def result(self, ticket: int) -> tuple[jax.Array, jax.Array]:
        """(mean, var) for a ticket; flushes if it is still queued.

        This is the only point the serving layer blocks on the device —
        everything upstream (flushes, slices) was dispatched asynchronously.
        """
        if ticket not in self._ready:
            self.flush()
        try:
            out = self._ready.pop(ticket)
        except KeyError:
            raise KeyError(f"ticket {ticket}: unknown, already collected, "
                           f"or evicted (max_ready={self.max_ready})") \
                from None
        return jax.block_until_ready(out)

    # -- batch path ---------------------------------------------------------

    def predict(self, U: jax.Array) -> tuple[jax.Array, jax.Array]:
        """Bucket-padded (mean, var) over a (u, d) batch of queries.

        Padding happens host-side: a NumPy fill costs nothing, while an
        eager ``jnp.pad`` would compile once per distinct pad width and leak
        compile time into the serving path. The jitted predict (one
        executable per bucket) is the only device dispatch.
        """
        u = U.shape[0]
        bucket = self._bucket_for(u)
        if bucket > u:
            Un = np.asarray(U)
            buf = np.zeros((bucket,) + Un.shape[1:], dtype=Un.dtype)
            buf[:u] = Un
            U = buf
            self.stats.n_padded_rows += bucket - u
        mean, var = self._predict_fn(self.model.params, self.model.state, U)
        self.stats.n_batches += 1
        return mean[:u], var[:u]

    def _bucket_for(self, u: int) -> int:
        for b in self.buckets:
            if b >= u:
                return b
        # oversized batches round up to a multiple of the largest bucket
        big = self.buckets[-1]
        return -(-u // big) * big

    # -- state hot-swap -----------------------------------------------------

    def swap_state(self, state: Any) -> None:
        """Install a new PosteriorState (after online assimilate/retire).

        Same treedef + leaf shapes -> the jitted executable is reused; a
        changed structure (e.g. pPIC after assimilate grew the block axis)
        triggers exactly one recompile on the next call.
        """
        if self.routed and not hasattr(state, "centroids"):
            # fail at swap time, not mid-flush under live traffic
            raise ValueError(
                f"routed server requires a state with block centroids; got "
                f"{type(state).__name__} (a pPITC store emits PITCState — "
                f"stream through a PIC-family store, or serve unrouted)")
        self.model = self.model.with_state(state)
        self.stats.n_state_swaps += 1

    # -- incremental-store lifecycle (api.StateStore protocol) --------------

    def _require_store(self, op: str) -> api.StateStore:
        if self.store is None:
            raise ValueError(
                f"GPServer.{op} needs an attached StateStore — construct "
                f"with GPServer(model, store=api.init_store(...)) or call "
                f"attach_store")
        return self.store

    def attach_store(self, store: api.StateStore) -> None:
        """Attach (or replace) the incremental store backing ``update``."""
        self.store = store

    def _commit(self, store: api.StateStore) -> None:
        """Swap in a mutated store: pending tickets flush FIRST so every
        ticket resolves against the posterior it was submitted under.
        Atomic: ``swap_state`` (and its routed-centroid validation) runs
        before ``self.store`` is reassigned, so a rejected state leaves the
        server on the old store AND the old posterior — a retry won't fold
        the same wave in twice."""
        self.flush()
        self.swap_state(store.to_state())
        self.store = store
        self.stats.n_updates += 1

    def update(self, X_new, y_new) -> None:
        """Assimilate a new data stream and hot-swap the posterior (Sec.
        5.2): O(|S|²·b) store update, zero recompilation when the state
        shapes are unchanged (pPITC) and exactly one recompile when the
        block axis grows (pPIC/pICF)."""
        self._commit(self._require_store("update").assimilate(X_new, y_new))

    def retire_machine(self, machine: int) -> None:
        """Fold a failed/decommissioned machine's contribution out and keep
        serving the (exact) surviving posterior."""
        self._commit(self._require_store("retire_machine").retire(machine))

    def revive_machine(self, machine: int) -> None:
        self._commit(self._require_store("revive_machine").revive(machine))

    # -- checkpoint / restore ----------------------------------------------

    def checkpoint(self, path) -> None:
        """Persist the CURRENT serving state (core.serialize, versioned
        npz). What a replica ships to its peers — states, not data."""
        serialize.save_state(path, self.model.state)

    def swap_from_checkpoint(self, path) -> None:
        """Restore a checkpointed state and hot-swap it under live traffic
        (pending tickets flush against the old state first). The routed
        centroid check of ``swap_state`` applies — a PITC checkpoint cannot
        be swapped into a routed server.

        Any attached store is DETACHED: it describes the pre-restore
        posterior, and a later ``update`` built on it would silently revert
        the restored state. Re-attach a store consistent with the
        checkpoint (``attach_store``) to resume streaming.
        """
        self.flush()
        self.swap_state(serialize.load_state(path))
        self.store = None
