"""Real-time microbatched GP prediction serving.

The paper's headline claim is that low-rank parallel GPs make *real-time*
prediction possible. The serving-side realization (core/api.py two-phase
architecture):

* the expensive factors live in a cached ``PosteriorState`` (fit once, or
  streamed through an attached ``api.StateStore``);
* everything decided PER DEPLOYMENT — kernel spec, query tile, bucket
  ladder, routed dispatch, backend caches, overflow-executable ladder —
  lives in an ``api.ServeSpec``, compiled once into an ``api.ServePlan``
  (``GPMethod.plan``). The server is a thin client: queueing, triggers,
  tickets, and the streaming lifecycle are here; every prediction goes
  through ``plan.diag`` / ``plan.routed_diag``;
* incoming query points are queued and padded to the plan's bucket ladder,
  so ONE jitted dispatch serves the whole microbatch with at most
  ``len(buckets)`` compilations ever;
* flushes trigger on **size** (queue reaches ``max_batch``) or on **age**
  (oldest pending ticket exceeds ``flush_deadline_ms`` at the next
  ``pump()``), so p99 latency at low arrival rates is bounded by the
  deadline instead of by how long the queue takes to fill;
* flushes dispatch asynchronously: the jitted predict and the per-ticket
  slices are enqueued on the XLA stream and nothing blocks until a ticket
  is actually resolved (``result`` calls ``block_until_ready``), so compute
  overlaps with further submits;
* with ``routed=True`` (pPIC/PIC states carrying block centroids) the plan
  routes each flush's staged batch host-side once; that single assignment
  both selects the matching overflow program — balanced flushes run the
  G=0 executable, so the overflow bucket is never even dispatched
  (``ServeStats.n_g0_flushes`` counts them) — and drives the device-side
  scatter, while each ticket's posterior stays invariant to what else
  arrived in the same microbatch (Remark 2;
  tests/test_routing_equivalence.py);
* the state is hot-swappable: after an incremental-store update (or a
  refit) ``swap_state`` REBINDS the plan — same treedef/shapes reuse every
  compiled executable under live traffic with zero recompilation (a grown
  block axis costs exactly one recompile);
* with an attached ``api.StateStore`` the server owns the full streaming
  lifecycle: ``update(X_new, y_new)`` assimilates + hot-swaps,
  ``retire_machine``/``revive_machine`` fold machines out/in, and
  ``checkpoint``/``swap_from_checkpoint`` persist/restore the posterior —
  plus ``checkpoint_store``/``restore_store`` for the store itself
  (``core.serialize``, versioned npz), so a restarted fleet keeps
  assimilating, not just serving.

Single-process by design — the concurrency story is the mesh underneath
(ShardMapRunner fit) plus XLA async dispatch; what this layer owns is
amortization (never redo O(b^3) work per query), batching (never launch
per-point kernels), and latency bounding (never hold a ticket past its
deadline). benchmarks/bench_serve_latency.py quantifies all three.
"""
from __future__ import annotations

import dataclasses
import time
from typing import Any, Callable

import jax
import numpy as np

from repro.core import api, serialize

# the ladder itself is spec-owned now (core/api.py); re-exported for the
# callers that built server ladders directly
default_buckets = api.default_buckets


@dataclasses.dataclass
class ServeStats:
    n_requests: int = 0
    n_batches: int = 0
    n_padded_rows: int = 0
    n_state_swaps: int = 0
    n_updates: int = 0        # store-backed assimilate/retire/revive swaps
    n_evicted: int = 0
    # flush-trigger split: what actually drained the queue
    n_size_flushes: int = 0
    n_deadline_flushes: int = 0
    n_manual_flushes: int = 0
    # routed flushes served by the G=0 executable (no overflow dispatch)
    n_g0_flushes: int = 0


class GPServer:
    """Microbatching front-end over a ``FittedGP`` — a thin client of the
    model's ``ServePlan``.

    ``submit`` enqueues query points and returns a ticket; ``flush`` runs one
    jitted predict over the padded queue and resolves every ticket to a
    (mean, var) pair. The queue drains on three triggers:

    * size     — ``submit`` auto-flushes when the queue reaches ``max_batch``;
    * deadline — when ``flush_deadline_ms`` is set, any ``submit``/``pump``
      that observes the oldest pending ticket older than the deadline flushes
      immediately (call ``pump()`` from the serving loop's idle path);
    * manual   — ``flush()``/``result()`` on a still-queued ticket.

    ``predict`` is the synchronous path for a caller-held batch (still
    bucket-padded, still amortized). ``clock`` is injectable for tests and
    simulation (seconds, monotonic).

    Construction: pass ``spec=api.ServeSpec(...)`` for the full serving
    policy, or the legacy keywords (``max_batch``/``buckets``/``routed``/
    ``block_q``), which assemble a spec. The plan is built once here and
    rebound on every state swap.
    """

    def __init__(self, model: api.FittedGP, *, max_batch: int = 64,
                 buckets: tuple[int, ...] | None = None,
                 max_ready: int = 65536,
                 flush_deadline_ms: float | None = None,
                 routed: bool = False,
                 store: api.StateStore | None = None,
                 block_q: int | None = None,
                 spec: api.ServeSpec | None = None,
                 clock: Callable[[], float] = time.monotonic):
        if spec is None:
            spec = api.ServeSpec(block_q=block_q, max_batch=max_batch,
                                 buckets=buckets, routed=routed)
        else:
            # an explicit spec OWNS the serving policy: a legacy kwarg that
            # disagrees must fail loudly, not be silently dropped (e.g.
            # routed=True alongside a non-routed spec would silently serve
            # the composition-DEPENDENT positional path)
            if routed or buckets is not None or block_q is not None or (
                    max_batch != 64 and (spec.max_batch is not None
                                         or spec.buckets is not None)):
                raise ValueError(
                    "GPServer got both spec= and legacy serving kwargs "
                    "(routed/buckets/block_q/max_batch); declare the "
                    "policy inside api.ServeSpec(...)")
            if spec.max_batch is None and spec.buckets is None:
                # a server NEEDS a finite ladder (identity bucketing would
                # compile one executable per distinct queue length — the
                # tail-latency failure mode microbatching exists to avoid)
                spec = dataclasses.replace(spec, max_batch=max_batch)
        self.spec = spec
        self.model = model
        self.store = store
        # queue threshold: the spec's declared max_batch, else its ladder top
        self.max_batch = (spec.max_batch if spec.max_batch is not None
                          else max(spec.buckets))
        self.routed = spec.routed
        method = model.method
        if self.routed and method.predict_routed_diag_fn is None:
            raise ValueError(
                f"routed=True but method {method.name!r} has no "
                f"predict_routed_diag (needs a state with block centroids, "
                f"e.g. ppic/pic)")
        # phase 1: compile the serving program — through the model's
        # per-spec plan memo, so a server and direct model.predict* calls
        # on the same spec share one executable lineage. params/state are
        # traced arguments of every plan executable, so hot-swapping either
        # re-runs the same compiled code at unchanged shapes/dtypes.
        self.plan = model.plan(spec)
        self.block_q = self.plan.block_q
        self.buckets = self.plan.buckets
        self.max_ready = max_ready
        self.flush_deadline_ms = flush_deadline_ms
        self._clock = clock
        self.stats = ServeStats()
        self._queue: list[tuple[int, jax.Array, float]] = []
        self._ready: dict[int, tuple[jax.Array, jax.Array]] = {}
        self._next_ticket = 0

    # -- request path -------------------------------------------------------

    def submit(self, x: jax.Array) -> int:
        """Enqueue one query point (d,); returns a ticket for ``result``.

        Points are staged host-side (NumPy): microbatch assembly must not
        touch XLA, otherwise every distinct queue length eagerly compiles a
        fresh stack/pad kernel and the one-time compiles show up as serving
        tail latency."""
        ticket = self._next_ticket
        self._next_ticket += 1
        self._queue.append((ticket, np.asarray(x), self._clock()))
        self.stats.n_requests += 1
        if len(self._queue) >= self.max_batch:
            self.flush(trigger="size")
        elif self._deadline_exceeded():
            self.flush(trigger="deadline")
        return ticket

    @property
    def pending(self) -> int:
        return len(self._queue)

    def oldest_age_ms(self) -> float:
        """Age of the oldest pending ticket (0.0 when the queue is empty)."""
        if not self._queue:
            return 0.0
        return (self._clock() - self._queue[0][2]) * 1e3

    def _deadline_exceeded(self) -> bool:
        return (self.flush_deadline_ms is not None and bool(self._queue)
                and self.oldest_age_ms() >= self.flush_deadline_ms)

    def pump(self) -> int:
        """Deadline driver: flush if the oldest pending ticket is past
        ``flush_deadline_ms``. Call from the serving loop whenever idle.
        Returns the number of tickets resolved (0 if nothing was due)."""
        if self._deadline_exceeded():
            return self.flush(trigger="deadline")
        return 0

    def flush(self, *, trigger: str = "manual") -> int:
        """Serve the queue with one padded, jitted plan dispatch.

        Dispatch is asynchronous: the predict call and the per-ticket result
        slices go onto the XLA stream without blocking; the host returns to
        accepting submits immediately and each ticket materializes at
        ``result`` time. Returns the number of tickets resolved.
        """
        if trigger not in ("size", "deadline", "manual"):
            # validate before touching the queue: a bad trigger must not
            # destroy pending tickets after predict but before resolution
            raise ValueError(f"unknown flush trigger {trigger!r}; "
                             f"expected 'size', 'deadline', or 'manual'")
        if not self._queue:
            return 0
        queue = self._queue
        U = np.stack([x for _, x, _ in queue])
        # routed flushes need no pre-grouping here: the plan routes the
        # staged batch host-side ONCE — the same assignment selects the
        # overflow program (balanced flushes run the G=0 executable — lazy
        # overflow dispatch) and drives the device-side scatter, which
        # argsorts by block itself. A second nearest-centroid pass for
        # queue locality would double the host routing cost on the
        # latency-sensitive flush path for no device-side benefit, and
        # per-ticket posteriors are arrival-order-invariant anyway
        # (tests/test_routing_equivalence.py, bitwise).
        tickets = [t for t, _, _ in queue]
        # predict before clearing: a failing batch (e.g. one malformed
        # point) must not destroy the other pending tickets
        mean, var = self.predict(U)
        if self.routed and self.plan.stats.last_g == 0:
            self.stats.n_g0_flushes += 1
        self._queue.clear()
        field = {"size": "n_size_flushes", "deadline": "n_deadline_flushes",
                 "manual": "n_manual_flushes"}[trigger]
        setattr(self.stats, field, getattr(self.stats, field) + 1)
        for i, t in enumerate(tickets):
            self._ready[t] = (mean[i], var[i])
        # bound memory against abandoned tickets: evict oldest results
        # (dicts preserve insertion order) beyond max_ready
        while len(self._ready) > self.max_ready:
            dropped = next(iter(self._ready))
            del self._ready[dropped]
            self.stats.n_evicted += 1
        return len(tickets)

    def done(self, ticket: int) -> bool:
        """True when a ticket's result is ready to collect without flushing.

        'Ready' means the flush was dispatched — the device values may still
        be in flight; ``result``/``sync`` do the blocking."""
        return ticket in self._ready

    def sync(self) -> None:
        """Block until every already-flushed result has materialized.

        A measurement/shutdown barrier (benchmarks use it to charge real
        flush compute to the clock); normal serving lets ``result`` block
        per ticket instead."""
        jax.block_until_ready(list(self._ready.values()))

    def result(self, ticket: int) -> tuple[jax.Array, jax.Array]:
        """(mean, var) for a ticket; flushes if it is still queued.

        This is the only point the serving layer blocks on the device —
        everything upstream (flushes, slices) was dispatched asynchronously.
        """
        if ticket not in self._ready:
            self.flush()
        try:
            out = self._ready.pop(ticket)
        except KeyError:
            raise KeyError(f"ticket {ticket}: unknown, already collected, "
                           f"or evicted (max_ready={self.max_ready})") \
                from None
        return jax.block_until_ready(out)

    # -- batch path ---------------------------------------------------------

    def predict(self, U: jax.Array) -> tuple[jax.Array, jax.Array]:
        """Bucket-padded (mean, var) over a (u, d) batch of queries — one
        plan dispatch (padding, staging, and — for routed plans — the
        occupancy-driven program selection are host-side inside the plan).
        """
        before = self.plan.stats.n_padded_rows
        if self.routed:
            mean, var = self.plan.routed_diag(U)
        else:
            mean, var = self.plan.diag(U)
        self.stats.n_batches += 1
        self.stats.n_padded_rows += self.plan.stats.n_padded_rows - before
        return mean, var

    # -- state hot-swap -----------------------------------------------------

    def swap_state(self, state: Any) -> None:
        """Install a new PosteriorState (after online assimilate/retire).

        The plan is REBOUND, not rebuilt: same treedef + leaf shapes -> every
        jitted executable is reused; a changed structure (e.g. pPIC after
        assimilate grew the block axis) triggers exactly one recompile per
        entry point on the next call.
        """
        if self.routed and not hasattr(state, "centroids"):
            # fail at swap time, not mid-flush under live traffic
            raise ValueError(
                f"routed server requires a state with block centroids; got "
                f"{type(state).__name__} (a pPITC store emits PITCState — "
                f"stream through a PIC-family store, or serve unrouted)")
        # with_state rebinds every memoized plan (ours included), keeping
        # the executable lineage — zero recompiles at unchanged shapes
        self.model = self.model.with_state(state)
        self.plan = self.model.plan(self.spec)
        self.stats.n_state_swaps += 1

    # -- incremental-store lifecycle (api.StateStore protocol) --------------

    def _require_store(self, op: str) -> api.StateStore:
        if self.store is None:
            raise ValueError(
                f"GPServer.{op} needs an attached StateStore — construct "
                f"with GPServer(model, store=api.init_store(...)) or call "
                f"attach_store")
        return self.store

    def attach_store(self, store: api.StateStore) -> None:
        """Attach (or replace) the incremental store backing ``update``."""
        self.store = store

    def _commit(self, store: api.StateStore) -> None:
        """Swap in a mutated store: pending tickets flush FIRST so every
        ticket resolves against the posterior it was submitted under.
        Atomic: ``swap_state`` (and its routed-centroid validation) runs
        before ``self.store`` is reassigned, so a rejected state leaves the
        server on the old store AND the old posterior — a retry won't fold
        the same wave in twice."""
        self.flush()
        self.swap_state(store.to_state())
        self.store = store
        self.stats.n_updates += 1

    def update(self, X_new, y_new) -> None:
        """Assimilate a new data stream and hot-swap the posterior (Sec.
        5.2): O(|S|²·b) store update, zero recompilation when the state
        shapes are unchanged (pPITC) and exactly one recompile when the
        block axis grows (pPIC/pICF)."""
        self._commit(self._require_store("update").assimilate(X_new, y_new))

    def retire_machine(self, machine: int) -> None:
        """Fold a failed/decommissioned machine's contribution out and keep
        serving the (exact) surviving posterior."""
        self._commit(self._require_store("retire_machine").retire(machine))

    def revive_machine(self, machine: int) -> None:
        self._commit(self._require_store("revive_machine").revive(machine))

    # -- checkpoint / restore ----------------------------------------------

    def checkpoint(self, path) -> None:
        """Persist the CURRENT serving state (core.serialize, versioned
        npz). What a replica ships to its peers — states, not data."""
        serialize.save_state(path, self.model.state)

    def swap_from_checkpoint(self, path) -> None:
        """Restore a checkpointed state and hot-swap it under live traffic
        (pending tickets flush against the old state first). The routed
        centroid check of ``swap_state`` applies — a PITC checkpoint cannot
        be swapped into a routed server.

        Any attached store is DETACHED: it describes the pre-restore
        posterior, and a later ``update`` built on it would silently revert
        the restored state. Re-attach a store consistent with the
        checkpoint (``attach_store``) to resume streaming.
        """
        self.flush()
        self.swap_state(serialize.load_state(path))
        self.store = None

    def checkpoint_store(self, path) -> None:
        """Persist the attached ``StateStore`` itself (factors, block
        caches, pivot basis — core.serialize.save_store): unlike a state
        checkpoint, a restarted process that loads this keeps ASSIMILATING,
        not just serving."""
        serialize.save_store(path, self._require_store("checkpoint_store"))

    def restore_store(self, path, *, kfn=None, runner=None) -> None:
        """Load a store checkpoint, attach it, and hot-swap its posterior
        (flushing pending tickets first) — the restarted-fleet resume path.
        ``kfn``/``runner`` override what the checkpoint could not encode
        (see ``core.serialize.load_store``)."""
        store = serialize.load_store(path, kfn=kfn, runner=runner)
        self.flush()
        self.swap_state(store.to_state())
        self.store = store
