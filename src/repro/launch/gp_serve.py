"""Real-time microbatched GP prediction serving — single-tenant front-end.

The paper's headline claim is that low-rank parallel GPs make *real-time*
prediction possible. The serving-side realization (core/api.py two-phase
architecture):

* the expensive factors live in a cached ``PosteriorState`` (fit once, or
  streamed through an attached ``api.StateStore``);
* everything decided PER DEPLOYMENT — kernel spec, query tile, bucket
  ladder, routed dispatch, backend caches, overflow-executable ladder —
  lives in an ``api.ServeSpec``, compiled once into an ``api.ServePlan``
  (``GPMethod.plan``). The server is a thin client: queueing, triggers,
  tickets, and the streaming lifecycle are the runtime's; every prediction
  goes through ``plan.diag`` / ``plan.routed_diag``;
* incoming query points are queued and padded to the plan's bucket ladder,
  so ONE jitted dispatch serves the whole microbatch with at most
  ``len(buckets)`` compilations ever;
* flushes trigger on **size** (queue reaches ``max_batch``) or on **age**
  (oldest pending ticket exceeds ``flush_deadline_ms`` at the next
  ``pump()``), so p99 latency at low arrival rates is bounded by the
  deadline instead of by how long the queue takes to fill;
* flushes dispatch asynchronously: the jitted predict and the per-ticket
  slices are enqueued on the XLA stream and nothing blocks until a ticket
  is actually resolved (``result`` calls ``block_until_ready``), so compute
  overlaps with further submits;
* with ``routed=True`` (pPIC/PIC states carrying block centroids) the plan
  routes each flush's staged batch host-side once; that single assignment
  both selects the matching overflow program — balanced flushes run the
  G=0 executable, so the overflow bucket is never even dispatched
  (``ServeStats.n_g0_flushes`` counts them) — and drives the device-side
  scatter, while each ticket's posterior stays invariant to what else
  arrived in the same microbatch (Remark 2;
  tests/test_routing_equivalence.py);
* the state is hot-swappable: after an incremental-store update (or a
  refit) ``swap_state`` REBINDS the plan — same treedef/shapes reuse every
  compiled executable under live traffic with zero recompilation (a grown
  block axis costs exactly one recompile);
* with an attached ``api.StateStore`` the server owns the full streaming
  lifecycle: ``update(X_new, y_new)`` assimilates + hot-swaps,
  ``retire_machine``/``revive_machine`` fold machines out/in, and
  ``checkpoint``/``swap_from_checkpoint`` persist/restore the posterior —
  plus ``checkpoint_store``/``restore_store`` for the store itself
  (``core.serialize``, versioned npz; the ``ServeSpec`` rides along so a
  restarted fleet member can reconstruct the whole deployment from one
  artifact), so a restarted fleet keeps assimilating, not just serving.

Since the multi-tenant runtime landed (``repro.serving``), ``GPServer`` is
a ONE-TENANT CLIENT of ``serving.TenantScheduler``: the queue, triggers,
tickets, admission hooks, and stats all live in the scheduler/registry; the
server contributes only the single-tenant ergonomics (no tenant_id on any
call) and the store/checkpoint lifecycle. Multi-tenant equivalence rests on
this — serving a tenant through the shared runtime IS serving it through a
GPServer (tests/test_multitenant_serving.py asserts it bitwise).

Single-process by design — the concurrency story is the mesh underneath
(ShardMapRunner fit) plus XLA async dispatch; what this layer owns is
amortization (never redo O(b^3) work per query), batching (never launch
per-point kernels), and latency bounding (never hold a ticket past its
deadline). benchmarks/bench_serve_latency.py quantifies all three.
"""
from __future__ import annotations

import time
from typing import Any, Callable

import jax

from repro.core import api, serialize
from repro.serving import ServeStats, TenantScheduler  # noqa: F401  (ServeStats
# is re-exported: it was defined here before the serving package existed)

# the ladder itself is spec-owned now (core/api.py); re-exported for the
# callers that built server ladders directly
default_buckets = api.default_buckets


class GPServer:
    """Microbatching front-end over a ``FittedGP`` — a thin single-tenant
    client of the shared serving runtime (``repro.serving``).

    ``submit`` enqueues query points and returns a ticket; ``flush`` runs one
    jitted predict over the padded queue and resolves every ticket to a
    (mean, var) pair. The queue drains on three triggers:

    * size     — ``submit`` auto-flushes when the queue reaches ``max_batch``;
    * deadline — when ``flush_deadline_ms`` is set, any ``submit``/``pump``
      that observes the oldest pending ticket older than the deadline flushes
      immediately (call ``pump()`` from the serving loop's idle path);
    * manual   — ``flush()``/``result()`` on a still-queued ticket.

    ``predict`` is the synchronous path for a caller-held batch (still
    bucket-padded, still amortized). ``clock`` is injectable for tests and
    simulation (seconds, monotonic).

    Construction: pass ``spec=api.ServeSpec(...)`` for the full serving
    policy, or the legacy keywords (``max_batch``/``buckets``/``routed``/
    ``block_q``), which assemble a spec. The plan is built once at admission
    and rebound on every state swap.

    ``health=`` (True or a ``serving.HealthPolicy``) opts a routed server
    into self-healing dispatch — per-block latency/finiteness tracking,
    retry with backoff, auto-retire of failing blocks from routing (their
    queries served degraded from the global posterior, flagged via
    ``collect``), and background checkpoint revive. ``chaos=`` (a
    ``serving.FaultPlan``/``FaultInjector``) attaches deterministic fault
    injection for tests and benches. ``sleep`` is the injectable retry
    backoff (virtual-time chaos tests pass a fake).
    """

    _TENANT = "default"

    def __init__(self, model: api.FittedGP, *, max_batch: int = 64,
                 buckets: tuple[int, ...] | None = None,
                 max_ready: int = 65536,
                 flush_deadline_ms: float | None = None,
                 routed: bool = False,
                 store: api.StateStore | None = None,
                 block_q: int | None = None,
                 spec: api.ServeSpec | None = None,
                 clock: Callable[[], float] = time.monotonic,
                 sleep: Callable[[float], None] = time.sleep,
                 health: Any = None,
                 chaos: Any = None):
        if spec is None:
            spec = api.ServeSpec(block_q=block_q, max_batch=max_batch,
                                 buckets=buckets, routed=routed)
        else:
            # an explicit spec OWNS the serving policy: a legacy kwarg that
            # disagrees must fail loudly, not be silently dropped (e.g.
            # routed=True alongside a non-routed spec would silently serve
            # the composition-DEPENDENT positional path)
            if routed or buckets is not None or block_q is not None or (
                    max_batch != 64 and (spec.max_batch is not None
                                         or spec.buckets is not None)):
                raise ValueError(
                    "GPServer got both spec= and legacy serving kwargs "
                    "(routed/buckets/block_q/max_batch); declare the "
                    "policy inside api.ServeSpec(...)")
        self._sched = TenantScheduler(clock=clock, sleep=sleep)
        self._t = self._sched.admit(
            self._TENANT, model, spec, store=store,
            flush_deadline_ms=flush_deadline_ms, max_ready=max_ready,
            max_batch=max_batch, health=health, chaos=chaos)
        self._clock = clock

    # -- tenant-record views (the record is the single source of truth) ------

    @property
    def spec(self) -> api.ServeSpec:
        return self._t.spec

    @property
    def model(self) -> api.FittedGP:
        return self._t.model

    @property
    def plan(self) -> api.ServePlan:
        return self._t.plan

    @property
    def store(self) -> api.StateStore | None:
        return self._t.store

    @property
    def stats(self) -> ServeStats:
        return self._t.stats

    @property
    def routed(self) -> bool:
        return self._t.spec.routed

    @property
    def max_batch(self) -> int:
        return self._t.max_batch

    @property
    def max_ready(self) -> int:
        return self._t.max_ready

    @property
    def block_q(self) -> int:
        return self._t.plan.block_q

    @property
    def buckets(self):
        return self._t.plan.buckets

    @property
    def flush_deadline_ms(self) -> float | None:
        return self._t.flush_deadline_ms

    @flush_deadline_ms.setter
    def flush_deadline_ms(self, value: float | None) -> None:
        self._t.flush_deadline_ms = value

    # -- request path -------------------------------------------------------

    def submit(self, x: jax.Array) -> int:
        """Enqueue one query point (d,); returns a ticket for ``result``.

        Points are staged host-side (NumPy): microbatch assembly must not
        touch XLA, otherwise every distinct queue length eagerly compiles a
        fresh stack/pad kernel and the one-time compiles show up as serving
        tail latency."""
        return self._sched.submit(self._TENANT, x)

    @property
    def pending(self) -> int:
        return self._t.pending

    def oldest_age_ms(self) -> float:
        """Age of the oldest pending ticket (0.0 when the queue is empty)."""
        return self._sched.oldest_age_ms(self._TENANT)

    def pump(self) -> int:
        """Deadline driver: flush if the oldest pending ticket is past
        ``flush_deadline_ms``. Call from the serving loop whenever idle.
        Returns the number of tickets resolved (0 if nothing was due)."""
        return self._sched.pump()

    def flush(self, *, trigger: str = "manual") -> int:
        """Serve the queue with one padded, jitted plan dispatch.

        Dispatch is asynchronous: the predict call and the per-ticket result
        slices go onto the XLA stream without blocking; the host returns to
        accepting submits immediately and each ticket materializes at
        ``result`` time. Returns the number of tickets resolved.
        """
        return self._sched.flush(self._TENANT, trigger=trigger)

    def done(self, ticket: int) -> bool:
        """True when a ticket's result is ready to collect without flushing.

        'Ready' means the flush was dispatched — the device values may still
        be in flight; ``result``/``sync`` do the blocking."""
        return self._sched.done(self._TENANT, ticket)

    def sync(self) -> None:
        """Block until every already-flushed result has materialized.

        A measurement/shutdown barrier (benchmarks use it to charge real
        flush compute to the clock); normal serving lets ``result`` block
        per ticket instead."""
        self._sched.sync(self._TENANT)

    def result(self, ticket: int) -> tuple[jax.Array, jax.Array]:
        """(mean, var) for a ticket; flushes if it is still queued.

        This is the only point the serving layer blocks on the device —
        everything upstream (flushes, slices) was dispatched asynchronously.
        """
        return self._sched.result(self._TENANT, ticket)

    def collect(self, ticket: int):
        """(mean, var, degraded) for a ticket — ``result`` plus the
        per-query degradation flag (True when the query's routed block was
        health-retired and the answer came from the global posterior;
        always False without ``health=``)."""
        return self._sched.collect(self._TENANT, ticket)

    # -- health -------------------------------------------------------------

    @property
    def health(self):
        """The server's ``serving.HealthTracker`` (None without
        ``health=``) — routing mask, per-block ledgers, revive timer."""
        return self._t.health

    def health_snapshot(self) -> dict | None:
        """Export view of per-block health (None without ``health=``)."""
        return None if self._t.health is None else self._t.health.snapshot()

    # -- batch path ---------------------------------------------------------

    def predict(self, U: jax.Array) -> tuple[jax.Array, jax.Array]:
        """Bucket-padded (mean, var) over a (u, d) batch of queries — one
        plan dispatch (padding, staging, and — for routed plans — the
        occupancy-driven program selection are host-side inside the plan).
        """
        return self._sched.predict(self._TENANT, U)

    # -- state hot-swap -----------------------------------------------------

    def swap_state(self, state: Any) -> None:
        """Install a new PosteriorState (after online assimilate/retire).

        The plan is REBOUND, not rebuilt: same treedef + leaf shapes -> every
        jitted executable is reused; a changed structure (e.g. pPIC after
        assimilate grew the block axis) triggers exactly one recompile per
        entry point on the next call. A routed server validates the state
        carries block centroids at swap time, not mid-flush under traffic.
        """
        self._sched.swap_state(self._TENANT, state)

    # -- incremental-store lifecycle (api.StateStore protocol) --------------

    def _require_store(self, op: str) -> api.StateStore:
        if self._t.store is None:
            raise ValueError(
                f"GPServer.{op} needs an attached StateStore — construct "
                f"with GPServer(model, store=api.init_store(...)) or call "
                f"attach_store")
        return self._t.store

    def attach_store(self, store: api.StateStore) -> None:
        """Attach (or replace) the incremental store backing ``update``."""
        self._t.store = store

    def update(self, X_new, y_new) -> None:
        """Assimilate a new data stream and hot-swap the posterior (Sec.
        5.2): O(|S|²·b) store update, zero recompilation when the state
        shapes are unchanged (pPITC) and exactly one recompile when the
        block axis grows (pPIC/pICF). Pending tickets flush first; the
        swap is atomic (``TenantScheduler.commit_store``)."""
        self._sched.commit_store(
            self._TENANT, self._require_store("update").assimilate(X_new,
                                                                   y_new))

    def retire_machine(self, machine: int) -> None:
        """Fold a failed/decommissioned machine's contribution out and keep
        serving the (exact) surviving posterior."""
        self._sched.commit_store(
            self._TENANT, self._require_store("retire_machine").retire(
                machine))

    def revive_machine(self, machine: int) -> None:
        self._sched.commit_store(
            self._TENANT, self._require_store("revive_machine").revive(
                machine))

    # -- checkpoint / restore ----------------------------------------------

    def checkpoint(self, path) -> None:
        """Persist the CURRENT serving state (core.serialize, versioned
        npz). What a replica ships to its peers — states, not data."""
        serialize.save_state(path, self._t.model.state)

    def swap_from_checkpoint(self, path) -> None:
        """Restore a checkpointed state and hot-swap it under live traffic
        (pending tickets flush against the old state first). The routed
        centroid check of ``swap_state`` applies — a PITC checkpoint cannot
        be swapped into a routed server.

        Any attached store is DETACHED: it describes the pre-restore
        posterior, and a later ``update`` built on it would silently revert
        the restored state. Re-attach a store consistent with the
        checkpoint (``attach_store``) to resume streaming.
        """
        self.flush()
        self.swap_state(serialize.load_state(path))
        self._t.store = None

    def checkpoint_store(self, path) -> None:
        """Persist the attached ``StateStore`` itself (factors, block
        caches, pivot basis — core.serialize.save_store) with this server's
        ``ServeSpec`` embedded next to it: unlike a state checkpoint, a
        restarted process that loads this keeps ASSIMILATING, not just
        serving — and a restarted FLEET MEMBER can re-admit the whole
        deployment (store + serving policy) from the one artifact
        (``serving.TenantRegistry.admit_from_checkpoint``)."""
        serialize.save_store(path, self._require_store("checkpoint_store"),
                             spec=self._t.spec)

    def restore_store(self, path, *, kfn=None, runner=None) -> None:
        """Load a store checkpoint, attach it, and hot-swap its posterior
        (flushing pending tickets first) — the restarted-fleet resume path.
        ``kfn``/``runner`` override what the checkpoint could not encode
        (see ``core.serialize.load_store``). The server keeps ITS OWN
        serving spec — the embedded one (if any) exists for fleet
        re-admission, where no live server holds a policy yet."""
        store = serialize.load_store(path, kfn=kfn, runner=runner)
        self.flush()
        self.swap_state(store.to_state())
        self._t.store = store
