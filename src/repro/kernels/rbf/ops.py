"""jit'd public wrapper for the fused RBF covariance kernel.

Handles padding (rows to block multiples, feature dim to a 128 multiple for
MXU alignment), VMEM-aware block-size selection, and the CPU fallback
(interpret mode executes the kernel body in Python — correct but slow, so the
wrapper only routes through Pallas when asked or when on TPU).
"""
from __future__ import annotations

import functools

import jax
import jax.numpy as jnp

from repro.kernels.rbf import ref
from repro.kernels.rbf.rbf import rbf_pallas

_LANE = 128
_VMEM_BUDGET = 8 * 1024 * 1024   # bytes, conservative half of v5e VMEM


def _pad_to(x: jax.Array, axis: int, mult: int) -> jax.Array:
    size = x.shape[axis]
    pad = (-size) % mult
    if pad == 0:
        return x
    widths = [(0, 0)] * x.ndim
    widths[axis] = (0, pad)
    return jnp.pad(x, widths)


def pick_blocks(n: int, m: int, d_padded: int,
                itemsize: int = 4) -> tuple[int, int]:
    """Largest hardware-aligned (block_q, block_k) whose tile working set
    (two input tiles + f32 output tile) fits the VMEM budget."""
    for b in (512, 256, 128):
        bq, bk = min(b, n), min(b, m)
        bytes_needed = (bq + bk) * d_padded * itemsize + bq * bk * 4
        if bytes_needed <= _VMEM_BUDGET:
            return max(bq, 8), max(bk, _LANE)
    return 8, _LANE


@functools.partial(jax.jit, static_argnames=("impl",))
def rbf_covariance(Xq: jax.Array, Xk: jax.Array, sig2, *,
                   impl: str = "auto") -> jax.Array:
    """sig2 * exp(-0.5 ||x-z||^2) over pre-scaled inputs; (n,d),(m,d)->(n,m).

    impl: "auto" (pallas on TPU, jnp elsewhere), "pallas" (compiled),
          "pallas_interpret" (Python-executed kernel body — for validation),
          "jnp" (reference).
    """
    if impl == "auto":
        impl = "pallas" if jax.default_backend() == "tpu" else "jnp"
    if impl == "jnp":
        return ref.rbf_covariance(Xq, Xk, sig2)

    n, d = Xq.shape
    m = Xk.shape[0]
    Xq_p = _pad_to(Xq, 1, _LANE)
    Xk_p = _pad_to(Xk, 1, _LANE)
    bq, bk = pick_blocks(n, m, Xq_p.shape[1], Xq.dtype.itemsize)
    Xq_p = _pad_to(Xq_p, 0, bq)
    Xk_p = _pad_to(Xk_p, 0, bk)
    out = rbf_pallas(Xq_p, Xk_p, sig2, block_q=bq, block_k=bk,
                     interpret=(impl == "pallas_interpret"))
    return out[:n, :m]
