"""jit'd public wrappers for the fused RBF kernels (covariance + serving).

Handles padding (rows to block multiples, feature dim to a 128 multiple for
MXU alignment), VMEM-aware block-size selection, and the CPU fallback
(interpret mode executes the kernel body in Python — correct but slow, so the
wrappers only route through Pallas when asked or when on TPU).
"""
from __future__ import annotations

import functools

import jax
import jax.numpy as jnp

from repro.kernels.rbf import ref
from repro.kernels.rbf.rbf import rbf_pallas
from repro.kernels.rbf.xcov import xcov_diag_pallas

_LANE = 128
_VMEM_BUDGET = 8 * 1024 * 1024   # bytes, conservative half of v5e VMEM
# largest support-set padding the fused serving kernel keeps VMEM-resident:
# two (s_pad, s_pad) f32 Cholesky factors at 1024 are 8 MiB total
MAX_FUSED_RESIDENT = 1024


def _pad_to(x: jax.Array, axis: int, mult: int) -> jax.Array:
    size = x.shape[axis]
    pad = (-size) % mult
    if pad == 0:
        return x
    widths = [(0, 0)] * x.ndim
    widths[axis] = (0, pad)
    return jnp.pad(x, widths)


def pick_blocks(n: int, m: int, d_padded: int,
                itemsize: int = 4) -> tuple[int, int]:
    """Largest hardware-aligned (block_q, block_k) whose tile working set
    (two input tiles + f32 output tile) fits the VMEM budget."""
    for b in (512, 256, 128):
        bq, bk = min(b, n), min(b, m)
        bytes_needed = (bq + bk) * d_padded * itemsize + bq * bk * 4
        if bytes_needed <= _VMEM_BUDGET:
            return max(bq, 8), max(bk, _LANE)
    return 8, _LANE


@functools.partial(jax.jit, static_argnames=("impl",))
def rbf_covariance(Xq: jax.Array, Xk: jax.Array, sig2, *,
                   impl: str = "auto") -> jax.Array:
    """sig2 * exp(-0.5 ||x-z||^2) over pre-scaled inputs; (n,d),(m,d)->(n,m).

    impl: "auto" (pallas on TPU, jnp elsewhere), "pallas" (compiled),
          "pallas_interpret" (Python-executed kernel body — for validation),
          "jnp" (reference).
    """
    if impl == "auto":
        impl = "pallas" if jax.default_backend() == "tpu" else "jnp"
    if impl == "jnp":
        return ref.rbf_covariance(Xq, Xk, sig2)

    n, d = Xq.shape
    m = Xk.shape[0]
    Xq_p = _pad_to(Xq, 1, _LANE)
    Xk_p = _pad_to(Xk, 1, _LANE)
    bq, bk = pick_blocks(n, m, Xq_p.shape[1], Xq.dtype.itemsize)
    Xq_p = _pad_to(Xq_p, 0, bq)
    Xk_p = _pad_to(Xk_p, 0, bk)
    out = rbf_pallas(Xq_p, Xk_p, sig2, block_q=bq, block_k=bk,
                     interpret=(impl == "pallas_interpret"))
    return out[:n, :m]


def pick_serve_block_q(n: int) -> int:
    """Query-tile size for the fused serving kernel at batch size n: the
    largest sublane-aligned power of two not exceeding the (8-aligned) batch,
    so small microbatches pad by < 2x and large ones tile at 256. This is
    what ``launch.gp_serve.default_buckets`` aligns its bucket ladder to
    (serving-shape selection benchmarked in benchmarks/bench_kernels.py)."""
    for b in (256, 128, 64, 32, 16):
        if n >= b:
            return b
    return 8


def _embed_tri_inv(L: jax.Array, s_pad: int) -> jax.Array:
    """(s, s) Cholesky factor -> (s_pad, s_pad) lower-triangular INVERSE,
    embedded in an identity. Materializing L^{-1} here (plain XLA, outside
    the kernel) is what lets the kernel apply the cached solve as an MXU
    gemm — Mosaic cannot lower the triangular_solve primitive in-kernel.
    The unit diagonal of the padding block keeps padded rows inert on the
    masked-to-zero covariance columns."""
    s = L.shape[0]
    Linv = jax.lax.linalg.triangular_solve(
        L, jnp.eye(s, dtype=L.dtype), left_side=True, lower=True)
    if s == s_pad:
        return Linv
    return jnp.eye(s_pad, dtype=L.dtype).at[:s, :s].set(Linv)


@functools.partial(jax.jit, static_argnames=("impl", "block_q"))
def xcov_diag(Xq: jax.Array, Xk: jax.Array, L1: jax.Array, alpha: jax.Array,
              sig2, L2: jax.Array | None = None, *, impl: str = "auto",
              block_q: int | None = None):
    """Fused serving hot path over pre-scaled inputs: (mean, var) of the
    summary-method diag predict (see kernels/rbf/xcov.py) without the
    (n, |S|) HBM round-trip.

    Xq: (n, d) queries, Xk: (s, d) support/training set, L1/L2: (s, s)
    cached lower Cholesky factors (variance = sig2 - q(L1) [+ q(L2)]),
    alpha: (s,) cached weights. impl as in ``rbf_covariance``.
    """
    if impl == "auto":
        impl = "pallas" if jax.default_backend() == "tpu" else "jnp"
    if impl == "jnp":
        return ref.xcov_diag(Xq, Xk, L1, alpha, sig2, L2)

    n, _ = Xq.shape
    s = Xk.shape[0]
    Xq_p = _pad_to(Xq, 1, _LANE)
    Xk_p = _pad_to(Xk, 1, _LANE)
    s_pad = -(-s // _LANE) * _LANE
    if s_pad > MAX_FUSED_RESIDENT:
        raise ValueError(
            f"|S|={s} exceeds the fused kernel's VMEM residency cap "
            f"{MAX_FUSED_RESIDENT}; use the compose path (impl='jnp')")
    Xk_p = _pad_to(Xk_p, 0, s_pad)
    with_l2 = L2 is not None
    L1_p = _embed_tri_inv(L1, s_pad)
    L2_p = _embed_tri_inv(L2, s_pad) if with_l2 else L1_p
    alpha_p = _pad_to(alpha[None, :], 1, s_pad)
    bq = block_q or pick_serve_block_q(n)
    Xq_p = _pad_to(Xq_p, 0, bq)
    mean, var = xcov_diag_pallas(Xq_p, Xk_p, L1_p, L2_p, alpha_p, sig2,
                                 s_valid=s, with_l2=with_l2, block_q=bq,
                                 interpret=(impl == "pallas_interpret"))
    return mean[:n], var[:n]
