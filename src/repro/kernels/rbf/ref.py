"""Pure-jnp oracles for the fused RBF kernels (covariance + serving diag)."""
from __future__ import annotations

import jax
import jax.numpy as jnp


def rbf_covariance(Xq: jax.Array, Xk: jax.Array, sig2) -> jax.Array:
    """sig2 * exp(-0.5 ||x - z||^2) for pre-lengthscale-scaled inputs.

    Xq: (n, d), Xk: (m, d) -> (n, m). Accumulates in float32 regardless of
    input dtype (matches the kernel's MXU accumulation).
    """
    Xq32 = Xq.astype(jnp.float32)
    Xk32 = Xk.astype(jnp.float32)
    q2 = jnp.sum(Xq32 * Xq32, axis=-1)[:, None]
    k2 = jnp.sum(Xk32 * Xk32, axis=-1)[None, :]
    cross = Xq32 @ Xk32.T
    d2 = jnp.maximum(q2 + k2 - 2.0 * cross, 0.0)
    out = jnp.asarray(sig2, jnp.float32) * jnp.exp(-0.5 * d2)
    return out.astype(Xq.dtype)


def xcov_diag(Xq: jax.Array, Xk: jax.Array, L1: jax.Array, alpha: jax.Array,
              sig2, L2: jax.Array | None = None):
    """Compose-path oracle for the fused serving kernel (xcov.py).

    Builds K_US dense, applies the cached triangular solves, reduces the
    variance quadratic form — the exact math ``ppitc.predict_batch_diag``
    (L2 = chol Sdd) and ``gp.predict_batch_diag`` (L2 = None) perform, over
    pre-lengthscale-scaled inputs. Accumulates in f32 for <=f32 inputs and
    f64 for f64, matching the kernel's accumulation dtype.
    """
    acc = jnp.float64 if Xq.dtype == jnp.float64 else jnp.float32
    Xqa, Xka = Xq.astype(acc), Xk.astype(acc)
    q2 = jnp.sum(Xqa * Xqa, axis=-1)[:, None]
    k2 = jnp.sum(Xka * Xka, axis=-1)[None, :]
    d2 = jnp.maximum(q2 + k2 - 2.0 * (Xqa @ Xka.T), 0.0)
    sig2 = jnp.asarray(sig2, acc)
    kus = sig2 * jnp.exp(-0.5 * d2)                    # (n, s)
    mean = jnp.sum(kus * alpha.astype(acc)[None, :], axis=1)
    v1 = jax.lax.linalg.triangular_solve(
        L1.astype(acc), kus, left_side=False, lower=True, transpose_a=True)
    var = sig2 - jnp.sum(v1 * v1, axis=1)
    if L2 is not None:
        v2 = jax.lax.linalg.triangular_solve(
            L2.astype(acc), kus, left_side=False, lower=True,
            transpose_a=True)
        var = var + jnp.sum(v2 * v2, axis=1)
    return mean.astype(Xq.dtype), var.astype(Xq.dtype)
