"""Pure-jnp oracle for the fused RBF covariance kernel."""
from __future__ import annotations

import jax
import jax.numpy as jnp


def rbf_covariance(Xq: jax.Array, Xk: jax.Array, sig2) -> jax.Array:
    """sig2 * exp(-0.5 ||x - z||^2) for pre-lengthscale-scaled inputs.

    Xq: (n, d), Xk: (m, d) -> (n, m). Accumulates in float32 regardless of
    input dtype (matches the kernel's MXU accumulation).
    """
    Xq32 = Xq.astype(jnp.float32)
    Xk32 = Xk.astype(jnp.float32)
    q2 = jnp.sum(Xq32 * Xq32, axis=-1)[:, None]
    k2 = jnp.sum(Xk32 * Xk32, axis=-1)[None, :]
    cross = Xq32 @ Xk32.T
    d2 = jnp.maximum(q2 + k2 - 2.0 * cross, 0.0)
    out = jnp.asarray(sig2, jnp.float32) * jnp.exp(-0.5 * d2)
    return out.astype(Xq.dtype)
