"""Pallas TPU kernel: fused pairwise-squared-distance + exp (RBF covariance).

This is the dominant FLOP producer of the paper's local-summary construction
(K_SD_m, diagonal blocks of K_D_mD_m, K_UD_m): a GEMM-shaped cross term plus
elementwise exp, fused so the (n x m) distance matrix never round-trips to
HBM.

TPU mapping:
  * grid (n/bq, m/bk); each program owns a (bq, bk) output tile in VMEM.
  * inputs arrive as (bq, d) / (bk, d) VMEM tiles — ops.py pads d to a
    multiple of 128 so the cross term runs on the MXU with aligned tiles
    (zero-padding feature dims does not change distances).
  * cross = Xq @ Xk^T on the MXU (f32 accumulation), norms + exp on the VPU.
  * arithmetic intensity ~ d/2 FLOPs per output byte for the GEMM part plus
    the transcendental; with bq=bk=256 the tile working set is
    (bq+bk)*d + bq*bk floats — ops.py picks block sizes to stay under ~8 MiB
    of VMEM.

Validated against ref.py in interpret mode (tests/test_kernels.py sweeps
shapes and dtypes).
"""
from __future__ import annotations

import functools

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl


def _rbf_kernel(sig2_ref, xq_ref, xk_ref, out_ref):
    xq = xq_ref[...].astype(jnp.float32)          # (bq, d)
    xk = xk_ref[...].astype(jnp.float32)          # (bk, d)
    # MXU: cross terms; VPU: norms + exp
    cross = jax.lax.dot_general(
        xq, xk, (((1,), (1,)), ((), ())),
        preferred_element_type=jnp.float32)       # (bq, bk)
    q2 = jnp.sum(xq * xq, axis=-1)[:, None]
    k2 = jnp.sum(xk * xk, axis=-1)[None, :]
    d2 = jnp.maximum(q2 + k2 - 2.0 * cross, 0.0)
    out_ref[...] = (sig2_ref[0, 0] * jnp.exp(-0.5 * d2)).astype(out_ref.dtype)


@functools.partial(jax.jit, static_argnames=("block_q", "block_k",
                                             "interpret"))
def rbf_pallas(Xq: jax.Array, Xk: jax.Array, sig2: jax.Array, *,
               block_q: int = 256, block_k: int = 256,
               interpret: bool = False) -> jax.Array:
    """Tiled fused RBF covariance. Caller guarantees n % block_q == 0,
    m % block_k == 0 (ops.py pads)."""
    n, d = Xq.shape
    m, _ = Xk.shape
    grid = (n // block_q, m // block_k)
    sig2 = jnp.asarray(sig2, jnp.float32).reshape(1, 1)
    return pl.pallas_call(
        _rbf_kernel,
        grid=grid,
        in_specs=[
            pl.BlockSpec((1, 1), lambda i, j: (0, 0)),          # sig2
            pl.BlockSpec((block_q, d), lambda i, j: (i, 0)),    # Xq tile
            pl.BlockSpec((block_k, d), lambda i, j: (j, 0)),    # Xk tile
        ],
        out_specs=pl.BlockSpec((block_q, block_k), lambda i, j: (i, j)),
        out_shape=jax.ShapeDtypeStruct((n, m), Xq.dtype),
        interpret=interpret,
    )(sig2, Xq, Xk)
