"""Pallas TPU kernel: fused serving hot path — cross-covariance tile +
cached triangular solves + predictive-variance quadratic form in one pass.

``predict_batch_diag`` for the summary methods (eqs. 7-8) is, per query
batch U against the cached state:

    K_US  = sig2 * exp(-0.5 ||u - s||^2)              (bq, |S|) tile
    mean  = K_US @ alpha
    var   = sig2 - ||L1^{-1} K_SU||^2_cols + ||L2^{-1} K_SU||^2_cols

with L1 = chol K_SS and L2 = chol Sdd (FGP drops the L2 term). The XLA
compose path materializes K_US in HBM and reads it back for each solve; this
kernel keeps the (bq, |S|) tile in VMEM end to end — covariance assembly on
the MXU, the cached triangular solves applied on-tile, and the
quadratic-form reduction on the VPU — so the |U| x |S| intermediate never
round-trips to HBM.

The solve realization: Mosaic has no lowering for the ``triangular_solve``
primitive, so the kernel must not call it. Instead ops.py applies the cached
solve by materializing the triangular INVERSES L^{-1} once per dispatch
(plain XLA, outside the kernel — O(|S|³) against the cached factors, dwarfed
by the O(|U||S|²) quadratic form it feeds) and the kernel computes
``V = K_US L^{-T}`` as an MXU gemm against the VMEM-resident inverse:
mathematically the cached triangular solve, realized as the matmul the MXU
can actually run. Both factors stay VMEM-resident across the whole query
grid (ops.py caps |S|_pad at 1024 to bound that residency at ~8 MiB f32).

TPU mapping:
  * grid (n/bq,): each program owns one (bq,) slice of (mean, var); the
    support set, both inverse factors, and alpha are resident;
  * accumulation dtype follows the input: f32 for f32/bf16 inputs (MXU
    accumulation), f64 for f64 — the float64 equivalence gate
    (tests/test_xcov_fused.py) runs the same kernel body in interpret mode.

Padding contract (ops.py): feature dim to a lane multiple, support rows to a
lane multiple with the inverse factors embedded in an identity (a unit
diagonal keeps padded rows inert on zeroed covariance columns), alpha
zero-padded, query rows to a block_q multiple. Padded support columns of the
covariance tile are masked to zero in-kernel against the STATIC valid count,
so they contribute nothing to mean or variance.
"""
from __future__ import annotations

import functools

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl


def _xcov_diag_kernel(sig2_ref, xq_ref, xk_ref, l1inv_ref, l2inv_ref,
                      alpha_ref, mean_ref, var_ref, *, s_valid: int,
                      with_l2: bool, acc_dtype):
    xq = xq_ref[...].astype(acc_dtype)                 # (bq, d)
    xk = xk_ref[...].astype(acc_dtype)                 # (s_pad, d)
    sig2 = sig2_ref[0, 0].astype(acc_dtype)
    # MXU: cross term; VPU: norms + exp (fused RBF, see rbf.py)
    cross = jax.lax.dot_general(
        xq, xk, (((1,), (1,)), ((), ())),
        preferred_element_type=acc_dtype)              # (bq, s_pad)
    q2 = jnp.sum(xq * xq, axis=-1)[:, None]
    k2 = jnp.sum(xk * xk, axis=-1)[None, :]
    kus = sig2 * jnp.exp(-0.5 * jnp.maximum(q2 + k2 - 2.0 * cross, 0.0))
    if s_valid < kus.shape[1]:                         # static: mask padding
        cols = jax.lax.broadcasted_iota(jnp.int32, kus.shape, 1)
        kus = jnp.where(cols < s_valid, kus, 0.0)

    alpha = alpha_ref[...].astype(acc_dtype)           # (1, s_pad)
    mean = jnp.sum(kus * alpha, axis=1)                # (bq,) row-reduce
    # cached triangular solve on-tile: V = K_US L^{-T} as an MXU gemm
    # against the VMEM-resident inverse (contract over L^{-1}'s columns);
    # the variance quadratic form is then a row-wise square-reduce
    v1 = jax.lax.dot_general(
        kus, l1inv_ref[...].astype(acc_dtype), (((1,), (1,)), ((), ())),
        preferred_element_type=acc_dtype)              # (bq, s_pad)
    var = sig2 - jnp.sum(v1 * v1, axis=1)
    if with_l2:
        v2 = jax.lax.dot_general(
            kus, l2inv_ref[...].astype(acc_dtype), (((1,), (1,)), ((), ())),
            preferred_element_type=acc_dtype)
        var = var + jnp.sum(v2 * v2, axis=1)
    mean_ref[...] = mean[None, :].astype(mean_ref.dtype)
    var_ref[...] = var[None, :].astype(var_ref.dtype)


@functools.partial(jax.jit, static_argnames=("s_valid", "with_l2", "block_q",
                                             "interpret"))
def xcov_diag_pallas(Xq: jax.Array, Xk: jax.Array, L1inv: jax.Array,
                     L2inv: jax.Array, alpha: jax.Array, sig2: jax.Array, *,
                     s_valid: int, with_l2: bool = True, block_q: int = 128,
                     interpret: bool = False):
    """Tiled fused serving kernel. Caller guarantees n % block_q == 0 and
    Xk/L1inv/L2inv/alpha padded per the module contract — L1inv/L2inv are
    the lower-triangular INVERSE factors (ops.py does all of this).
    Returns ((n,) mean, (n,) var) in Xq's dtype."""
    n, d = Xq.shape
    s_pad = Xk.shape[0]
    acc_dtype = jnp.float64 if Xq.dtype == jnp.float64 else jnp.float32
    sig2 = jnp.asarray(sig2, acc_dtype).reshape(1, 1)
    grid = (n // block_q,)
    kernel = functools.partial(_xcov_diag_kernel, s_valid=s_valid,
                               with_l2=with_l2, acc_dtype=acc_dtype)
    mean, var = pl.pallas_call(
        kernel,
        grid=grid,
        in_specs=[
            pl.BlockSpec((1, 1), lambda i: (0, 0)),            # sig2
            pl.BlockSpec((block_q, d), lambda i: (i, 0)),      # Xq tile
            pl.BlockSpec((s_pad, d), lambda i: (0, 0)),        # support set
            pl.BlockSpec((s_pad, s_pad), lambda i: (0, 0)),    # L1^{-1}
            pl.BlockSpec((s_pad, s_pad), lambda i: (0, 0)),    # L2^{-1}
            pl.BlockSpec((1, s_pad), lambda i: (0, 0)),        # alpha
        ],
        out_specs=[
            pl.BlockSpec((1, block_q), lambda i: (0, i)),
            pl.BlockSpec((1, block_q), lambda i: (0, i)),
        ],
        out_shape=[
            jax.ShapeDtypeStruct((1, n), Xq.dtype),
            jax.ShapeDtypeStruct((1, n), Xq.dtype),
        ],
        interpret=interpret,
    )(sig2, Xq, Xk, L1inv, L2inv, alpha)
    return mean[0], var[0]
