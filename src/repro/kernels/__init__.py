"""Pallas TPU kernels for the framework's compute hot-spots, each with an
ops.py jit wrapper and a ref.py pure-jnp oracle (validated in interpret
mode on CPU):

  rbf/        fused pairwise-sqdist + exp covariance (the paper's local-
              summary hot spot: K_SD, K_DD blocks, K_UD)
  attention/  flash attention (GQA / causal / sliding-window) + the chunked
              O(T*(W+c)) windowed reference path
  ssd/        Mamba-2 SSD intra-chunk block (decay-masked chained matmuls)
"""
