"""Pure-jnp oracle for the SSD intra-chunk kernel."""
from __future__ import annotations

import jax.numpy as jnp


def intra_chunk(xdt, dA, Bc, Cc):
    """One (batch*chunk, head) tile of the SSD algorithm.

    xdt: (cs, P)  dt-weighted inputs for this head
    dA:  (cs,)    log-decay increments for this head
    Bc:  (cs, N)  input projections (shared across heads)
    Cc:  (cs, N)  output projections

    Returns:
      Y_diag (cs, P) — intra-chunk output
      S      (P, N)  — chunk state contribution (decayed to chunk end)
      cum    (cs,)   — cumulative log-decay (host uses it for inter-chunk)
    """
    cs = dA.shape[0]
    cum = jnp.cumsum(dA)
    L = cum[:, None] - cum[None, :]
    mask = jnp.tril(jnp.ones((cs, cs), bool))
    L = jnp.where(mask, jnp.exp(jnp.where(mask, L, 0.0)), 0.0)
    G = Cc @ Bc.T                                 # (cs, cs)
    Y_diag = (G * L) @ xdt                        # (cs, P)
    decay_end = jnp.exp(cum[-1] - cum)            # (cs,)
    S = xdt.T @ (Bc * decay_end[:, None])         # (P, N)
    return Y_diag, S, cum
