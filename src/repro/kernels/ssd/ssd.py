"""Pallas TPU kernel: SSD intra-chunk block (Mamba-2 hot spot).

The SSD algorithm's inner loop is three chained matmuls per (batch-chunk,
head) tile — C_c B_c^T (MXU), a decay-mask elementwise (VPU), and the
(cs x cs)(cs x P) product (MXU) — plus the decayed state outer product.
The CUDA reference fuses these with warp-level scans; the TPU-native
adaptation keeps the whole tile (cs<=256, N=128, P<=128: ~0.5 MB) resident
in VMEM and lets the MXU run the chained products, with the cumulative
log-decay computed as a VPU cumsum (no cross-lane shuffles needed).

The sequential inter-chunk recurrence stays in JAX (ops.py) — it is O(nc)
tiny matvecs and XLA pipelines it behind the next chunk's kernel work.
"""
from __future__ import annotations

import functools

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl


def _ssd_kernel(xdt_ref, dA_ref, b_ref, c_ref, y_ref, s_ref, cum_ref):
    xdt = xdt_ref[0, :, 0, :].astype(jnp.float32)      # (cs, P)
    dA = dA_ref[0, 0, :].astype(jnp.float32)           # (cs,)
    Bc = b_ref[0].astype(jnp.float32)                  # (cs, N)
    Cc = c_ref[0].astype(jnp.float32)                  # (cs, N)
    cs = dA.shape[0]

    cum = jnp.cumsum(dA)                               # VPU scan
    L = cum[:, None] - cum[None, :]
    tri = jax.lax.broadcasted_iota(jnp.int32, (cs, cs), 0) >= \
        jax.lax.broadcasted_iota(jnp.int32, (cs, cs), 1)
    L = jnp.where(tri, jnp.exp(jnp.where(tri, L, 0.0)), 0.0)

    G = jax.lax.dot_general(Cc, Bc, (((1,), (1,)), ((), ())),
                            preferred_element_type=jnp.float32)
    Y = jax.lax.dot_general(G * L, xdt, (((1,), (0,)), ((), ())),
                            preferred_element_type=jnp.float32)
    decay_end = jnp.exp(cum[-1] - cum)
    S = jax.lax.dot_general(xdt, Bc * decay_end[:, None],
                            (((0,), (0,)), ((), ())),
                            preferred_element_type=jnp.float32)

    y_ref[0, :, 0, :] = Y.astype(y_ref.dtype)
    s_ref[0, 0] = S.astype(s_ref.dtype)
    cum_ref[0, 0] = cum.astype(cum_ref.dtype)


@functools.partial(jax.jit, static_argnames=("interpret",))
def ssd_intra_chunk(xdt, dA, Bc, Cc, *, interpret: bool = False):
    """xdt: (BC, cs, H, P); dA: (BC, H, cs); Bc/Cc: (BC, cs, N).
    Returns Y_diag (BC, cs, H, P), S (BC, H, P, N), cum (BC, H, cs)."""
    BC, cs, H, P = xdt.shape
    N = Bc.shape[-1]
    grid = (BC, H)
    return pl.pallas_call(
        _ssd_kernel,
        grid=grid,
        in_specs=[
            pl.BlockSpec((1, cs, 1, P), lambda i, h: (i, 0, h, 0)),
            pl.BlockSpec((1, 1, cs), lambda i, h: (i, h, 0)),
            pl.BlockSpec((1, cs, N), lambda i, h: (i, 0, 0)),
            pl.BlockSpec((1, cs, N), lambda i, h: (i, 0, 0)),
        ],
        out_specs=[
            pl.BlockSpec((1, cs, 1, P), lambda i, h: (i, 0, h, 0)),
            pl.BlockSpec((1, 1, P, N), lambda i, h: (i, h, 0, 0)),
            pl.BlockSpec((1, 1, cs), lambda i, h: (i, h, 0)),
        ],
        out_shape=[
            jax.ShapeDtypeStruct((BC, cs, H, P), jnp.float32),
            jax.ShapeDtypeStruct((BC, H, P, N), jnp.float32),
            jax.ShapeDtypeStruct((BC, H, cs), jnp.float32),
        ],
        interpret=interpret,
    )(xdt, dA, Bc, Cc)
