"""jit'd wrapper: full SSD scan with the Pallas intra-chunk kernel.

Drop-in equivalent of models/ssm.ssd_scan (same signature/outputs): the
heavy per-chunk work runs in the Pallas kernel; the O(nc) inter-chunk state
recurrence and the off-diagonal combine stay in JAX.
"""
from __future__ import annotations

import functools

import jax
import jax.numpy as jnp

from repro.kernels.ssd.ssd import ssd_intra_chunk


@functools.partial(jax.jit, static_argnames=("chunk", "impl"))
def ssd_scan(xh, dt, A, Bm, Cm, chunk: int, *, impl: str = "auto"):
    """xh: (B,L,H,P); dt: (B,L,H) post-softplus; A: (H,) negative rates;
    Bm/Cm: (B,L,N). Returns (Y (B,L,H,P), final state (B,H,P,N))."""
    if impl == "auto":
        impl = "pallas" if jax.default_backend() == "tpu" else "jnp"
    if impl == "jnp":
        from repro.models.ssm import ssd_scan as ref_scan
        return ref_scan(xh, dt, A, Bm, Cm, chunk)

    B, L, H, P = xh.shape
    N = Bm.shape[-1]
    nc = L // chunk
    BC = B * nc

    xdt = (xh * dt[..., None]).reshape(BC, chunk, H, P)
    dA = (dt * A[None, None, :]).reshape(B, nc, chunk, H)
    dA = jnp.moveaxis(dA, 3, 2).reshape(BC, H, chunk)
    Bc = Bm.reshape(BC, chunk, N)
    Cc = Cm.reshape(BC, chunk, N)

    Y_diag, S, cum = ssd_intra_chunk(
        xdt, dA, Bc, Cc, interpret=(impl == "pallas_interpret"))

    # inter-chunk recurrence (JAX scan over nc steps)
    S_b = S.reshape(B, nc, H, P, N)
    cum_b = cum.reshape(B, nc, H, chunk)
    chunk_decay = jnp.exp(cum_b[..., -1])               # (B, nc, H)

    def step(prev, inp):
        S_c, g_c = inp
        new = prev * g_c[..., None, None] + S_c
        return new, prev

    final, prev_states = jax.lax.scan(
        step, jnp.zeros_like(S_b[:, 0]),
        (jnp.moveaxis(S_b, 1, 0), jnp.moveaxis(chunk_decay, 1, 0)))
    prev_states = jnp.moveaxis(prev_states, 0, 1)       # (B, nc, H, P, N)

    in_decay = jnp.exp(cum_b)                           # (B, nc, H, cs)
    Cc_b = Cm.reshape(B, nc, chunk, N)
    Y_off = jnp.einsum("bcin,bchpn,bchi->bcihp", Cc_b, prev_states,
                       in_decay)
    Y = (Y_diag.reshape(B, nc, chunk, H, P) + Y_off).reshape(B, L, H, P)
    return Y, final
