"""jit'd public wrapper for flash attention.

Responsibilities: GQA head broadcast, (B, H, T, D) <-> (BH, T, D) flattening,
head-dim padding to 128 lanes, sequence padding to block multiples, and
implementation routing ("auto" uses Pallas on TPU, the jnp reference
elsewhere; "pallas_interpret" validates the kernel body on CPU).
"""
from __future__ import annotations

import functools

import jax
import jax.numpy as jnp

from repro.kernels.attention import ref
from repro.kernels.attention.flash import flash_attention_flat

_LANE = 128


def _pad_axis(x, axis, mult):
    pad = (-x.shape[axis]) % mult
    if pad == 0:
        return x
    widths = [(0, 0)] * x.ndim
    widths[axis] = (0, pad)
    return jnp.pad(x, widths)


@functools.partial(jax.jit, static_argnames=("causal", "window", "impl",
                                             "block_q", "block_k"))
def attention(q, k, v, *, causal: bool = True, window: int | None = None,
              scale: float | None = None, q_offset: int = 0,
              impl: str = "auto", block_q: int = 128, block_k: int = 128):
    """Multi-head attention with GQA. q: (B,Hq,Tq,D); k,v: (B,Hkv,Tk,D)."""
    if impl == "auto":
        impl = "pallas" if jax.default_backend() == "tpu" else "jnp"
    if impl == "jnp":
        Tq, Tk = q.shape[2], k.shape[2]
        # §Perf: sliding-window sequences use the chunked O(T*(W+c)) path
        # when it saves >=2x over the masked-full computation
        if (causal and window is not None and Tq == Tk and q_offset == 0
                and Tq >= 2 * window and Tq % min(window, 512) == 0):
            return ref.attention_windowed_chunked(q, k, v, window=window,
                                                  scale=scale)
        return ref.attention(q, k, v, causal=causal, window=window,
                             scale=scale, q_offset=q_offset)

    B, Hq, Tq, D = q.shape
    Hkv, Tk = k.shape[1], k.shape[2]
    G = Hq // Hkv
    scale = (D ** -0.5) if scale is None else scale

    # GQA: repeat kv heads to match q heads (VMEM tiles are per flattened
    # head, so the broadcast costs HBM reads, not extra FLOPs per tile pair)
    if G > 1:
        k = jnp.repeat(k, G, axis=1)
        v = jnp.repeat(v, G, axis=1)

    qf = _pad_axis(_pad_axis(q.reshape(B * Hq, Tq, D), 2, _LANE), 1, block_q)
    kf = _pad_axis(_pad_axis(k.reshape(B * Hq, Tk, D), 2, _LANE), 1, block_k)
    vf = _pad_axis(_pad_axis(v.reshape(B * Hq, Tk, D), 2, _LANE), 1, block_k)
    # padded keys sit at positions >= Tk; causal masking hides them iff
    # qpos < Tk, which holds for real rows. For non-causal, mask via window
    # trick is not available — assert instead.
    assert causal or kf.shape[1] == Tk, \
        "non-causal flash requires Tk % block_k == 0"

    params = jnp.stack([jnp.asarray(scale, jnp.float32),
                        jnp.asarray(q_offset, jnp.float32)])
    out = flash_attention_flat(qf, kf, vf, params, block_q=block_q,
                               block_k=block_k, causal=causal, window=window,
                               interpret=(impl == "pallas_interpret"))
    return out[:, :Tq, :D].reshape(B, Hq, Tq, D)
