"""Pallas TPU flash attention (forward) with GQA, causal and sliding-window.

TPU adaptation notes (vs. the CUDA flash-attention blueprint):
  * no warp-level shuffles — the online-softmax running (max, sum) state lives
    in VMEM scratch per (block_q, D) tile; block reductions are plain VPU ops;
  * tiles are MXU-aligned: block_q x head_dim and block_k x head_dim with
    head_dim padded to 128 by ops.py;
  * the KV loop is the innermost grid dimension so the output tile stays
    resident in VMEM across KV steps (revisiting semantics), accumulated in
    f32;
  * causal/window handling is per-tile masking with explicit zeroing of
    masked probabilities (avoids the exp(-inf - -inf) = 1 trap on tiles that
    are fully masked).

Layout: q (BH, Tq, D) flattened outside; grid (BH, Tq/bq, Tk/bk).
"""
from __future__ import annotations

import functools

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl
from jax.experimental.pallas import tpu as pltpu

NEG_INF = -1e30


def _flash_kernel(params_ref, q_ref, k_ref, v_ref, out_ref, m_ref, l_ref,
                  acc_ref, *, block_q: int, block_k: int, causal: bool,
                  window: int | None):
    kv_idx = pl.program_id(2)
    q_idx = pl.program_id(1)
    num_kv = pl.num_programs(2)

    @pl.when(kv_idx == 0)
    def _init():
        m_ref[...] = jnp.full_like(m_ref, NEG_INF)
        l_ref[...] = jnp.zeros_like(l_ref)
        acc_ref[...] = jnp.zeros_like(acc_ref)

    scale = params_ref[0]
    q_offset = params_ref[1].astype(jnp.int32)

    q = q_ref[0].astype(jnp.float32)                   # (bq, D)
    k = k_ref[0].astype(jnp.float32)                   # (bk, D)
    v = v_ref[0].astype(jnp.float32)                   # (bk, D)
    s = jax.lax.dot_general(q, k, (((1,), (1,)), ((), ())),
                            preferred_element_type=jnp.float32) * scale

    qpos = q_idx * block_q + jax.lax.broadcasted_iota(
        jnp.int32, (block_q, block_k), 0) + q_offset
    kpos = kv_idx * block_k + jax.lax.broadcasted_iota(
        jnp.int32, (block_q, block_k), 1)
    mask = jnp.ones((block_q, block_k), jnp.bool_)
    if causal:
        mask &= kpos <= qpos
    if window is not None:
        mask &= kpos > qpos - window
    s = jnp.where(mask, s, NEG_INF)

    m_prev = m_ref[...]                                 # (bq, 1)
    m_cur = jnp.maximum(m_prev[:, 0], jnp.max(s, axis=-1))[:, None]
    alpha = jnp.exp(m_prev - m_cur)                     # (bq, 1); -inf-safe: 1
    p = jnp.where(mask, jnp.exp(s - m_cur), 0.0)        # (bq, bk)
    l_ref[...] = l_ref[...] * alpha + jnp.sum(p, axis=-1)[:, None]
    m_ref[...] = m_cur
    acc_ref[...] = acc_ref[...] * alpha + jax.lax.dot_general(
        p, v, (((1,), (0,)), ((), ())), preferred_element_type=jnp.float32)

    @pl.when(kv_idx == num_kv - 1)
    def _finish():
        out_ref[0] = (acc_ref[...]
                      / jnp.maximum(l_ref[...], 1e-30)).astype(out_ref.dtype)


@functools.partial(jax.jit, static_argnames=("block_q", "block_k", "causal",
                                             "window", "interpret"))
def flash_attention_flat(q, k, v, params, *, block_q: int = 128,
                         block_k: int = 128, causal: bool = True,
                         window: int | None = None,
                         interpret: bool = False):
    """q: (BH, Tq, D), k/v: (BH, Tk, D) — GQA head-broadcast done by ops.py.
    params: (2,) f32 [scale, q_offset]."""
    BH, Tq, D = q.shape
    Tk = k.shape[1]
    grid = (BH, Tq // block_q, Tk // block_k)
    kernel = functools.partial(_flash_kernel, block_q=block_q,
                               block_k=block_k, causal=causal, window=window)
    return pl.pallas_call(
        kernel,
        grid=grid,
        in_specs=[
            pl.BlockSpec(memory_space=pltpu.SMEM),                 # params
            pl.BlockSpec((1, block_q, D), lambda b, i, j: (b, i, 0)),
            pl.BlockSpec((1, block_k, D), lambda b, i, j: (b, j, 0)),
            pl.BlockSpec((1, block_k, D), lambda b, i, j: (b, j, 0)),
        ],
        out_specs=pl.BlockSpec((1, block_q, D), lambda b, i, j: (b, i, 0)),
        out_shape=jax.ShapeDtypeStruct((BH, Tq, D), q.dtype),
        scratch_shapes=[
            pltpu.VMEM((block_q, 1), jnp.float32),   # running max
            pltpu.VMEM((block_q, 1), jnp.float32),   # running denom
            pltpu.VMEM((block_q, D), jnp.float32),   # f32 accumulator
        ],
        interpret=interpret,
    )(params, q, k, v)
