"""Pure-jnp oracle for flash attention (GQA, causal, sliding-window).

``attention_windowed_chunked`` is the FLOP-efficient sliding-window path
(§Perf): each query chunk only touches its (window + chunk)-wide key span,
so cost is O(T·(W+c)·D) instead of the masked-full O(T^2·D). Exact vs
``attention`` (tests/test_kernels.py)."""
from __future__ import annotations

import jax
import jax.numpy as jnp

NEG_INF = -1e30


def attention_windowed_chunked(q, k, v, *, window: int,
                               scale: float | None = None,
                               q_offset: int = 0,
                               chunk: int | None = None):
    """Sliding-window causal attention via fixed-span key slices.

    q: (B, Hq, T, D); k, v: (B, Hkv, T, D), GQA broadcast done here.
    Requires T % chunk == 0 (caller pads); chunk defaults to min(window, 512).
    """
    B, Hq, T, D = q.shape
    Hkv = k.shape[1]
    G = Hq // Hkv
    scale = (D ** -0.5) if scale is None else scale
    c = chunk or min(window, 512)
    c = min(c, T)
    if T % c:
        c = T  # fallback: single chunk
    nc = T // c
    span = window + c   # keys covering [qpos - window + 1, qpos] for a chunk

    kf = jnp.pad(k.astype(jnp.float32),
                 ((0, 0), (0, 0), (window, 0), (0, 0)))
    vf = jnp.pad(v.astype(jnp.float32),
                 ((0, 0), (0, 0), (window, 0), (0, 0)))
    qf = q.astype(jnp.float32).reshape(B, Hkv, G, T, D)

    def one_chunk(i):
        qs = jax.lax.dynamic_slice_in_dim(qf, i * c, c, axis=3)
        ks = jax.lax.dynamic_slice_in_dim(kf, i * c, span, axis=2)
        vs = jax.lax.dynamic_slice_in_dim(vf, i * c, span, axis=2)
        logits = jnp.einsum("bhgqd,bhkd->bhgqk", qs, ks) * scale
        qpos = i * c + jnp.arange(c) + q_offset
        kpos = i * c - window + jnp.arange(span) + q_offset
        mask = ((kpos[None, :] <= qpos[:, None])
                & (kpos[None, :] > qpos[:, None] - window)
                & (kpos[None, :] >= q_offset))   # left-pad region invalid
        logits = jnp.where(mask[None, None, None], logits, NEG_INF)
        probs = jax.nn.softmax(logits, axis=-1)
        return jnp.einsum("bhgqk,bhkd->bhgqd", probs, vs)

    out = jax.lax.map(one_chunk, jnp.arange(nc))      # (nc, B, Hkv, G, c, D)
    out = jnp.moveaxis(out, 0, 3).reshape(B, Hkv, G, T, D)
    return out.reshape(B, Hq, T, D).astype(q.dtype)


def attention(q: jax.Array, k: jax.Array, v: jax.Array, *,
              causal: bool = True, window: int | None = None,
              scale: float | None = None,
              q_offset: int = 0) -> jax.Array:
    """Reference attention.

    q: (B, Hq, Tq, D); k, v: (B, Hkv, Tk, D) with Hq % Hkv == 0 (GQA).
    ``window``: sliding-window size (keys within [i - window + 1, i]).
    ``q_offset``: absolute position of q[0] (decode: Tq=1, q_offset=cache_len).
    Softmax in float32.
    """
    B, Hq, Tq, D = q.shape
    Hkv = k.shape[1]
    G = Hq // Hkv
    scale = (D ** -0.5) if scale is None else scale

    qf = q.astype(jnp.float32).reshape(B, Hkv, G, Tq, D)
    kf = k.astype(jnp.float32)
    vf = v.astype(jnp.float32)
    logits = jnp.einsum("bhgqd,bhkd->bhgqk", qf, kf) * scale

    qpos = jnp.arange(Tq) + q_offset
    kpos = jnp.arange(k.shape[2])
    mask = jnp.ones((Tq, k.shape[2]), bool)
    if causal:
        mask &= kpos[None, :] <= qpos[:, None]
    if window is not None:
        mask &= kpos[None, :] > qpos[:, None] - window
    logits = jnp.where(mask[None, None, None], logits, NEG_INF)
    probs = jax.nn.softmax(logits, axis=-1)
    out = jnp.einsum("bhgqk,bhkd->bhgqd", probs, vf)
    return out.reshape(B, Hq, Tq, D).astype(q.dtype)
