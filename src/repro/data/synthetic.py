"""Synthetic datasets mirroring the paper's two domains + LM token streams.

The real AIMPEAK/SARCOS data are not vendored; these generators reproduce
their statistical shape (dimensions, scale, noise levels quoted in Sec. 6) so
the benchmark harness exercises identical matrix sizes and the predictive-
quality curves are qualitatively comparable. Large-n GP draws use random
Fourier features (exact O(n^3) sampling is the very thing the paper avoids).
"""
from __future__ import annotations

import math
from typing import NamedTuple

import jax
import jax.numpy as jnp


class Dataset(NamedTuple):
    X: jax.Array
    y: jax.Array
    X_test: jax.Array
    y_test: jax.Array
    mean_y: jax.Array
    std_y: jax.Array


def rff_function(key, d: int, *, n_features: int = 512,
                 lengthscale=1.0, signal: float = 1.0):
    """Random smooth function ~ GP(0, SE kernel) via random Fourier features."""
    kw, kb, ka = jax.random.split(key, 3)
    ls = jnp.broadcast_to(jnp.asarray(lengthscale, jnp.float32), (d,))
    W = jax.random.normal(kw, (n_features, d)) / ls[None, :]
    b = jax.random.uniform(kb, (n_features,), maxval=2 * math.pi)
    a = jax.random.normal(ka, (n_features,)) * signal

    def f(X):
        phi = jnp.cos(X @ W.T + b) * math.sqrt(2.0 / n_features)
        return phi @ a

    return f


def _make(key, n, n_test, d, *, lengthscale, noise, out_mean, out_std):
    k1, k2, k3, k4 = jax.random.split(key, 4)
    f = rff_function(k1, d, lengthscale=lengthscale)
    X = jax.random.uniform(k2, (n, d), minval=-2.0, maxval=2.0)
    Xt = jax.random.uniform(k3, (n_test, d), minval=-2.0, maxval=2.0)
    fy = f(jnp.concatenate([X, Xt]))
    fy = (fy - fy.mean()) / (fy.std() + 1e-9)
    eps = noise * jax.random.normal(k4, (n + n_test,))
    y_all = out_mean + out_std * (fy + eps)
    return Dataset(X, y_all[:n], Xt, y_all[n:],
                   jnp.asarray(out_mean), jnp.asarray(out_std))


def aimpeak_like(key, n: int = 8000, n_test: int = 800) -> Dataset:
    """Traffic-speed-like: 5-d inputs (length, lanes, limit, direction,
    time), mean 49.5 km/h, sd 21.7 (paper Sec. 6)."""
    return _make(key, n, n_test, 5, lengthscale=1.2, noise=0.3,
                 out_mean=49.5, out_std=21.7)


def sarcos_like(key, n: int = 8000, n_test: int = 800) -> Dataset:
    """Robot-arm inverse-dynamics-like: 21-d inputs (7 pos + 7 vel + 7 acc),
    torque mean 13.7, sd 20.5 (paper Sec. 6)."""
    # lengthscale ~ sqrt(d) keeps typical pairwise correlations O(1)
    return _make(key, n, n_test, 21, lengthscale=4.5, noise=0.25,
                 out_mean=13.7, out_std=20.5)


def standardize(ds: Dataset) -> Dataset:
    """Center/scale outputs (the GP core assumes zero prior mean)."""
    return Dataset(ds.X, (ds.y - ds.mean_y) / ds.std_y, ds.X_test,
                   (ds.y_test - ds.mean_y) / ds.std_y, ds.mean_y, ds.std_y)


def lm_tokens(key, *, batch: int, seq: int, vocab: int,
              zipf_a: float = 1.2):
    """Zipf-distributed synthetic token stream (batch, seq+1) — realistic
    rank-frequency profile so embedding-gather patterns aren't uniform."""
    u = jax.random.uniform(key, (batch, seq + 1), minval=1e-6, maxval=1.0)
    ranks = jnp.floor(u ** (-1.0 / (zipf_a - 1.0))).astype(jnp.int32)
    return jnp.clip(ranks, 0, vocab - 1)
