"""Sharded data loading: deterministic, resumable, device-put against the
mesh batch sharding. Host-side generation (synthetic) stands in for the
storage layer; the cursor lives in the checkpoint so restarts resume
mid-epoch exactly."""
from __future__ import annotations

from typing import Iterator, NamedTuple

import jax
import jax.numpy as jnp
from jax.sharding import NamedSharding

from repro.data import synthetic
from repro.parallel import sharding as shd


class LoaderState(NamedTuple):
    step: int
    seed: int


class TokenLoader:
    """Synthetic LM token batches, sharded over the mesh DP axes."""

    def __init__(self, cfg, mesh, *, batch: int, seq: int, seed: int = 0):
        self.cfg, self.mesh = cfg, mesh
        self.batch, self.seq = batch, seq
        self.state = LoaderState(0, seed)
        self._sharding = NamedSharding(mesh, shd.batch_spec(mesh))

    def save_state(self) -> dict:
        return {"step": self.state.step, "seed": self.state.seed}

    def restore_state(self, d: dict) -> None:
        self.state = LoaderState(int(d["step"]), int(d["seed"]))

    def __iter__(self) -> Iterator[dict]:
        return self

    def __next__(self) -> dict:
        key = jax.random.fold_in(jax.random.PRNGKey(self.state.seed),
                                 self.state.step)
        toks = synthetic.lm_tokens(key, batch=self.batch, seq=self.seq,
                                   vocab=self.cfg.vocab)
        batch = {"tokens": jax.device_put(toks[:, :-1], self._sharding),
                 "labels": jax.device_put(toks[:, 1:], self._sharding)}
        if self.cfg.enc_dec:
            kf = jax.random.fold_in(key, 1)
            frames = jax.random.normal(
                kf, (self.batch, self.cfg.enc_seq, self.cfg.d_model),
                jnp.bfloat16)
            batch["frames"] = jax.device_put(frames, self._sharding)
        if self.cfg.family == "vlm":
            kf = jax.random.fold_in(key, 2)
            emb = jax.random.normal(
                kf, (self.batch, self.seq, self.cfg.d_model), jnp.bfloat16)
            batch["inputs_embeds"] = jax.device_put(emb, self._sharding)
        self.state = LoaderState(self.state.step + 1, self.state.seed)
        return batch


def gp_blocks(ds: synthetic.Dataset, runner) -> tuple:
    """Standardize + block-shard a GP dataset for a Runner."""
    ds = synthetic.standardize(ds)
    return ds, runner.shard_blocks(ds.X), runner.shard_blocks(ds.y)
