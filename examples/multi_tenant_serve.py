"""Multi-tenant GP serving: many posteriors, one scheduler, one compile.

Three districts each fit their own pPIC posterior (same kernel family and
serving policy, different data). Serving them as three processes would pay
the XLA compile ladder three times; the ``TenantRegistry`` admits all three
into ONE compiled lineage — plan-compatible tenants share every executable
while keeping independent posteriors, queues, and stats — and the
``TenantScheduler`` drains their queues earliest-weighted-deadline-first:

* ``city``   — weight 2.0: its staleness budget is effectively halved, so
  under contention its tickets are due (and flushed) first;
* ``suburb`` — adaptive deadline: brisk traffic flushes at the cadence the
  tenant's own arrivals set, sparse traffic waits out the full budget;
* ``rural``  — admission control: a queue-depth cap sheds the oldest
  ticket instead of growing without bound.

The coda checkpoints a tenant's store WITH its ServeSpec and re-admits it
from the artifact alone — fleet restart in one call.

    PYTHONPATH=src python examples/multi_tenant_serve.py
"""
import os
import tempfile

import numpy as np

import jax

from repro.core import api, covariance as cov, support
from repro.data import synthetic
from repro.parallel.runner import VmapRunner
from repro.serving import AdaptiveDeadline, TenantScheduler

N, M, S_SIZE = 1536, 8, 48


def main():
    key = jax.random.PRNGKey(3)
    ds = synthetic.standardize(synthetic.aimpeak_like(key, n=N, n_test=192))
    kfn = cov.make_kernel("se")
    params = cov.init_params(5, signal=1.0, noise=0.3, lengthscale=1.2)
    S = support.select_support(kfn, params, ds.X[:1024], S_SIZE)
    runner = VmapRunner(M=M)

    # three districts = three posteriors: same structure (one compiled
    # lineage), different data (rolled targets stand in for district feeds)
    def fit_district(roll):
        y = np.roll(np.asarray(ds.y), roll)
        store = api.init_store("ppic", kfn, params, ds.X, y, S=S,
                               runner=runner)
        return api.FittedGP(api.get("ppic"), kfn, params,
                            store.to_state()), store

    (city, city_store), (suburb, _), (rural, _) = map(
        fit_district, (0, 191, 517))

    t = [0.0]                                  # virtual clock, seconds
    sched = TenantScheduler(clock=lambda: t[0])
    spec = api.ServeSpec(max_batch=32, routed=True)
    sched.admit("city", city, spec, store=city_store, weight=2.0,
                flush_deadline_ms=25.0)
    sched.admit("suburb", suburb, spec, flush_deadline_ms=25.0,
                adaptive=AdaptiveDeadline(gain=1.5))
    sched.admit("rural", rural, spec, flush_deadline_ms=25.0,
                max_pending=4, overflow="shed_oldest")
    plan = sched.registry.get("city").plan
    print(f"admitted {len(sched.registry)} tenants -> "
          f"{sched.registry.n_lineages} compiled lineage(s); "
          f"executables shared: "
          f"{plan._exec is sched.registry.get('rural').plan._exec}")

    # skewed interleaved traffic: city dominates, suburb trickles briskly,
    # rural bursts past its queue cap. pump() between arrivals is the whole
    # serving loop — it flushes every due tenant, most-urgent first.
    plan.warmup(ds.X_test.shape[1], dtype=np.asarray(ds.X_test).dtype)
    n_traces0 = plan.stats.n_traces
    rng = np.random.RandomState(0)
    draws = rng.choice(3, size=256, p=[0.6, 0.3, 0.1])
    tickets = {"city": [], "suburb": [], "rural": []}
    for i, k in enumerate(draws):
        tid = ("city", "suburb", "rural")[k]
        if tid == "rural":                     # bursty: 3 points at once
            for j in range(3):
                tickets[tid].append(
                    sched.submit(tid, ds.X_test[(i + j) % 192]))
        else:
            tickets[tid].append(sched.submit(tid, ds.X_test[i % 192]))
        t[0] += 0.003                          # 3 ms between arrivals
        sched.pump()
    sched.flush()                              # drain every tail

    print(f"zero recompiles across tenant interleavings: "
          f"{plan.stats.n_traces == n_traces0}")
    for tid, st in sorted(sched.registry.stats_by_tenant().items()):
        snap = st.snapshot()
        print(f"  {tid:7s} requests={st.n_requests:3d} "
              f"flushes={st.n_flushes:3d} "
              f"(deadline={st.n_deadline_flushes}, size={st.n_size_flushes})"
              f" shed={st.n_shed} "
              f"staleness_p50={snap['staleness_ms']['p50']:.1f}ms")
    eff = sched.effective_deadline_ms("suburb")
    print(f"suburb adaptive deadline in force: {eff:.2f}ms "
          f"(declared budget 25.0ms)")

    # results resolve per tenant against its own posterior
    m_city = np.asarray(sched.result("city", tickets["city"][0])[0])
    m_rural = np.asarray(sched.result("rural", tickets["rural"][-1])[0])
    print(f"city mean[0]={float(m_city):+.4f}  "
          f"rural mean[-1]={float(m_rural):+.4f}")

    # fleet restart: the checkpoint carries store AND ServeSpec, so
    # re-admission needs nothing but the artifact
    from repro.core import serialize
    with tempfile.TemporaryDirectory() as d:
        path = os.path.join(d, "city.npz")
        serialize.save_store(path, city_store, spec=spec)
        sched.evict("city")
        sched.admit_from_checkpoint("city", path, kfn=kfn, runner=runner,
                                    weight=2.0, flush_deadline_ms=25.0)
        tk = sched.submit("city", ds.X_test[0])
        m2 = np.asarray(sched.result("city", tk)[0])
        print(f"re-admitted from checkpoint: {sched.registry.n_lineages} "
              f"lineage(s), mean matches: "
              f"{np.array_equal(m2, np.asarray(m_city))}")

    totals = sched.rollup()["totals"]
    print(f"fleet totals: requests={totals['n_requests']} "
          f"batches={totals['n_batches']} shed={totals['n_shed']} "
          f"rejected={totals['n_rejected']}")


if __name__ == "__main__":
    main()
