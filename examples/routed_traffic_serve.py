"""Centroid-routed pPIC serving with a deadline-driven flusher.

Queries from live traffic arrive in arbitrary order, so the positional
query-block assignment of ``ppic.predict_batch`` would give each request a
posterior that depends on what else happened to share its microbatch. The
routed path (Remark 2) dispatches every query to the block whose fit-time
centroid it is nearest — the posterior becomes a pure function of (query,
state) — and the deadline flusher bounds how long a lone request can wait
for company before the server predicts anyway.

    PYTHONPATH=src python examples/routed_traffic_serve.py
"""
import numpy as np

import jax

from repro.core import api, covariance as cov, ppic, support
from repro.data import synthetic
from repro.launch.gp_serve import GPServer
from repro.parallel.runner import VmapRunner


def main():
    key = jax.random.PRNGKey(3)
    n, M, s = 2048, 8, 64
    ds = synthetic.standardize(synthetic.aimpeak_like(key, n=n, n_test=256))
    kfn = cov.make_kernel("se")
    params = cov.init_params(5, signal=1.0, noise=0.3, lengthscale=1.2)
    S = support.select_support(kfn, params, ds.X[:1024], s)

    # bootstrap on the first half of the morning's data; the second half
    # will stream in through the store (Sec. 5.2) WITHOUT losing routing —
    # the streamed PICState carries refreshed block centroids
    store = api.init_store("ppic", kfn, params, ds.X[:n // 2],
                           ds.y[:n // 2], S=S, runner=VmapRunner(M=M))
    model = api.FittedGP(api.get("ppic"), kfn, params, store.to_state())
    print(f"fitted pPIC: n={n // 2} M={M} |S|={s}; "
          f"block centroids cached: {model.state.centroids.shape}")

    # traffic simulation: requests trickle in one at a time on a virtual
    # clock; the deadline (not the batch size) decides when to predict
    t = [0.0]
    server = GPServer(model, max_batch=64, flush_deadline_ms=25.0,
                      routed=True, store=store, clock=lambda: t[0])
    # the second data wave streams in mid-morning: rank-b updates of the
    # |S|-space factor + fresh block caches/centroids, hot-swapped into the
    # ROUTED server (grown block axis -> exactly one recompile)
    server.update(ds.X[n // 2:], ds.y[n // 2:])
    model = server.model
    print(f"streamed wave 2: blocks {n // 2 // M}x{M} -> "
          f"{model.state.Xb.shape[1]}x{model.state.Xb.shape[0]}, "
          f"centroids {model.state.centroids.shape}")
    rng = np.random.RandomState(0)
    order = rng.permutation(ds.X_test.shape[0])
    tickets = {}
    for i in order:
        tickets[int(i)] = server.submit(ds.X_test[int(i)])
        t[0] += 0.004                      # 4 ms between arrivals
        server.pump()                      # idle loop: deadline check
    server.flush()                         # drain the tail

    mean = np.stack([np.asarray(server.result(tk)[0])
                     for tk in (tickets[i] for i in range(len(tickets)))])
    rmse = float(np.sqrt(np.mean((mean - np.asarray(ds.y_test)) ** 2)))
    st = server.stats
    print(f"served {st.n_requests} tickets in {st.n_batches} microbatches "
          f"(deadline flushes: {st.n_deadline_flushes}, size: "
          f"{st.n_size_flushes}, manual: {st.n_manual_flushes})")
    print(f"rmse={rmse:.4f}")

    # composition invariance: the shuffled trickle (arbitrary microbatch
    # boundaries) reproduces the whole-batch routed posterior to roundoff —
    # with the positional path this deviation would be O(posterior scale)
    ref_mean, _ = ppic.predict_routed_diag(kfn, params, model.state,
                                           ds.X_test)
    dev = float(np.abs(mean - np.asarray(ref_mean)).max())
    pos_mean, _ = ppic.predict_batch_diag(kfn, params, model.state,
                                          ds.X_test[order])
    pos_dev = float(np.abs(np.asarray(pos_mean)
                           - np.asarray(ref_mean)[order]).max())
    print(f"routed trickle vs whole-batch:     max |dmean| = {dev:.2e}")
    print(f"positional shuffle vs whole-batch: max |dmean| = {pos_dev:.2e}")


if __name__ == "__main__":
    main()
