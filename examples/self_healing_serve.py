"""Self-healing GP serving: a block dies mid-stream, nobody notices.

One pPIC tenant serves routed traffic while a deterministic ``FaultPlan``
kills a block for a few flushes (the machine stops answering, exactly a
mid-stream hardware loss). The health ladder attached at admission does
the rest, with zero recompiles and zero exceptions reaching the caller:

* retry    — the failed flush is retried with exponential backoff;
* retire   — at the failure threshold the block is dropped from ROUTING
             (a mask, not a refit: the compiled executables are untouched);
* degrade  — queries routed at the dead block are answered from the
             global S-space posterior (pPITC path) with a per-query
             ``degraded`` flag — bounded loss, never an error;
* revive   — once the revive window passes, ``pump()`` reloads the last
             checkpoint and folds the block back in; post-revive
             predictions are bitwise what a never-faulted server returns.

    PYTHONPATH=src python examples/self_healing_serve.py
"""
import os
import tempfile

import numpy as np

import jax

from repro.core import api, clustering, covariance as cov, serialize, support
from repro.data import synthetic
from repro.parallel.runner import VmapRunner
from repro.serving import FaultInjector, FaultPlan, HealthPolicy, \
    TenantScheduler

N, M, S_SIZE, FLUSH = 1536, 8, 48, 16


def main():
    key = jax.random.PRNGKey(7)
    ds = synthetic.standardize(synthetic.aimpeak_like(key, n=N, n_test=256))
    kfn = cov.make_kernel("se")
    params = cov.init_params(5, signal=1.0, noise=0.3, lengthscale=1.2)
    S = support.select_support(kfn, params, ds.X[:1024], S_SIZE)
    store = api.init_store("ppic", kfn, params, ds.X, ds.y, S=S,
                           runner=VmapRunner(M=M))
    model = api.FittedGP(api.get("ppic"), kfn, params, store.to_state())
    spec = api.ServeSpec(max_batch=FLUSH, routed=True)

    # the checkpoint the revive path restores from — store + ServeSpec
    ckpt = os.path.join(tempfile.mkdtemp(prefix="self_healing_"), "store.npz")
    serialize.save_store(ckpt, store, spec=spec)

    # pick the victim that flush 2 actually routes the most traffic to, so
    # the injected death is guaranteed to strand real queries
    U = np.asarray(ds.X_test[:FLUSH * 8])
    centroids = np.asarray(model.state.centroids)
    victim = int(np.bincount(
        clustering.nearest_center_np(U[2 * FLUSH:3 * FLUSH], centroids),
        minlength=M).argmax())

    # transient fault: the block dies for dispatch attempts [2, 6) and
    # would answer again after — the shape a revive must fully erase
    chaos = FaultInjector(FaultPlan(fail_at={victim: (2, 6)}))
    policy = HealthPolicy(max_retries=2, max_consecutive_failures=1,
                          backoff_base_ms=0.1, checkpoint=ckpt,
                          revive_after_ms=0.0)

    sched = TenantScheduler()
    tenant = sched.admit("grid", model, spec, store=store,
                         health=policy, chaos=chaos)
    tenant.plan.warmup(ds.X.shape[1])
    traces0 = tenant.plan.stats.n_traces
    oracle = model.plan(spec)              # the never-faulted twin

    print(f"serving 8 flushes of {FLUSH}; block {victim} dies at flush 2")
    outs = []
    for f in range(8):
        tks = [sched.submit("grid", x) for x in U[f * FLUSH:(f + 1) * FLUSH]]
        sched.flush("grid")
        h = tenant.health.snapshot()       # before pump() revives
        dead = [m for m, b in enumerate(h["blocks"]) if not b["alive"]]
        sched.pump()                       # revive opportunity
        rows = [sched.collect("grid", tk) for tk in tks]
        outs.extend(rows)
        n_deg = sum(dg for *_, dg in rows)
        print(f"  flush {f}: degraded {n_deg:2d}/{FLUSH} rows, "
              f"retired blocks {dead or '[]'}")

    assert all(np.isfinite(m).all() and np.isfinite(v).all()
               for m, v, _ in outs), "a query ever saw a non-finite answer"
    st = tenant.stats
    print(f"ladder: retries={st.n_retries} auto_retired={st.n_auto_retired} "
          f"degraded_rows={st.n_degraded_rows} revives={st.n_revives}")

    # post-revive flushes are bitwise what a never-faulted plan serves
    ref_m, ref_v = map(np.asarray, oracle.routed_diag(U[7 * FLUSH:8 * FLUSH]))
    last = outs[7 * FLUSH:]
    bitwise = all(np.array_equal(np.asarray(m), ref_m[i])
                  and np.array_equal(np.asarray(v), ref_v[i]) and not dg
                  for i, (m, v, dg) in enumerate(last))
    print(f"post-revive bitwise == never-faulted: {bitwise}")
    print(f"recompiles during serving: "
          f"{tenant.plan.stats.n_traces - traces0}")


if __name__ == "__main__":
    main()
