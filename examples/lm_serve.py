"""Batched serving demo: prefill + decode with KV/SSM caches on a reduced
config, including the ring-buffer windowed cache (§Perf optimization).

    PYTHONPATH=src python examples/lm_serve.py --arch gemma3-4b --tokens 48
"""
import argparse
import time

import jax
import jax.numpy as jnp

from repro.configs.registry import ARCH_NAMES, smoke_config
from repro.launch.serve import prefill_then_decode
from repro.models import transformer as tf


def main():
    ap = argparse.ArgumentParser()
    ap.add_argument("--arch", default="gemma3-4b", choices=ARCH_NAMES)
    ap.add_argument("--batch", type=int, default=4)
    ap.add_argument("--prompt", type=int, default=16)
    ap.add_argument("--tokens", type=int, default=32)
    ap.add_argument("--temperature", type=float, default=0.8)
    args = ap.parse_args()

    cfg = smoke_config(args.arch)
    key = jax.random.PRNGKey(0)
    params = tf.init_model(key, cfg)
    prompts = jax.random.randint(key, (args.batch, args.prompt), 0,
                                 cfg.vocab)
    enc_kv = None
    if cfg.enc_dec:
        frames = jax.random.normal(key, (args.batch, cfg.enc_seq,
                                         cfg.d_model), jnp.float32)
        enc_kv = tf.encode(params, frames, cfg)

    t0 = time.perf_counter()
    if cfg.enc_dec:
        state = tf.init_serve(cfg, args.batch,
                              args.prompt + args.tokens + 8, enc_kv=enc_kv)
        logits = None
        toks = prompts
        for t in range(args.prompt):
            logits, state = tf.decode_step(params, toks[:, t:t + 1], state,
                                           cfg)
        outs = [toks]
        for _ in range(args.tokens):
            key, sub = jax.random.split(key)
            nxt = jax.random.categorical(
                sub, logits[:, -1] / args.temperature)[:, None]
            outs.append(nxt)
            logits, state = tf.decode_step(params, nxt, state, cfg)
        seq = jnp.concatenate(outs, axis=1)
    else:
        seq = prefill_then_decode(params, prompts, cfg,
                                  max_len=args.prompt + args.tokens + 8,
                                  n_decode=args.tokens,
                                  temperature=args.temperature, key=key)
    dt = time.perf_counter() - t0
    print(f"arch={cfg.name} generated {args.tokens} tokens x "
          f"{args.batch} seqs in {dt:.1f}s "
          f"({args.batch * args.tokens / dt:.1f} tok/s on CPU, reduced cfg)")
    print("sample token ids:", seq[0, -10:].tolist())


if __name__ == "__main__":
    main()
