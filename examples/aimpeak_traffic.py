"""AIMPEAK-like traffic prediction with streaming/online updates (Sec. 5.2)
served in real time through the microbatching GP server.

Morning-peak traffic arrives in 5-minute waves; the summary store assimilates
each wave with ONE |S|x|S| add — no recompute of earlier waves' O(b^3) work —
and the serving layer hot-swaps the cached PosteriorState under live traffic
(launch/gp_serve.py): the jitted predict executable is reused across swaps.
Straggler deadlines keep predictions real-time (the paper's motivating use
case).

    PYTHONPATH=src python examples/aimpeak_traffic.py
"""
import jax
import jax.numpy as jnp

from repro.core import api, covariance as cov, online, support
from repro.data import synthetic
from repro.launch.gp_serve import GPServer
from repro.parallel.runner import VmapRunner
from repro.runtime import straggler


def main():
    key = jax.random.PRNGKey(7)
    M, waves, wave_n = 8, 4, 1024
    ds = synthetic.standardize(
        synthetic.aimpeak_like(key, n=waves * wave_n, n_test=512))
    kfn = cov.make_kernel("se")
    params = cov.init_params(5, signal=1.0, noise=0.3, lengthscale=1.2)
    runner = VmapRunner(M=M)
    rmse = lambda m: float(jnp.sqrt(jnp.mean((m - ds.y_test) ** 2)))

    S = support.select_support(kfn, params, ds.X[:1024], 128)

    # wave 0 bootstraps the store; the server holds the cached state
    store = online.build(kfn, params, S, ds.X[:wave_n], ds.y[:wave_n],
                         runner)
    server = GPServer(api.FittedGP(api.get("ppitc"), kfn, params,
                                   online.to_state(store, S)),
                      max_batch=512)
    mean, _ = server.predict(ds.X_test)
    print(f"wave 1/{waves}: |D|={wave_n:6d} rmse={rmse(mean):.4f}")

    # later waves fold in online; the server hot-swaps the state
    for w in range(1, waves):
        sl = slice(w * wave_n, (w + 1) * wave_n)
        store = online.assimilate(store, kfn, params, S, ds.X[sl], ds.y[sl],
                                  runner)
        server.swap_state(online.to_state(store, S))
        mean, _ = server.predict(ds.X_test)
        print(f"wave {w + 1}/{waves}: |D|={(w + 1) * wave_n:6d} "
              f"rmse={rmse(mean):.4f}")
    # pPITC states live in |S|-space, so every swap reuses the same
    # compiled executable (same pytree structure/shapes)
    print(f"server: {server.stats.n_batches} batches, "
          f"{server.stats.n_state_swaps} state swaps")

    # real-time deadline: predict with whatever summaries arrived
    print("\nstraggler deadline sweep (fraction of blocks included, rmse):")
    rows = straggler.simulate(key, store, kfn, params, S, ds.X_test,
                              ds.y_test, deadlines=(1.2, 1.5, 3.0, 60.0))
    for r in rows:
        print(f"  deadline={r['deadline']:6.1f}  "
              f"included={r['fraction']:.2f}  rmse={r['rmse']:.4f}")


if __name__ == "__main__":
    main()
