"""AIMPEAK-like traffic prediction with streaming/online updates (Sec. 5.2)
served in real time through the microbatching GP server.

Morning-peak traffic arrives in 5-minute waves; the server's attached
``StateStore`` (api.init_store) assimilates each wave with rank-b Cholesky
updates of the cached |S|-space factor — no recompute of earlier waves'
O(b^3) work and no |S|^3 refactorization — and ``GPServer.update`` hot-swaps
the cached PosteriorState under live traffic: the jitted predict executable
is reused across swaps. Straggler deadlines keep predictions real-time (the
paper's motivating use case).

    PYTHONPATH=src python examples/aimpeak_traffic.py
"""
import jax
import jax.numpy as jnp

from repro.core import api, covariance as cov, support
from repro.data import synthetic
from repro.launch.gp_serve import GPServer
from repro.parallel.runner import VmapRunner
from repro.runtime import straggler


def main():
    key = jax.random.PRNGKey(7)
    M, waves, wave_n = 8, 4, 1024
    ds = synthetic.standardize(
        synthetic.aimpeak_like(key, n=waves * wave_n, n_test=512))
    kfn = cov.make_kernel("se")
    params = cov.init_params(5, signal=1.0, noise=0.3, lengthscale=1.2)
    runner = VmapRunner(M=M)
    rmse = lambda m: float(jnp.sqrt(jnp.mean((m - ds.y_test) ** 2)))

    S = support.select_support(kfn, params, ds.X[:1024], 128)

    # wave 0 bootstraps the store; the server owns the streaming lifecycle
    store = api.init_store("ppitc", kfn, params, ds.X[:wave_n],
                           ds.y[:wave_n], S=S, runner=runner)
    server = GPServer(api.FittedGP(api.get("ppitc"), kfn, params,
                                   store.to_state()),
                      max_batch=512, store=store)
    mean, _ = server.predict(ds.X_test)
    print(f"wave 1/{waves}: |D|={wave_n:6d} rmse={rmse(mean):.4f}")

    # later waves fold in online; update() assimilates + hot-swaps in one go
    for w in range(1, waves):
        sl = slice(w * wave_n, (w + 1) * wave_n)
        server.update(ds.X[sl], ds.y[sl])
        mean, _ = server.predict(ds.X_test)
        print(f"wave {w + 1}/{waves}: |D|={(w + 1) * wave_n:6d} "
              f"rmse={rmse(mean):.4f}")
    # pPITC states live in |S|-space, so every swap reuses the same
    # compiled executable (same pytree structure/shapes)
    print(f"server: {server.stats.n_batches} batches, "
          f"{server.stats.n_state_swaps} state swaps "
          f"({server.stats.n_updates} streaming updates)")

    # real-time deadline: predict with whatever summaries arrived
    print("\nstraggler deadline sweep (fraction of blocks included, rmse):")
    rows = straggler.simulate(key, server.store, ds.X_test, ds.y_test,
                              deadlines=(1.2, 1.5, 3.0, 60.0))
    for r in rows:
        print(f"  deadline={r['deadline']:6.1f}  "
              f"included={r['fraction']:.2f}  rmse={r['rmse']:.4f}")


if __name__ == "__main__":
    main()
