"""End-to-end driver — SARCOS-like robot-arm inverse dynamics (paper Sec. 6).

Full production pipeline: data -> hyperparameter MLE (distributable PITC
likelihood) -> support selection -> pPIC + pICF predictions across machines
-> metrics (RMSE / MNLP, paper Sec. 6.1) -> summary checkpoint -> simulated
machine failure + recovery.

    PYTHONPATH=src python examples/sarcos_robot.py [--n 4096] [--machines 8]
"""
import argparse
import dataclasses
import tempfile

import jax
import jax.numpy as jnp

from repro.checkpoint.manager import CheckpointManager
from repro.core import api, covariance as cov, hyper, serialize, support
from repro.data import synthetic
from repro.parallel.runner import VmapRunner
from repro.runtime import fault


def mnlp(mean, var, y):
    v = jnp.maximum(var, 1e-9)
    return float(0.5 * jnp.mean((y - mean) ** 2 / v
                                + jnp.log(2 * jnp.pi * v)))


def main():
    ap = argparse.ArgumentParser()
    ap.add_argument("--n", type=int, default=4096)
    ap.add_argument("--machines", type=int, default=8)
    ap.add_argument("--support", type=int, default=128)
    ap.add_argument("--rank", type=int, default=256)
    ap.add_argument("--mle-steps", type=int, default=60)
    args = ap.parse_args()

    key = jax.random.PRNGKey(0)
    ds = synthetic.standardize(synthetic.sarcos_like(key, n=args.n,
                                                     n_test=512))
    kfn = cov.make_kernel("se")
    runner = VmapRunner(M=args.machines)
    rmse = lambda m: float(jnp.sqrt(jnp.mean((m - ds.y_test) ** 2)))

    # --- hyperparameter MLE on the distributable PITC likelihood ----------
    p0 = cov.init_params(21, signal=1.0, noise=0.5, lengthscale=4.0)
    S0 = support.select_support(kfn, p0, ds.X[:1024], args.support)
    params, losses = hyper.fit_parallel(kfn, p0, S0, ds.X, ds.y, runner,
                                        steps=args.mle_steps, lr=0.05)
    print(f"MLE: PITC-nlml {float(losses[0]):.1f} -> {float(losses[-1]):.1f}"
          f"  lengthscale[:3]={jnp.exp(params['log_lengthscale'][:3])}")

    # --- support selection with fitted hyperparameters --------------------
    S = support.select_support_parallel(kfn, params, ds.X[:1024],
                                        args.support, runner)

    # --- pPIC: fit once, predict from the cached PosteriorState ------------
    model = api.fit("ppic", kfn, params, ds.X, ds.y, S=S, runner=runner)
    mean, var = model.predict_diag(ds.X_test)
    print(f"pPIC : rmse={rmse(mean):.4f} "
          f"mnlp={mnlp(mean, var, ds.y_test):.3f}")

    # --- pICF-based GP (paper Sec. 4; R ~ 2x|S| per Sec. 6) ----------------
    modeli = api.fit("picf", kfn, params, ds.X, ds.y, rank=args.rank,
                     runner=runner)
    meani, vari = modeli.predict_diag(ds.X_test)
    print(f"pICF : rmse={rmse(meani):.4f} "
          f"mnlp={mnlp(meani, vari, ds.y_test):.3f}")

    # --- checkpoint posterior + summaries, then failure recovery -----------
    cluster = fault.build(kfn, params, S, ds.X, ds.y, runner)
    with tempfile.TemporaryDirectory() as tmp:
        # the serving-facing checkpoint: the versioned PosteriorState npz
        # (what a replica ships to its peers — core/serialize.py)
        ckpt = serialize.save_state(f"{tmp}/ppic_state.npz", model.state)
        meta = serialize.peek(ckpt)
        print(f"state checkpoint: {meta['state']} v{meta['schema']} "
              f"({len(meta['fields'])} fields)")
        # the fit-side checkpoint: the summary pytree (fold-back source)
        mgr = CheckpointManager(tmp)
        mgr.save(0, cluster.store.store)
        cluster = fault.fail(cluster, machine=3)
        mean_d, _ = cluster.store.predict(ds.X_test)
        print(f"after machine-3 failure (degraded): rmse={rmse(mean_d):.4f}")
        _, restored = mgr.restore_latest(jax.tree.map(
            lambda a: jnp.zeros_like(a), cluster.store.store))
        mean_r, _ = dataclasses.replace(cluster.store,
                                        store=restored).predict(ds.X_test)
        print(f"after checkpoint restore:           rmse={rmse(mean_r):.4f}")
        # the serialized posterior round-trips bitwise
        assert all(bool(jnp.array_equal(a, b)) for a, b in
                   zip(serialize.load_state(ckpt), model.state))


if __name__ == "__main__":
    main()
