"""Quickstart: parallel GP regression in ~40 lines.

Builds a synthetic traffic-like dataset, selects a support set, fits pPIC
across 8 simulated machines through the method registry (core/api.py), and
compares repeated cached-state predictions against exact full-GP.

    PYTHONPATH=src python examples/quickstart.py
"""
import jax
import jax.numpy as jnp

import numpy as np

from repro.core import api, clustering, covariance as cov, support
from repro.data import synthetic
from repro.parallel.runner import VmapRunner

key = jax.random.PRNGKey(0)
M = 8

# 1. data (paper Sec. 6 scale-down): 5-d traffic-speed-like field
ds = synthetic.standardize(synthetic.aimpeak_like(key, n=2048, n_test=256))

# 2. kernel + hyperparameters (see examples/sarcos_robot.py for MLE fitting).
#    A KernelSpec (not a bare function) declares HOW covariances are built —
#    impl="auto" serves the Pallas fused path on TPU and dense jnp on CPU —
#    and threads through every predict path, full covariance included.
kfn = cov.make_spec("se")
params = cov.init_params(d=5, signal=1.0, noise=0.3, lengthscale=1.2)

# 3. support set: greedy differential-entropy selection (Sec. 3, Def. 2)
S = support.select_support(kfn, params, ds.X[:1024], size=256)

# 4. co-cluster (D_m, U_m) so each machine's local correction helps
#    (paper Remark 2 after Def. 5), then FIT ONCE across M machines.
#    The fit caches a PosteriorState; every predict after that skips the
#    O((|D|/M)^3) summary work. Swap in ShardMapRunner(mesh=...) for real
#    devices — the fit path is runner-agnostic and yields the same state.
Xc, yc, Uc, _, perm_u = clustering.cocluster(
    np.asarray(ds.X), np.asarray(ds.y), np.asarray(ds.X_test), M, key)
model = api.fit("ppic", kfn, params, jnp.asarray(Xc), jnp.asarray(yc),
                S=S, runner=VmapRunner(M=M))

# 5. predict from the cached state (repeatable at O(|U||S|) per call).
#    FittedGP.predict* are thin clients of a ServePlan (phase-1/phase-2
#    split): the jitted executables are built once and reused per call.
post = model.predict(jnp.asarray(Uc))
mean = jnp.asarray(clustering.uncluster(np.asarray(post.mean), perm_u))

# 5b. the same posterior without pre-clustering the queries: routed
#     prediction sends each query to its nearest block centroid (Remark 2
#     at serving time) — order/composition-invariant, no permutation
#     bookkeeping. Building the plan explicitly exposes the serving policy
#     (bucket ladder, overflow-executable ladder, cached per-block C^-1);
#     see examples/routed_traffic_serve.py for the server on top of it.
plan = model.plan(api.ServeSpec(routed=True, max_batch=256,
                                cached_cinv=True))
routed_mean, _ = plan.routed_diag(ds.X_test)

# 6. compare with the exact O(n^3) full GP (also through the registry)
exact_model = api.fit("fgp", kfn, params, ds.X, ds.y)
exact_mean, exact_var = exact_model.predict_diag(ds.X_test)

rmse = lambda m: float(jnp.sqrt(jnp.mean((m - ds.y_test) ** 2)))
print(f"methods registered: {api.names()}")
print(f"pPIC  (M={M})  rmse={rmse(mean):.4f}")
print(f"pPIC routed    rmse={rmse(routed_mean):.4f}")
print(f"full GP        rmse={rmse(exact_mean):.4f}")
print(f"mean |pPIC - FGP| = {float(jnp.abs(mean - exact_mean).mean()):.4f}")
print(f"pPIC mean variance = {float(post.var.mean()):.4f} (>0, calibrated)")
