"""Quickstart: parallel GP regression in ~40 lines.

Builds a synthetic traffic-like dataset, selects a support set, runs pPIC
across 8 simulated machines, and compares against exact full-GP.

    PYTHONPATH=src python examples/quickstart.py
"""
import jax
import jax.numpy as jnp

import numpy as np

from repro.core import clustering, covariance as cov, gp, ppic, support
from repro.data import synthetic
from repro.parallel.runner import VmapRunner

key = jax.random.PRNGKey(0)
M = 8

# 1. data (paper Sec. 6 scale-down): 5-d traffic-speed-like field
ds = synthetic.standardize(synthetic.aimpeak_like(key, n=2048, n_test=256))

# 2. kernel + hyperparameters (see examples/sarcos_robot.py for MLE fitting)
kfn = cov.make_kernel("se")
params = cov.init_params(d=5, signal=1.0, noise=0.3, lengthscale=1.2)

# 3. support set: greedy differential-entropy selection (Sec. 3, Def. 2)
S = support.select_support(kfn, params, ds.X[:1024], size=256)

# 4. co-cluster (D_m, U_m) so each machine's local correction helps
#    (paper Remark 2 after Def. 5), then run pPIC across M machines
#    (vmap simulation; swap in ShardMapRunner(mesh=...) for real devices —
#    the per-machine code is identical)
Xc, yc, Uc, _, perm_u = clustering.cocluster(
    np.asarray(ds.X), np.asarray(ds.y), np.asarray(ds.X_test), M, key)
runner = VmapRunner(M=M)
post = ppic.predict(kfn, params, S, jnp.asarray(Xc), jnp.asarray(yc),
                    jnp.asarray(Uc), runner)
post = post._replace(
    mean=jnp.asarray(clustering.uncluster(np.asarray(post.mean), perm_u)))

# 5. compare with the exact O(n^3) full GP
exact = gp.predict(kfn, params, ds.X, ds.y, ds.X_test, diag_only=True)

rmse = lambda m: float(jnp.sqrt(jnp.mean((m - ds.y_test) ** 2)))
print(f"pPIC  (M={M})  rmse={rmse(post.mean):.4f}")
print(f"full GP        rmse={rmse(exact.mean):.4f}")
print(f"mean |pPIC - FGP| = {float(jnp.abs(post.mean - exact.mean).mean()):.4f}")
print(f"pPIC mean variance = {float(post.var.mean()):.4f} (>0, calibrated)")
