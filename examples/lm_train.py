"""LM training driver: train a reduced config of any assigned architecture
for a few hundred steps on CPU (full-scale shardings come from the same
builders — see src/repro/launch/dryrun.py for the 512-chip lowering).

    PYTHONPATH=src python examples/lm_train.py --arch qwen3-1.7b --steps 200
    PYTHONPATH=src python examples/lm_train.py --arch mixtral-8x22b \
        --steps 50 --compress
"""
import argparse
import tempfile

import jax

from repro.checkpoint.manager import CheckpointManager
from repro.configs.registry import ARCH_NAMES, smoke_config
from repro.data.loader import TokenLoader
from repro.launch import train as train_lib
from repro.optim.adam import Adam, cosine_schedule


def main():
    ap = argparse.ArgumentParser()
    ap.add_argument("--arch", default="qwen3-1.7b", choices=ARCH_NAMES)
    ap.add_argument("--steps", type=int, default=200)
    ap.add_argument("--batch", type=int, default=8)
    ap.add_argument("--seq", type=int, default=64)
    ap.add_argument("--compress", action="store_true",
                    help="int8+error-feedback gradient compression")
    ap.add_argument("--microbatches", type=int, default=1)
    ap.add_argument("--ckpt-every", type=int, default=100)
    args = ap.parse_args()

    cfg = smoke_config(args.arch).scaled(
        n_layers=max(smoke_config(args.arch).n_layers, 4))
    mesh = jax.make_mesh((1,), ("data",))
    opt = Adam(lr=cosine_schedule(3e-3, warmup=20, total=args.steps),
               clip_norm=1.0, weight_decay=0.01)
    state = train_lib.init_state(jax.random.PRNGKey(0), cfg, opt,
                                 compress=args.compress)
    step_fn, jitted = train_lib.make_train_step(
        cfg, mesh, opt, microbatches=args.microbatches, remat=True,
        compress=args.compress, attn_impl="jnp")
    jstep = jitted(state)
    loader = TokenLoader(cfg, mesh, batch=args.batch, seq=args.seq)

    with tempfile.TemporaryDirectory() as tmp:
        mgr = CheckpointManager(tmp, keep=2)
        for i in range(args.steps):
            state, metrics = jstep(state, next(loader))
            if i % 20 == 0 or i == args.steps - 1:
                print(f"step {i:4d} loss={float(metrics.loss):.4f} "
                      f"gnorm={float(metrics.grad_norm):.2f} "
                      f"moe_aux={float(metrics.moe_loss):.3f}")
            if (i + 1) % args.ckpt_every == 0:
                mgr.save(i + 1, state, sync=False)
        mgr.wait()
        print(f"checkpoints kept: {mgr.steps()}; loader cursor: "
              f"{loader.save_state()}")


if __name__ == "__main__":
    main()
