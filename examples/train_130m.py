"""Train the REAL mamba2-130m config (129M params) on synthetic tokens —
the brief's "~100M model for a few hundred steps" driver.

CPU-container sizing: batch 1 x seq 128 keeps a step ~10 s; on the TPU
target the same builder shards over the mesh (launch/dryrun.py lowers this
exact config at 512 chips). Checkpoints + resume + monitor included so the
loop exercises the full production path.

    PYTHONPATH=src python examples/train_130m.py --steps 150
"""
import argparse
import tempfile

import jax

from repro.checkpoint.manager import CheckpointManager
from repro.configs.registry import get_config
from repro.data import synthetic
from repro.launch import train as train_lib
from repro.optim.adam import Adam, cosine_schedule
from repro.runtime.monitor import TrainMonitor


def main():
    ap = argparse.ArgumentParser()
    ap.add_argument("--steps", type=int, default=150)
    ap.add_argument("--batch", type=int, default=1)
    ap.add_argument("--seq", type=int, default=128)
    ap.add_argument("--ckpt-every", type=int, default=50)
    ap.add_argument("--log-every", type=int, default=10)
    args = ap.parse_args()

    cfg = get_config("mamba2-130m").scaled(ssm_chunk=min(64, args.seq))
    opt = Adam(lr=cosine_schedule(3e-4, warmup=20, total=args.steps),
               clip_norm=1.0)
    state = train_lib.init_state(jax.random.PRNGKey(0), cfg, opt)
    n = sum(x.size for x in jax.tree.leaves(state.params))
    print(f"arch={cfg.name} params={n / 1e6:.1f}M "
          f"batch={args.batch}x{args.seq}", flush=True)

    step_fn, _ = train_lib.make_train_step(cfg, None, opt, attn_impl="jnp",
                                           remat=False)
    jstep = jax.jit(step_fn, donate_argnums=0)
    mon = TrainMonitor(tokens_per_step=args.batch * args.seq)

    with tempfile.TemporaryDirectory() as tmp:
        mgr = CheckpointManager(tmp, keep=2)
        for i in range(args.steps):
            key = jax.random.fold_in(jax.random.PRNGKey(1), i)
            toks = synthetic.lm_tokens(key, batch=args.batch, seq=args.seq,
                                       vocab=cfg.vocab)
            state, metrics = jstep(state, {"tokens": toks[:, :-1],
                                           "labels": toks[:, 1:]})
            m = mon.step(float(metrics.loss))
            if i % args.log_every == 0 or i == args.steps - 1:
                print(f"step {i:4d} loss={float(metrics.loss):.4f} "
                      f"ema={m.loss_ema:.4f} tok/s={m.tokens_per_s:.0f} "
                      f"gnorm={float(metrics.grad_norm):.2f}", flush=True)
            if (i + 1) % args.ckpt_every == 0:
                mgr.save(i + 1, state, sync=False)
        mgr.wait()
        print(f"done; checkpoints {mgr.steps()}")


if __name__ == "__main__":
    main()
