"""GP head on LM features — the paper's method composed with the LM stack.

A frozen reduced-config LM embeds token sequences; pPIC GP regression (deep-
kernel style) predicts a scalar target (here: synthetic "quality score")
from the mean-pooled final hidden state, WITH calibrated uncertainty — the
thing a point-estimate reward head cannot give. Data stays sharded across
machines; only |S|-dim summaries cross the network (DESIGN.md §4).

    PYTHONPATH=src python examples/gp_head_lm.py
"""
import jax
import jax.numpy as jnp

from repro.configs.registry import smoke_config
from repro.core import covariance as cov, ppic, support
from repro.data import synthetic
from repro.models import transformer as tf
from repro.parallel.runner import VmapRunner


def embed_sequences(params, toks, cfg):
    """Frozen LM feature extractor: mean-pooled pre-logits hidden state."""
    from repro.models import layers
    x = layers.embed(params["embed"], toks).astype(jnp.float32)
    B, T, _ = x.shape
    pos = jnp.broadcast_to(jnp.arange(T)[None], (B, T))
    for pos_i in range(cfg.period):
        p = jax.tree.map(lambda a: a[0], params["stack"][pos_i])
        x, _ = tf.apply_layer(p, x, cfg, cfg.layer_pattern[pos_i],
                              positions=pos, attn_impl="jnp",
                              compute_dtype=jnp.float32)
    return x.mean(axis=1)   # (B, d_model)


def main():
    key = jax.random.PRNGKey(0)
    cfg = smoke_config("qwen3-1.7b")
    lm_params = tf.init_model(key, cfg)
    M, n, n_test = 4, 512, 128

    # synthetic corpus + scalar target that depends on token statistics
    toks = synthetic.lm_tokens(key, batch=n + n_test, seq=32,
                               vocab=cfg.vocab)[:, :-1]
    feats = embed_sequences(lm_params, toks, cfg)          # (n+test, d)
    w = jax.random.normal(jax.random.PRNGKey(1), (feats.shape[1],))
    score = jnp.tanh(feats @ w / jnp.sqrt(feats.shape[1]))
    score = score + 0.05 * jax.random.normal(jax.random.PRNGKey(2),
                                             score.shape)

    X, y = feats[:n], score[:n]
    Xt, yt = feats[n:], score[n:]
    y_mu, y_sd = y.mean(), y.std()
    y = (y - y_mu) / y_sd

    kfn = cov.make_kernel("se")
    p0 = cov.init_params(X.shape[1], signal=1.0, noise=0.2,
                         lengthscale=float(jnp.sqrt(X.shape[1])))
    # short MLE on a subset calibrates signal/noise/lengthscales
    from repro.core import hyper
    params, _ = hyper.fit(kfn, p0, X[:256], y[:256], steps=80, lr=0.05)
    S = support.select_support(kfn, params, X[:256], 64)
    runner = VmapRunner(M=M)
    post = ppic.predict(kfn, params, S, X, y, Xt, runner)

    pred = post.mean * y_sd + y_mu
    rmse = float(jnp.sqrt(jnp.mean((pred - yt) ** 2)))
    base = float(jnp.sqrt(jnp.mean((yt - yt.mean()) ** 2)))
    sigma = jnp.sqrt(jnp.maximum(post.var, 1e-9)) * y_sd
    inside = float(jnp.mean((jnp.abs(pred - yt) < 2 * sigma)))
    print(f"GP-head rmse={rmse:.4f} (predict-mean baseline {base:.4f})")
    print(f"2-sigma coverage: {inside:.2%} (calibration target ~95%)")


if __name__ == "__main__":
    main()
