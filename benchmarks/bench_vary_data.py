"""Paper Fig. 1: predictive performance + time vs data size |D|.

Methods: FGP, pPITC/pPIC/pICF (vmap-parallel) and their centralized
counterparts (blockwise/woodbury on one machine). Sizes are scaled to the
CPU container; the trends (RMSE down with |D|, parallel time ~|D|^3/M^3 +
|S|^2 terms, speedup growing with |D| — Sec. 6.2.1 observations) are the
reproduction target."""
from __future__ import annotations

import jax
import jax.numpy as jnp

from repro.core import covariance as cov, gp, icf, picf, pitc, ppic, ppitc
from repro.core import support
from repro.data import synthetic
from repro.parallel.runner import VmapRunner

from benchmarks import common

SIZES = (512, 1024, 2048, 4096)
M = 8
S_SIZE = 128
RANK = 128


def run(domain: str = "aimpeak", sizes=SIZES, quick: bool = False):
    key = jax.random.PRNGKey(0)
    gen = (synthetic.aimpeak_like if domain == "aimpeak"
           else synthetic.sarcos_like)
    sizes = sizes[:2] if quick else sizes
    kfn = cov.make_kernel("se")
    runner = VmapRunner(M=M)

    for n in sizes:
        ds = synthetic.standardize(gen(key, n=n, n_test=256))
        d = ds.X.shape[1]
        ls = 1.2 if domain == "aimpeak" else 4.5
        params = cov.init_params(d, signal=1.0, noise=0.3,
                                 lengthscale=ls, dtype=jnp.float32)
        S = support.select_support(kfn, params, ds.X[:min(n, 2048)], S_SIZE)
        sum_bytes = (S_SIZE ** 2 + S_SIZE) * 4

        # --- FGP (exact) on n <= 2048 (cubic blow-up beyond)
        if n <= 2048:
            t = common.timeit(
                jax.jit(lambda: gp.predict(kfn, params, ds.X, ds.y,
                                           ds.X_test, diag_only=True)))
            post = gp.predict(kfn, params, ds.X, ds.y, ds.X_test,
                              diag_only=True)
            common.emit(f"fig1/{domain}/fgp/n{n}", t,
                        f"rmse={common.rmse(post.mean, ds.y_test):.4f};"
                        f"mnlp={common.mnlp(post.mean, post.var, ds.y_test):.3f}")

        # --- pPITC / PITC
        t_par = common.timeit(jax.jit(
            lambda: ppitc.predict(kfn, params, S, ds.X, ds.y,
                                  ds.X_test, runner).mean))
        t_cen = common.timeit(jax.jit(
            lambda: pitc.pitc_predict_blockwise(kfn, params, S, ds.X, ds.y,
                                                ds.X_test, M).mean))
        post = ppitc.predict(kfn, params, S, ds.X, ds.y, ds.X_test, runner)
        mp = common.modeled_parallel_us(t_par, M, sum_bytes)
        common.emit(f"fig1/{domain}/ppitc/n{n}", t_par,
                    f"rmse={common.rmse(post.mean, ds.y_test):.4f};"
                    f"mnlp={common.mnlp(post.mean, post.var, ds.y_test):.3f};"
                    f"centralized_us={t_cen:.0f};modeled_par_us={mp:.0f};"
                    f"modeled_speedup={t_cen / mp:.2f}")

        # --- pPIC / PIC
        t_par = common.timeit(jax.jit(
            lambda: ppic.predict(kfn, params, S, ds.X, ds.y,
                                 ds.X_test, runner).mean))
        t_cen = common.timeit(jax.jit(
            lambda: pitc.pic_predict_blockwise(kfn, params, S, ds.X, ds.y,
                                               ds.X_test, M).mean))
        post = ppic.predict(kfn, params, S, ds.X, ds.y, ds.X_test, runner)
        mp = common.modeled_parallel_us(t_par, M, sum_bytes)
        common.emit(f"fig1/{domain}/ppic/n{n}", t_par,
                    f"rmse={common.rmse(post.mean, ds.y_test):.4f};"
                    f"mnlp={common.mnlp(post.mean, post.var, ds.y_test):.3f};"
                    f"centralized_us={t_cen:.0f};modeled_par_us={mp:.0f};"
                    f"modeled_speedup={t_cen / mp:.2f}")

        # --- pICF / ICF
        sum_bytes_icf = (RANK ** 2 + RANK + RANK * 256) * 4
        t_par = common.timeit(jax.jit(
            lambda: picf.predict(kfn, params, ds.X, ds.y, ds.X_test, RANK,
                                 runner, shard_u=True).mean))
        fac = icf.icf_factor(kfn, params, ds.X, RANK)
        t_cen = common.timeit(jax.jit(
            lambda: icf.icf_predict(kfn, params, ds.X, ds.y, ds.X_test,
                                    fac.F).mean))
        post = picf.predict(kfn, params, ds.X, ds.y, ds.X_test, RANK,
                            runner, shard_u=True)
        mp = common.modeled_parallel_us(t_par, M, sum_bytes_icf)
        common.emit(f"fig1/{domain}/picf/n{n}", t_par,
                    f"rmse={common.rmse(post.mean, ds.y_test):.4f};"
                    f"mnlp={common.mnlp(post.mean, post.var, ds.y_test):.3f};"
                    f"centralized_us={t_cen:.0f};modeled_par_us={mp:.0f};"
                    f"modeled_speedup={t_cen / mp:.2f}")
