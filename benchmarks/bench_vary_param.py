"""Paper Fig. 3: performance vs parameter P (= |S| for pPITC/pPIC, = R for
pICF). Reproduces Sec. 6.2.3: pICF needs much larger R than |S| for
comparable accuracy; its MNLP degrades sharply at small R (non-PSD
predictive covariance, Remark 2 after Thm 3)."""
from __future__ import annotations

import jax
import jax.numpy as jnp

from repro.core import covariance as cov, picf, ppic, ppitc, support
from repro.data import synthetic
from repro.parallel.runner import VmapRunner

from benchmarks import common

PARAMS = (32, 64, 128, 256)
N = 2048
M = 8


def run(domain: str = "aimpeak", values=PARAMS, quick: bool = False):
    key = jax.random.PRNGKey(2)
    gen = (synthetic.aimpeak_like if domain == "aimpeak"
           else synthetic.sarcos_like)
    values = values[:2] if quick else values
    n = 512 if quick else N
    ds = synthetic.standardize(gen(key, n=n, n_test=256))
    d = ds.X.shape[1]
    kfn = cov.make_kernel("se")
    ls = 1.2 if domain == "aimpeak" else 4.5
    params = cov.init_params(d, signal=1.0, noise=0.3, lengthscale=ls,
                             dtype=jnp.float32)
    runner = VmapRunner(M=M)

    for P in values:
        S = support.select_support(kfn, params, ds.X[:min(n, 2048)], P)
        for name, fn in (
            ("ppitc", lambda: ppitc.predict(kfn, params, S, ds.X, ds.y,
                                            ds.X_test, runner)),
            ("ppic", lambda: ppic.predict(kfn, params, S, ds.X, ds.y,
                                          ds.X_test, runner)),
            ("picf", lambda: picf.predict(kfn, params, ds.X, ds.y,
                                          ds.X_test, P, runner,
                                          shard_u=True)),
        ):
            t = common.timeit(jax.jit(lambda fn=fn: fn().mean))
            post = fn()
            neg_var = float(jnp.mean((post.var < 0).astype(jnp.float32)))
            common.emit(
                f"fig3/{domain}/{name}/P{P}", t,
                f"rmse={common.rmse(post.mean, ds.y_test):.4f};"
                f"mnlp={common.mnlp(post.mean, post.var, ds.y_test):.3f};"
                f"neg_var_frac={neg_var:.3f}")
