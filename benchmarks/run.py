"""Benchmark harness entry point — one module per paper table/figure plus
the beyond-paper fault/kernel/serving/LM benches. Prints
``name,us_per_call,derived`` CSV rows (and collects them in
benchmarks.common.ROWS).

    PYTHONPATH=src python -m benchmarks.run            # full
    PYTHONPATH=src python -m benchmarks.run --quick    # CI-sized
    PYTHONPATH=src python -m benchmarks.run --smoke    # toy sizes, seconds
    PYTHONPATH=src python -m benchmarks.run --only fig1
"""
from __future__ import annotations

import argparse
import sys


def main() -> None:
    ap = argparse.ArgumentParser()
    ap.add_argument("--quick", action="store_true")
    ap.add_argument("--smoke", action="store_true",
                    help="toy sizes for every suite — exercises the whole "
                         "harness in seconds (CI), including the routed "
                         "serve path and the deadline-flusher p99 "
                         "simulation")
    ap.add_argument("--only", default=None,
                    help="substring filter: fig1|fig2|fig3|table1|fault|"
                         "kernel|serve|lm")
    ap.add_argument("--json", default=None, metavar="PATH",
                    help="write machine-readable results (rows + headline "
                         "metrics such as amortized speedup and p50/p99 "
                         "serve latency) to PATH, e.g. BENCH_serve.json — "
                         "the cross-PR perf trajectory file")
    args = ap.parse_args()
    quick = args.quick or args.smoke

    from benchmarks import (bench_complexity, bench_fault, bench_kernels,
                            bench_lm_smoke, bench_serve_latency,
                            bench_vary_data, bench_vary_machines,
                            bench_vary_param)

    # --smoke shrinks the swept axes to single toy points on top of quick=True
    fig1_sizes = (256,) if args.smoke else bench_vary_data.SIZES
    fig2_machines = (2, 4) if args.smoke else bench_vary_machines.MS
    fig3_values = bench_vary_param.PARAMS[:1] if args.smoke \
        else bench_vary_param.PARAMS

    suites = [
        ("fig1", lambda: [bench_vary_data.run("aimpeak", sizes=fig1_sizes,
                                              quick=quick),
                          bench_vary_data.run("sarcos", sizes=fig1_sizes,
                                              quick=quick)]),
        ("fig2", lambda: bench_vary_machines.run("aimpeak",
                                                 machines=fig2_machines,
                                                 quick=quick)),
        ("fig3", lambda: bench_vary_param.run("aimpeak", values=fig3_values,
                                              quick=quick)),
        ("table1", lambda: bench_complexity.run(quick=quick)),
        ("fault", lambda: bench_fault.run(quick=quick)),
        ("kernel", lambda: bench_kernels.run(quick=quick)),
        ("serve", lambda: bench_serve_latency.run(quick=args.quick,
                                                  smoke=args.smoke)),
        ("lm", lambda: bench_lm_smoke.run(quick=quick)),
    ]
    print("name,us_per_call,derived")
    failures = []
    for name, fn in suites:
        if args.only and args.only not in name:
            continue
        try:
            fn()
        except Exception as e:  # keep the harness going, report at exit
            failures.append((name, repr(e)))
            print(f"{name}/ERROR,0,{e!r}")
    if args.json:
        # written even on failure: a partial trajectory beats none, and the
        # exit code still flags the run
        from benchmarks import common
        common.write_json(args.json, argv=sys.argv[1:])
        print(f"wrote {args.json} ({len(common.ROWS)} rows, "
              f"{len(common.METRICS)} metrics)")
    if failures:
        sys.exit(1)


if __name__ == "__main__":
    main()
