"""Benchmark harness entry point — one module per paper table/figure plus
the beyond-paper fault/kernel/LM benches. Prints ``name,us_per_call,derived``
CSV rows (and collects them in benchmarks.common.ROWS).

    PYTHONPATH=src python -m benchmarks.run            # full
    PYTHONPATH=src python -m benchmarks.run --quick    # CI-sized
    PYTHONPATH=src python -m benchmarks.run --only fig1
"""
from __future__ import annotations

import argparse
import sys


def main() -> None:
    ap = argparse.ArgumentParser()
    ap.add_argument("--quick", action="store_true")
    ap.add_argument("--only", default=None,
                    help="substring filter: fig1|fig2|fig3|table1|fault|"
                         "kernel|lm")
    args = ap.parse_args()

    from benchmarks import (bench_complexity, bench_fault, bench_kernels,
                            bench_lm_smoke, bench_vary_data,
                            bench_vary_machines, bench_vary_param)

    suites = [
        ("fig1", lambda: [bench_vary_data.run("aimpeak", quick=args.quick),
                          bench_vary_data.run("sarcos", quick=args.quick)]),
        ("fig2", lambda: bench_vary_machines.run("aimpeak",
                                                 quick=args.quick)),
        ("fig3", lambda: bench_vary_param.run("aimpeak", quick=args.quick)),
        ("table1", lambda: bench_complexity.run(quick=args.quick)),
        ("fault", lambda: bench_fault.run(quick=args.quick)),
        ("kernel", lambda: bench_kernels.run(quick=args.quick)),
        ("lm", lambda: bench_lm_smoke.run(quick=args.quick)),
    ]
    print("name,us_per_call,derived")
    failures = []
    for name, fn in suites:
        if args.only and args.only not in name:
            continue
        try:
            fn()
        except Exception as e:  # keep the harness going, report at exit
            failures.append((name, repr(e)))
            print(f"{name}/ERROR,0,{e!r}")
    if failures:
        sys.exit(1)


if __name__ == "__main__":
    main()
