"""Paper Table 1: empirical scaling-exponent check of the complexity rows.

Fits log-log slopes of measured time vs |D| (fixed M, |S|): pPITC/pPIC per-
machine work should scale ~ (|D|/M)^3 block-cholesky once |D| dominates the
|S|-terms; FGP ~ |D|^3. Slopes are reported in the derived column."""
from __future__ import annotations


import jax
import jax.numpy as jnp
import numpy as np

from repro.core import covariance as cov, gp, ppic, ppitc, support
from repro.data import synthetic
from repro.parallel.runner import VmapRunner

from benchmarks import common

SIZES = (512, 1024, 2048)
M = 8
S_SIZE = 64


def _slope(xs, ts):
    lx, lt = np.log(np.asarray(xs)), np.log(np.asarray(ts))
    return float(np.polyfit(lx, lt, 1)[0])


def run(quick: bool = False):
    key = jax.random.PRNGKey(3)
    sizes = SIZES[:2] if quick else SIZES
    kfn = cov.make_kernel("se")
    runner = VmapRunner(M=M)
    times = {"fgp": [], "ppitc": [], "ppic": []}
    for n in sizes:
        ds = synthetic.standardize(synthetic.aimpeak_like(key, n=n,
                                                          n_test=64))
        params = cov.init_params(5, signal=1.0, noise=0.3, lengthscale=1.2,
                                 dtype=jnp.float32)
        S = support.select_support(kfn, params, ds.X[:512], S_SIZE)
        times["fgp"].append(common.timeit(jax.jit(
            lambda: gp.predict(kfn, params, ds.X, ds.y, ds.X_test,
                               diag_only=True).mean)))
        times["ppitc"].append(common.timeit(jax.jit(
            lambda: ppitc.predict(kfn, params, S, ds.X, ds.y, ds.X_test,
                                  runner).mean)))
        times["ppic"].append(common.timeit(jax.jit(
            lambda: ppic.predict(kfn, params, S, ds.X, ds.y, ds.X_test,
                                 runner).mean)))
    for name, ts in times.items():
        common.emit(f"table1/{name}/slope", ts[-1],
                    f"loglog_slope={_slope(sizes, ts):.2f};"
                    f"times_us={';'.join(f'{t:.0f}' for t in ts)}")
