"""Beyond-paper: fault-tolerance / straggler benchmarks enabled by the
summary algebra (Sec. 5.2 + DESIGN.md §5): accuracy vs straggler deadline,
failure-recovery cost vs full recompute, online assimilation cost."""
from __future__ import annotations

import jax
import jax.numpy as jnp

from repro.core import covariance as cov, online, support
from repro.data import synthetic
from repro.parallel.runner import VmapRunner
from repro.runtime import straggler

from benchmarks import common

N, M, S_SIZE = 2048, 16, 128


def run(quick: bool = False):
    key = jax.random.PRNGKey(4)
    n = 512 if quick else N
    ds = synthetic.standardize(synthetic.aimpeak_like(key, n=n, n_test=256))
    kfn = cov.make_kernel("se")
    params = cov.init_params(5, signal=1.0, noise=0.3, lengthscale=1.0,
                             dtype=jnp.float32)
    S = support.select_support(kfn, params, ds.X[:512], S_SIZE)
    runner = VmapRunner(M=M)

    t_build = common.timeit(lambda: jax.tree.leaves(online.build(
        kfn, params, S, ds.X, ds.y, runner))[0])
    store = online.build(kfn, params, S, ds.X, ds.y, runner)

    # straggler deadline sweep
    rows = straggler.simulate(key, store, kfn, params, S, ds.X_test,
                              ds.y_test, deadlines=(1.2, 2.0, 5.0, 50.0))
    for r in rows:
        common.emit(f"fault/straggler/deadline{r['deadline']}", t_build,
                    f"fraction={r['fraction']:.2f};rmse={r['rmse']:.4f}")

    # failure recovery: re-aggregation vs full rebuild
    t_recover = common.timeit(lambda: jax.tree.leaves(
        online.global_summary(online.retire(store, 3)))[0])
    common.emit("fault/recover_degraded", t_recover,
                f"full_rebuild_us={t_build:.0f};"
                f"speedup_vs_rebuild={t_build / max(t_recover, 1e-9):.1f}")

    # online assimilation of one new block vs rebuild
    X2 = ds.X[: n // M]
    y2 = ds.y[: n // M]
    t_assim = common.timeit(lambda: jax.tree.leaves(online.assimilate(
        store, kfn, params, S, X2, y2, VmapRunner(M=1)))[0])
    common.emit("fault/online_assimilate_block", t_assim,
                f"full_rebuild_us={t_build:.0f}")
