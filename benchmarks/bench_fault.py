"""Beyond-paper: fault-tolerance / straggler benchmarks enabled by the
summary algebra (Sec. 5.2 + DESIGN.md §5): accuracy vs straggler deadline,
failure-recovery cost vs full recompute, online assimilation cost, the
incremental (rank-b cholupdate) ``to_state`` vs a cold refit — all through
the ``api.StateStore`` protocol serving uses — plus the self-healing
serving loop under deterministic fault injection (``serving.chaos``):
injected block failure mid-stream, auto-retire + degraded routed serving,
checkpoint revive, and the recovery metrics the CI chaos job tracks."""
from __future__ import annotations

import dataclasses
import os
import tempfile
import time

import jax
import jax.numpy as jnp
import numpy as np

from repro.core import api, covariance as cov, serialize, support
from repro.data import synthetic
from repro.parallel.runner import VmapRunner
from repro.runtime import straggler
from repro.serving import (FaultInjector, FaultPlan, HealthPolicy,
                           TenantScheduler)

from benchmarks import common

N, M, S_SIZE = 2048, 16, 128


def run(quick: bool = False):
    key = jax.random.PRNGKey(4)
    n = 512 if quick else N
    ds = synthetic.standardize(synthetic.aimpeak_like(key, n=n, n_test=256))
    kfn = cov.make_kernel("se")
    params = cov.init_params(5, signal=1.0, noise=0.3, lengthscale=1.0,
                             dtype=jnp.float32)
    S = support.select_support(kfn, params, ds.X[:512], S_SIZE)
    runner = VmapRunner(M=M)

    build = lambda: api.init_store("ppitc", kfn, params, ds.X, ds.y, S=S,
                                   runner=runner)
    t_build = common.timeit(
        lambda: jax.tree.leaves(build().store)[0])
    store = build()

    # straggler deadline sweep
    rows = straggler.simulate(key, store, ds.X_test, ds.y_test,
                              deadlines=(1.2, 2.0, 5.0, 50.0))
    for r in rows:
        common.emit(f"fault/straggler/deadline{r['deadline']}", t_build,
                    f"fraction={r['fraction']:.2f};rmse={r['rmse']:.4f}")

    # failure recovery: rank-b downdate + O(s^2) to_state vs full rebuild
    t_recover = common.timeit(lambda: jax.tree.leaves(
        store.retire(3).to_state())[0])
    common.emit("fault/recover_degraded", t_recover,
                f"full_rebuild_us={t_build:.0f};"
                f"speedup_vs_rebuild={t_build / max(t_recover, 1e-9):.1f}")

    # online assimilation + incremental to_state vs rebuild, over wave size:
    # the rank-b cholupdate path is O(|S|^2 b), so the win over the O(|S|^3
    # + n b^2) rebuild grows as b shrinks below |S| (b == |S| is the
    # flop-parity point — same O(|S|^3), sweep-sequential constants)
    st1 = dataclasses.replace(store, runner=VmapRunner(M=1))
    for b in sorted({8, 32, n // M}):
        X2, y2 = ds.X[:b], ds.y[:b]
        t_assim = common.timeit(lambda: jax.tree.leaves(
            st1.assimilate(X2, y2).to_state())[0])
        common.emit(f"fault/online_assimilate_b{b}", t_assim,
                    f"full_rebuild_us={t_build:.0f};"
                    f"speedup_vs_rebuild={t_build / max(t_assim, 1e-9):.1f}")
        if b == 8:
            common.metric("assimilate_b8_speedup_vs_rebuild",
                          t_build / max(t_assim, 1e-9))

    # --- self-healing serving under injected faults ------------------------
    # one block dies mid-stream (serving/chaos.py, deterministic schedule);
    # the health ladder (serving/health.py) retries, auto-retires it from
    # routing, serves its stranded queries degraded from the global
    # posterior, and revives it from the last save_store checkpoint — all
    # with zero recompiles. The emitted metrics are the CI chaos job's
    # recovery trajectory.
    pic_store = api.init_store("ppic", kfn, params, ds.X, ds.y, S=S,
                               runner=runner)
    model = api.FittedGP(api.get("ppic"), kfn, params, pic_store.to_state())
    flush_u = 16
    spec = api.ServeSpec(max_batch=flush_u, routed=True)
    ckpt = os.path.join(tempfile.mkdtemp(prefix="bench_fault_"), "store.npz")
    serialize.save_store(ckpt, pic_store, spec=spec)
    policy = HealthPolicy(max_retries=2, max_consecutive_failures=1,
                          backoff_base_ms=0.1, checkpoint=ckpt,
                          revive_after_ms=0.0)
    # the victim answers flushes 0..1, dies for the next few dispatch
    # attempts, and would answer again after — the transient-fault shape
    # whose end state must be bitwise-indistinguishable from no fault.
    # Target the block the faulted flush actually routes the most queries
    # to, so the injected death is guaranteed to strand real traffic.
    from repro.core import clustering
    U = np.asarray(ds.X_test[:flush_u * 8])
    centroids = np.asarray(model.state.centroids)
    victim = int(np.bincount(
        clustering.nearest_center_np(U[2 * flush_u:3 * flush_u], centroids),
        minlength=centroids.shape[0]).argmax())
    injector = FaultInjector(FaultPlan(fail_at={victim: (2, 6)}))
    sched = TenantScheduler()
    tenant = sched.admit("chaos", model, spec, store=pic_store,
                         health=policy, chaos=injector)
    tenant.plan.warmup(ds.X.shape[1])
    traces0 = tenant.plan.stats.n_traces
    oracle = model.plan(api.ServeSpec(max_batch=flush_u, routed=True))

    flush_us, tickets = [], []
    for f in range(8):
        rows = U[f * flush_u:(f + 1) * flush_u]
        tk0 = tenant.next_ticket
        for x in rows:
            sched.submit("chaos", x)
        t0 = time.perf_counter()
        sched.flush("chaos")
        sched.sync("chaos")
        flush_us.append((time.perf_counter() - t0) * 1e6)
        tickets.append(list(range(tk0, tenant.next_ticket)))
        sched.pump()        # revive opportunity once the window passes
    outs = {tk: sched.collect("chaos", tk)
            for f in tickets for tk in f}
    assert all(np.isfinite(m).all() and np.isfinite(v).all()
               for m, v, _ in outs.values()), \
        "self-healing serving returned non-finite posteriors"
    # post-revive flushes must be bitwise what a never-faulted plan serves
    last = tickets[-1]
    ref_m, ref_v = oracle.routed_diag(U[(len(tickets) - 1) * flush_u:
                                        len(tickets) * flush_u])
    ref_m, ref_v = np.asarray(ref_m), np.asarray(ref_v)
    post_bitwise = all(
        np.array_equal(np.asarray(outs[tk][0]), ref_m[i])
        and np.array_equal(np.asarray(outs[tk][1]), ref_v[i])
        and not outs[tk][2]
        for i, tk in enumerate(last))
    serving_traces = tenant.plan.stats.n_traces - traces0
    st = tenant.stats
    healthy_us = float(np.median([flush_us[0], flush_us[-1]]))
    faulted_us = float(max(flush_us))
    common.emit("fault/chaos/flush_healthy", healthy_us,
                f"flushes={len(flush_us)}")
    common.emit("fault/chaos/flush_faulted", faulted_us,
                f"retries={st.n_retries};auto_retired={st.n_auto_retired}")
    common.emit("fault/chaos/recovery", faulted_us,
                f"degraded_rows={st.n_degraded_rows};"
                f"revives={st.n_revives};post_revive_bitwise={post_bitwise};"
                f"serving_traces={serving_traces}")
    common.metric("chaos_degraded_rows", st.n_degraded_rows)
    common.metric("chaos_auto_retired", st.n_auto_retired)
    common.metric("chaos_revives", st.n_revives)
    common.metric("chaos_post_revive_bitwise", float(post_bitwise))
    common.metric("chaos_serving_traces", serving_traces)
    assert st.n_auto_retired >= 1 and st.n_revives >= 1, \
        f"chaos scenario never exercised the ladder: {st.snapshot()}"
    assert serving_traces == 0, \
        f"self-healing serving recompiled {serving_traces}x mid-stream"
    assert post_bitwise, "post-revive serving is not bitwise-identical"
