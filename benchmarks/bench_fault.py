"""Beyond-paper: fault-tolerance / straggler benchmarks enabled by the
summary algebra (Sec. 5.2 + DESIGN.md §5): accuracy vs straggler deadline,
failure-recovery cost vs full recompute, online assimilation cost, and the
incremental (rank-b cholupdate) ``to_state`` vs a cold refit — all through
the ``api.StateStore`` protocol serving uses."""
from __future__ import annotations

import dataclasses

import jax
import jax.numpy as jnp

from repro.core import api, covariance as cov, support
from repro.data import synthetic
from repro.parallel.runner import VmapRunner
from repro.runtime import straggler

from benchmarks import common

N, M, S_SIZE = 2048, 16, 128


def run(quick: bool = False):
    key = jax.random.PRNGKey(4)
    n = 512 if quick else N
    ds = synthetic.standardize(synthetic.aimpeak_like(key, n=n, n_test=256))
    kfn = cov.make_kernel("se")
    params = cov.init_params(5, signal=1.0, noise=0.3, lengthscale=1.0,
                             dtype=jnp.float32)
    S = support.select_support(kfn, params, ds.X[:512], S_SIZE)
    runner = VmapRunner(M=M)

    build = lambda: api.init_store("ppitc", kfn, params, ds.X, ds.y, S=S,
                                   runner=runner)
    t_build = common.timeit(
        lambda: jax.tree.leaves(build().store)[0])
    store = build()

    # straggler deadline sweep
    rows = straggler.simulate(key, store, ds.X_test, ds.y_test,
                              deadlines=(1.2, 2.0, 5.0, 50.0))
    for r in rows:
        common.emit(f"fault/straggler/deadline{r['deadline']}", t_build,
                    f"fraction={r['fraction']:.2f};rmse={r['rmse']:.4f}")

    # failure recovery: rank-b downdate + O(s^2) to_state vs full rebuild
    t_recover = common.timeit(lambda: jax.tree.leaves(
        store.retire(3).to_state())[0])
    common.emit("fault/recover_degraded", t_recover,
                f"full_rebuild_us={t_build:.0f};"
                f"speedup_vs_rebuild={t_build / max(t_recover, 1e-9):.1f}")

    # online assimilation + incremental to_state vs rebuild, over wave size:
    # the rank-b cholupdate path is O(|S|^2 b), so the win over the O(|S|^3
    # + n b^2) rebuild grows as b shrinks below |S| (b == |S| is the
    # flop-parity point — same O(|S|^3), sweep-sequential constants)
    st1 = dataclasses.replace(store, runner=VmapRunner(M=1))
    for b in sorted({8, 32, n // M}):
        X2, y2 = ds.X[:b], ds.y[:b]
        t_assim = common.timeit(lambda: jax.tree.leaves(
            st1.assimilate(X2, y2).to_state())[0])
        common.emit(f"fault/online_assimilate_b{b}", t_assim,
                    f"full_rebuild_us={t_build:.0f};"
                    f"speedup_vs_rebuild={t_build / max(t_assim, 1e-9):.1f}")
        if b == 8:
            common.metric("assimilate_b8_speedup_vs_rebuild",
                          t_build / max(t_assim, 1e-9))
